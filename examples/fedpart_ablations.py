"""Ablations from the paper at CPU scale: selection order (Table 7) and
warm-up duration (Table 6) on the synthetic vision task.

    PYTHONPATH=src python examples/fedpart_ablations.py
"""

import sys

sys.path.insert(0, "src")

from repro.core.schedule import FedPartSchedule
from repro.data import (VisionDatasetSpec, balanced_eval_set, build_clients,
                        iid_partition, make_vision_dataset)
from repro.fl import FLRunConfig, resnet_task, run_federated


def run(order="sequential", warmup=2):
    spec = VisionDatasetSpec(num_classes=8, image_size=16, noise=1.0)
    X, y = make_vision_dataset(spec, 1000, seed=0)
    Xe, ye = make_vision_dataset(spec, 500, seed=9)
    eval_set = balanced_eval_set(Xe, ye, per_class=24)
    clients = build_clients(X, y, iid_partition(len(y), 4, seed=0))
    adapter = resnet_task("resnet8", num_classes=8)
    schedule = FedPartSchedule(num_groups=10, warmup_rounds=warmup,
                               rounds_per_layer=1, cycles=1, order=order)
    cfg = FLRunConfig(local_epochs=1, batch_size=32, lr=1e-3)
    return run_federated(adapter, clients, eval_set, schedule.rounds(), cfg)


def main():
    print("--- selection order (paper Table 7: seq > rev > rand) ---")
    for order in ("sequential", "reverse", "random"):
        res = run(order=order)
        print(f"order={order:10s} best_acc={res.best_acc:.4f}")

    print("--- warm-up rounds (paper Table 6) ---")
    for warmup in (0, 2, 5):
        res = run(warmup=warmup)
        print(f"warmup={warmup} best_acc={res.best_acc:.4f}")


if __name__ == "__main__":
    main()
