"""FedPart as a datacenter training feature: the round schedule driving the
mesh-parallel partial train steps on an assigned architecture — gradients,
optimizer state, and the per-round transmitted bytes all scoped to the
scheduled layer group.

    PYTHONPATH=src python examples/fedpart_mesh_training.py --arch gemma-2b
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.fedtrain import main as fedtrain_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--rounds", type=int, default=6)
    args = ap.parse_args()
    fedtrain_main([
        "--arch", args.arch, "--rounds", str(args.rounds),
        "--steps-per-round", "3", "--batch", "4", "--seq", "32",
    ])


if __name__ == "__main__":
    main()
