"""Quickstart: FedPart vs. full-network updates (FedAvg) on a synthetic
federated vision task — the paper's core comparison (Table 1) at CPU scale.

Runs both strategies with a matched round budget, prints accuracy curves and
the communication/computation ledger.  ~2-4 minutes on CPU.

--engine selects the client-simulation engine (docs/ENGINES.md).  The default
is the sequential oracle: this demo's conv model hits the batched engines'
grouped-conv slow path on XLA:CPU; on accelerator backends (or matmul models —
see benchmarks/engine_bench.py) pick --engine vmap, or --engine shard_map with
--sim-devices N to spread clients over N devices.

    PYTHONPATH=src python examples/quickstart.py \
        [--engine sequential|vmap|shard_map] [--sim-devices N]

--population N streams the federation from N virtual clients whose shards
derive on demand from (seed, client_id) — try --population 1000000
--cohort-size 4: same demo, million-client fleet (docs/POPULATION.md).
"""

import argparse
import sys

sys.path.insert(0, "src")

if __name__ == "__main__":
    # shard_map on CPU: simulate N host devices (must precede the jax import
    # that repro pulls in below).
    from repro.launch._simdev import force_sim_devices
    force_sim_devices()


from repro.core.schedule import FedPartSchedule, matched_fnu
from repro.data import (VisionDatasetSpec, balanced_eval_set, build_clients,
                        iid_partition, make_vision_dataset)
from repro.fl import FLRunConfig, resnet_task, run_federated


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--engine", choices=["sequential", "vmap", "shard_map"],
                    default="sequential",
                    help="client-simulation engine (see module docstring)")
    ap.add_argument("--sim-devices", type=int, default=0,
                    help="shard_map mesh size (0 = all visible devices)")
    ap.add_argument("--plan", choices=["homogeneous", "nested", "random"],
                    default="homogeneous",
                    help="per-client layer plan (docs/HETEROGENEITY.md): "
                         "capacity-tiered clients train different group "
                         "subsets in the same round")
    ap.add_argument("--capacity-tiers", type=float, nargs="*", default=[],
                    help="tier capacity fractions in (0, 1], clients "
                         "round-robin (e.g. 0.3 0.6 1.0)")
    ap.add_argument("--compression",
                    choices=["none", "int8", "onebit", "topk"],
                    default="none",
                    help="compress the transmitted subtree (int8 / 1-bit / "
                         "top-k with error feedback, docs/COMPRESSION.md); "
                         "the comm column then prices the encoded bytes")
    ap.add_argument("--population", type=int, default=0,
                    help="stream N virtual clients from a seeded "
                         "SyntheticPopulation (docs/POPULATION.md) instead of "
                         "materialising 4 shards; per-round cost is O(cohort)")
    ap.add_argument("--cohort-size", type=int, default=0,
                    help="explicit clients per round (0 = full participation "
                         "of a materialised fleet, or 4 under --population)")
    args = ap.parse_args(argv)

    spec = VisionDatasetSpec(num_classes=8, image_size=16, noise=1.0)
    Xe, ye = make_vision_dataset(spec, 600, seed=99)
    eval_set = balanced_eval_set(Xe, ye, per_class=24)
    if args.population > 0:
        from repro.fl.population import SyntheticPopulation
        clients = SyntheticPopulation(spec=spec, population=args.population,
                                      samples_per_client=300, seed=0)
        cohort = args.cohort_size or 4
    else:
        X, y = make_vision_dataset(spec, 1200, seed=0)
        clients = build_clients(X, y, iid_partition(len(y), 4, seed=0))
        cohort = args.cohort_size
    adapter = resnet_task("resnet8", num_classes=8)

    schedule = FedPartSchedule(num_groups=10, warmup_rounds=2,
                               rounds_per_layer=1, cycles=1)
    run_cfg = FLRunConfig(local_epochs=1, batch_size=32, lr=1e-3,
                          engine=args.engine, sim_devices=args.sim_devices,
                          plan=args.plan,
                          capacity_tiers=tuple(args.capacity_tiers),
                          compression=args.compression,
                          cohort_size=cohort)

    print(f"=== FedPart (partial network updates) [engine={args.engine}"
          + (f", plan={args.plan}" if args.plan != "homogeneous" else "")
          + (f", compression={args.compression}"
             if args.compression != "none" else "")
          + "] ===")
    fp = run_federated(adapter, clients, eval_set, schedule.rounds(), run_cfg,
                       verbose=True)
    print("\n=== FedAvg-FNU (full network updates, matched rounds) ===")
    fnu = run_federated(adapter, clients, eval_set,
                        matched_fnu(schedule).rounds(), run_cfg, verbose=True)

    print("\n================ summary ================")
    print(f"{'':12s} {'best acc':>9s} {'comm (MB)':>10s} {'comp ratio':>10s}")
    print(f"{'FedPart':12s} {fp.best_acc:9.4f} {fp.comm_total_bytes/1e6:10.1f} "
          f"{fp.comp_total_flops/fp.comp_fnu_flops:10.2%}")
    print(f"{'FedAvg-FNU':12s} {fnu.best_acc:9.4f} {fnu.comm_total_bytes/1e6:10.1f} "
          f"{'100.00%':>10s}")
    print(f"\nFedPart comm = {fp.comm_total_bytes/fnu.comm_total_bytes:.1%} of FNU "
          f"(paper Eq. 5: partial rounds move 1/M of the bytes)")


if __name__ == "__main__":
    main()
