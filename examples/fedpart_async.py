"""FedPart under the async runtime: sync barrier vs FedBuff on a straggling
fleet.

The synchronous loop pays for every round's slowest client; the async runtime
(``repro.fl.runtime``, docs/ASYNC.md) merges as soon as K updates arrive and
discounts stale ones polynomially, so the virtual clock — not the round
counter — decides which strategy wins.  This demo runs the same FedPart
schedule two ways on a fleet with heavy compute heterogeneity and compares
*time-to-accuracy* on the shared virtual timeline (both use the event-driven
runtime, which is what books virtual time; the barrier *policy* has exactly
the synchronous loop's semantics — tests/test_async_runtime.py pins that):

1. barrier policy (``async_policy="sync"``) — synchronous FedAvg as an
   event-driven policy: every merge waits for the round's slowest client;
2. FedBuff (K = a quarter of the fleet, staleness exponent 0.5) — merges
   early, stragglers land stale and discounted;
3. FedBuff + host-parallel dispatch (``--max-inflight``, default 2) — the
   server keeps several cohorts training concurrently, each on its own
   disjoint device submesh, so the virtual clock (and the host) overlap
   cohorts instead of serialising them on merges.

Uses the tiny-transformer NLP task (fast on CPU; the conv model would hit
the vmap grouped-conv slow path — docs/ENGINES.md).  ~1-2 minutes.

    PYTHONPATH=src python examples/fedpart_async.py [--clients 8]
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.configs.base import get_config
from repro.core.schedule import FedPartSchedule
from repro.data import (TextDatasetSpec, balanced_eval_set, build_clients,
                        iid_partition, make_text_dataset)
from repro.fl import AvailabilityConfig, FLRunConfig, nlp_task, run_federated


def setup(clients: int, samples_per_client: int = 48):
    cfg = get_config("nlp-transformer", smoke=True).with_(
        num_layers=2, d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
        vocab_size=256, max_position_embeddings=16)
    spec = TextDatasetSpec(num_classes=4, vocab_size=256, seq_len=16)
    X, y = make_text_dataset(spec, samples_per_client * clients, seed=0)
    Xe, ye = make_text_dataset(spec, 400, seed=99)
    eval_set = balanced_eval_set(Xe, ye, per_class=32)
    data = build_clients(X, y, iid_partition(len(y), clients, seed=0))
    return nlp_task(num_classes=4, cfg=cfg), data, eval_set


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=16)
    ap.add_argument("--speed-spread", type=float, default=4.0,
                    help="fleet heterogeneity (4.0: ~25x fastest-to-slowest)")
    ap.add_argument("--threshold", type=float, default=0.5,
                    help="accuracy threshold for time-to-accuracy")
    ap.add_argument("--max-inflight", type=int, default=2,
                    help="in-flight cohorts for the host-parallel variant")
    ap.add_argument("--plan", choices=["homogeneous", "nested", "random"],
                    default="homogeneous",
                    help="per-client layer plan for every variant "
                         "(docs/HETEROGENEITY.md); pair with "
                         "--capacity-tiers to give the straggling fleet "
                         "capacity-matched group subsets")
    ap.add_argument("--capacity-tiers", type=float, nargs="*", default=[],
                    help="tier capacity fractions in (0, 1], clients "
                         "round-robin (e.g. 0.5 1.0)")
    args = ap.parse_args(argv)

    adapter, data, eval_set = setup(args.clients)
    sched = FedPartSchedule(num_groups=4, warmup_rounds=2, rounds_per_layer=2,
                            cycles=2, bridge_rounds=1)
    rounds = sched.rounds()[: args.rounds]
    fleet = AvailabilityConfig(speed_spread=args.speed_spread,
                               latency_jitter=0.2, seed=7)
    base = dict(local_epochs=1, batch_size=16, lr=3e-3, engine="vmap",
                sample_fraction=0.5, availability=fleet, plan=args.plan,
                capacity_tiers=tuple(args.capacity_tiers))

    variants = [
        ("sync barrier", FLRunConfig(**base, runtime="async",
                                     async_policy="sync")),
        ("fedbuff K=n/4", FLRunConfig(**base, runtime="async",
                                      async_policy="fedbuff",
                                      buffer_k=max(1, args.clients // 4),
                                      staleness_exponent=0.5)),
        (f"fedbuff x{args.max_inflight} inflight",
         FLRunConfig(**base, runtime="async", async_policy="fedbuff",
                     buffer_k=max(1, args.clients // 4),
                     staleness_exponent=0.5,
                     max_inflight_cohorts=args.max_inflight)),
    ]

    print(f"fleet: {args.clients} clients, speed spread {args.speed_spread} "
          f"(speeds span ~{(1 + args.speed_spread) ** 2:.0f}x), 50% sampled "
          f"per dispatch\n")
    rows = []
    for name, cfg in variants:
        t0 = time.time()
        res = run_federated(adapter, data, eval_set, rounds, cfg)
        tta = res.timeline.time_to_accuracy(args.threshold)
        stale = max((h["staleness_max"] for h in res.history), default=0)
        overlap = res.timeline.overlap_seconds()
        rows.append((name, res.best_acc, res.timeline.total_seconds, tta,
                     stale, overlap))
        print(f"[{name:22s}] wall={time.time()-t0:5.1f}s "
              f"virtual={res.timeline.total_seconds:8.2f}s "
              f"best_acc={res.best_acc:.4f} "
              f"tta@{args.threshold:.2f}="
              f"{'never' if np.isinf(tta) else f'{tta:.2f}s'} "
              f"max_staleness={stale} overlap={overlap:.2f}s")

    print("\n=================== summary (virtual time) ===================")
    print(f"{'variant':24s} {'best acc':>9s} {'total (s)':>10s} "
          f"{'tta (s)':>9s} {'staleness':>9s} {'overlap':>8s}")
    for name, acc, total, tta, stale, overlap in rows:
        tta_s = "never" if np.isinf(tta) else f"{tta:.2f}"
        print(f"{name:24s} {acc:9.4f} {total:10.2f} {tta_s:>9s} {stale:9d} "
              f"{overlap:8.2f}")
    print("\nFedBuff merges at K updates instead of waiting for the slowest "
          "straggler,\nso its virtual clock advances ~K/cohort as fast; stale "
          "updates merge against\nthe *current* frozen context with "
          "polynomially discounted weight.  With\n--max-inflight > 1 the "
          "server additionally keeps several cohorts training at\nonce on "
          "disjoint submeshes — overlap shows how much of the run ran "
          "concurrently\n(docs/ASYNC.md).")


if __name__ == "__main__":
    main()
