"""Batched serving example: prefill + greedy decode on any assigned
architecture (smoke scale on CPU).  Exercises the same prefill/serve steps
the production dry-run lowers at 32k/500k.

    PYTHONPATH=src python examples/serve_llm.py --arch tinyllama-1.1b
    PYTHONPATH=src python examples/serve_llm.py --arch xlstm-125m      # SSM
    PYTHONPATH=src python examples/serve_llm.py --arch deepseek-v3-671b # MLA+MoE
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.launch.serve import serve_session


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    out = serve_session(cfg, args.batch, args.prompt_len, args.gen)
    print(f"[serve_llm] {args.arch}: generated token grid {out.shape}")
    print(out)


if __name__ == "__main__":
    main()
