"""FedPart on the language modality (paper Table 3): federated text
classification with the small transformer, FedPart vs FNU, plus the
FedProx composition.

    PYTHONPATH=src python examples/fedpart_language.py
"""

import sys

sys.path.insert(0, "src")

from repro.core.schedule import FedPartSchedule, matched_fnu
from repro.data import (TextDatasetSpec, balanced_eval_set, build_clients,
                        dirichlet_partition, make_text_dataset)
from repro.fl import AlgoConfig, FLRunConfig, nlp_task, run_federated


def main():
    spec = TextDatasetSpec(num_classes=4, vocab_size=512, seq_len=48)
    X, y = make_text_dataset(spec, 1600, seed=0)
    Xe, ye = make_text_dataset(spec, 800, seed=7)
    eval_set = balanced_eval_set(Xe, ye, per_class=48)
    # Mild heterogeneity (paper Table 4: Dirichlet alpha=1)
    clients = build_clients(X, y, dirichlet_partition(y, 4, alpha=1.0, seed=0))
    adapter = nlp_task(num_classes=4, smoke=True)

    # 2 blocks + embed + head = 4 groups for the smoke transformer
    schedule = FedPartSchedule(num_groups=4, warmup_rounds=2,
                               rounds_per_layer=2, cycles=2, bridge_rounds=1)

    for algo in ("fedavg", "fedprox"):
        run_cfg = FLRunConfig(local_epochs=2, batch_size=32, lr=1e-3,
                              algo=AlgoConfig(name=algo))
        fp = run_federated(adapter, clients, eval_set, schedule.rounds(), run_cfg)
        fnu = run_federated(adapter, clients, eval_set,
                            matched_fnu(schedule).rounds(), run_cfg)
        print(f"[{algo}] FedPart best={fp.best_acc:.4f} "
              f"(comm {fp.comm_total_bytes/fp.comm_fnu_bytes:.1%} of FNU) | "
              f"FNU best={fnu.best_acc:.4f}")


if __name__ == "__main__":
    main()
