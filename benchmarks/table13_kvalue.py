"""Paper Appendix G / Table 13: Monte-Carlo estimate of the mask-uniformity
constant k (Assumption 3) — should be close to 1."""

import time

import jax

from repro.core.partition import build_partition
from repro.core.telemetry import estimate_k
from repro.models import resnet


def run(quick: bool = True):
    params = resnet.resnet_init(jax.random.key(0), resnet.RESNET8, 8)
    part = build_partition(params, resnet.resnet_group_key, resnet.resnet_order_key)
    import jax.numpy as jnp

    label = jnp.arange(4) % 8

    def loss(p, x):
        logits, _ = resnet.resnet_apply(p, x, train=False)
        return resnet.cls_loss(logits, label)

    n = 6 if quick else 32
    t0 = time.time()
    grads = []
    for i in range(n):
        x = jax.random.normal(jax.random.key(i), (4, 16, 16, 3)) * 0.5
        grads.append(jax.grad(lambda p: loss(p, x))(params))
    k_rand = estimate_k(grads, part, params, masks="random",
                        num_masks=16 if quick else 64)
    k_grp = estimate_k(grads, part, params, masks="groups")
    dt = 1e6 * (time.time() - t0) / n
    return [
        {"name": "table13/k_random_masks", "us_per_call": dt,
         "derived": f"k={k_rand:.3f} (paper MC setting: 1.09-1.18)", "k": k_rand},
        {"name": "table13/k_layer_group_masks", "us_per_call": dt,
         "derived": f"k={k_grp:.1f} (structured masks strain Assumption 3)",
         "k": k_grp},
    ]
