"""Paper Table 5: training rounds per layer (R/L) ablation — more cycles of
shorter per-layer training beats fewer long cycles."""

from repro.fl import FLRunConfig

from benchmarks.common import fedpart_schedule, timed_run, vision_setup


def run(quick: bool = True):
    adapter, clients, eval_set = vision_setup(samples=500 if quick else 1500,
                                              clients=3)
    rows = []
    rls = [1, 2] if quick else [1, 2, 4]
    for rl in rls:
        schedule = fedpart_schedule(num_groups=10, rl=rl, warmup=1)
        cfg = FLRunConfig(local_epochs=1, batch_size=32, lr=1e-3)
        _, row = timed_run(f"table5/rl{rl}", adapter, clients, eval_set,
                           schedule.rounds(), cfg)
        rows.append(row)
    return rows
