"""Paper Table 7: trainable-layer selection order — sequential vs reverse vs
random (paper finds seq > rev ~ rand)."""

from repro.fl import FLRunConfig

from benchmarks.common import fedpart_schedule, timed_run, vision_setup


def run(quick: bool = True):
    adapter, clients, eval_set = vision_setup(samples=500 if quick else 1500,
                                              clients=3)
    rows = []
    for order in ("sequential", "reverse", "random"):
        schedule = fedpart_schedule(num_groups=10, order=order, warmup=1)
        cfg = FLRunConfig(local_epochs=1, batch_size=32, lr=1e-3)
        _, row = timed_run(f"table7/{order}", adapter, clients, eval_set,
                           schedule.rounds(), cfg)
        rows.append(row)
    return rows
