"""Heterogeneity bench: per-client layer plans across engines and tiers.

Under a heterogeneous plan (``FLRunConfig.plan``, docs/HETEROGENEITY.md) a
mixed cohort stops sharing one pruned single-group program: the batched
engines switch to the masked plan program (the per-client group bitmask is a
stacked batch input) and aggregation runs per-group participant-weighted
averaging.  This bench prices that machinery on the tiny-transformer NLP
regime (where the batched engines win on CPU — docs/ENGINES.md):

* per-round wall-clock for each plan kind (homogeneous / nested / random)
  under the vmap engine, with the homogeneous row doubling as the legacy
  baseline;
* ``speedup`` rows the CI bench lane gates (scale-free, benchmarks/compare.py):
  vmap vs sequential *under a nested plan*, and the plan-overhead ratio
  (homogeneous vs nested wall-clock — what switching the masked program on
  costs);
* **per-tier clients/s**: for the nested plan, each capacity tier's clients
  processed per second per device (``clients_per_sec_per_device``) — the
  scale-free throughput split the hetero scheduler actually delivers per
  tier.

    PYTHONPATH=src python benchmarks/hetero_bench.py --clients 8 --reps 3
    PYTHONPATH=src python benchmarks/hetero_bench.py --json hetero.json

``--json PATH`` writes the rows machine-readable (the ``BENCH_*.json``
trajectory format; BENCH_hetero.json is the committed baseline the bench CI
lane compares against).  Also exposes ``run(quick=True)`` for
``python -m benchmarks.run``.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, "src")
# repo root, so `benchmarks.common` resolves when run as a script too
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

if __name__ == "__main__":
    from repro.launch._simdev import force_sim_devices
    force_sim_devices()

import jax

from repro.configs.base import get_config
from repro.core.schedule import FULL_NETWORK, PlanAssigner, RoundSpec
from repro.data import (TextDatasetSpec, build_clients, iid_partition,
                        make_text_dataset)
from repro.fl import AlgoConfig, LocalTrainer, make_engine, nlp_task
from repro.optim.adam import AdamConfig

TIERS = (0.3, 0.6, 1.0)
PARTIAL_GROUP = 1


def _setup(clients: int, samples_per_client: int):
    cfg = get_config("nlp-transformer", smoke=True).with_(
        num_layers=1, d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
        vocab_size=256, max_position_embeddings=12)
    spec = TextDatasetSpec(num_classes=4, vocab_size=256, seq_len=12)
    X, y = make_text_dataset(spec, samples_per_client * clients, seed=0)
    adapter = nlp_task(num_classes=4, cfg=cfg)
    data = build_clients(X, y, iid_partition(len(y), clients, seed=0))
    params = adapter.init(jax.random.key(0))
    return adapter, data, params, adapter.partition(params)


def _time_plan_round(engine_name, adapter, data, params, partition, spec,
                     plan_kind, *, reps, batch_size=8, sim_devices=0):
    """Fresh trainer+engine, one warmup round (compile) then ``reps`` timed
    rounds of ``spec`` under ``plan_kind``.  Returns (sec/round, devices)."""
    algo = AlgoConfig()
    trainer = LocalTrainer(adapter=adapter, partition=partition, algo=algo,
                           adam=AdamConfig(lr=1e-3))
    engine = make_engine(engine_name, trainer=trainer, partition=partition,
                         algo=algo, sim_devices=sim_devices)
    assigner = PlanAssigner(num_groups=partition.num_groups, kind=plan_kind,
                            capacity_tiers=TIERS)
    plan = assigner.assign(spec, list(range(len(data))))
    seeds = list(range(len(data)))
    weights = [len(d) for d in data]
    import jax.numpy as jnp
    p = jax.tree.map(jnp.copy, params)   # donation-safe private copy

    def one_round(p):
        new_params, _, _ = engine.run_round(
            p, spec, data, seeds=seeds, weights=weights,
            epochs=1, batch_size=batch_size, plan=plan)
        jax.block_until_ready(jax.tree.leaves(new_params))
        return new_params

    p = one_round(p)                 # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        p = one_round(p)
    return (time.perf_counter() - t0) / reps, getattr(engine, "num_devices", 1)


def bench(clients=8, samples_per_client=32, reps=3, sim_devices=0,
          verbose=True):
    adapter, data, params, partition = _setup(clients, samples_per_client)
    assigner = PlanAssigner(num_groups=partition.num_groups, kind="nested",
                            capacity_tiers=TIERS)
    rows = []
    # Mixed phases like the equivalence tests: the FNU round is where nested
    # plans diverge most (every tier trains a different prefix).
    for phase, spec in [
        ("partial", RoundSpec(0, "partial", 0, PARTIAL_GROUP)),
        ("fnu", RoundSpec(0, "warmup", -1, FULL_NETWORK)),
    ]:
        times = {}
        for kind in ("homogeneous", "nested", "random"):
            sec, ndev = _time_plan_round(
                "vmap", adapter, data, params, partition, spec, kind,
                reps=reps, sim_devices=sim_devices)
            times[kind] = sec
            thr = clients / (sec * ndev)
            rows.append({
                "name": f"hetero_nlp_{phase}_{kind}_vmap_c{clients}",
                "us_per_call": sec * 1e6,
                "clients_per_sec_per_device": thr,
                "derived": f"{thr:.1f} clients/s/dev",
            })
            if verbose:
                print(f"[hetero:{phase:7s}] {kind:12s} vmap "
                      f"{sec*1e3:8.1f} ms/round {thr:.1f} clients/s/dev")
        # plan overhead: what the masked plan program costs vs the legacy
        # single-group program on the SAME cohort (scale-free, gated)
        overhead = times["homogeneous"] / times["nested"]
        rows.append({
            "name": f"hetero_nlp_{phase}_plan_overhead_vmap_c{clients}",
            "us_per_call": (times["nested"] - times["homogeneous"]) * 1e6,
            "speedup": overhead,
            "derived": f"homog/nested={overhead:.2f}x",
        })
        if verbose:
            print(f"[hetero:{phase:7s}] plan overhead: nested is "
                  f"{1/overhead:.2f}x homogeneous wall-clock")
        # vmap vs sequential under the nested plan (scale-free, gated):
        # batching must keep paying once cohorts are heterogeneous
        seq_sec, _ = _time_plan_round(
            "sequential", adapter, data, params, partition, spec, "nested",
            reps=reps)
        speedup = seq_sec / times["nested"]
        rows.append({
            "name": f"hetero_nlp_{phase}_nested_vmap_speedup_c{clients}",
            "us_per_call": 0.0,
            "speedup": speedup,
            "derived": f"{speedup:.2f}x vs sequential",
        })
        if verbose:
            print(f"[hetero:{phase:7s}] nested vmap speedup vs sequential: "
                  f"{speedup:.2f}x")
        # per-tier clients/s: the round processes every tier together; each
        # tier's share of the cohort divided by the same round wall-clock —
        # the throughput the scheduler delivers per capacity class
        ndev = max(sim_devices, 1)
        tier_of = [assigner.tier_of(ci) for ci in range(clients)]
        for t, cap in enumerate(TIERS):
            n_tier = sum(1 for x in tier_of if x == t)
            if n_tier == 0:
                continue
            thr = n_tier / (times["nested"] * ndev)
            rows.append({
                "name": f"hetero_nlp_{phase}_nested_tier{cap}_c{clients}",
                "us_per_call": times["nested"] * 1e6,
                "clients_per_sec_per_device": thr,
                "derived": f"{n_tier} clients @ cap {cap}: "
                           f"{thr:.1f} clients/s/dev",
            })
            if verbose:
                print(f"[hetero:{phase:7s}] tier cap={cap}: {n_tier} clients "
                      f"-> {thr:.1f} clients/s/dev")
    return rows


def run(quick: bool = True):
    """Harness hook for ``python -m benchmarks.run``."""
    return bench(clients=8, reps=2 if quick else 5, verbose=False)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--samples-per-client", type=int, default=32)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--sim-devices", type=int, default=0,
                    help="forced CPU host devices (also the shard_map mesh)")
    ap.add_argument("--json", default="",
                    help="write rows as machine-readable JSON (BENCH_*.json)")
    args = ap.parse_args(argv)

    from benchmarks.common import enable_compile_cache, write_json_rows
    enable_compile_cache()
    rows = bench(clients=args.clients,
                 samples_per_client=args.samples_per_client,
                 reps=args.reps, sim_devices=args.sim_devices)
    print("\nname,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    if args.json:
        write_json_rows(args.json, rows, bench="hetero_bench",
                        clients=args.clients, reps=args.reps,
                        tiers=list(TIERS))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
