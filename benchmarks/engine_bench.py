"""Client-engine bench: sequential vs vmap vs shard_map wall-clock + traces.

The sequential oracle dispatches one jitted call per (client, step) and syncs
the host on every loss; the vmap engine runs the whole round as one vmapped
program plus one on-device aggregation; the shard_map engine spreads the
client axis over a device mesh (``--sim-devices``) and psums the aggregate.
This bench measures steady-state *per-round* wall-clock (compile excluded —
each engine gets one warmup round per phase), the number of XLA traces each
engine built, and — for shard_map — per-device client throughput, for a
partial round and an FNU round.

The default workload is the cross-device regime the batched engines target —
many small clients on a tiny transformer — where per-dispatch overhead
dominates per-step compute and vmap amortises it across the client axis
(>=3x at 8 clients on this container's 2 CPU cores).  ``--task vision``
switches to the paper's conv model: there, per-client conv weights lower to
grouped convolutions that XLA:CPU executes poorly, so the batched engines
only pay off on accelerator backends — the bench reports it honestly either
way.  CPU "devices" forced via --sim-devices share the same physical cores:
shard_map numbers there measure engine overhead, not real parallel speedup
(docs/ENGINES.md).

The batched engines donate the global params into their aggregation jit by
default (in-place splice; ``make_engine(donate=...)``): each batched-engine
timing is taken both ways and a ``*_donate_delta`` row records the
throughput change and the live-device-buffer delta.

    PYTHONPATH=src python benchmarks/engine_bench.py --clients 8 --reps 5
    PYTHONPATH=src python benchmarks/engine_bench.py \
        --engine shard_map --sim-devices 4
    PYTHONPATH=src python benchmarks/engine_bench.py --json bench.json

``--json PATH`` additionally writes the rows as machine-readable JSON (the
``BENCH_*.json`` trajectory format).  Also exposes ``run(quick=True)`` for
``python -m benchmarks.run``.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, "src")
# repo root, so `benchmarks.common` resolves when run as a script too
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

if __name__ == "__main__":
    # shard_map on CPU: simulate N host devices (XLA reads the flag at
    # first-import time, so set it before the jax import below).
    from repro.launch._simdev import force_sim_devices
    force_sim_devices()

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.schedule import FULL_NETWORK, RoundSpec
from repro.data import (TextDatasetSpec, VisionDatasetSpec, build_clients,
                        iid_partition, make_text_dataset, make_vision_dataset)
from repro.fl import AlgoConfig, LocalTrainer, make_engine, nlp_task, resnet_task
from repro.optim.adam import AdamConfig

PARTIAL_GROUP = 1


def _setup(task: str, clients: int, samples_per_client: int):
    if task == "nlp":
        cfg = get_config("nlp-transformer", smoke=True).with_(
            num_layers=1, d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
            vocab_size=256, max_position_embeddings=12)
        spec = TextDatasetSpec(num_classes=4, vocab_size=256, seq_len=12)
        X, y = make_text_dataset(spec, samples_per_client * clients, seed=0)
        adapter = nlp_task(num_classes=4, cfg=cfg)
        batch_size = 8
    elif task == "vision":
        spec = VisionDatasetSpec(num_classes=8, image_size=12)
        X, y = make_vision_dataset(spec, samples_per_client * clients, seed=0)
        adapter = resnet_task("resnet8", num_classes=8)
        batch_size = 32
    else:
        raise ValueError(f"unknown task {task!r}")
    data = build_clients(X, y, iid_partition(len(y), clients, seed=0))
    params = adapter.init(jax.random.key(0))
    return adapter, data, params, adapter.partition(params), batch_size


def _live_bytes() -> int:
    import gc
    gc.collect()
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.live_arrays())


def _time_engine(engine_name, adapter, data, params, partition, spec,
                 *, epochs, batch_size, reps, sim_devices=0, donate=True,
                 fused=False):
    """Fresh trainer+engine; one warmup round (compile), then ``reps`` timed
    rounds.  Returns (seconds_per_round, traces, mesh_devices, live_bytes).

    With donation on, ``run_round`` consumes its params argument, so the
    timed loop threads the returned tree through a private copy (identical
    shapes every round — no retraces, same per-round work either way)."""
    algo = AlgoConfig()
    trainer = LocalTrainer(adapter=adapter, partition=partition, algo=algo,
                           adam=AdamConfig(lr=1e-3))
    engine = make_engine(engine_name, trainer=trainer, partition=partition,
                         algo=algo, sim_devices=sim_devices, donate=donate,
                         fused_adam=fused)
    seeds = list(range(len(data)))
    weights = [len(d) for d in data]
    import jax.numpy as jnp
    p = jax.tree.map(jnp.copy, params)   # donation-safe private copy

    def one_round(p):
        new_params, _, _ = engine.run_round(
            p, spec, data, seeds=seeds, weights=weights,
            epochs=epochs, batch_size=batch_size)
        jax.block_until_ready(jax.tree.leaves(new_params))
        return new_params

    p = one_round(p)                 # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        p = one_round(p)
    per_round = (time.perf_counter() - t0) / reps
    live = _live_bytes()
    devices = getattr(engine, "num_devices", 1)
    return per_round, engine.trace_count, devices, live


def bench(task="nlp", clients=8, samples_per_client=32, epochs=1, reps=5,
          engines=("sequential", "vmap"), sim_devices=0, fused=False,
          verbose=True):
    adapter, data, params, partition, batch_size = _setup(
        task, clients, samples_per_client)
    # Opt-in fused masked-Adam local steps (docs/KERNELS.md).  Row names get
    # a `_fused` tag so a fused run never collides with the pinned unfused
    # rows the CI baseline gates — the fused lane is exploratory, not gated
    # (on CPU the kernel runs in interpret mode, so its absolute numbers
    # measure the emulator, not the TPU path).
    tag = "_fused" if fused else ""
    task_tag = f"{task}{tag}"
    rows = []
    for phase, spec in [
        ("partial", RoundSpec(0, "partial", 0, PARTIAL_GROUP)),
        ("fnu", RoundSpec(0, "warmup", -1, FULL_NETWORK)),
    ]:
        times, traces = {}, {}
        for name in engines:
            sec, tr, ndev, live = _time_engine(
                name, adapter, data, params, partition, spec, epochs=epochs,
                batch_size=batch_size, reps=reps, sim_devices=sim_devices,
                fused=fused)
            times[name], traces[name] = sec, tr
            derived = f"traces={tr}"
            extra = ""
            row = {
                "name": f"engine_{task_tag}_{phase}_{name}_c{clients}",
                "us_per_call": sec * 1e6,
                "traces": tr,
            }
            if name == "shard_map":
                # per-device client throughput: the scaling quantity this
                # engine exists for (clients processed per second per device)
                thr = clients / (sec * ndev)
                derived += f" devices={ndev} {thr:.1f} clients/s/dev"
                extra = f" [{ndev} dev, {thr:.1f} clients/s/dev]"
                row["devices"] = ndev
                row["clients_per_sec_per_device"] = thr
            row["derived"] = derived
            rows.append(row)
            if verbose:
                print(f"[{task}:{phase:7s}] clients={clients:3d} "
                      f"{name}={sec*1e3:8.1f} ms/round "
                      f"(traces={tr}){extra}")
            if name != "sequential":
                # Buffer-donation delta: same engine with donate=False (the
                # pre-donation behavior) vs the donate=True timing above.
                sec_nd, _, _, live_nd = _time_engine(
                    name, adapter, data, params, partition, spec,
                    epochs=epochs, batch_size=batch_size, reps=reps,
                    sim_devices=sim_devices, donate=False, fused=fused)
                thr_delta = (sec_nd / sec - 1.0) * 100.0
                mem_delta = (live_nd - live) / 1e6
                rows.append({
                    "name": f"engine_{task_tag}_{phase}_{name}_donate_delta_c{clients}",
                    "us_per_call": (sec_nd - sec) * 1e6,
                    "derived": (f"donate {thr_delta:+.1f}% throughput "
                                f"{mem_delta:+.2f}MB live saved"),
                    "throughput_delta_pct": thr_delta,
                    "live_mb_delta": mem_delta,
                })
                if verbose:
                    print(f"[{task}:{phase:7s}] clients={clients:3d} "
                          f"{name} donation: {thr_delta:+.1f}% throughput, "
                          f"live buffers {mem_delta:+.2f} MB vs no-donate")
        if "sequential" in times:
            for name in engines:
                if name == "sequential":
                    continue
                speedup = times["sequential"] / times[name]
                rows.append({
                    "name": f"engine_{task_tag}_{phase}_{name}_speedup_c{clients}",
                    "us_per_call": 0.0,
                    "derived": f"{speedup:.2f}x",
                    "speedup": speedup,
                })
                if verbose:
                    print(f"[{task}:{phase:7s}] clients={clients:3d} "
                          f"{name} speedup vs sequential: {speedup:.2f}x")
    return rows


def run(quick: bool = True):
    """Harness hook: one point in quick mode, a client sweep in full."""
    rows = []
    for clients in ((8,) if quick else (4, 8, 16, 32)):
        rows.extend(bench(clients=clients, reps=3 if quick else 5,
                          verbose=False))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", choices=["nlp", "vision"], default="nlp")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--samples-per-client", type=int, default=32)
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--engine",
                    choices=["all", "sequential", "vmap", "shard_map"],
                    default="all",
                    help="bench one engine (always paired with the "
                         "sequential baseline) or the default seq+vmap pair")
    ap.add_argument("--sim-devices", type=int, default=0,
                    help="shard_map mesh size; on CPU, N>1 forces N "
                         "simulated host devices (must be first jax use)")
    ap.add_argument("--fused", action="store_true",
                    help="opt-in: fused Pallas masked-Adam local steps "
                         "(docs/KERNELS.md); rows are tagged `_fused` and "
                         "are NOT part of the pinned CI baseline — on CPU "
                         "the kernel runs in interpret mode")
    ap.add_argument("--json", default="",
                    help="also write rows as machine-readable JSON to PATH")
    args = ap.parse_args(argv)
    from benchmarks.common import enable_compile_cache
    enable_compile_cache()
    if args.engine == "all":
        engines = ("sequential", "vmap")
    elif args.engine == "sequential":
        engines = ("sequential",)
    else:
        engines = ("sequential", args.engine)
    rows = bench(task=args.task, clients=args.clients,
                 samples_per_client=args.samples_per_client,
                 epochs=args.epochs, reps=args.reps, engines=engines,
                 sim_devices=args.sim_devices, fused=args.fused)
    if args.json:
        from benchmarks.common import write_json_rows
        write_json_rows(args.json, rows, bench="engine_bench",
                        task=args.task, clients=args.clients,
                        reps=args.reps, engines=list(engines),
                        sim_devices=args.sim_devices, fused=args.fused)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
