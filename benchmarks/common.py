"""Shared benchmark scaffolding: reduced-scale federated setups mirroring the
paper's experiment grid, with per-round timing.

Every ``table*.py`` module exposes ``run(quick=True) -> list[dict]`` where
each row has at least {"name", "us_per_call", "derived"} — ``benchmarks.run``
prints them as CSV.  ``us_per_call`` is wall-time per communication round.
"""

from __future__ import annotations

import json
import os
import platform
import time

from repro.core.schedule import FedPartSchedule, matched_fnu
from repro.data import (TextDatasetSpec, VisionDatasetSpec, balanced_eval_set,
                        build_clients, dirichlet_partition, iid_partition,
                        make_text_dataset, make_vision_dataset)
from repro.fl import nlp_task, resnet_task, run_federated


def vision_setup(num_classes=16, image_size=16, samples=800, clients=4,
                 alpha=0.0, seed=0, depth="resnet8", noise=1.2):
    """Calibrated so FedAvg-FNU lands mid-range after ~10 rounds — strategies
    can then separate (noise 1.2 / 16 classes; see EXPERIMENTS.md §Claims)."""
    spec = VisionDatasetSpec(num_classes=num_classes, image_size=image_size,
                             noise=noise)
    X, y = make_vision_dataset(spec, samples, seed=seed)
    Xe, ye = make_vision_dataset(spec, samples // 2, seed=seed + 99)
    eval_set = balanced_eval_set(Xe, ye, per_class=16)
    if alpha > 0:
        parts = dirichlet_partition(y, clients, alpha, seed=seed)
    else:
        parts = iid_partition(len(y), clients, seed=seed)
    adapter = resnet_task(depth, num_classes=num_classes)
    return adapter, build_clients(X, y, parts), eval_set


def text_setup(samples=1200, clients=4, seed=0):
    spec = TextDatasetSpec(num_classes=4, vocab_size=512, seq_len=48)
    X, y = make_text_dataset(spec, samples, seed=seed)
    Xe, ye = make_text_dataset(spec, samples // 2, seed=seed + 7)
    eval_set = balanced_eval_set(Xe, ye, per_class=32)
    adapter = nlp_task(num_classes=4, smoke=True)
    return adapter, build_clients(X, y, iid_partition(len(y), clients, seed)), eval_set


def fedpart_schedule(num_groups, quick=True, cycles=1, rl=1, warmup=2,
                     order="sequential", bridge=1, seed=0):
    return FedPartSchedule(num_groups=num_groups, warmup_rounds=warmup,
                           rounds_per_layer=rl, cycles=cycles,
                           bridge_rounds=bridge, order=order, seed=seed)


def enable_compile_cache() -> None:
    """Point jax at the repo's persistent XLA compile cache (the same
    ``.jax_cache/`` family tests/conftest.py uses; ``REPRO_BENCH_CACHE``
    overrides the path, empty disables).  Cold bench runs are dominated by
    XLA compiles — one warm run per machine/jax version turns every later
    run into replays, which is what makes the CI bench-regression lane's
    numbers about the *code* instead of the compiler."""
    cache = os.environ.get(
        "REPRO_BENCH_CACHE",
        os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                     ".jax_cache"))
    if not cache:
        return
    import jax

    jax.config.update("jax_compilation_cache_dir", os.path.abspath(cache))
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.2)


def write_json_rows(path: str, rows: list[dict], **meta) -> None:
    """Write bench rows as machine-readable JSON (the ``BENCH_*.json``
    trajectory format): ``{"meta": {...}, "rows": [...]}`` with enough
    environment context to compare runs across commits."""
    import jax

    payload = {
        "meta": {
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "python": platform.python_version(),
            **meta,
        },
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=False)
        f.write("\n")
    print(f"[json] wrote {len(rows)} rows -> {path}")


def timed_run(name, adapter, clients, eval_set, rounds, run_cfg):
    t0 = time.time()
    res = run_federated(adapter, clients, eval_set, rounds, run_cfg)
    elapsed = time.time() - t0
    return res, {
        "name": name,
        "us_per_call": 1e6 * elapsed / max(len(rounds), 1),
        "derived": f"best_acc={res.best_acc:.4f}",
        "best_acc": res.best_acc,
        "comm_ratio": res.comm_total_bytes / max(res.comm_fnu_bytes, 1),
        "comp_ratio": res.comp_total_flops / max(res.comp_fnu_flops, 1),
    }


def compare_fnu_fedpart(name, adapter, clients, eval_set, schedule, run_cfg):
    rows = []
    fp, row = timed_run(f"{name}/fedpart", adapter, clients, eval_set,
                        schedule.rounds(), run_cfg)
    rows.append(row)
    fnu, row = timed_run(f"{name}/fnu", adapter, clients, eval_set,
                         matched_fnu(schedule).rounds(), run_cfg)
    rows.append(row)
    rows[0]["derived"] += (
        f" comm={rows[0]['comm_ratio']:.2f}xFNU comp={rows[0]['comp_ratio']:.2f}xFNU"
        f" vs_fnu_acc={fnu.best_acc:.4f}"
    )
    return rows
