"""Bench-regression gate: compare a fresh ``--json`` bench run against a
committed ``BENCH_*.json`` baseline.

The CI bench lane (``.github/workflows/bench.yml``) runs
``benchmarks/engine_bench.py`` and ``benchmarks/async_bench.py`` on a pinned
small config and feeds both through this script.  Rows are matched by
``name``; a row regresses when a gated metric moves past the tolerance band
(default 25%), and a baseline row missing from the current run fails outright
(coverage must not silently shrink).  Extra current rows are reported but
never fail — they are tomorrow's baseline.

Two classes of metric:

* **scale-free** (compared by default): ``speedup`` (engine vs sequential,
  inflight=N vs inflight=1) and ``clients_per_sec_per_device``-style
  throughput ratios... these measure the *code*, so they transfer between a
  laptop and a CI runner.  ``clients_per_sec_per_device`` is absolute-rate
  but still gated by default because the lane's warm-cache double-run keeps
  it stable on one runner class; loosen ``--tolerance`` if your fleet is
  heterogeneous.
* **absolute** (``--absolute`` only): ``us_per_call`` / ``wall_seconds``
  wall-clock.  Off by default — different machines legitimately differ by
  far more than any tolerance band.

    python benchmarks/compare.py --baseline BENCH_engine.json \
        --current engine.json --tolerance 0.25
"""

from __future__ import annotations

import argparse
import json

# metric -> direction: +1 = higher is better, -1 = lower is better
# overhead_ratio / peak_ratio: population_bench's O(cohort) invariants —
# per-round wall and peak host memory of a 10^6-client streamed fleet
# relative to a small fleet; lower is better, growth means O(N) crept in.
SCALE_FREE = {"speedup": +1, "clients_per_sec_per_device": +1,
              "overhead_ratio": -1, "peak_ratio": -1}
ABSOLUTE = {"us_per_call": -1, "wall_seconds": -1}


def load_rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        payload = json.load(f)
    rows = payload["rows"] if isinstance(payload, dict) else payload
    return {r["name"]: r for r in rows}


def compare(baseline: dict[str, dict], current: dict[str, dict],
            tolerance: float, absolute: bool) -> list[dict]:
    """One record per (row, gated metric) plus missing-row records."""
    metrics = dict(SCALE_FREE)
    if absolute:
        metrics.update(ABSOLUTE)
    records = []
    for name, base_row in baseline.items():
        cur_row = current.get(name)
        if cur_row is None:
            records.append({"name": name, "metric": "-", "status": "MISSING",
                            "base": None, "cur": None, "delta": None})
            continue
        for metric, direction in metrics.items():
            base = base_row.get(metric)
            cur = cur_row.get(metric)
            if base is None or cur is None:
                continue
            base, cur = float(base), float(cur)
            if base <= 0:
                continue        # degenerate baseline: nothing to gate on
            delta = cur / base - 1.0
            worse = -delta if direction > 0 else delta
            status = "FAIL" if worse > tolerance else "ok"
            records.append({"name": name, "metric": metric, "status": status,
                            "base": base, "cur": cur, "delta": delta})
    for name in current:
        if name not in baseline:
            records.append({"name": name, "metric": "-", "status": "NEW",
                            "base": None, "cur": None, "delta": None})
    return records


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_*.json baseline")
    ap.add_argument("--current", required=True,
                    help="fresh --json output to gate")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional regression (default 0.25)")
    ap.add_argument("--absolute", action="store_true",
                    help="also gate absolute wall-clock metrics "
                         "(same-machine comparisons only)")
    args = ap.parse_args(argv)

    records = compare(load_rows(args.baseline), load_rows(args.current),
                      args.tolerance, args.absolute)
    width = max((len(r["name"]) for r in records), default=4)
    failures = 0
    for r in records:
        if r["status"] in ("FAIL", "MISSING"):
            failures += 1
        if r["base"] is None:
            print(f"{r['name']:{width}s}  {r['status']}")
        else:
            print(f"{r['name']:{width}s}  {r['metric']:28s} "
                  f"base={r['base']:10.4f}  cur={r['cur']:10.4f}  "
                  f"{r['delta']:+7.1%}  {r['status']}")
    gated = sum(r["base"] is not None for r in records)
    print(f"\n[compare] {gated} gated metrics, "
          f"{sum(r['status'] == 'NEW' for r in records)} new rows, "
          f"{failures} failure(s) at tolerance {args.tolerance:.0%}")
    if gated == 0 and not failures:
        print("[compare] WARNING: no overlapping gated metrics — "
              "check the bench flags match the baseline's")
        return 1
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
