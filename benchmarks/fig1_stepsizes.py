"""Paper Fig. 1: per-iteration update step sizes — FNU spikes after each
aggregation (layer mismatch); FedPart's spikes are smaller."""

import time

from repro.core.schedule import FedPartSchedule, matched_fnu
from repro.fl import FLRunConfig, run_federated

from benchmarks.common import vision_setup


def run(quick: bool = True):
    adapter, clients, eval_set = vision_setup(samples=400 if quick else 1200,
                                              clients=2 if quick else 4)
    schedule = FedPartSchedule(num_groups=10, warmup_rounds=2,
                               rounds_per_layer=1, cycles=1)
    cfg = FLRunConfig(local_epochs=2, batch_size=32, lr=1e-3,
                      track_stepsizes=True)
    rows = []
    for name, rounds in (("fedpart", schedule.rounds()),
                         ("fnu", matched_fnu(schedule).rounds())):
        t0 = time.time()
        res = run_federated(adapter, clients, eval_set, rounds, cfg)
        spike = res.tracker.post_aggregation_spike()
        rows.append({
            "name": f"fig1/{name}",
            "us_per_call": 1e6 * (time.time() - t0) / len(rounds),
            "derived": f"post_agg_spike={spike:.3f}",
            "spike": spike,
        })
    return rows
