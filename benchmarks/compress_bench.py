"""Compression bench: transmitted-subtree encodings across a federated run.

Sweeps ``FLRunConfig.compression`` (docs/COMPRESSION.md) on the tiny-NLP
vmap regime (where the batched engines win on CPU — docs/ENGINES.md) with a
short FedPart schedule, and prices what each wire format actually moves:

* per-round wall-clock + accuracy-at-budget for each kind
  (none / int8 / onebit / topk) — the lossy channel must not cost accuracy
  at this scale, and the qdq epilogue must stay noise-level on wall-clock;
* ``byte_ratio`` rows the CI bench lane gates (scale-free, carried in the
  ``speedup`` key for benchmarks/compare.py): dense transmitted bytes over
  encoded transmitted bytes, measured from the runs' own comm ledgers.
  These are deterministic functions of the parameter shapes and schedule,
  so the gate is tight even across runner classes.

The int8 ratio is asserted ≥ 3.9 in-bench: with one f32 scale per leaf the
exact ceiling is 4·n/(n+4L) ≈ 4× (never quite 4); onebit and topk clear 4×
with a wide margin.  See docs/COMPRESSION.md for the byte model.

    PYTHONPATH=src python benchmarks/compress_bench.py --reps 2
    PYTHONPATH=src python benchmarks/compress_bench.py --json compress.json

``--json PATH`` writes the rows machine-readable (the ``BENCH_*.json``
trajectory format; BENCH_compress.json is the committed baseline the bench
CI lane compares against).  Also exposes ``run(quick=True)`` for
``python -m benchmarks.run``.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, "src")
# repo root, so `benchmarks.common` resolves when run as a script too
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

if __name__ == "__main__":
    from repro.launch._simdev import force_sim_devices
    force_sim_devices()

from repro.configs.base import get_config
from repro.core.schedule import FedPartSchedule
from repro.data import (TextDatasetSpec, balanced_eval_set, build_clients,
                        iid_partition, make_text_dataset)
from repro.fl import FLRunConfig, nlp_task, run_federated

KINDS = ("none", "int8", "onebit", "topk")
INT8_MIN_RATIO = 3.9     # per-leaf-scale ceiling is 4·n/(n+4L) < 4


def _setup(clients: int, samples_per_client: int):
    cfg = get_config("nlp-transformer", smoke=True).with_(
        num_layers=1, d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
        vocab_size=256, max_position_embeddings=12)
    spec = TextDatasetSpec(num_classes=4, vocab_size=256, seq_len=12)
    X, y = make_text_dataset(spec, samples_per_client * clients, seed=0)
    Xe, ye = make_text_dataset(spec, 256, seed=7)
    eval_set = balanced_eval_set(Xe, ye, per_class=16)
    adapter = nlp_task(num_classes=4, cfg=cfg)
    data = build_clients(X, y, iid_partition(len(y), clients, seed=0))
    return adapter, data, eval_set


def bench(clients=8, samples_per_client=32, reps=2, verbose=True):
    adapter, data, eval_set = _setup(clients, samples_per_client)
    import jax
    num_groups = adapter.partition(adapter.init(jax.random.key(0))).num_groups
    # warmup + one pass over the groups: mixes an FNU round (worst case for
    # compression savings) with the partial rounds the paper runs on.
    sched = FedPartSchedule(num_groups=num_groups, warmup_rounds=1,
                            rounds_per_layer=1, cycles=1)
    rounds = sched.rounds()

    rows, bytes_by_kind, acc_by_kind = [], {}, {}
    for kind in KINDS:
        run_cfg = FLRunConfig(local_epochs=1, batch_size=8, lr=1e-3,
                              engine="vmap", compression=kind)
        secs = []
        for _ in range(reps):
            t0 = time.perf_counter()
            res = run_federated(adapter, data, eval_set, rounds, run_cfg)
            secs.append(time.perf_counter() - t0)
        sec = min(secs) / len(rounds)
        bytes_by_kind[kind] = int(res.comm_total_bytes)
        acc_by_kind[kind] = float(res.best_acc)
        rows.append({
            "name": f"compress_nlp_{kind}_vmap_c{clients}",
            "us_per_call": sec * 1e6,
            "best_acc": acc_by_kind[kind],
            "comm_bytes": bytes_by_kind[kind],
            "derived": f"best_acc={acc_by_kind[kind]:.4f} "
                       f"bytes={bytes_by_kind[kind]}",
        })
        if verbose:
            print(f"[compress] {kind:6s} vmap {sec*1e3:8.1f} ms/round "
                  f"acc={acc_by_kind[kind]:.4f} "
                  f"bytes={bytes_by_kind[kind]}")

    dense = bytes_by_kind["none"]
    for kind in KINDS[1:]:
        ratio = dense / bytes_by_kind[kind]
        # byte ratio rides the gated scale-free `speedup` key: it is a pure
        # shape/schedule function, so any drift is a real ledger regression
        rows.append({
            "name": f"compress_nlp_{kind}_byte_ratio_c{clients}",
            "us_per_call": 0.0,
            "speedup": ratio,
            "derived": f"{ratio:.2f}x fewer bytes than dense",
        })
        if verbose:
            print(f"[compress] {kind:6s} byte ratio: {ratio:.2f}x vs dense")
    int8_ratio = dense / bytes_by_kind["int8"]
    assert int8_ratio >= INT8_MIN_RATIO, (
        f"int8 byte ratio {int8_ratio:.3f} below {INT8_MIN_RATIO} — "
        "scale overhead grew past one f32 per leaf-equivalent block")
    return rows


def run(quick: bool = True):
    """Harness hook for ``python -m benchmarks.run``."""
    return bench(clients=8, reps=1 if quick else 3, verbose=False)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--samples-per-client", type=int, default=32)
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--json", default="",
                    help="write rows as machine-readable JSON (BENCH_*.json)")
    args = ap.parse_args(argv)

    from benchmarks.common import enable_compile_cache, write_json_rows
    enable_compile_cache()
    rows = bench(clients=args.clients,
                 samples_per_client=args.samples_per_client, reps=args.reps)
    print("\nname,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    if args.json:
        write_json_rows(args.json, rows, bench="compress_bench",
                        clients=args.clients, reps=args.reps,
                        kinds=list(KINDS))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
