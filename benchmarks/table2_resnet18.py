"""Paper Table 2: deeper model (ResNet-18) — FedPart's comm/comp savings grow
with depth (18 groups -> partial rounds move ~1/18 of the bytes).

Quick mode runs a short schedule *prefix* (each ResNet-18 partial group is a
separate XLA compilation — 18 of them dominate CPU wall time) and reports the
cost ledger computed exactly over the FULL schedule via core.costs (the
ledger is analytic — it does not need the run)."""

import time

import jax

from repro.core.costs import comm_cost, comp_cost
from repro.core.partition import group_param_counts
from repro.fl import FLRunConfig, run_federated

from benchmarks.common import compare_fnu_fedpart, fedpart_schedule, vision_setup


def run(quick: bool = True):
    adapter, clients, eval_set = vision_setup(
        samples=240 if quick else 1500, clients=2 if quick else 8,
        image_size=12 if quick else 16, depth="resnet18",
        num_classes=8 if quick else 16, noise=1.0,
    )
    schedule = fedpart_schedule(num_groups=18, quick=quick, warmup=1)
    cfg = FLRunConfig(local_epochs=1, batch_size=32, lr=1e-3)

    if not quick:
        return compare_fnu_fedpart("table2/resnet18", adapter, clients,
                                   eval_set, schedule, cfg)

    # quick: run the first 5 rounds (warmup + 4 partial groups) as evidence
    # the deep-model path trains; ledger from the full 19-round schedule.
    rounds = schedule.rounds()
    t0 = time.time()
    res = run_federated(adapter, clients, eval_set, rounds[:5], cfg)
    elapsed = time.time() - t0

    params = adapter.init(jax.random.key(0))
    part = adapter.partition(params)
    comm = comm_cost(params, part, rounds)
    comp = comp_cost(part, rounds,
                     group_fwd_flops=group_param_counts(params, part).astype(float))
    return [{
        "name": "table2/resnet18_prefix5",
        "us_per_call": 1e6 * elapsed / 5,
        "derived": (
            f"acc@5r={res.best_acc:.4f} "
            f"full_sched_comm={comm.ratio_to_fnu:.3f}xFNU "
            f"full_sched_comp={comp.ratio_to_fnu:.3f}xFNU (18 groups)"
        ),
    }]
