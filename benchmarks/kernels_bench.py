"""Kernel micro-benchmarks (CPU timings of the XLA paths; the Pallas kernels
themselves are TPU-targeted and validated in interpret mode by the tests).

- attention: jnp oracle timing across the dry-run-relevant tile shapes.
- fused masked Adam (ops wrapper, interpret) vs unfused jnp Adam: correctness
  already tested; here we record the unfused baseline's CPU time and the
  fused kernel's HBM-traffic model (bytes moved per parameter)."""

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ops as fa
from repro.optim.adam import AdamConfig, adam_init, adam_update


def _time(f, *args, n=5):
    f(*args)  # warmup/compile
    t0 = time.time()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return 1e6 * (time.time() - t0) / n


def run(quick: bool = True):
    rows = []
    shapes = [(1, 512, 8, 64)] if quick else [(1, 512, 8, 64), (2, 1024, 8, 128)]
    for b, s, h, d in shapes:
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
        ref = jax.jit(lambda q, k, v: fa.attention_reference(q, k, v))
        us = _time(ref, q, k, v)
        flops = 4 * b * h * s * s * d
        rows.append({
            "name": f"kernels/attention_ref_b{b}s{s}h{h}d{d}",
            "us_per_call": us,
            "derived": f"cpu_gflops={flops / us / 1e3:.2f}",
        })

    # unfused Adam CPU baseline
    n = 1 << 20
    p = {"w": jax.random.normal(jax.random.key(1), (n,))}
    g = {"w": jax.random.normal(jax.random.key(2), (n,))}
    st = adam_init(p)
    cfg = AdamConfig()
    upd = jax.jit(lambda g, s, p: adam_update(g, s, p, cfg))
    us = _time(upd, g, st, p)
    # fused kernel bytes model: reads p,g,m,v + writes p,m,v = 7 passes
    # (f32) = 28 B/param; unfused XLA CPU measured below for contrast.
    rows.append({
        "name": "kernels/adam_unfused_1M",
        "us_per_call": us,
        "derived": f"GBps={(n * 28) / us / 1e3:.2f} fused_model=28B/param",
    })
    return rows
