"""Kernel micro-benchmarks (CPU timings of the XLA paths; the Pallas kernels
themselves are TPU-targeted and validated in interpret mode by the tests).

- attention: jnp oracle timing across the dry-run-relevant tile shapes.
- masked Adam (docs/KERNELS.md): the fused-path update — one elementwise op
  over the packed ``(rows, 128)`` buffer (``masked_adam_ref``, the kernel's
  XLA-lowerable oracle) — against the per-leaf tree ``adam_update`` the
  unfused engines run.  The speedup row is scale-free (it measures op-count
  amortisation across the leaf axis, not the machine) and is gated in the
  bench CI lane against ``BENCH_kernels.json``; the end-to-end step row
  (pack + update + unpack) and the interpret-mode Pallas row are absolute
  wall-clock, reported but never gated.  The ``derived`` columns carry the
  ``core.costs`` traffic book (7 vs 14 f32 passes) for roofline context.

    PYTHONPATH=src python benchmarks/kernels_bench.py --json kernels.json

Also exposes ``run(quick=True)`` for ``python -m benchmarks.run``.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, "src")
# repo root, so `benchmarks.common` resolves when run as a script too
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costs
from repro.kernels.flash_attention import ops as fa
from repro.kernels.masked_adam import ops as madam_ops
from repro.kernels.masked_adam.kernel import masked_adam_kernel
from repro.kernels.masked_adam.ref import masked_adam_ref
from repro.optim.adam import AdamConfig, adam_init, adam_update

# Pinned masked-Adam workload: a model-like tree of many small leaves — the
# regime the packed layout exists for (one fused elementwise op instead of
# one op chain per leaf).  128 leaves x 1024 f32 = 131k params, leaf sizes
# exact block multiples (no padding skew); at this leaf size the per-leaf op
# dispatch dominates and the speedup row sits well clear of noise (~6x on
# the 2-core CI class vs ~1.1x for 16k-element leaves).
ADAM_LEAVES = 128
ADAM_LEAF_SIZE = 1024


def _time(f, *args, n=5):
    """Median of ``n`` per-call timings (scheduler spikes on the shared
    2-core CI runners land in the tail, and the gated row is a *ratio* of
    two of these — the median keeps it a property of the op graph)."""
    f(*args)  # warmup/compile
    samples = []
    for _ in range(n):
        t0 = time.perf_counter()
        out = f(*args)
        jax.block_until_ready(out)
        samples.append(time.perf_counter() - t0)
    return 1e6 * float(np.median(samples))


def _attention_rows(quick: bool, reps: int):
    rows = []
    shapes = [(1, 512, 8, 64)] if quick else [(1, 512, 8, 64), (2, 1024, 8, 128)]
    for b, s, h, d in shapes:
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32)
        ref = jax.jit(lambda q, k, v: fa.attention_reference(q, k, v))
        us = _time(ref, q, k, v, n=reps)
        flops = 4 * b * h * s * s * d
        rows.append({
            "name": f"kernels/attention_ref_b{b}s{s}h{h}d{d}",
            "us_per_call": us,
            "derived": f"cpu_gflops={flops / us / 1e3:.2f}",
        })
    return rows


def _adam_tree(n_leaves=ADAM_LEAVES, leaf_size=ADAM_LEAF_SIZE):
    keys = jax.random.split(jax.random.key(1), 2 * n_leaves)
    params = {f"l{i:03d}": jax.random.normal(keys[i], (leaf_size,), jnp.float32)
              for i in range(n_leaves)}
    grads = {f"l{i:03d}": jax.random.normal(keys[n_leaves + i], (leaf_size,),
                                            jnp.float32)
             for i in range(n_leaves)}
    return params, grads


def _masked_adam_rows(reps: int):
    rows = []
    params, grads = _adam_tree()
    n = ADAM_LEAVES * ADAM_LEAF_SIZE
    cfg = AdamConfig()
    state = adam_init(params)

    # unfused: the per-leaf tree update every non-fused engine path runs
    unfused = jax.jit(lambda g, s, p: adam_update(g, s, p, cfg))
    us_unfused = _time(unfused, grads, state, params, n=reps)
    rows.append({
        "name": f"kernels/adam_unfused_tree_{ADAM_LEAVES}leaves",
        "us_per_call": us_unfused,
        "derived": (f"leaves={ADAM_LEAVES} "
                    f"model={costs.adam_step_bytes(n, fused=False)}B"),
    })

    # fused-path update op: the kernel's math on the packed (rows, 128)
    # buffer (masked_adam_ref is the XLA-lowerable oracle of the Pallas
    # kernel — same op graph the fused engines scan on CPU backends)
    pp, meta = madam_ops.pack(params)
    pg, _ = madam_ops.pack(grads)
    m = jnp.zeros_like(pp)
    v = jnp.zeros_like(pp)
    mask = jnp.ones((pp.shape[0] // 8,), jnp.int32)
    sc = jnp.array([1e-3, 1 - 0.9, 1 - 0.999, 1e-8], jnp.float32)
    fused = jax.jit(lambda p, g, m, v: masked_adam_ref(p, g, m, v, mask, sc))
    us_fused = _time(fused, pp, pg, m, v, n=reps)
    rows.append({
        "name": "kernels/masked_adam_packed_update",
        "us_per_call": us_fused,
        "derived": (f"rows={pp.shape[0]} "
                    f"model={costs.adam_step_bytes(n, fused=True)}B"),
    })

    # the gated scale-free row: op-count amortisation of the packed layout
    speedup = us_unfused / us_fused
    rows.append({
        "name": "kernels/masked_adam_fused_vs_unfused_speedup",
        "us_per_call": 0.0,
        "speedup": speedup,
        "derived": (f"{speedup:.2f}x "
                    f"traffic_bound={costs.fused_adam_traffic_ratio():.2f}x"),
    })

    # end-to-end fused step as the engines run it (pack + update + unpack):
    # absolute wall-clock, reported but never gated
    def step(p_tree, g_tree, m, v):
        pp, meta = madam_ops.pack(p_tree)
        pg, _ = madam_ops.pack(g_tree)
        np_, nm, nv = masked_adam_ref(pp, pg, m, v, mask, sc)
        return madam_ops.unpack(np_, meta), nm, nv

    e2e = jax.jit(step)
    us_e2e = _time(e2e, params, grads, m, v, n=reps)
    rows.append({
        "name": "kernels/masked_adam_step_pack_update_unpack",
        "us_per_call": us_e2e,
        "derived": f"pack_overhead={us_e2e / us_fused:.2f}x",
    })

    # interpret-mode Pallas kernel (tiny: interpret is an emulator, the row
    # exists to keep the real kernel path timed at all on CPU CI)
    rows_small = 256
    ks = jax.random.split(jax.random.key(2), 4)
    args = [jax.random.normal(k, (rows_small, 128), jnp.float32) for k in ks]
    args[3] = jnp.abs(args[3])
    small_mask = jnp.ones((rows_small // 8,), jnp.int32)
    kern = lambda p, g, m, v: masked_adam_kernel(
        p, g, m, v, small_mask, sc, interpret=True)
    us_interp = _time(kern, *args, n=max(2, reps // 2))
    rows.append({
        "name": "kernels/masked_adam_pallas_interpret_32k",
        "us_per_call": us_interp,
        "derived": "interpret-mode emulator, absolute only",
    })
    return rows


def run(quick: bool = True, reps: int = 5):
    return _attention_rows(quick, reps) + _masked_adam_rows(reps)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also time the larger attention shapes")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--json", default="",
                    help="also write rows as machine-readable JSON to PATH")
    args = ap.parse_args(argv)
    from benchmarks.common import enable_compile_cache
    enable_compile_cache()
    rows = run(quick=not args.full, reps=args.reps)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r.get('derived', '')}")
    if args.json:
        from benchmarks.common import write_json_rows
        write_json_rows(args.json, rows, bench="kernels_bench",
                        reps=args.reps, full=bool(args.full),
                        adam_leaves=ADAM_LEAVES,
                        adam_leaf_size=ADAM_LEAF_SIZE)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
