"""Async-runtime bench: staleness x participation time-to-accuracy sweep,
plus the host-parallel in-flight-cohort sweep.

For a fixed FedPart schedule on the tiny-transformer NLP task (the regime
where the batched engines win on CPU — docs/ENGINES.md), sweep the async
runtime's levers against a heterogeneous, jittery fleet:

* **participation** — the fraction of the fleet sampled per dispatch
  (``FLRunConfig.sample_fraction``);
* **staleness exponent** — the polynomial discount ``(1+s)^-a`` FedBuff
  applies to late updates (0 = no discount);
* **max in-flight cohorts** — host-parallel dispatch
  (``FLRunConfig.max_inflight_cohorts``, default sweep {1, 2, 4}): how many
  cohorts train concurrently on disjoint device submeshes.  These rows
  report host *wall-clock*, per-device client throughput, and the virtual
  overlap actually achieved, plus a scale-free ``speedup`` row (inflight=N
  vs inflight=1 wall-clock) that the CI bench lane gates on
  (``benchmarks/compare.py``).

* **server control loop** — a pinned straggler config run twice, with
  ``controller="static"`` and ``controller="adaptive"`` (docs/CONTROL.md),
  plus a scale-free ratio row (static clipped time-to-accuracy / adaptive
  clipped time-to-accuracy, virtual-clock only so it is deterministic and
  machine-independent) that the CI bench lane gates on: adaptive must not
  reach the threshold later than static.

* **trace-driven participation** — the same A/B on a *skewed diurnal
  availability trace* (wide per-client duty-cycle spread,
  ``participation_sampling="biased"`` + inverse-probability debiased
  merges, docs/ASYNC.md), with the participation controller off vs on
  (``controller_participation_target``).  Same clipped-tta ratio row,
  same CI gate.

plus the sync-barrier oracle as the reference row.  Each cell reports final
and best accuracy, *virtual* total time, time-to-accuracy at the threshold,
and the max staleness actually observed — the trade the async literature
cares about (fast virtual clock vs degraded merges).  Results are printed as
the usual CSV rows and, with ``--json``, written machine-readable for the
``BENCH_*.json`` trajectory.

    PYTHONPATH=src python benchmarks/async_bench.py --clients 8 --rounds 12
    PYTHONPATH=src python benchmarks/async_bench.py --sim-devices 4 \
        --inflight 1 2 4 --json async.json

``--sim-devices N`` (N > 1) forces N simulated CPU host devices so the
in-flight cohorts have disjoint submeshes to land on (must precede the first
jax import — handled below).  Also exposes ``run(quick=True)`` for
``python -m benchmarks.run``.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, "src")
# repo root, so `benchmarks.common` resolves when run as a script too
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

if __name__ == "__main__":
    # host-parallel dispatch on CPU: simulate N host devices (XLA reads the
    # flag at first-import time, so set it before the jax import below).
    from repro.launch._simdev import force_sim_devices
    force_sim_devices()

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.schedule import FedPartSchedule
from repro.data import (TextDatasetSpec, balanced_eval_set, build_clients,
                        iid_partition, make_text_dataset)
from repro.fl import AvailabilityConfig, FLRunConfig, nlp_task, run_federated


def _setup(clients: int, samples_per_client: int):
    cfg = get_config("nlp-transformer", smoke=True).with_(
        num_layers=1, d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
        vocab_size=256, max_position_embeddings=12)
    spec = TextDatasetSpec(num_classes=4, vocab_size=256, seq_len=12)
    X, y = make_text_dataset(spec, samples_per_client * clients, seed=0)
    Xe, ye = make_text_dataset(spec, 320, seed=99)
    eval_set = balanced_eval_set(Xe, ye, per_class=32)
    data = build_clients(X, y, iid_partition(len(y), clients, seed=0))
    adapter = nlp_task(num_classes=4, cfg=cfg)
    num_groups = adapter.partition(adapter.init(jax.random.key(0))).num_groups
    return adapter, data, eval_set, num_groups


def _devices_used(engine: str, sim_devices: int, inflight: int) -> int:
    """Devices a config's in-flight cohorts can actually occupy."""
    if engine == "sequential":
        return 1
    n = jax.device_count()
    if engine == "shard_map":
        return sim_devices if sim_devices > 0 else n
    return min(max(inflight, 1), n)          # vmap: width-1 submeshes


def bench(clients=8, samples_per_client=32, rounds=12, threshold=0.4,
          participations=(1.0, 0.5), staleness_exps=(0.0, 0.5, 2.0),
          inflights=(1, 2, 4), inflight_reps=3, speed_spread=3.0,
          engine="vmap", sim_devices=0, verbose=True):
    adapter, data, eval_set, num_groups = _setup(clients, samples_per_client)
    sched = FedPartSchedule(num_groups=num_groups, warmup_rounds=2,
                            rounds_per_layer=1, cycles=3, bridge_rounds=1)
    specs = sched.rounds()[:rounds]
    fleet = AvailabilityConfig(speed_spread=speed_spread, latency_jitter=0.2,
                               seed=7)
    base = dict(local_epochs=1, batch_size=8, lr=3e-3, engine=engine,
                sim_devices=sim_devices, availability=fleet)

    configs = [("sync_oracle", dict(runtime="async", async_policy="sync",
                                    sample_fraction=1.0))]
    for part in participations:
        for a in staleness_exps:
            configs.append((
                f"fedbuff_p{part:g}_a{a:g}",
                dict(runtime="async", async_policy="fedbuff",
                     buffer_k=max(1, int(round(part * clients)) // 2),
                     staleness_exponent=a, sample_fraction=part),
            ))
    # Host-parallel sweep: small cohorts (quarter of the fleet) so inflight
    # cohorts have idle clients to sample; goal = cohort size.
    for mi in inflights:
        configs.append((
            f"inflight{mi}",
            dict(runtime="async", async_policy="fedbuff", buffer_k=0,
                 staleness_exponent=0.5, sample_fraction=0.25,
                 max_inflight_cohorts=mi),
        ))

    # Adaptive-controller A/B (docs/CONTROL.md): the same straggler-bound
    # config (merge-driven dispatch, small cohorts, discounted staleness)
    # with the control loop off vs on.  Gated on *virtual* time-to-accuracy,
    # so the ratio row below is seed-deterministic and machine-independent.
    ab_base = dict(runtime="async", async_policy="fedbuff", buffer_k=0,
                   staleness_exponent=0.5, sample_fraction=0.25,
                   max_inflight_cohorts=1)
    configs.append(("ab_static", dict(ab_base)))
    configs.append(("ab_adaptive", dict(ab_base, controller="adaptive",
                                        controller_inflight_bounds=(1, 4))))

    # Trace-driven participation A/B (docs/ASYNC.md): the same fleet behind
    # a skewed diurnal availability trace, cohorts selected biased-by-
    # availability with inverse-probability debiased merges, with the
    # participation controller off vs on.  trace_period=2.0 puts several
    # on/off cycles inside the run's virtual span at this scale.
    trace_fleet = AvailabilityConfig(
        speed_spread=speed_spread, latency_jitter=0.2, seed=7,
        trace="diurnal", trace_period=2.0, duty_cycle=(0.25, 0.9))
    tr_base = dict(runtime="async", async_policy="fedbuff", buffer_k=0,
                   staleness_exponent=0.5, sample_fraction=0.25,
                   participation_sampling="biased", availability=trace_fleet)
    configs.append(("trace_static", dict(tr_base)))
    configs.append(("trace_adaptive", dict(
        tr_base, controller="adaptive",
        controller_participation_target=0.5,
        controller_cohort_bounds=(1, max(2, clients // 2)))))

    rows, inflight_walls, ab_tta = [], {}, {}
    for name, kw in configs:
        cfg = FLRunConfig(**{**base, **kw})
        # The inflight rows feed the CI regression gate, so their host
        # wall-clock is measured as the min over ``inflight_reps`` runs (the
        # virtual-time results are seed-deterministic and identical across
        # reps; min is the standard robust timing estimator and absorbs the
        # per-process warm-up rep).
        reps = inflight_reps if name.startswith("inflight") else 1
        wall = float("inf")
        for _ in range(max(1, reps)):
            t0 = time.time()
            res = run_federated(adapter, data, eval_set, specs, cfg)
            wall = min(wall, time.time() - t0)
        tl = res.timeline
        tta = tl.time_to_accuracy(threshold)
        stale = max((h["staleness_max"] for h in res.history), default=0)
        mi = kw.get("max_inflight_cohorts", 1)
        trained = len(tl.of_kind("complete")) + len(tl.of_kind("drop"))
        ndev = _devices_used(engine, sim_devices, mi)
        row = {
            "name": f"async_{name}_c{clients}",
            "us_per_call": 1e6 * wall / max(len(specs), 1),
            "derived": (f"best_acc={res.best_acc:.4f} "
                        f"vtime={tl.total_seconds:.2f}s "
                        f"tta@{threshold:g}="
                        f"{'inf' if np.isinf(tta) else f'{tta:.2f}'} "
                        f"max_stale={stale}"),
            "best_acc": res.best_acc,
            "final_acc": res.final_acc,
            "virtual_seconds": tl.total_seconds,
            "time_to_accuracy": None if np.isinf(tta) else tta,
            "accuracy_curve": tl.accuracy_curve(),
            "max_staleness": stale,
            "delivered_comm_bytes": tl.delivered_comm_bytes,
            "spent_comp_flops": tl.spent_comp_flops,
            "participation": kw.get("sample_fraction", 1.0),
            "staleness_exponent": kw.get("staleness_exponent", 0.0),
            "buffer_k": kw.get("buffer_k", 0),
            "policy": kw["async_policy"],
            "max_inflight": mi,
            "controller": kw.get("controller", "static"),
            "participation_sampling": kw.get("participation_sampling",
                                             "blind"),
            "wall_seconds": wall,
            "clients_trained": trained,
            "devices_used": ndev,
            "clients_per_sec_per_device": trained / max(wall * ndev, 1e-9),
            "virtual_overlap_seconds": tl.overlap_seconds(),
        }
        rows.append(row)
        if name.startswith(("ab_", "trace_")):
            # Clipped tta: a run that never reaches the threshold counts as
            # its full virtual span, so the ratio below stays finite and
            # still rewards finishing the same rounds in less virtual time.
            ab_tta[name] = min(tta, tl.total_seconds)
            row["derived"] += (" control="
                               f"{len(tl.of_kind('control'))} events")
        if name.startswith("inflight"):
            inflight_walls[mi] = wall
            row["derived"] += (f" wall={wall:.1f}s "
                               f"{row['clients_per_sec_per_device']:.2f} "
                               f"clients/s/dev "
                               f"overlap={row['virtual_overlap_seconds']:.2f}s")
        if verbose:
            print(f"[{name:20s}] wall={wall:5.1f}s {row['derived']}")

    # Scale-free host-overlap speedups: same config, inflight N vs 1 — the
    # metric the CI bench lane gates on (machine-speed independent).
    if 1 in inflight_walls:
        for mi, wall in sorted(inflight_walls.items()):
            if mi == 1:
                continue
            speedup = inflight_walls[1] / max(wall, 1e-9)
            rows.append({
                "name": f"async_inflight{mi}_speedup_c{clients}",
                "us_per_call": 0.0,
                "derived": f"{speedup:.2f}x wall vs inflight=1",
                "speedup": speedup,
                "max_inflight": mi,
            })
            if verbose:
                print(f"[inflight{mi} speedup   ] {speedup:.2f}x wall-clock "
                      f"vs inflight=1")

    # Adaptive-control gate: static clipped tta / adaptive clipped tta, as a
    # scale-free "speedup" row (>= 1 means the control loop pays its way).
    if {"ab_static", "ab_adaptive"} <= ab_tta.keys():
        ratio = ab_tta["ab_static"] / max(ab_tta["ab_adaptive"], 1e-9)
        rows.append({
            "name": f"async_adaptive_tta_ratio_c{clients}",
            "us_per_call": 0.0,
            "derived": (f"{ratio:.2f}x virtual tta vs static control "
                        f"(static={ab_tta['ab_static']:.2f}s "
                        f"adaptive={ab_tta['ab_adaptive']:.2f}s)"),
            "speedup": ratio,
            "controller": "adaptive",
        })
        if verbose:
            print(f"[adaptive tta ratio  ] {ratio:.2f}x virtual "
                  f"time-to-accuracy vs static control")

    # Trace-participation gate: same clipped-tta ratio on the skewed diurnal
    # trace — the participation controller must not slow the run down.
    if {"trace_static", "trace_adaptive"} <= ab_tta.keys():
        ratio = ab_tta["trace_static"] / max(ab_tta["trace_adaptive"], 1e-9)
        rows.append({
            "name": f"async_trace_adaptive_tta_ratio_c{clients}",
            "us_per_call": 0.0,
            "derived": (f"{ratio:.2f}x virtual tta vs static participation "
                        f"(static={ab_tta['trace_static']:.2f}s "
                        f"adaptive={ab_tta['trace_adaptive']:.2f}s)"),
            "speedup": ratio,
            "controller": "adaptive",
            "participation_sampling": "biased",
            "trace": "diurnal",
        })
        if verbose:
            print(f"[trace tta ratio     ] {ratio:.2f}x virtual "
                  f"time-to-accuracy vs static participation control")
    return rows


def run(quick: bool = True):
    """Harness hook: a reduced sweep in quick mode."""
    if quick:
        return bench(clients=6, rounds=8, participations=(0.5,),
                     staleness_exps=(0.0, 2.0), inflights=(1, 2),
                     verbose=False)
    return bench(clients=16, rounds=24, verbose=False)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--samples-per-client", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--threshold", type=float, default=0.4,
                    help="accuracy threshold for time-to-accuracy")
    ap.add_argument("--speed-spread", type=float, default=3.0)
    ap.add_argument("--engine", choices=["sequential", "vmap", "shard_map"],
                    default="vmap")
    ap.add_argument("--sim-devices", type=int, default=0,
                    help="forced CPU host devices / shard_map mesh size "
                         "(must be the first jax use; gives inflight "
                         "cohorts disjoint submeshes to land on)")
    ap.add_argument("--inflight", type=int, nargs="+", default=[1, 2, 4],
                    help="max_inflight_cohorts values to sweep")
    ap.add_argument("--participations", type=float, nargs="*", default=None,
                    help="participation grid (empty list skips the "
                         "staleness sweep — the CI bench lane's pinned "
                         "config)")
    ap.add_argument("--staleness-exps", type=float, nargs="*", default=None)
    ap.add_argument("--json", default="",
                    help="also write rows as machine-readable JSON to PATH")
    args = ap.parse_args(argv)
    from benchmarks.common import enable_compile_cache
    enable_compile_cache()
    parts = ((1.0, 0.5) if args.participations is None
             else tuple(args.participations))
    exps = ((0.0, 0.5, 2.0) if args.staleness_exps is None
            else tuple(args.staleness_exps))
    rows = bench(clients=args.clients,
                 samples_per_client=args.samples_per_client,
                 rounds=args.rounds, threshold=args.threshold,
                 speed_spread=args.speed_spread, engine=args.engine,
                 sim_devices=args.sim_devices, participations=parts,
                 staleness_exps=exps, inflights=tuple(args.inflight))
    if args.json:
        from benchmarks.common import write_json_rows
        write_json_rows(args.json, rows, bench="async_bench",
                        clients=args.clients, rounds=args.rounds,
                        threshold=args.threshold,
                        speed_spread=args.speed_spread,
                        engine=args.engine, sim_devices=args.sim_devices,
                        inflight=list(args.inflight))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
