"""Paper Table 6: initial full-network warm-up ablation (0 / 2 / 5 rounds)."""

from repro.fl import FLRunConfig

from benchmarks.common import fedpart_schedule, timed_run, vision_setup


def run(quick: bool = True):
    adapter, clients, eval_set = vision_setup(samples=500 if quick else 1500,
                                              clients=3)
    rows = []
    warmups = [0, 2] if quick else [0, 2, 5]
    for w in warmups:
        schedule = fedpart_schedule(num_groups=10, warmup=w)
        cfg = FLRunConfig(local_epochs=1, batch_size=32, lr=1e-3)
        _, row = timed_run(f"table6/warmup{w}", adapter, clients, eval_set,
                           schedule.rounds(), cfg)
        rows.append(row)
    return rows
