"""Paper Table 1: FedAvg / FedProx / MOON x {FNU, FedPart} on synthetic
vision (reduced scale; directional claims)."""

from repro.fl import AlgoConfig, FLRunConfig

from benchmarks.common import compare_fnu_fedpart, fedpart_schedule, vision_setup


def run(quick: bool = True):
    adapter, clients, eval_set = vision_setup(
        samples=600 if quick else 2000, clients=3 if quick else 8
    )
    schedule = fedpart_schedule(num_groups=10, quick=quick,
                                cycles=1 if quick else 2)
    rows = []
    algos = ["fedavg"] if quick else ["fedavg", "fedprox", "moon"]
    for algo in algos:
        # local_epochs=2 quick / 8 full: the paper's mechanism (layer
        # mismatch) needs heavy local training; see claims_experiment.py.
        cfg = FLRunConfig(local_epochs=2 if quick else 8, batch_size=32,
                          lr=1e-3, algo=AlgoConfig(name=algo))
        rows += compare_fnu_fedpart(f"table1/{algo}", adapter, clients,
                                    eval_set, schedule, cfg)
    return rows
