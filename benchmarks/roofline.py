"""Roofline table: reads the dry-run artifacts (experiments/dryrun/*.json)
and emits the per-(arch x shape x mesh) three-term roofline rows.  Also the
generator for EXPERIMENTS.md §Roofline (``python -m benchmarks.roofline``)."""

from __future__ import annotations

import glob
import json
import os

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records(pattern: str = "*.json") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, pattern))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(quick: bool = True):
    rows = []
    for rec in load_records():
        name = f"roofline/{rec['arch']}__{rec['shape']}__{rec['mesh']}"
        if rec.get("mode") == "fedpart":
            name += f"__fedpart[{rec.get('fedpart_group')}]"
        if rec.get("status") != "ok":
            rows.append({"name": name, "us_per_call": 0.0,
                         "derived": f"skipped:{rec.get('reason', '?')}"})
            continue
        r = rec["roofline"]
        rows.append({
            "name": name,
            "us_per_call": r["compute_s"] * 1e6,
            "derived": (
                f"dominant={r['dominant']} "
                f"compute={r['compute_s']*1e3:.2f}ms "
                f"mem={r['memory_s_min']*1e3:.2f}-{r['memory_s_hlo']*1e3:.0f}ms "
                f"coll={r['collective_s']*1e3:.2f}ms "
                f"hbm={rec['hbm_gb_per_device']:.2f}GB/dev "
                f"useful={rec['model_flops_total_ratio']:.2f}"
            ),
        })
    if not rows:
        rows.append({"name": "roofline/none", "us_per_call": 0.0,
                     "derived": "no dry-run artifacts; run python -m repro.launch.dryrun --all"})
    return rows


def markdown_table(records: list[dict]) -> str:
    """EXPERIMENTS.md §Roofline table."""
    lines = [
        "| arch | shape | mesh | mode | GB/dev | fits 16GB | compute (ms) | "
        "mem lo-hi (ms) | coll (ms) | dominant | useful-FLOPs |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in records:
        if rec.get("status") == "skipped":
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                f"{rec.get('mode','-')} | — | — | — | — | — | skipped | — |"
            )
            continue
        r = rec["roofline"]
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | {rec['mode']} "
            f"| {rec['hbm_gb_per_device']:.2f} | {'Y' if rec['fits_v5e_16gb'] else 'N'} "
            f"| {r['compute_s']*1e3:.2f} "
            f"| {r['memory_s_min']*1e3:.2f}–{r['memory_s_hlo']*1e3:.0f} "
            f"| {r['collective_s']*1e3:.2f} | {r['dominant']} "
            f"| {rec['model_flops_total_ratio']:.2f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table(load_records()))
