"""Benchmark entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # quick (CI) mode
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale grid
    PYTHONPATH=src python -m benchmarks.run --only table1,fig1

Prints ``name,us_per_call,derived`` CSV per the harness convention.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = [
    "table1_algorithms",
    "table2_resnet18",
    "table3_nlp",
    "table4_heterogeneity",
    "table5_rounds_per_layer",
    "table6_warmup",
    "table7_order",
    "table9_privacy",
    "table13_kvalue",
    "fig1_stepsizes",
    "engine_bench",
    "async_bench",
    "hetero_bench",
    "population_bench",
    "compress_bench",
    "kernels_bench",
    "roofline",
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale grid")
    ap.add_argument("--only", default="", help="comma list of bench prefixes")
    args = ap.parse_args(argv)

    selected = BENCHES
    if args.only:
        prefixes = [p.strip() for p in args.only.split(",")]
        selected = [b for b in BENCHES if any(b.startswith(p) for p in prefixes)]

    print("name,us_per_call,derived")
    failures = 0
    for mod_name in selected:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            rows = mod.run(quick=not args.full)
            for row in rows:
                print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
            sys.stdout.flush()
        except Exception:
            failures += 1
            print(f"{mod_name},0,FAILED", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {mod_name} done in {time.time()-t0:.0f}s", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
