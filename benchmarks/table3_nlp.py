"""Paper Table 3: language modality — FedPart on the transformer classifier
(AGNews-style synthetic task)."""

from repro.fl import FLRunConfig

from benchmarks.common import compare_fnu_fedpart, fedpart_schedule, text_setup


def run(quick: bool = True):
    adapter, clients, eval_set = text_setup(samples=800 if quick else 2400,
                                            clients=3 if quick else 8)
    schedule = fedpart_schedule(num_groups=4, quick=quick, rl=2,
                                cycles=1 if quick else 3)
    cfg = FLRunConfig(local_epochs=2, batch_size=32, lr=1e-3)
    return compare_fnu_fedpart("table3/nlp", adapter, clients, eval_set,
                               schedule, cfg)
