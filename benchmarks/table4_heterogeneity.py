"""Paper Table 4 / Appendix F.3: data heterogeneity (Dirichlet alpha=1 and
extreme alpha=0.1)."""

from repro.fl import FLRunConfig

from benchmarks.common import compare_fnu_fedpart, fedpart_schedule, vision_setup


def run(quick: bool = True):
    rows = []
    alphas = [1.0] if quick else [1.0, 0.1]
    for alpha in alphas:
        adapter, clients, eval_set = vision_setup(
            samples=600 if quick else 2000, clients=4, alpha=alpha,
        )
        schedule = fedpart_schedule(num_groups=10, quick=quick)
        cfg = FLRunConfig(local_epochs=1, batch_size=32, lr=1e-3)
        rows += compare_fnu_fedpart(f"table4/alpha{alpha}", adapter, clients,
                                    eval_set, schedule, cfg)
    return rows
