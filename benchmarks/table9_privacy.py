"""Paper Table 9: DLG reconstruction PSNR — full-network gradients vs a
single FedPart group's gradients (less information -> worse reconstruction)."""

import time

import jax
import jax.numpy as jnp

from repro.core.partition import build_partition
from repro.fl.privacy import DLGConfig, dlg_attack, psnr
from repro.models import resnet


def run(quick: bool = True):
    # Small conv net (resnet8 first block scale) + one target image.
    params = resnet.resnet_init(jax.random.key(0), resnet.RESNET8, 8)
    part = build_partition(params, resnet.resnet_group_key, resnet.resnet_order_key)
    target = jax.random.normal(jax.random.key(5), (1, 16, 16, 3)) * 0.5
    label = jnp.array([1])

    def loss_fn(p, x):
        logits, _ = resnet.resnet_apply(p, x, train=False)
        return resnet.cls_loss(logits, label)

    iters = 250 if quick else 600
    cfg = DLGConfig(iterations=iters, lr=0.05)
    rows = []
    cases = [("all", None), ("#1_conv", 0)] if quick else [
        ("all", None), ("#1_conv", 0), ("#9_conv", 8), ("#10_fc", 9)]
    for name, group in cases:
        t0 = time.time()
        x_hat, match = dlg_attack(
            loss_fn, params, target, cfg,
            partition=part if group is not None else None, group=group,
        )
        p = float(psnr(target, x_hat, data_range=2.0))
        rows.append({
            "name": f"table9/dlg_{name}",
            "us_per_call": 1e6 * (time.time() - t0) / iters,
            "derived": f"psnr={p:.2f}dB",
            "psnr": p,
        })
    # paper's claim: partial < full
    full = next(r for r in rows if r["name"].endswith("all"))["psnr"]
    for r in rows[1:]:
        r["derived"] += f" (full={full:.2f}dB)"
    return rows
