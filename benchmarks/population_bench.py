"""Population-scale bench: per-round overhead and peak host memory when the
fleet goes from thousands (materialised) to a million (streamed).

Three variants of the same federation — identical model, cohort size, and
round count; only the client store changes:

* ``mat_nS``      — the legacy path: every shard materialised up front
                    (``build_clients`` over one global array);
* ``stream_nS``   — a ``fl.population.SyntheticPopulation`` of the same S
                    clients, shards derived on demand from (seed, id);
* ``stream_nL``   — the same streaming store at L = 10^6 clients: the
                    population the legacy path cannot even allocate.

Each row reports per-round wall-clock (warm compile cache; the cohort's
training cost is identical across variants, so wall differences isolate the
client-store overhead) and the tracemalloc peak of host allocations across
the run (device buffers are out of scope — the population machinery is
host-side numpy by design).

Two scale-free ratios feed the CI regression gate (``benchmarks/compare.py``,
``bench.yml``):

* ``overhead_ratio``  = per-round wall at L-stream / S-stream.  O(cohort)
  dispatch means the population size must not show up in the round loop —
  the ratio stays ~1 and a regression means an O(N) scan crept back in;
* ``peak_ratio``      = peak host bytes at L-stream / S-materialised.  The
  million-client run must stay *cheaper* than materialising thousands —
  the ratio sits well below 1 and a regression means the store started
  retaining O(population) state.

    PYTHONPATH=src python benchmarks/population_bench.py --json population.json
    PYTHONPATH=src python benchmarks/population_bench.py --population 1000000
"""

from __future__ import annotations

import argparse
import os
import sys
import time
import tracemalloc

sys.path.insert(0, "src")
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core.schedule import FedPartSchedule
from repro.data import (VisionDatasetSpec, balanced_eval_set, build_clients,
                        iid_partition, make_vision_dataset)
from repro.fl import FLRunConfig, resnet_task, run_federated
from repro.fl.population import SyntheticPopulation


def _setup(num_classes=4, image_size=8):
    spec = VisionDatasetSpec(num_classes=num_classes, image_size=image_size)
    Xe, ye = make_vision_dataset(spec, 128, seed=99)
    eval_set = balanced_eval_set(Xe, ye, per_class=16)
    return spec, resnet_task("resnet4", num_classes=num_classes), eval_set


def _measure(adapter, clients, eval_set, rounds, cfg):
    """(per-round wall seconds, peak host bytes) for one federated run.

    tracemalloc wraps the whole run — including, for the materialised
    variant, nothing (its arrays were built outside) — so builders are
    passed as thunks: the O(N) materialisation cost must land inside the
    traced region it belongs to."""
    tracemalloc.start()
    data = clients() if callable(clients) else clients
    t0 = time.time()
    res = run_federated(adapter, data, eval_set, rounds, cfg)
    wall = time.time() - t0
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert res.history, "bench run produced no rounds"
    return wall / max(len(rounds), 1), peak


def bench(population_small=2000, population_large=1_000_000, cohort=4,
          rounds=3, samples_per_client=16, verbose=True):
    spec, adapter, eval_set = _setup()
    sched = FedPartSchedule(num_groups=4, warmup_rounds=1, rounds_per_layer=1,
                            cycles=1)
    specs = sched.rounds()[:rounds]
    cfg = FLRunConfig(local_epochs=1, batch_size=16, lr=2e-3, adam_eps=1e-3,
                      engine="sequential", cohort_size=cohort)

    # Warm the XLA compiles on a throwaway fleet so every measured run pays
    # only the client-store costs the bench is about.
    warm = SyntheticPopulation(spec=spec, population=8,
                               samples_per_client=samples_per_client, seed=1)
    run_federated(adapter, warm, eval_set, specs, cfg)

    def mat_clients():
        X, y = make_vision_dataset(
            spec, samples_per_client * population_small, seed=0)
        return build_clients(
            X, y, iid_partition(len(y), population_small, seed=0))

    variants = [
        (f"mat_n{population_small}", mat_clients),
        (f"stream_n{population_small}", lambda: SyntheticPopulation(
            spec=spec, population=population_small,
            samples_per_client=samples_per_client, seed=0)),
        (f"stream_n{population_large}", lambda: SyntheticPopulation(
            spec=spec, population=population_large,
            samples_per_client=samples_per_client, seed=0)),
    ]

    rows, stats = [], {}
    for name, clients in variants:
        per_round, peak = _measure(adapter, clients, eval_set, specs, cfg)
        stats[name] = (per_round, peak)
        row = {
            "name": f"population_{name}",
            "us_per_call": 1e6 * per_round,
            "derived": (f"per_round={per_round:.3f}s "
                        f"peak_host={peak / 1e6:.1f}MB"),
            "wall_seconds": per_round * len(specs),
            "per_round_seconds": per_round,
            "peak_host_bytes": peak,
            "cohort": cohort,
            "rounds": len(specs),
        }
        rows.append(row)
        if verbose:
            print(f"[{name:16s}] {row['derived']}")

    small, large = (f"stream_n{population_small}",
                    f"stream_n{population_large}")
    mat = f"mat_n{population_small}"
    overhead = stats[large][0] / max(stats[small][0], 1e-9)
    peak_ratio = stats[large][1] / max(stats[mat][1], 1)
    rows.append({
        "name": f"population_overhead_n{population_large}",
        "us_per_call": 0.0,
        "derived": f"{overhead:.2f}x per-round wall vs n={population_small}",
        "overhead_ratio": overhead,
    })
    rows.append({
        "name": f"population_peak_n{population_large}",
        "us_per_call": 0.0,
        "derived": (f"{peak_ratio:.3f}x peak host memory vs materialised "
                    f"n={population_small}"),
        "peak_ratio": peak_ratio,
    })
    if verbose:
        print(f"[overhead_ratio  ] {overhead:.2f}x per-round "
              f"(1M stream vs {population_small} stream)")
        print(f"[peak_ratio      ] {peak_ratio:.3f}x peak host bytes "
              f"(1M stream vs {population_small} materialised)")
    return rows


def run(quick: bool = True):
    """Harness hook for ``python -m benchmarks.run``."""
    if quick:
        return bench(population_small=1000, rounds=2, verbose=False)
    return bench(verbose=False)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--population-small", type=int, default=2000)
    ap.add_argument("--population", type=int, default=1_000_000,
                    help="large (streamed) population size")
    ap.add_argument("--cohort-size", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--samples-per-client", type=int, default=16)
    ap.add_argument("--json", default="",
                    help="also write rows as machine-readable JSON to PATH")
    args = ap.parse_args(argv)
    from benchmarks.common import enable_compile_cache
    enable_compile_cache()
    rows = bench(population_small=args.population_small,
                 population_large=args.population,
                 cohort=args.cohort_size, rounds=args.rounds,
                 samples_per_client=args.samples_per_client)
    if args.json:
        from benchmarks.common import write_json_rows
        write_json_rows(args.json, rows, bench="population_bench",
                        population_small=args.population_small,
                        population_large=args.population,
                        cohort=args.cohort_size, rounds=args.rounds)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
