"""The paper's headline claim at a faithful operating point.

The quick benchmarks run local_epochs=1 for CPU budget — but the paper's
mechanism *requires* heavy local training (8 local epochs): layer mismatch is
created by averaging well-converged local models.  This experiment uses the
paper's 8 local epochs at matched communication rounds and reports
FedPart vs FNU accuracy + the cost ledger + step-size spikes.

    PYTHONPATH=src python experiments/claims_experiment.py [--epochs 8]
"""

import argparse
import json
import sys
import time

sys.path.insert(0, "src")

from repro.core.schedule import FedPartSchedule, matched_fnu
from repro.data import (VisionDatasetSpec, balanced_eval_set, build_clients,
                        iid_partition, make_vision_dataset)
from repro.fl import FLRunConfig, resnet_task, run_federated


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--samples", type=int, default=800)
    ap.add_argument("--classes", type=int, default=16)
    ap.add_argument("--noise", type=float, default=1.2)
    ap.add_argument("--cycles", type=int, default=2)
    ap.add_argument("--out", default="experiments/claims_result.json")
    args = ap.parse_args()

    spec = VisionDatasetSpec(num_classes=args.classes, image_size=16,
                             noise=args.noise)
    X, y = make_vision_dataset(spec, args.samples, seed=0)
    Xe, ye = make_vision_dataset(spec, args.samples // 2, seed=99)
    eval_set = balanced_eval_set(Xe, ye, per_class=16)
    clients = build_clients(X, y, iid_partition(len(y), args.clients, seed=0))
    adapter = resnet_task("resnet8", num_classes=args.classes)

    sched = FedPartSchedule(num_groups=10, warmup_rounds=3, rounds_per_layer=1,
                            cycles=args.cycles, bridge_rounds=2)
    cfg = FLRunConfig(local_epochs=args.epochs, batch_size=32, lr=1e-3,
                      track_stepsizes=True)

    t0 = time.time()
    fp = run_federated(adapter, clients, eval_set, sched.rounds(), cfg,
                       verbose=True)
    fnu = run_federated(adapter, clients, eval_set,
                        matched_fnu(sched).rounds(), cfg, verbose=True)
    out = {
        "local_epochs": args.epochs,
        "rounds": sched.total_rounds,
        "fedpart": {"best_acc": fp.best_acc, "final_acc": fp.final_acc,
                    "comm_ratio": fp.comm_total_bytes / fp.comm_fnu_bytes,
                    "comp_ratio": fp.comp_total_flops / fp.comp_fnu_flops,
                    "spike": fp.tracker.post_aggregation_spike(),
                    "acc_curve": [h.get("acc") for h in fp.history]},
        "fnu": {"best_acc": fnu.best_acc, "final_acc": fnu.final_acc,
                "spike": fnu.tracker.post_aggregation_spike(),
                "acc_curve": [h.get("acc") for h in fnu.history]},
        "elapsed_s": time.time() - t0,
    }
    print(json.dumps(out, indent=2, default=float))
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, default=float)


if __name__ == "__main__":
    main()
