#!/usr/bin/env bash
# Canonical tier-1 verify: the fast correctness subset (everything not marked
# `slow`; see pytest.ini).  Usage:
#
#   scripts/tier1.sh                      # tier-1 subset, fail-fast
#   scripts/tier1.sh --slow               # the full suite, slow lane included
#   scripts/tier1.sh -k engine            # extra pytest args pass through
#   scripts/tier1.sh -k engine --slow     # flags are position-independent
set -euo pipefail
cd "$(dirname "$0")/.."

ARGS=(-x -q)
REST=()
for arg in "$@"; do
  if [[ "$arg" == "--slow" ]]; then
    ARGS+=(-m "")        # clear the default "not slow" filter from pytest.ini
  else
    REST+=("$arg")
  fi
done

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest "${ARGS[@]}" ${REST[@]+"${REST[@]}"}
