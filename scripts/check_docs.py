#!/usr/bin/env python
"""Docs drift checker — fails the build when prose and code disagree.

Run from the repo root (the tier-1 lint lane does: ``python
scripts/check_docs.py``).  Three classes of rot are caught:

1. **Flag-table drift** — every field of ``FLRunConfig`` (parsed from
   ``src/repro/fl/server.py`` with ``ast``; no jax import, so this runs
   anywhere) must appear as a row of README.md's knob table *and* be
   mentioned in at least one ``docs/*.md`` page.
2. **Dead links** — every relative markdown link in README.md and
   ``docs/*.md`` must resolve to an existing file (anchors stripped).
3. **Dead path references** — every ``src/`` / ``tests/`` / ``scripts/`` /
   ``benchmarks/`` / ``examples/`` / ``docs/`` path mentioned anywhere in
   those documents must exist on disk.

Exit status is the number of failures (0 = clean); each failure prints one
``[check_docs] FAIL`` line with the file and the offending reference.
"""

from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
CONFIG_SOURCE = ROOT / "src" / "repro" / "fl" / "server.py"
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

# path-like tokens we hold docs accountable for (prose or backticks)
PATH_RE = re.compile(
    r"\b(?:src|tests|scripts|benchmarks|examples|docs)/[A-Za-z0-9_./-]*")
# [text](target) markdown links; targets with a scheme are skipped below
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def flrunconfig_fields() -> list[str]:
    """FLRunConfig's annotated field names, via ast (no repro/jax import)."""
    tree = ast.parse(CONFIG_SOURCE.read_text(), filename=str(CONFIG_SOURCE))
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "FLRunConfig":
            return [stmt.target.id for stmt in node.body
                    if isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)]
    raise SystemExit(f"[check_docs] FLRunConfig not found in {CONFIG_SOURCE}")


def check_flag_table(fields: list[str], failures: list[str]) -> None:
    readme = (ROOT / "README.md").read_text()
    # table rows look like: | `field_name` | `--flag` or — | meaning |
    table_fields = set(re.findall(r"^\|\s*`(\w+)`\s*\|", readme, re.M))
    docs_text = "\n".join(p.read_text() for p in DOC_FILES
                          if p.parent.name == "docs")
    for field in fields:
        if field not in table_fields:
            failures.append(
                f"README.md: FLRunConfig.{field} missing from the knob table")
        if not re.search(rf"\b{re.escape(field)}\b", docs_text):
            failures.append(
                f"docs/: FLRunConfig.{field} not documented in any docs page")
    for name in table_fields - set(fields):
        failures.append(
            f"README.md: knob table row `{name}` is not an FLRunConfig field")


def check_links(doc: Path, text: str, failures: list[str]) -> None:
    for target in LINK_RE.findall(text):
        if "://" in target or target.startswith(("mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (doc.parent / rel).resolve()
        # README badge links point at ../../actions/... on the forge, not at
        # files in the tree — only hold links accountable inside the repo.
        if ROOT not in resolved.parents and resolved != ROOT:
            continue
        if not resolved.exists():
            failures.append(f"{doc.relative_to(ROOT)}: dead link -> {target}")


def check_path_refs(doc: Path, text: str, failures: list[str]) -> None:
    for token in PATH_RE.findall(text):
        path = token.rstrip(".,;:")
        # glob-ish mentions ("docs/*.md", "BENCH_*.json") aren't single paths
        if "*" in path or not (ROOT / path).exists():
            if "*" in path:
                matches = list(ROOT.glob(path))
                if matches:
                    continue
            failures.append(
                f"{doc.relative_to(ROOT)}: references missing path {path}")


def main() -> int:
    failures: list[str] = []
    fields = flrunconfig_fields()
    check_flag_table(fields, failures)
    for doc in DOC_FILES:
        text = doc.read_text()
        check_links(doc, text, failures)
        check_path_refs(doc, text, failures)
    for line in failures:
        print(f"[check_docs] FAIL {line}")
    checked = len(DOC_FILES)
    print(f"[check_docs] {len(fields)} FLRunConfig fields, {checked} "
          f"documents, {len(failures)} failure(s)")
    return len(failures)


if __name__ == "__main__":
    sys.exit(main())
