"""Transmission-compression properties (core.compress, docs/COMPRESSION.md).

Pins the module's contracts (ISSUE 7):

* quantize→dequantize round-trips preserve shape and per-leaf dtype — checked
  through ``PackMeta`` (the packed masked-Adam layout's dtype-fidelity record),
  so the compressed path composes with the kernel's pack/unpack;
* int8 elementwise error is bounded by ``scale / 254`` per block;
* error-feedback residuals telescope: after any number of rounds,
  ``sum(transmitted) + residual == sum(true updates)``;
* the host wire format (``encode_leaf`` / ``decode_leaf``) is bit-identical
  to the on-device ``qdq_leaf`` path and its actual array bytes equal the
  analytic ledger (``leaf_encoded_bytes``);
* ``compression="none"`` is structurally absent (``make_config`` returns
  ``None``; ``CompressionConfig`` refuses the kind).

Property-based via hypothesis when available, with seeded deterministic
fallbacks mirroring tests/test_kernels_adam.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compress
from repro.core.compress import CompressionConfig, make_config
from repro.kernels.masked_adam import ops

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAS_HYPOTHESIS = False

KINDS = ("int8", "onebit", "topk")
_SHAPES = [(7,), (16,), (130,), (4, 33), (8, 128), (3, 5, 7), ()]
_DTYPES = [jnp.float32, jnp.bfloat16, jnp.float16]


def _cfg(kind, block_rows=0, topk_fraction=0.25):
    return CompressionConfig(kind=kind, block_rows=block_rows,
                             topk_fraction=topk_fraction)


def _rand(shape, dtype, seed):
    x = jax.random.normal(jax.random.key(seed), shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# "none" is structurally absent
# ---------------------------------------------------------------------------

def test_make_config_none_returns_none():
    assert make_config("none") is None
    assert make_config() is None


def test_config_rejects_none_kind():
    with pytest.raises(ValueError):
        CompressionConfig(kind="none")
    with pytest.raises(ValueError):
        make_config("gzip")
    with pytest.raises(ValueError):
        CompressionConfig(kind="topk", topk_fraction=0.0)
    with pytest.raises(ValueError):
        CompressionConfig(kind="int8", block_rows=-1)


def test_leaf_encoded_bytes_none_is_dense_f32():
    assert compress.leaf_encoded_bytes(100, None) == 400
    assert compress.leaf_encoded_bytes(0, None) == 0


# ---------------------------------------------------------------------------
# round-trip: shape + per-leaf dtype via PackMeta
# ---------------------------------------------------------------------------

def _mixed_tree(seed=0):
    return {
        "a": {"w": _rand((8, 128), jnp.float32, seed),
              "b": _rand((33,), jnp.bfloat16, seed + 1)},
        "c": {"s": _rand((), jnp.float32, seed + 2),
              "h": _rand((4, 33), jnp.float16, seed + 3)},
    }


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("block_rows", [0, 1])
def test_roundtrip_preserves_packmeta(kind, block_rows):
    """qdq (engines) and encode→decode (wire) both return trees whose packed
    layout — shapes, sizes, per-leaf dtypes recorded in PackMeta — is
    identical to the input's."""
    tree = _mixed_tree()
    cfg = _cfg(kind, block_rows)
    qdq = jax.tree.map(
        lambda x: compress.qdq_leaf(x.astype(jnp.float32), cfg).astype(x.dtype),
        tree)
    wire = jax.tree.map(
        lambda x: compress.decode_leaf(compress.encode_leaf(x, cfg), cfg), tree)
    _, meta0 = ops.pack(tree)
    for restored in (qdq, wire):
        _, meta = ops.pack(restored)
        assert meta.shapes == meta0.shapes
        assert meta.sizes == meta0.sizes
        assert meta.dtypes == meta0.dtypes
        assert meta.treedef == meta0.treedef


# ---------------------------------------------------------------------------
# int8 error bound: |x - deq| <= scale / 254 per block
# ---------------------------------------------------------------------------

def _assert_int8_bound(x, block_rows):
    cfg = _cfg("int8", block_rows)
    flat = jnp.asarray(x, jnp.float32).reshape(-1)
    deq = compress.qdq_leaf(flat, cfg)
    blocks, _ = compress._blocked(flat, cfg)
    scale = compress._int8_scales(blocks)          # (nb, 1)
    err, _ = compress._blocked(jnp.abs(flat - deq), cfg)
    bound = scale / 254.0 + 1e-7 * scale           # f32 rounding headroom
    assert bool(jnp.all(err <= bound)), (
        f"int8 error {float(err.max())} exceeds bound {float(bound.max())}")


@pytest.mark.parametrize("n", [1, 7, 128, 300])
@pytest.mark.parametrize("block_rows", [0, 1])
def test_int8_error_bound_seeded(n, block_rows):
    _assert_int8_bound(_rand((n,), jnp.float32, n), block_rows)


def test_int8_zero_block_is_exact():
    cfg = _cfg("int8")
    z = jnp.zeros((64,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(compress.qdq_leaf(z, cfg)),
                                  np.zeros(64, np.float32))


# ---------------------------------------------------------------------------
# error-feedback telescoping: sum(c) + r == sum(u)
# ---------------------------------------------------------------------------

def _assert_telescopes(kind, updates, block_rows=0):
    cfg = _cfg(kind, block_rows)
    g = jnp.zeros_like(updates[0])
    res = jnp.zeros_like(updates[0])
    sent = jnp.zeros_like(updates[0])
    for u in updates:
        tx, res = compress.transmit_leaf(g, g + u, res, cfg)
        sent = sent + (tx - g)
    total = np.asarray(sum(np.asarray(u, np.float64) for u in updates))
    np.testing.assert_allclose(np.asarray(sent) + np.asarray(res), total,
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind", KINDS)
def test_error_feedback_telescopes_seeded(kind):
    updates = [_rand((96,), jnp.float32, 10 + t) * 0.1 for t in range(5)]
    _assert_telescopes(kind, updates)


@pytest.mark.parametrize("kind", KINDS)
def test_no_error_feedback_keeps_residual_zero(kind):
    cfg = CompressionConfig(kind=kind, error_feedback=False, topk_fraction=0.25)
    g = jnp.zeros((64,), jnp.float32)
    res = jnp.zeros_like(g)
    for t in range(3):
        _, res = compress.transmit_leaf(g, g + _rand((64,), jnp.float32, t),
                                        res, cfg)
    np.testing.assert_array_equal(np.asarray(res), np.zeros(64, np.float32))


# ---------------------------------------------------------------------------
# wire format == on-device qdq, bit for bit; bytes match the analytic model
# ---------------------------------------------------------------------------

def _assert_wire_matches_qdq(x, kind, block_rows):
    cfg = _cfg(kind, block_rows)
    qdq = compress.qdq_leaf(jnp.asarray(x, jnp.float32), cfg)
    enc = compress.encode_leaf(x, cfg)
    dec = compress.decode_leaf(enc, cfg)
    np.testing.assert_array_equal(np.asarray(qdq, np.float32),
                                  np.asarray(dec, np.float32))
    assert enc.nbytes == compress.leaf_encoded_bytes(int(np.asarray(x).size),
                                                     cfg)


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("shape", [(5,), (128,), (4, 33), (8, 128)])
@pytest.mark.parametrize("block_rows", [0, 1])
def test_wire_matches_qdq_seeded(kind, shape, block_rows):
    _assert_wire_matches_qdq(_rand(shape, jnp.float32, sum(shape)), kind,
                             block_rows)


def test_topk_keeps_largest_magnitudes():
    cfg = _cfg("topk", topk_fraction=0.25)
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0, 1.0, -0.3], jnp.float32)
    deq = np.asarray(compress.qdq_leaf(x, cfg))
    # k = ceil(0.25 * 8) = 2: only the two largest-|x| survive
    assert np.count_nonzero(deq) == 2
    np.testing.assert_array_equal(deq[[1, 3]], np.asarray([-5.0, 3.0]))


def test_onebit_uses_mean_abs_scale():
    cfg = _cfg("onebit")
    x = jnp.asarray([1.0, -3.0, 2.0, -2.0], jnp.float32)
    deq = np.asarray(compress.qdq_leaf(x, cfg))
    np.testing.assert_allclose(deq, [2.0, -2.0, 2.0, -2.0], rtol=1e-6)


# ---------------------------------------------------------------------------
# property sweep (hypothesis when present, seeded fallback otherwise)
# ---------------------------------------------------------------------------

def _property_case(kind, shape, seed, block_rows):
    x = _rand(shape or (1,), jnp.float32, seed)
    x = x.reshape(shape)
    _assert_wire_matches_qdq(x, kind, block_rows)
    if kind == "int8":
        _assert_int8_bound(x, block_rows)
    flat_updates = [_rand((int(np.prod(shape)) or 1,), jnp.float32, seed + t)
                    for t in range(3)]
    _assert_telescopes(kind, flat_updates, block_rows)


if HAS_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(kind=st.sampled_from(KINDS),
           shape=st.sampled_from(_SHAPES),
           seed=st.integers(0, 2**31 - 1),
           block_rows=st.sampled_from([0, 1, 8]))
    def test_compress_properties(kind, shape, seed, block_rows):
        _property_case(kind, shape, seed, block_rows)

else:  # seeded fallback so the property is still exercised without hypothesis

    @pytest.mark.parametrize("seed", range(10))
    def test_compress_properties(seed):
        rng = np.random.default_rng(seed)
        _property_case(KINDS[int(rng.integers(len(KINDS)))],
                       _SHAPES[int(rng.integers(len(_SHAPES)))],
                       seed, int(rng.choice([0, 1, 8])))


# ---------------------------------------------------------------------------
# tree-level: stats and untrained groups pass through, residual untouched
# ---------------------------------------------------------------------------

def _stat_tree(seed=0):
    return {
        "blocks": {
            "0": {"w": _rand((64,), jnp.float32, seed),
                  "mean_ema": _rand((8,), jnp.float32, seed + 1)},
            "1": {"w": _rand((64,), jnp.float32, seed + 2)},
        },
    }


def _stat_partition():
    from repro.core.partition import Partition
    return Partition(
        group_keys=(("block", "blocks", 0), ("block", "blocks", 1)),
        assignment={"blocks/0/w": 0, "blocks/0/mean_ema": 0, "blocks/1/w": 1})


@pytest.mark.parametrize("kind", KINDS)
def test_transmit_tree_excludes_stats_and_untrained_groups(kind):
    cfg = _cfg(kind)
    part = _stat_partition()
    g = jax.tree.map(jnp.zeros_like, _stat_tree())
    local = _stat_tree(seed=5)
    res = compress.init_residual(g)
    tx, new_res = compress.transmit_tree(g, local, res, cfg, partition=part,
                                         groups=(0,))
    # transmitted leaf moved through Q
    assert float(jnp.abs(tx["blocks"]["0"]["w"] -
                         local["blocks"]["0"]["w"]).max()) > 0 or kind != "topk"
    # BN stat passes through exactly; untrained group leaf passes through
    np.testing.assert_array_equal(np.asarray(tx["blocks"]["0"]["mean_ema"]),
                                  np.asarray(local["blocks"]["0"]["mean_ema"]))
    np.testing.assert_array_equal(np.asarray(tx["blocks"]["1"]["w"]),
                                  np.asarray(local["blocks"]["1"]["w"]))
    # residuals: only the transmitted leaf's slot may move
    np.testing.assert_array_equal(
        np.asarray(new_res["blocks"]["1"]["w"]), np.zeros(64, np.float32))
    np.testing.assert_array_equal(
        np.asarray(new_res["blocks"]["0"]["mean_ema"]),
        np.zeros(8, np.float32))


@pytest.mark.parametrize("kind", KINDS)
def test_transmit_tree_plan_matches_static_selection(kind):
    """The traced-bitmask variant must agree with the structural one."""
    cfg = _cfg(kind)
    part = _stat_partition()
    g = jax.tree.map(jnp.zeros_like, _stat_tree())
    local = _stat_tree(seed=9)
    res = compress.init_residual(g)
    tx_a, res_a = compress.transmit_tree(g, local, res, cfg, partition=part,
                                         groups=(0,))
    tx_b, res_b = compress.transmit_tree_plan(
        g, local, res, jnp.asarray([1.0, 0.0]), cfg, partition=part)
    for a, b in zip(jax.tree.leaves(tx_a), jax.tree.leaves(tx_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(res_a), jax.tree.leaves(res_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_group_encoded_bytes_matches_tree_model():
    part = _stat_partition()
    tree = _stat_tree()
    for kind in KINDS:
        cfg = _cfg(kind)
        got = compress.group_encoded_bytes(tree, part, cfg)
        # group 0: compressed w (64) + dense-f32 stat (8); group 1: w only
        want0 = (compress.leaf_encoded_bytes(64, cfg) +
                 compress.leaf_encoded_bytes(8, None))
        want1 = compress.leaf_encoded_bytes(64, cfg)
        assert got.tolist() == [want0, want1]
        assert compress.tree_encoded_bytes(tree, cfg) == want0 + want1
