import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, masking
from repro.core.partition import build_partition
from repro.models import resnet
from tests.conftest import small_params


def test_mean_of_identical_models_is_identity(params):
    out = aggregation.tree_mean([params, params, params])
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_weighted_mean():
    a = {"w": jnp.zeros(4)}
    b = {"w": jnp.ones(4)}
    out = aggregation.tree_mean([a, b], weights=[1, 3])
    np.testing.assert_allclose(np.asarray(out["w"]), 0.75)


def test_partial_aggregate_touches_only_group(params):
    part = build_partition(params)
    clients = []
    for i in range(3):
        c = jax.tree.map(lambda x: x + 1.0 + i, params)
        clients.append(masking.select(c, part, 1))
    new = aggregation.aggregate_partial(params, clients)
    for (path, old), (_, nw) in zip(
        jax.tree_util.tree_flatten_with_path(params)[0],
        jax.tree_util.tree_flatten_with_path(new)[0],
    ):
        ps = "/".join(str(getattr(k, "key", k)) for k in path)
        if part.group_of(ps) == 1:
            np.testing.assert_allclose(np.asarray(nw), np.asarray(old) + 2.0,
                                       rtol=1e-5, atol=1e-6)
        else:
            np.testing.assert_array_equal(np.asarray(nw), np.asarray(old))


def test_bn_stats_never_aggregated():
    p = resnet.resnet_init(jax.random.key(0), resnet.RESNET8, 4)
    client = jax.tree.map(lambda x: x + 1.0, p)
    new = aggregation.aggregate_full(p, [client, client])
    flat_old = jax.tree_util.tree_flatten_with_path(p)[0]
    flat_new = jax.tree_util.tree_flatten_with_path(new)[0]
    saw_stat = False
    for (path, old), (_, nw) in zip(flat_old, flat_new):
        ps = "/".join(str(getattr(k, "key", k)) for k in path)
        if aggregation.is_local_stat(ps):
            saw_stat = True
            np.testing.assert_array_equal(np.asarray(nw), np.asarray(old))
        else:
            np.testing.assert_allclose(np.asarray(nw), np.asarray(old) + 1.0, rtol=1e-5)
    assert saw_stat
