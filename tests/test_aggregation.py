import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, masking
from repro.core.partition import build_partition
from repro.models import resnet


def test_mean_of_identical_models_is_identity(params):
    out = aggregation.tree_mean([params, params, params])
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_weighted_mean():
    a = {"w": jnp.zeros(4)}
    b = {"w": jnp.ones(4)}
    out = aggregation.tree_mean([a, b], weights=[1, 3])
    np.testing.assert_allclose(np.asarray(out["w"]), 0.75)


def test_partial_aggregate_touches_only_group(params):
    part = build_partition(params)
    clients = []
    for i in range(3):
        c = jax.tree.map(lambda x: x + 1.0 + i, params)
        clients.append(masking.select(c, part, 1))
    new = aggregation.aggregate_partial(params, clients)
    for (path, old), (_, nw) in zip(
        jax.tree_util.tree_flatten_with_path(params)[0],
        jax.tree_util.tree_flatten_with_path(new)[0],
    ):
        ps = "/".join(str(getattr(k, "key", k)) for k in path)
        if part.group_of(ps) == 1:
            np.testing.assert_allclose(np.asarray(nw), np.asarray(old) + 2.0,
                                       rtol=1e-5, atol=1e-6)
        else:
            np.testing.assert_array_equal(np.asarray(nw), np.asarray(old))


def test_bn_stats_never_aggregated():
    p = resnet.resnet_init(jax.random.key(0), resnet.RESNET8, 4)
    client = jax.tree.map(lambda x: x + 1.0, p)
    new = aggregation.aggregate_full(p, [client, client])
    flat_old = jax.tree_util.tree_flatten_with_path(p)[0]
    flat_new = jax.tree_util.tree_flatten_with_path(new)[0]
    saw_stat = False
    for (path, old), (_, nw) in zip(flat_old, flat_new):
        ps = "/".join(str(getattr(k, "key", k)) for k in path)
        if aggregation.is_local_stat(ps):
            saw_stat = True
            np.testing.assert_array_equal(np.asarray(nw), np.asarray(old))
        else:
            np.testing.assert_allclose(np.asarray(nw), np.asarray(old) + 1.0, rtol=1e-5)
    assert saw_stat


# ---------------------------------------------------------------------------
# Edge cases: single client, zero weights, BN stats on partial rounds,
# and the stacked (client-axis) reductions used by the vmap engine.
# ---------------------------------------------------------------------------

def test_single_client_round_is_identity_full(params):
    """With one client, full aggregation must return that client's params."""
    client = jax.tree.map(lambda x: x * 1.5 + 0.25, params)
    out = aggregation.aggregate_full(params, [client], weights=[17])
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(client)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_single_client_round_is_identity_partial(params):
    part = build_partition(params)
    client = jax.tree.map(lambda x: x - 2.0, params)
    out = aggregation.aggregate_partial(params, [masking.select(client, part, 2)],
                                        weights=[5])
    for (path, _), a, b in zip(
        jax.tree_util.tree_flatten_with_path(params)[0],
        jax.tree.leaves(out),
        jax.tree.leaves(params),
    ):
        ps = "/".join(str(getattr(k, "key", k)) for k in path)
        if part.group_of(ps) == 2:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b) - 2.0, rtol=1e-5)
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("weights", [[0, 0], [0.0, -1.0], [-3, 3]])
def test_zero_weight_guard(params, weights):
    """Degenerate client weights must fail loudly, not divide by zero."""
    with pytest.raises(ValueError, match="positive"):
        aggregation.tree_mean([params, params], weights=weights)


def test_weight_count_mismatch_guard(params):
    with pytest.raises(ValueError, match="weights"):
        aggregation.tree_mean([params, params], weights=[1.0])


def test_bn_stats_never_aggregated_on_partial_rounds():
    """Partial uploads carry the group's BN running moments, but the server
    must splice only the learnable leaves (paper §4: local statistics never
    travel into the global model)."""
    p = resnet.resnet_init(jax.random.key(0), resnet.RESNET8, 4)
    part = build_partition(p, resnet.resnet_group_key, resnet.resnet_order_key)
    clients = [masking.select(jax.tree.map(lambda x: x + 1.0 + i, p), part, g)
               for i in range(2) for g in [1]]
    new = aggregation.aggregate_partial(p, clients, weights=[1, 3])
    saw_stat = saw_learnable = False
    for (path, old), nw in zip(jax.tree_util.tree_flatten_with_path(p)[0],
                               jax.tree.leaves(new)):
        ps = "/".join(str(getattr(k, "key", k)) for k in path)
        if part.group_of(ps) != 1:
            np.testing.assert_array_equal(np.asarray(nw), np.asarray(old))
        elif aggregation.is_local_stat(ps):
            saw_stat = True
            np.testing.assert_array_equal(np.asarray(nw), np.asarray(old))
        else:
            saw_learnable = True
            # weighted mean of (+1, +2) at weights (1, 3) -> +1.75
            np.testing.assert_allclose(np.asarray(nw), np.asarray(old) + 1.75,
                                       rtol=1e-5, atol=1e-6)
    assert saw_stat and saw_learnable


def test_stacked_mean_matches_list_mean(params):
    clients = [jax.tree.map(lambda x: x * (i + 1.0), params) for i in range(3)]
    w = [1.0, 4.0, 2.0]
    ref = aggregation.tree_mean(clients, weights=w)
    stacked = masking.stack_trees(clients)
    out = aggregation.tree_mean_stacked(stacked, w)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_stacked_partial_matches_list_partial():
    p = resnet.resnet_init(jax.random.key(1), resnet.RESNET8, 4)
    part = build_partition(p, resnet.resnet_group_key, resnet.resnet_order_key)
    group, w = 3, [2.0, 1.0]
    clients = [jax.tree.map(lambda x: x + 0.5 * (i + 1), p) for i in range(2)]
    ref = aggregation.aggregate_partial(p, [masking.select(c, part, group) for c in clients], w)
    out = aggregation.aggregate_partial_stacked(p, masking.stack_trees(clients), part, group, w)
    assert jax.tree.structure(out) == jax.tree.structure(ref)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_stacked_full_matches_list_full():
    p = resnet.resnet_init(jax.random.key(2), resnet.RESNET8, 4)
    clients = [jax.tree.map(lambda x: x - 0.1 * (i + 1), p) for i in range(3)]
    w = [1.0, 1.0, 2.0]
    ref = aggregation.aggregate_full(p, clients, w)
    out = aggregation.aggregate_full_stacked(p, masking.stack_trees(clients), w)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_stacked_zero_weight_guard(params):
    stacked = masking.stack_trees([params, params])
    with pytest.raises(ValueError, match="positive"):
        aggregation.tree_mean_stacked(stacked, [0.0, 0.0])
