"""Expert-parallel (shard_map) MoE must match the GSPMD-auto path exactly —
run in a subprocess with 8 forced host devices."""

import json
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_config
from repro.models import api, moe_ep
from repro.models.api import InputShape

results = {}
for arch in ("deepseek-v3-671b", "llama4-maverick-400b-a17b"):
    cfg = get_config(arch, smoke=True).with_(num_experts=8)  # 8 experts / 2 model ranks
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    params = api.init(jax.random.key(0), cfg)
    shape = InputShape("t", 16, 4, "train")
    batch = api.synth_batch(jax.random.key(1), cfg, shape)

    with mesh:
        base = jax.jit(lambda p, b: api.loss(p, cfg, b))(params, batch)
        logits_base = jax.jit(lambda p, b: api.forward(p, cfg, b)[0])(params, batch)
    with moe_ep.expert_parallel(mesh):
        ep_fn = jax.jit(lambda p, b: api.loss(p, cfg, b))
        lg_fn = jax.jit(lambda p, b: api.forward(p, cfg, b)[0])
        with mesh:
            ep = ep_fn(params, batch)
            logits_ep = lg_fn(params, batch)
    # gradients too
    with mesh:
        g_base = jax.jit(jax.grad(lambda p: api.loss(p, cfg, batch)))(params)
    with moe_ep.expert_parallel(mesh):
        g_fn = jax.jit(jax.grad(lambda p: api.loss(p, cfg, batch)))
        with mesh:
            g_ep = g_fn(params)
    gdiff = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(g_base), jax.tree.leaves(g_ep))
    )
    results[arch] = {
        "loss_base": float(base), "loss_ep": float(ep),
        "loss_diff": abs(float(base) - float(ep)), "grad_maxdiff": gdiff,
        "logits_maxdiff": float(jnp.max(jnp.abs(logits_base - logits_ep))),
    }
print(json.dumps(results))
"""


import pytest


@pytest.mark.slow
def test_ep_matches_auto():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(__file__)), timeout=480,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    for arch, r in out.items():
        # model math must agree tightly
        assert r["logits_maxdiff"] < 2e-4, (arch, r)
        # the aux load-balance loss is computed per data shard + pmean under
        # EP (standard expert-parallel semantics) vs globally under auto —
        # a small, documented statistical difference.
        assert r["loss_diff"] < 2e-3, (arch, r)
        assert r["grad_maxdiff"] < 1e-2, (arch, r)
