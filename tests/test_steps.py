"""Launcher step functions: FedPart partial steps on stacked models update
exactly one layer group; optimizer state is subtree-sized."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import steps
from repro.models import api
from repro.models.api import InputShape
from repro.optim.adam import AdamConfig

TRAIN = InputShape("t", 16, 2, "train")


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = api.init(jax.random.key(0), cfg)
    batch = api.synth_batch(jax.random.key(1), cfg, TRAIN)
    return cfg, params, batch


def test_list_groups(setup):
    cfg, params, _ = setup
    groups = steps.list_groups(params)
    # embed + 2 blocks + tail(final_norm|head) = 4 groups for smoke tinyllama
    keys = [(g.key, g.index) for g in groups]
    assert keys[0] == ("embed", None)
    assert ("blocks", 0) in keys and ("blocks", 1) in keys
    assert keys[-1][0].startswith("final_norm")


def test_fnu_step_decreases_loss(setup):
    cfg, params, batch = setup
    step = jax.jit(steps.make_train_step(cfg, AdamConfig(lr=1e-3), remat=False))
    opt = steps.init_opt_state(params)
    p1, opt, l0 = step(params, opt, batch)
    p2, opt, l1 = step(p1, opt, batch)
    assert float(l1) < float(l0)


@pytest.mark.parametrize("gidx", [0, 1, 3])
def test_fedpart_step_touches_only_group(setup, gidx):
    cfg, params, batch = setup
    groups = steps.list_groups(params)
    group = groups[gidx % len(groups)]
    step = jax.jit(steps.make_fedpart_train_step(cfg, group, AdamConfig(lr=1e-2),
                                                 remat=False))
    opt = steps.init_partial_opt_state(params, group)
    newp, newopt, loss = step(params, opt, batch)
    assert np.isfinite(float(loss))

    # which stacked layers changed?
    for key in params:
        for (patha, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(params[key])[0],
            jax.tree_util.tree_flatten_with_path(newp[key])[0],
        ):
            a, b = np.asarray(a), np.asarray(b)
            if group.index is not None and key == group.key:
                # only layer group.index of this stack changed
                for layer in range(a.shape[0]):
                    changed = bool(np.any(a[layer] != b[layer]))
                    assert changed == (layer == group.index)
            elif group.index is None and key in group.key.split("|"):
                assert bool(np.any(a != b))
            else:
                np.testing.assert_array_equal(a, b)


def test_partial_opt_state_is_smaller(setup):
    cfg, params, _ = setup
    groups = steps.list_groups(params)
    full = steps.init_opt_state(params)
    part = steps.init_partial_opt_state(params, groups[1])
    n_full = sum(x.size for x in jax.tree.leaves(full.m))
    n_part = sum(x.size for x in jax.tree.leaves(part.m))
    assert n_part < n_full / 2


def test_prefill_and_serve_steps(setup):
    cfg, params, _ = setup
    shape = InputShape("p", 16, 2, "prefill")
    batch = api.synth_batch(jax.random.key(2), cfg, shape)
    logits, cache = jax.jit(steps.make_prefill_step(cfg))(params, batch)
    assert logits.shape == (2, 1, cfg.vocab_size)
    # decode against a fresh fixed-size cache
    dshape = InputShape("d", 32, 2, "decode")
    dbatch = api.synth_batch(jax.random.key(3), cfg, dshape)
    serve = jax.jit(steps.make_serve_step(cfg))
    lg, cache2 = serve(params, dbatch["token"], dbatch["cache"], dbatch["pos"])
    assert lg.shape == (2, 1, cfg.vocab_size)
