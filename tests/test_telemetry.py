import jax
import jax.numpy as jnp
import pytest

from repro.core.partition import build_partition
from repro.core.telemetry import StepSizeTracker, estimate_k, update_step_size
from tests.conftest import small_params


def test_update_step_size():
    a = {"w": jnp.zeros(4)}
    b = {"w": jnp.full((4,), 3.0)}
    assert update_step_size(a, b) == pytest.approx(6.0)


def test_tracker_spike_detection():
    t = StepSizeTracker()
    prev = {"w": jnp.zeros(4)}
    # small steps, boundary, then big steps (simulated mismatch spike)
    for delta in (0.1, 0.1, 0.1):
        new = {"w": prev["w"] + delta}
        t.record(prev, new)
        prev = new
    t.mark_round_boundary()
    for delta in (0.5, 0.5, 0.5):
        new = {"w": prev["w"] + delta}
        t.record(prev, new)
        prev = new
    spike = t.post_aggregation_spike(window=3)
    assert spike == pytest.approx(5.0, rel=0.01)


def test_estimate_k_lower_bound():
    params = small_params()
    part = build_partition(params)
    keys = jax.random.split(jax.random.key(0), 6)
    grads = [jax.tree.map(lambda x, kk=k: jax.random.normal(kk, x.shape) * 0.1, params)
             for k in keys]
    k_val = estimate_k(grads, part, params)
    assert k_val >= 1.0
    assert k_val < 5.0    # iid gaussian grads -> groups comparable (paper: ~1.1)
