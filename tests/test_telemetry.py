import jax
import jax.numpy as jnp
import pytest

from repro.core.partition import build_partition
from repro.core.telemetry import (StepSizeTracker, Timeline, estimate_k,
                                  update_step_size)
from tests.conftest import small_params


def test_update_step_size():
    a = {"w": jnp.zeros(4)}
    b = {"w": jnp.full((4,), 3.0)}
    assert update_step_size(a, b) == pytest.approx(6.0)


def test_tracker_spike_detection():
    t = StepSizeTracker()
    prev = {"w": jnp.zeros(4)}
    # small steps, boundary, then big steps (simulated mismatch spike)
    for delta in (0.1, 0.1, 0.1):
        new = {"w": prev["w"] + delta}
        t.record(prev, new)
        prev = new
    t.mark_round_boundary()
    for delta in (0.5, 0.5, 0.5):
        new = {"w": prev["w"] + delta}
        t.record(prev, new)
        prev = new
    spike = t.post_aggregation_spike(window=3)
    assert spike == pytest.approx(5.0, rel=0.01)


# -- Timeline windows (the controller's observation API, docs/CONTROL.md) ---


def _synthetic_timeline() -> Timeline:
    """Two merges with a straggling second cohort — every reducer below is
    hand-computable from these numbers."""
    tl = Timeline()
    tl.record(0.0, "dispatch", version=0, group=0, clients=[0, 1], t_end=4.0)
    tl.record(1.0, "dispatch", version=0, group=0, clients=[2], t_end=3.0)
    tl.record(2.0, "complete", client=0, staleness=0, comm_bytes=10,
              comp_flops=5.0)
    tl.record(2.0, "merge", version=0, group=0, loss=2.0)
    tl.record(3.0, "complete", client=2, staleness=1, comm_bytes=10,
              comp_flops=5.0)
    tl.record(4.0, "complete", client=1, staleness=1, comm_bytes=10,
              comp_flops=5.0)
    tl.record(4.0, "drop", client=3, comp_flops=5.0)
    tl.record(4.0, "merge", version=1, group=1, loss=1.0)
    tl.record(4.0, "eval", version=1, acc=0.5)
    return tl


def test_window_spans_last_merges_and_clamps():
    tl = _synthetic_timeline()
    w1 = tl.window(1)
    # boundary = merge v0 at t=2; window = everything after it
    assert (w1.t_start, w1.t_end) == (2.0, 4.0)
    assert w1.duration == 2.0 and w1.merges == 1
    assert len(w1.of_kind("complete")) == 2
    assert len(w1.of_kind("eval")) == 1      # trailing eval included
    # spanning more merges than exist clamps to the start of the run
    w9 = tl.window(9)
    assert (w9.t_start, w9.t_end) == (0.0, 4.0)
    assert w9.merges == 2 and len(w9.events) == len(tl.events)
    with pytest.raises(ValueError):
        tl.window(0)


def test_window_empty_and_single_merge_edges():
    empty = Timeline().window()
    assert (empty.t_start, empty.t_end, empty.duration) == (0.0, 0.0, 0.0)
    assert empty.events == [] and empty.merges == 0
    assert empty.staleness_moments() == (0.0, 0.0)
    assert empty.discounted_mix(1.0) == 1.0   # nothing delivered: neutral
    assert empty.effective_participation(4) == 0.0
    assert empty.span_seconds() == 0.0 and empty.overlap_seconds() == 0.0
    assert empty.group_progress() == {}
    single = Timeline()
    single.record(0.0, "dispatch", version=0, group=0, clients=[0], t_end=1.5)
    single.record(1.5, "complete", client=0, staleness=0, comm_bytes=4,
                  comp_flops=2.0)
    single.record(1.5, "merge", version=0, group=0, loss=3.0)
    w = single.window(4)
    assert (w.t_start, w.t_end) == (0.0, 1.5)
    assert w.staleness_moments() == (0.0, 0.0)
    assert w.effective_participation(2) == 0.5
    assert w.group_progress() == {0: 0.0}     # one merge: no delta yet


def test_window_staleness_moments_hand_computed():
    w = _synthetic_timeline().window(1)
    # completes in window: staleness 1 and 1 -> E[s]=1, E[s^2]=1
    assert w.staleness_moments() == (1.0, 1.0)
    full = _synthetic_timeline().window(2)
    # staleness 0, 1, 1 -> E[s]=2/3, E[s^2]=2/3
    m1, m2 = full.staleness_moments()
    assert m1 == pytest.approx(2 / 3) and m2 == pytest.approx(2 / 3)
    # discounted mix at a=1: mean(1, 1/2, 1/2) = 2/3
    assert full.discounted_mix(1.0) == pytest.approx(2 / 3)
    assert full.discounted_mix(0.0) == 1.0


def test_window_effective_participation_hand_computed():
    tl = _synthetic_timeline()
    # whole run: clients {0, 1, 2} delivered, client 3 only dropped
    assert tl.window(2).effective_participation(8) == pytest.approx(3 / 8)
    # last-merge window: clients {1, 2}
    assert tl.window(1).effective_participation(8) == pytest.approx(2 / 8)
    with pytest.raises(ValueError):
        tl.window(1).effective_participation(0)


def test_window_span_and_overlap_hand_computed():
    tl = _synthetic_timeline()
    full = tl.window(2)
    # spans [0,4] and [1,3]: 4 + 2 flight seconds, overlap [1,3] = 2
    assert full.span_seconds() == pytest.approx(6.0)
    assert full.overlap_seconds() == pytest.approx(2.0)
    # last-merge window [2,4]: both cohorts dispatched before it -> excluded
    assert tl.window(1).span_seconds() == 0.0
    # dispatches inside the window are clipped to its end
    tl2 = Timeline()
    tl2.record(0.0, "merge", version=0, group=0, loss=2.0)
    tl2.record(1.0, "dispatch", version=1, group=0, clients=[0], t_end=9.0)
    tl2.record(3.0, "merge", version=1, group=0, loss=1.0)
    assert tl2.window(1).span_seconds() == pytest.approx(2.0)  # [1,3] only


def test_window_group_progress_hand_computed():
    tl = Timeline()
    tl.record(1.0, "merge", version=0, group=0, loss=2.0)
    tl.record(2.0, "merge", version=1, group=0, loss=1.4)
    tl.record(3.0, "merge", version=2, group=-1, loss=1.3)
    tl.record(4.0, "merge", version=3, group=0, loss=1.0)
    w = tl.window(4)
    prog = w.group_progress()
    assert prog[0] == pytest.approx(1.0)      # 2.0 -> 1.0 across the window
    assert prog[-1] == 0.0                    # single FNU merge: no delta
    # a narrower window only sees the recent merges
    assert tl.window(2).group_progress() == {-1: 0.0, 0: 0.0}


def test_telemetry_doctests_run():
    """The Timeline/TimelineWindow docstrings double as unit specs; make
    sure every example actually runs (pytest.ini doesn't collect doctests
    globally, so exercise them here — same pattern as test_schedule.py)."""
    import doctest

    import repro.core.telemetry as m

    res = doctest.testmod(m)
    assert res.attempted > 0
    assert res.failed == 0


def test_estimate_k_lower_bound():
    params = small_params()
    part = build_partition(params)
    keys = jax.random.split(jax.random.key(0), 6)
    grads = [jax.tree.map(lambda x, kk=k: jax.random.normal(kk, x.shape) * 0.1, params)
             for k in keys]
    k_val = estimate_k(grads, part, params)
    assert k_val >= 1.0
    assert k_val < 5.0    # iid gaussian grads -> groups comparable (paper: ~1.1)
