"""The batched engines (vmap, shard_map) must match the sequential oracle.

Same federation, same schedule, same seeds, every engine: global params,
per-round history losses, and the comm/comp cost books must agree to <=1e-5
for FNU and partial rounds, across FedAvg / FedProx / MOON, including ragged
client sizes (different step counts, and — in the bucket test — a client
smaller than the batch size, which lands in its own batch-width bucket).

The same bar holds under heterogeneous *per-client layer plans*
(``FLRunConfig(plan=..., capacity_tiers=...)``, docs/HETEROGENEITY.md): the
sequential oracle trains each client's exact pruned group set while the
batched engines run one masked plan program over the stacked cohort — the
``test_hetero_plan_*`` block pins sequential == vmap == shard_map for nested
and random plans, ragged buckets, the degenerate async runtime, and (slow
lane) a forced-2-device mesh at inflight 1 and 2.

The shard_map engine is additionally pinned against the oracle on a
*multi-device* mesh: a subprocess forces 2 simulated host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=2``, which must precede
the first jax import — hence the subprocess, same pattern as
tests/test_moe_ep.py) so the client axis is genuinely sharded, padding
clients and all.  In-process tests cover the degenerate 1-device mesh.

Note on Adam eps: with the default eps=1e-8, Adam's bias-corrected first
steps normalise near-zero gradients to ±1, so benign float reassociation
between the vmapped and per-step compiled programs can flip an update's sign
and diverge by O(lr).  The runs here pin ``adam_eps=1e-3`` to keep the
comparison in Adam's linear regime — both engines still execute identical
configs, so this tests engine equivalence, not optimizer robustness.
"""

import jax
import numpy as np
import pytest

from repro.core.schedule import FedPartSchedule, FNUSchedule
from repro.data import (VisionDatasetSpec, balanced_eval_set, build_clients,
                        make_vision_dataset)
from repro.fl import AlgoConfig, FLRunConfig, resnet_task, run_federated

BATCH = 16


def _make_setup(client_sizes):
    spec = VisionDatasetSpec(num_classes=4, image_size=8)
    X, y = make_vision_dataset(spec, sum(client_sizes), seed=0)
    Xe, ye = make_vision_dataset(spec, 64, seed=9)
    eval_set = balanced_eval_set(Xe, ye, per_class=8)
    bounds = np.cumsum((0,) + tuple(client_sizes))
    parts = [np.arange(bounds[i], bounds[i + 1]) for i in range(len(client_sizes))]
    # resnet4: same BN / shortcut / multi-group structure as resnet8 at a
    # fraction of the XLA compile cost (the dominant cost here).
    return resnet_task("resnet4", num_classes=4), build_clients(X, y, parts), eval_set


@pytest.fixture(scope="module")
def setup():
    # Ragged step counts (36 -> 2 steps/epoch, 56 -> 3, 40 -> 2) in one
    # batch-width bucket: exercises the pad-and-mask step masking.
    return _make_setup((36, 56, 40))


def _run(setup, algo: str, engine: str, rounds, **kw):
    adapter, clients, eval_set = setup
    kw.setdefault("adam_eps", 1e-3)
    cfg = FLRunConfig(local_epochs=1, batch_size=BATCH, lr=2e-3,
                      algo=AlgoConfig(name=algo), engine=engine, **kw)
    return run_federated(adapter, clients, eval_set, rounds, cfg)


def _assert_equivalent(a, b, tol=1e-5):
    flat_a = jax.tree_util.tree_flatten_with_path(a.params)[0]
    flat_b = jax.tree.leaves(b.params)
    assert len(flat_a) == len(flat_b)
    for (path, la), lb in zip(flat_a, flat_b):
        np.testing.assert_allclose(
            np.asarray(la), np.asarray(lb), rtol=tol, atol=tol,
            err_msg=f"param {jax.tree_util.keystr(path)} diverged",
        )
    la = np.array([h["loss"] for h in a.history])
    lb = np.array([h["loss"] for h in b.history])
    np.testing.assert_allclose(la, lb, rtol=tol, atol=tol)
    assert a.comm_total_bytes == b.comm_total_bytes
    assert a.comm_fnu_bytes == b.comm_fnu_bytes
    assert a.comp_total_flops == b.comp_total_flops
    assert a.comp_fnu_flops == b.comp_fnu_flops


# 1 FNU warmup + 1 partial round (group 0): covers both phases per algorithm.
MIXED = FedPartSchedule(num_groups=6, warmup_rounds=1, rounds_per_layer=1,
                        cycles=1).rounds()[:2]


@pytest.mark.parametrize("algo", ["fedavg", "fedprox", "moon"])
def test_vmap_matches_sequential_mixed_schedule(setup, algo):
    seq = _run(setup, algo, "sequential", MIXED)
    vm = _run(setup, algo, "vmap", MIXED)
    _assert_equivalent(seq, vm)


def test_vmap_matches_sequential_small_client_bucket():
    """A client below the batch size (12 < 16) trains with bs=12 in the
    sequential oracle; the vmap engine must route it through its own
    batch-width bucket and still agree.  One partial round: bucket routing is
    phase-independent, and the fresh batch shapes make this the
    compile-heaviest case in the module."""
    small = _make_setup((12, 36, 20))
    seq = _run(small, "fedavg", "sequential", MIXED[1:])
    vm = _run(small, "fedavg", "vmap", MIXED[1:])
    _assert_equivalent(seq, vm)


@pytest.mark.slow
def test_vmap_matches_sequential_deeper_schedule(setup):
    """Longer horizon (second partial group + an extra FNU): drift stays
    bounded over more rounds too."""
    rounds = FedPartSchedule(num_groups=6, warmup_rounds=1, rounds_per_layer=1,
                             cycles=1).rounds()[:4]
    for algo in ("fedavg", "moon"):
        seq = _run(setup, algo, "sequential", rounds)
        vm = _run(setup, algo, "vmap", rounds)
        _assert_equivalent(seq, vm)


def test_vmap_matches_sequential_fnu_only(setup):
    rounds = FNUSchedule(2).rounds()
    seq = _run(setup, "fedavg", "sequential", rounds)
    vm = _run(setup, "fedavg", "vmap", rounds)
    _assert_equivalent(seq, vm)


@pytest.mark.parametrize("engine", ["vmap", "shard_map"])
def test_batched_engines_reject_stepsize_tracking(setup, engine):
    adapter, clients, eval_set = setup
    cfg = FLRunConfig(local_epochs=1, batch_size=BATCH, engine=engine,
                      track_stepsizes=True)
    with pytest.raises(ValueError, match="sequential"):
        run_federated(adapter, clients, eval_set, FNUSchedule(1).rounds(), cfg)


@pytest.mark.parametrize("engine", ["vmap", "shard_map"])
def test_batched_engines_zero_weight_guard(setup, engine):
    """Degenerate round weights must raise (as the oracle does via
    tree_mean), not propagate NaN through the on-device aggregation."""
    from repro.fl import LocalTrainer, make_engine
    from repro.optim.adam import AdamConfig

    adapter, clients, _ = setup
    params = adapter.init(jax.random.key(0))
    part = adapter.partition(params)
    algo = AlgoConfig()
    trainer = LocalTrainer(adapter=adapter, partition=part, algo=algo,
                           adam=AdamConfig(lr=1e-3))
    engine = make_engine(engine, trainer=trainer, partition=part, algo=algo)
    with pytest.raises(ValueError, match="positive"):
        engine.run_round(params, MIXED[1], clients,
                         seeds=[1, 2, 3], weights=[0, 0, 0],
                         epochs=1, batch_size=BATCH)


def test_unknown_engine_rejected(setup):
    adapter, clients, eval_set = setup
    cfg = FLRunConfig(engine="pmap")
    with pytest.raises(ValueError, match="unknown engine"):
        run_federated(adapter, clients, eval_set, FNUSchedule(1).rounds(), cfg)


# -- heterogeneous per-client layer plans (docs/HETEROGENEITY.md) -----------
#
# Capacity tiers chosen so all three tiers differ on resnet4's 6 groups
# (nested prefixes ceil(c*6) = 3 / 5 / 6).  MIXED's partial round trains
# group 0 — inside every prefix, so a nested plan for it is homogeneous and
# resolve_plan would collapse it to the legacy path; HETERO_MIXED swaps in a
# *group-4* partial round instead, which tier 0 clamps to its deepest group
# (2) while the other tiers follow the schedule (4) — both rounds get
# genuinely mixed cohorts, exercising the masked plan step, the per-group
# participant-weighted aggregation, and the zero-trainer frozen fallback
# (group 5 is trained by the full-capacity tier alone on the FNU round;
# groups 0, 1, 3, 5 have no trainer on the partial round).
#
# adam_eps: unlike the homogeneous tests (the same pruned program, vmapped vs
# looped), these compare two genuinely *different* float programs — the
# oracle's pruned group-set step vs the batched engines' masked FNU-shaped
# plan step — so reassociation noise on near-zero gradients is larger and
# eps=1e-3 no longer keeps every Adam step in the linear regime on plan FNU
# rounds (fedprox drifts to ~4e-5).  eps=1e-2 restores <=1e-5 headroom; the
# configs stay identical across engines, so equivalence is still the claim.

TIERS = (0.34, 0.67, 1.0)
HETERO_EPS = 1e-2
HETERO_MIXED = [MIXED[0],
                type(MIXED[1])(index=1, phase="partial", cycle=0, group=4)]


@pytest.mark.parametrize("algo", ["fedavg", "fedprox"])
def test_hetero_plan_vmap_matches_sequential(setup, algo):
    """Nested per-client plans: the vmapped masked-plan program must match
    the oracle's per-client pruned group sets, FNU + partial."""
    seq = _run(setup, algo, "sequential", HETERO_MIXED,
               plan="nested", capacity_tiers=TIERS, adam_eps=HETERO_EPS)
    vm = _run(setup, algo, "vmap", HETERO_MIXED,
              plan="nested", capacity_tiers=TIERS, adam_eps=HETERO_EPS)
    _assert_equivalent(seq, vm)


def test_hetero_plan_shard_map_matches_sequential(setup):
    """Per-group psum'd weight sums on the (degenerate 1-device) mesh must
    agree with the oracle; the multi-device sharpening lives in the slow
    2-device subprocess test."""
    seq = _run(setup, "fedavg", "sequential", HETERO_MIXED,
               plan="nested", capacity_tiers=TIERS, adam_eps=HETERO_EPS)
    sm = _run(setup, "fedavg", "shard_map", HETERO_MIXED,
              plan="nested", capacity_tiers=TIERS, adam_eps=HETERO_EPS)
    _assert_equivalent(seq, sm)


@pytest.mark.slow
def test_hetero_plan_random_kind_engines_agree(setup):
    """Seeded random plans (arbitrary per-client group subsets) through the
    same masked program: vmap == sequential.  Slow lane: the nested tests
    above pin the same masked program in tier-1; random only changes which
    bits are set (nightly hetero-equivalence job)."""
    seq = _run(setup, "fedavg", "sequential", HETERO_MIXED,
               plan="random", capacity_tiers=TIERS, adam_eps=HETERO_EPS)
    vm = _run(setup, "fedavg", "vmap", HETERO_MIXED,
              plan="random", capacity_tiers=TIERS, adam_eps=HETERO_EPS)
    _assert_equivalent(seq, vm)


@pytest.mark.slow
def test_hetero_plan_ragged_buckets(setup):
    """A client below the batch size routes through its own bucket while the
    per-client bitmask rides along (heterogeneous version of the
    small-client bucket test).  Slow lane: bucket routing is plan-agnostic
    (`_bucket_gmask` just permutes rows) and the homogeneous bucket test
    stays tier-1; the 2-device subprocess also re-covers hetero buckets
    (nightly hetero-equivalence job)."""
    small = _make_setup((12, 36, 20))
    seq = _run(small, "fedavg", "sequential", HETERO_MIXED[1:],
               plan="nested", capacity_tiers=TIERS, adam_eps=HETERO_EPS)
    vm = _run(small, "fedavg", "vmap", HETERO_MIXED[1:],
              plan="nested", capacity_tiers=TIERS, adam_eps=HETERO_EPS)
    _assert_equivalent(seq, vm)


def test_hetero_plan_async_degenerate_matches_sync(setup):
    """Degenerate async runtime under a heterogeneous plan: the per-(client,
    group) buffered merge must reproduce the sync per-group aggregation."""
    sync = _run(setup, "fedavg", "vmap", HETERO_MIXED,
                plan="nested", capacity_tiers=TIERS, adam_eps=HETERO_EPS)
    asy = _run(setup, "fedavg", "vmap", HETERO_MIXED,
               plan="nested", capacity_tiers=TIERS, runtime="async",
               adam_eps=HETERO_EPS)
    _assert_equivalent(sync, asy)


# -- fused Pallas masked-Adam path (fused_adam=True, docs/KERNELS.md) -------
#
# The acceptance bar (ISSUE 6): the fused path — local steps through the
# packed masked-Adam kernel, interpret mode on CPU — matches the *unfused
# sequential oracle* (whose partial rounds are ``partitioned_step``'s pruned
# form) to <=1e-5 under every engine x {homogeneous, nested, random} plans,
# on the module's ragged-step-count cohort.  Baselines are cached per plan:
# the oracle runs once, each fused engine compares against it.

_FUSED_BASELINES: dict = {}


def _fused_baseline(setup, plan):
    if plan not in _FUSED_BASELINES:
        if plan == "homogeneous":
            _FUSED_BASELINES[plan] = _run(setup, "fedavg", "sequential", MIXED)
        else:
            _FUSED_BASELINES[plan] = _run(
                setup, "fedavg", "sequential", HETERO_MIXED,
                plan=plan, capacity_tiers=TIERS, adam_eps=HETERO_EPS)
    return _FUSED_BASELINES[plan]


@pytest.mark.parametrize("engine", ["sequential", "vmap", "shard_map"])
@pytest.mark.parametrize("plan", ["homogeneous", "nested", "random"])
def test_fused_adam_matches_partitioned_oracle(setup, engine, plan):
    """fused_adam=True x every engine x every plan kind == the unfused
    sequential oracle (Eq. 1 masked kernel form vs pruned partitioned form),
    params + losses + cost books."""
    if plan == "homogeneous":
        fz = _run(setup, "fedavg", engine, MIXED, fused_adam=True)
    else:
        fz = _run(setup, "fedavg", engine, HETERO_MIXED, fused_adam=True,
                  plan=plan, capacity_tiers=TIERS, adam_eps=HETERO_EPS)
    _assert_equivalent(_fused_baseline(setup, plan), fz)


def test_fused_async_degenerate_matches_sync(setup):
    """The async runtime inherits the fused path through
    ``run_local_async``: degenerate async == sync, both fused."""
    sync = _run(setup, "fedavg", "vmap", MIXED, fused_adam=True)
    asy = _run(setup, "fedavg", "vmap", MIXED, fused_adam=True,
               runtime="async")
    _assert_equivalent(sync, asy)


@pytest.mark.slow
def test_fused_ragged_small_client_bucket():
    """Fused path through a dedicated batch-width bucket (client 12 < 16):
    bucket routing is step-implementation-agnostic."""
    small = _make_setup((12, 36, 20))
    seq = _run(small, "fedavg", "sequential", MIXED[1:])
    fz = _run(small, "fedavg", "vmap", MIXED[1:], fused_adam=True)
    _assert_equivalent(seq, fz)


def test_fused_rejects_weight_decay(setup):
    """The kernel implements plain Adam; a weight-decay config must be
    refused at engine construction, not silently ignored."""
    from repro.fl import LocalTrainer, make_engine
    from repro.optim.adam import AdamConfig

    adapter, _, _ = setup
    params = adapter.init(jax.random.key(0))
    part = adapter.partition(params)
    trainer = LocalTrainer(adapter=adapter, partition=part,
                           algo=AlgoConfig(), adam=AdamConfig(weight_decay=0.1))
    with pytest.raises(ValueError, match="weight_decay"):
        make_engine("vmap", trainer=trainer, partition=part,
                    algo=AlgoConfig(), fused_adam=True)


def test_homogeneous_plan_is_identical_to_default(setup):
    """plan="homogeneous" (with tiers set, which it ignores) must be the
    pre-plan path exactly — same programs, same numbers, every engine
    (shard_map on the degenerate 1-device mesh; the acceptance bar is all
    three engines)."""
    for engine in ("sequential", "vmap", "shard_map"):
        base = _run(setup, "fedavg", engine, MIXED)
        homog = _run(setup, "fedavg", engine, MIXED,
                     plan="homogeneous", capacity_tiers=TIERS)
        for a, b in zip(jax.tree.leaves(base.params),
                        jax.tree.leaves(homog.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_hetero_plan_deeper_schedule_all_engines(setup):
    """Slow lane (nightly): longer horizon + FedProx across all three
    engines under nested plans — drift stays bounded as rounds accumulate.
    The partial rounds walk the *deep* groups (3, 4, 5), so every one is
    clamped differently per tier (3/4/5 vs tier-0's deepest group 2) and no
    round collapses to the homogeneous path."""
    spec_t = type(MIXED[1])
    rounds = [MIXED[0]] + [spec_t(index=i + 1, phase="partial", cycle=0,
                                  group=g) for i, g in enumerate((3, 4, 5))]
    for algo in ("fedavg", "fedprox"):
        seq = _run(setup, algo, "sequential", rounds,
                   plan="nested", capacity_tiers=TIERS, adam_eps=HETERO_EPS)
        for engine in ("vmap", "shard_map"):
            other = _run(setup, algo, engine, rounds,
                         plan="nested", capacity_tiers=TIERS, adam_eps=HETERO_EPS)
            _assert_equivalent(seq, other)


# -- shard_map engine -------------------------------------------------------


def test_shard_map_matches_sequential_single_device(setup):
    """Degenerate 1-device mesh: the shard_map machinery (client padding,
    on-mesh psum, splice) must already agree in-process before the
    multi-device subprocess test sharpens it."""
    seq = _run(setup, "fedavg", "sequential", MIXED)
    sm = _run(setup, "fedavg", "shard_map", MIXED)
    _assert_equivalent(seq, sm)


def test_shard_map_rejects_oversized_mesh(setup):
    """Asking for more mesh devices than exist must fail with the hint about
    forcing host devices, not a cryptic mesh error."""
    adapter, clients, eval_set = setup
    cfg = FLRunConfig(local_epochs=1, batch_size=BATCH, engine="shard_map",
                      sim_devices=len(jax.devices()) + 1)
    with pytest.raises(ValueError, match="host"):
        run_federated(adapter, clients, eval_set, FNUSchedule(1).rounds(), cfg)


# The multi-device run needs XLA_FLAGS before the first jax import, so it
# lives in a subprocess (the pattern test_moe_ep.py established).  2 forced
# host devices, ragged clients (3 -> padded to 4, two per device), plus a
# small-client two-bucket case; sequential vs shard_map for all three
# algorithms, vmap riding along on fedavg to pin the three-way equality.
_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys, json
sys.path.insert(0, "src")
import jax
import numpy as np
jax.config.update("jax_compilation_cache_dir", os.path.abspath(".jax_cache"))
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.2)

from repro.core.schedule import FedPartSchedule
from repro.data import (VisionDatasetSpec, balanced_eval_set, build_clients,
                        make_vision_dataset)
from repro.fl import AlgoConfig, FLRunConfig, resnet_task, run_federated

assert len(jax.devices()) == 2, jax.devices()

def make_setup(client_sizes):
    spec = VisionDatasetSpec(num_classes=4, image_size=8)
    X, y = make_vision_dataset(spec, sum(client_sizes), seed=0)
    Xe, ye = make_vision_dataset(spec, 64, seed=9)
    eval_set = balanced_eval_set(Xe, ye, per_class=8)
    bounds = np.cumsum((0,) + tuple(client_sizes))
    parts = [np.arange(bounds[i], bounds[i + 1])
             for i in range(len(client_sizes))]
    return resnet_task("resnet4", num_classes=4), build_clients(X, y, parts), eval_set

def run(setup, algo, engine, rounds, runtime="sync", inflight=1):
    adapter, clients, eval_set = setup
    cfg = FLRunConfig(local_epochs=1, batch_size=16, lr=2e-3, adam_eps=1e-3,
                      algo=AlgoConfig(name=algo), engine=engine, sim_devices=2,
                      runtime=runtime, max_inflight_cohorts=inflight)
    return run_federated(adapter, clients, eval_set, rounds, cfg)

def diffs(a, b):
    pd = max(float(np.max(np.abs(np.asarray(x) - np.asarray(z))))
             for x, z in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)))
    ld = max(abs(x["loss"] - z["loss"]) for x, z in zip(a.history, b.history))
    books = (a.comm_total_bytes == b.comm_total_bytes
             and a.comm_fnu_bytes == b.comm_fnu_bytes
             and a.comp_total_flops == b.comp_total_flops
             and a.comp_fnu_flops == b.comp_fnu_flops)
    return {"param_maxdiff": pd, "loss_maxdiff": ld, "books_equal": books}

MIXED = FedPartSchedule(num_groups=6, warmup_rounds=1, rounds_per_layer=1,
                        cycles=1).rounds()[:2]
results = {}
ragged = make_setup((36, 56, 40))         # one bucket, padded 3 -> 4 clients
for algo in ("fedavg", "fedprox", "moon"):
    seq = run(ragged, algo, "sequential", MIXED)
    shard = run(ragged, algo, "shard_map", MIXED)
    results[algo] = diffs(seq, shard)
    if algo == "fedavg":
        results["fedavg_vmap_vs_shard"] = diffs(
            run(ragged, algo, "vmap", MIXED), shard)
        # degenerate async runtime on a real 2-device mesh: the event-driven
        # path (explicitly pinned at max_inflight_cohorts=1, the merge-driven
        # regime) must reproduce the sync barrier through the sharded backend
        results["fedavg_async_shard"] = diffs(
            run(ragged, algo, "shard_map", MIXED, runtime="async",
                inflight=1), shard)
        # host-parallel dispatch on the same mesh: full participation leaves
        # no idle clients for a second cohort, so inflight=2 must collapse to
        # the same barrier arithmetic -- now with the cohort programs bound
        # to width-1 submeshes of the 2-device mesh
        results["fedavg_async_shard_inflight2"] = diffs(
            run(ragged, algo, "shard_map", MIXED, runtime="async",
                inflight=2), shard)
buckets = make_setup((12, 36, 20))        # two buckets, each padded to 2
results["fedavg_buckets"] = diffs(
    run(buckets, "fedavg", "sequential", MIXED[1:]),
    run(buckets, "fedavg", "shard_map", MIXED[1:]))
print(json.dumps(results))
"""


def _run_subprocess_script(script):
    import json
    import os
    import subprocess
    import sys

    res = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(__file__)), timeout=900,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    return json.loads(res.stdout.strip().splitlines()[-1])


def test_shard_map_matches_sequential_multidevice():
    out = _run_subprocess_script(_SHARD_SCRIPT)
    for case, r in out.items():
        assert r["param_maxdiff"] <= 1e-5, (case, r)
        assert r["loss_maxdiff"] <= 1e-5, (case, r)
        assert r["books_equal"], (case, r)


# Heterogeneous plans on a genuinely sharded 2-device mesh: the per-client
# bitmask crosses device boundaries with its clients (3 clients pad to 4, two
# per device — the padding client's all-zero mask and zero weights must stay
# inert), per-group weight sums psum across the mesh, and the async runtime
# dispatches plan cohorts through the same submesh-bound programs at
# inflight 1 AND 2.  Slow lane: the nightly job runs it via tier1.sh --slow.
_HETERO_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys, json
sys.path.insert(0, "src")
import jax
import numpy as np
jax.config.update("jax_compilation_cache_dir", os.path.abspath(".jax_cache"))
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.2)

from repro.core.schedule import FedPartSchedule
from repro.data import (VisionDatasetSpec, balanced_eval_set, build_clients,
                        make_vision_dataset)
from repro.fl import AlgoConfig, FLRunConfig, resnet_task, run_federated

assert len(jax.devices()) == 2, jax.devices()

def make_setup(client_sizes):
    spec = VisionDatasetSpec(num_classes=4, image_size=8)
    X, y = make_vision_dataset(spec, sum(client_sizes), seed=0)
    Xe, ye = make_vision_dataset(spec, 64, seed=9)
    eval_set = balanced_eval_set(Xe, ye, per_class=8)
    bounds = np.cumsum((0,) + tuple(client_sizes))
    parts = [np.arange(bounds[i], bounds[i + 1])
             for i in range(len(client_sizes))]
    return resnet_task("resnet4", num_classes=4), build_clients(X, y, parts), eval_set

TIERS = (0.34, 0.67, 1.0)

def run(setup, algo, engine, rounds, runtime="sync", inflight=1):
    adapter, clients, eval_set = setup
    cfg = FLRunConfig(local_epochs=1, batch_size=16, lr=2e-3, adam_eps=1e-2,
                      algo=AlgoConfig(name=algo), engine=engine, sim_devices=2,
                      runtime=runtime, max_inflight_cohorts=inflight,
                      plan="nested", capacity_tiers=TIERS)
    return run_federated(adapter, clients, eval_set, rounds, cfg)

def diffs(a, b):
    pd = max(float(np.max(np.abs(np.asarray(x) - np.asarray(z))))
             for x, z in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)))
    ld = max(abs(x["loss"] - z["loss"]) for x, z in zip(a.history, b.history))
    books = (a.comm_total_bytes == b.comm_total_bytes
             and a.comp_total_flops == b.comp_total_flops)
    return {"param_maxdiff": pd, "loss_maxdiff": ld, "books_equal": books}

# warm-up FNU + a *group-4* partial round: group 4 sits outside tier 0's
# nested prefix (3), so both rounds are genuinely heterogeneous (the group-0
# partial of the fast lane's MIXED would collapse to the legacy path)
from repro.core.schedule import RoundSpec
MIXED = [FedPartSchedule(num_groups=6, warmup_rounds=1).rounds()[0],
         RoundSpec(index=1, phase="partial", cycle=0, group=4)]
results = {}
ragged = make_setup((36, 56, 40))         # one bucket, padded 3 -> 4 clients
for algo in ("fedavg", "fedprox"):
    seq = run(ragged, algo, "sequential", MIXED)
    shard = run(ragged, algo, "shard_map", MIXED)
    results[f"{algo}_hetero"] = diffs(seq, shard)
    if algo == "fedavg":
        results["fedavg_hetero_vmap_vs_shard"] = diffs(
            run(ragged, algo, "vmap", MIXED), shard)
        # degenerate async with hetero plans through the sharded backend,
        # merge-driven (inflight=1) and host-parallel (inflight=2: full
        # participation leaves no second cohort, so it must collapse to the
        # same barrier arithmetic on width-1 submesh-bound plan programs)
        results["fedavg_hetero_async_shard"] = diffs(
            run(ragged, algo, "shard_map", MIXED, runtime="async",
                inflight=1), shard)
        results["fedavg_hetero_async_shard_inflight2"] = diffs(
            run(ragged, algo, "shard_map", MIXED, runtime="async",
                inflight=2), shard)
buckets = make_setup((12, 36, 20))        # two buckets, each padded to 2
results["fedavg_hetero_buckets"] = diffs(
    run(buckets, "fedavg", "sequential", MIXED[1:]),
    run(buckets, "fedavg", "shard_map", MIXED[1:]))
print(json.dumps(results))
"""


@pytest.mark.slow
def test_hetero_plan_shard_map_multidevice():
    out = _run_subprocess_script(_HETERO_SHARD_SCRIPT)
    for case, r in out.items():
        assert r["param_maxdiff"] <= 1e-5, (case, r)
        assert r["loss_maxdiff"] <= 1e-5, (case, r)
        assert r["books_equal"], (case, r)


# -- compressed transmitted subtrees (compression=..., docs/COMPRESSION.md) --
#
# The quantize->dequantize transmission step (core.compress) runs in three
# places — the sequential oracle's host loop, the vmap engine's jitted tx
# stage, and *inside* the shard_map device program before the weight-scale
# psum — plus host-side at async update resolution.  All four must agree to
# <=1e-5 on params/losses and exactly on the byte books (the ledger prices
# the encoded wire format).  Error-feedback residuals are keyed by real
# client id (``run_round(client_ids=...)``), so engine equivalence here also
# pins the residual threading.
#
# Tolerance note: quantization amplifies the usual cross-engine float noise
# only when a ~1e-7 pre-quantization difference flips a rounding decision
# (one int8 step = scale/127) or a top-k threshold tie.  At this module's
# scale (lr=2e-3, 2 rounds) the measured cross-engine divergence stays at
# ~1e-7 for almost every element, but a single near-boundary element can
# flip a bin and surface at ~1e-5 — trajectory luck, not an engine bug
# (error feedback repays the flip on the next transmission).  The
# compressed-path tests therefore run at COMPRESS_TOL; every uncompressed
# test keeps the strict 1e-5 bar.

COMPRESS_KINDS = ("int8", "topk")
COMPRESS_TOL = 5e-5


@pytest.mark.parametrize("kind", COMPRESS_KINDS)
def test_compress_vmap_matches_sequential(setup, kind):
    seq = _run(setup, "fedavg", "sequential", MIXED, compression=kind)
    vm = _run(setup, "fedavg", "vmap", MIXED, compression=kind)
    _assert_equivalent(seq, vm, tol=COMPRESS_TOL)


def test_compress_shard_map_matches_sequential(setup):
    """Compressed tx inside the device program (degenerate 1-device mesh);
    the multi-device sharpening lives in the slow 2-device subprocess."""
    seq = _run(setup, "fedavg", "sequential", MIXED, compression="int8")
    sm = _run(setup, "fedavg", "shard_map", MIXED, compression="int8")
    _assert_equivalent(seq, sm, tol=COMPRESS_TOL)


def test_compress_hetero_plan_engines_agree(setup):
    """int8 under nested per-client plans: the traced-bitmask tx variant
    (transmit_tree_plan) must match the oracle's structural selection."""
    seq = _run(setup, "fedavg", "sequential", HETERO_MIXED, compression="int8",
               plan="nested", capacity_tiers=TIERS, adam_eps=HETERO_EPS)
    vm = _run(setup, "fedavg", "vmap", HETERO_MIXED, compression="int8",
              plan="nested", capacity_tiers=TIERS, adam_eps=HETERO_EPS)
    _assert_equivalent(seq, vm, tol=COMPRESS_TOL)


@pytest.mark.slow
def test_compress_hetero_plan_shard_map(setup):
    """Plan + compression through the shard_map plan program (per-group
    eff-weight epilogue on the compressed view).  Slow lane: the vmap test
    above pins the same transmit_tree_plan arithmetic in tier-1 (nightly
    compress-equivalence job)."""
    seq = _run(setup, "fedavg", "sequential", HETERO_MIXED, compression="int8",
               plan="nested", capacity_tiers=TIERS, adam_eps=HETERO_EPS)
    sm = _run(setup, "fedavg", "shard_map", HETERO_MIXED, compression="int8",
              plan="nested", capacity_tiers=TIERS, adam_eps=HETERO_EPS)
    _assert_equivalent(seq, sm, tol=COMPRESS_TOL)


@pytest.mark.slow
def test_compress_random_plan_and_topk_shard_map(setup):
    """Random plan kind + top-k through all three engines (nightly): the
    sparsification threshold is the tie-sensitive case, so it gets the
    broader sweep in the slow lane."""
    for engine in ("vmap", "shard_map"):
        seq = _run(setup, "fedavg", "sequential", HETERO_MIXED,
                   compression="topk", plan="random", capacity_tiers=TIERS,
                   adam_eps=HETERO_EPS)
        other = _run(setup, "fedavg", engine, HETERO_MIXED,
                     compression="topk", plan="random", capacity_tiers=TIERS,
                     adam_eps=HETERO_EPS)
        _assert_equivalent(seq, other, tol=COMPRESS_TOL)


@pytest.mark.slow
def test_compress_ragged_buckets():
    """A client below the batch size (12 < 16) routes through its own
    batch-width bucket with its EF residual riding along — residual stacking
    is bucket-local but keyed by real client id."""
    small = _make_setup((12, 36, 20))
    seq = _run(small, "fedavg", "sequential", MIXED[1:], compression="int8")
    vm = _run(small, "fedavg", "vmap", MIXED[1:], compression="int8")
    _assert_equivalent(seq, vm, tol=COMPRESS_TOL)


def test_compress_async_degenerate_matches_sync(setup):
    """Degenerate async == sync under int8: the runtime's host-side
    compression at update resolution (against the dispatch-version model,
    with its own residual store) must reproduce the sync engines' in-round
    tx step, and the encoded byte books must match."""
    sync = _run(setup, "fedavg", "vmap", MIXED, compression="int8")
    asy = _run(setup, "fedavg", "vmap", MIXED, compression="int8",
               runtime="async")
    _assert_equivalent(sync, asy)


def test_compress_none_is_identical_to_default(setup):
    """compression="none" must be structurally absent: bit-for-bit equal to
    the pre-compression path on every engine, with no residual state ever
    allocated and no client-id requirement."""
    from repro.fl import LocalTrainer, make_engine
    from repro.optim.adam import AdamConfig

    for engine in ("sequential", "vmap", "shard_map"):
        base = _run(setup, "fedavg", engine, MIXED)
        none = _run(setup, "fedavg", engine, MIXED, compression="none")
        for a, b in zip(jax.tree.leaves(base.params),
                        jax.tree.leaves(none.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert base.comm_total_bytes == none.comm_total_bytes

    adapter, clients, _ = setup
    params = adapter.init(jax.random.key(0))
    part = adapter.partition(params)
    eng = make_engine(
        "vmap", trainer=LocalTrainer(adapter=adapter, partition=part,
                                     algo=AlgoConfig(),
                                     adam=AdamConfig(lr=1e-3)),
        partition=part, algo=AlgoConfig())
    assert eng.compression is None and eng._residuals == {}
    eng.run_round(params, MIXED[1], clients, seeds=[1, 2, 3],
                  weights=[1, 1, 1], epochs=1, batch_size=BATCH)
    assert eng._residuals == {}


def test_compress_requires_client_ids(setup):
    """Engines built with compression must refuse an id-less run_round —
    silently keying residuals by cohort position would corrupt error
    feedback under partial participation."""
    from repro.core import compress
    from repro.fl import LocalTrainer, make_engine
    from repro.optim.adam import AdamConfig

    adapter, clients, _ = setup
    params = adapter.init(jax.random.key(0))
    part = adapter.partition(params)
    eng = make_engine(
        "sequential", trainer=LocalTrainer(adapter=adapter, partition=part,
                                           algo=AlgoConfig(),
                                           adam=AdamConfig(lr=1e-3)),
        partition=part, algo=AlgoConfig(),
        compression=compress.make_config("int8"))
    with pytest.raises(ValueError, match="client_ids"):
        eng.run_round(params, MIXED[1], clients, seeds=[1, 2, 3],
                      weights=[1, 1, 1], epochs=1, batch_size=BATCH)


def test_compress_zero_trainer_groups_stay_frozen(setup):
    """Acceptance bar: on a partial round where some groups have no trainer
    (nested tiers on HETERO_MIXED's group-4 round leave groups 0/1/3/5
    untrained), those groups must stay bit-identical to the pre-round global
    even while other groups' error-feedback residuals are active."""
    from repro.core import masking

    adapter, clients, eval_set = setup
    untrained = (0, 1, 3, 5)
    for engine in ("sequential", "vmap", "shard_map"):
        cfg = FLRunConfig(local_epochs=1, batch_size=BATCH, lr=2e-3,
                          adam_eps=HETERO_EPS, engine=engine,
                          plan="nested", capacity_tiers=TIERS,
                          compression="onebit")
        res = run_federated(adapter, clients, eval_set, HETERO_MIXED[1:], cfg)
        init = adapter.init(jax.random.key(cfg.seed))
        frozen = masking.select(init, res.partition, untrained)
        got = masking.select(res.params, res.partition, untrained)
        for (path, a), b in zip(jax.tree_util.tree_flatten_with_path(frozen)[0],
                                jax.tree.leaves(got)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"{engine}: frozen {jax.tree_util.keystr(path)} moved")


# Compressed transmission on a genuinely sharded 2-device mesh: the tx step
# runs inside the device program (before the weight-scale psum), padding
# clients carry zero residuals, and the async runtime compresses host-side
# at resolution against the same dispatch-version model.  Slow lane: the
# nightly compress-equivalence job runs it via tier1.sh --slow.
_COMPRESS_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys, json
sys.path.insert(0, "src")
import jax
import numpy as np
jax.config.update("jax_compilation_cache_dir", os.path.abspath(".jax_cache"))
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.2)

from repro.core.schedule import FedPartSchedule
from repro.data import (VisionDatasetSpec, balanced_eval_set, build_clients,
                        make_vision_dataset)
from repro.fl import AlgoConfig, FLRunConfig, resnet_task, run_federated

assert len(jax.devices()) == 2, jax.devices()

def make_setup(client_sizes):
    spec = VisionDatasetSpec(num_classes=4, image_size=8)
    X, y = make_vision_dataset(spec, sum(client_sizes), seed=0)
    Xe, ye = make_vision_dataset(spec, 64, seed=9)
    eval_set = balanced_eval_set(Xe, ye, per_class=8)
    bounds = np.cumsum((0,) + tuple(client_sizes))
    parts = [np.arange(bounds[i], bounds[i + 1])
             for i in range(len(client_sizes))]
    return resnet_task("resnet4", num_classes=4), build_clients(X, y, parts), eval_set

def run(setup, engine, rounds, compression, runtime="sync"):
    adapter, clients, eval_set = setup
    cfg = FLRunConfig(local_epochs=1, batch_size=16, lr=2e-3, adam_eps=1e-3,
                      algo=AlgoConfig(), engine=engine, sim_devices=2,
                      runtime=runtime, compression=compression)
    return run_federated(adapter, clients, eval_set, rounds, cfg)

def diffs(a, b):
    pd = max(float(np.max(np.abs(np.asarray(x) - np.asarray(z))))
             for x, z in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)))
    ld = max(abs(x["loss"] - z["loss"]) for x, z in zip(a.history, b.history))
    books = a.comm_total_bytes == b.comm_total_bytes
    return {"param_maxdiff": pd, "loss_maxdiff": ld, "books_equal": books}

MIXED = FedPartSchedule(num_groups=6, warmup_rounds=1, rounds_per_layer=1,
                        cycles=1).rounds()[:2]
results = {}
ragged = make_setup((36, 56, 40))         # one bucket, padded 3 -> 4 clients
for kind in ("int8", "topk"):
    seq = run(ragged, "sequential", MIXED, kind)
    shard = run(ragged, "shard_map", MIXED, kind)
    results[kind] = diffs(seq, shard)
# none bitwise: explicit "none" == the default pre-compression config
base = run(ragged, "shard_map", MIXED, "none")
none = run(ragged, "shard_map", MIXED, "none")
r = diffs(base, none)
results["none_bitwise"] = dict(r, books_equal=(r["param_maxdiff"] == 0.0
                                               and r["books_equal"]))
# degenerate async on the sharded backend, int8: host-side resolution
# compression must match the in-program tx of the sync path
results["int8_async"] = diffs(
    run(ragged, "shard_map", MIXED, "int8", runtime="async"),
    run(ragged, "shard_map", MIXED, "int8"))
print(json.dumps(results))
"""


@pytest.mark.slow
def test_compress_shard_map_multidevice():
    out = _run_subprocess_script(_COMPRESS_SHARD_SCRIPT)
    for case, r in out.items():
        tol = 0.0 if case == "none_bitwise" else 1e-5
        assert r["param_maxdiff"] <= tol, (case, r)
        assert r["loss_maxdiff"] <= tol, (case, r)
        assert r["books_equal"], (case, r)
