import numpy as np
import pytest

from repro.data import (TextDatasetSpec, VisionDatasetSpec, balanced_eval_set,
                        build_clients, dirichlet_partition, iid_partition,
                        make_text_dataset, make_vision_dataset)
from repro.data.partitioner import partition_stats


def test_vision_dataset_learnable_structure():
    spec = VisionDatasetSpec(num_classes=4, image_size=16, noise=0.1)
    X, y = make_vision_dataset(spec, 400, seed=0)
    assert X.shape == (400, 16, 16, 3) and y.shape == (400,)
    # class-conditional means must separate (the task is learnable)
    means = np.stack([X[y == c].mean(axis=0).ravel() for c in range(4)])
    d = np.linalg.norm(means[0] - means[1])
    assert d > 1.0


def test_text_dataset_shapes():
    spec = TextDatasetSpec(num_classes=4, vocab_size=64, seq_len=32)
    X, y = make_text_dataset(spec, 100, seed=0)
    assert X.shape == (100, 32) and X.max() < 64 and y.max() < 4


def test_dirichlet_skew_increases_as_alpha_drops():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, 2000).astype(np.int64)

    def skew(alpha):
        parts = dirichlet_partition(labels, 8, alpha, seed=1)
        stats = partition_stats(parts, labels).astype(float)
        probs = stats / np.maximum(stats.sum(1, keepdims=True), 1)
        return float(np.std(probs, axis=0).mean())

    assert skew(0.1) > skew(100.0)


def test_balanced_eval_set():
    spec = VisionDatasetSpec(num_classes=5, image_size=8)
    X, y = make_vision_dataset(spec, 500, seed=0)
    ex, ey = balanced_eval_set(X, y, per_class=10)
    _, counts = np.unique(ey, return_counts=True)
    assert (counts == 10).all()


def test_client_batches_epochs():
    spec = VisionDatasetSpec(num_classes=3, image_size=8)
    X, y = make_vision_dataset(spec, 90, seed=0)
    clients = build_clients(X, y, iid_partition(90, 3, seed=0))
    batches = list(clients[0].batches(batch_size=10, epochs=2, seed=0))
    assert len(batches) == 6       # 30 samples -> 3 batches x 2 epochs
    assert all(b[0].shape == (10, 8, 8, 3) for b in batches)


def test_tiny_client_still_yields():
    spec = VisionDatasetSpec(num_classes=3, image_size=8)
    X, y = make_vision_dataset(spec, 5, seed=0)
    clients = build_clients(X, y, [np.arange(5)])
    batches = list(clients[0].batches(batch_size=32, epochs=1, seed=0))
    assert len(batches) == 1 and batches[0][0].shape[0] == 5
