import numpy as np
import pytest

from repro.data import (TextDatasetSpec, VisionDatasetSpec, balanced_eval_set,
                        build_clients, dirichlet_partition, iid_partition,
                        make_text_dataset, make_vision_dataset)
from repro.data.partitioner import partition_stats


def test_vision_dataset_learnable_structure():
    spec = VisionDatasetSpec(num_classes=4, image_size=16, noise=0.1)
    X, y = make_vision_dataset(spec, 400, seed=0)
    assert X.shape == (400, 16, 16, 3) and y.shape == (400,)
    # class-conditional means must separate (the task is learnable)
    means = np.stack([X[y == c].mean(axis=0).ravel() for c in range(4)])
    d = np.linalg.norm(means[0] - means[1])
    assert d > 1.0


def test_text_dataset_shapes():
    spec = TextDatasetSpec(num_classes=4, vocab_size=64, seq_len=32)
    X, y = make_text_dataset(spec, 100, seed=0)
    assert X.shape == (100, 32) and X.max() < 64 and y.max() < 4


def test_dirichlet_skew_increases_as_alpha_drops():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, 2000).astype(np.int64)

    def skew(alpha):
        parts = dirichlet_partition(labels, 8, alpha, seed=1)
        stats = partition_stats(parts, labels).astype(float)
        probs = stats / np.maximum(stats.sum(1, keepdims=True), 1)
        return float(np.std(probs, axis=0).mean())

    assert skew(0.1) > skew(100.0)


def test_balanced_eval_set():
    spec = VisionDatasetSpec(num_classes=5, image_size=8)
    X, y = make_vision_dataset(spec, 500, seed=0)
    ex, ey = balanced_eval_set(X, y, per_class=10)
    _, counts = np.unique(ey, return_counts=True)
    assert (counts == 10).all()


def test_client_batches_epochs():
    spec = VisionDatasetSpec(num_classes=3, image_size=8)
    X, y = make_vision_dataset(spec, 90, seed=0)
    clients = build_clients(X, y, iid_partition(90, 3, seed=0))
    batches = list(clients[0].batches(batch_size=10, epochs=2, seed=0))
    assert len(batches) == 6       # 30 samples -> 3 batches x 2 epochs
    assert all(b[0].shape == (10, 8, 8, 3) for b in batches)


def test_tiny_client_still_yields():
    spec = VisionDatasetSpec(num_classes=3, image_size=8)
    X, y = make_vision_dataset(spec, 5, seed=0)
    clients = build_clients(X, y, [np.arange(5)])
    batches = list(clients[0].batches(batch_size=32, epochs=1, seed=0))
    assert len(batches) == 1 and batches[0][0].shape[0] == 5


def test_batch_plan_matches_batches_iterator():
    """ClientDataset.batches and batch_plan are the same contract — the
    sequential and vmap engines must consume identical batch orders."""
    from repro.data import ClientDataset, batch_plan

    rng = np.random.default_rng(0)
    ds = ClientDataset(rng.normal(size=(37, 4)), rng.integers(0, 3, 37))
    plan = batch_plan(len(ds), batch_size=8, epochs=2, seed=11)
    got = list(ds.batches(batch_size=8, epochs=2, seed=11))
    assert len(got) == len(plan)
    for (x, y), idx in zip(got, plan):
        np.testing.assert_array_equal(x, ds.inputs[idx])
        np.testing.assert_array_equal(y, ds.labels[idx])


def test_stack_client_batches_pads_and_masks_ragged_steps():
    from repro.data import ClientDataset, stack_client_batches

    rng = np.random.default_rng(1)
    sizes = [24, 40]                      # 3 vs 5 steps/epoch at bs=8
    dss = [ClientDataset(rng.normal(size=(n, 4)), rng.integers(0, 3, n))
           for n in sizes]
    (bucket,) = stack_client_batches(dss, batch_size=8, epochs=1, seeds=[5, 6])
    assert bucket.num_clients == 2
    assert bucket.batch_width == 8
    assert bucket.num_steps == 5
    np.testing.assert_array_equal(bucket.step_valid, [[1, 1, 1, 0, 0],
                                                      [1, 1, 1, 1, 1]])
    # valid steps carry exactly the sequential iterator's batches
    for ci, ds in enumerate(dss):
        for si, (x, y) in enumerate(ds.batches(8, 1, [5, 6][ci])):
            np.testing.assert_array_equal(bucket.inputs[ci, si], x)
            np.testing.assert_array_equal(bucket.labels[ci, si], y)


def test_stack_client_batches_buckets_small_clients():
    from repro.data import ClientDataset, stack_client_batches

    rng = np.random.default_rng(2)
    sizes = [5, 16, 24]                   # 5 < bs -> own bucket with bs=5
    dss = [ClientDataset(rng.normal(size=(n, 4)), rng.integers(0, 3, n))
           for n in sizes]
    buckets = stack_client_batches(dss, batch_size=8, epochs=2, seeds=[1, 2, 3])
    assert [b.batch_width for b in buckets] == [5, 8]
    assert buckets[0].members == (0,)
    assert buckets[1].members == (1, 2)
    # every bucket row replays the sequential iterator exactly
    for b in buckets:
        for row, pos in enumerate(b.members):
            seq = list(dss[pos].batches(8, 2, [1, 2, 3][pos]))
            for si, (x, y) in enumerate(seq):
                np.testing.assert_array_equal(b.inputs[row, si], x)
                np.testing.assert_array_equal(b.labels[row, si], y)
            assert b.step_valid[row].sum() == len(seq)


def test_stack_client_batches_seed_count_mismatch():
    from repro.data import ClientDataset, stack_client_batches

    ds = ClientDataset(np.zeros((8, 2)), np.zeros(8, dtype=np.int64))
    with pytest.raises(ValueError, match="seed"):
        stack_client_batches([ds], batch_size=4, epochs=1, seeds=[1, 2])


def test_stack_client_batches_pads_clients_to_mesh_multiple():
    from repro.data import ClientDataset, stack_client_batches

    rng = np.random.default_rng(3)
    sizes = [5, 16, 24, 32]               # buckets: bs=5 (1 client), bs=8 (3)
    dss = [ClientDataset(rng.normal(size=(n, 4)), rng.integers(0, 3, n))
           for n in sizes]
    buckets = stack_client_batches(dss, batch_size=8, epochs=1,
                                   seeds=[1, 2, 3, 4], pad_clients_to=4)
    # every bucket's client axis is a multiple of 4; members stay real-only
    assert [b.num_clients for b in buckets] == [4, 4]
    assert [b.num_real for b in buckets] == [1, 3]
    assert buckets[0].members == (0,)
    assert buckets[1].members == (1, 2, 3)
    for b in buckets:
        # padding clients copy the first member's data with all steps invalid
        for row in range(b.num_real, b.num_clients):
            np.testing.assert_array_equal(b.inputs[row], b.inputs[0])
            assert b.step_valid[row].sum() == 0
        # real rows are untouched by padding
        for row, pos in enumerate(b.members):
            seq = list(dss[pos].batches(8, 1, [1, 2, 3, 4][pos]))
            assert b.step_valid[row].sum() == len(seq)

    with pytest.raises(ValueError, match="pad_clients_to"):
        stack_client_batches(dss, batch_size=8, epochs=1,
                             seeds=[1, 2, 3, 4], pad_clients_to=0)
