"""Serving correctness: prefill + one-token decode must reproduce the
full-sequence forward logits at the next position — for every cache kind
(GQA KV, MLA latent, SSM state, hybrid, enc-dec)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.models.api import InputShape

ARCHS = [
    "tinyllama-1.1b",       # GQA KV cache
    "gemma-2b",             # MQA + GeGLU
    # cache kinds below compile slowly on CPU -> slow lane
    pytest.param("deepseek-v3-671b", marks=pytest.mark.slow),   # MLA latent cache + MoE
    pytest.param("xlstm-125m", marks=pytest.mark.slow),         # mLSTM/sLSTM state
    pytest.param("zamba2-7b", marks=pytest.mark.slow),          # mamba2 state + shared attn cache
    pytest.param("whisper-small", marks=pytest.mark.slow),      # enc-dec self+cross cache
    pytest.param("llama4-maverick-400b-a17b", marks=pytest.mark.slow),  # MoE top-1
]

S = 12


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_then_decode_matches_forward(name):
    cfg = get_config(name, smoke=True)
    params = api.init(jax.random.key(0), cfg)
    shape = InputShape("p", S, 2, "prefill")
    batch = api.synth_batch(jax.random.key(1), cfg, shape)

    # Full forward over S tokens -> cache; reference forward over S+1 tokens.
    logits_s, cache, _ = api.forward(params, cfg, batch, collect_cache=True)
    next_tok = jnp.argmax(logits_s[:, -1, :], axis=-1)[:, None].astype(jnp.int32)

    ref_batch = dict(batch)
    ref_batch["tokens"] = jnp.concatenate([batch["tokens"], next_tok], axis=1)
    ref_logits, _, _ = api.forward(params, cfg, ref_batch)
    want = ref_logits[:, -1, :]

    # Grow attention caches by 1 slot and decode the next token.
    def grow(path, leaf):
        keyname = str(getattr(path[-1], "key", path[-1]))
        if keyname in ("k", "v", "c_kv", "k_rope", "self_k", "self_v") and (
            leaf.ndim >= 4 and leaf.shape[2] == batch["tokens"].shape[1] + cfg.num_media_tokens
        ):
            pad = [(0, 0)] * leaf.ndim
            pad[2] = (0, 1)
            return jnp.pad(leaf, pad)
        return leaf

    cache = jax.tree_util.tree_map_with_path(grow, cache)
    pos = jnp.int32(batch["tokens"].shape[1] + cfg.num_media_tokens)
    got_logits, _ = api.decode_step(params, cfg, next_tok, cache, pos)
    got = got_logits[:, 0, :]

    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4,
        err_msg=f"{name}: decode logits diverge from forward",
    )
