import pytest

from repro.launch.hlo_analysis import (collective_bytes, model_flops_6nd,
                                       roofline)

SAMPLE_HLO = """
  %ar = bf16[1024,64]{1,0} all-reduce(%x), replica_groups={}, to_apply=%sum
  %ag.1 = f32[2048]{0} all-gather(%y), dimensions={0}
  %a2a = (bf16[16,8]{1,0}, bf16[16,8]{1,0}) all-to-all(%p, %q)
  %cp = u32[4]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %ar2 = bf16[8]{0} all-reduce-start(%w)
  %ar2d = bf16[8]{0} all-reduce-done(%ar2)
  %notacoll = bf16[999]{0} add(%a, %b)
"""


def test_collective_parser():
    out = collective_bytes(SAMPLE_HLO)
    assert out["per_kind_bytes"]["all-reduce"] == 1024 * 64 * 2 + 8 * 2
    assert out["per_kind_bytes"]["all-gather"] == 2048 * 4
    assert out["per_kind_bytes"]["all-to-all"] == 16 * 8 * 2 * 2
    assert out["per_kind_bytes"]["collective-permute"] == 4 * 4
    assert out["per_kind_count"]["all-reduce"] == 2   # start counted, done not


def test_roofline_terms():
    t = roofline(flops=197e12, hbm_bytes=819e9, coll_bytes=50e9,
                 residency_bytes=819e9 / 4)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s_hlo == pytest.approx(1.0)
    assert t.memory_s_min == pytest.approx(0.5)
    assert t.collective_s == pytest.approx(1.0)
    assert t.dominant in ("compute", "collective")


def test_model_flops():
    assert model_flops_6nd(1e9, 1e6) == 6e15
