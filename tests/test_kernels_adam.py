"""Fused masked-Adam Pallas kernel vs. oracle + pytree wrapper semantics.

Also pins the pack/unpack dtype-fidelity contract (ISSUE 6): per-leaf dtypes
recorded in ``PackMeta`` and restored by ``unpack``, mixed-dtype / 0-dim /
empty-leaf round trips (hypothesis when available, seeded cases always), the
``tree_flatten_with_path`` == ``jax.tree.flatten`` layout-order assertion the
mask builders rely on, the client-stacked pack variants, and the three-way
``fused_masked_step == masked_step == partitioned_step`` equivalence at
mixed-group block boundaries.
"""

import typing
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import masking
from repro.core.partition import build_partition
from repro.kernels.masked_adam import ops
from repro.kernels.masked_adam.kernel import (masked_adam_kernel,
                                              masked_adam_stacked)
from repro.kernels.masked_adam.ref import masked_adam_ref
from repro.optim.adam import AdamConfig, adam_init, adam_update
from repro.optim.partial import (fused_adam_init, fused_masked_step,
                                 masked_step, partitioned_step)
from tests.conftest import small_params
from tests.test_partial_equivalence import _loss_fn

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAS_HYPOTHESIS = False


@pytest.mark.parametrize("rows,br", [(32, 8), (64, 16), (128, 8)])
@pytest.mark.parametrize("step", [1, 10])
def test_kernel_matches_ref(rows, br, step):
    ks = jax.random.split(jax.random.key(rows + step), 4)
    p = jax.random.normal(ks[0], (rows, 128), jnp.float32)
    g = jax.random.normal(ks[1], (rows, 128), jnp.float32)
    m = jax.random.normal(ks[2], (rows, 128), jnp.float32) * 0.1
    v = jnp.abs(jax.random.normal(ks[3], (rows, 128))) * 0.01
    nb = rows // br
    mask = jnp.asarray(np.random.default_rng(0).integers(0, 2, nb), jnp.int32)
    sc = jnp.array([1e-3, 1 - 0.9**step, 1 - 0.999**step, 1e-8], jnp.float32)
    out_k = masked_adam_kernel(p, g, m, v, mask, sc, block_rows=br, interpret=True)
    out_r = masked_adam_ref(p, g, m, v, mask, sc, block_rows=br)
    for a, b, name in zip(out_k, out_r, "pmv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   err_msg=name)


def test_pack_unpack_roundtrip():
    params = small_params()
    packed, meta = ops.pack(params)
    restored = ops.unpack(packed, meta)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b), atol=0)


def test_mixed_group_boundary_within_one_packed_tensor():
    """Two *adjacent* blocks of one packed tensor carrying different mask
    bits — the mixed-group tensor boundary the kernel docstring promises
    (per-client layer plans make such boundaries routine): the trained block
    must equal plain Adam, its frozen neighbour must copy through bit-exact,
    with no bleed across the block edge.  Interpret mode, kernel == ref."""
    br = 8
    ks = jax.random.split(jax.random.key(42), 4)
    # one logical tensor spanning 4 blocks; blocks 1 and 2 are adjacent with
    # different bits (0|1), as are 2 and 3 (1|0)
    rows = 4 * br
    p = jax.random.normal(ks[0], (rows, 128), jnp.float32)
    g = jax.random.normal(ks[1], (rows, 128), jnp.float32)
    m = jax.random.normal(ks[2], (rows, 128), jnp.float32) * 0.1
    v = jnp.abs(jax.random.normal(ks[3], (rows, 128))) * 0.01
    mask = jnp.asarray([0, 1, 0, 1], jnp.int32)
    sc = jnp.array([1e-3, 1 - 0.9**3, 1 - 0.999**3, 1e-8], jnp.float32)

    out_k = masked_adam_kernel(p, g, m, v, mask, sc, block_rows=br,
                               interpret=True)
    out_r = masked_adam_ref(p, g, m, v, mask, sc, block_rows=br)
    for a, b, name in zip(out_k, out_r, "pmv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   err_msg=name)
    # frozen blocks copy through bit-exact; trained blocks move
    newp = np.asarray(out_k[0])
    orig = np.asarray(p)
    for b_idx, bit in enumerate(mask.tolist()):
        blk = slice(b_idx * br, (b_idx + 1) * br)
        if bit:
            assert np.abs(newp[blk] - orig[blk]).max() > 0
        else:
            np.testing.assert_array_equal(newp[blk], orig[blk])


def test_fused_mixed_group_blocks_in_one_leaf_pin_wrapper_vs_ref():
    """ops-level pin of the same boundary: a hand-built block mask that
    flips mid-leaf must behave exactly like running unfused Adam on the
    masked rows only — the wrapper's pack/unpack cannot smear the boundary."""
    leaf = jax.random.normal(jax.random.key(7), (16, 128), jnp.float32)
    params = {"w": leaf}
    grads = {"w": jnp.full_like(leaf, 0.02)}
    zeros = {"w": jnp.zeros_like(leaf)}
    # (16, 128) rows with block_rows=8 -> 2 blocks of one tensor: train the
    # first, freeze the second
    bm = np.asarray([1, 0], np.int32)
    newp, _, _ = ops.fused_masked_adam(
        params, grads, zeros, {"w": jnp.zeros_like(leaf)}, jnp.int32(1), bm,
        lr=1e-3, block_rows=8)
    ref_p, _ = adam_update(grads, adam_init(params), params,
                           AdamConfig(lr=1e-3))
    np.testing.assert_allclose(np.asarray(newp["w"][:8]),
                               np.asarray(ref_p["w"][:8]), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(newp["w"][8:]),
                                  np.asarray(leaf[8:]))


def test_fused_matches_unfused_adam_on_selected_group():
    """On the trainable group the fused kernel must equal plain Adam; frozen
    groups must be untouched."""
    params = small_params()
    part = build_partition(params)
    grads = jax.tree.map(lambda x: jnp.ones_like(x) * 0.01, params)
    zeros = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
    bm = ops.block_mask_for_group(params, part, 2)
    newp, newm, newv = ops.fused_masked_adam(
        params, grads, zeros, jax.tree.map(jnp.copy, zeros), jnp.int32(1), bm,
        lr=1e-3,
    )
    ref_p, _ = adam_update(grads, adam_init(params), params, AdamConfig(lr=1e-3))
    for (path, a), (_, want), (_, orig) in zip(
        jax.tree_util.tree_flatten_with_path(newp)[0],
        jax.tree_util.tree_flatten_with_path(ref_p)[0],
        jax.tree_util.tree_flatten_with_path(params)[0],
    ):
        ps = "/".join(str(getattr(k, "key", k)) for k in path)
        if part.group_of(ps) == 2:
            np.testing.assert_allclose(np.asarray(a), np.asarray(want), atol=1e-6)
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(orig))


# ---------------------------------------------------------------------------
# pack/unpack dtype fidelity (per-leaf dtypes recorded and restored)
# ---------------------------------------------------------------------------

_SHAPES = [(), (0,), (1,), (5,), (3, 4), (2, 3, 2), (130,)]
_DTYPES = [jnp.float32, jnp.bfloat16, jnp.float16, jnp.int32]


def _make_tree(specs, seed):
    """Dict tree from (shape, dtype) specs; values exactly representable in
    every listed dtype's f32 round trip (small ints, normals cast down)."""
    rng = np.random.default_rng(seed)
    tree = {}
    for i, (shape, dt) in enumerate(specs):
        if dt == jnp.int32:
            arr = rng.integers(-99, 100, size=shape).astype(np.int32)
        else:
            arr = rng.normal(size=shape).astype(np.float32)
        tree[f"leaf{i:02d}"] = jnp.asarray(arr).astype(dt)
    return tree


def _assert_roundtrip(tree, block_rows=8):
    packed, meta = ops.pack(tree, block_rows)
    assert packed.dtype == jnp.float32          # kernel compute dtype
    restored = ops.unpack(packed, meta)
    for (pa, a), (_, b) in zip(
        jax.tree_util.tree_flatten_with_path(tree)[0],
        jax.tree_util.tree_flatten_with_path(restored)[0],
    ):
        assert b.dtype == a.dtype, f"{pa}: {a.dtype} -> {b.dtype}"
        assert b.shape == a.shape, pa
        np.testing.assert_array_equal(
            np.asarray(a.astype(jnp.float32)),
            np.asarray(b.astype(jnp.float32)), err_msg=str(pa))


@pytest.mark.parametrize("block_rows", [8, 16])
def test_pack_unpack_mixed_dtype_roundtrip_exact(block_rows):
    """The ISSUE 6 bugfix pin: bf16/f16/int32 leaves come back in their own
    dtype (not leaves[0]'s), including 0-dim scalars and empty leaves."""
    specs = list(zip(_SHAPES, [jnp.float32, jnp.bfloat16, jnp.float16,
                               jnp.int32, jnp.bfloat16, jnp.float16,
                               jnp.int32]))
    _assert_roundtrip(_make_tree(specs, seed=0), block_rows)


def test_unpack_global_dtype_override_warns():
    """``unpack(dtype=...)`` still works (casts every leaf) but is
    deprecated now that per-leaf dtypes round-trip by default."""
    tree = _make_tree([((3, 4), jnp.bfloat16), ((5,), jnp.float32)], seed=1)
    packed, meta = ops.pack(tree)
    with pytest.deprecated_call():
        forced = ops.unpack(packed, meta, dtype=jnp.float32)
    assert all(leaf.dtype == jnp.float32 for leaf in jax.tree.leaves(forced))
    # and the default path emits no warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        restored = ops.unpack(packed, meta)
    assert [leaf.dtype for leaf in jax.tree.leaves(restored)] == \
        [leaf.dtype for leaf in jax.tree.leaves(tree)]


if HAS_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(
        specs=st.lists(
            st.tuples(st.sampled_from(_SHAPES), st.sampled_from(_DTYPES)),
            min_size=1, max_size=6),
        seed=st.integers(0, 2**31 - 1),
        block_rows=st.sampled_from([8, 16]),
    )
    def test_pack_unpack_roundtrip_property(specs, seed, block_rows):
        _assert_roundtrip(_make_tree(specs, seed), block_rows)

else:  # seeded fallback so the property is still exercised without hypothesis

    @pytest.mark.parametrize("seed", range(10))
    def test_pack_unpack_roundtrip_property(seed):
        rng = np.random.default_rng(seed)
        specs = [
            (_SHAPES[int(rng.integers(len(_SHAPES)))],
             _DTYPES[int(rng.integers(len(_DTYPES)))])
            for _ in range(int(rng.integers(1, 7)))
        ]
        _assert_roundtrip(_make_tree(specs, seed), int(rng.choice([8, 16])))


# ---------------------------------------------------------------------------
# layout-order contract: tree_flatten_with_path == jax.tree.flatten
# ---------------------------------------------------------------------------

class _NTBlock(typing.NamedTuple):
    kernel: jax.Array
    bias: jax.Array


def test_layout_order_holds_for_dict_and_namedtuple_trees():
    tree = {
        "z": _NTBlock(kernel=jnp.ones((4, 4)), bias=jnp.zeros((4,))),
        "a": {"w": jnp.ones((2, 3)), "s": jnp.float32(1.0)},
    }
    packed, meta = ops.pack(tree)          # pack runs the assertion itself
    _assert_roundtrip(tree)
    # leaf spans in the packed buffer follow flatten order exactly
    leaves = jax.tree.leaves(tree)
    flat = np.asarray(packed).reshape(-1)
    off = 0
    for leaf, n, pn in zip(leaves, meta.sizes, meta.padded):
        np.testing.assert_array_equal(
            flat[off : off + n],
            np.asarray(leaf, np.float32).reshape(-1))
        off += pn


def test_layout_order_assertion_rejects_reordered_leaves():
    tree = {"a": jnp.ones((2,)), "b": jnp.zeros((3,))}
    leaves = jax.tree.leaves(tree)
    ops._assert_layout_order(tree, leaves)                 # agrees: fine
    with pytest.raises(AssertionError, match="different order"):
        ops._assert_layout_order(tree, leaves[::-1])       # misaligned


# ---------------------------------------------------------------------------
# client-stacked pack variants (batched-engine layout)
# ---------------------------------------------------------------------------

def test_pack_stacked_roundtrip_and_per_client_layout():
    C = 3
    rng = np.random.default_rng(11)
    tree = {
        "w": jnp.asarray(rng.normal(size=(C, 4, 5)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(C, 130)).astype(np.float32)
                         ).astype(jnp.bfloat16),
        "s": jnp.asarray(rng.normal(size=(C,)).astype(np.float32)),
    }
    packed, meta = ops.pack_stacked(tree)
    assert packed.shape[0] == C and packed.shape[2] == 128
    restored = ops.unpack_stacked(packed, meta)
    for (pa, a), (_, b) in zip(
        jax.tree_util.tree_flatten_with_path(tree)[0],
        jax.tree_util.tree_flatten_with_path(restored)[0],
    ):
        assert b.dtype == a.dtype and b.shape == a.shape, pa
        np.testing.assert_array_equal(
            np.asarray(a.astype(jnp.float32)),
            np.asarray(b.astype(jnp.float32)), err_msg=str(pa))
    # each client's slab equals the single-tree pack of that client's slice
    for c in range(C):
        one = jax.tree.map(lambda x: x[c], tree)
        pc, mc = ops.pack(one)
        np.testing.assert_array_equal(np.asarray(packed[c]), np.asarray(pc))
        assert mc.padded == meta.padded


def test_pack_stacked_rejects_empty_and_ragged_trees():
    with pytest.raises(ValueError, match="at least one leaf"):
        ops.pack_stacked({})
    with pytest.raises(ValueError, match="client axis"):
        ops.pack_stacked({"a": jnp.ones((3, 2)), "b": jnp.ones((4, 2))})


# ---------------------------------------------------------------------------
# plan bitmask -> per-client block masks
# ---------------------------------------------------------------------------

def test_block_masks_for_plan_matches_per_group_masks():
    params = small_params()
    part = build_partition(params)
    plan = np.zeros((3, part.num_groups), np.int32)
    plan[0, :] = 1                       # full-capacity client
    plan[1, [0, 2]] = 1                  # partial subset
    masks = ops.block_masks_for_plan(params, part, plan)
    gids = ops.block_group_ids(params, part)
    assert masks.shape == (3, len(gids))
    for c in range(3):
        sel = {g for g in range(part.num_groups) if plan[c, g]}
        want = ops.block_mask_for_group(params, part, sel)
        np.testing.assert_array_equal(masks[c], want, err_msg=f"client {c}")
        # traced builder (what the engines run under vmap) agrees too
        traced = ops.plan_block_mask(gids, jnp.asarray(plan[c]))
        np.testing.assert_array_equal(np.asarray(traced), want,
                                      err_msg=f"client {c} traced")
    assert not masks[2].any()            # all-zero plan row -> nothing trains


def test_masked_adam_stacked_matches_per_client_kernel_calls():
    C, rows, br = 3, 32, 8
    ks = jax.random.split(jax.random.key(5), 4)
    p = jax.random.normal(ks[0], (C, rows, 128), jnp.float32)
    g = jax.random.normal(ks[1], (C, rows, 128), jnp.float32)
    m = jax.random.normal(ks[2], (C, rows, 128), jnp.float32) * 0.1
    v = jnp.abs(jax.random.normal(ks[3], (C, rows, 128))) * 0.01
    masks = jnp.asarray(
        np.random.default_rng(3).integers(0, 2, (C, rows // br)), jnp.int32)
    sc = jnp.array([1e-3, 1 - 0.9**2, 1 - 0.999**2, 1e-8], jnp.float32)
    outs = masked_adam_stacked(p, g, m, v, masks, sc, block_rows=br,
                               interpret=True)
    for c in range(C):
        ref = masked_adam_kernel(p[c], g[c], m[c], v[c], masks[c], sc,
                                 block_rows=br, interpret=True)
        for a, b, name in zip(outs, ref, "pmv"):
            np.testing.assert_allclose(
                np.asarray(a[c]), np.asarray(b), atol=1e-6,
                err_msg=f"client {c} {name}")


# ---------------------------------------------------------------------------
# three-way equivalence: fused == masked == partitioned (Eq. 1, DESIGN.md §6)
# ---------------------------------------------------------------------------

def _assert_trees_close(got, want, **tol):
    tol.setdefault("rtol", 2e-5)
    tol.setdefault("atol", 2e-6)
    for (pa, a), (_, b) in zip(
        jax.tree_util.tree_flatten_with_path(got)[0],
        jax.tree_util.tree_flatten_with_path(want)[0],
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   err_msg=f"{pa} differs", **tol)


@pytest.mark.parametrize("groups", [
    2,
    pytest.param(0, marks=pytest.mark.slow),
    (0, 2),        # multi-group: block boundaries between trained/frozen
])
def test_three_way_fused_masked_partitioned(groups):
    """The three realisations of the paper's Eq. 1 — full-grad masked update,
    pruned-subtree update, and the fused packed-kernel update — must agree on
    real transformer leaves where trained and frozen groups share packed-block
    neighbourhoods."""
    params = small_params()
    part = build_partition(params)
    x = jax.random.randint(jax.random.key(1), (4, 6), 0, 32)
    y = jax.random.randint(jax.random.key(2), (4,), 0, 8)
    loss_fn = _loss_fn((x, y))
    cfg = AdamConfig(lr=1e-2)
    gsel = groups if isinstance(groups, int) else set(groups)

    mask = masking.mask_tree(params, part, gsel)
    p_masked, _, loss_m = masked_step(loss_fn, params, adam_init(params),
                                      mask, cfg)
    p_fused, st_fused, loss_f = fused_masked_step(
        loss_fn, params, fused_adam_init(params), part, gsel, cfg)
    assert np.allclose(float(loss_m), float(loss_f), rtol=1e-6)
    assert int(st_fused.step) == 1
    _assert_trees_close(p_fused, p_masked)

    if isinstance(groups, int):
        p_part, _, loss_p = partitioned_step(loss_fn, params, part, groups,
                                             None, cfg)
        assert np.allclose(float(loss_f), float(loss_p), rtol=1e-6)
        _assert_trees_close(p_fused, p_part)

    # frozen groups copy through bit-exact in the fused path
    sel = {gsel} if isinstance(gsel, int) else gsel
    for (path, a), (_, orig) in zip(
        jax.tree_util.tree_flatten_with_path(p_fused)[0],
        jax.tree_util.tree_flatten_with_path(params)[0],
    ):
        ps = "/".join(str(getattr(k, "key", k)) for k in path)
        if part.group_of(ps) not in sel:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(orig),
                                          err_msg=ps)


def test_fused_masked_step_rejects_weight_decay():
    params = small_params()
    part = build_partition(params)
    with pytest.raises(ValueError, match="weight_decay"):
        fused_masked_step(lambda p: jnp.float32(0.0), params,
                          fused_adam_init(params), part, 0,
                          AdamConfig(weight_decay=0.1))
