"""Fused masked-Adam Pallas kernel vs. oracle + pytree wrapper semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partition import build_partition
from repro.kernels.masked_adam import ops
from repro.kernels.masked_adam.kernel import masked_adam_kernel
from repro.kernels.masked_adam.ref import masked_adam_ref
from repro.optim.adam import AdamConfig, adam_init, adam_update
from tests.conftest import small_params


@pytest.mark.parametrize("rows,br", [(32, 8), (64, 16), (128, 8)])
@pytest.mark.parametrize("step", [1, 10])
def test_kernel_matches_ref(rows, br, step):
    ks = jax.random.split(jax.random.key(rows + step), 4)
    p = jax.random.normal(ks[0], (rows, 128), jnp.float32)
    g = jax.random.normal(ks[1], (rows, 128), jnp.float32)
    m = jax.random.normal(ks[2], (rows, 128), jnp.float32) * 0.1
    v = jnp.abs(jax.random.normal(ks[3], (rows, 128))) * 0.01
    nb = rows // br
    mask = jnp.asarray(np.random.default_rng(0).integers(0, 2, nb), jnp.int32)
    sc = jnp.array([1e-3, 1 - 0.9**step, 1 - 0.999**step, 1e-8], jnp.float32)
    out_k = masked_adam_kernel(p, g, m, v, mask, sc, block_rows=br, interpret=True)
    out_r = masked_adam_ref(p, g, m, v, mask, sc, block_rows=br)
    for a, b, name in zip(out_k, out_r, "pmv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   err_msg=name)


def test_pack_unpack_roundtrip():
    params = small_params()
    packed, meta = ops.pack(params)
    restored = ops.unpack(packed, meta)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b), atol=0)


def test_mixed_group_boundary_within_one_packed_tensor():
    """Two *adjacent* blocks of one packed tensor carrying different mask
    bits — the mixed-group tensor boundary the kernel docstring promises
    (per-client layer plans make such boundaries routine): the trained block
    must equal plain Adam, its frozen neighbour must copy through bit-exact,
    with no bleed across the block edge.  Interpret mode, kernel == ref."""
    br = 8
    ks = jax.random.split(jax.random.key(42), 4)
    # one logical tensor spanning 4 blocks; blocks 1 and 2 are adjacent with
    # different bits (0|1), as are 2 and 3 (1|0)
    rows = 4 * br
    p = jax.random.normal(ks[0], (rows, 128), jnp.float32)
    g = jax.random.normal(ks[1], (rows, 128), jnp.float32)
    m = jax.random.normal(ks[2], (rows, 128), jnp.float32) * 0.1
    v = jnp.abs(jax.random.normal(ks[3], (rows, 128))) * 0.01
    mask = jnp.asarray([0, 1, 0, 1], jnp.int32)
    sc = jnp.array([1e-3, 1 - 0.9**3, 1 - 0.999**3, 1e-8], jnp.float32)

    out_k = masked_adam_kernel(p, g, m, v, mask, sc, block_rows=br,
                               interpret=True)
    out_r = masked_adam_ref(p, g, m, v, mask, sc, block_rows=br)
    for a, b, name in zip(out_k, out_r, "pmv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   err_msg=name)
    # frozen blocks copy through bit-exact; trained blocks move
    newp = np.asarray(out_k[0])
    orig = np.asarray(p)
    for b_idx, bit in enumerate(mask.tolist()):
        blk = slice(b_idx * br, (b_idx + 1) * br)
        if bit:
            assert np.abs(newp[blk] - orig[blk]).max() > 0
        else:
            np.testing.assert_array_equal(newp[blk], orig[blk])


def test_fused_mixed_group_blocks_in_one_leaf_pin_wrapper_vs_ref():
    """ops-level pin of the same boundary: a hand-built block mask that
    flips mid-leaf must behave exactly like running unfused Adam on the
    masked rows only — the wrapper's pack/unpack cannot smear the boundary."""
    leaf = jax.random.normal(jax.random.key(7), (16, 128), jnp.float32)
    params = {"w": leaf}
    grads = {"w": jnp.full_like(leaf, 0.02)}
    zeros = {"w": jnp.zeros_like(leaf)}
    # (16, 128) rows with block_rows=8 -> 2 blocks of one tensor: train the
    # first, freeze the second
    bm = np.asarray([1, 0], np.int32)
    newp, _, _ = ops.fused_masked_adam(
        params, grads, zeros, {"w": jnp.zeros_like(leaf)}, jnp.int32(1), bm,
        lr=1e-3, block_rows=8)
    ref_p, _ = adam_update(grads, adam_init(params), params,
                           AdamConfig(lr=1e-3))
    np.testing.assert_allclose(np.asarray(newp["w"][:8]),
                               np.asarray(ref_p["w"][:8]), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(newp["w"][8:]),
                                  np.asarray(leaf[8:]))


def test_fused_matches_unfused_adam_on_selected_group():
    """On the trainable group the fused kernel must equal plain Adam; frozen
    groups must be untouched."""
    params = small_params()
    part = build_partition(params)
    grads = jax.tree.map(lambda x: jnp.ones_like(x) * 0.01, params)
    zeros = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
    bm = ops.block_mask_for_group(params, part, 2)
    newp, newm, newv = ops.fused_masked_adam(
        params, grads, zeros, jax.tree.map(jnp.copy, zeros), jnp.int32(1), bm,
        lr=1e-3,
    )
    ref_p, _ = adam_update(grads, adam_init(params), params, AdamConfig(lr=1e-3))
    for (path, a), (_, want), (_, orig) in zip(
        jax.tree_util.tree_flatten_with_path(newp)[0],
        jax.tree_util.tree_flatten_with_path(ref_p)[0],
        jax.tree_util.tree_flatten_with_path(params)[0],
    ):
        ps = "/".join(str(getattr(k, "key", k)) for k in path)
        if part.group_of(ps) == 2:
            np.testing.assert_allclose(np.asarray(a), np.asarray(want), atol=1e-6)
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(orig))
