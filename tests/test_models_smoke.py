"""Per-architecture smoke tests (deliverable f): every assigned arch, reduced
config, one forward + one train step + one decode step on CPU; asserts output
shapes and finiteness."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import api
from repro.models.api import InputShape
from repro.optim.adam import AdamConfig, adam_init, adam_update

# Archs whose smoke-scale compile alone costs 5-15s on CPU: their train /
# remat / decode variants run in the slow lane (forward stays tier-1).
HEAVY_ARCHS = {"whisper-small", "zamba2-7b", "xlstm-125m",
               "deepseek-v3-671b", "llama4-maverick-400b-a17b"}
MARKED_ARCHS = [
    pytest.param(a, marks=pytest.mark.slow) if a in HEAVY_ARCHS else a
    for a in ASSIGNED_ARCHS
]

TRAIN = InputShape("t", 32, 2, "train")
DECODE = InputShape("d", 64, 2, "decode")


@pytest.fixture(scope="module")
def states():
    return {}


def _setup(name):
    cfg = get_config(name, smoke=True)
    params = api.init(jax.random.key(0), cfg)
    return cfg, params


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_forward_and_loss(name):
    cfg, params = _setup(name)
    batch = api.synth_batch(jax.random.key(1), cfg, TRAIN)
    logits, _, aux = api.forward(params, cfg, batch)
    assert logits.shape == (2, TRAIN.seq_len, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    loss = api.loss(params, cfg, batch)
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("name", MARKED_ARCHS)
def test_train_step_no_nans(name):
    cfg, params = _setup(name)
    batch = api.synth_batch(jax.random.key(1), cfg, TRAIN)
    loss0, grads = jax.value_and_grad(lambda p: api.loss(p, cfg, batch))(params)
    new_params, _ = adam_update(grads, adam_init(params), params, AdamConfig(lr=1e-3))
    loss1 = api.loss(new_params, cfg, batch)
    assert bool(jnp.isfinite(loss1))
    assert float(loss1) < float(loss0)   # one Adam step on the same batch


@pytest.mark.parametrize("name", MARKED_ARCHS)
def test_decode_step(name):
    cfg, params = _setup(name)
    batch = api.synth_batch(jax.random.key(2), cfg, DECODE)
    logits, cache = api.decode_step(
        params, cfg, batch["token"], batch["cache"], batch["pos"]
    )
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(batch["cache"])


@pytest.mark.parametrize("name", MARKED_ARCHS)
def test_remat_and_unroll_agree(name):
    """remat / unroll knobs must not change the math."""
    cfg, params = _setup(name)
    batch = api.synth_batch(jax.random.key(1), cfg, TRAIN)
    l0 = api.loss(params, cfg, batch)
    l1 = api.loss(params, cfg, batch, remat=True, unroll=cfg.num_layers)
    assert float(jnp.abs(l0 - l1)) < 1e-4
