"""Dry-run machinery on a small host mesh, run in a subprocess so the forced
device count never leaks into other tests."""

import json
import os
import subprocess
import sys


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "src")
import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.launch import hlo_analysis, steps
from repro.launch.sharding import input_shardings, params_shardings
from repro.models import api
from repro.models.api import InputShape

cfg = get_config("tinyllama-1.1b", smoke=True)
mesh = jax.make_mesh((4, 2), ("data", "model"))
shape = InputShape("t", 64, 8, "train")
params_shapes = jax.eval_shape(lambda: api.init(jax.random.key(0), cfg))
p_shard = params_shardings(params_shapes, mesh)
specs = api.input_specs(cfg, shape)
b_shard = input_shardings(specs, mesh)
opt_shapes = jax.eval_shape(steps.init_opt_state, params_shapes)
opt_shard = type(opt_shapes)(
    step=NamedSharding(mesh, P()),
    m=params_shardings(opt_shapes.m, mesh),
    v=params_shardings(opt_shapes.v, mesh),
)
step = steps.make_train_step(cfg, remat=True)
with mesh:
    compiled = jax.jit(
        step, in_shardings=(p_shard, opt_shard, b_shard),
        out_shardings=(p_shard, opt_shard, NamedSharding(mesh, P())),
    ).lower(params_shapes, opt_shapes, specs).compile()

mem = hlo_analysis.extract_memory(compiled)
cost = hlo_analysis.extract_cost(compiled)
coll = hlo_analysis.collective_bytes(compiled.as_text())
print(json.dumps({
    "devices": jax.device_count(),
    "temp": mem["temp_size_in_bytes"],
    "flops": cost["flops"],
    "coll_total": coll["total_bytes"],
    "ar_count": coll["per_kind_count"]["all-reduce"],
}))
"""


def test_small_mesh_dryrun_compiles():
    env = dict(os.environ)
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
        timeout=300,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["devices"] == 8
    assert out["flops"] > 0
    assert out["coll_total"] > 0      # data-parallel grads must all-reduce
    assert out["ar_count"] > 0


def test_production_mesh_shapes():
    # mesh construction itself (without devices) is covered by the dryrun
    # artifacts; here we only check the axis bookkeeping helpers.
    from repro.launch.mesh import dp_axes

    class FakeMesh:
        axis_names = ("pod", "data", "model")

    assert dp_axes(FakeMesh()) == ("pod", "data")
