"""Sharding rules: named tensor-parallel specs, greedy fallback, divisibility
edge cases (whisper's 51865 vocab, zamba2's 112 heads)."""

from jax.sharding import PartitionSpec as P

from repro.launch.sharding import _greedy_spec, param_spec


def spec(path, shape, fsdp=False):
    return param_spec(path, shape, model=16, data=16, fsdp=fsdp)


def test_attention_projections_col_row():
    assert spec("blocks/attn/wq/w", (22, 2048, 2048)) == P(None, None, "model")
    assert spec("blocks/attn/wo/w", (22, 2048, 2048)) == P(None, "model", None)


def test_fsdp_extends_dmodel_axis():
    assert spec("blocks/attn/wq/w", (60, 7168, 7168), fsdp=True) == P(None, "data", "model")
    assert spec("blocks/mlp/w_down/w", (60, 20480, 7168), fsdp=True) == P(None, "model", "data")


def test_expert_parallelism():
    assert spec("moe_blocks/moe/experts/w_gate", (58, 256, 7168, 2048)) == \
        P(None, "model", None, None)
    assert spec("moe_blocks/moe/experts/w_down", (58, 256, 2048, 7168)) == \
        P(None, "model", None, None)


def test_vocab_embedding_divisible():
    assert spec("embed/table", (128256, 2048)) == P("model", None)


def test_vocab_embedding_odd_falls_back_to_dmodel():
    # whisper vocab 51865 is not divisible by 16 -> shard d_model instead
    assert spec("embed/table", (51865, 768)) == P(None, "model")


def test_greedy_fallback_on_unknown_param():
    # largest divisible dim gets "model": 112 = 7*16
    s = spec("weird/custom/w", (81, 112, 64))
    assert s == P(None, "model", None)
    # indivisible large dim skipped in favour of a divisible smaller one
    s2 = spec("weird/custom/w", (81, 113, 64))
    assert s2 == P(None, None, "model")


def test_greedy_never_shards_indivisible():
    s = _greedy_spec((7, 9, 11), 16, 16, False)
    assert s == (None, None, None)


def test_scalars_replicated():
    assert spec("blocks/mamba/a_log", (81, 112)) == P(None, "model")  # 112? no ->
    # 112 % 16 != 0 -> greedy declines; 81 also indivisible -> replicated... check:
    assert spec("blocks/mamba/dt_bias", (81, 7)) == P(None, None)
    assert spec("final_norm/scale", (4096,)) == P(None)
