"""The submesh allocator, the occupancy ledger, and the trace-sharing claim
behind host-parallel async dispatch (docs/ASYNC.md "Host-parallel dispatch").

In-process tests cover the allocator's acquire/release/exhaustion contract on
whatever devices exist (a 1-device pool still exercises every invariant) plus
the pure-python occupancy and timeline arithmetic.  The multi-device
invariants — equal-width partition with no device overlap, and one shared
trace serving two disjoint submeshes through an AbstractMesh — need real
(forced) host devices, so they run in a subprocess, same pattern as
tests/test_engine_equivalence.py.
"""

import jax
import pytest

from repro.core.costs import SubmeshOccupancy, VirtualTimeModel
from repro.core.telemetry import Timeline
from repro.launch.mesh import SubmeshPool


# -- allocator contract (any device count) ----------------------------------


def test_pool_acquire_release_exhaustion():
    pool = SubmeshPool(1)
    assert pool.num_submeshes == 1 and pool.width >= 1
    sm = pool.acquire()
    assert sm is not None and sm.index == 0
    assert pool.acquire() is None          # exhausted: caller queues
    assert pool.free_count == 0
    pool.release(sm)
    assert pool.free_count == 1
    assert pool.acquire() is sm            # same lease comes back


def test_pool_release_validation():
    pool = SubmeshPool(1)
    sm = pool.acquire()
    pool.release(sm)
    with pytest.raises(ValueError, match="twice"):
        pool.release(sm)
    import dataclasses
    foreign = dataclasses.replace(sm, index=5)
    with pytest.raises(ValueError, match="not from this pool"):
        pool.release(foreign)


def test_pool_construction_validation():
    with pytest.raises(ValueError, match="num_submeshes"):
        SubmeshPool(0)
    with pytest.raises(ValueError, match="cannot cut"):
        SubmeshPool(1, width=len(jax.devices()) + 1)


def test_pool_clamps_to_visible_devices():
    # asking for more submeshes than devices yields one per device, not an
    # error — the runtime then simply runs fewer cohorts concurrently
    pool = SubmeshPool(len(jax.devices()) + 7)
    assert pool.num_submeshes == len(jax.devices())
    assert pool.width == 1


def test_engine_pools_none_for_single_inflight():
    """max_inflight=1 keeps the engines' default placement (the PR 3 path)."""
    from repro.fl.batched import VmapEngine

    assert VmapEngine.cohort_pool.__qualname__  # exists
    # cohort_pool is an instance method but doesn't touch engine state for
    # the max_inflight<=1 early-out, so probe it through a bare instance.
    eng = object.__new__(VmapEngine)
    assert eng.cohort_pool(1) is None
    assert eng.cohort_pool(0) is None


# -- occupancy ledger (pure python) -----------------------------------------


def test_occupancy_booking_and_overlap():
    occ = VirtualTimeModel().occupancy()
    assert isinstance(occ, SubmeshOccupancy)
    occ.book(0, 0.0, 2.0)
    occ.book(1, 1.0, 3.0)       # overlaps [1, 2] with submesh 0
    occ.book(0, 4.0, 5.0)
    assert occ.busy_seconds(0) == pytest.approx(3.0)
    assert occ.busy_seconds(1) == pytest.approx(2.0)
    assert occ.busy_seconds() == pytest.approx(4.0)   # union, not sum
    assert occ.overlap_seconds() == pytest.approx(1.0)
    assert occ.max_concurrency() == 2
    s = occ.summary()
    assert s["cohorts"] == 3 and s["submeshes"] == 2
    assert s["busy_seconds"][0] == pytest.approx(3.0)
    assert s["max_concurrency"] == 2


def test_occupancy_rejects_negative_span():
    occ = SubmeshOccupancy()
    with pytest.raises(ValueError, match="before it starts"):
        occ.book(0, 2.0, 1.0)


def test_occupancy_adjacent_spans_not_concurrent():
    occ = SubmeshOccupancy()
    occ.book(0, 0.0, 1.0)
    occ.book(1, 1.0, 2.0)       # back-to-back: no overlap
    assert occ.overlap_seconds() == 0.0
    assert occ.max_concurrency() == 1


def test_timeline_cohort_spans_and_overlap():
    tl = Timeline()
    tl.record(0.0, "dispatch", version=0, group=0, clients=[0], t_end=2.0,
              submesh=0)
    tl.record(0.5, "dispatch", version=0, group=0, clients=[1], t_end=1.5,
              submesh=1)
    tl.record(3.0, "dispatch", version=1, group=1, clients=[0], t_end=4.0)
    tl.record(0.0, "merge", version=0)      # no t_end: not a cohort span
    assert tl.cohort_spans() == [(0, 0.0, 2.0), (1, 0.5, 1.5), (-1, 3.0, 4.0)]
    assert tl.overlap_seconds() == pytest.approx(1.0)


# -- multi-device invariants (forced host devices => subprocess) -------------


_POOL_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys, json
sys.path.insert(0, "src")
import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.compat import (SHARD_MAP_NO_CHECK_KW, abstract_client_mesh,
                               shard_map)
from repro.launch.mesh import SubmeshPool

out = {}
pool = SubmeshPool(2)
out["num"] = pool.num_submeshes
out["widths"] = [sm.width for sm in pool.submeshes]
devs = [tuple(str(d) for d in sm.devices) for sm in pool.submeshes]
out["disjoint"] = len(set(devs[0]) & set(devs[1])) == 0
out["mesh_axes"] = [sm.mesh.axis_names for sm in pool.submeshes]

# leftover devices stay unused when widths don't divide evenly
pool3 = SubmeshPool(3)
out["num3"] = pool3.num_submeshes
out["widths3"] = [sm.width for sm in pool3.submeshes]
covered = [d for sm in pool3.submeshes for d in sm.devices]
out["disjoint3"] = len(set(covered)) == len(covered)

# one AbstractMesh trace serves both equal-width submeshes
am = abstract_client_mesh(2)
out["abstract_mesh"] = am is not None
if am is not None:
    traces = [0]
    def body(x):
        traces[0] += 1
        return jax.lax.psum(x, "clients")
    fn = jax.jit(shard_map(body, mesh=am, in_specs=P("clients"),
                           out_specs=P(), **SHARD_MAP_NO_CHECK_KW))
    import jax.numpy as jnp
    for sm in pool.submeshes:
        x = jax.device_put(jnp.arange(4.0),
                           NamedSharding(sm.mesh, P("clients")))
        fn(x).block_until_ready()
    out["traces"] = traces[0]
print(json.dumps(out))
"""


def test_pool_partition_and_trace_sharing_multidevice():
    import json
    import os
    import subprocess
    import sys

    res = subprocess.run(
        [sys.executable, "-c", _POOL_SCRIPT], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(__file__)), timeout=300,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["num"] == 2 and out["widths"] == [2, 2]
    assert out["disjoint"]
    assert out["mesh_axes"] == [["clients"], ["clients"]]
    assert out["num3"] == 3 and out["widths3"] == [1, 1, 1]
    assert out["disjoint3"]
    assert out["abstract_mesh"], "this jax should build an AbstractMesh"
    assert out["traces"] == 1, "equal-width submeshes must share one trace"
