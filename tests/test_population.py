"""Population-scale client store (fl.population, docs/POPULATION.md).

Pins the subsystem's three contracts:

1. **Equivalence** — a ``SyntheticPopulation``-backed run is bit-identical to
   the same federation run from the materialised ``Sequence`` (its shards
   pre-built client by client), across the sequential/vmap engines and the
   degenerate async runtime; ``MaterializedPopulation`` wrapping is exact by
   construction.  Bounding the state store *with spill* is also exact: MOON
   prev-models and EF residuals that crossed the disk boundary train
   bit-identically.

2. **Scale** — every per-round host cost is O(cohort): Floyd's sampler draws
   k ids with k rng draws, ``IncrementalSampler`` tops up without
   replacement, lazy speed multipliers and shards make a million-client
   fleet dispatchable in milliseconds, and the seed-collision regression
   pins why the linear per-(round, client) formula had to go.

3. **Boundedness** — the LRU store caps in-memory entries, spills
   value-exactly, and drops to "first contact" semantics without spill.
"""

import numpy as np
import pytest

import jax

from repro.core.schedule import FNUSchedule, FedPartSchedule
from repro.data import (VisionDatasetSpec, balanced_eval_set, build_clients,
                        iid_partition, make_vision_dataset)
from repro.fl import AlgoConfig, AvailabilityConfig, FLRunConfig, resnet_task, run_federated
from repro.fl.population import (ClientStateStore, IncrementalSampler,
                                 MaterializedPopulation, SyntheticPopulation,
                                 as_population, client_round_seed,
                                 resolve_cohort_size, sample_excluding,
                                 sample_without_replacement)
from repro.fl.population.sampling import _nth_absent
from repro.fl.runtime.clients import ClientAvailability


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def test_floyd_uniform_subsets_and_draw_count():
    rng = np.random.default_rng(0)
    for n, k in [(1, 1), (5, 0), (5, 5), (10, 3), (10**9, 6)]:
        before = rng.bit_generator.state
        out = sample_without_replacement(rng, n, k)
        assert len(out) == len(set(out)) == k
        assert all(0 <= x < n for x in out)
        # exactly k draws: replaying k integers() advances to the same state
        replay = np.random.Generator(np.random.PCG64())
        replay.bit_generator.state = before
        for j in range(n - k, n):
            replay.integers(0, j + 1)
        assert replay.bit_generator.state == rng.bit_generator.state


def test_floyd_covers_all_subsets():
    # n=4, k=2: every 2-subset should appear with roughly equal frequency.
    rng = np.random.default_rng(1)
    counts = {}
    for _ in range(3000):
        s = frozenset(sample_without_replacement(rng, 4, 2))
        counts[s] = counts.get(s, 0) + 1
    assert len(counts) == 6
    freqs = np.array(list(counts.values())) / 3000
    assert np.all(np.abs(freqs - 1 / 6) < 0.03)


def test_floyd_rejects_bad_k():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        sample_without_replacement(rng, 5, 6)
    with pytest.raises(ValueError):
        sample_without_replacement(rng, 5, -1)


def test_nth_absent_brute_force():
    rng = np.random.default_rng(2)
    for _ in range(200):
        n = int(rng.integers(1, 40))
        excluded = sorted(rng.choice(n, size=int(rng.integers(0, n)),
                                     replace=False).tolist())
        present = [i for i in range(n + len(excluded) + 5)
                   if i not in set(excluded)]
        for rank in range(min(len(present), 10)):
            assert _nth_absent(rank, excluded) == present[rank]


def test_sample_excluding_avoids_busy_and_matches_floyd_when_empty():
    rng_a = np.random.default_rng(3)
    rng_b = np.random.default_rng(3)
    # empty exclusion: identical stream AND identical result as plain Floyd
    assert (sample_excluding(rng_a, 100, 7, []) ==
            sample_without_replacement(rng_b, 100, 7))
    assert rng_a.bit_generator.state == rng_b.bit_generator.state
    busy = [0, 1, 2, 50, 99]
    for _ in range(50):
        out = sample_excluding(rng_a, 100, 10, busy)
        assert len(out) == len(set(out)) == 10
        assert not set(out) & set(busy)
        assert all(0 <= x < 100 for x in out)


def test_incremental_sampler_never_repeats():
    rng = np.random.default_rng(4)
    s = IncrementalSampler(rng, 30, busy=[3, 7])
    seen = set()
    while s.remaining > 0:
        out = s.draw(4)
        assert not set(out) & seen
        assert not set(out) & {3, 7}
        seen.update(out)
    assert seen == set(range(30)) - {3, 7}
    assert s.draw(5) == []


def test_resolve_cohort_size():
    assert resolve_cohort_size(100, 0.25) == 25
    assert resolve_cohort_size(100, 0.0) == 1          # floor of 1
    assert resolve_cohort_size(100, 0.25, cohort_size=8) == 8
    assert resolve_cohort_size(5, 1.0, cohort_size=999) == 5   # clamped
    assert resolve_cohort_size(10**6, 0.5, cohort_size=16) == 16
    with pytest.raises(ValueError):
        resolve_cohort_size(10, 1.0, cohort_size=-1)


# ---------------------------------------------------------------------------
# seed derivation (satellite: collision regression)
# ---------------------------------------------------------------------------

def test_linear_seed_formula_collides_but_seedsequence_does_not():
    # The historical formula: seed*100_003 + round*1_009 + client_id.
    # (round r, client c+1_009) == (round r+1, client c) — adjacent rounds
    # reuse batch-order seeds as soon as ids span more than 1_009.
    seed = 0
    old = lambda r, c: seed * 100_003 + r * 1_009 + c
    assert old(0, 1_009) == old(1, 0)        # the collision this PR fixes
    rounds, ids = range(8), [0, 1, 17, 1_009, 1_010, 2_018, 10**6]
    old_seeds = [old(r, c) for r in rounds for c in ids]
    assert len(set(old_seeds)) < len(old_seeds)
    new_seeds = [client_round_seed(seed, r, c) for r in rounds for c in ids]
    assert len(set(new_seeds)) == len(new_seeds)


def test_client_round_seed_deterministic_and_seed_sensitive():
    assert client_round_seed(3, 5, 7) == client_round_seed(3, 5, 7)
    assert client_round_seed(3, 5, 7) != client_round_seed(4, 5, 7)
    assert client_round_seed(3, 5, 7) != client_round_seed(3, 6, 7)
    assert client_round_seed(3, 5, 7) != client_round_seed(3, 5, 8)
    assert 0 <= client_round_seed(0, 0, 10**7) < 2**32


# ---------------------------------------------------------------------------
# bounded state store
# ---------------------------------------------------------------------------

def _tree(v):
    return {"w": np.full((3, 2), v, np.float32), "b": np.arange(v, v + 4.0)}


def test_store_unbounded_is_a_dict():
    st = ClientStateStore()
    for i in range(50):
        st.put("moon", i, _tree(i))
    assert len(st) == 50 and st.evictions == 0
    for i in range(50):
        np.testing.assert_array_equal(st.get("moon", i)["w"], _tree(i)["w"])


def test_store_lru_evicts_least_recent_and_drops_without_spill():
    st = ClientStateStore(max_entries=2)
    st.put("ef", 0, _tree(0))
    st.put("ef", 1, _tree(1))
    st.get("ef", 0)                      # 0 becomes most-recent
    st.put("ef", 2, _tree(2))            # evicts 1, not 0
    assert st.get("ef", 1) is None
    assert st.get("ef", 0) is not None and st.get("ef", 2) is not None
    assert st.evictions == 1 and st.spills == 0


def test_store_spill_round_trip_value_exact(tmp_path):
    rng = np.random.default_rng(5)
    st = ClientStateStore(max_entries=3, spill_dir=str(tmp_path))
    trees = {i: {"a": rng.standard_normal((4, 5)).astype(np.float32),
                 "b": (rng.standard_normal(7), {"c": rng.integers(0, 9, 3)})}
             for i in range(12)}
    for i, t in trees.items():
        st.put("ef", i, t)
    assert len(st) == 3 and st.spills == 9
    for i, t in trees.items():           # every entry reloads bit-exact
        got = st.get("ef", i)
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
            np.testing.assert_array_equal(a, b)
    assert st.loads >= 9
    st.pop("ef", 0)
    assert st.get("ef", 0) is None


def test_store_kinds_are_namespaced():
    st = ClientStateStore()
    st.put("moon", 1, _tree(1))
    st.put("ef", 1, _tree(2))
    np.testing.assert_array_equal(st.get("moon", 1)["w"], _tree(1)["w"])
    np.testing.assert_array_equal(st.get("ef", 1)["w"], _tree(2)["w"])


# ---------------------------------------------------------------------------
# synthetic populations
# ---------------------------------------------------------------------------

SPEC = VisionDatasetSpec(num_classes=4, image_size=8)


def test_population_deterministic_and_order_independent():
    a = SyntheticPopulation(spec=SPEC, population=100, samples_per_client=12,
                            seed=7, cache_entries=0)
    b = SyntheticPopulation(spec=SPEC, population=100, samples_per_client=12,
                            seed=7, cache_entries=0)
    for cid in (99, 3, 42):              # different access orders
        da, db = a.dataset(cid), b.dataset(cid)
        np.testing.assert_array_equal(da.inputs, db.inputs)
        np.testing.assert_array_equal(da.labels, db.labels)
    d1, d2 = a.dataset(5), a.dataset(5)  # idempotent
    np.testing.assert_array_equal(d1.inputs, d2.inputs)
    c = SyntheticPopulation(spec=SPEC, population=100, samples_per_client=12,
                            seed=8, cache_entries=0)
    assert not np.array_equal(a.dataset(5).inputs, c.dataset(5).inputs)


def test_population_num_samples_without_materialising():
    pop = SyntheticPopulation(spec=SPEC, population=1000,
                              samples_per_client=(8, 32), seed=0,
                              cache_entries=0)
    for cid in (0, 1, 999):
        n = pop.num_samples(cid)
        assert 8 <= n <= 32
        assert len(pop.dataset(cid)) == n
    sizes = {pop.num_samples(c) for c in range(64)}
    assert len(sizes) > 1                # the range actually varies


def test_population_dirichlet_label_skew():
    pop = SyntheticPopulation(spec=SPEC, population=50, samples_per_client=200,
                              alpha=0.1, seed=0, cache_entries=0)
    # strong skew: most clients concentrate mass on few classes
    fracs = []
    for cid in range(8):
        y = pop.dataset(cid).labels
        fracs.append(np.bincount(y, minlength=4).max() / len(y))
    assert np.mean(fracs) > 0.6
    iid = SyntheticPopulation(spec=SPEC, population=50, samples_per_client=200,
                              alpha=0.0, seed=0, cache_entries=0)
    y = iid.dataset(0).labels
    assert np.bincount(y, minlength=4).max() / len(y) < 0.5


def test_population_cache_and_validation(tmp_path):
    pop = SyntheticPopulation(spec=SPEC, population=10, samples_per_client=8,
                              seed=0, cache_entries=2,
                              cache_dir=str(tmp_path))
    ref = {c: np.array(pop.dataset(c).inputs) for c in range(6)}
    assert pop.cache_stats()["evictions"] > 0
    for c in range(6):                   # spill round-trip: shards exact
        np.testing.assert_array_equal(pop.dataset(c).inputs, ref[c])
    with pytest.raises(IndexError):
        pop.dataset(10)
    with pytest.raises(ValueError):
        SyntheticPopulation(spec=SPEC, population=0)


def test_million_client_population_is_lazy():
    pop = SyntheticPopulation(spec=SPEC, population=1_000_000,
                              samples_per_client=16, seed=0)
    assert pop.num_clients == 1_000_000
    assert pop.num_samples(999_999) == 16
    assert len(pop.dataset(999_999)) == 16
    assert pop.capacity_tier(999_998, 3) == (999_998 % 3)


def test_as_population_wraps_and_passes_through():
    X, y = make_vision_dataset(SPEC, 32, seed=0)
    clients = build_clients(X, y, iid_partition(32, 4, seed=0))
    pop = as_population(clients)
    assert isinstance(pop, MaterializedPopulation)
    assert pop.num_clients == 4
    assert as_population(pop) is pop
    np.testing.assert_array_equal(pop.dataset(2).inputs, clients[2].inputs)
    with pytest.raises(ValueError):
        MaterializedPopulation([])
    with pytest.raises(ValueError, match="refusing to materialize"):
        SyntheticPopulation(spec=SPEC, population=200_000).materialize()


# ---------------------------------------------------------------------------
# lazy availability (no O(N) tables)
# ---------------------------------------------------------------------------

def test_availability_speed_is_lazy_and_deterministic():
    cfg = AvailabilityConfig(speed_spread=3.0, seed=11)
    big = ClientAvailability(cfg, 10**9)         # must not allocate O(N)
    s = big.speed(999_999_999)
    assert s == big.speed(999_999_999)           # memoised + deterministic
    small = ClientAvailability(cfg, 8)
    # order-independence: same (seed, id) hash regardless of fleet size
    assert small.speed(5) == ClientAvailability(cfg, 10**6).speed(5)
    assert small.speeds.shape == (8,)            # diagnostic table still works
    spread = small.speeds
    assert spread.min() < 1.0 < spread.max()


def test_availability_degenerate_consumes_no_randomness():
    av = ClientAvailability(AvailabilityConfig(), 10**6)
    state = av._rng.bit_generator.state
    assert av.speed(123_456) == 1.0
    assert av.arrival_ok() is True
    assert av.arrival_ok(123_456, t=7.5) is True
    assert av.jitter() == 1.0 and av.drops() is False
    assert av._rng.bit_generator.state == state


# ---------------------------------------------------------------------------
# end-to-end equivalence: population-backed == materialised
# ---------------------------------------------------------------------------

def _eval_set():
    Xe, ye = make_vision_dataset(SPEC, 64, seed=9)
    return balanced_eval_set(Xe, ye, per_class=8)


def _cfg(**kw):
    kw.setdefault("adam_eps", 1e-3)
    return FLRunConfig(local_epochs=1, batch_size=16, lr=2e-3, **kw)


def _assert_same(a, b, tol=0.0):
    for la, lb in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        d = float(np.max(np.abs(np.asarray(la) - np.asarray(lb))))
        assert d <= tol, d
    for ha, hb in zip(a.history, b.history):
        assert abs(ha["loss"] - hb["loss"]) <= max(tol, 1e-6)


@pytest.fixture(scope="module")
def pop_setup():
    pop = SyntheticPopulation(spec=SPEC, population=8, samples_per_client=24,
                              seed=3)
    mat = [pop.dataset(i) for i in range(8)]
    return resnet_task("resnet4", num_classes=4), pop, mat, _eval_set()


@pytest.mark.parametrize("engine", ["sequential", "vmap"])
def test_population_run_matches_materialised(pop_setup, engine):
    adapter, pop, mat, eval_set = pop_setup
    rounds = FedPartSchedule(num_groups=4, warmup_rounds=1, rounds_per_layer=1,
                             cycles=1).rounds()[:3]
    cfg = _cfg(engine=engine, sample_fraction=0.5, algo=AlgoConfig(name="moon"))
    _assert_same(run_federated(adapter, pop, eval_set, rounds, cfg),
                 run_federated(adapter, mat, eval_set, rounds, cfg))


def test_population_run_matches_materialised_async(pop_setup):
    adapter, pop, mat, eval_set = pop_setup
    rounds = FNUSchedule(2).rounds()
    cfg = _cfg(engine="sequential", runtime="async",
               compression="int8", error_feedback=True)
    ra = run_federated(adapter, pop, eval_set, rounds, cfg)
    rb = run_federated(adapter, mat, eval_set, rounds, cfg)
    _assert_same(ra, rb)
    # and the degenerate async still equals sync, population-backed
    rs = run_federated(adapter, pop, eval_set, rounds,
                       _cfg(engine="sequential", compression="int8",
                            error_feedback=True))
    _assert_same(ra, rs, tol=1e-5)


def test_bounded_store_with_spill_is_exact(pop_setup, tmp_path):
    # MOON prevs + EF residuals evicted to disk must train bit-identically
    # to the unbounded run (satellite: state survives eviction value-exact).
    adapter, pop, _, eval_set = pop_setup
    rounds = FNUSchedule(3).rounds()
    base = _cfg(engine="sequential", sample_fraction=0.75,
                algo=AlgoConfig(name="moon"),
                compression="int8", error_feedback=True)
    bounded = _cfg(engine="sequential", sample_fraction=0.75,
                   algo=AlgoConfig(name="moon"),
                   compression="int8", error_feedback=True,
                   state_store_entries=2, state_store_spill=str(tmp_path))
    _assert_same(run_federated(adapter, pop, eval_set, rounds, base),
                 run_federated(adapter, pop, eval_set, rounds, bounded))


def test_cohort_size_overrides_fraction(pop_setup):
    adapter, pop, _, eval_set = pop_setup
    rounds = FNUSchedule(1).rounds()
    cfg = _cfg(engine="sequential", sample_fraction=1.0, cohort_size=3)
    res = run_federated(adapter, pop, eval_set, rounds, cfg)
    assert res.history[-1]["loss"] > 0
    cfg_async = _cfg(engine="sequential", runtime="async",
                     sample_fraction=1.0, cohort_size=3)
    ra = run_federated(adapter, pop, eval_set, rounds, cfg_async)
    disp = [e for e in ra.timeline.events if e["kind"] == "dispatch"]
    assert all(len(e["clients"]) == 3 for e in disp)


def test_million_client_round_smoke():
    # One real round sampled from a 10^6-client fleet: the run must only ever
    # touch the cohort (seconds, not hours — materialising would be ~GBs).
    pop = SyntheticPopulation(spec=SPEC, population=1_000_000,
                              samples_per_client=16, seed=0)
    adapter = resnet_task("resnet4", num_classes=4)
    cfg = _cfg(engine="sequential", cohort_size=2, runtime="async",
               availability=AvailabilityConfig(speed_spread=2.0,
                                               unavailable_prob=0.3, seed=1))
    res = run_federated(adapter, pop, _eval_set(), FNUSchedule(1).rounds(), cfg)
    disp = [e for e in res.timeline.events if e["kind"] == "dispatch"]
    assert disp and all(len(e["clients"]) == 2 for e in disp)
    assert all(c < 1_000_000 for e in disp for c in e["clients"])
