"""DLG privacy attack (paper §4.4 / Table 9): partial-update gradients leak
less — reconstruction PSNR under a single-group observation must be worse
than under full-gradient observation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partition import build_partition
from repro.fl.privacy import DLGConfig, dlg_attack, mse, psnr


def tiny_model():
    key = jax.random.key(0)
    ks = jax.random.split(key, 3)
    params = {
        "layer1": {"w": jax.random.normal(ks[0], (48, 24)) * 0.2},
        "layer2": {"w": jax.random.normal(ks[1], (24, 16)) * 0.2},
        "head": {"w": jax.random.normal(ks[2], (16, 4)) * 0.2},
    }

    def loss_fn(p, x):
        h = jnp.tanh(x.reshape(x.shape[0], -1) @ p["layer1"]["w"])
        h = jnp.tanh(h @ p["layer2"]["w"])
        logits = h @ p["head"]["w"]
        return -jnp.mean(jax.nn.log_softmax(logits)[:, 0])  # label-0 loss

    return params, loss_fn


def test_psnr_metric():
    x = jnp.ones((8, 8))
    assert float(psnr(x, x)) > 100
    noisy = x + 0.1
    assert 15 < float(psnr(x, noisy)) < 25


@pytest.mark.slow
def test_dlg_full_beats_partial():
    params, loss_fn = tiny_model()
    part = build_partition(params)
    target = jax.random.normal(jax.random.key(5), (1, 48)) * 0.5
    cfg = DLGConfig(iterations=150, lr=0.05)

    x_full, _ = dlg_attack(loss_fn, params, target, cfg)
    x_part, _ = dlg_attack(loss_fn, params, target, cfg,
                           partition=part, group=1)  # observe layer2 grads only

    psnr_full = float(psnr(target, x_full, data_range=2.0))
    psnr_part = float(psnr(target, x_part, data_range=2.0))
    # Full-gradient observation reconstructs strictly better (paper Table 9).
    assert psnr_full > psnr_part + 1.0, (psnr_full, psnr_part)


@pytest.mark.slow
def test_dlg_full_reconstruction_quality():
    params, loss_fn = tiny_model()
    target = jax.random.normal(jax.random.key(5), (1, 48)) * 0.5
    x_hat, match = dlg_attack(loss_fn, params, target, DLGConfig(iterations=400, lr=0.05))
    assert float(mse(target, x_hat)) < float(mse(target, jnp.zeros_like(target)))
