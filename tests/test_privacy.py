"""DLG privacy attack (paper §4.4 / Table 9): partial-update gradients leak
less — reconstruction PSNR under a single-group observation must be worse
than under full-gradient observation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import masking
from repro.core.partition import build_partition, total_param_count
from repro.fl.privacy import DLGConfig, dlg_attack, mse, psnr


def tiny_model():
    key = jax.random.key(0)
    ks = jax.random.split(key, 3)
    params = {
        "layer1": {"w": jax.random.normal(ks[0], (48, 24)) * 0.2},
        "layer2": {"w": jax.random.normal(ks[1], (24, 16)) * 0.2},
        "head": {"w": jax.random.normal(ks[2], (16, 4)) * 0.2},
    }

    def loss_fn(p, x):
        h = jnp.tanh(x.reshape(x.shape[0], -1) @ p["layer1"]["w"])
        h = jnp.tanh(h @ p["layer2"]["w"])
        logits = h @ p["head"]["w"]
        return -jnp.mean(jax.nn.log_softmax(logits)[:, 0])  # label-0 loss

    return params, loss_fn


def test_psnr_metric():
    x = jnp.ones((8, 8))
    assert float(psnr(x, x)) > 100
    noisy = x + 0.1
    assert 15 < float(psnr(x, noisy)) < 25


def test_dlg_partial_round_attack_surface_shrinks():
    """FedPart's §5 privacy claim at test scale: on a partial round the
    attacker observes only the transmitted subtree's gradients — strictly
    fewer equations for the same unknowns, for every single-group round —
    and a short DLG run under the weakest observation (deepest group)
    reconstructs measurably worse than under full observation."""
    params, loss_fn = tiny_model()
    part = build_partition(params)
    target = jax.random.normal(jax.random.key(5), (1, 48)) * 0.5

    # Structural surface: each partial round exposes a strict subset of the
    # gradient entries, and the groups tile the full surface exactly.
    full_count = total_param_count(params)
    grads = jax.grad(lambda p: loss_fn(p, target))(params)
    observed = [total_param_count(masking.select(grads, part, g))
                for g in range(part.num_groups)]
    assert all(0 < n < full_count for n in observed)
    assert sum(observed) == full_count
    # The attacker's equation count shrinks with depth (48*24 > 24*16 > 16*4).
    assert observed == sorted(observed, reverse=True)

    # Behavioral surface: same attack budget, deepest-group observation only
    # (the paper's hardest case) vs full observation.
    cfg = DLGConfig(iterations=120, lr=0.05)
    x_full, _ = dlg_attack(loss_fn, params, target, cfg)
    x_part, match = dlg_attack(loss_fn, params, target, cfg,
                               partition=part, group=2)  # head grads only
    mse_full = float(mse(target, x_full))
    mse_part = float(mse(target, x_part))
    assert np.isfinite(match)
    assert mse_part > 1.2 * mse_full, (mse_full, mse_part)


@pytest.mark.slow
def test_dlg_full_beats_partial():
    params, loss_fn = tiny_model()
    part = build_partition(params)
    target = jax.random.normal(jax.random.key(5), (1, 48)) * 0.5
    cfg = DLGConfig(iterations=150, lr=0.05)

    x_full, _ = dlg_attack(loss_fn, params, target, cfg)
    x_part, _ = dlg_attack(loss_fn, params, target, cfg,
                           partition=part, group=1)  # observe layer2 grads only

    psnr_full = float(psnr(target, x_full, data_range=2.0))
    psnr_part = float(psnr(target, x_part, data_range=2.0))
    # Full-gradient observation reconstructs strictly better (paper Table 9).
    assert psnr_full > psnr_part + 1.0, (psnr_full, psnr_part)


@pytest.mark.slow
def test_dlg_full_reconstruction_quality():
    params, loss_fn = tiny_model()
    target = jax.random.normal(jax.random.key(5), (1, 48)) * 0.5
    x_hat, match = dlg_attack(loss_fn, params, target, DLGConfig(iterations=400, lr=0.05))
    assert float(mse(target, x_hat)) < float(mse(target, jnp.zeros_like(target)))


# -- compressed observations (core.compress, docs/COMPRESSION.md) -----------
#
# Transmission compression is lossy, so an eavesdropper on the compressed
# wire sees *at most* the information of the exact updates: DLG from the
# quantized observation must reconstruct no better than from the exact one,
# and the structural attack surface (encoded bytes per group) still strictly
# shrinks on partial rounds.


def _qdq_transform(kind):
    from repro.core import compress

    cfg = compress.make_config(kind)
    return lambda g: jax.tree.map(lambda leaf: compress.qdq_leaf(leaf, cfg), g)


def test_dlg_compress_observation_reconstructs_no_better():
    """int8 / 1-bit observed updates: same attack budget as the exact
    baseline, quantized target observation — reconstruction error must not
    drop below the exact-observation error (data-processing direction; the
    coarse 1-bit channel should hurt the attacker outright)."""
    params, loss_fn = tiny_model()
    target = jax.random.normal(jax.random.key(5), (1, 48)) * 0.5
    cfg = DLGConfig(iterations=120, lr=0.05)

    x_exact, _ = dlg_attack(loss_fn, params, target, cfg)
    mse_exact = float(mse(target, x_exact))
    for kind in ("int8", "onebit"):
        x_q, match = dlg_attack(loss_fn, params, target, cfg,
                                observe_transform=_qdq_transform(kind))
        assert np.isfinite(float(match))
        mse_q = float(mse(target, x_q))
        # "no better": allow float/optimisation jitter, never a real gain.
        assert mse_q >= 0.95 * mse_exact, (kind, mse_exact, mse_q)


def test_dlg_compress_partial_surface_still_shrinks():
    """On a partial round the compressed observation is both quantized AND
    restricted to one group's subtree: the per-group encoded-byte surface is
    a strict subset that tiles the full surface, ordered by depth exactly as
    the dense ledger, and DLG under the deepest-group quantized observation
    reconstructs worse than under full quantized observation."""
    from repro.core import compress

    params, loss_fn = tiny_model()
    part = build_partition(params)
    target = jax.random.normal(jax.random.key(5), (1, 48)) * 0.5
    grads = jax.grad(lambda p: loss_fn(p, target))(params)

    for kind in ("int8", "onebit"):
        ccfg = compress.make_config(kind)
        full_bytes = compress.tree_encoded_bytes(grads, ccfg)
        per_group = [
            compress.tree_encoded_bytes(masking.select(grads, part, g), ccfg)
            for g in range(part.num_groups)
        ]
        assert all(0 < b < full_bytes for b in per_group), (kind, per_group)
        assert sum(per_group) == full_bytes
        assert per_group == sorted(per_group, reverse=True)

    cfg = DLGConfig(iterations=120, lr=0.05)
    transform = _qdq_transform("int8")
    x_full, _ = dlg_attack(loss_fn, params, target, cfg,
                           observe_transform=transform)
    x_part, match = dlg_attack(loss_fn, params, target, cfg,
                               partition=part, group=2,
                               observe_transform=transform)
    assert np.isfinite(float(match))
    mse_full = float(mse(target, x_full))
    mse_part = float(mse(target, x_part))
    assert mse_part > 1.2 * mse_full, (mse_full, mse_part)
