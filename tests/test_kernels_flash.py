"""Flash-attention Pallas kernel vs. pure-jnp oracle: shape/dtype sweep in
interpret mode (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops

CASES = [
    # (b, h, hkv, sq, skv, d, causal, window)
    (1, 2, 2, 128, 128, 64, True, 0),
    (2, 4, 2, 256, 256, 64, True, 0),      # GQA 2:1
    (1, 4, 1, 128, 256, 128, True, 0),     # MQA
    (1, 2, 2, 128, 384, 64, False, 0),     # cross-attention shape
    (2, 2, 2, 256, 256, 32, True, 64),     # sliding window
    (1, 2, 2, 128, 128, 96, True, 0),      # non-128 head dim (pad path)
    (1, 2, 2, 192, 192, 64, True, 0),      # non-block seq (pad path)
]


@pytest.mark.parametrize("case", CASES)
def test_against_oracle_f32(case):
    b, h, hkv, sq, skv, d, causal, window = case
    ks = jax.random.split(jax.random.key(hash(case) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, skv, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, skv, hkv, d), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, window=window, interpret=True)
    ref = ops.attention_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_dtypes(dtype):
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 128, 2, 64)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 128, 2, 64)).astype(dtype)
    out = ops.flash_attention(q, k, v, interpret=True)
    ref = ops.attention_reference(q, k, v)
    assert out.dtype == dtype
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_window_equals_full_when_large():
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 128, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 128, 2, 64), jnp.float32)
    full = ops.flash_attention(q, k, v, causal=True, window=0, interpret=True)
    winbig = ops.flash_attention(q, k, v, causal=True, window=4096, interpret=True)
    np.testing.assert_allclose(np.asarray(full), np.asarray(winbig), atol=1e-6)
