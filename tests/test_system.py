"""End-to-end system behaviour: full FedPart run -> checkpoint -> reload ->
serve-style evaluation, plus the paper's headline directional claims at
micro scale."""

import jax
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core.schedule import FedPartSchedule, matched_fnu
from repro.data import (VisionDatasetSpec, balanced_eval_set, build_clients,
                        iid_partition, make_vision_dataset)
from repro.fl import FLRunConfig, resnet_task, run_federated

# Full FedPart runs + checkpoint roundtrips: minutes of wall-clock.
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def fl_run():
    spec = VisionDatasetSpec(num_classes=8, image_size=16, noise=1.0)
    X, y = make_vision_dataset(spec, 500, seed=0)
    Xe, ye = make_vision_dataset(spec, 300, seed=9)
    eval_set = balanced_eval_set(Xe, ye, per_class=12)
    clients = build_clients(X, y, iid_partition(len(y), 2, seed=0))
    adapter = resnet_task("resnet8", num_classes=8)
    sched = FedPartSchedule(num_groups=10, warmup_rounds=2, rounds_per_layer=1,
                            cycles=1)
    cfg = FLRunConfig(local_epochs=1, batch_size=32, lr=2e-3, track_stepsizes=True)
    res = run_federated(adapter, clients, eval_set, sched.rounds(), cfg)
    return adapter, eval_set, sched, cfg, res, clients


def test_fedpart_end_to_end(fl_run):
    _, _, sched, _, res, _ = fl_run
    assert res.best_acc > 0.3
    assert res.comm_total_bytes < 0.4 * res.comm_fnu_bytes


def test_checkpoint_roundtrip_preserves_eval(fl_run, tmp_path):
    adapter, eval_set, _, _, res, _ = fl_run
    save_checkpoint(str(tmp_path / "ckpt"), res.params, {"best": res.best_acc})
    params2, state = load_checkpoint(str(tmp_path / "ckpt"))
    acc_before = float(adapter.evaluate(res.params, eval_set[0][:64], eval_set[1][:64]))
    params2 = jax.tree.map(lambda a, b: b.astype(a.dtype), res.params, params2)
    acc_after = float(adapter.evaluate(params2, eval_set[0][:64], eval_set[1][:64]))
    assert acc_before == pytest.approx(acc_after, abs=1e-6)
    assert state["best"] == pytest.approx(res.best_acc)


def test_paper_claim_comm_savings_eq5(fl_run):
    """Partial rounds move ~1/M of the bytes (Eq. 5)."""
    _, _, sched, _, res, _ = fl_run
    part = res.partition
    from repro.core.costs import comm_cost

    report = comm_cost(res.params, part, sched.rounds())
    partial_rounds = [r for r in sched.rounds() if not r.is_full]
    full_bytes = report.fnu_total_bytes / len(sched.rounds())
    mean_partial = np.mean(
        [report.per_round_bytes[r.index] for r in partial_rounds]
    )
    # groups are not perfectly uniform in a ResNet; allow 3x of 1/M
    assert mean_partial < 3.0 * full_bytes / part.num_groups


def test_paper_claim_layer_mismatch_spike(fl_run):
    """FNU shows a post-aggregation step-size spike; FedPart's is smaller
    (paper Fig. 1).  Micro-scale: assert both measurable and ordered."""
    adapter, eval_set, sched, cfg, fp_res, clients = fl_run
    fnu = run_federated(adapter, clients, eval_set,
                        matched_fnu(sched).rounds(), cfg)
    fp_spike = fp_res.tracker.post_aggregation_spike()
    fnu_spike = fnu.tracker.post_aggregation_spike()
    assert np.isfinite(fp_spike) and np.isfinite(fnu_spike)
    assert fnu_spike > 1.0          # mismatch exists under FNU
    assert fp_spike < fnu_spike     # FedPart reduces it
