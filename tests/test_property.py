"""Property-based tests (hypothesis) on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import aggregation, masking
from repro.core.partition import build_partition, total_param_count
from repro.core.schedule import FedPartSchedule
from repro.data.partitioner import dirichlet_partition, iid_partition
from tests.conftest import small_params

PARAMS = small_params()
PART = build_partition(PARAMS)


@given(groups=st.sets(st.integers(0, PART.num_groups - 1), min_size=1))
@settings(max_examples=25, deadline=None)
def test_select_complement_partition_property(groups):
    """select(G) ∪ complement(G) == params, disjointly, for ANY group set."""
    sel = masking.select(PARAMS, PART, sorted(groups))
    comp = masking.complement(PARAMS, PART, sorted(groups))
    assert total_param_count(sel) + total_param_count(comp) == total_param_count(PARAMS)
    merged = masking.merge(sel, comp)
    assert jax.tree.structure(merged) == jax.tree.structure(PARAMS)


@given(
    num_groups=st.integers(2, 12),
    warmup=st.integers(0, 4),
    rl=st.integers(1, 4),
    cycles=st.integers(1, 3),
    bridge=st.integers(0, 3),
    order=st.sampled_from(["sequential", "reverse", "random"]),
    seed=st.integers(0, 100),
)
@settings(max_examples=40, deadline=None)
def test_schedule_invariants(num_groups, warmup, rl, cycles, bridge, order, seed):
    s = FedPartSchedule(num_groups=num_groups, warmup_rounds=warmup,
                        rounds_per_layer=rl, cycles=cycles, bridge_rounds=bridge,
                        order=order, seed=seed)
    rounds = s.rounds()
    assert len(rounds) == s.total_rounds
    # every cycle trains every group exactly rl times
    for c in range(cycles):
        counts = {}
        for r in rounds:
            if r.phase == "partial" and r.cycle == c:
                counts[r.group] = counts.get(r.group, 0) + 1
        assert counts == {g: rl for g in range(num_groups)}
    # indices strictly consecutive
    assert [r.index for r in rounds] == list(range(len(rounds)))


@given(w=st.lists(st.floats(0.1, 10.0), min_size=2, max_size=5))
@settings(max_examples=25, deadline=None)
def test_weighted_mean_convexity(w):
    """Weighted average of client scalars stays within [min, max]."""
    trees = [{"x": jnp.full((3,), float(i + 1))} for i in range(len(w))]
    out = aggregation.tree_mean(trees, weights=w)
    val = float(out["x"][0])
    assert 1.0 - 1e-5 <= val <= len(w) + 1e-5


@given(n=st.integers(10, 200), clients=st.integers(2, 8), seed=st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_iid_partition_property(n, clients, seed):
    parts = iid_partition(n, clients, seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n        # disjoint cover
    sizes = [len(p) for p in parts]
    assert max(sizes) - min(sizes) <= 1       # balanced


@given(
    clients=st.integers(2, 6),
    alpha=st.floats(0.1, 10.0),
    seed=st.integers(0, 50),
)
@settings(max_examples=20, deadline=None)
def test_dirichlet_partition_property(clients, alpha, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 5, 300).astype(np.int64)
    parts = dirichlet_partition(labels, clients, alpha, seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == len(labels)
    assert len(np.unique(allidx)) == len(labels)
    assert all(len(p) >= 2 for p in parts)


@given(data=st.data())
@settings(max_examples=15, deadline=None)
def test_partial_aggregate_preserves_frozen(data):
    g = data.draw(st.integers(0, PART.num_groups - 1))
    n_clients = data.draw(st.integers(1, 4))
    subs = []
    for i in range(n_clients):
        c = jax.tree.map(lambda x: x * (i + 2.0), PARAMS)
        subs.append(masking.select(c, PART, g))
    new = aggregation.aggregate_partial(PARAMS, subs)
    comp_old = masking.complement(PARAMS, PART, g)
    comp_new = masking.complement(new, PART, g)
    for a, b in zip(jax.tree.leaves(comp_old), jax.tree.leaves(comp_new)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(step=st.integers(1, 1000))
@settings(max_examples=10, deadline=None)
def test_masked_adam_pack_block_alignment(step):
    from repro.kernels.masked_adam import ops as ma_ops

    packed, meta = ma_ops.pack(PARAMS, block_rows=8)
    assert packed.shape[0] % 8 == 0
    bm = ma_ops.block_mask_for_group(PARAMS, PART, 0, block_rows=8)
    assert bm.shape[0] == packed.shape[0] // 8
