import os

import jax
import jax.numpy as jnp
import pytest

# NOTE: no XLA_FLAGS here — smoke tests and benches see 1 device; only
# launch/dryrun.py (run as its own process) forces 512 host devices.

# Persistent XLA compile cache (keyed by HLO): identical programs built by
# different jit instances — e.g. the eval fn across every run_federated call,
# or a step fn shared by two tests — compile once per machine instead of once
# per LocalTrainer.  This is what keeps the tier-1 lane fast.
_CACHE_DIR = os.path.join(os.path.dirname(__file__), os.pardir, ".jax_cache")
jax.config.update("jax_compilation_cache_dir", os.path.abspath(_CACHE_DIR))
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.2)


def pytest_collection_modifyitems(config, items):
    """Everything not marked ``slow`` is tier-1 (the default `pytest -q` run,
    see pytest.ini); tag it so `-m tier1` selects the same subset."""
    for item in items:
        if "slow" not in item.keywords:
            item.add_marker(pytest.mark.tier1)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)


def small_params(key=None):
    """A small transformer-shaped pytree used across partition tests."""
    key = key if key is not None else jax.random.key(0)
    ks = jax.random.split(key, 8)
    return {
        "embed": {"table": jax.random.normal(ks[0], (32, 16))},
        "blocks": {
            "0": {"attn": {"wq": {"w": jax.random.normal(ks[1], (16, 16))},
                           "wo": {"w": jax.random.normal(ks[2], (16, 16))}},
                  "norm": {"scale": jnp.ones(16)}},
            "1": {"attn": {"wq": {"w": jax.random.normal(ks[3], (16, 16))},
                           "wo": {"w": jax.random.normal(ks[4], (16, 16))}},
                  "norm": {"scale": jnp.ones(16)}},
            "2": {"attn": {"wq": {"w": jax.random.normal(ks[5], (16, 16))},
                           "wo": {"w": jax.random.normal(ks[6], (16, 16))}},
                  "norm": {"scale": jnp.ones(16)}},
        },
        "head": {"w": jax.random.normal(ks[7], (16, 8)), "b": jnp.zeros(8)},
    }


@pytest.fixture
def params():
    return small_params()
