import jax
import jax.numpy as jnp
import pytest

# NOTE: no XLA_FLAGS here — smoke tests and benches see 1 device; only
# launch/dryrun.py (run as its own process) forces 512 host devices.


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)


def small_params(key=None):
    """A small transformer-shaped pytree used across partition tests."""
    key = key if key is not None else jax.random.key(0)
    ks = jax.random.split(key, 8)
    return {
        "embed": {"table": jax.random.normal(ks[0], (32, 16))},
        "blocks": {
            "0": {"attn": {"wq": {"w": jax.random.normal(ks[1], (16, 16))},
                           "wo": {"w": jax.random.normal(ks[2], (16, 16))}},
                  "norm": {"scale": jnp.ones(16)}},
            "1": {"attn": {"wq": {"w": jax.random.normal(ks[3], (16, 16))},
                           "wo": {"w": jax.random.normal(ks[4], (16, 16))}},
                  "norm": {"scale": jnp.ones(16)}},
            "2": {"attn": {"wq": {"w": jax.random.normal(ks[5], (16, 16))},
                           "wo": {"w": jax.random.normal(ks[6], (16, 16))}},
                  "norm": {"scale": jnp.ones(16)}},
        },
        "head": {"w": jax.random.normal(ks[7], (16, 8)), "b": jnp.zeros(8)},
    }


@pytest.fixture
def params():
    return small_params()
