import numpy as np
import pytest

from repro.core import costs
from repro.core.partition import Partition
from repro.core.schedule import FedPartSchedule, FNUSchedule


def uniform_partition(m: int) -> Partition:
    keys = tuple(("block", "blocks", i) for i in range(m))
    # one synthetic path per group
    assignment = {f"blocks/{i}/w": i for i in range(m)}
    return Partition(group_keys=keys, assignment=assignment)


def uniform_params(m: int, n: int = 64):
    import jax.numpy as jnp

    return {"blocks": {str(i): {"w": jnp.ones((n,), jnp.float32)} for i in range(m)}}


def test_eq5_comm_ratio_partial_rounds():
    """Eq. 5: a full cycle of partial rounds moves 1/M of FNU bytes."""
    m = 8
    params = uniform_params(m)
    part = uniform_partition(m)
    sched = FedPartSchedule(num_groups=m, warmup_rounds=0, rounds_per_layer=1,
                            cycles=1)
    report = costs.comm_cost(params, part, sched.rounds())
    assert report.ratio_to_fnu == pytest.approx(1.0 / m)


def test_eq6_paper_compute_ratio_asymptote():
    """Paper Eq. 6 bookkeeping -> 2/3 for large M; ours (truncated) -> 1/2."""
    m = 400
    part = uniform_partition(m)
    sched = FedPartSchedule(num_groups=m, warmup_rounds=0, rounds_per_layer=1,
                            cycles=1)
    paper = costs.comp_cost(part, sched.rounds(), bookkeeping="paper")
    trunc = costs.comp_cost(part, sched.rounds(), bookkeeping="truncated")
    assert paper.ratio_to_fnu == pytest.approx(2.0 / 3.0, abs=0.01)
    assert trunc.ratio_to_fnu == pytest.approx(0.5, abs=0.01)
    assert costs.paper_asymptotic_comp_ratio() == pytest.approx(2.0 / 3.0)


def test_warmup_rounds_cost_full():
    m = 4
    params = uniform_params(m)
    part = uniform_partition(m)
    sched = FedPartSchedule(num_groups=m, warmup_rounds=4, rounds_per_layer=1,
                            cycles=1)
    report = costs.comm_cost(params, part, sched.rounds())
    per_round = report.per_round_bytes
    assert (per_round[:4] == per_round[0]).all()          # warmup = full
    assert per_round[4] * m == per_round[0]               # partial = 1/M


def test_fnu_schedule_ratio_is_one():
    m = 4
    params = uniform_params(m)
    part = uniform_partition(m)
    sched = FNUSchedule(total=7)
    assert costs.comm_cost(params, part, sched.rounds()).ratio_to_fnu == 1.0
    assert costs.comp_cost(part, sched.rounds()).ratio_to_fnu == 1.0


def test_shallower_groups_cost_more_compute():
    """Truncated backward: training group 0 needs the full activation-grad
    chain; training the deepest group needs almost none."""
    m = 10
    part = uniform_partition(m)
    s0 = FedPartSchedule(num_groups=m, warmup_rounds=0, rounds_per_layer=1, cycles=1)
    report = costs.comp_cost(part, s0.rounds(), bookkeeping="truncated")
    per = report.per_round_flops
    assert per[0] > per[-1]
    assert np.all(np.diff(per) <= 0)


def test_fused_adam_kernel_book():
    n = 1 << 20
    # full training: fused does 7 passes, unfused 14 -> exactly 2x traffic
    assert costs.adam_step_bytes(n, fused=True) == 4 * 7 * n
    assert costs.adam_step_bytes(n, fused=False) == 4 * 14 * n
    assert costs.fused_adam_traffic_ratio(1.0) == pytest.approx(2.0)
    # frozen blocks skip the write-back: 4 passes, ratio 3.5x
    assert costs.adam_step_bytes(n, fused=True, trained_fraction=0.0) == 4 * 4 * n
    assert costs.fused_adam_traffic_ratio(0.0) == pytest.approx(3.5)
    # unfused traffic is mask-independent
    assert costs.adam_step_bytes(n, fused=False, trained_fraction=0.25) == \
        costs.adam_step_bytes(n, fused=False)
    assert costs.adam_step_flops(n, 0.5) == costs.adam_step_flops(n) // 2
    with pytest.raises(ValueError, match="trained_fraction"):
        costs.adam_step_bytes(n, fused=True, trained_fraction=1.5)
