import numpy as np
import pytest

from repro.core import costs
from repro.core.partition import Partition
from repro.core.schedule import FedPartSchedule, FNUSchedule


def uniform_partition(m: int) -> Partition:
    keys = tuple(("block", "blocks", i) for i in range(m))
    # one synthetic path per group
    assignment = {f"blocks/{i}/w": i for i in range(m)}
    return Partition(group_keys=keys, assignment=assignment)


def uniform_params(m: int, n: int = 64):
    import jax.numpy as jnp

    return {"blocks": {str(i): {"w": jnp.ones((n,), jnp.float32)} for i in range(m)}}


def test_eq5_comm_ratio_partial_rounds():
    """Eq. 5: a full cycle of partial rounds moves 1/M of FNU bytes."""
    m = 8
    params = uniform_params(m)
    part = uniform_partition(m)
    sched = FedPartSchedule(num_groups=m, warmup_rounds=0, rounds_per_layer=1,
                            cycles=1)
    report = costs.comm_cost(params, part, sched.rounds())
    assert report.ratio_to_fnu == pytest.approx(1.0 / m)


def test_eq6_paper_compute_ratio_asymptote():
    """Paper Eq. 6 bookkeeping -> 2/3 for large M; ours (truncated) -> 1/2."""
    m = 400
    part = uniform_partition(m)
    sched = FedPartSchedule(num_groups=m, warmup_rounds=0, rounds_per_layer=1,
                            cycles=1)
    paper = costs.comp_cost(part, sched.rounds(), bookkeeping="paper")
    trunc = costs.comp_cost(part, sched.rounds(), bookkeeping="truncated")
    assert paper.ratio_to_fnu == pytest.approx(2.0 / 3.0, abs=0.01)
    assert trunc.ratio_to_fnu == pytest.approx(0.5, abs=0.01)
    assert costs.paper_asymptotic_comp_ratio() == pytest.approx(2.0 / 3.0)


def test_warmup_rounds_cost_full():
    m = 4
    params = uniform_params(m)
    part = uniform_partition(m)
    sched = FedPartSchedule(num_groups=m, warmup_rounds=4, rounds_per_layer=1,
                            cycles=1)
    report = costs.comm_cost(params, part, sched.rounds())
    per_round = report.per_round_bytes
    assert (per_round[:4] == per_round[0]).all()          # warmup = full
    assert per_round[4] * m == per_round[0]               # partial = 1/M


def test_fnu_schedule_ratio_is_one():
    m = 4
    params = uniform_params(m)
    part = uniform_partition(m)
    sched = FNUSchedule(total=7)
    assert costs.comm_cost(params, part, sched.rounds()).ratio_to_fnu == 1.0
    assert costs.comp_cost(part, sched.rounds()).ratio_to_fnu == 1.0


def test_shallower_groups_cost_more_compute():
    """Truncated backward: training group 0 needs the full activation-grad
    chain; training the deepest group needs almost none."""
    m = 10
    part = uniform_partition(m)
    s0 = FedPartSchedule(num_groups=m, warmup_rounds=0, rounds_per_layer=1, cycles=1)
    report = costs.comp_cost(part, s0.rounds(), bookkeeping="truncated")
    per = report.per_round_flops
    assert per[0] > per[-1]
    assert np.all(np.diff(per) <= 0)


def test_fused_adam_kernel_book():
    n = 1 << 20
    # full training: fused does 7 passes, unfused 14 -> exactly 2x traffic
    assert costs.adam_step_bytes(n, fused=True) == 4 * 7 * n
    assert costs.adam_step_bytes(n, fused=False) == 4 * 14 * n
    assert costs.fused_adam_traffic_ratio(1.0) == pytest.approx(2.0)
    # frozen blocks skip the write-back: 4 passes, ratio 3.5x
    assert costs.adam_step_bytes(n, fused=True, trained_fraction=0.0) == 4 * 4 * n
    assert costs.fused_adam_traffic_ratio(0.0) == pytest.approx(3.5)
    # unfused traffic is mask-independent
    assert costs.adam_step_bytes(n, fused=False, trained_fraction=0.25) == \
        costs.adam_step_bytes(n, fused=False)
    assert costs.adam_step_flops(n, 0.5) == costs.adam_step_flops(n) // 2
    with pytest.raises(ValueError, match="trained_fraction"):
        costs.adam_step_bytes(n, fused=True, trained_fraction=1.5)


# -- compressed-byte ledger (core.compress, docs/COMPRESSION.md) ------------


def test_compress_leaf_encoded_bytes_model():
    """Analytic wire-byte model: payload + per-block scales + top-k indices."""
    from repro.core import compress

    cfg8 = compress.make_config("int8")
    assert compress.leaf_encoded_bytes(1000, cfg8) == 1000 + 4   # 1 leaf scale
    blocked = compress.make_config("int8", block_rows=1)         # 128-elem blocks
    assert compress.leaf_encoded_bytes(1000, blocked) == 1000 + 4 * 8
    cfg1 = compress.make_config("onebit")
    assert compress.leaf_encoded_bytes(1000, cfg1) == 125 + 4    # packed bits
    assert compress.leaf_encoded_bytes(1001, cfg1) == 126 + 4    # ceil
    cfgk = compress.make_config("topk", topk_fraction=0.01)
    assert compress.leaf_encoded_bytes(1000, cfgk) == 10 * (4 + 4)
    assert compress.leaf_encoded_bytes(3, cfgk) == 1 * 8         # k >= 1
    for cfg in (cfg8, cfg1, cfgk, None):
        assert compress.leaf_encoded_bytes(0, cfg) == 0
    assert compress.leaf_encoded_bytes(100, None) == 400         # dense f32


def test_comm_cost_compressed_ledger():
    """comm_cost(compression=...) prices the encoded wire format per round
    while the FNU baseline stays dense f32, so ratio_to_fnu reports the
    combined Eq. 5 x quantization saving."""
    from repro.core import compress

    m, n = 8, 64
    params = uniform_params(m, n)
    part = uniform_partition(m)
    sched = FedPartSchedule(num_groups=m, warmup_rounds=0, rounds_per_layer=1,
                            cycles=1)
    cfg = compress.make_config("int8")
    rep = costs.comm_cost(params, part, sched.rounds(), compression=cfg)
    per_group = n + 4                                   # codes + 1 leaf scale
    assert (rep.per_round_bytes == per_group).all()
    assert rep.total_bytes == m * per_group
    assert rep.fnu_total_bytes == m * (m * n * 4)       # dense FNU baseline
    assert rep.ratio_to_fnu == pytest.approx(per_group / (m * n * 4.0))
    # compression=None is the legacy dense ledger exactly
    dense = costs.comm_cost(params, part, sched.rounds())
    none = costs.comm_cost(params, part, sched.rounds(), compression=None)
    assert none.total_bytes == dense.total_bytes
    assert none.fnu_total_bytes == dense.fnu_total_bytes


def test_async_books_consume_encoded_bytes():
    """The async runtime's virtual clock must book *encoded* sizes: every
    delivered update's comm_bytes equals the encoded per-group table entry
    (never the dense one), and the identical federation finishes sooner on
    the virtual clock because VirtualTimeModel.comm_seconds consumed the
    smaller transfers."""
    from repro.core import compress
    from repro.core.partition import group_param_bytes, total_param_bytes
    from repro.data import (VisionDatasetSpec, balanced_eval_set,
                            build_clients, iid_partition, make_vision_dataset)
    from repro.fl import FLRunConfig, resnet_task, run_federated

    spec = VisionDatasetSpec(num_classes=4, image_size=8)
    X, y = make_vision_dataset(spec, 96, seed=0)
    Xe, ye = make_vision_dataset(spec, 64, seed=9)
    eval_set = balanced_eval_set(Xe, ye, per_class=8)
    clients = build_clients(X, y, iid_partition(len(y), 3, seed=0))
    adapter = resnet_task("resnet4", num_classes=4)
    rounds = FedPartSchedule(num_groups=6, warmup_rounds=1, rounds_per_layer=1,
                             cycles=1).rounds()[:2]

    def run(compression):
        cfg = FLRunConfig(local_epochs=1, batch_size=16, lr=2e-3,
                          adam_eps=1e-3, engine="vmap", runtime="async",
                          compression=compression)
        return run_federated(adapter, clients, eval_set, rounds, cfg)

    dense_res = run("none")
    int8_res = run("int8")
    part = int8_res.partition
    enc = compress.group_encoded_bytes(int8_res.params, part,
                                       compress.make_config("int8"))
    dense_group = group_param_bytes(int8_res.params, part)
    full_enc = int(enc.sum())
    allowed = {full_enc} | {int(b) for b in enc}
    completes = int8_res.timeline.of_kind("complete")
    assert completes, "async run delivered no updates"
    for ev in completes:
        assert ev["comm_bytes"] in allowed, ev
    # never the dense sizes
    dense_sizes = {int(total_param_bytes(int8_res.params))} | \
        {int(b) for b in dense_group}
    assert not {ev["comm_bytes"] for ev in completes} & dense_sizes
    # smaller transfers -> earlier virtual finish, same schedule
    assert int8_res.timeline.total_seconds < dense_res.timeline.total_seconds
    assert int8_res.timeline.delivered_comm_bytes < \
        dense_res.timeline.delivered_comm_bytes
