import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (load_checkpoint, load_pytree, save_checkpoint,
                              save_pytree)
from tests.conftest import small_params


def test_pytree_roundtrip(tmp_path):
    params = small_params()
    path = str(tmp_path / "params.npz")
    save_pytree(path, params)
    restored = load_pytree(path)
    flat_a = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_b = jax.tree_util.tree_flatten_with_path(restored)[0]
    assert len(flat_a) == len(flat_b)
    for (pa, a), (pb, b) in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), b)


def test_checkpoint_with_state(tmp_path):
    params = {"w": jnp.arange(6.0).reshape(2, 3)}
    save_checkpoint(str(tmp_path / "ckpt"), params, {"round": 7, "acc": 0.5})
    p, state = load_checkpoint(str(tmp_path / "ckpt"))
    np.testing.assert_array_equal(p["w"], np.arange(6.0).reshape(2, 3))
    assert state["round"] == 7
