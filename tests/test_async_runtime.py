"""The async runtime must collapse to the synchronous path in the degenerate
config, and behave deterministically + sanely outside it.

Degenerate config = full participation default-K barrier (buffer goal =
cohort size), staleness exponent 0, perfect fleet (default
``AvailabilityConfig``): the acceptance bar is params / per-round losses /
cost books equal to the synchronous ``run_federated`` to <=1e-5 for FedAvg
and FedProx, on full AND partial rounds, under both batched execution
backends (vmap, shard_map) and the sequential oracle.  Same setup, seeds,
and adam_eps rationale as tests/test_engine_equivalence.py.

Beyond the degenerate corner: policy unit semantics (per-group splice into
the *current* frozen context, polynomial staleness mixing), the
schedule-by-server-version lookup, availability-model determinism, and
event-loop invariants (staleness actually occurs under heterogeneity + K=1;
dropped updates burn compute but never merge; identical seeds => identical
histories).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partition import build_partition
from repro.core.schedule import FedPartSchedule, FNUSchedule, ScheduleIndex
from repro.core.telemetry import Timeline, TimelineWindow
from repro.data import (VisionDatasetSpec, balanced_eval_set, build_clients,
                        make_vision_dataset)
from repro.fl import (AlgoConfig, AvailabilityConfig, FLRunConfig,
                      resnet_task, run_federated)
from repro.fl.runtime.clients import ClientAvailability
from repro.fl.runtime.control import (AdaptiveInflightController,
                                      PolicyAdjustment,
                                      ProgressGroupController,
                                      StalenessBufferController,
                                      make_controller)
from repro.fl.runtime.policy import (ClientUpdate, FedBuffPolicy,
                                     SyncFedAvgPolicy, make_policy)

BATCH = 16


def _make_setup(client_sizes):
    spec = VisionDatasetSpec(num_classes=4, image_size=8)
    X, y = make_vision_dataset(spec, sum(client_sizes), seed=0)
    Xe, ye = make_vision_dataset(spec, 64, seed=9)
    eval_set = balanced_eval_set(Xe, ye, per_class=8)
    bounds = np.cumsum((0,) + tuple(client_sizes))
    parts = [np.arange(bounds[i], bounds[i + 1]) for i in range(len(client_sizes))]
    return resnet_task("resnet4", num_classes=4), build_clients(X, y, parts), eval_set


@pytest.fixture(scope="module")
def setup():
    # Same ragged sizes as test_engine_equivalence => warm XLA cache reuse.
    return _make_setup((36, 56, 40))


# 1 FNU warmup + 1 partial round: both phases per config.
MIXED = FedPartSchedule(num_groups=6, warmup_rounds=1, rounds_per_layer=1,
                        cycles=1).rounds()[:2]


def _run(setup, algo, engine, runtime, rounds=MIXED, **kw):
    adapter, clients, eval_set = setup
    cfg = FLRunConfig(local_epochs=1, batch_size=BATCH, lr=2e-3, adam_eps=1e-3,
                      algo=AlgoConfig(name=algo), engine=engine,
                      runtime=runtime, **kw)
    return run_federated(adapter, clients, eval_set, rounds, cfg)


def _assert_equivalent(a, b):
    for (path, la), lb in zip(
        jax.tree_util.tree_flatten_with_path(a.params)[0],
        jax.tree.leaves(b.params),
    ):
        np.testing.assert_allclose(
            np.asarray(la), np.asarray(lb), rtol=1e-5, atol=1e-5,
            err_msg=f"param {jax.tree_util.keystr(path)} diverged",
        )
    la = np.array([h["loss"] for h in a.history])
    lb = np.array([h["loss"] for h in b.history])
    np.testing.assert_allclose(la, lb, rtol=1e-5, atol=1e-5)
    assert a.comm_total_bytes == b.comm_total_bytes
    assert a.comm_fnu_bytes == b.comm_fnu_bytes
    assert a.comp_total_flops == b.comp_total_flops
    assert a.comp_fnu_flops == b.comp_fnu_flops


# -- degenerate-config equivalence (the acceptance bar) ---------------------


@pytest.mark.parametrize("algo", ["fedavg", "fedprox"])
@pytest.mark.parametrize("engine", ["vmap", "shard_map"])
def test_async_degenerate_matches_sync(setup, algo, engine):
    """Full participation, perfect fleet, goal = cohort, exponent 0: the
    event-driven path must reproduce the synchronous barrier loop on both
    batched backends, full + partial rounds."""
    sync = _run(setup, algo, engine, "sync")
    asy = _run(setup, algo, engine, "async")
    _assert_equivalent(sync, asy)
    assert asy.timeline is not None
    # one barrier merge per schedule entry, nothing stale, nothing dropped
    assert len(asy.timeline.of_kind("merge")) == len(MIXED)
    assert all(h["staleness_max"] == 0 for h in asy.history)
    assert not asy.timeline.of_kind("drop")


def test_async_degenerate_matches_sync_sequential_engine(setup):
    sync = _run(setup, "fedavg", "sequential", "sync")
    asy = _run(setup, "fedavg", "sequential", "async")
    _assert_equivalent(sync, asy)


def test_async_degenerate_matches_sync_partial_participation(setup):
    """sample_fraction < 1: with a perfect fleet the async cohort sampler
    consumes the selection RNG exactly like the sync server, so partial
    participation is degenerate-equivalent too."""
    rounds = FNUSchedule(2).rounds()
    sync = _run(setup, "fedavg", "vmap", "sync", rounds=rounds,
                sample_fraction=0.67)
    asy = _run(setup, "fedavg", "vmap", "async", rounds=rounds,
               sample_fraction=0.67)
    _assert_equivalent(sync, asy)


def test_async_sync_policy_is_barrier_oracle(setup):
    """The explicit 'sync' policy (barrier per cohort) is degenerate-
    equivalent as well — FedBuff with goal=cohort and the barrier oracle
    coincide on a perfect fleet."""
    asy_buff = _run(setup, "fedavg", "vmap", "async")
    asy_sync = _run(setup, "fedavg", "vmap", "async", async_policy="sync")
    _assert_equivalent(asy_buff, asy_sync)


# -- non-degenerate behavior ------------------------------------------------


# seed picked so stragglers actually overlap merges within the 5-round
# horizon below (staleness > 0 occurs; deterministic given the seed)
HETERO = AvailabilityConfig(speed_spread=3.0, latency_jitter=0.3, seed=5)


def test_async_heterogeneous_staleness_and_determinism(setup):
    """K=1 on a heterogeneous fleet: the schedule advances while stragglers
    are in flight, so staleness must actually occur; and the whole event
    simulation is seed-deterministic."""
    rounds = FedPartSchedule(num_groups=6, warmup_rounds=1, rounds_per_layer=1,
                             cycles=1).rounds()[:5]
    kw = dict(rounds=rounds, availability=HETERO, buffer_k=1,
              staleness_exponent=0.5, sample_fraction=0.67)
    a = _run(setup, "fedavg", "vmap", "async", **kw)
    assert max(h["staleness_max"] for h in a.history) >= 1
    assert a.timeline.total_seconds > 0.0
    # merges advance the virtual clock monotonically
    ts = [e["t"] for e in a.timeline.of_kind("merge")]
    assert ts == sorted(ts)
    b = _run(setup, "fedavg", "vmap", "async", **kw)
    assert [h["loss"] for h in a.history] == [h["loss"] for h in b.history]
    assert [h["t"] for h in a.history] == [h["t"] for h in b.history]


def test_async_dropout_burns_compute_but_never_merges(setup):
    rounds = FNUSchedule(3).rounds()
    a = _run(setup, "fedavg", "vmap", "async", rounds=rounds,
             availability=AvailabilityConfig(dropout_prob=0.5, seed=11))
    drops = a.timeline.of_kind("drop")
    assert drops, "dropout_prob=0.5 over 3 cohorts should drop something"
    assert all(e["comp_flops"] > 0 for e in drops)
    merged = sum(h["merged"] for h in a.history)
    completes = len(a.timeline.of_kind("complete"))
    assert merged == completes  # every delivered update merged, no drop did
    assert len(a.history) == len(rounds)


def test_async_rejects_stepsize_tracking(setup):
    adapter, clients, eval_set = setup
    cfg = FLRunConfig(runtime="async", track_stepsizes=True)
    with pytest.raises(ValueError, match="sync"):
        run_federated(adapter, clients, eval_set, FNUSchedule(1).rounds(), cfg)


def test_unknown_runtime_and_policy_rejected(setup):
    adapter, clients, eval_set = setup
    with pytest.raises(ValueError, match="unknown runtime"):
        run_federated(adapter, clients, eval_set, FNUSchedule(1).rounds(),
                      FLRunConfig(runtime="threads"))
    with pytest.raises(ValueError, match="unknown policy"):
        run_federated(adapter, clients, eval_set, FNUSchedule(1).rounds(),
                      FLRunConfig(runtime="async", async_policy="fifo"))


# -- host-parallel dispatch (max_inflight_cohorts) --------------------------


def test_inflight_default_is_single_and_validated(setup):
    """The knob defaults to the merge-driven regime, and nonsense rejects."""
    assert FLRunConfig().max_inflight_cohorts == 1
    adapter, clients, eval_set = setup
    cfg = FLRunConfig(runtime="async", max_inflight_cohorts=0)
    with pytest.raises(ValueError, match="max_inflight_cohorts"):
        run_federated(adapter, clients, eval_set, FNUSchedule(1).rounds(), cfg)


def test_merge_driven_dispatches_at_every_merge(setup):
    """max_inflight=1 is the merge-driven regime: every merge dispatches the
    next cohort, even when an earlier cohort hasn't delivered its first
    update yet (a straggler-triggered merge right after a dispatch).  Gating
    that dispatch on the in-flight count skips merges — this config then
    dispatches only 3 cohorts for 5 rounds."""
    rounds = FedPartSchedule(num_groups=6, warmup_rounds=1, rounds_per_layer=1,
                             cycles=1).rounds()[:5]
    a = _run(setup, "fedavg", "vmap", "async", rounds=rounds,
             availability=HETERO, buffer_k=1, staleness_exponent=0.5,
             sample_fraction=0.67)
    assert len(a.timeline.of_kind("dispatch")) == len(rounds)


def test_inflight2_degenerate_full_participation_matches_sync(setup):
    """Full participation leaves no idle clients to feed a second cohort, so
    inflight=2 degenerates to the merge-driven path — and therefore to the
    synchronous loop (the dispatch semantics depend only on virtual events,
    never on the host's device count)."""
    sync = _run(setup, "fedavg", "vmap", "sync")
    asy2 = _run(setup, "fedavg", "vmap", "async", max_inflight_cohorts=2)
    _assert_equivalent(sync, asy2)


def test_inflight2_heterogeneous_engine_equivalent_and_deterministic(setup):
    """With idle capacity, inflight=2 genuinely overlaps cohorts in virtual
    time; the event sequence is engine-independent (the engines only decide
    *where* a cohort's compiled program runs) and seed-deterministic."""
    rounds = FedPartSchedule(num_groups=6, warmup_rounds=1, rounds_per_layer=1,
                             cycles=1).rounds()[:4]
    kw = dict(rounds=rounds, availability=HETERO, buffer_k=1,
              staleness_exponent=0.5, sample_fraction=0.34,
              max_inflight_cohorts=2)
    vm = _run(setup, "fedavg", "vmap", "async", **kw)
    sq = _run(setup, "fedavg", "sequential", "async", **kw)
    _assert_equivalent(vm, sq)
    again = _run(setup, "fedavg", "vmap", "async", **kw)
    assert [h["loss"] for h in vm.history] == [h["loss"] for h in again.history]
    assert [h["t"] for h in vm.history] == [h["t"] for h in again.history]
    assert len(vm.history) == len(rounds)


def test_inflight2_books_overlap_and_occupancy(setup):
    """The timeline must show the overlap inflight>1 exists to create:
    cohort spans carry submesh bindings, the occupancy roll-up is recorded,
    and concurrent spans actually occur."""
    rounds = FedPartSchedule(num_groups=6, warmup_rounds=1, rounds_per_layer=1,
                             cycles=1).rounds()[:4]
    one = _run(setup, "fedavg", "vmap", "async", rounds=rounds,
               availability=HETERO, buffer_k=1, sample_fraction=0.34)
    two = _run(setup, "fedavg", "vmap", "async", rounds=rounds,
               availability=HETERO, buffer_k=1, sample_fraction=0.34,
               max_inflight_cohorts=2)
    assert two.timeline.overlap_seconds() > one.timeline.overlap_seconds()
    assert two.timeline.total_seconds < one.timeline.total_seconds
    spans = two.timeline.cohort_spans()
    assert spans and all(e >= s for _, s, e in spans)
    occ = two.timeline.of_kind("occupancy")
    assert len(occ) == 1
    # every *launched* cohort is booked (a cohort still queued when the run
    # ends is dispatched in the timeline but never launched)
    assert 0 < occ[0]["cohorts"] <= len(spans)
    assert occ[0]["max_concurrency"] >= 2
    assert occ[0]["overlap_seconds"] > 0.0
    # more cohorts were dispatched than the merge-driven run needed
    assert len(spans) >= len(one.timeline.cohort_spans())


# -- adaptive server control loop (runtime/control.py, docs/CONTROL.md) -----


def test_controller_static_default_is_structurally_absent():
    """controller="static" (the default) builds no controller object — the
    None seam, like compression="none" — and nonsense names reject."""
    assert FLRunConfig().controller == "static"
    assert make_controller(FLRunConfig()) is None
    with pytest.raises(ValueError, match="unknown controller"):
        make_controller(FLRunConfig(controller="pid"))
    with pytest.raises(ValueError, match="controller_window"):
        make_controller(FLRunConfig(controller="adaptive",
                                    controller_window=0))


def test_controller_static_bit_identical_and_uninstrumented(setup):
    """The explicit static config reproduces the default async path
    *bitwise* (params, histories, books) and records no control events."""
    kw = dict(rounds=MIXED, availability=HETERO, buffer_k=1,
              staleness_exponent=0.5, sample_fraction=0.67)
    base = _run(setup, "fedavg", "vmap", "async", **kw)
    explicit = _run(setup, "fedavg", "vmap", "async", controller="static",
                    **kw)
    for a, b in zip(jax.tree.leaves(base.params),
                    jax.tree.leaves(explicit.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert base.history == explicit.history
    assert base.comm_total_bytes == explicit.comm_total_bytes
    assert not base.timeline.of_kind("control")


def test_controller_adaptive_degenerate_bounds_match_static(setup):
    """Adaptive with every actuator pinned (inflight bounds (1,1), buffer
    bounds at the configured K, exponent 0, zero repeats) must walk the
    static trajectory bitwise — the controller observes but can't move."""
    kw = dict(rounds=MIXED, availability=HETERO, buffer_k=1,
              staleness_exponent=0.0, sample_fraction=0.67)
    static = _run(setup, "fedavg", "vmap", "async", **kw)
    frozen = _run(setup, "fedavg", "vmap", "async", controller="adaptive",
                  controller_inflight_bounds=(1, 1),
                  controller_buffer_bounds=(1, 1),
                  controller_max_repeats=0, **kw)
    for a, b in zip(jax.tree.leaves(static.params),
                    jax.tree.leaves(frozen.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert [h["loss"] for h in static.history] == \
        [h["loss"] for h in frozen.history]


def test_controller_adaptive_engine_independent_and_deterministic(setup):
    """Adaptive decisions are virtual-event-only, so the controlled run is
    engine-independent (vmap vs the sequential oracle) and replays exactly
    under the same seed; the run completes every scheduled merge."""
    rounds = FedPartSchedule(num_groups=6, warmup_rounds=1, rounds_per_layer=1,
                             cycles=1).rounds()[:5]
    kw = dict(rounds=rounds, availability=HETERO, buffer_k=1,
              staleness_exponent=0.5, sample_fraction=0.34,
              controller="adaptive", controller_window=2)
    vm = _run(setup, "fedavg", "vmap", "async", **kw)
    sq = _run(setup, "fedavg", "sequential", "async", **kw)
    _assert_equivalent(vm, sq)
    assert ([e["note"] for e in vm.timeline.of_kind("control")]
            == [e["note"] for e in sq.timeline.of_kind("control")])
    again = _run(setup, "fedavg", "vmap", "async", **kw)
    assert [h["loss"] for h in vm.history] == [h["loss"] for h in again.history]
    assert [h["t"] for h in vm.history] == [h["t"] for h in again.history]
    assert len(vm.history) == len(rounds)


def test_controller_adaptive_grows_inflight_on_stragglers(setup):
    """On a straggling fleet with idle capacity, the inflight controller
    must actually grow the in-flight target (a control event says so) and
    the run must finish sooner on the virtual clock than static."""
    rounds = FedPartSchedule(num_groups=6, warmup_rounds=1, rounds_per_layer=1,
                             cycles=1).rounds()[:5]
    kw = dict(rounds=rounds, availability=HETERO, buffer_k=1,
              staleness_exponent=0.5, sample_fraction=0.34)
    static = _run(setup, "fedavg", "vmap", "async", **kw)
    adaptive = _run(setup, "fedavg", "vmap", "async", controller="adaptive",
                    controller_window=2, **kw)
    controls = adaptive.timeline.of_kind("control")
    assert any(e["max_inflight"] > 1 for e in controls)
    assert adaptive.timeline.total_seconds < static.timeline.total_seconds
    # overridden groups are booked in the ledgers as actually trained
    assert adaptive.comm_total_bytes > 0


def _win(events, t_start=0.0, t_end=None):
    te = (t_end if t_end is not None
          else max((e["t"] for e in events), default=0.0))
    return TimelineWindow(t_start=t_start, t_end=te, events=list(events))


def test_inflight_controller_hill_climbs():
    c = AdaptiveInflightController(bounds=(1, 4), current=1)
    busy = _win([{"t": 0.0, "kind": "dispatch", "t_end": 2.0},
                 {"t": 2.0, "kind": "merge", "version": 0, "group": 0,
                  "loss": 1.0}])
    adj = c.observe(busy)                      # util 1.0 => grow
    assert adj.max_inflight == 2 and c.current == 2
    idle = _win([{"t": 4.0, "kind": "merge", "version": 1, "group": 0,
                  "loss": 1.0}], t_start=2.0)
    adj = c.observe(idle)                      # util 0.0 => shrink
    assert adj.max_inflight == 1 and c.current == 1
    assert not c.observe(idle)                 # clamped at lo: no-op
    assert not c.observe(_win([]))             # empty window: no-op
    with pytest.raises(ValueError, match="bounds"):
        AdaptiveInflightController(bounds=(0, 4), current=1)


def test_staleness_buffer_controller_defends_mix_floor():
    mk = lambda stale: _win(  # noqa: E731
        [{"t": 1.0, "kind": "complete", "client": 0, "staleness": stale},
         {"t": 1.0, "kind": "merge", "version": 0, "group": 0, "loss": 1.0}])
    c = StalenessBufferController(exponent=1.0, bounds=(1, 8), current=2)
    adj = c.observe(mk(3))                     # mix 0.25 < 0.5 => K up
    assert adj.buffer_k == 3 and c.current == 3
    adj = c.observe(mk(0))                     # mix 1.0 >= floor+slack => down
    assert adj.buffer_k == 2 and c.current == 2
    c0 = StalenessBufferController(exponent=0.0, bounds=(1, 8), current=2)
    assert not c0.observe(mk(5))               # exponent 0: discount never bites
    assert not c.observe(_win([]))             # nothing delivered: no-op


def test_progress_group_controller_repeats_bounded():
    c = ProgressGroupController(max_repeats=1)
    improving = _win(
        [{"t": 1.0, "kind": "merge", "version": 0, "group": 2, "loss": 2.0},
         {"t": 2.0, "kind": "merge", "version": 1, "group": 2, "loss": 1.5}])
    adj = c.observe(improving)
    assert adj.group_override == 2             # still paying: repeat
    assert not c.observe(improving)            # consecutive-repeat cap hit
    assert c.observe(improving).group_override == 2   # cap resets after a skip
    fnu = _win(
        [{"t": 1.0, "kind": "merge", "version": 0, "group": 2, "loss": 2.0},
         {"t": 2.0, "kind": "merge", "version": 1, "group": -1, "loss": 1.0}])
    assert not c.observe(fnu)                  # FNU rounds follow the schedule
    worse = _win(
        [{"t": 1.0, "kind": "merge", "version": 0, "group": 2, "loss": 1.0},
         {"t": 2.0, "kind": "merge", "version": 1, "group": 2, "loss": 1.4}])
    assert not c.observe(worse)                # regressing: advance
    single = _win([{"t": 1.0, "kind": "merge", "version": 0, "group": 2,
                    "loss": 1.0}])
    assert not c.observe(single)               # one merge: no evidence yet


def test_policy_adjustment_merge_and_truthiness():
    noop = PolicyAdjustment()
    assert not noop
    a = PolicyAdjustment(max_inflight=2, note="a")
    b = PolicyAdjustment(buffer_k=3, note="b")
    ab = a.merged(b)
    assert (ab.max_inflight, ab.buffer_k, ab.note) == (2, 3, "a; b")
    assert ab and noop.merged(noop).note == ""


def test_schedule_index_override_group():
    rounds = FedPartSchedule(num_groups=3, warmup_rounds=1, rounds_per_layer=1,
                             cycles=1).rounds()
    idx = ScheduleIndex.from_rounds(rounds)
    spec = idx.override_group(2, 0)
    assert idx.for_version(2) is spec
    assert (spec.group, spec.index, spec.phase) == (0, 2, "partial")
    assert idx.for_version(1) == rounds[1]          # others untouched
    # overrides never perturb index identity semantics (excluded from eq)
    assert idx == ScheduleIndex.from_rounds(rounds)
    # re-pinning a full round keeps the base phase
    fnu = idx.override_group(0, -1)
    assert fnu.phase == "warmup" and fnu.group == -1


# -- policy unit semantics --------------------------------------------------


def _tiny_partitioned():
    params = {
        "layer1": {"w": jnp.full((2,), 1.0)},
        "layer2": {"w": jnp.full((2,), 2.0)},
        "head": {"w": jnp.full((2,), 3.0)},
    }
    return params, build_partition(params)


def _upd(part, params, group, value, *, version, weight=1.0):
    from repro.core import masking
    base = params if group < 0 else masking.select(params, part, group)
    sub = jax.tree.map(lambda x: jnp.full_like(x, value), base)
    return ClientUpdate(client_id=0, version=version, group=group,
                        subtree=sub, weight=weight, loss=0.0, dispatched_t=0.0)


def test_merge_mixed_groups_splice_current_context():
    """A buffer holding updates for different layer groups (the FedPart-
    specific case): each averaged subtree splices into the current model;
    untouched groups keep the current — not any historical — values."""
    params, part = _tiny_partitioned()
    pol = FedBuffPolicy(partition=part)
    ups = [_upd(part, params, 0, 10.0, version=0),
           _upd(part, params, 1, 20.0, version=1)]
    new, info = pol.merge(params, ups, version=2)
    np.testing.assert_allclose(np.asarray(new["layer1"]["w"]), 10.0)
    np.testing.assert_allclose(np.asarray(new["layer2"]["w"]), 20.0)
    np.testing.assert_allclose(np.asarray(new["head"]["w"]), 3.0)  # untouched
    assert info["merged"] == 2 and info["staleness_max"] == 2
    assert info["groups"] == {0: 1, 1: 1}


def test_merge_full_and_partial_order_independent():
    """A FULL_NETWORK update sharing the buffer with a partial-group update
    (a straggling warmup/bridge round under FedBuff): the full tree merges
    first and the targeted subtree splices on top, whichever arrived first —
    the partial update is never wiped by a later full splice."""
    params, part = _tiny_partitioned()
    pol = FedBuffPolicy(partition=part)
    g0 = _upd(part, params, 0, 10.0, version=1)
    full = _upd(part, params, -1, 7.0, version=0)
    for ups in ([g0, full], [full, g0]):
        new, _ = pol.merge(params, ups, version=1)
        np.testing.assert_allclose(np.asarray(new["layer1"]["w"]), 10.0)
        np.testing.assert_allclose(np.asarray(new["layer2"]["w"]), 7.0)
        np.testing.assert_allclose(np.asarray(new["head"]["w"]), 7.0)
    # Stale full + fresh partial with discounting: the partial group's mixing
    # context is the progressively-merged model (post full merge), and the
    # fresh partial replaces it outright.
    pol1 = FedBuffPolicy(partition=part, staleness_exponent=1.0)
    new, _ = pol1.merge(params, [g0, full], version=1)  # full stale 1 => m=1/2
    np.testing.assert_allclose(np.asarray(new["layer1"]["w"]), 10.0)
    np.testing.assert_allclose(np.asarray(new["layer2"]["w"]), 4.5)  # (2+7)/2


def test_merge_staleness_mixing_polynomial():
    """Exponent a: a single update of staleness s merges with coefficient
    m=(1+s)^-a against the current value — exponent 0 is pure replacement."""
    params, part = _tiny_partitioned()
    # fresh (exponent irrelevant): replacement
    pol0 = FedBuffPolicy(partition=part, staleness_exponent=1.0)
    new, _ = pol0.merge(params, [_upd(part, params, 0, 9.0, version=4)],
                        version=4)
    np.testing.assert_allclose(np.asarray(new["layer1"]["w"]), 9.0)
    # staleness 1, a=1 => m=0.5: halfway between current (1.0) and update (9.0)
    new, info = pol0.merge(params, [_upd(part, params, 0, 9.0, version=3)],
                           version=4)
    np.testing.assert_allclose(np.asarray(new["layer1"]["w"]), 5.0)
    assert info["staleness_mean"] == 1.0
    # exponent 0: stale or not, replacement (degenerate-config arithmetic)
    pol_a0 = FedBuffPolicy(partition=part, staleness_exponent=0.0)
    new, _ = pol_a0.merge(params, [_upd(part, params, 0, 9.0, version=0)],
                          version=4)
    np.testing.assert_allclose(np.asarray(new["layer1"]["w"]), 9.0)


def test_merge_intra_buffer_staleness_weighting():
    """Two same-group updates, one stale: the stale one's relative weight is
    discounted by (1+s)^-a inside the average."""
    params, part = _tiny_partitioned()
    pol = FedBuffPolicy(partition=part, staleness_exponent=1.0)
    ups = [_upd(part, params, 0, 0.0, version=2),    # fresh, scale 1
           _upd(part, params, 0, 8.0, version=0)]    # stale 2, scale 1/3
    new, _ = pol.merge(params, ups, version=2)
    # avg = (1*0 + 1/3*8)/(4/3) = 2; m = (4/3)/2 = 2/3 => 1/3*1 + 2/3*2
    np.testing.assert_allclose(np.asarray(new["layer1"]["w"]), 1 / 3 + 4 / 3,
                               rtol=1e-6)


def test_policy_goal_and_should_merge():
    _, part = _tiny_partitioned()
    fb = FedBuffPolicy(partition=part, buffer_goal=3)
    assert fb.goal(cohort_size=8) == 3
    assert not fb.should_merge(2, pending=5, cohort_size=8)
    assert fb.should_merge(3, pending=5, cohort_size=8)
    assert fb.should_merge(1, pending=0, cohort_size=8)  # starvation guard
    fb0 = FedBuffPolicy(partition=part)                  # K=0 => cohort size
    assert fb0.goal(cohort_size=5) == 5
    sy = SyncFedAvgPolicy(partition=part)
    assert not sy.should_merge(4, pending=1, cohort_size=5)
    assert sy.should_merge(4, pending=0, cohort_size=5)
    assert not sy.should_merge(0, pending=0, cohort_size=5)
    with pytest.raises(ValueError, match="unknown policy"):
        make_policy("fifo", part)


def test_staleness_scale_formula():
    _, part = _tiny_partitioned()
    pol = FedBuffPolicy(partition=part, staleness_exponent=0.5)
    assert pol.staleness_scale(0) == 1.0
    np.testing.assert_allclose(pol.staleness_scale(3), 0.5)
    pol0 = FedBuffPolicy(partition=part, staleness_exponent=0.0)
    assert pol0.staleness_scale(10_000) == 1.0
    with pytest.raises(ValueError):
        pol.staleness_scale(-1)


# -- schedule-by-version lookup --------------------------------------------


def test_schedule_index_clamps_and_stales():
    rounds = FedPartSchedule(num_groups=3, warmup_rounds=1, rounds_per_layer=1,
                             cycles=1).rounds()
    idx = ScheduleIndex.from_rounds(rounds)
    assert len(idx) == len(rounds)
    assert idx.for_version(0).phase == "warmup"
    assert idx.for_version(1).group == 0
    # past-the-end versions clamp to the final spec (late dispatch drain)
    assert idx.for_version(10_000) == rounds[-1]
    assert ScheduleIndex.staleness(5, 2) == 3
    assert ScheduleIndex.staleness(2, 5) == 0
    with pytest.raises(ValueError):
        idx.for_version(-1)
    with pytest.raises(ValueError):
        ScheduleIndex.from_rounds([])


# -- availability model -----------------------------------------------------


def test_availability_degenerate_consumes_no_randomness():
    av = ClientAvailability(AvailabilityConfig(), 8)
    state = av._rng.bit_generator.state
    assert all(av.arrival_ok(ci, 0.0) for ci in range(8))
    assert av.jitter() == 1.0 and not av.drops()
    assert av._rng.bit_generator.state == state  # untouched stream
    np.testing.assert_array_equal(av.speeds, np.ones(8))


def test_availability_seeded_and_bounded():
    cfg = AvailabilityConfig(speed_spread=3.0, latency_jitter=0.5,
                             dropout_prob=0.3, unavailable_prob=0.4, seed=5)
    a, b = ClientAvailability(cfg, 16), ClientAvailability(cfg, 16)
    np.testing.assert_array_equal(a.speeds, b.speeds)
    assert ((a.speeds >= 1 / 4.0) & (a.speeds <= 4.0)).all()
    assert [a.jitter() for _ in range(5)] == [b.jitter() for _ in range(5)]
    assert [a.drops() for _ in range(20)] == [b.drops() for _ in range(20)]
    assert ([a.arrival_ok(ci, 0.0) for ci in range(16)]
            == [b.arrival_ok(ci, 0.0) for ci in range(16)])
    for j in (a.jitter() for _ in range(10)):
        assert 1.0 <= j <= 1.5


def test_availability_config_validation():
    with pytest.raises(ValueError):
        AvailabilityConfig(dropout_prob=1.0)
    with pytest.raises(ValueError):
        AvailabilityConfig(speed_spread=-0.1)
    assert AvailabilityConfig().is_degenerate
    assert not AvailabilityConfig(latency_jitter=0.1).is_degenerate


# -- timeline ---------------------------------------------------------------


def test_timeline_time_to_accuracy():
    tl = Timeline()
    tl.record(1.0, "eval", version=0, acc=0.2)
    tl.record(3.0, "eval", version=1, acc=0.5)
    tl.record(2.0, "eval", version=2, acc=0.4)   # out-of-order insert
    assert tl.time_to_accuracy(0.1) == 1.0
    assert tl.time_to_accuracy(0.45) == 3.0
    assert tl.time_to_accuracy(0.9) == float("inf")
    assert tl.accuracy_curve() == [(1.0, 0.2), (2.0, 0.4), (3.0, 0.5)]
    assert tl.total_seconds == 3.0
