import jax
import numpy as np
import pytest

from repro.core import masking
from repro.core.partition import (
    build_partition,
    group_param_bytes,
    group_param_counts,
    total_param_count,
)
from repro.models import resnet


def test_default_partition_ordering(params):
    p = build_partition(params)
    # embed first, blocks in order, head last
    assert p.group_keys[0] == ("embed",)
    assert p.group_keys[-1] == ("head",)
    assert p.num_groups == 5
    assert [k for k in p.group_keys if k[0] == "block"] == [
        ("block", "blocks", 0), ("block", "blocks", 1), ("block", "blocks", 2)
    ]


def test_partition_is_exhaustive_and_disjoint(params):
    p = build_partition(params)
    counts = group_param_counts(params, p)
    assert counts.sum() == total_param_count(params)
    assert (counts > 0).all()


def test_select_complement_merge_roundtrip(params):
    p = build_partition(params)
    for g in range(p.num_groups):
        sel = masking.select(params, p, g)
        comp = masking.complement(params, p, g)
        merged = masking.merge(sel, comp)
        assert jax.tree.structure(merged) == jax.tree.structure(params)
        for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mask_matches_select(params):
    p = build_partition(params)
    mask = masking.mask_tree(params, p, [1, 3])
    sel = masking.select(params, p, [1, 3])
    n_masked = sum(int(m.sum()) for m in jax.tree.leaves(mask))
    n_sel = total_param_count(sel)
    assert n_masked == n_sel


def test_apply_mask_stacked_broadcasts_over_clients(params):
    part = build_partition(params)
    mask = masking.mask_tree(params, part, 1)
    clients = [jax.tree.map(lambda x, i=i: x + float(i), params) for i in range(3)]
    stacked = masking.stack_trees(clients)
    out = masking.apply_mask_stacked(stacked, mask)
    ref = masking.stack_trees([masking.apply_mask(c, mask) for c in clients])
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resnet8_partition_matches_paper_appendix_a():
    """Paper Appendix A: ResNet-8 has groups #1..#10 (9 conv+BN, 1 FC)."""
    p8 = resnet.resnet_init(jax.random.key(0), resnet.RESNET8, 10)
    part = build_partition(p8, resnet.resnet_group_key, resnet.resnet_order_key)
    assert part.num_groups == 10
    assert part.group_keys[-1] == ("head",)


@pytest.mark.slow
def test_resnet18_partition_group_count():
    p18 = resnet.resnet_init(jax.random.key(0), resnet.RESNET18, 10)
    part = build_partition(p18, resnet.resnet_group_key, resnet.resnet_order_key)
    # conv_in + 8 blocks x 2 convs + fc = 18 groups
    assert part.num_groups == 18


def test_group_bytes_accounting(params):
    p = build_partition(params)
    gb = group_param_bytes(params, p)
    total = sum(
        np.prod(np.shape(leaf)) * np.dtype(leaf.dtype).itemsize
        for leaf in jax.tree.leaves(params)
    )
    assert gb.sum() == total
