"""FedPart mesh trainer: rounds cycle groups, loss improves, the comm ledger
matches the schedule."""

import jax
import pytest

from repro.configs import get_config
from repro.core.schedule import FULL_NETWORK, FedPartSchedule, RoundSpec
from repro.launch.fedtrain import FedPartMeshTrainer
from repro.models import api
from repro.models.api import InputShape
from repro.optim.adam import AdamConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = api.init(jax.random.key(0), cfg)
    trainer = FedPartMeshTrainer(cfg, AdamConfig(lr=2e-3))
    shape = InputShape("t", 16, 2, "train")
    batch = api.synth_batch(jax.random.key(1), cfg, shape)
    return cfg, params, trainer, batch


def test_rounds_cycle_and_learn(setup):
    cfg, params, trainer, batch = setup
    n = len(trainer.groups(params))
    sched = FedPartSchedule(num_groups=n, warmup_rounds=1, rounds_per_layer=1,
                            cycles=1)
    losses = []
    for spec in sched.rounds()[: n + 1]:
        params, loss = trainer.run_round(params, spec, [batch, batch])
        losses.append(loss)
    assert losses[-1] < losses[0]          # same batch -> must improve


def test_force_sim_devices_flag_forms(monkeypatch):
    """The pre-jax-import sniffer must accept both '--sim-devices N' and
    '--sim-devices=N', and leave malformed argv for argparse to reject."""
    import os

    from repro.launch._simdev import force_sim_devices

    for argv in (["--sim-devices", "4"], ["--sim-devices=4"]):
        monkeypatch.delenv("XLA_FLAGS", raising=False)
        force_sim_devices(argv)
        assert os.environ["XLA_FLAGS"] == \
            "--xla_force_host_platform_device_count=4"
    monkeypatch.setenv("XLA_FLAGS", "--existing")
    force_sim_devices(["--sim-devices", "2"])
    assert os.environ["XLA_FLAGS"] == \
        "--existing --xla_force_host_platform_device_count=2"
    # no-ops: N<=1, missing value, non-numeric value (argparse's job)
    for argv in (["--sim-devices", "1"], ["--sim-devices"],
                 ["--sim-devices", "lots"], []):
        monkeypatch.delenv("XLA_FLAGS", raising=False)
        force_sim_devices(argv)
        assert "XLA_FLAGS" not in os.environ


def test_transmission_ledger(setup):
    cfg, params, trainer, _ = setup
    full = trainer.transmitted_params(params, RoundSpec(0, "warmup", -1, FULL_NETWORK))
    total = sum(x.size for x in jax.tree.leaves(params))
    assert full == total
    partial = trainer.transmitted_params(params, RoundSpec(1, "partial", 0, 1))
    assert 0 < partial < total // 2
    # all groups together cover the full model exactly once
    n = len(trainer.groups(params))
    s = sum(trainer.transmitted_params(params, RoundSpec(i, "partial", 0, i))
            for i in range(n))
    assert s == total
