"""Per-client layer plans: PlanAssigner + per-group participant-weighted
aggregation (docs/HETEROGENEITY.md).

Property tests (hypothesis when available, seeded deterministic cases always)
pin the three aggregation invariants the heterogeneity refactor rests on:

* per-group denominators sum **exactly** the weights of the clients whose
  plan bit is set (integer-valued weights, so float summation order cannot
  blur "exactly");
* a group nobody trained is **bit-identical** to the frozen global;
* a homogeneous plan reproduces today's single-group aggregation
  **bit-for-bit** (the legacy paths are a special case of the plan path,
  not a parallel implementation).

The async policy's per-(client, group) merge is pinned against the same
arithmetic.  Engine-level equivalence under heterogeneous plans lives in
tests/test_engine_equivalence.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, masking
from repro.core.partition import build_partition
from repro.core.schedule import (FULL_NETWORK, PlanAssigner, RoundSpec)
from tests.conftest import small_params

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAS_HYPOTHESIS = False

PARAMS = small_params()
PART = build_partition(PARAMS)
M = PART.num_groups

PARTIAL = RoundSpec(3, "partial", 0, 2)
FNU = RoundSpec(0, "warmup", -1, FULL_NETWORK)


def _client_trees(n, seed):
    rng = np.random.default_rng(seed)
    return [jax.tree.map(
        lambda x: x + jnp.asarray(rng.normal(0, 0.1, x.shape), x.dtype),
        PARAMS) for _ in range(n)]


def _random_plan(n, rng):
    """Random (n, M) bool plan, every row non-empty."""
    plan = rng.random((n, M)) < 0.4
    for i in range(n):
        if not plan[i].any():
            plan[i, rng.integers(0, M)] = True
    return plan


# ---------------------------------------------------------------------------
# PlanAssigner
# ---------------------------------------------------------------------------


def test_homogeneous_assigns_none():
    pa = PlanAssigner(num_groups=M)          # default kind, default tier
    assert pa.assign(PARTIAL, [0, 1, 2]) is None
    assert pa.assign(FNU, [0, 1]) is None


def test_full_capacity_tiers_reproduce_round_mask():
    """nested with every tier at 1.0 == the homogeneous round mask."""
    pa = PlanAssigner(num_groups=M, kind="nested", capacity_tiers=(1.0, 1.0))
    plan = pa.assign(PARTIAL, [0, 1, 2])
    assert (plan == pa.base_mask(PARTIAL)[None, :]).all()
    assert pa.assign(FNU, [0, 1]).all()


def test_nested_prefixes_and_clamping():
    pa = PlanAssigner(num_groups=M, kind="nested", capacity_tiers=(0.4, 1.0))
    # tier 0 holds ceil(0.4*5)=2 groups, tier 1 all 5
    assert pa.prefix_len(0) == 2 and pa.prefix_len(1) == M
    fnu = pa.assign(FNU, [0, 1])
    assert fnu[0].astype(int).tolist() == [1, 1, 0, 0, 0]
    assert fnu[1].all()
    # partial round for a group beyond tier 0's prefix clamps to its deepest
    part = pa.assign(RoundSpec(1, "partial", 0, 4), [0, 1])
    assert part[0].astype(int).tolist() == [0, 1, 0, 0, 0]
    assert part[1].astype(int).tolist() == [0, 0, 0, 0, 1]
    # within the prefix the schedule is followed verbatim
    part = pa.assign(RoundSpec(1, "partial", 0, 1), [0, 1])
    assert (part == pa.base_mask(RoundSpec(1, "partial", 0, 1))[None, :]).all()


def test_random_plans_deterministic_and_cohort_independent():
    pa = PlanAssigner(num_groups=M, kind="random",
                      capacity_tiers=(0.4, 0.8), seed=7)
    a = pa.assign(PARTIAL, [0, 1, 2, 3])
    b = pa.assign(PARTIAL, [0, 1, 2, 3])
    np.testing.assert_array_equal(a, b)
    # a client's draw is a function of (seed, round, client) only — not of
    # who else is in the cohort (engines may dispatch different cohorts)
    solo = pa.assign(PARTIAL, [2])
    np.testing.assert_array_equal(a[2], solo[0])
    # rows are never empty and respect the tier budget
    assert a.any(axis=1).all()
    for i, ci in enumerate([0, 1, 2, 3]):
        assert a[i].sum() == pa.prefix_len(ci)
    # a different round redraws
    c = pa.assign(RoundSpec(4, "partial", 0, 2), [0, 1, 2, 3])
    assert not (a == c).all()


def test_assigner_validation():
    with pytest.raises(ValueError, match="plan kind"):
        PlanAssigner(num_groups=M, kind="prefix")
    with pytest.raises(ValueError, match="capacity tiers"):
        PlanAssigner(num_groups=M, kind="nested", capacity_tiers=(0.0, 1.0))
    with pytest.raises(ValueError, match="capacity tiers"):
        PlanAssigner(num_groups=M, kind="nested", capacity_tiers=(1.5,))
    # empty tier tuple falls back to the single full-capacity tier
    assert PlanAssigner(num_groups=M, kind="nested").capacity_tiers == (1.0,)


def test_resolve_plan_collapses_homogeneous_and_validates():
    from repro.fl.batched import resolve_plan

    base = np.zeros((3, M), dtype=bool)
    base[:, PARTIAL.group] = True
    assert resolve_plan(base, PARTIAL, M) is None
    assert resolve_plan(np.ones((3, M), bool), FNU, M) is None
    assert resolve_plan(None, PARTIAL, M) is None
    hetero = base.copy()
    hetero[0, PARTIAL.group] = False
    hetero[0, 0] = True
    assert resolve_plan(hetero, PARTIAL, M) is not None
    with pytest.raises(ValueError, match="at least one group"):
        resolve_plan(np.zeros((2, M), bool), PARTIAL, M)
    with pytest.raises(ValueError, match="does not match"):
        resolve_plan(np.ones((2, M + 1), bool), PARTIAL, M)


# ---------------------------------------------------------------------------
# Aggregation properties (the helpers; hypothesis + seeded deterministic)
# ---------------------------------------------------------------------------


def _check_denominators_exact(plan, int_weights):
    """Group denominators == the exact sum of participant weights."""
    denom = aggregation.plan_group_denominators(plan, int_weights)
    for g in range(plan.shape[1]):
        assert denom[g] == sum(int(w) for w, bit in zip(int_weights, plan[:, g])
                               if bit), g


def _check_zero_participant_frozen(plan, weights, clients):
    """Leaves of a zero-trainer group survive bit-identical."""
    stacked = masking.stack_trees(clients)
    out = aggregation.aggregate_plan_stacked(PARAMS, stacked, PART, plan, weights)
    denom = aggregation.plan_group_denominators(plan, weights)
    checked = 0
    for (path, leaf), orig in zip(
            jax.tree_util.tree_flatten_with_path(out)[0],
            jax.tree.leaves(PARAMS)):
        ps = "/".join(str(getattr(k, "key", k)) for k in path)
        if denom[PART.group_of(ps)] == 0 or aggregation.is_local_stat(ps):
            assert np.asarray(leaf).tobytes() == np.asarray(orig).tobytes(), ps
            checked += 1
    return checked


def _check_host_stacked_agree(plan, weights, clients):
    host = aggregation.aggregate_plan(PARAMS, clients, PART, plan, weights)
    dev = aggregation.aggregate_plan_stacked(
        PARAMS, masking.stack_trees(clients), PART, plan, weights)
    for a, b in zip(jax.tree.leaves(host), jax.tree.leaves(dev)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("seed", range(8))
def test_plan_aggregation_properties_seeded(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 6))
    plan = _random_plan(n, rng)
    weights = rng.integers(1, 200, n).astype(np.float32)
    clients = _client_trees(n, seed)
    _check_denominators_exact(plan, weights)
    _check_zero_participant_frozen(plan, weights, clients)
    _check_host_stacked_agree(plan, weights, clients)


def test_zero_participant_group_explicitly():
    """A plan column that is all-zero keeps that whole group frozen."""
    n = 3
    plan = np.ones((n, M), dtype=bool)
    plan[:, 1] = False
    clients = _client_trees(n, 123)
    checked = _check_zero_participant_frozen(
        plan, np.asarray([3.0, 1.0, 2.0], np.float32), clients)
    assert checked >= len(PART.paths_in(1))


def test_homogeneous_plan_bitwise_equals_legacy_aggregation():
    """One-hot plans == aggregate_partial_stacked, all-ones ==
    aggregate_full_stacked, bit-for-bit (same normalise-then-tensordot)."""
    n = 4
    clients = _client_trees(n, 11)
    stacked = masking.stack_trees(clients)
    w = np.asarray([36, 56, 40, 8], np.float32)
    for g in range(M):
        plan = np.zeros((n, M), dtype=bool)
        plan[:, g] = True
        a = aggregation.aggregate_plan_stacked(PARAMS, stacked, PART, plan, w)
        b = aggregation.aggregate_partial_stacked(PARAMS, stacked, PART, g, w)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            assert np.asarray(x).tobytes() == np.asarray(y).tobytes()
    a = aggregation.aggregate_plan_stacked(
        PARAMS, stacked, PART, np.ones((n, M), bool), w)
    b = aggregation.aggregate_full_stacked(PARAMS, stacked, w)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.asarray(x).tobytes() == np.asarray(y).tobytes()


def test_plan_aggregation_shape_guards():
    stacked = masking.stack_trees(_client_trees(2, 0))
    with pytest.raises(ValueError, match="do not match"):
        aggregation.aggregate_plan_stacked(
            PARAMS, stacked, PART, np.ones((3, M), bool), [1.0, 1.0])
    with pytest.raises(ValueError, match="client trees"):
        aggregation.aggregate_plan(
            PARAMS, _client_trees(2, 0), PART, np.ones((3, M), bool),
            [1.0, 1.0, 1.0])
    with pytest.raises(ValueError, match="mismatch"):
        aggregation.plan_group_denominators(np.ones((2, M), bool), [1.0])


if HAS_HYPOTHESIS:

    @given(data=st.data())
    @settings(max_examples=25, deadline=None)
    def test_plan_denominators_exact_property(data):
        n = data.draw(st.integers(1, 6))
        rows = data.draw(st.lists(
            st.lists(st.booleans(), min_size=M, max_size=M),
            min_size=n, max_size=n))
        plan = np.asarray(rows, dtype=bool)
        for i in range(n):              # plans never have empty rows
            if not plan[i].any():
                plan[i, 0] = True
        weights = np.asarray(
            data.draw(st.lists(st.integers(1, 10_000), min_size=n,
                               max_size=n)), np.float32)
        _check_denominators_exact(plan, weights)

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_plan_zero_participant_and_host_device_property(seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 5))
        plan = _random_plan(n, rng)
        weights = rng.integers(1, 100, n).astype(np.float32)
        clients = _client_trees(n, seed % 1000)
        _check_zero_participant_frozen(plan, weights, clients)
        _check_host_stacked_agree(plan, weights, clients)

    @given(g=st.integers(0, M - 1), seed=st.integers(0, 2**20))
    @settings(max_examples=15, deadline=None)
    def test_homogeneous_bitwise_property(g, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 5))
        clients = _client_trees(n, seed % 997)
        stacked = masking.stack_trees(clients)
        w = rng.integers(1, 100, n).astype(np.float32)
        plan = np.zeros((n, M), dtype=bool)
        plan[:, g] = True
        a = aggregation.aggregate_plan_stacked(PARAMS, stacked, PART, plan, w)
        b = aggregation.aggregate_partial_stacked(PARAMS, stacked, PART, g, w)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            assert np.asarray(x).tobytes() == np.asarray(y).tobytes()


# ---------------------------------------------------------------------------
# Async policy: per-(client, group) merge
# ---------------------------------------------------------------------------


def _plan_update(client_id, groups, tree, weight, version=0):
    from repro.fl.runtime.policy import ClientUpdate

    groups = tuple(int(g) for g in groups)
    return ClientUpdate(
        client_id=client_id, version=version, group=FULL_NETWORK,
        subtree=aggregation.drop_local_stats(
            masking.select(tree, PART, groups)),
        weight=weight, loss=0.5, dispatched_t=0.0, groups=groups)


def test_policy_merge_plan_updates_matches_aggregate_plan():
    """Exponent 0: the buffered per-(client, group) merge must equal the
    synchronous per-group participant-weighted aggregation."""
    from repro.fl.runtime.policy import make_policy

    clients = _client_trees(3, 42)
    plan = np.zeros((3, M), dtype=bool)
    plan[0, [0, 1]] = True
    plan[1, [1, 2]] = True
    plan[2, 4] = True
    w = [36.0, 56.0, 40.0]
    ups = [_plan_update(i, np.flatnonzero(plan[i]), clients[i], w[i])
           for i in range(3)]
    policy = make_policy("fedbuff", PART)
    merged, info = policy.merge(PARAMS, ups, version=0)
    want = aggregation.aggregate_plan(PARAMS, clients, PART, plan, w)
    for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)
    # group 3 had no trainer: frozen verbatim; per-group counts unbundled
    assert info["groups"] == {0: 1, 1: 2, 2: 1, 4: 1}


def test_policy_merge_full_capacity_plan_update_joins_group_denominators():
    """A full-capacity client under a plan kind carries groups=(0..M-1) —
    never the legacy FULL_NETWORK sentinel — so its contribution joins each
    group's participant-weighted average instead of dodging the denominators
    via a whole-tree splice (the async dispatch records trained group sets
    from the *raw* plan even when resolve_plan collapses the cohort's
    execution path)."""
    from repro.fl.runtime.policy import make_policy

    clients = _client_trees(2, 99)
    plan = np.zeros((2, M), dtype=bool)
    plan[0, :] = True                    # full-capacity tier: every group
    plan[1, 1] = True                    # weak tier: group 1 only
    w = [30.0, 70.0]
    ups = [_plan_update(i, np.flatnonzero(plan[i]), clients[i], w[i])
           for i in range(2)]
    merged, info = make_policy("fedbuff", PART).merge(PARAMS, ups, version=0)
    want = aggregation.aggregate_plan(PARAMS, clients, PART, plan, w)
    for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)
    # group 1 counted BOTH clients; every other group only the full one
    assert info["groups"][1] == 2
    assert all(info["groups"][g] == 1 for g in range(M) if g != 1)


def test_policy_merge_plan_staleness_is_per_client_group():
    """A stale client's *every* group contribution carries its staleness
    scale; a fresh client sharing one group dilutes it there only."""
    from repro.fl.runtime.policy import make_policy

    clients = _client_trees(2, 7)
    # client 0 (stale, version 0) trained groups {1, 2}; client 1 (fresh,
    # version 2) trained group {1}
    ups = [_plan_update(0, (1, 2), clients[0], 10.0, version=0),
           _plan_update(1, (1,), clients[1], 10.0, version=2)]
    policy = make_policy("fedbuff", PART, staleness_exponent=1.0)
    merged, _ = policy.merge(PARAMS, ups, version=2)
    s0 = policy.staleness_scale(2)                   # stale discount 1/3
    for path, leaf in jax.tree_util.tree_flatten_with_path(merged)[0]:
        ps = "/".join(str(getattr(k, "key", k)) for k in path)
        if aggregation.is_local_stat(ps):
            continue
        g = PART.group_of(ps)
        l0, l1, gl = (np.asarray(x).astype(np.float64) for x in (
            _leaf_at(clients[0], ps), _leaf_at(clients[1], ps),
            _leaf_at(PARAMS, ps)))
        if g == 1:     # both trained: staleness-weighted avg, then m-mixing
            wa, wb = 10.0 * s0, 10.0
            avg = (wa * l0 + wb * l1) / (wa + wb)
            m = (wa + wb) / 20.0
            want = (1 - m) * gl + m * avg
        elif g == 2:   # stale client alone: avg == its tree, mixed by s0
            want = (1 - s0) * gl + s0 * l0
        else:          # untouched groups stay at the current global
            want = gl
        np.testing.assert_allclose(np.asarray(leaf).astype(np.float64), want,
                                   rtol=1e-5, atol=1e-5, err_msg=ps)


def _leaf_at(tree, path_str):
    node = tree
    for k in path_str.split("/"):
        node = node[k]
    return node
