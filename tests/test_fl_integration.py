"""End-to-end FL behaviour: FedPart runs, learns, books costs correctly, and
composes with FedProx/MOON (paper Table 1 matrix).

These runs use the sequential oracle engine: on CPU the conv model's
per-client weights make the vmapped engine lower to grouped convolutions,
which XLA:CPU executes slower than the per-client loop.  The batched engine
gets its own end-to-end coverage (and the oracle-agreement pin) in
tests/test_engine_equivalence.py and benchmarks/engine_bench.py."""

import numpy as np
import pytest

from repro.core.schedule import FedPartSchedule, FNUSchedule
from repro.data import (VisionDatasetSpec, balanced_eval_set, build_clients,
                        dirichlet_partition, iid_partition, make_vision_dataset)
from repro.fl import AlgoConfig, FLRunConfig, resnet_task, run_federated


@pytest.fixture(scope="module")
def vision_setup():
    spec = VisionDatasetSpec(num_classes=4, image_size=12)
    X, y = make_vision_dataset(spec, 320, seed=0)
    Xe, ye = make_vision_dataset(spec, 200, seed=9)
    eval_set = balanced_eval_set(Xe, ye, per_class=16)
    clients = build_clients(X, y, iid_partition(len(y), 2, seed=0))
    adapter = resnet_task("resnet8", num_classes=4)
    return adapter, clients, eval_set


def test_fedpart_learns_and_saves_comm(vision_setup):
    adapter, clients, eval_set = vision_setup
    sched = FedPartSchedule(num_groups=10, warmup_rounds=1, rounds_per_layer=1,
                            cycles=1)
    cfg = FLRunConfig(local_epochs=1, batch_size=32, lr=2e-3)
    res = run_federated(adapter, clients, eval_set, sched.rounds(), cfg)
    assert res.best_acc > 0.3            # well above 0.25 chance
    assert res.comm_total_bytes < 0.35 * res.comm_fnu_bytes
    assert res.comp_total_flops < res.comp_fnu_flops


def test_fnu_baseline_runs(vision_setup):
    adapter, clients, eval_set = vision_setup
    cfg = FLRunConfig(local_epochs=1, batch_size=32, lr=2e-3)
    res = run_federated(adapter, clients, eval_set, FNUSchedule(3).rounds(), cfg)
    assert res.comm_total_bytes == res.comm_fnu_bytes
    assert res.best_acc > 0.25


@pytest.mark.parametrize("algo", ["fedprox", "moon"])
def test_algorithms_compose_with_fedpart(vision_setup, algo):
    adapter, clients, eval_set = vision_setup
    sched = FedPartSchedule(num_groups=10, warmup_rounds=1, rounds_per_layer=1,
                            cycles=1)
    cfg = FLRunConfig(local_epochs=1, batch_size=32, lr=2e-3,
                      algo=AlgoConfig(name=algo))
    res = run_federated(adapter, clients, eval_set, sched.rounds()[:3], cfg)
    assert np.isfinite(res.history[-1]["loss"])


def test_stepsize_tracker_runs(vision_setup):
    adapter, clients, eval_set = vision_setup
    sched = FedPartSchedule(num_groups=10, warmup_rounds=2, rounds_per_layer=1,
                            cycles=1)
    cfg = FLRunConfig(local_epochs=1, batch_size=32, lr=2e-3, track_stepsizes=True)
    res = run_federated(adapter, clients, eval_set, sched.rounds()[:4], cfg)
    assert len(res.tracker.sizes) > 0
    assert len(res.tracker.boundaries) == 4


def test_dirichlet_heterogeneity_runs(vision_setup):
    adapter, _, eval_set = vision_setup
    spec = VisionDatasetSpec(num_classes=4, image_size=12)
    X, y = make_vision_dataset(spec, 320, seed=0)
    clients = build_clients(X, y, dirichlet_partition(y, 3, alpha=0.5, seed=0))
    sched = FedPartSchedule(num_groups=10, warmup_rounds=1, rounds_per_layer=1,
                            cycles=1)
    cfg = FLRunConfig(local_epochs=1, batch_size=16, lr=2e-3)
    res = run_federated(adapter, clients, eval_set, sched.rounds()[:3], cfg)
    assert np.isfinite(res.history[-1]["loss"])


def test_client_sampling(vision_setup):
    adapter, clients, eval_set = vision_setup
    cfg = FLRunConfig(local_epochs=1, batch_size=32, lr=2e-3, sample_fraction=0.5)
    res = run_federated(adapter, clients, eval_set, FNUSchedule(2).rounds(), cfg)
    assert len(res.history) == 2
