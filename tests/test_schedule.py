
from repro.core.schedule import FedPartSchedule, matched_fnu


def test_round_counts():
    s = FedPartSchedule(num_groups=10, warmup_rounds=5, rounds_per_layer=2,
                        cycles=3, bridge_rounds=5)
    rounds = s.rounds()
    assert len(rounds) == s.total_rounds == 5 + 3 * 20 + 2 * 5
    assert all(r.index == i for i, r in enumerate(rounds))


def test_phases_and_groups():
    s = FedPartSchedule(num_groups=4, warmup_rounds=2, rounds_per_layer=2, cycles=2,
                        bridge_rounds=1)
    rounds = s.rounds()
    assert all(r.is_full for r in rounds[:2])
    partial = [r for r in rounds if r.phase == "partial"]
    # sequential: each group appears R/L times consecutively, each cycle
    groups_c0 = [r.group for r in partial if r.cycle == 0]
    assert groups_c0 == [0, 0, 1, 1, 2, 2, 3, 3]
    bridges = [r for r in rounds if r.phase == "bridge"]
    assert len(bridges) == 1 and bridges[0].is_full


def test_reverse_and_random_orders():
    rev = FedPartSchedule(num_groups=4, warmup_rounds=0, rounds_per_layer=1,
                          cycles=1, order="reverse")
    assert [r.group for r in rev.rounds()] == [3, 2, 1, 0]
    rnd1 = FedPartSchedule(num_groups=8, warmup_rounds=0, rounds_per_layer=1,
                           cycles=1, order="random", seed=1)
    rnd2 = FedPartSchedule(num_groups=8, warmup_rounds=0, rounds_per_layer=1,
                           cycles=1, order="random", seed=1)
    assert [r.group for r in rnd1.rounds()] == [r.group for r in rnd2.rounds()]
    assert sorted(r.group for r in rnd1.rounds()) == list(range(8))


def test_every_cycle_covers_every_group():
    s = FedPartSchedule(num_groups=6, warmup_rounds=1, rounds_per_layer=3,
                        cycles=4, order="random", seed=3)
    for c in range(4):
        groups = {r.group for r in s.rounds() if r.phase == "partial" and r.cycle == c}
        assert groups == set(range(6))


def test_matched_fnu_budget():
    s = FedPartSchedule(num_groups=10, warmup_rounds=5, rounds_per_layer=2, cycles=2)
    f = matched_fnu(s)
    assert f.total_rounds == s.total_rounds
    assert all(r.is_full for r in f.rounds())


def test_zero_cycles_is_warmup_only():
    """cycles=0: no partial rounds, no bridges — just the FNU warm-up (the
    degenerate FedAvg corner of the schedule space)."""
    s = FedPartSchedule(num_groups=7, warmup_rounds=3, rounds_per_layer=2,
                        cycles=0, bridge_rounds=5)
    rounds = s.rounds()
    assert len(rounds) == s.total_rounds == 3
    assert all(r.phase == "warmup" and r.is_full for r in rounds)


def test_zero_warmup_starts_partial_immediately():
    s = FedPartSchedule(num_groups=3, warmup_rounds=0, rounds_per_layer=2,
                        cycles=2, bridge_rounds=1)
    rounds = s.rounds()
    assert rounds[0].phase == "partial" and rounds[0].group == 0
    assert len(rounds) == s.total_rounds == 0 + 2 * 3 * 2 + 1
    assert all(r.index == i for i, r in enumerate(rounds))


def test_random_order_deterministic_and_per_cycle():
    """order="random" under a fixed seed: identical schedule objects produce
    identical round lists, and each cycle draws a *fresh* permutation from the
    one generator (so cycles differ from each other with overwhelming
    probability at 8! arrangements)."""
    def mk():
        return FedPartSchedule(num_groups=8, warmup_rounds=1,
                               rounds_per_layer=1, cycles=3,
                               bridge_rounds=2, order="random", seed=7)
    a, b = mk().rounds(), mk().rounds()
    assert [(r.phase, r.group) for r in a] == [(r.phase, r.group) for r in b]
    per_cycle = [[r.group for r in a if r.phase == "partial" and r.cycle == c]
                 for c in range(3)]
    assert all(sorted(g) == list(range(8)) for g in per_cycle)
    assert len({tuple(g) for g in per_cycle}) > 1


def test_schedule_doctests_run():
    """The runnable examples in core/schedule.py's docstrings must actually
    run (pytest.ini doesn't collect doctests globally, so exercise them
    here — docs that can rot silently aren't docs)."""
    import doctest

    import repro.core.schedule as m

    res = doctest.testmod(m)
    assert res.failed == 0
    assert res.attempted >= 4     # module example + FedPartSchedule examples


def test_round_count_matches_paper_formula():
    """total_rounds == W + C*M*(R/L) + (C-1)*B across a grid: the paper's
    W + C*(M*R/L + B) budget with the last cycle's bridge dropped (bridges
    only separate cycles; code and docstring agree)."""
    for W in (0, 2, 5):
        for C in (1, 2, 4):
            for M, RL, B in ((3, 1, 2), (10, 2, 5), (6, 3, 0)):
                s = FedPartSchedule(num_groups=M, warmup_rounds=W,
                                    rounds_per_layer=RL, cycles=C,
                                    bridge_rounds=B)
                expect = W + C * M * RL + (C - 1) * B
                assert s.total_rounds == expect
                assert len(s.rounds()) == expect
