import numpy as np
import pytest

from repro.core.schedule import (FULL_NETWORK, FedPartSchedule, FNUSchedule,
                                 matched_fnu)


def test_round_counts():
    s = FedPartSchedule(num_groups=10, warmup_rounds=5, rounds_per_layer=2,
                        cycles=3, bridge_rounds=5)
    rounds = s.rounds()
    assert len(rounds) == s.total_rounds == 5 + 3 * 20 + 2 * 5
    assert all(r.index == i for i, r in enumerate(rounds))


def test_phases_and_groups():
    s = FedPartSchedule(num_groups=4, warmup_rounds=2, rounds_per_layer=2, cycles=2,
                        bridge_rounds=1)
    rounds = s.rounds()
    assert all(r.is_full for r in rounds[:2])
    partial = [r for r in rounds if r.phase == "partial"]
    # sequential: each group appears R/L times consecutively, each cycle
    groups_c0 = [r.group for r in partial if r.cycle == 0]
    assert groups_c0 == [0, 0, 1, 1, 2, 2, 3, 3]
    bridges = [r for r in rounds if r.phase == "bridge"]
    assert len(bridges) == 1 and bridges[0].is_full


def test_reverse_and_random_orders():
    rev = FedPartSchedule(num_groups=4, warmup_rounds=0, rounds_per_layer=1,
                          cycles=1, order="reverse")
    assert [r.group for r in rev.rounds()] == [3, 2, 1, 0]
    rnd1 = FedPartSchedule(num_groups=8, warmup_rounds=0, rounds_per_layer=1,
                           cycles=1, order="random", seed=1)
    rnd2 = FedPartSchedule(num_groups=8, warmup_rounds=0, rounds_per_layer=1,
                           cycles=1, order="random", seed=1)
    assert [r.group for r in rnd1.rounds()] == [r.group for r in rnd2.rounds()]
    assert sorted(r.group for r in rnd1.rounds()) == list(range(8))


def test_every_cycle_covers_every_group():
    s = FedPartSchedule(num_groups=6, warmup_rounds=1, rounds_per_layer=3,
                        cycles=4, order="random", seed=3)
    for c in range(4):
        groups = {r.group for r in s.rounds() if r.phase == "partial" and r.cycle == c}
        assert groups == set(range(6))


def test_matched_fnu_budget():
    s = FedPartSchedule(num_groups=10, warmup_rounds=5, rounds_per_layer=2, cycles=2)
    f = matched_fnu(s)
    assert f.total_rounds == s.total_rounds
    assert all(r.is_full for r in f.rounds())
