"""The availability-trace participation axis (docs/ASYNC.md).

Trace-driven on/off windows must be pure (no stream randomness),
deterministic at population scale, and collapse bit-exactly onto the
legacy i.i.d. arrival process in the degenerate config; biased cohort
selection must never pick off-window clients and its merges must
inverse-probability debias back to the uniform objective; the runtime
must *wait* — never train — when every sampled candidate is unavailable
(the ``picked = rejected[:k]`` regression); and the two participation
controllers must move only their own knobs, within bounds.
"""

import json

import jax
import numpy as np
import pytest

from repro.core.schedule import FedPartSchedule
from repro.core.telemetry import TimelineWindow
from repro.data import (VisionDatasetSpec, balanced_eval_set, build_clients,
                        iid_partition, make_vision_dataset)
from repro.fl import (AlgoConfig, AvailabilityConfig, FLRunConfig,
                      resnet_task, run_federated)
from repro.fl.population import (resolve_cohort_size,
                                 weighted_sample_without_replacement)
from repro.fl.runtime.clients import ClientAvailability
from repro.fl.runtime.control import (ParticipationController,
                                      PlanAssignmentController,
                                      make_controller)
from repro.core import aggregation


# -- trace model units ------------------------------------------------------


def test_trace_params_pure_and_population_scale():
    """Diurnal duty/phase are pure functions of (seed, id): identical across
    instances and fleet sizes, bounded by the configured range, and derived
    without touching the per-dispatch stream."""
    cfg = AvailabilityConfig(trace="diurnal", duty_cycle=(0.2, 0.8),
                             trace_period=4.0, seed=11)
    a = ClientAvailability(cfg, 8)
    b = ClientAvailability(cfg, 10**9)
    state = a._rng.bit_generator.state
    for ci in (0, 5, 999_999_999):
        duty, phase, period = a._trace_params(ci)
        assert (duty, phase, period) == b._trace_params(ci)
        assert 0.2 <= duty <= 0.8 and 0.0 <= phase < 1.0 and period == 4.0
    assert a._rng.bit_generator.state == state  # pure: stream untouched


def test_trace_on_and_next_on_time_math(tmp_path):
    """Known duty/phase via a file trace: the on-window is
    ``frac(t/period + phase) < duty`` and next_on_time lands exactly at the
    next cycle start."""
    p = tmp_path / "trace.json"
    p.write_text(json.dumps({"period": 2.0, "duty": [0.25], "phase": [0.5]}))
    av = ClientAvailability(
        AvailabilityConfig(trace="file", trace_path=str(p)), 4)
    # frac(t/2 + 0.5) < 0.25  <=>  t in [1.0, 1.5) mod 2
    assert not av.trace_on(0, 0.0)
    assert av.trace_on(0, 1.0) and av.trace_on(0, 1.49)
    assert not av.trace_on(0, 1.5)
    assert av.trace_on(0, 3.2)
    assert av.next_on_time(0, 0.0) == pytest.approx(1.0)
    assert av.next_on_time(0, 1.2) == 1.2          # already on
    assert av.next_on_time(0, 1.6) == pytest.approx(3.0)
    # tiling: every client maps to entry i % len(duty)
    assert av._trace_params(3) == av._trace_params(0)


def test_trace_file_loader_npz_and_validation(tmp_path):
    good = tmp_path / "t.npz"
    np.savez(good, duty=[0.5, 1.0], phase=[0.0, 0.25], period=8.0)
    av = ClientAvailability(
        AvailabilityConfig(trace="file", trace_path=str(good)), 4)
    assert av._trace_params(0) == (0.5, 0.0, 8.0)
    assert av._trace_params(3) == (1.0, 0.25, 8.0)   # 3 % 2 == 1
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"duty": [0.5, 1.5], "phase": [0.0, 0.0]}))
    with pytest.raises(ValueError, match="duty"):
        ClientAvailability(
            AvailabilityConfig(trace="file", trace_path=str(bad)), 4
        )._trace_params(0)
    ragged = tmp_path / "ragged.json"
    ragged.write_text(json.dumps({"duty": [0.5], "phase": [0.0, 0.1]}))
    with pytest.raises(ValueError, match="equal"):
        ClientAvailability(
            AvailabilityConfig(trace="file", trace_path=str(ragged)), 4
        )._trace_params(0)


def test_trace_config_validation():
    with pytest.raises(ValueError, match="unknown trace"):
        AvailabilityConfig(trace="weekly")
    with pytest.raises(ValueError, match="duty_cycle"):
        AvailabilityConfig(duty_cycle=(0.0, 0.5))
    with pytest.raises(ValueError, match="duty_cycle"):
        AvailabilityConfig(duty_cycle=(0.8, 0.2))
    with pytest.raises(ValueError, match="trace_period"):
        AvailabilityConfig(trace="diurnal", trace_period=0.0)
    with pytest.raises(ValueError, match="trace_path"):
        AvailabilityConfig(trace="file")
    with pytest.raises(ValueError, match="retry_wait"):
        AvailabilityConfig(retry_wait=0.0)
    assert not AvailabilityConfig(trace="diurnal").is_degenerate
    with pytest.raises(ValueError, match="client_id"):
        ClientAvailability(
            AvailabilityConfig(trace="diurnal"), 4).arrival_ok()


def test_trace_degenerate_duty_matches_iid_stream_bitwise():
    """duty_cycle=(1, 1) is the degenerate trace: always on, and the
    per-dispatch arrival stream replays bit-for-bit against no trace."""
    plain = ClientAvailability(
        AvailabilityConfig(unavailable_prob=0.4, seed=9), 16)
    traced = ClientAvailability(
        AvailabilityConfig(unavailable_prob=0.4, seed=9, trace="diurnal",
                           duty_cycle=(1.0, 1.0), trace_period=2.0), 16)
    assert all(traced.trace_on(ci, t)
               for ci in range(16) for t in (0.0, 0.7, 123.4))
    draws_p = [plain.arrival_ok(ci, 0.3) for ci in range(16)] * 4
    draws_t = [traced.arrival_ok(ci, 0.3) for ci in range(16)] * 4
    assert draws_p == draws_t
    assert plain._rng.bit_generator.state == traced._rng.bit_generator.state


def test_trace_inclusion_prob_and_availability_weight():
    av = ClientAvailability(
        AvailabilityConfig(trace="diurnal", duty_cycle=(0.2, 0.8),
                           unavailable_prob=0.25, seed=3), 8)
    for ci in range(8):
        duty, _, _ = av._trace_params(ci)
        assert av.inclusion_prob(ci) == duty
        on = av.trace_on(ci, 1.3)
        assert av.availability_weight(ci, 1.3) == (
            0.75 if on else 0.0)
    plain = ClientAvailability(AvailabilityConfig(unavailable_prob=0.25), 8)
    assert plain.inclusion_prob(5) == 1.0
    assert plain.availability_weight(5, 0.0) == 0.75


# -- weighted sampling + debiased aggregation -------------------------------


def test_participation_weighted_sampler_units():
    rng = np.random.default_rng(0)
    ids = [10, 20, 30, 40]
    w = np.array([1.0, 0.0, 2.0, 0.0])
    picks = weighted_sample_without_replacement(rng, ids, w, 2)
    assert sorted(picks) == [10, 30]         # zero-weight never picked
    assert weighted_sample_without_replacement(rng, ids, w, 0) == []
    with pytest.raises(ValueError, match="positive-weight"):
        weighted_sample_without_replacement(rng, ids, w, 3)
    with pytest.raises(ValueError, match=">= 0"):
        weighted_sample_without_replacement(
            rng, ids, np.array([1.0, -0.1, 1.0, 1.0]), 1)
    with pytest.raises(ValueError, match="one weight per id"):
        weighted_sample_without_replacement(rng, ids, np.ones(3), 1)
    a = weighted_sample_without_replacement(
        np.random.default_rng(7), list(range(100)), np.ones(100), 10)
    b = weighted_sample_without_replacement(
        np.random.default_rng(7), list(range(100)), np.ones(100), 10)
    assert a == b and len(set(a)) == 10      # seeded + without replacement


def test_participation_debias_weights_unit():
    w = np.array([2.0, 4.0])
    assert aggregation.debias_weights(w, np.array([1.0, 1.0])) is w
    np.testing.assert_allclose(
        aggregation.debias_weights(w, np.array([0.5, 1.0])), [4.0, 4.0])
    with pytest.raises(ValueError, match="inclusion probs"):
        aggregation.debias_weights(w, np.ones(3))
    with pytest.raises(ValueError, match="inclusion"):
        aggregation.debias_weights(w, np.array([0.0, 1.0]))
    with pytest.raises(ValueError, match="inclusion"):
        aggregation.debias_weights(w, np.array([0.5, 1.5]))


# -- config validation ------------------------------------------------------


def test_participation_run_config_validation():
    with pytest.raises(ValueError, match="sample_fraction"):
        FLRunConfig(sample_fraction=0.0)
    with pytest.raises(ValueError, match="sample_fraction"):
        FLRunConfig(sample_fraction=-0.5)
    with pytest.raises(ValueError, match="sample_fraction"):
        FLRunConfig(sample_fraction=1.5)
    with pytest.raises(ValueError, match="cohort_size"):
        FLRunConfig(cohort_size=-1)
    with pytest.raises(ValueError, match="participation_sampling"):
        FLRunConfig(participation_sampling="greedy")
    with pytest.raises(ValueError, match="controller_participation_target"):
        FLRunConfig(controller_participation_target=1.5)
    with pytest.raises(ValueError, match="controller_cohort_bounds"):
        FLRunConfig(controller_cohort_bounds=(0, 4))
    with pytest.raises(ValueError, match="controller_cohort_bounds"):
        FLRunConfig(controller_cohort_bounds=(5, 4))
    with pytest.raises(ValueError, match="controller_plan_boost_max"):
        FLRunConfig(controller_plan_boost_max=-1)


def test_participation_resolve_cohort_size_edges():
    assert resolve_cohort_size(10, 0.5) == 5
    assert resolve_cohort_size(10, 0.01) == 1          # floor of 1
    assert resolve_cohort_size(10, 1.0, cohort_size=64) == 10   # pop clamp
    assert resolve_cohort_size(10**9, 1.0, cohort_size=8) == 8
    with pytest.raises(ValueError, match="cohort_size"):
        resolve_cohort_size(10, 1.0, cohort_size=-1)


# -- controller units -------------------------------------------------------


def _window(events, t_end=1.0):
    return TimelineWindow(t_start=0.0, t_end=t_end, events=events)


def test_participation_controller_moves_cohort_within_bounds():
    ctl = ParticipationController(target=0.5, bounds=(1, 8), current=4,
                                  num_clients=8)
    # nothing delivered: silent
    assert not ctl.observe(_window([]))
    low = _window([{"t": 0.5, "kind": "complete", "client": 0}])
    adj = ctl.observe(low)                   # ep = 1/8 << target: grow
    assert adj.cohort_size == 5 and ctl.current == 5
    assert adj.max_inflight is None and adj.plan_boost is None
    high = _window([{"t": 0.5, "kind": "complete", "client": c}
                    for c in range(8)])
    adj = ctl.observe(high)                  # ep = 1.0 >> target: shrink
    assert adj.cohort_size == 4 and ctl.current == 4
    ok = _window([{"t": 0.5, "kind": "complete", "client": c}
                  for c in range(4)])
    assert not ctl.observe(ok)               # ep = 0.5 == target: deadband
    for _ in range(20):
        ctl.observe(low)
    assert ctl.current == 8                  # clamped at hi


def test_participation_controller_debiased_tracks_ht_estimate():
    ctl = ParticipationController(target=0.5, bounds=(1, 8), current=4,
                                  num_clients=8, debiased=True)
    # one delivered client at inclusion_prob 0.25 counts as 4 clients:
    # ep_HT = 4/8 = target, so the debiased controller holds still where
    # the plain one would grow.
    w = _window([{"t": 0.5, "kind": "complete", "client": 0,
                  "inclusion_prob": 0.25}])
    assert not ctl.observe(w)
    plain = ParticipationController(target=0.5, bounds=(1, 8), current=4,
                                    num_clients=8, debiased=False)
    assert plain.observe(w).cohort_size == 5


def test_participation_controller_validation():
    with pytest.raises(ValueError, match="bounds"):
        ParticipationController(target=0.5, bounds=(0, 4), current=1,
                                num_clients=8)
    with pytest.raises(ValueError, match="target"):
        ParticipationController(target=0.0, bounds=(1, 4), current=1,
                                num_clients=8)
    with pytest.raises(ValueError, match="num_clients"):
        ParticipationController(target=0.5, bounds=(1, 4), current=1,
                                num_clients=0)
    ctl = ParticipationController(target=0.5, bounds=(1, 4), current=99,
                                  num_clients=8)
    assert ctl.current == 4                  # start clamped into bounds


def _stalled_window(group, n=2, loss=1.0):
    evs = []
    for i in range(n):
        evs.append({"t": 0.2 + i * 0.2, "kind": "merge", "version": i,
                    "group": group, "loss": loss})
    return _window(evs)


def test_plan_assignment_controller_boosts_stalled_deep_groups():
    ctl = PlanAssignmentController(num_tiers=2, min_prefix=2, max_boost=2)
    # deep group 3 merged twice with zero progress: boost grows
    adj = ctl.observe(_stalled_window(3))
    assert adj.plan_boost == 1 and ctl.current == 1
    assert adj.cohort_size is None and adj.group_override is None
    adj = ctl.observe(_stalled_window(3))
    assert adj.plan_boost == 2
    assert not ctl.observe(_stalled_window(3))       # clamped at max_boost
    # shallow stall (group < min_prefix) is not coverage-limited: no grow,
    # but it is still *stalled*, so no decay either
    assert not ctl.observe(_stalled_window(1))
    # recovered window (improving losses): boost decays
    improving = _window([
        {"t": 0.2, "kind": "merge", "version": 0, "group": 3, "loss": 2.0},
        {"t": 0.4, "kind": "merge", "version": 1, "group": 3, "loss": 1.0},
    ])
    adj = ctl.observe(improving)
    assert adj.plan_boost == 1 and ctl.current == 1


def test_plan_assignment_controller_validation():
    with pytest.raises(ValueError, match="num_tiers"):
        PlanAssignmentController(num_tiers=0, min_prefix=1, max_boost=1)
    with pytest.raises(ValueError, match="max_boost"):
        PlanAssignmentController(num_tiers=1, min_prefix=1, max_boost=-1)


def test_make_controller_participation_knobs():
    base = dict(local_epochs=1, controller="adaptive")
    ctl = make_controller(FLRunConfig(**base), num_clients=8, num_groups=6,
                          cohort_size=4)
    names = [type(p).__name__ for p in ctl.parts]
    assert "ParticipationController" not in names
    assert "PlanAssignmentController" not in names
    ctl = make_controller(
        FLRunConfig(**base, controller_participation_target=0.5,
                    controller_plan_boost_max=2, plan="nested",
                    capacity_tiers=(0.3, 1.0)),
        num_clients=8, num_groups=6, cohort_size=4)
    names = [type(p).__name__ for p in ctl.parts]
    assert "ParticipationController" in names
    assert "PlanAssignmentController" in names
    # homogeneous plan never gets the assignment controller
    ctl = make_controller(
        FLRunConfig(**base, controller_plan_boost_max=2),
        num_clients=8, num_groups=6, cohort_size=4)
    assert "PlanAssignmentController" not in [
        type(p).__name__ for p in ctl.parts]
    with pytest.raises(ValueError, match="num_clients"):
        make_controller(
            FLRunConfig(**base, controller_participation_target=0.5),
            num_groups=6)


# -- telemetry reducers -----------------------------------------------------


def test_participation_telemetry_reducers():
    w = _window([
        {"t": 0.2, "kind": "complete", "client": 0, "inclusion_prob": 0.25,
         "tier": 0},
        {"t": 0.4, "kind": "complete", "client": 1, "tier": 1},
        {"t": 0.6, "kind": "complete", "client": 0, "inclusion_prob": 0.25,
         "tier": 0},
        {"t": 0.8, "kind": "drop", "client": 2},       # drops never count
    ])
    assert w.effective_participation(8) == 2 / 8
    assert w.effective_participation(8, inverse_probability=True) == (
        (4.0 + 1.0) / 8)
    # HT estimate clips at full coverage and floors tiny probs at 1/n
    tiny = _window([{"t": 0.1, "kind": "complete", "client": 0,
                     "inclusion_prob": 1e-9}])
    assert tiny.effective_participation(4, inverse_probability=True) == 1.0
    assert w.inclusion_moments() == (pytest.approx(0.5), 0.25)
    assert _window([]).inclusion_moments() == (1.0, 1.0)
    assert w.tier_participation(2) == [2 / 3, 1 / 3]
    assert _window([]).tier_participation(2) == [0.0, 0.0]
    # tier falls back to client % num_tiers when not recorded
    fallback = _window([{"t": 0.1, "kind": "complete", "client": 3}])
    assert fallback.tier_participation(2) == [0.0, 1.0]


# -- end-to-end: the participation axis through the async runtime -----------

SPEC = VisionDatasetSpec(num_classes=4, image_size=8)
ROUNDS = FedPartSchedule(num_groups=6, warmup_rounds=1, rounds_per_layer=1,
                         cycles=1).rounds()


@pytest.fixture(scope="module")
def setup():
    X, y = make_vision_dataset(SPEC, 6 * 24, seed=0)
    Xe, ye = make_vision_dataset(SPEC, 64, seed=9)
    eval_set = balanced_eval_set(Xe, ye, per_class=8)
    clients = build_clients(X, y, iid_partition(len(y), 6, seed=0))
    return resnet_task("resnet4", num_classes=4), clients, eval_set


def _run(setup, rounds, availability, **kw):
    adapter, clients, eval_set = setup
    cfg = FLRunConfig(local_epochs=1, batch_size=16, lr=2e-3, adam_eps=1e-3,
                      algo=AlgoConfig(name="fedavg"), engine="sequential",
                      runtime="async", async_policy="fedbuff",
                      availability=availability, **kw)
    return run_federated(adapter, clients, eval_set, rounds, cfg)


def _assert_bitwise(a, b):
    for la, lb in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    assert [h["loss"] for h in a.history] == [h["loss"] for h in b.history]


def test_trace_degenerate_run_bitwise_matches_no_trace(setup):
    """The pinned degeneracy contract: duty (1, 1) end-to-end equals the
    i.i.d.-only runtime bit-for-bit (params, losses, timeline)."""
    kw = dict(sample_fraction=0.67, buffer_k=1, staleness_exponent=0.5)
    base = _run(setup, ROUNDS[:3],
                AvailabilityConfig(unavailable_prob=0.3, seed=3), **kw)
    deg = _run(setup, ROUNDS[:3],
               AvailabilityConfig(unavailable_prob=0.3, seed=3,
                                  trace="diurnal", duty_cycle=(1.0, 1.0),
                                  trace_period=2.0), **kw)
    _assert_bitwise(base, deg)
    assert base.timeline.events == deg.timeline.events


def test_biased_uniform_availability_keeps_uniform_weights(setup):
    """Biased selection over a uniformly-available fleet records
    inclusion_prob == 1.0 on every delivery, so the merge's debias step is
    the exact identity (``debias_weights`` returns its input unchanged) and
    the objective stays today's uniform average."""
    kw = dict(sample_fraction=0.67, buffer_k=1, staleness_exponent=0.5,
              participation_sampling="biased")
    for av in (AvailabilityConfig(seed=3),
               AvailabilityConfig(seed=3, trace="diurnal",
                                  duty_cycle=(1.0, 1.0), trace_period=2.0)):
        res = _run(setup, ROUNDS[:3], av, **kw)
        completes = res.timeline.of_kind("complete")
        assert completes
        assert all(e["inclusion_prob"] == 1.0 for e in completes)


SKEWED = AvailabilityConfig(trace="diurnal", trace_period=0.05,
                            duty_cycle=(0.15, 0.9), unavailable_prob=0.4,
                            speed_spread=2.0, seed=5)


def test_trace_unavailable_clients_never_train(setup, tmp_path):
    """The ``picked = rejected[:k]`` regression: with every client off at
    t=0 the runtime books a wait and trains nobody until a window opens."""
    p = tmp_path / "trace.json"
    p.write_text(json.dumps({"period": 2.0, "duty": [0.25], "phase": [0.5]}))
    av = AvailabilityConfig(trace="file", trace_path=str(p))
    res = _run(setup, ROUNDS[:3], av, sample_fraction=0.5, buffer_k=1,
               staleness_exponent=0.5)
    tl = res.timeline
    waits = tl.of_kind("wait")
    assert waits and waits[0]["t"] == 0.0
    assert waits[0]["until"] == pytest.approx(1.0)   # next on-window
    model = ClientAvailability(av, 6)
    dispatches = tl.of_kind("dispatch")
    assert dispatches and dispatches[0]["t"] >= 1.0
    for e in dispatches:
        for ci in e["clients"]:
            assert model.trace_on(ci, e["t"])        # only on-window clients
    assert len(tl.of_kind("merge")) == 3             # still completes


@pytest.mark.parametrize("mode", ["blind", "biased"])
def test_trace_skewed_run_only_trains_on_window_clients(setup, mode):
    res = _run(setup, ROUNDS[:4], SKEWED, sample_fraction=0.5, buffer_k=2,
               staleness_exponent=0.5, participation_sampling=mode)
    model = ClientAvailability(SKEWED, 6)
    for e in res.timeline.of_kind("dispatch"):
        for ci in e["clients"]:
            assert model.trace_on(ci, e["t"])
    if mode == "biased":
        probs = {e["inclusion_prob"]
                 for e in res.timeline.of_kind("complete")}
        assert probs and all(0.15 <= p <= 0.9 for p in probs)


def test_iid_heavy_unavailability_retries_and_completes(setup):
    """No trace, brutal i.i.d. arrival odds: empty draws book retry_wait
    backoffs (never training rejected clients) and the run still finishes."""
    av = AvailabilityConfig(unavailable_prob=0.85, seed=2, retry_wait=0.25)
    res = _run(setup, ROUNDS[:3], av, sample_fraction=0.5, buffer_k=1,
               staleness_exponent=0.5)
    tl = res.timeline
    assert len(tl.of_kind("merge")) == 3
    for w in tl.of_kind("wait"):
        assert w["until"] == pytest.approx(w["t"] + 0.25)


@pytest.mark.slow
def test_trace_biased_run_is_engine_independent(setup):
    """The virtual event sequence of a skewed-trace biased run is an
    engine-invariant: vmap and the sequential oracle dispatch the same
    clients at the same virtual times."""
    kw = dict(sample_fraction=0.5, buffer_k=2, staleness_exponent=0.5,
              participation_sampling="biased")
    adapter, clients, eval_set = setup
    runs = {}
    for engine in ("sequential", "vmap"):
        cfg = FLRunConfig(local_epochs=1, batch_size=16, lr=2e-3,
                          adam_eps=1e-3, algo=AlgoConfig(name="fedavg"),
                          engine=engine, runtime="async",
                          async_policy="fedbuff", availability=SKEWED, **kw)
        runs[engine] = run_federated(adapter, clients, eval_set,
                                     ROUNDS[:4], cfg)
    ev_a = [(e["t"], e["clients"])
            for e in runs["sequential"].timeline.of_kind("dispatch")]
    ev_b = [(e["t"], e["clients"])
            for e in runs["vmap"].timeline.of_kind("dispatch")]
    assert ev_a == ev_b
    np.testing.assert_allclose(
        [h["loss"] for h in runs["sequential"].history],
        [h["loss"] for h in runs["vmap"].history], rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_trace_biased_debiased_beats_blind_time_to_accuracy(setup):
    """The payoff claim: on a skewed trace, availability-biased cohorts with
    debiased merges reach the end of the same schedule in less virtual time
    than blind rejection sampling (clipped time-to-accuracy, deterministic
    under the pinned seed)."""
    kw = dict(sample_fraction=0.5, buffer_k=2, staleness_exponent=0.5)
    tta = {}
    for mode in ("blind", "biased"):
        res = _run(setup, ROUNDS[:6], SKEWED,
                   participation_sampling=mode, **kw)
        tl = res.timeline
        tta[mode] = min(tl.time_to_accuracy(0.3), tl.total_seconds)
    assert tta["biased"] < tta["blind"]


def test_participation_controller_in_the_loop(setup):
    """End-to-end adaptive run: control events record the cohort/plan knobs
    and the cohort target stays inside the configured bounds."""
    res = _run(setup, ROUNDS[:4], SKEWED, sample_fraction=0.34, buffer_k=1,
               staleness_exponent=0.5, participation_sampling="biased",
               controller="adaptive", controller_participation_target=0.6,
               controller_cohort_bounds=(1, 4), controller_window=2)
    controls = res.timeline.of_kind("control")
    assert controls
    for e in controls:
        assert 1 <= e["cohort_size"] <= 4
        assert e["plan_boost"] == 0          # no plan controller configured
    assert len(res.timeline.of_kind("merge")) == 4


def test_sync_runtime_rejects_biased_sampling(setup):
    adapter, clients, eval_set = setup
    cfg = FLRunConfig(participation_sampling="biased")
    with pytest.raises(ValueError, match="async"):
        run_federated(adapter, clients, eval_set, ROUNDS[:1], cfg)
