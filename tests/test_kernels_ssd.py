"""SSD chunk Pallas kernel vs. the sequential-recurrence oracle AND the
model's chunked_decay_attention implementation — shape/chunk/dtype sweep."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_chunk import ops
from repro.models.ssm import chunked_decay_attention

CASES = [
    # (b, h, s, n, p, chunk)
    (1, 2, 128, 128, 128, 64),
    (2, 3, 256, 128, 128, 128),
    (1, 2, 128, 64, 128, 32),      # N pad path
    (1, 1, 256, 128, 64, 128),     # P pad path
]


def _inputs(case, dtype=jnp.float32):
    b, h, s, n, p, chunk = case
    ks = jax.random.split(jax.random.key(hash(case) % 2**31), 4)
    q = jax.random.normal(ks[0], (b, s, h, n), dtype) * 0.3
    k = jax.random.normal(ks[1], (b, s, h, n), dtype) * 0.3
    v = jax.random.normal(ks[2], (b, s, h, p), dtype)
    # decays in (0.8, 1.0) — realistic mamba regime
    log_a = -jnp.abs(jax.random.normal(ks[3], (b, s, h))) * 0.2
    return q, k, v, log_a


@pytest.mark.parametrize("case", CASES)
def test_kernel_matches_sequential_oracle(case):
    q, k, v, log_a = _inputs(case)
    chunk = case[-1]
    y, state = ops.ssd_scan(q, k, v, log_a, chunk=chunk, interpret=True)
    y_ref, state_ref = ops.ssd_reference(q, k, v, log_a)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_ref),
                               atol=2e-4, rtol=2e-4)


def test_kernel_matches_model_implementation():
    """The model's jnp chunked implementation and the Pallas kernel must
    agree (they are alternative lowerings of the same math)."""
    case = (2, 2, 256, 128, 128, 128)
    q, k, v, log_a = _inputs(case)
    y_k, st_k = ops.ssd_scan(q, k, v, log_a, chunk=128, interpret=True)
    y_m, st_m = chunked_decay_attention(q, k, v, log_a, chunk=128)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m), atol=2e-4, rtol=2e-4)
    # model state layout is (B,H,N,P) as well
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_m), atol=2e-4, rtol=2e-4)


def test_bf16(case=(1, 2, 128, 128, 128, 64)):
    q, k, v, log_a = _inputs(case, jnp.bfloat16)
    y, _ = ops.ssd_scan(q, k, v, log_a.astype(jnp.float32), chunk=64, interpret=True)
    y_ref, _ = ops.ssd_reference(q, k, v, log_a.astype(jnp.float32))
    assert y.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
                               atol=0.15, rtol=0.15)
