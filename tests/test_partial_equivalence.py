"""The paper's Eq. 1 masked update and the framework's partitioned update
must be mathematically identical (DESIGN.md §6)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import masking
from repro.core.partition import build_partition
from repro.optim.adam import AdamConfig, adam_init
from repro.optim.partial import full_step, masked_step, partitioned_step
from tests.conftest import small_params


def _loss_fn(batch):
    x, y = batch

    def loss(params):
        h = jnp.take(params["embed"]["table"], x, axis=0)       # (B,S,16)
        for i in ("0", "1", "2"):
            blk = params["blocks"][i]
            h = jnp.tanh(h @ blk["attn"]["wq"]["w"]) * blk["norm"]["scale"]
            h = h @ blk["attn"]["wo"]["w"] + h
        pooled = h.mean(axis=1)
        logits = pooled @ params["head"]["w"] + params["head"]["b"]
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)

    return loss


@pytest.mark.parametrize("group", [
    0,
    pytest.param(1, marks=pytest.mark.slow),
    2,
    pytest.param(4, marks=pytest.mark.slow),
])
def test_masked_equals_partitioned(group):
    params = small_params()
    part = build_partition(params)
    x = jax.random.randint(jax.random.key(1), (4, 6), 0, 32)
    y = jax.random.randint(jax.random.key(2), (4,), 0, 8)
    loss_fn = _loss_fn((x, y))
    cfg = AdamConfig(lr=1e-2)

    mask = masking.mask_tree(params, part, group)
    p_masked, _, loss_m = masked_step(loss_fn, params, adam_init(params), mask, cfg)
    p_part, _, loss_p = partitioned_step(loss_fn, params, part, group, None, cfg)

    assert np.allclose(float(loss_m), float(loss_p), rtol=1e-6)
    for (path_a, a), (path_b, b) in zip(
        jax.tree_util.tree_flatten_with_path(p_masked)[0],
        jax.tree_util.tree_flatten_with_path(p_part)[0],
    ):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-6,
            err_msg=f"{path_a} differs",
        )


def test_partial_changes_only_its_group():
    params = small_params()
    part = build_partition(params)
    x = jax.random.randint(jax.random.key(1), (4, 6), 0, 32)
    y = jax.random.randint(jax.random.key(2), (4,), 0, 8)
    loss_fn = _loss_fn((x, y))

    new_p, _, _ = partitioned_step(loss_fn, params, part, 2, None, AdamConfig())
    for (path, a), (_, b) in zip(
        jax.tree_util.tree_flatten_with_path(params)[0],
        jax.tree_util.tree_flatten_with_path(new_p)[0],
    ):
        ps = "/".join(str(getattr(k, "key", k)) for k in path)
        changed = bool(np.any(np.asarray(a) != np.asarray(b)))
        in_group = part.group_of(ps) == 2
        assert changed == in_group, (ps, changed, in_group)


def test_full_step_changes_everything():
    params = small_params()
    x = jax.random.randint(jax.random.key(1), (4, 6), 0, 32)
    y = jax.random.randint(jax.random.key(2), (4,), 0, 8)
    loss_fn = _loss_fn((x, y))
    new_p, _, _ = full_step(loss_fn, params, adam_init(params), AdamConfig())
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_p)):
        assert bool(np.any(np.asarray(a) != np.asarray(b)))
