"""Post-compile analysis: collective-bytes parsing and roofline terms.

``cost_analysis`` supplies HLO FLOPs and bytes accessed; collective traffic
is NOT in there, so we parse the optimized HLO text and sum the result-shape
bytes of every collective op (documented proxy for operand bytes: equal for
all-reduce/collective-permute, the gathered size for all-gather, the
pre-scatter size for reduce-scatter's operand — we record per-op-kind
subtotals so either convention can be reconstructed).

Roofline constants (TPU v5e, per chip): 197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, Any]:
    """Sum result-shape bytes per collective kind from optimized HLO text.

    ``-start`` ops are counted, matching ``-done`` pairs are not (avoid double
    counting async collectives)."""
    per_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        per_kind[kind] += b
        counts[kind] += 1
    total = sum(per_kind.values())
    return {"total_bytes": total, "per_kind_bytes": per_kind, "per_kind_count": counts}


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """Per-device roofline terms, in seconds.

    Two memory terms are reported:
    - ``memory_s_hlo``: HLO "bytes accessed" / HBM_BW — the spec's term.  On
      the CPU-backend HLO this counts every unfused operand read and is a
      gross UPPER bound (TPU XLA fuses elementwise chains away).
    - ``memory_s_min``: 2x per-device buffer residency / HBM_BW — a LOWER
      bound (every live byte written+read once).

    ``dominant`` uses the lower bound: for matmul-dominated graphs on the
    TPU backend real traffic sits close to it, and the upper bound would
    otherwise mislabel every workload memory-bound."""

    compute_s: float
    memory_s_hlo: float
    memory_s_min: float
    collective_s: float
    flops: float
    hbm_bytes: float
    residency_bytes: float
    coll_bytes: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s_min,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s_min, self.collective_s)

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s_hlo": self.memory_s_hlo,
            "memory_s_min": self.memory_s_min,
            "collective_s": self.collective_s,
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "residency_bytes": self.residency_bytes,
            "coll_bytes": self.coll_bytes,
            "dominant": self.dominant,
        }


def roofline(
    flops: float, hbm_bytes: float, coll_bytes: float, residency_bytes: float = 0.0
) -> RooflineTerms:
    """All quantities are per-device (the SPMD-partitioned executable)."""
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS,
        memory_s_hlo=hbm_bytes / HBM_BW,
        memory_s_min=2.0 * residency_bytes / HBM_BW,
        collective_s=coll_bytes / ICI_BW,
        flops=flops,
        hbm_bytes=hbm_bytes,
        residency_bytes=residency_bytes,
        coll_bytes=coll_bytes,
    )


def extract_cost(compiled) -> dict[str, float]:
    """Normalise compiled.cost_analysis() across jax versions."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    out = {"flops": float(ca.get("flops", 0.0))}
    # bytes accessed: prefer the aggregate key
    out["bytes"] = float(ca.get("bytes accessed", 0.0))
    for k, val in ca.items():
        if k.startswith("bytes accessed"):
            out.setdefault("bytes_detail", {})[k] = float(val)
    out["utilization_keys"] = {}
    return out


def extract_memory(compiled) -> dict[str, int]:
    ma = compiled.memory_analysis()
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    )
    out = {}
    for k in keys:
        out[k] = int(getattr(ma, k, 0) or 0)
    out["per_device_total_bytes"] = (
        out["argument_size_in_bytes"]
        + out["output_size_in_bytes"]
        + out["temp_size_in_bytes"]
        - out["alias_size_in_bytes"]
    )
    return out


def model_flops_6nd(active_params: float, tokens: float) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for a train step;
    forward-only callers divide by 3."""
    return 6.0 * active_params * tokens
