"""FL training driver — the paper's end-to-end entry point.

Runs federated training (FNU baseline or FedPart) on synthetic vision/text
tasks with the paper's models (ResNet-8/18, small NLP transformer), prints
per-round accuracy and the comm/comp cost ledger, and writes a JSON result.

Examples:
    python -m repro.launch.train --task resnet8 --strategy fedpart \
        --clients 8 --cycles 2 --rl 2 --warmup 5
    python -m repro.launch.train --task resnet8 --strategy fnu --rounds 30
    python -m repro.launch.train --task nlp --strategy fedpart --algo fedprox
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.checkpoint import save_checkpoint
from repro.core.schedule import FedPartSchedule, FNUSchedule
from repro.data import (
    TextDatasetSpec,
    VisionDatasetSpec,
    balanced_eval_set,
    build_clients,
    dirichlet_partition,
    iid_partition,
    make_text_dataset,
    make_vision_dataset,
)
from repro.fl import AlgoConfig, FLRunConfig, nlp_task, resnet_task, run_federated


def build_task_and_data(args):
    if args.task in ("resnet8", "resnet18"):
        spec = VisionDatasetSpec(num_classes=args.classes, image_size=args.image_size)
        X, y = make_vision_dataset(spec, args.samples, seed=args.seed)
        Xe, ye = make_vision_dataset(spec, max(args.samples // 2, 200), seed=args.seed + 99)
        adapter = resnet_task(args.task, num_classes=args.classes)
    elif args.task == "nlp":
        spec = TextDatasetSpec(num_classes=4)
        X, y = make_text_dataset(spec, args.samples, seed=args.seed)
        Xe, ye = make_text_dataset(spec, max(args.samples // 2, 200), seed=args.seed + 99)
        adapter = nlp_task(num_classes=4, smoke=args.smoke)
    else:
        raise SystemExit(f"unknown task {args.task}")

    if args.alpha > 0:
        parts = dirichlet_partition(y, args.clients, args.alpha, seed=args.seed)
    else:
        parts = iid_partition(len(y), args.clients, seed=args.seed)
    clients = build_clients(X, y, parts)
    eval_set = balanced_eval_set(Xe, ye, per_class=args.eval_per_class)
    return adapter, clients, eval_set


def build_schedule(args, num_groups: int):
    if args.strategy == "fnu":
        total = args.rounds or (
            args.warmup + args.cycles * num_groups * args.rl
            + (args.cycles - 1) * args.bridge
        )
        return FNUSchedule(total=total)
    return FedPartSchedule(
        num_groups=num_groups,
        warmup_rounds=args.warmup,
        rounds_per_layer=args.rl,
        cycles=args.cycles,
        bridge_rounds=args.bridge,
        order=args.order,
        seed=args.seed,
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="resnet8",
                    choices=["resnet8", "resnet18", "nlp"])
    ap.add_argument("--strategy", default="fedpart", choices=["fedpart", "fnu"])
    ap.add_argument("--algo", default="fedavg",
                    choices=["fedavg", "fedprox", "moon"])
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--samples", type=int, default=2000)
    ap.add_argument("--classes", type=int, default=20)
    ap.add_argument("--image-size", type=int, default=32)
    ap.add_argument("--alpha", type=float, default=0.0,
                    help="Dirichlet alpha (0 = IID)")
    ap.add_argument("--sample-fraction", type=float, default=1.0)
    ap.add_argument("--local-epochs", type=int, default=2)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=5)
    ap.add_argument("--rl", type=int, default=2, help="rounds per layer (R/L)")
    ap.add_argument("--cycles", type=int, default=1)
    ap.add_argument("--bridge", type=int, default=5)
    ap.add_argument("--order", default="sequential",
                    choices=["sequential", "reverse", "random"])
    ap.add_argument("--rounds", type=int, default=0,
                    help="FNU rounds (default: match FedPart budget)")
    ap.add_argument("--eval-per-class", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--track-stepsizes", action="store_true")
    ap.add_argument("--out", default="")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    adapter, clients, eval_set = build_task_and_data(args)
    # Discover the group count from a throwaway init.
    probe = adapter.partition(adapter.init(jax.random.key(0)))
    schedule = build_schedule(args, probe.num_groups)
    print(f"[train] task={args.task} strategy={args.strategy} algo={args.algo} "
          f"groups={probe.num_groups} rounds={schedule.total_rounds} "
          f"clients={len(clients)}")

    run_cfg = FLRunConfig(
        local_epochs=args.local_epochs,
        batch_size=args.batch_size,
        lr=args.lr,
        algo=AlgoConfig(name=args.algo),
        sample_fraction=args.sample_fraction,
        seed=args.seed,
        track_stepsizes=args.track_stepsizes,
    )
    t0 = time.time()
    result = run_federated(adapter, clients, eval_set, schedule.rounds(), run_cfg,
                           verbose=not args.quiet)
    elapsed = time.time() - t0

    summary = {
        "task": args.task,
        "strategy": args.strategy,
        "algo": args.algo,
        "best_acc": result.best_acc,
        "final_acc": result.final_acc,
        "rounds": schedule.total_rounds,
        "comm_bytes": result.comm_total_bytes,
        "comm_ratio_to_fnu": result.comm_total_bytes / max(result.comm_fnu_bytes, 1),
        "comp_flops": result.comp_total_flops,
        "comp_ratio_to_fnu": result.comp_total_flops / max(result.comp_fnu_flops, 1),
        "elapsed_s": elapsed,
        "history": result.history,
    }
    if result.tracker is not None:
        summary["stepsizes"] = result.tracker.sizes
        summary["boundaries"] = result.tracker.boundaries
        summary["post_agg_spike"] = result.tracker.post_aggregation_spike()
    print(f"[train] best_acc={result.best_acc:.4f} "
          f"comm={summary['comm_ratio_to_fnu']:.2%} of FNU, "
          f"comp={summary['comp_ratio_to_fnu']:.2%} of FNU, {elapsed:.0f}s")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=2, default=float)
    if args.checkpoint_dir:
        save_checkpoint(args.checkpoint_dir, result.params,
                        {"rounds": schedule.total_rounds, "best_acc": result.best_acc})
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
