import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers, compiles, and fits — and extract the roofline terms.

The two lines above MUST stay first: jax locks the device count on first
initialisation, and only the dry-run wants 512 placeholder host devices.

Measurement design (see launch/cost_probes.py): the MAIN compile uses the
production configuration — scan-over-layers + per-layer remat — which gives
the true HBM residency (memory_analysis) but understates FLOPs/collectives
(XLA counts loop bodies once).  Tiny fully-unrolled PROBE compiles fit the
exact linear cost model per metric and extrapolate to full depth.

Usage:
    python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    python -m repro.launch.dryrun --all                 # 40 pairs, single-pod
    python -m repro.launch.dryrun --all --multi-pod     # + (pod) axis
    python -m repro.launch.dryrun --arch X --shape train_4k --fedpart --group 12

Artifacts: experiments/dryrun/<arch>__<shape>__<mesh>[__fedpartN].json
"""

import argparse
import json
import sys
import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.partition import tree_paths
from repro.launch import hlo_analysis, steps
from repro.launch.cost_probes import fit_and_extrapolate, probe_plan
from repro.launch.mesh import make_production_mesh

import jax as _jax


def make_mesh_override(shape_str: str):
    """e.g. "32,8" -> 256-chip mesh (data=32, model=8); "2,16,16" multi-pod."""
    dims = tuple(int(x) for x in shape_str.split(","))
    axes = ("pod", "data", "model")[-len(dims):]
    return _jax.make_mesh(dims, axes)
from repro.launch.sharding import input_shardings, params_shardings
from repro.models import api
from repro.models.api import INPUT_SHAPES

ARTIFACT_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"
)

# FSDP policy (DESIGN.md §5): shard params over "data" too when the model is
# too big for tensor-parallel-only residency.
FSDP_TRAIN_THRESHOLD = 8e9       # params
FSDP_SERVE_THRESHOLD = 60e9


def _param_count(shapes) -> float:
    return float(sum(np.prod(x.shape) for x in jax.tree.leaves(shapes)))


def _active_param_count(cfg, shapes) -> float:
    """Active params per token (MoE: routed experts counted at top-k/E)."""
    total = 0.0
    for path, leaf in tree_paths(shapes):
        n = float(np.prod(leaf.shape))
        if "experts" in path:
            n *= cfg.num_experts_per_tok / max(cfg.num_experts, 1)
        total += n
    return total


def compile_step(
    cfg,
    shape,
    mesh,
    *,
    unroll: int,
    remat: bool,
    fedpart_group: int | None = None,
    moe_ep: bool = False,
    act_shard: bool = False,
    accum: int = 1,
):
    """Lower + compile one step function.  Returns (compiled, extras)."""
    from contextlib import nullcontext

    from repro.models import moe_ep as moe_ep_mod

    params_shapes = jax.eval_shape(lambda: api.init(jax.random.key(0), cfg))
    n_params = _param_count(params_shapes)
    window = api.decode_window(cfg, shape.seq_len)
    specs = api.input_specs(cfg, shape)
    fsdp = n_params > (
        FSDP_TRAIN_THRESHOLD if shape.kind == "train" else FSDP_SERVE_THRESHOLD
    )
    p_shard = params_shardings(params_shapes, mesh, fsdp=fsdp)
    extras = {"params": n_params, "fsdp": fsdp, "window": window,
              "params_shapes": params_shapes}

    from repro.models import act_sharding as act_mod

    ep_ctx = (
        moe_ep_mod.expert_parallel(mesh, fsdp=fsdp)
        if (moe_ep and cfg.is_moe)
        else nullcontext()
    )
    act_ctx = act_mod.activation_sharding(mesh) if act_shard else nullcontext()
    with ep_ctx, act_ctx, mesh:
        if shape.kind == "train":
            if fedpart_group is not None:
                groups = steps.list_groups(params_shapes)
                group = groups[fedpart_group % len(groups)]
                extras["fedpart_group"] = f"{group.key}[{group.index}]"
                step = steps.make_fedpart_train_step(
                    cfg, group, remat=remat, unroll=unroll
                )
                opt_shapes = jax.eval_shape(
                    lambda p: steps.init_partial_opt_state(p, group), params_shapes
                )
            else:
                step = steps.make_train_step(cfg, remat=remat, unroll=unroll,
                                             accum=accum)
                opt_shapes = jax.eval_shape(steps.init_opt_state, params_shapes)
            opt_shard = type(opt_shapes)(
                step=NamedSharding(mesh, P()),
                m=params_shardings(opt_shapes.m, mesh, fsdp=fsdp),
                v=params_shardings(opt_shapes.v, mesh, fsdp=fsdp),
            )
            b_shard = input_shardings(specs, mesh)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, opt_shard, b_shard),
                out_shardings=(p_shard, opt_shard, NamedSharding(mesh, P())),
            )
            lowered = jitted.lower(params_shapes, opt_shapes, specs)
        elif shape.kind == "prefill":
            step = steps.make_prefill_step(cfg, window=window, unroll=unroll)
            b_shard = input_shardings(specs, mesh)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(params_shapes, specs)
        else:  # decode
            step = steps.make_serve_step(cfg, window=window, unroll=unroll)
            io_shard = input_shardings(specs, mesh)
            jitted = jax.jit(
                step,
                in_shardings=(
                    p_shard, io_shard["token"], io_shard["cache"], io_shard["pos"]
                ),
                out_shardings=(NamedSharding(mesh, P()), io_shard["cache"]),
            )
            lowered = jitted.lower(
                params_shapes, specs["token"], specs["cache"], specs["pos"]
            )
        return lowered.compile(), extras


def _costs_of(compiled) -> dict[str, float]:
    cost = hlo_analysis.extract_cost(compiled)
    coll = hlo_analysis.collective_bytes(compiled.as_text())
    return {
        "flops": cost["flops"],
        "hbm_bytes": cost["bytes"],
        "coll_bytes": float(coll["total_bytes"]),
        **{f"coll_{k}": float(v) for k, v in coll["per_kind_bytes"].items()},
    }


def analyze_pair(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    fedpart_group: int | None = None,
    probes: bool = True,
    moe_ep: bool = False,
    act_shard: bool = False,
    mesh_shape: str | None = None,
    accum: int = 1,
    save: bool = True,
    verbose: bool = True,
) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = mesh_shape.replace(",", "x") if mesh_shape else (
        "2x16x16" if multi_pod else "16x16")
    tag = f"{arch}__{shape_name}__{mesh_name}" + (
        f"__fedpart{fedpart_group}" if fedpart_group is not None else ""
    ) + ("__ep" if moe_ep else "") + ("__act" if act_shard else "") + (
        f"__accum{accum}" if accum > 1 else "")
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "mode": "fedpart" if fedpart_group is not None else "baseline",
    }
    if not api.supports_shape(cfg, shape):
        record["status"] = "skipped"
        record["reason"] = "unsupported by design (DESIGN.md §4: whisper long_500k)"
        if save:
            _save(tag, record)
        if verbose:
            print(f"[dryrun] {tag}: SKIPPED ({record['reason']})")
        return record

    t0 = time.time()
    mesh = (make_mesh_override(mesh_shape) if mesh_shape
            else make_production_mesh(multi_pod=multi_pod))
    n_chips = int(np.prod(list(mesh.shape.values())))

    # MAIN compile: production shape (scan + remat) -> memory truth.
    compiled, extras = compile_step(
        cfg, shape, mesh, unroll=1, remat=True, fedpart_group=fedpart_group,
        moe_ep=moe_ep, act_shard=act_shard, accum=accum,
    )
    record["params"] = extras["params"]
    record["fsdp"] = extras["fsdp"]
    if "fedpart_group" in extras:
        record["fedpart_group"] = extras["fedpart_group"]
    mem = hlo_analysis.extract_memory(compiled)
    raw = _costs_of(compiled)
    record["main_compile_s"] = time.time() - t0

    # PROBE compiles: tiny fully-unrolled variants -> exact per-layer costs.
    record["moe_ep"] = moe_ep
    record["act_shard"] = act_shard
    record["accum"] = accum
    if probes and fedpart_group is None:
        plan = probe_plan(cfg)
        probe_costs = []
        for pc in plan.probe_cfgs:
            pcomp, _ = compile_step(
                pc, shape, mesh, unroll=max(pc.num_layers, 4), remat=False,
                moe_ep=moe_ep, act_shard=act_shard, accum=accum,
            )
            probe_costs.append(_costs_of(pcomp))
        corrected = fit_and_extrapolate(plan, probe_costs)
        record["cost_model"] = "probe-extrapolated"
    elif fedpart_group is not None:
        # FedPart's truncated backward is group-position-dependent, so the
        # linear probe model does not apply.  Compile once fully unrolled
        # (costs exact; memory comes from the scan compile above — unrolled
        # remat is CSE'd away, see §Notes).
        ucomp, _ = compile_step(
            cfg, shape, mesh, unroll=cfg.num_layers, remat=False,
            fedpart_group=fedpart_group, moe_ep=moe_ep, act_shard=act_shard,
        )
        corrected = _costs_of(ucomp)
        record["cost_model"] = "full-unroll exact"
    else:
        corrected = raw
        record["cost_model"] = "raw (scan bodies counted once)"

    if accum > 1:
        # The accumulation loop is a scan: probe costs are per-microbatch.
        corrected = {k: v * accum for k, v in corrected.items()}
    terms = hlo_analysis.roofline(
        corrected["flops"], corrected["hbm_bytes"], corrected["coll_bytes"],
        residency_bytes=mem["per_device_total_bytes"],
    )
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    params_shapes = extras["params_shapes"]
    active = _active_param_count(cfg, params_shapes)
    mf = hlo_analysis.model_flops_6nd(active, tokens)
    if shape.kind != "train":
        mf /= 3.0  # forward-only

    record.update(
        status="ok",
        chips=n_chips,
        memory=mem,
        raw_cost=raw,
        cost=corrected,
        roofline=terms.to_dict(),
        model_flops=mf,
        model_flops_total_ratio=(mf / max(corrected["flops"] * n_chips, 1.0)),
        hbm_gb_per_device=mem["per_device_total_bytes"] / 1e9,
        fits_v5e_16gb=mem["per_device_total_bytes"] < 16e9,
        total_s=time.time() - t0,
    )
    if save:
        _save(tag, record)
    if verbose:
        r = record["roofline"]
        print(
            f"[dryrun] {tag}: OK {record['total_s']:.0f}s "
            f"| {mem['per_device_total_bytes']/1e9:.2f} GB/dev "
            f"| compute {r['compute_s']*1e3:.3f}ms mem {r['memory_s_min']*1e3:.3f}-{r['memory_s_hlo']*1e3:.0f}ms "
            f"coll {r['collective_s']*1e3:.3f}ms -> {r['dominant']} "
            f"| useful-flops {record['model_flops_total_ratio']:.2f}"
        )
    return record


def _save(tag: str, record: dict) -> None:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    rec = {k: v for k, v in record.items() if k != "params_shapes"}
    with open(os.path.join(ARTIFACT_DIR, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=2, default=float)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fedpart", action="store_true")
    ap.add_argument("--group", type=int, default=None,
                    help="FedPart trainable group index (default 12 = mid-stack)")
    ap.add_argument("--moe-ep", action="store_true",
                    help="explicit shard_map expert parallelism (perf path)")
    ap.add_argument("--mesh", default=None,
                    help="override mesh shape, e.g. 32,8 or 2,16,16")
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches (train shapes)")
    ap.add_argument("--act-shard", action="store_true",
                    help="attention-score/residual sharding constraints (perf path)")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--no-save", action="store_true")
    args = ap.parse_args(argv)

    archs = ASSIGNED_ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    pairs = [(a, s) for a in archs for s in shapes]

    failures = 0
    for arch, shape in pairs:
        try:
            g = (args.group if args.group is not None else 12) if args.fedpart else None
            analyze_pair(
                arch, shape, multi_pod=args.multi_pod, fedpart_group=g,
                probes=not args.no_probes, moe_ep=args.moe_ep,
                act_shard=args.act_shard, mesh_shape=args.mesh,
                accum=args.accum, save=not args.no_save,
            )
        except Exception:
            failures += 1
            print(f"[dryrun] {arch} x {shape} FAILED:")
            traceback.print_exc()
    print(f"[dryrun] done: {len(pairs) - failures}/{len(pairs)} OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
