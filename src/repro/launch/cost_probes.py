"""Exact cost extraction via probe compiles.

Problem: the production step functions scan over layer stacks (compile-time
and HBM-accurate), but XLA's ``cost_analysis`` counts a while-loop body ONCE,
so FLOPs / bytes / collective-bytes are understated by the trip count.
Fully unrolling fixes the counts but breaks rematerialisation (XLA CSEs the
recomputation away), destroying the memory picture — measured in §Perf notes.

Resolution: per (arch, shape, mesh) we compile 1–3 tiny *probe* variants of
the same architecture (1–2 layers per stack) fully unrolled, and fit the
exact linear model

    cost = base + Σ_stacks  n_s · per_layer_s

which is exact for homogeneous stacks (ours are, by construction).  The
production scan compile supplies the memory analysis; the probe fit supplies
FLOPs / HBM bytes / collective bytes at full depth.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ProbePlan:
    """Probe configs, their stack-count rows, and the full config's row.

    rows[i] are the coefficients [1, n_s1, n_s2, ...] of probe i;
    full_row are the coefficients of the full-size config."""

    probe_cfgs: tuple[ModelConfig, ...]
    rows: np.ndarray
    full_row: np.ndarray


def probe_plan(cfg: ModelConfig) -> ProbePlan:
    if cfg.kind == "decoder":
        if cfg.is_moe and cfg.first_dense_layers > 0:
            # two stacks: dense (first_dense_layers) + moe (rest)
            p1 = cfg.with_(num_layers=2, first_dense_layers=1)
            p2 = cfg.with_(num_layers=3, first_dense_layers=2)
            p3 = cfg.with_(num_layers=3, first_dense_layers=1)
            rows = np.array([[1, 1, 1], [1, 2, 1], [1, 1, 2]], float)
            full = np.array(
                [1, cfg.first_dense_layers, cfg.num_layers - cfg.first_dense_layers],
                float,
            )
            return ProbePlan((p1, p2, p3), rows, full)
        # single homogeneous stack (dense, or all-moe)
        p1 = cfg.with_(num_layers=1, first_dense_layers=0)
        p2 = cfg.with_(num_layers=2, first_dense_layers=0)
        rows = np.array([[1, 1], [1, 2]], float)
        return ProbePlan((p1, p2), rows, np.array([1, cfg.num_layers], float))
    if cfg.kind == "xlstm":
        p1 = cfg.with_(num_layers=2)   # 1 pair
        p2 = cfg.with_(num_layers=4)   # 2 pairs
        rows = np.array([[1, 1], [1, 2]], float)
        return ProbePlan((p1, p2), rows, np.array([1, cfg.num_layers // 2], float))
    if cfg.kind == "hybrid":
        per = max(cfg.attn_every, 1)
        n_chunks, tail = cfg.num_layers // per, cfg.num_layers % per
        p1 = cfg.with_(num_layers=per)          # 1 chunk, 0 tail
        p2 = cfg.with_(num_layers=2 * per)      # 2 chunks
        p3 = cfg.with_(num_layers=per + 1)      # 1 chunk, 1 tail
        rows = np.array([[1, 1, 0], [1, 2, 0], [1, 1, 1]], float)
        return ProbePlan((p1, p2, p3), rows, np.array([1, n_chunks, tail], float))
    if cfg.kind == "encdec":
        p1 = cfg.with_(encoder_layers=1, num_layers=1)
        p2 = cfg.with_(encoder_layers=2, num_layers=1)
        p3 = cfg.with_(encoder_layers=1, num_layers=2)
        rows = np.array([[1, 1, 1], [1, 2, 1], [1, 1, 2]], float)
        return ProbePlan(
            (p1, p2, p3), rows,
            np.array([1, cfg.encoder_layers or cfg.num_layers, cfg.num_layers], float),
        )
    raise ValueError(cfg.kind)


def fit_and_extrapolate(
    plan: ProbePlan, probe_costs: list[dict[str, float]]
) -> dict[str, float]:
    """Solve the linear model per metric and evaluate at the full row."""
    keys = probe_costs[0].keys()
    out = {}
    for k in keys:
        y = np.array([c[k] for c in probe_costs], float)
        coef, *_ = np.linalg.lstsq(plan.rows, y, rcond=None)
        val = float(plan.full_row @ coef)
        out[k] = max(val, 0.0)
    return out
