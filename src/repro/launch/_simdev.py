"""Pre-jax-import helper: force N simulated CPU host devices.

XLA reads ``XLA_FLAGS`` once, at backend init, so this must run before the
first ``import jax`` anywhere in the process — which is why this module
imports nothing but the stdlib and why entry points (launch/fedtrain.py,
examples/quickstart.py, benchmarks/engine_bench.py) call it at the very top
of the file, before their other imports pull jax in.
"""

from __future__ import annotations

import os
import sys


def force_sim_devices(argv: list[str] | None = None) -> None:
    """Scan argv for ``--sim-devices N`` / ``--sim-devices=N``; for N > 1,
    append ``--xla_force_host_platform_device_count=N`` to ``XLA_FLAGS``.

    Missing or non-numeric values are ignored here — argparse sees the same
    argv later and prints the real usage error.
    """
    argv = sys.argv[1:] if argv is None else argv
    val = None
    for i, arg in enumerate(argv):
        if arg == "--sim-devices" and i + 1 < len(argv):
            val = argv[i + 1]
        elif arg.startswith("--sim-devices="):
            val = arg.split("=", 1)[1]
    try:
        n = int(val) if val is not None else 0
    except ValueError:
        return
    if n > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}"
        ).strip()
