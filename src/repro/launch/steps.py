"""Step functions lowered by the launcher / dry-run.

- ``train_step``          — FNU baseline: full-network Adam step.
- ``fedpart_train_step``  — the paper's technique on the production mesh:
  gradients + optimizer state + gradient collectives restricted to one layer
  group (a static layer index into the stacked block params, plus the
  embed/head groups).  XLA prunes the dead backward graph; the gradient
  all-reduce shrinks to the group's bytes (DESIGN.md §3).
- ``prefill_step``        — full-sequence forward + KV cache write.
- ``serve_step``          — one-token decode against the cache.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import api
from repro.optim.adam import AdamConfig, AdamState, adam_init, adam_update

PyTree = Any

STACK_KEYS = ("blocks", "moe_blocks", "pairs", "chunks", "tail", "enc_blocks", "dec_blocks")


# ---------------------------------------------------------------------------
# FNU train step
# ---------------------------------------------------------------------------

def _microbatches(batch, accum: int):
    """Split the leading batch axis into ``accum`` microbatches (stacked)."""
    return jax.tree.map(
        lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]), batch
    )


def make_train_step(cfg: ModelConfig, adam: AdamConfig = AdamConfig(), *,
                    impl: str = "xla", remat: bool = True, unroll: int = 1,
                    accum: int = 1):
    """FNU step.  ``accum`` > 1 scans gradient accumulation over microbatches
    — activation residency scales with the microbatch, the optimizer applies
    the mean gradient once (§Perf iteration 5)."""

    def loss_fn(p, b):
        return api.loss(p, cfg, b, impl=impl, remat=remat, unroll=unroll)

    def train_step(params, opt_state: AdamState, batch):
        if accum <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = _microbatches(batch, accum)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                lv, g = jax.value_and_grad(loss_fn)(params, mb)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + lv), None

            zeros = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params
            )
            (g_sum, l_sum), _ = jax.lax.scan(
                acc_body, (zeros, jnp.float32(0.0)), micro
            )
            grads = jax.tree.map(lambda g: g / accum, g_sum)
            loss = l_sum / accum
        new_params, new_state = adam_update(grads, opt_state, params, adam)
        return new_params, new_state, loss

    return train_step


def init_opt_state(params: PyTree) -> AdamState:
    return adam_init(params)


# ---------------------------------------------------------------------------
# FedPart partial train step (stacked-layer grouping)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StackedGroup:
    """One FedPart layer group of a stacked model: layer ``index`` of the
    stack at ``params[key]``; or the non-stacked subtree at ``key`` when
    ``index`` is None (embed / head / final_norm / shared_attn)."""

    key: str
    index: int | None = None


def list_groups(params: PyTree) -> list[StackedGroup]:
    """Enumerate FedPart groups shallow->deep for a stacked model."""
    groups: list[StackedGroup] = []
    if "embed" in params:
        groups.append(StackedGroup("embed"))
    for key in STACK_KEYS:
        if key in params:
            n = jax.tree.leaves(params[key])[0].shape[0]
            groups.extend(StackedGroup(key, i) for i in range(n))
    for key in ("shared_attn", "mtp"):
        if key in params:
            groups.append(StackedGroup(key))
    tail_keys = [k for k in ("final_norm", "enc_norm", "enc_pos", "dec_pos", "head") if k in params]
    if tail_keys:
        # norms/positions/head travel with the head group (Appendix-A style)
        groups.append(StackedGroup("|".join(tail_keys)))
    return groups


def _select_group(params: PyTree, group: StackedGroup) -> PyTree:
    if group.index is not None:
        return jax.tree.map(lambda x: x[group.index], params[group.key])
    keys = group.key.split("|")
    return {k: params[k] for k in keys}


def _inject_group(params: PyTree, group: StackedGroup, sub: PyTree) -> PyTree:
    out = dict(params)
    if group.index is not None:
        out[group.key] = jax.tree.map(
            lambda full, t: jax.lax.dynamic_update_index_in_dim(
                full, t.astype(full.dtype), group.index, 0
            ),
            params[group.key],
            sub,
        )
        return out
    for k, v in sub.items():
        out[k] = v
    return out


def make_fedpart_train_step(
    cfg: ModelConfig,
    group: StackedGroup,
    adam: AdamConfig = AdamConfig(),
    *,
    impl: str = "xla",
    remat: bool = True,
    unroll: int = 1,
):
    """Partial step: grads/optimizer state only for ``group``.

    opt_state is over the group's subtree (1/M of full-model state)."""

    def train_step(params, opt_state: AdamState, batch):
        trainable = _select_group(params, group)

        def loss_fn(sub):
            return api.loss(_inject_group(params, group, sub), cfg, batch,
                            impl=impl, remat=remat, unroll=unroll)

        loss, grads = jax.value_and_grad(loss_fn)(trainable)
        new_sub, new_state = adam_update(grads, opt_state, trainable, adam)
        return _inject_group(params, group, new_sub), new_state, loss

    return train_step


def init_partial_opt_state(params: PyTree, group: StackedGroup) -> AdamState:
    return adam_init(_select_group(params, group))


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, *, window: int = 0, impl: str = "xla",
                      unroll: int = 1):
    def prefill_step(params, batch):
        logits, cache, _ = api.forward(
            params, cfg, batch, window=window, impl=impl, collect_cache=True,
            unroll=unroll,
        )
        return logits[:, -1:, :], cache

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, window: int = 0, unroll: int = 1):
    def serve_step(params, token, cache, pos):
        return api.decode_step(params, cfg, token, cache, pos, window=window,
                               unroll=unroll)

    return serve_step
