"""Production meshes.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model").

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import)."""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / local runs)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_client_mesh(devices: int = 0) -> jax.sharding.Mesh:
    """1-D mesh with a single ``"clients"`` axis for the FL simulation's
    shard_map engine (``repro.fl.batched.ShardMapEngine``): the round's
    stacked client axis is sharded over it, one vmapped shard per device.

    ``devices=0`` takes every visible device; otherwise the first ``devices``
    of them.  On CPU, simulate a multi-device host with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before the
    first jax import).
    """
    avail = jax.devices()
    n = len(avail) if devices in (0, None) else int(devices)
    if n < 1 or n > len(avail):
        raise ValueError(
            f"requested {devices} mesh devices but only {len(avail)} are "
            "visible; on CPU set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=N before the first jax import"
        )
    return jax.sharding.Mesh(np.asarray(avail[:n]), ("clients",))


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """The data-parallel axes (FL-client axes): ("pod","data") when present."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh: jax.sharding.Mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
