"""Production meshes.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model").

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import)."""

from __future__ import annotations

import dataclasses
import threading

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / local runs)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_client_mesh(devices: int = 0) -> jax.sharding.Mesh:
    """1-D mesh with a single ``"clients"`` axis for the FL simulation's
    shard_map engine (``repro.fl.batched.ShardMapEngine``): the round's
    stacked client axis is sharded over it, one vmapped shard per device.

    ``devices=0`` takes every visible device; otherwise the first ``devices``
    of them.  On CPU, simulate a multi-device host with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before the
    first jax import).
    """
    avail = jax.devices()
    n = len(avail) if devices in (0, None) else int(devices)
    if n < 1 or n > len(avail):
        raise ValueError(
            f"requested {devices} mesh devices but only {len(avail)} are "
            "visible; on CPU set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=N before the first jax import"
        )
    return jax.sharding.Mesh(np.asarray(avail[:n]), ("clients",))


@dataclasses.dataclass(frozen=True)
class Submesh:
    """One disjoint slice of the client mesh, as handed out by ``SubmeshPool``.

    Carries both the raw device tuple and a ready 1-D ``"clients"`` mesh over
    them, so an engine binding can either commit single-device inputs
    (``devices[0]``; the vmap engine) or shard the stacked client axis
    (``mesh``; the shard_map engine)."""

    index: int
    devices: tuple
    mesh: jax.sharding.Mesh = dataclasses.field(compare=False, hash=False)

    @property
    def width(self) -> int:
        return len(self.devices)


class SubmeshPool:
    """Disjoint-submesh allocator over ``make_client_mesh``.

    The host-parallel async runtime (``repro.fl.runtime``) trains up to
    ``max_inflight_cohorts`` cohorts concurrently; each one runs on its own
    *submesh* — a contiguous slice of the client mesh's devices — so the
    cohorts' compiled programs never contend for the same device.  The pool
    hands submeshes out (``acquire``) and takes them back (``release``) with
    three invariants:

    * **no overlap** — submeshes partition a prefix of the device list; a
      device belongs to at most one submesh (asserted at construction);
    * **exclusive lease** — an acquired submesh cannot be acquired again
      until released; releasing a free or foreign submesh raises;
    * **bounded** — ``acquire`` on an exhausted pool returns ``None`` (the
      caller queues; it never blocks or over-subscribes).

    All submeshes share one width (``total // num_submeshes`` by default), so
    equal-shape cohort programs can share a single trace across them (the
    engines' AbstractMesh binding — docs/ENGINES.md).  Leftover devices that
    don't fill a full-width submesh stay unused.  Thread-safe: ``acquire`` /
    ``release`` may be called from dispatch callbacks.
    """

    def __init__(self, num_submeshes: int, devices: int = 0,
                 width: int | None = None):
        base = make_client_mesh(devices)
        devs = tuple(base.devices.flat)
        if num_submeshes < 1:
            raise ValueError(f"num_submeshes must be >= 1, got {num_submeshes}")
        num = min(num_submeshes, len(devs))
        w = (len(devs) // num) if width is None else int(width)
        if w < 1 or num * w > len(devs):
            raise ValueError(
                f"cannot cut {num} submeshes of width {w} from {len(devs)} "
                "devices")
        self.submeshes: tuple[Submesh, ...] = tuple(
            Submesh(index=i, devices=devs[i * w: (i + 1) * w],
                    mesh=jax.sharding.Mesh(
                        np.asarray(devs[i * w: (i + 1) * w]), ("clients",)))
            for i in range(num)
        )
        seen: set = set()
        for sm in self.submeshes:
            for d in sm.devices:
                assert d not in seen, f"device {d} in two submeshes"
                seen.add(d)
        self._free: list[int] = list(range(num - 1, -1, -1))  # pop -> index 0 first
        self._lock = threading.Lock()

    @property
    def num_submeshes(self) -> int:
        return len(self.submeshes)

    @property
    def width(self) -> int:
        return self.submeshes[0].width

    @property
    def free_count(self) -> int:
        with self._lock:
            return len(self._free)

    def acquire(self) -> Submesh | None:
        """Lowest-index free submesh, or ``None`` when exhausted."""
        with self._lock:
            if not self._free:
                return None
            return self.submeshes[self._free.pop()]

    def release(self, sub: Submesh) -> None:
        with self._lock:
            if not (0 <= sub.index < len(self.submeshes)
                    and self.submeshes[sub.index].devices == sub.devices):
                raise ValueError(f"submesh {sub.index} is not from this pool")
            if sub.index in self._free:
                raise ValueError(f"submesh {sub.index} released twice")
            self._free.append(sub.index)
            self._free.sort(reverse=True)   # keep index-0-first acquire order


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """The data-parallel axes (FL-client axes): ("pod","data") when present."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh: jax.sharding.Mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
