"""Serving driver: batched prefill + decode for any assigned architecture.

CPU-scale by default (smoke config); the production path is exercised by the
dry-run (prefill_32k / decode_32k / long_500k shapes).

    python -m repro.launch.serve --arch tinyllama-1.1b --batch 4 \
        --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch import steps
from repro.models import api
from repro.models.api import InputShape


def serve_session(cfg, batch: int, prompt_len: int, gen: int, seed: int = 0,
                  verbose: bool = True):
    """Prefill a random prompt batch, then greedy-decode ``gen`` tokens."""
    key = jax.random.key(seed)
    params = api.init(key, cfg)
    shape = InputShape("serve", prompt_len, batch, "prefill")
    prompt = api.synth_batch(jax.random.fold_in(key, 1), cfg, shape)

    cache_len = prompt_len + gen
    prefill = jax.jit(steps.make_prefill_step(cfg))
    serve = jax.jit(steps.make_serve_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, prompt)
    cache = _grow_attention_caches(cache, prompt_len, cache_len)
    prefill_s = time.time() - t0
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)

    toks = [tok]
    t1 = time.time()
    for i in range(gen - 1):
        pos = jnp.int32(prompt_len + i)
        logits, cache = serve(params, tok, cache, pos)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        toks.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.time() - t1
    out = jnp.concatenate(toks, axis=1)
    if verbose:
        print(f"[serve] prefill {batch}x{prompt_len} in {prefill_s:.2f}s | "
              f"decode {gen} tokens in {decode_s:.2f}s "
              f"({batch * gen / max(decode_s, 1e-9):.1f} tok/s)")
    return out


_ATTN_CACHE_KEYS = {"k", "v", "c_kv", "k_rope", "self_k", "self_v"}


def _grow_attention_caches(cache, prompt_len: int, cache_len: int):
    """Pad attention caches (stacked (L,B,S,...) layout, seq axis 2) from the
    prefill length to prompt+gen.  SSM/conv states and encoder cross-KV are
    untouched."""

    def grow(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
        if name in _ATTN_CACHE_KEYS and leaf.ndim >= 4 and leaf.shape[2] == prompt_len:
            pad = [(0, 0)] * leaf.ndim
            pad[2] = (0, cache_len - prompt_len)
            return jnp.pad(leaf, pad)
        return leaf

    return jax.tree_util.tree_map_with_path(grow, cache)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full", action="store_true",
                    help="use the full-size config (dry-run scale; not for CPU)")
    args = ap.parse_args(argv)
    cfg = get_config(args.arch, smoke=not args.full)
    serve_session(cfg, args.batch, args.prompt_len, args.gen)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
