"""Per-parameter / per-input sharding rules for the production mesh.

Strategy (DESIGN.md §5):

1. Named rules for the big matmuls — megatron-style tensor parallelism over
   the "model" axis (attention hidden, FFN hidden, expert axis) with an
   optional FSDP extension sharding d_model over "data" for the largest
   archs.
2. A greedy fallback for everything else: shard the largest divisible dim
   over "model" (and over "data" under FSDP) — this guarantees every leaf of
   every arch lowers, including awkward cases (whisper's 51865 vocab,
   zamba2's 112 SSM heads) where the named rule would not divide.

Activations: batch over the data-parallel axes ("pod","data"); long_500k
(batch=1) shards the cache *sequence* axis over "data" instead.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.partition import tree_paths, path_str
from repro.launch.mesh import axis_size, dp_axes

PyTree = Any


# ---------------------------------------------------------------------------
# Named rules: (path regex, spec builder).  Specs are given for the *unstacked*
# suffix dims; a leading layer-stack dim (if present) is prepended as None.
# A rule returns None to decline (e.g. when dims don't divide).
# ---------------------------------------------------------------------------

def _col(model: int, fsdp: bool, data: int):
    """(d_in, d_out) column-parallel: out on model, in on data under FSDP."""

    def rule(shape):
        if shape[-1] % model:
            return None
        din = "data" if (fsdp and shape[-2] % data == 0) else None
        return (din, "model")

    return rule


def _row(model: int, fsdp: bool, data: int):
    """(d_in, d_out) row-parallel: in on model, out on data under FSDP."""

    def rule(shape):
        if shape[-2] % model:
            return None
        dout = "data" if (fsdp and shape[-1] % data == 0) else None
        return ("model", dout)

    return rule


def _expert_col(model: int, fsdp: bool, data: int):
    """(E, d, f): experts on model (expert parallelism)."""

    def rule(shape):
        if shape[-3] % model:
            return None
        return ("model", "data" if (fsdp and shape[-2] % data == 0) else None, None)

    return rule


def _expert_row(model: int, fsdp: bool, data: int):
    def rule(shape):
        if shape[-3] % model:
            return None
        return ("model", None, "data" if (fsdp and shape[-1] % data == 0) else None)

    return rule


def _vocab_embed(model: int, fsdp: bool, data: int):
    """(V, d): shard vocab on model when divisible, else d."""

    def rule(shape):
        if shape[-2] % model == 0:
            return ("model", None)
        if shape[-1] % model == 0:
            return (None, "model")
        return None

    return rule


def param_rules(model: int, data: int, fsdp: bool):
    col = _col(model, fsdp, data)
    row = _row(model, fsdp, data)
    return [
        # attention projections
        (r"(attn|self_attn|cross_attn)/(wq|wk|wv|wq_b|wkv_b)/w$", col),
        (r"(attn|self_attn|cross_attn)/wo/w$", row),
        (r"attn/(wq_a|wkv_a)/w$", col),
        # dense MLPs (incl. shared experts)
        (r"(mlp|shared)/(w_gate|w_up|w_in)/w$", col),
        (r"(mlp|shared)/(w_down|w_out)/w$", row),
        # MoE experts
        (r"experts/(w_gate|w_up)$", _expert_col(model, fsdp, data)),
        (r"experts/w_down$", _expert_row(model, fsdp, data)),
        (r"router/w$", lambda shape: (None, None)),
        # SSM family
        (r"(mamba|mlstm)/(in_proj|up_proj|wq|wk|wv)/w$", col),
        (r"(mamba|mlstm)/(out_proj|down_proj)/w$", row),
        (r"slstm/w_x/w$", col),
        (r"slstm/out_proj/w$", row),
        # embeddings / heads
        (r"embed/table$", _vocab_embed(model, fsdp, data)),
        (r"head/w$", col),
    ]


def _greedy_spec(shape: tuple[int, ...], model: int, data: int, fsdp: bool):
    """Fallback: largest dim divisible by ``model`` gets "model"; under FSDP
    the largest remaining dim divisible by ``data`` gets "data"."""
    spec: list = [None] * len(shape)
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if shape[i] >= model and shape[i] % model == 0:
            spec[i] = "model"
            break
    if fsdp:
        for i in order:
            if spec[i] is None and shape[i] >= data and shape[i] % data == 0:
                spec[i] = "data"
                break
    return tuple(spec)


def param_spec(
    path: str,
    shape: tuple[int, ...],
    *,
    model: int,
    data: int,
    fsdp: bool = False,
    stacked: bool = True,
) -> P:
    """PartitionSpec for one parameter leaf."""
    if len(shape) == 0:
        return P()
    for pattern, rule in param_rules(model, data, fsdp):
        if re.search(pattern, path):
            base = rule(shape)
            if base is None:
                continue
            pad = len(shape) - len(base)
            if pad < 0:   # rule written for more dims than leaf has
                continue
            return P(*([None] * pad), *base)
    if len(shape) == 1:
        return P(None)
    # >=3D leaves are treated as layer-stacked: never shard the leading dim.
    inner = shape[1:] if (stacked and len(shape) >= 3) else shape
    spec = _greedy_spec(inner, model, data, fsdp)
    if len(inner) != len(shape):
        spec = (None, *spec)
    return P(*spec)


def params_shardings(
    params_shapes: PyTree, mesh: jax.sharding.Mesh, *, fsdp: bool = False
) -> PyTree:
    """NamedSharding pytree for a params(-like) pytree of ShapeDtypeStructs."""
    model = axis_size(mesh, "model")
    data = axis_size(mesh, "data")
    flat = tree_paths(params_shapes)
    specs = {}
    for path, leaf in flat:
        ps = path_str(path)
        specs[ps] = NamedSharding(
            mesh, param_spec(ps, tuple(leaf.shape), model=model, data=data, fsdp=fsdp)
        )

    def assign(path, leaf):
        ps = "/".join(_entry(e) for e in path)
        return specs[ps]

    return jax.tree_util.tree_map_with_path(assign, params_shapes)


def _entry(e):
    import jax.tree_util as jtu

    if isinstance(e, jtu.DictKey):
        return str(e.key)
    if isinstance(e, jtu.SequenceKey):
        return str(e.idx)
    return str(e)


# ---------------------------------------------------------------------------
# Activation / input shardings
# ---------------------------------------------------------------------------

def batch_spec(shape: tuple[int, ...], mesh: jax.sharding.Mesh) -> P:
    """Token/label/embedding inputs: batch over the DP axes when divisible."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([axis_size(mesh, a) for a in dp]))
    if shape and shape[0] % dp_size == 0 and shape[0] > 0:
        return P(dp, *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def cache_spec(shape: tuple[int, ...], mesh: jax.sharding.Mesh) -> P:
    """KV/SSM cache leaves.  Layout conventions (dims from the left):
    (L, B, S, ...) attention caches; (L, B, ...) state caches.

    batch -> DP axes when divisible; else the sequence axis (long_500k,
    batch=1) -> "data"; heads/feature dims -> "model" greedily."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([axis_size(mesh, a) for a in dp]))
    model = axis_size(mesh, "model")
    data = axis_size(mesh, "data")
    spec: list = [None] * len(shape)
    if len(shape) < 2:
        return P(*spec)
    # dim 0 is the layer stack for stacked caches; batch is dim 1 when the
    # cache is stacked, dim 0 otherwise.  Heuristic: treat the first dim <= 256
    # following a small leading dim as batch.
    b_dim = 1 if len(shape) >= 3 else 0
    if shape[b_dim] % dp_size == 0:
        spec[b_dim] = dp
    elif len(shape) > b_dim + 1 and shape[b_dim + 1] % data == 0 and shape[b_dim + 1] >= data:
        spec[b_dim + 1] = "data"   # sequence-parallel cache
    # "model" on the largest remaining divisible dim (prefer trailing dims).
    for i in range(len(shape) - 1, b_dim, -1):
        if spec[i] is None and shape[i] >= model and shape[i] % model == 0:
            spec[i] = "model"
            break
    return P(*spec)


def input_shardings(specs: PyTree, mesh: jax.sharding.Mesh, *, is_cache_fn=None) -> PyTree:
    """Shardings for an input_specs dict: batch rules for arrays, cache rules
    for anything under a "cache" key."""

    def assign(path, leaf):
        keys = [_entry(e) for e in path]
        shape = tuple(leaf.shape)
        if "cache" in keys:
            return NamedSharding(mesh, cache_spec(shape, mesh))
        return NamedSharding(mesh, batch_spec(shape, mesh))

    return jax.tree_util.tree_map_with_path(assign, specs)
