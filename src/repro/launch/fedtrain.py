"""Production FedPart trainer: the paper's round schedule driving the
*mesh-parallel* step functions (steps.py) on any architecture config.

This is the bridge between the two halves of the repo: `fl/` simulates many
clients on CPU for the paper-faithful experiments; THIS driver runs FedPart
as a datacenter training feature — each round jit-executes either the FNU
step or the partial step for the scheduled layer group, with the gradient
collectives and optimizer state scoped to that group (DESIGN.md §3).
Round boundaries ARE the communication rounds: under data parallelism the
per-step gradient all-reduce plays the role of server aggregation (the
clients-as-data-shards mapping).

CPU-runnable at smoke scale:

    python -m repro.launch.fedtrain --arch tinyllama-1.1b --rounds 8 \
        --steps-per-round 4 --rl 1

It also fronts the many-client *simulation* half (fl/) so the engine choice is
a launch-surface flag: ``--sim-clients N`` runs the paper-faithful federation
on a synthetic vision task with ``--engine sequential`` (per-client oracle
loop, the default — the conv model hits vmap's grouped-conv slow path on
XLA:CPU), ``--engine vmap`` (batched vmap-over-clients), or
``--engine shard_map`` (clients sharded over ``--sim-devices`` mesh devices;
on CPU the flag also forces that many simulated host devices — see
docs/ENGINES.md):

    python -m repro.launch.fedtrain --sim-clients 8 --rounds 12 --engine vmap
    python -m repro.launch.fedtrain --sim-clients 8 --rounds 12 \
        --engine shard_map --sim-devices 4

``--runtime async`` swaps the barrier-per-round loop for the event-driven
simulator (``repro.fl.runtime``, docs/ASYNC.md): partial participation
(``--participation``), buffered staleness-weighted aggregation
(``--buffer-k``, ``--staleness-exp``) and a seeded client
availability/latency model (``--speed-spread``, ``--latency-jitter``,
``--dropout``), with time-to-accuracy booked on a virtual clock.
``--max-inflight N`` keeps N cohorts training concurrently, each on its own
disjoint device submesh (host-parallel dispatch, docs/ASYNC.md):

    python -m repro.launch.fedtrain --sim-clients 8 --rounds 12 \
        --engine vmap --runtime async --participation 0.5 --buffer-k 2 \
        --staleness-exp 0.5 --speed-spread 3.0 --max-inflight 2

``--controller adaptive`` closes the server control loop (docs/CONTROL.md):
between merges the server observes a window of the virtual timeline and
re-targets the in-flight cohort count, the FedBuff goal K, and the next
layer group, within ``--controller-inflight-bounds`` /
``--controller-buffer-bounds`` / ``--controller-max-repeats``:

    python -m repro.launch.fedtrain --sim-clients 8 --rounds 12 \
        --engine vmap --runtime async --participation 0.25 \
        --staleness-exp 0.5 --speed-spread 3.0 --controller adaptive

``--trace diurnal --duty-cycle 0.25 0.9`` drives participation from
deterministic per-client on/off windows instead of the i.i.d.
``--unavailable`` coin; ``--participation-sampling biased`` then weights
cohort selection by current availability and inverse-probability debiases
the merge, and ``--controller-participation-target`` /
``--controller-plan-boost-max`` close the loop on cohort size and
capacity-tier plan depth (docs/ASYNC.md, docs/CONTROL.md):

    python -m repro.launch.fedtrain --sim-clients 8 --rounds 12 \
        --engine vmap --runtime async --participation 0.5 \
        --trace diurnal --duty-cycle 0.25 0.9 --trace-period 2.0 \
        --participation-sampling biased --controller adaptive \
        --controller-participation-target 0.5

``--plan nested --capacity-tiers 0.3 0.6 1.0`` gives capacity-tiered clients
*different layer subsets in the same round* (per-client layer plans,
docs/HETEROGENEITY.md); each group is aggregated over only the clients that
trained it:

    python -m repro.launch.fedtrain --sim-clients 8 --rounds 12 \
        --engine vmap --plan nested --capacity-tiers 0.3 0.6 1.0

``--compression int8|onebit|topk`` quantises/sparsifies the transmitted
subtree at the client→server boundary with per-client error feedback
(docs/COMPRESSION.md); the comm ledger then prices the encoded wire format:

    python -m repro.launch.fedtrain --sim-clients 8 --rounds 12 \
        --engine vmap --compression int8

``--population N`` swaps the materialised client list for a *streaming*
``fl.population.SyntheticPopulation`` of N virtual clients whose shards are
derived on demand from (seed, client_id) — host cost per round is O(cohort),
so N can be millions (docs/POPULATION.md).  ``--cohort-size K`` pins the
dispatch size directly (the natural knob at population scale);
``--state-store-entries`` / ``--state-store-spill`` bound the per-client
MOON/EF state:

    python -m repro.launch.fedtrain --population 1000000 --cohort-size 8 \
        --rounds 12 --runtime async --participation 0.5
"""

from __future__ import annotations

import argparse
import time
from typing import Any

if __name__ == "__main__":
    # --sim-devices N on CPU simulates an N-device host; XLA reads the flag
    # at first-import time, so it must be set before jax loads below.
    from repro.launch._simdev import force_sim_devices
    force_sim_devices()

import jax
import numpy as np

from repro.configs import get_config
from repro.core.schedule import FULL_NETWORK, FedPartSchedule, RoundSpec
from repro.launch import steps
from repro.models import api
from repro.models.api import InputShape
from repro.optim.adam import AdamConfig

PyTree = Any


class FedPartMeshTrainer:
    """Round loop cycling layer groups over jitted partial steps.

    One jitted step per distinct group is cached; optimizer state is
    re-initialised per round over the group's subtree (paper semantics:
    clients start each round fresh from the broadcast model)."""

    def __init__(self, cfg, adam: AdamConfig = AdamConfig(), *,
                 remat: bool = False, donate: bool = True):
        self.cfg = cfg
        self.adam = adam
        self.remat = remat
        self._full = jax.jit(steps.make_train_step(cfg, adam, remat=remat))
        self._partial: dict[int, Any] = {}
        self._groups: list[steps.StackedGroup] | None = None

    def groups(self, params) -> list[steps.StackedGroup]:
        if self._groups is None:
            self._groups = steps.list_groups(params)
        return self._groups

    def _partial_step(self, params, gidx: int):
        if gidx not in self._partial:
            group = self.groups(params)[gidx]
            self._partial[gidx] = jax.jit(
                steps.make_fedpart_train_step(self.cfg, group, self.adam,
                                              remat=self.remat)
            )
        return self._partial[gidx]

    def run_round(self, params, spec: RoundSpec, batches) -> tuple[PyTree, float]:
        """One communication round: several local steps of the scheduled
        group (or the full network), fresh optimizer state."""
        if spec.is_full:
            opt = steps.init_opt_state(params)
            step = self._full
        else:
            gidx = spec.group % len(self.groups(params))
            opt = steps.init_partial_opt_state(params, self.groups(params)[gidx])
            step = self._partial_step(params, gidx)
        losses = []
        for batch in batches:
            params, opt, loss = step(params, opt, batch)
            losses.append(float(loss))
        return params, float(np.mean(losses))

    def transmitted_params(self, params, spec: RoundSpec) -> int:
        """Parameter count this round's aggregation moves (ledger)."""
        if spec.is_full:
            return int(sum(x.size for x in jax.tree.leaves(params)))
        group = self.groups(params)[spec.group % len(self.groups(params))]
        sub = steps._select_group(params, group)
        return int(sum(x.size for x in jax.tree.leaves(sub)))


def run_simulation(args) -> int:
    """Many-client FL simulation (fl/ stack) behind the launch surface."""
    from repro.core.schedule import FedPartSchedule
    from repro.data import (VisionDatasetSpec, balanced_eval_set, build_clients,
                            iid_partition, make_vision_dataset)
    from repro.fl import (AvailabilityConfig, FLRunConfig, resnet_task,
                          run_federated)
    from repro.fl.population import SyntheticPopulation

    spec = VisionDatasetSpec(num_classes=8, image_size=16)
    Xe, ye = make_vision_dataset(spec, 400, seed=99)
    eval_set = balanced_eval_set(Xe, ye, per_class=24)
    if args.population > 0:
        # Streaming population: shards derive lazily from (seed, client_id);
        # nothing O(population) is ever built (docs/POPULATION.md).
        clients = SyntheticPopulation(spec=spec, population=args.population,
                                      samples_per_client=160, seed=0)
        n_clients = args.population
    else:
        X, y = make_vision_dataset(spec, 160 * args.sim_clients, seed=0)
        clients = build_clients(
            X, y, iid_partition(len(y), args.sim_clients, seed=0))
        n_clients = args.sim_clients
    adapter = resnet_task("resnet8", num_classes=8)
    cycles = max(1, -(-args.rounds // (10 * args.rl)))   # just enough rounds
    sched = FedPartSchedule(num_groups=10, warmup_rounds=args.warmup,
                            rounds_per_layer=args.rl, cycles=cycles)
    cfg = FLRunConfig(local_epochs=1, batch_size=args.batch, lr=args.lr,
                      engine=args.engine, sim_devices=args.sim_devices,
                      fused_adam=args.fused_adam,
                      runtime=args.runtime, async_policy=args.async_policy,
                      buffer_k=args.buffer_k,
                      staleness_exponent=args.staleness_exp,
                      sample_fraction=args.participation,
                      cohort_size=args.cohort_size,
                      participation_sampling=args.participation_sampling,
                      state_store_entries=args.state_store_entries,
                      state_store_spill=args.state_store_spill,
                      max_inflight_cohorts=args.max_inflight,
                      controller=args.controller,
                      controller_window=args.controller_window,
                      controller_inflight_bounds=tuple(
                          args.controller_inflight_bounds),
                      controller_buffer_bounds=tuple(
                          args.controller_buffer_bounds),
                      controller_mix_floor=args.controller_mix_floor,
                      controller_max_repeats=args.controller_max_repeats,
                      controller_participation_target=(
                          args.controller_participation_target),
                      controller_cohort_bounds=tuple(
                          args.controller_cohort_bounds),
                      controller_plan_boost_max=args.controller_plan_boost_max,
                      plan=args.plan,
                      capacity_tiers=tuple(args.capacity_tiers),
                      compression=args.compression,
                      topk_fraction=args.topk_fraction,
                      error_feedback=not args.no_error_feedback,
                      compression_block_rows=args.compression_block_rows,
                      availability=AvailabilityConfig(
                          speed_spread=args.speed_spread,
                          latency_jitter=args.latency_jitter,
                          dropout_prob=args.dropout,
                          unavailable_prob=args.unavailable,
                          trace=args.trace,
                          trace_period=args.trace_period,
                          duty_cycle=tuple(args.duty_cycle),
                          trace_path=args.trace_path))
    t0 = time.time()
    res = run_federated(adapter, clients, eval_set,
                        sched.rounds()[: args.rounds], cfg, verbose=True)
    extra = ""
    if res.timeline is not None:
        stale = [h["staleness_max"] for h in res.history]
        extra = (f" vtime={res.timeline.total_seconds:.3f}s "
                 f"max_staleness={max(stale) if stale else 0}")
    print(f"[fedtrain.sim] engine={args.engine} runtime={args.runtime} "
          f"clients={n_clients} rounds={args.rounds} "
          f"in {time.time()-t0:.1f}s | best_acc={res.best_acc:.4f} "
          f"comm={res.comm_total_bytes/max(res.comm_fnu_bytes,1):.2%} of FNU"
          f"{extra}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--full-size", action="store_true",
                    help="full config (mesh scale); default smoke")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--steps-per-round", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--rl", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--sim-clients", type=int, default=0,
                    help="simulate N federated clients (fl/ stack) instead of "
                         "the mesh trainer")
    ap.add_argument("--population", type=int, default=0,
                    help="stream N virtual clients from a seeded "
                         "SyntheticPopulation instead of materialising "
                         "--sim-clients shards up front; per-round host cost "
                         "is O(cohort), so N can be millions "
                         "(docs/POPULATION.md)")
    ap.add_argument("--cohort-size", type=int, default=0,
                    help="explicit clients per dispatch/round (0 = "
                         "--participation fraction of the fleet); the natural "
                         "knob under --population")
    ap.add_argument("--state-store-entries", type=int, default=0,
                    help="LRU cap on per-client MOON/EF state entries "
                         "(0 = unbounded, the legacy behavior)")
    ap.add_argument("--state-store-spill", default="",
                    help="directory to spill evicted per-client state to "
                         "(empty = evicted entries are dropped)")
    ap.add_argument("--engine", choices=["sequential", "vmap", "shard_map"],
                    default="sequential",
                    help="client engine for --sim-clients: per-client oracle "
                         "loop (default), batched vmap-over-clients, or "
                         "mesh-sharded shard_map (see --sim-devices)")
    ap.add_argument("--fused-adam", action="store_true",
                    help="run local steps through the Pallas masked-Adam "
                         "kernel (packed optimizer state; interpret mode "
                         "off-TPU — docs/KERNELS.md)")
    ap.add_argument("--sim-devices", type=int, default=0,
                    help="shard_map mesh size over the 'clients' axis "
                         "(0 = all visible devices; on CPU, N>1 also forces "
                         "N simulated host devices)")
    ap.add_argument("--runtime", choices=["sync", "async"], default="sync",
                    help="round execution model for --sim-clients: barrier "
                         "per round, or the event-driven async simulator "
                         "(docs/ASYNC.md)")
    ap.add_argument("--async-policy", choices=["fedbuff", "sync"],
                    default="fedbuff",
                    help="async aggregation policy: FedBuff goal-K buffer or "
                         "the per-cohort barrier oracle")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="fraction of clients sampled per dispatch/round")
    ap.add_argument("--participation-sampling", choices=["blind", "biased"],
                    default="blind",
                    help="async cohort selection: rejection-sample the "
                         "arrival process blind (default), or weight "
                         "candidates by current availability and debias the "
                         "merge by inverse inclusion probability "
                         "(docs/ASYNC.md)")
    ap.add_argument("--buffer-k", type=int, default=0,
                    help="FedBuff merge goal K (0 = cohort size)")
    ap.add_argument("--staleness-exp", type=float, default=0.0,
                    help="polynomial staleness discount exponent a in "
                         "(1+staleness)^-a")
    ap.add_argument("--max-inflight", type=int, default=1,
                    help="cohorts concurrently in flight under --runtime "
                         "async: 1 = merge-driven dispatch, >1 trains that "
                         "many cohorts at once on disjoint device submeshes "
                         "(docs/ASYNC.md)")
    ap.add_argument("--controller", choices=["static", "adaptive"],
                    default="static",
                    help="server control loop under --runtime async "
                         "(docs/CONTROL.md): static config (default, no "
                         "controller object) or the adaptive bundle that "
                         "re-targets --max-inflight, --buffer-k, and the "
                         "layer-group schedule between merges")
    ap.add_argument("--controller-window", type=int, default=4,
                    help="merges per controller observation window")
    ap.add_argument("--controller-inflight-bounds", type=int, nargs=2,
                    default=[1, 4], metavar=("LO", "HI"),
                    help="adaptive in-flight cohort target bounds")
    ap.add_argument("--controller-buffer-bounds", type=int, nargs=2,
                    default=[1, 8], metavar=("LO", "HI"),
                    help="adaptive FedBuff goal-K bounds")
    ap.add_argument("--controller-mix-floor", type=float, default=0.5,
                    help="windowed discounted-mixing-coefficient floor the "
                         "staleness controller defends")
    ap.add_argument("--controller-max-repeats", type=int, default=2,
                    help="max consecutive layer-group repeats the progress "
                         "controller may schedule")
    ap.add_argument("--controller-participation-target", type=float,
                    default=0.0,
                    help="windowed effective-participation target the "
                         "participation controller holds by re-sizing the "
                         "cohort (0 = controller off; docs/CONTROL.md)")
    ap.add_argument("--controller-cohort-bounds", type=int, nargs=2,
                    default=[1, 64], metavar=("LO", "HI"),
                    help="adaptive cohort-size bounds for the participation "
                         "controller")
    ap.add_argument("--controller-plan-boost-max", type=int, default=0,
                    help="max extra layer groups the plan-assignment "
                         "controller may grant stalled-tier clients "
                         "(0 = controller off; needs --plan nested|random)")
    ap.add_argument("--plan", choices=["homogeneous", "nested", "random"],
                    default="homogeneous",
                    help="per-client layer plan for --sim-clients "
                         "(docs/HETEROGENEITY.md): every client trains the "
                         "scheduled group (default), FedPLT-style capacity "
                         "prefixes, or seeded random per-client group subsets")
    ap.add_argument("--capacity-tiers", type=float, nargs="*", default=[],
                    help="capacity fractions in (0, 1], one per tier, clients "
                         "assigned round-robin (e.g. 0.3 0.6 1.0); empty = "
                         "one full-capacity tier")
    ap.add_argument("--compression",
                    choices=["none", "int8", "onebit", "topk"],
                    default="none",
                    help="transmitted-subtree compression for --sim-clients "
                         "(docs/COMPRESSION.md): symmetric int8, 1-bit "
                         "sign+scale, or top-k sparsification, each with "
                         "per-client error feedback")
    ap.add_argument("--topk-fraction", type=float, default=0.01,
                    help="retained fraction per leaf under --compression topk")
    ap.add_argument("--no-error-feedback", action="store_true",
                    help="disable the per-client error-feedback residual "
                         "(compressed kinds only)")
    ap.add_argument("--compression-block-rows", type=int, default=0,
                    help="quantisation scale granularity: 0 = one scale per "
                         "leaf, B = one per B*128-element block (the masked-"
                         "Adam packed-row layout, docs/KERNELS.md)")
    ap.add_argument("--speed-spread", type=float, default=0.0,
                    help="per-client compute-speed heterogeneity (log-uniform "
                         "spread; 0 = homogeneous fleet)")
    ap.add_argument("--latency-jitter", type=float, default=0.0,
                    help="per-dispatch multiplicative latency noise")
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="per-dispatch probability a client update is lost")
    ap.add_argument("--unavailable", type=float, default=0.0,
                    help="per-dispatch probability a sampled client is "
                         "offline (the i.i.d. arrival knob)")
    ap.add_argument("--trace", choices=["", "diurnal", "file"], default="",
                    help="trace-driven availability: deterministic per-client "
                         "periodic on/off windows (diurnal) or an on-disk "
                         "trace (file; see --trace-path)")
    ap.add_argument("--trace-period", type=float, default=16.0,
                    help="virtual seconds per on/off trace cycle")
    ap.add_argument("--duty-cycle", type=float, nargs=2, default=[1.0, 1.0],
                    metavar=("LO", "HI"),
                    help="per-client on-fraction range for --trace diurnal")
    ap.add_argument("--trace-path", default="",
                    help="availability trace file (.npz or JSON with "
                         "duty/phase arrays) for --trace file")
    args = ap.parse_args(argv)

    if args.sim_clients > 0 or args.population > 0:
        return run_simulation(args)

    cfg = get_config(args.arch, smoke=not args.full_size)
    key = jax.random.key(0)
    params = api.init(key, cfg)
    trainer = FedPartMeshTrainer(cfg, AdamConfig(lr=args.lr))
    n_groups = len(trainer.groups(params))
    sched = FedPartSchedule(num_groups=n_groups, warmup_rounds=args.warmup,
                            rounds_per_layer=args.rl, cycles=10_000)
    shape = InputShape("t", args.seq, args.batch, "train")

    total_tx, full_tx = 0, 0
    t0 = time.time()
    for spec in sched.rounds()[: args.rounds]:
        batches = [
            api.synth_batch(jax.random.fold_in(key, spec.index * 100 + i), cfg, shape)
            for i in range(args.steps_per_round)
        ]
        params, loss = trainer.run_round(params, spec, batches)
        tx = trainer.transmitted_params(params, spec)
        total_tx += tx
        full_tx += trainer.transmitted_params(params, RoundSpec(0, "warmup", -1, FULL_NETWORK))
        tag = "FNU " if spec.is_full else f"g={spec.group:3d}"
        print(f"[fedtrain] round {spec.index:3d} [{tag}] loss={loss:.4f} "
              f"tx={tx/1e6:.2f}M params")
    print(f"[fedtrain] {args.rounds} rounds in {time.time()-t0:.0f}s | "
          f"comm={total_tx/max(full_tx,1):.2%} of FNU")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
