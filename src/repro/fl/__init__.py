from repro.fl.algorithms import AlgoConfig  # noqa: F401
from repro.fl.batched import (ENGINES, SequentialEngine, ShardMapEngine,  # noqa: F401
                              VmapEngine, make_engine)
from repro.fl.client import LocalTrainer  # noqa: F401
from repro.fl.population import (ClientPopulation, ClientStateStore,  # noqa: F401
                                 MaterializedPopulation, SyntheticPopulation,
                                 as_population)
from repro.fl.runtime import (AvailabilityConfig, ClientAvailability,  # noqa: F401
                              run_federated_async)
from repro.fl.server import (RUNTIMES, FLResult, FLRunConfig,  # noqa: F401
                             run_federated)
from repro.fl.tasks import TaskAdapter, nlp_task, resnet_task  # noqa: F401
