from repro.fl.algorithms import AlgoConfig  # noqa: F401
from repro.fl.batched import (ENGINES, SequentialEngine, ShardMapEngine,  # noqa: F401
                              VmapEngine, make_engine)
from repro.fl.client import LocalTrainer  # noqa: F401
from repro.fl.server import FLResult, FLRunConfig, run_federated  # noqa: F401
from repro.fl.tasks import TaskAdapter, nlp_task, resnet_task  # noqa: F401
