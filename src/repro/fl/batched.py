"""Batched client-simulation engines: vmap-over-clients round execution.

The sequential oracle (``SequentialEngine``, the original ``run_federated``
inner loop) dispatches O(clients x steps) jitted calls per round and syncs the
host on every step's loss.  ``VmapEngine`` replaces that with two compiled
dispatches per (phase, group):

1. *local training*: the selected clients' batches are stacked along a
   leading client axis (``data.pipeline.stack_client_batches``) and the whole
   local round runs as one ``jax.vmap``-over-clients program with a
   ``lax.scan`` over steps inside — partial rounds share the group's pruned
   backward graph across every client;
2. *aggregation*: stacked-leaf weighted reductions on device
   (``core.aggregation.*_stacked``), BN running moments excluded exactly as
   in the host path.

Ragged client datasets follow the pad-and-mask contract: clients are bucketed
by effective batch width ``min(batch_size, n)`` (one compiled program per
width) and padded step-wise inside a bucket; padded steps compute but their
parameter/optimizer updates and losses are discarded via ``step_valid``, so
the engine matches the sequential oracle leaf-for-leaf (see
``tests/test_engine_equivalence.py``).

Both engines expose ``trace_count`` (XLA traces built so far) — the quantity
``benchmarks/engine_bench.py`` reports next to wall-clock.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, masking
from repro.core.partition import Partition
from repro.core.schedule import FULL_NETWORK, RoundSpec
from repro.data.pipeline import ClientDataset, stack_client_batches
from repro.fl.algorithms import AlgoConfig
from repro.fl.client import LocalTrainer
from repro.optim.adam import adam_init

PyTree = Any

ENGINES = ("sequential", "vmap")


@dataclasses.dataclass
class SequentialEngine:
    """Reference oracle: one client at a time, aggregation on host."""

    trainer: LocalTrainer
    partition: Partition
    algo: AlgoConfig
    name: str = "sequential"

    @property
    def trace_count(self) -> int:
        return self.trainer.trace_count

    def run_round(
        self,
        params: PyTree,
        spec: RoundSpec,
        datasets: Sequence[ClientDataset],
        *,
        seeds: Sequence[int],
        weights: Sequence[float],
        epochs: int,
        batch_size: int,
        prev_params: Sequence[PyTree | None] | None = None,
        tracker=None,
    ) -> tuple[PyTree, list[float], list[PyTree] | None]:
        keep_locals = self.algo.name == "moon"
        uploads, losses, new_locals = [], [], ([] if keep_locals else None)
        for i, (ds, seed) in enumerate(zip(datasets, seeds)):
            local, loss = self.trainer.run_local_round(
                params,
                spec.group,
                ds,
                epochs=epochs,
                batch_size=batch_size,
                seed=seed,
                prev_params=prev_params[i] if prev_params is not None else None,
                step_tracker=tracker if i == 0 else None,
            )
            losses.append(loss)
            if keep_locals:
                new_locals.append(local)
            if spec.is_full:
                uploads.append(local)
            else:
                uploads.append(masking.select(local, self.partition, spec.group))
        if spec.is_full:
            new_params = aggregation.aggregate_full(params, uploads, weights)
        else:
            new_params = aggregation.aggregate_partial(params, uploads, weights)
        return new_params, losses, new_locals


@dataclasses.dataclass
class VmapEngine:
    """Batched engine: whole round = vmapped local training + on-device agg."""

    trainer: LocalTrainer
    partition: Partition
    algo: AlgoConfig
    name: str = "vmap"

    def __post_init__(self):
        self.trace_count = 0
        self._local_fns: dict[tuple[int, bool], Callable] = {}
        self._agg_fns: dict[int, Callable] = {}

    # -- compiled-program builders ----------------------------------------

    def _local_fn(self, group: int, stacked_prev: bool) -> Callable:
        """Jitted vmap-over-clients local round for ``group`` (FULL_NETWORK
        for FNU).  Cached per (group, prev-layout); batch/step widths retrace
        via jit's shape cache."""
        key = (group, stacked_prev)
        if key in self._local_fns:
            return self._local_fns[key]

        step_fn = (
            self.trainer.make_full_step()
            if group < 0
            else self.trainer.make_partial_step(group)
        )
        partition = self.partition

        def one_client(global_params, inputs, labels, step_valid, prev):
            if group < 0:
                opt0 = adam_init(global_params)
            else:
                opt0 = adam_init(masking.select(global_params, partition, group))

            def body(carry, xs):
                params, opt = carry
                x, y, valid = xs
                new_p, new_o, loss = step_fn(params, opt, x, y, global_params, prev)
                keep = valid > 0
                params = jax.tree.map(lambda a, b: jnp.where(keep, a, b), new_p, params)
                opt = jax.tree.map(lambda a, b: jnp.where(keep, a, b), new_o, opt)
                return (params, opt), jnp.where(keep, loss.astype(jnp.float32), 0.0)

            (params, _), step_losses = jax.lax.scan(
                body, (global_params, opt0), (inputs, labels, step_valid)
            )
            mean_loss = jnp.sum(step_losses) / jnp.maximum(jnp.sum(step_valid), 1.0)
            return params, mean_loss

        prev_axis = 0 if stacked_prev else None

        def local_round(global_params, inputs, labels, step_valid, prev):
            self.trace_count += 1  # trace-time side effect: compiled replays skip it
            return jax.vmap(one_client, in_axes=(None, 0, 0, 0, prev_axis))(
                global_params, inputs, labels, step_valid, prev
            )

        self._local_fns[key] = jax.jit(local_round)
        return self._local_fns[key]

    def _agg_fn(self, group: int) -> Callable:
        if group in self._agg_fns:
            return self._agg_fns[group]
        partition = self.partition

        def agg(global_params, stacked, weights):
            self.trace_count += 1
            if group < 0:
                return aggregation.aggregate_full_stacked(global_params, stacked, weights)
            return aggregation.aggregate_partial_stacked(
                global_params, stacked, partition, group, weights
            )

        self._agg_fns[group] = jax.jit(agg)
        return self._agg_fns[group]

    # -- round execution ---------------------------------------------------

    def run_round(
        self,
        params: PyTree,
        spec: RoundSpec,
        datasets: Sequence[ClientDataset],
        *,
        seeds: Sequence[int],
        weights: Sequence[float],
        epochs: int,
        batch_size: int,
        prev_params: Sequence[PyTree | None] | None = None,
        tracker=None,
    ) -> tuple[PyTree, list[float], list[PyTree] | None]:
        if tracker is not None:
            raise ValueError(
                "per-step step-size tracking needs engine='sequential' "
                "(the vmap engine never materialises per-step params)"
            )
        # The aggregation normalisation runs inside jit where weights are
        # traced — guard the degenerate case here, mirroring tree_mean's
        # host-side check in the sequential engine.
        if float(sum(weights)) <= 0.0:
            raise ValueError(
                f"client weights must sum to a positive value, got {sum(weights)}"
            )
        group = FULL_NETWORK if spec.is_full else spec.group
        use_prev = self.algo.name == "moon"
        num = len(datasets)

        parts: list[tuple[tuple[int, ...], PyTree, jax.Array]] = []
        for bucket in stack_client_batches(datasets, batch_size, epochs, seeds):
            if use_prev:
                prev_arg = masking.stack_trees([
                    prev_params[p] if prev_params is not None and prev_params[p] is not None else params
                    for p in bucket.members
                ])
            else:
                prev_arg = params
            fn = self._local_fn(group, stacked_prev=use_prev)
            locals_stacked, bucket_losses = fn(
                params, bucket.inputs, bucket.labels, bucket.step_valid, prev_arg
            )
            parts.append((bucket.members, locals_stacked, bucket_losses))

        if len(parts) == 1 and parts[0][0] == tuple(range(num)):
            stacked = parts[0][1]
            losses_dev = parts[0][2]
        else:
            # Multiple batch-width buckets: concatenate along the client axis
            # and restore the round's picked-client order.
            order = np.concatenate([np.asarray(m) for m, _, _ in parts])
            inv = jnp.asarray(np.argsort(order))
            stacked = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0)[inv],
                *[t for _, t, _ in parts],
            )
            losses_dev = jnp.concatenate([l for _, _, l in parts])[inv]

        new_params = self._agg_fn(group)(
            params, stacked, jnp.asarray(weights, dtype=jnp.float32)
        )
        losses = [float(x) for x in np.asarray(losses_dev)]
        new_locals = masking.unstack_tree(stacked, num) if use_prev else None
        return new_params, losses, new_locals


def make_engine(
    name: str, *, trainer: LocalTrainer, partition: Partition, algo: AlgoConfig
):
    if name == "sequential":
        return SequentialEngine(trainer=trainer, partition=partition, algo=algo)
    if name == "vmap":
        return VmapEngine(trainer=trainer, partition=partition, algo=algo)
    raise ValueError(f"unknown engine {name!r}; expected one of {ENGINES}")
