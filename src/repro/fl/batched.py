"""Batched client-simulation engines: vmap- and shard_map-over-clients.

The sequential oracle (``SequentialEngine``, the original ``run_federated``
inner loop) dispatches O(clients x steps) jitted calls per round and syncs the
host on every step's loss.  The two batched engines replace that with a
handful of compiled dispatches per (phase, group), sharing one *pad-and-mask
local-round core* (``_BatchedEngineBase``):

* ``VmapEngine`` — the selected clients' batches are stacked along a leading
  client axis (``data.pipeline.stack_client_batches``) and the whole local
  round runs as one ``jax.vmap``-over-clients program with a ``lax.scan`` over
  steps inside, followed by one on-device stacked aggregation
  (``core.aggregation.*_stacked``).  Single device.
* ``ShardMapEngine`` — the stacked client axis is distributed over a 1-D
  ``jax.sharding.Mesh`` ("clients" axis, ``launch.mesh.make_client_mesh``)
  via ``shard_map``: each device vmaps the local round over its shard of
  clients, and aggregation is an on-mesh ``psum`` of weight-scaled updates —
  only the round's *transmitted* subtree (the trainable group on partial
  rounds, BN running moments always excluded) ever crosses devices, mirroring
  the paper's communication claim.  Clients are padded up to a multiple of
  the mesh size (zero-weight padding clients; see ``stack_client_batches``).

Ragged client datasets follow the pad-and-mask contract: clients are bucketed
by effective batch width ``min(batch_size, n)`` (one compiled program per
width) and padded step-wise inside a bucket; padded steps compute but their
parameter/optimizer updates and losses are discarded via ``step_valid``, so
the engines match the sequential oracle leaf-for-leaf (see
``tests/test_engine_equivalence.py`` and docs/ENGINES.md).

All engines expose ``trace_count`` (XLA traces built so far) — the quantity
``benchmarks/engine_bench.py`` reports next to wall-clock.

Heterogeneous cohorts (per-client layer plans, ``core.schedule.PlanAssigner``,
docs/HETEROGENEITY.md): every entry point takes ``plan=`` — a ``(clients, M)``
group bitmask.  ``resolve_plan`` collapses homogeneous plans to ``None`` so
the legacy single-group programs (and their numerics) are kept structurally;
a genuinely mixed cohort runs the *masked plan program* instead: the bitmask
becomes a stacked per-client batch input to one compiled FNU-shaped step
(Eq. 1's literal masked form — see ``_one_client_plan_fn``), the sequential
oracle trains each client's exact pruned group set, and aggregation averages
each layer group over only the clients that trained it
(``core.aggregation.aggregate_plan*``; the shard_map engine psums per-group
participant-weighted sums on-mesh).

Beyond ``run_round`` (train + aggregate, the synchronous contract), every
engine also exposes ``run_local_async`` — cohort training *without*
aggregation, returning the still-in-flight stacked locally-trained params
(``run_local`` is its blocking wrapper).  That is the async runtime's
execution backend (``repro.fl.runtime``): a dispatched cohort is one stacked
batch through the same compiled local-round core, and aggregation happens
later in the server policy, possibly against a newer global model.  For
host-parallel dispatch (``FLRunConfig.max_inflight_cohorts`` > 1),
``cohort_pool`` carves the engine's devices into disjoint submeshes
(``launch.mesh.SubmeshPool``) and ``run_local_async(submesh=...)`` binds a
cohort's program to one — width-1 device-following jit for the vmap engine,
an AbstractMesh-traced shard_map for the sharded engine — so equal-width
submeshes share a single trace and concurrent cohorts never contend for a
device (docs/ENGINES.md, docs/ASYNC.md).

Transmission compression (``core.compress``, docs/COMPRESSION.md): engines
built with ``compression=`` (a ``CompressionConfig``; ``None`` = off, the
byte-identical legacy paths) apply the quantize→dequantize transmission step
to every client's update at the transmission boundary — the sequential oracle
and the vmap engine right before aggregation, the shard_map engine *inside*
the device program before the weight-scale psum (so only compressed-value
subtrees ever cross the mesh).  Error-feedback residuals are per real client:
``run_round`` then requires ``client_ids=`` so residuals persist across
rounds regardless of cohort composition.  The async runtime compresses
host-side at update resolution instead (``repro.fl.runtime.engine``), so
``run_local_async`` always returns *uncompressed* locals.

With ``donate=True`` (default) the batched engines donate the global params
into the aggregation jit (in-place splice — ``run_round`` then *consumes* its
params argument; thread the returned tree) and the stacked MOON prev-model
tree into the local-round jit.  ``benchmarks/engine_bench.py`` times every
batched engine both ways and reports the delta.

Example (any engine is a drop-in swap at the config level)::

    from repro.fl import FLRunConfig, run_federated
    cfg = FLRunConfig(engine="vmap")                      # single device
    cfg = FLRunConfig(engine="shard_map", sim_devices=0)  # all devices
    run_federated(adapter, clients, eval_set, rounds, cfg)

or directly, one round at a time::

    engine = make_engine("shard_map", trainer=trainer,
                         partition=partition, algo=algo, sim_devices=2)
    new_params, losses, _ = engine.run_round(
        params, spec, datasets, seeds=seeds, weights=weights,
        epochs=1, batch_size=32)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import aggregation, compress, masking
from repro.core.compat import SHARD_MAP_NO_CHECK_KW as _SHARD_MAP_KW
from repro.core.compat import shard_map as _shard_map
from repro.core.partition import Partition
from repro.core.schedule import FULL_NETWORK, RoundSpec, round_base_mask
from repro.data.pipeline import ClientDataset, stack_client_batches
from repro.fl.algorithms import AlgoConfig
from repro.fl.client import LocalTrainer
from repro.kernels.masked_adam import ops as madam_ops
from repro.optim.adam import adam_init
from repro.optim.partial import fused_adam_init, guard_fused_config

PyTree = Any

ENGINES = ("sequential", "vmap", "shard_map")

CLIENT_AXIS = "clients"  # mesh axis name the shard_map engine reduces over

FUSED_BLOCK_ROWS = 8     # kernel block granularity the fused engines pack to


def _transmitted_rows(params: PyTree, partition: Partition, groups,
                      block_rows: int = FUSED_BLOCK_ROWS) -> np.ndarray:
    """Static packed-row indices of the round's *transmitted* blocks: the
    trainable ``groups``' leaves minus BN running moments — exactly the
    subtree the unfused shard_map path selects + ``drop_local_stats``-es
    before its psum, expressed in ``ops.pack`` layout."""
    bm = madam_ops.block_mask_for_group(
        params, partition, groups, block_rows,
        exclude=aggregation.is_local_stat)
    blocks = np.flatnonzero(bm)
    return (blocks[:, None] * block_rows
            + np.arange(block_rows)[None, :]).reshape(-1)


def _plan_rows(params: PyTree, partition: Partition,
               block_rows: int = FUSED_BLOCK_ROWS
               ) -> tuple[np.ndarray, np.ndarray]:
    """Static (rows, per-row group ids) for plan rounds: every non-stat
    block travels (any client may have trained it), each row weighted by its
    group's per-client effective weight."""
    gids = madam_ops.block_group_ids(
        params, partition, block_rows, exclude=aggregation.is_local_stat)
    blocks = np.flatnonzero(gids >= 0)
    rows = (blocks[:, None] * block_rows
            + np.arange(block_rows)[None, :]).reshape(-1)
    return rows, np.repeat(gids[blocks], block_rows)


def resolve_plan(plan, spec: RoundSpec, num_groups: int):
    """Normalise a per-client layer plan (``core.schedule.PlanAssigner``).

    Returns ``None`` — keep the legacy single-group programs — when no plan
    was given *or* every row equals the round's homogeneous mask (all groups
    on FNU rounds, one-hot ``spec.group`` otherwise); the
    ``plan="homogeneous"`` == pre-plan behaviour guarantee is structural
    (same compiled programs, same arithmetic), not a numeric coincidence.
    Otherwise returns the validated ``(clients, M)`` bool array for the
    engines' plan paths (docs/HETEROGENEITY.md)."""
    if plan is None:
        return None
    p = np.asarray(plan, dtype=bool)
    if p.ndim != 2 or p.shape[1] != num_groups:
        raise ValueError(
            f"plan shape {p.shape} does not match {num_groups} layer groups")
    if not p.any(axis=1).all():
        raise ValueError("every client's plan must train at least one group")
    if (p == round_base_mask(spec, num_groups)[None, :]).all():
        return None
    return p


class _CompressionState:
    """Per-client error-feedback residual store shared by the engines.

    Residuals are keyed by the *real* client id (not the cohort position), so
    error feedback telescopes correctly across rounds with partial
    participation.  Entirely inert when ``self.compression is None`` — no
    state is allocated and no compression branch is ever taken.

    With a ``state_store`` (``fl.population.ClientStateStore``) attached the
    residuals live there instead of an unbounded dict — bounded LRU memory
    with optional disk spill, the population-scale contract
    (docs/POPULATION.md).  An evicted-and-spilled residual reloads
    value-exact; an evicted-and-dropped one restarts from zero (the caller
    opted into that by bounding the store without a spill dir)."""

    def _init_compression_state(self) -> None:
        self._residuals: dict[int, PyTree] = {}

    def _require_client_ids(self, client_ids, num: int) -> list[int] | None:
        if self.compression is None:
            return None
        if client_ids is None:
            raise ValueError(
                "compression needs client_ids= on run_round: error-feedback "
                "residuals persist per real client across rounds")
        ids = [int(c) for c in client_ids]
        if len(ids) != num:
            raise ValueError(f"{len(ids)} client_ids for {num} client datasets")
        return ids

    def _residual_for(self, cid: int, params: PyTree) -> PyTree:
        store = getattr(self, "state_store", None)
        res = (store.get("ef", cid) if store is not None
               else self._residuals.get(cid))
        return res if res is not None else compress.init_residual(params)

    def _set_residual(self, cid: int, tree: PyTree) -> None:
        store = getattr(self, "state_store", None)
        if store is not None:
            store.put("ef", cid, tree)
        else:
            self._residuals[cid] = tree


@dataclasses.dataclass
class SequentialEngine(_CompressionState):
    """Reference oracle: one client at a time, aggregation on host."""

    trainer: LocalTrainer
    partition: Partition
    algo: AlgoConfig
    fused_adam: bool = False
    compression: compress.CompressionConfig | None = None
    state_store: Any = None     # fl.population.ClientStateStore (EF residuals)
    name: str = "sequential"

    def __post_init__(self):
        self._init_compression_state()
        if self.fused_adam:
            guard_fused_config(self.trainer.adam)

    @property
    def trace_count(self) -> int:
        return self.trainer.trace_count

    def run_round(
        self,
        params: PyTree,
        spec: RoundSpec,
        datasets: Sequence[ClientDataset],
        *,
        seeds: Sequence[int],
        weights: Sequence[float],
        epochs: int,
        batch_size: int,
        prev_params: Sequence[PyTree | None] | None = None,
        tracker=None,
        plan=None,
        client_ids: Sequence[int] | None = None,
    ) -> tuple[PyTree, list[float], list[PyTree] | None]:
        plan = resolve_plan(plan, spec, self.partition.num_groups)
        ids = self._require_client_ids(client_ids, len(datasets))
        keep_locals = self.algo.name == "moon"
        uploads, losses, new_locals = [], [], ([] if keep_locals else None)
        for i, (ds, seed) in enumerate(zip(datasets, seeds)):
            groups_i = (tuple(int(g) for g in np.flatnonzero(plan[i]))
                        if plan is not None else None)
            local, loss = self.trainer.run_local_round(
                params,
                spec.group,
                ds,
                epochs=epochs,
                batch_size=batch_size,
                seed=seed,
                prev_params=prev_params[i] if prev_params is not None else None,
                step_tracker=tracker if i == 0 else None,
                groups=groups_i,
                fused=self.fused_adam,
            )
            losses.append(loss)
            if keep_locals:
                new_locals.append(local)     # MOON keeps the TRUE local model
            send = local
            if self.compression is not None:
                # Transmission boundary: what travels (and is aggregated) is
                # the compressed view global + Q(update + residual).
                tx_groups = (groups_i if plan is not None
                             else None if spec.is_full else (spec.group,))
                res = self._residual_for(ids[i], params)
                send, new_res = compress.transmit_tree(
                    params, local, res, self.compression,
                    partition=self.partition, groups=tx_groups)
                self._set_residual(ids[i], new_res)
            if plan is not None:
                uploads.append(masking.select(send, self.partition, groups_i))
            elif spec.is_full:
                uploads.append(send)
            else:
                uploads.append(masking.select(send, self.partition, spec.group))
        if plan is not None:
            new_params = aggregation.aggregate_plan(
                params, uploads, self.partition, plan, weights)
        elif spec.is_full:
            new_params = aggregation.aggregate_full(params, uploads, weights)
        else:
            new_params = aggregation.aggregate_partial(params, uploads, weights)
        return new_params, losses, new_locals

    def run_local(
        self,
        params: PyTree,
        spec: RoundSpec,
        datasets: Sequence[ClientDataset],
        *,
        seeds: Sequence[int],
        epochs: int,
        batch_size: int,
        prev_params: Sequence[PyTree | None] | None = None,
        plan=None,
    ) -> tuple[PyTree, list[float]]:
        """Cohort training without aggregation (async runtime backend): the
        per-client oracle loop, locals stacked into the common client-axis
        layout the policies consume."""
        plan = resolve_plan(plan, spec, self.partition.num_groups)
        locals_, losses = [], []
        for i, (ds, seed) in enumerate(zip(datasets, seeds)):
            local, loss = self.trainer.run_local_round(
                params, spec.group, ds,
                epochs=epochs, batch_size=batch_size, seed=seed,
                prev_params=prev_params[i] if prev_params is not None else None,
                groups=(tuple(int(g) for g in np.flatnonzero(plan[i]))
                        if plan is not None else None),
                fused=self.fused_adam,
            )
            locals_.append(local)
            losses.append(loss)
        return masking.stack_trees(locals_), losses

    def cohort_pool(self, max_inflight: int):
        """No device binding: the oracle trains eagerly on the default
        device (host-parallel dispatch still applies in *virtual* time)."""
        return None

    def run_local_async(
        self,
        params: PyTree,
        spec: RoundSpec,
        datasets: Sequence[ClientDataset],
        *,
        seeds: Sequence[int],
        epochs: int,
        batch_size: int,
        prev_params: Sequence[PyTree | None] | None = None,
        submesh=None,
        plan=None,
    ) -> tuple[PyTree, np.ndarray]:
        """Common cohort contract for the async runtime; the oracle has no
        deferred execution, so this is ``run_local`` with array losses."""
        if submesh is not None:
            raise ValueError("the sequential engine has no submesh binding")
        stacked, losses = self.run_local(
            params, spec, datasets, seeds=seeds, epochs=epochs,
            batch_size=batch_size, prev_params=prev_params, plan=plan)
        return stacked, np.asarray(losses, dtype=np.float32)


@dataclasses.dataclass
class _BatchedEngineBase(_CompressionState):
    """Shared pad-and-mask local-round core for the stacked engines.

    Owns the pieces both batched engines agree on:

    * ``_one_client_fn(group)`` — the scan-over-steps local round for a single
      client (padded steps masked via ``step_valid``), ready to be ``vmap``-ed
      over a client axis;
    * the bucketed batch plan (``_buckets``): one
      ``data.pipeline.stack_client_batches`` bucket per effective batch
      width, with the MOON prev-model stacking and padding-client handling;
    * ``_gather_order`` — concatenating per-bucket per-client outputs back
      into the round's picked-client order.

    Subclasses implement ``_local_fn`` (how a bucket's stacked clients are
    executed: plain ``vmap`` vs ``shard_map``-over-mesh) and ``run_round``
    (how the buckets' results are aggregated).
    """

    trainer: LocalTrainer
    partition: Partition
    algo: AlgoConfig
    donate: bool = True
    fused_adam: bool = False
    compression: compress.CompressionConfig | None = None
    state_store: Any = None     # fl.population.ClientStateStore (EF residuals)

    def __post_init__(self):
        self.trace_count = 0
        self._local_fns: dict[tuple[int, bool], Callable] = {}
        self._agg_fns: dict[Any, Callable] = {}
        self._cohort_fns: dict[tuple[int, bool], Callable] = {}
        self._init_compression_state()
        if self.fused_adam:
            guard_fused_config(self.trainer.adam)

    # Donation sets (active when ``donate``).  Only buffers whose shapes can
    # actually alias an output are donated — donating the stacked
    # inputs/labels would just trigger XLA's "not usable" warning:
    #
    # * the *global params* into the aggregation/splice jit (arg 0): output
    #   tree is leaf-for-leaf shape-identical, so the splice updates in
    #   place instead of holding two full models live.  This makes
    #   ``run_round`` consume its params argument — callers thread the
    #   returned tree (``run_federated`` always did).
    # * the *stacked MOON prev-model* tree into the local-round jit (arg 4):
    #   it is rebuilt host-side every round and matches the stacked-locals
    #   output exactly, saving one whole per-client model copy per bucket.

    def _donate_prev(self, stacked_prev: bool) -> tuple[int, ...]:
        return (4,) if (self.donate and stacked_prev) else ()

    def _donate_params(self) -> tuple[int, ...]:
        return (0,) if self.donate else ()

    # -- shared local-round core -------------------------------------------

    @staticmethod
    def _scan_local_steps(step_fn, global_params, opt0, inputs, labels,
                          step_valid, prev, leaf_bits=None):
        """The shared pad-and-mask scan over (possibly padded) steps: invalid
        steps compute but their parameter/optimizer updates and losses are
        discarded.  ``leaf_bits`` (per-client layer plans) additionally masks
        each leaf's parameter update by its group's plan bit, every step —
        frozen leaves stay re-pinned to the broadcast global."""

        def body(carry, xs):
            params, opt = carry
            x, y, valid = xs
            new_p, new_o, loss = step_fn(params, opt, x, y, global_params, prev)
            keep = valid > 0
            if leaf_bits is None:
                params = jax.tree.map(
                    lambda a, b: jnp.where(keep, a, b), new_p, params)
            else:
                params = jax.tree.map(
                    lambda a, b, bit: jnp.where(
                        jnp.logical_and(keep, bit > 0), a, b),
                    new_p, params, leaf_bits)
            opt = jax.tree.map(lambda a, b: jnp.where(keep, a, b), new_o, opt)
            return (params, opt), jnp.where(keep, loss.astype(jnp.float32), 0.0)

        (params, _), step_losses = jax.lax.scan(
            body, (global_params, opt0), (inputs, labels, step_valid)
        )
        mean_loss = jnp.sum(step_losses) / jnp.maximum(jnp.sum(step_valid), 1.0)
        return params, mean_loss

    def _one_client_fn(self, group: int) -> Callable:
        """Single-client local round (``_scan_local_steps`` over the pruned
        full/partial step for ``group``).  With ``fused_adam`` the step is
        the Pallas masked-Adam kernel over the packed (rows, 128) layout
        instead: same scan, same signature, packed optimizer state
        (docs/KERNELS.md)."""
        if self.fused_adam:
            step_fn = self.trainer.make_fused_step(
                None if group < 0 else group, FUSED_BLOCK_ROWS)

            def one_client(global_params, inputs, labels, step_valid, prev):
                opt0 = fused_adam_init(global_params, FUSED_BLOCK_ROWS)
                return self._scan_local_steps(
                    step_fn, global_params, opt0, inputs, labels, step_valid,
                    prev)

            return one_client

        step_fn = (
            self.trainer.make_full_step()
            if group < 0
            else self.trainer.make_partial_step(group)
        )
        partition = self.partition

        def one_client(global_params, inputs, labels, step_valid, prev):
            if group < 0:
                opt0 = adam_init(global_params)
            else:
                opt0 = adam_init(masking.select(global_params, partition, group))
            return self._scan_local_steps(
                step_fn, global_params, opt0, inputs, labels, step_valid, prev)

        return one_client

    def _one_client_plan_fn(self) -> Callable:
        """Single-client local round under a per-client layer plan.

        The FNU step runs every group's arithmetic and the client's ``(M,)``
        group bitmask masks the parameter update per leaf, each step — the
        paper's Eq. 1 literal masked form.  That is what lets ONE compiled
        program serve every plan row in a stacked cohort: the pruned-subtree
        form the homogeneous paths run would need one trace per distinct
        group set, defeating vmap/shard_map.  Frozen leaves are re-pinned to
        the broadcast global after every step, so trainable leaves see
        exactly the frozen context the pruned form sees (equivalence to the
        sequential oracle pinned in tests/test_engine_equivalence.py).
        Client-local statistics (BN running moments) always update,
        mirroring the pruned path's stats splice.

        With ``fused_adam`` the per-client bitmask instead becomes a traced
        per-*block* kernel mask (``ops.plan_block_mask``): untrained blocks
        are frozen inside the kernel itself, so no per-leaf re-pinning is
        needed — still one compiled program for every plan row."""
        if self.fused_adam:
            plan_step = self.trainer.make_fused_plan_step(FUSED_BLOCK_ROWS)

            def one_client(global_params, inputs, labels, step_valid, prev,
                           gmask):
                opt0 = fused_adam_init(global_params, FUSED_BLOCK_ROWS)

                def step_fn(p, o, x, y, gp, pv):
                    return plan_step(p, o, x, y, gp, pv, gmask)

                return self._scan_local_steps(
                    step_fn, global_params, opt0, inputs, labels, step_valid,
                    prev)

            return one_client

        step_fn = self.trainer.make_full_step()
        partition = self.partition

        def one_client(global_params, inputs, labels, step_valid, prev, gmask):
            opt0 = adam_init(global_params)

            def _bit(path, leaf):
                p = "/".join(masking._entry_str(e) for e in path)
                if aggregation.is_local_stat(p):
                    return jnp.float32(1.0)      # stats ride along unmasked
                return gmask[partition.group_of(p)]

            leaf_bits = jax.tree_util.tree_map_with_path(_bit, global_params)
            return self._scan_local_steps(
                step_fn, global_params, opt0, inputs, labels, step_valid,
                prev, leaf_bits=leaf_bits)

        return one_client

    def _local_fn(self, group: int, stacked_prev: bool) -> Callable:
        raise NotImplementedError

    # -- shared host-side plumbing -----------------------------------------

    @staticmethod
    def _bucket_gmask(plan: np.ndarray, bucket) -> np.ndarray:
        """This bucket's rows of the cohort plan, as the stacked ``(clients,
        M)`` float32 bitmask batch input (padding clients all-zero: they
        train nothing and carry no aggregation weight)."""
        g = np.zeros((bucket.num_clients, plan.shape[1]), dtype=np.float32)
        g[: bucket.num_real] = plan[list(bucket.members)]
        return g

    def _stacked_residuals(self, ids: Sequence[int], members: Sequence[int],
                           num_clients: int, params: PyTree) -> PyTree:
        """Stack the given cohort members' error-feedback residuals along the
        client axis (all-zero residuals for padding clients)."""
        rs = [self._residual_for(ids[m], params) for m in members]
        rs += [compress.init_residual(params)] * (num_clients - len(rs))
        return masking.stack_trees(rs)

    def _store_residuals(self, ids: Sequence[int], members: Sequence[int],
                         new_res_stacked: PyTree) -> None:
        """Write back per-client residual slices (padding rows dropped)."""
        for i, m in enumerate(members):
            self._set_residual(ids[m], jax.tree.map(
                lambda x, i=i: x[i], new_res_stacked))

    def _guard_round(self, weights: Sequence[float], tracker) -> None:
        if tracker is not None:
            raise ValueError(
                "per-step step-size tracking needs engine='sequential' "
                f"(the {self.name} engine never materialises per-step params)"
            )
        # The aggregation normalisation runs inside jit where weights are
        # traced — guard the degenerate case here, mirroring tree_mean's
        # host-side check in the sequential engine.
        if float(sum(weights)) <= 0.0:
            raise ValueError(
                f"client weights must sum to a positive value, got {sum(weights)}"
            )

    def _buckets(
        self,
        params: PyTree,
        datasets: Sequence[ClientDataset],
        *,
        batch_size: int,
        epochs: int,
        seeds: Sequence[int],
        prev_params: Sequence[PyTree | None] | None,
        use_prev: bool,
        pad_clients_to: int = 1,
    ):
        """Yield ``(bucket, prev_arg)`` per batch-width bucket.  ``prev_arg``
        is the MOON previous-local-model argument: stacked per client (padding
        clients fall back to the global model) when ``use_prev``, else the
        global params broadcast unbatched."""
        for bucket in stack_client_batches(
            datasets, batch_size, epochs, seeds, pad_clients_to=pad_clients_to
        ):
            if use_prev:
                prevs = [
                    prev_params[p] if prev_params is not None and prev_params[p] is not None else params
                    for p in bucket.members
                ]
                prevs += [params] * (bucket.num_clients - bucket.num_real)
                prev_arg = masking.stack_trees(prevs)
            else:
                prev_arg = params
            yield bucket, prev_arg

    @staticmethod
    def _gather_order(parts: list[tuple[tuple[int, ...], PyTree]], num: int) -> PyTree:
        """Concatenate per-bucket per-client outputs (leading client axis,
        already sliced to real members) back into picked-client order."""
        if len(parts) == 1 and parts[0][0] == tuple(range(num)):
            return parts[0][1]
        order = np.concatenate([np.asarray(m) for m, _ in parts])
        inv = jnp.asarray(np.argsort(order))
        return jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0)[inv], *[t for _, t in parts]
        )

    # -- cohort execution (async runtime backend) ---------------------------

    def _cohort_pad_for(self, submesh) -> int:
        """Client-axis padding multiple for cohort dispatches (the bound
        submesh's width for the shard_map engine, 1 otherwise)."""
        return 1

    def _cohort_fn(self, group: int, stacked_prev: bool, submesh=None) -> Callable:
        """Local-round program *without* aggregation: returns the stacked
        locally-trained params + per-client losses.  The async runtime's
        policies aggregate later, possibly against a newer global model.
        ``submesh`` binds the program to an explicit device set (host-parallel
        dispatch); ``None`` keeps the engine's default placement."""
        raise NotImplementedError

    def _plan_cohort_fn(self, stacked_prev: bool, submesh=None) -> Callable:
        """``_cohort_fn`` for heterogeneous cohorts: same contract with the
        stacked per-client group bitmask as a sixth batch input."""
        raise NotImplementedError

    def _place_cohort_args(self, args: tuple, submesh, *,
                           stacked_prev: bool) -> tuple:
        """Commit one bucket's ``(params, inputs, labels, step_valid, prev)``
        onto ``submesh``'s devices (no-op without a submesh)."""
        return args

    def cohort_pool(self, max_inflight: int):
        """A ``launch.mesh.SubmeshPool`` carving this engine's devices into
        up to ``max_inflight`` disjoint submeshes, or ``None`` when cohorts
        should keep the engine's default placement (``max_inflight == 1`` —
        the PR 3 regime — or an engine with no device binding)."""
        return None

    def run_local_async(
        self,
        params: PyTree,
        spec: RoundSpec,
        datasets: Sequence[ClientDataset],
        *,
        seeds: Sequence[int],
        epochs: int,
        batch_size: int,
        prev_params: Sequence[PyTree | None] | None = None,
        submesh=None,
        plan=None,
    ) -> tuple[PyTree, jax.Array]:
        """Train one *cohort* (clients dispatched together against the same
        global model) without syncing the host: returns
        ``(stacked_locals, losses_dev)`` where both are still-in-flight jax
        arrays — jax's async dispatch returns immediately, so the caller can
        launch further cohorts on other submeshes before materialising any
        results.  ``submesh`` (from ``cohort_pool``) commits the cohort's
        inputs to a disjoint device set; equal-width submeshes share one
        trace (the vmap engine's programs are device-agnostic, the shard_map
        engine traces over an AbstractMesh when this jax supports it).
        ``plan`` (a per-client group bitmask) swaps the single-group program
        for the masked plan program; a homogeneous plan collapses to the
        legacy path (``resolve_plan``)."""
        group = FULL_NETWORK if spec.is_full else spec.group
        plan = resolve_plan(plan, spec, self.partition.num_groups)
        use_prev = self.algo.name == "moon"
        num = len(datasets)

        parts: list[tuple[tuple[int, ...], tuple[PyTree, jax.Array]]] = []
        for bucket, prev_arg in self._buckets(
            params, datasets, batch_size=batch_size, epochs=epochs, seeds=seeds,
            prev_params=prev_params, use_prev=use_prev,
            pad_clients_to=self._cohort_pad_for(submesh),
        ):
            if plan is None:
                fn = self._cohort_fn(group, stacked_prev=use_prev,
                                     submesh=submesh)
                args = (params, bucket.inputs, bucket.labels,
                        bucket.step_valid, prev_arg)
            else:
                fn = self._plan_cohort_fn(stacked_prev=use_prev,
                                          submesh=submesh)
                args = (params, bucket.inputs, bucket.labels,
                        bucket.step_valid, prev_arg,
                        self._bucket_gmask(plan, bucket))
            args = self._place_cohort_args(args, submesh,
                                           stacked_prev=use_prev)
            locals_stacked, bucket_losses = fn(*args)
            n = bucket.num_real
            parts.append((bucket.members, (
                jax.tree.map(lambda x: x[:n], locals_stacked), bucket_losses[:n],
            )))

        return self._gather_order(parts, num)

    def run_local(
        self,
        params: PyTree,
        spec: RoundSpec,
        datasets: Sequence[ClientDataset],
        *,
        seeds: Sequence[int],
        epochs: int,
        batch_size: int,
        prev_params: Sequence[PyTree | None] | None = None,
        plan=None,
    ) -> tuple[PyTree, list[float]]:
        """Blocking ``run_local_async``: same cohort contract —
        ``stacked_locals`` carries a leading client axis in ``datasets``
        order (padding clients sliced off) — with the losses materialised as
        floats."""
        stacked, losses_dev = self.run_local_async(
            params, spec, datasets, seeds=seeds, epochs=epochs,
            batch_size=batch_size, prev_params=prev_params, plan=plan)
        return stacked, [float(x) for x in np.asarray(losses_dev)]


@dataclasses.dataclass
class VmapEngine(_BatchedEngineBase):
    """Batched engine: whole round = vmapped local training + on-device agg."""

    name: str = "vmap"

    # -- compiled-program builders ----------------------------------------

    def _local_fn(self, group: int, stacked_prev: bool) -> Callable:
        """Jitted vmap-over-clients local round for ``group`` (FULL_NETWORK
        for FNU).  Cached per (group, prev-layout); batch/step widths retrace
        via jit's shape cache."""
        key = (group, stacked_prev)
        if key in self._local_fns:
            return self._local_fns[key]

        one_client = self._one_client_fn(group)
        prev_axis = 0 if stacked_prev else None

        def local_round(global_params, inputs, labels, step_valid, prev):
            self.trace_count += 1  # trace-time side effect: compiled replays skip it
            return jax.vmap(one_client, in_axes=(None, 0, 0, 0, prev_axis))(
                global_params, inputs, labels, step_valid, prev
            )

        self._local_fns[key] = jax.jit(
            local_round, donate_argnums=self._donate_prev(stacked_prev))
        return self._local_fns[key]

    def _plan_local_fn(self, stacked_prev: bool) -> Callable:
        """Jitted vmap-over-clients *plan* round: one program serves every
        per-client group bitmask — the mask is a stacked batch input, not a
        static constant, so heterogeneous cohorts never retrace."""
        key = ("plan", stacked_prev)
        if key in self._local_fns:
            return self._local_fns[key]

        one_client = self._one_client_plan_fn()
        prev_axis = 0 if stacked_prev else None

        def local_round(global_params, inputs, labels, step_valid, prev, gmask):
            self.trace_count += 1
            return jax.vmap(one_client, in_axes=(None, 0, 0, 0, prev_axis, 0))(
                global_params, inputs, labels, step_valid, prev, gmask
            )

        self._local_fns[key] = jax.jit(
            local_round, donate_argnums=self._donate_prev(stacked_prev))
        return self._local_fns[key]

    def _cohort_fn(self, group: int, stacked_prev: bool, submesh=None) -> Callable:
        # The vmap local round already returns (stacked locals, losses) —
        # sync and async dispatches share one compiled program per group, and
        # because jit follows its committed inputs, every width-1 submesh
        # shares this single trace too (one executable per device, one trace).
        return self._local_fn(group, stacked_prev)

    def _plan_cohort_fn(self, stacked_prev: bool, submesh=None) -> Callable:
        # Same device-following story as _cohort_fn, one program for every
        # plan row and every width-1 submesh.
        return self._plan_local_fn(stacked_prev)

    def _place_cohort_args(self, args: tuple, submesh, *,
                           stacked_prev: bool) -> tuple:
        if submesh is None:
            return args
        dev = submesh.devices[0]
        return tuple(jax.device_put(a, dev) for a in args)

    def cohort_pool(self, max_inflight: int):
        """Width-1 submeshes (this engine's programs are single-device):
        cohort ``i`` runs whole on visible device ``i``."""
        if max_inflight <= 1:
            return None
        from repro.launch.mesh import SubmeshPool

        num = min(max_inflight, len(jax.devices()))
        return SubmeshPool(num, devices=num, width=1)

    def _agg_fn(self, group: int) -> Callable:
        if group in self._agg_fns:
            return self._agg_fns[group]
        partition = self.partition

        def agg(global_params, stacked, weights):
            self.trace_count += 1
            if group < 0:
                return aggregation.aggregate_full_stacked(global_params, stacked, weights)
            return aggregation.aggregate_partial_stacked(
                global_params, stacked, partition, group, weights
            )

        # Donating the global params makes the splice an in-place update —
        # callers must treat run_round as consuming its params argument.
        self._agg_fns[group] = jax.jit(agg, donate_argnums=self._donate_params())
        return self._agg_fns[group]

    def _plan_agg_fn(self) -> Callable:
        """On-device per-group participant-weighted aggregation: the plan
        bitmask and raw weights are traced inputs, so one program serves
        every heterogeneous cohort of a given size."""
        if "plan" in self._agg_fns:
            return self._agg_fns["plan"]
        partition = self.partition

        def agg(global_params, stacked, plan_f, weights):
            self.trace_count += 1
            return aggregation.aggregate_plan_stacked(
                global_params, stacked, partition, plan_f, weights)

        self._agg_fns["plan"] = jax.jit(agg, donate_argnums=self._donate_params())
        return self._agg_fns["plan"]

    def _tx_fn(self, group: int) -> Callable:
        """Jitted vmapped transmission-compression step: the cohort's stacked
        true locals + per-client residuals -> (compressed server view
        ``global + Q(update + residual)``, new residuals).  Runs between the
        local round and the stacked aggregation — the vmap engine's
        transmission boundary."""
        key = ("tx", group)
        if key in self._agg_fns:
            return self._agg_fns[key]
        partition, cfg = self.partition, self.compression
        sel = None if group < 0 else (group,)

        def tx(global_params, stacked, res):
            self.trace_count += 1
            return jax.vmap(
                lambda l, r: compress.transmit_tree(
                    global_params, l, r, cfg, partition=partition, groups=sel)
            )(stacked, res)

        self._agg_fns[key] = jax.jit(tx)
        return self._agg_fns[key]

    def _plan_tx_fn(self) -> Callable:
        """``_tx_fn`` for heterogeneous cohorts: the per-client group bitmask
        rides the stacked axis, so one program serves every plan."""
        key = ("tx", "plan")
        if key in self._agg_fns:
            return self._agg_fns[key]
        partition, cfg = self.partition, self.compression

        def tx(global_params, stacked, res, plan_f):
            self.trace_count += 1
            return jax.vmap(
                lambda l, r, m: compress.transmit_tree_plan(
                    global_params, l, r, m, cfg, partition=partition)
            )(stacked, res, plan_f)

        self._agg_fns[key] = jax.jit(tx)
        return self._agg_fns[key]

    # -- round execution ---------------------------------------------------

    def run_round(
        self,
        params: PyTree,
        spec: RoundSpec,
        datasets: Sequence[ClientDataset],
        *,
        seeds: Sequence[int],
        weights: Sequence[float],
        epochs: int,
        batch_size: int,
        prev_params: Sequence[PyTree | None] | None = None,
        tracker=None,
        plan=None,
        client_ids: Sequence[int] | None = None,
    ) -> tuple[PyTree, list[float], list[PyTree] | None]:
        self._guard_round(weights, tracker)
        plan = resolve_plan(plan, spec, self.partition.num_groups)
        ids = self._require_client_ids(client_ids, len(datasets))
        group = FULL_NETWORK if spec.is_full else spec.group
        use_prev = self.algo.name == "moon"
        num = len(datasets)

        parts: list[tuple[tuple[int, ...], tuple[PyTree, jax.Array]]] = []
        for bucket, prev_arg in self._buckets(
            params, datasets, batch_size=batch_size, epochs=epochs, seeds=seeds,
            prev_params=prev_params, use_prev=use_prev,
        ):
            if plan is None:
                fn = self._local_fn(group, stacked_prev=use_prev)
                locals_stacked, bucket_losses = fn(
                    params, bucket.inputs, bucket.labels, bucket.step_valid,
                    prev_arg)
            else:
                fn = self._plan_local_fn(stacked_prev=use_prev)
                locals_stacked, bucket_losses = fn(
                    params, bucket.inputs, bucket.labels, bucket.step_valid,
                    prev_arg, self._bucket_gmask(plan, bucket))
            parts.append((bucket.members, (locals_stacked, bucket_losses)))

        stacked, losses_dev = self._gather_order(parts, num)
        agg_in = stacked                 # MOON keeps the TRUE locals below
        if self.compression is not None:
            res = self._stacked_residuals(ids, range(num), num, params)
            if plan is None:
                agg_in, new_res = self._tx_fn(group)(params, stacked, res)
            else:
                agg_in, new_res = self._plan_tx_fn()(
                    params, stacked, res, jnp.asarray(plan, jnp.float32))
            self._store_residuals(ids, range(num), new_res)
        if plan is None:
            new_params = self._agg_fn(group)(
                params, agg_in, jnp.asarray(weights, dtype=jnp.float32)
            )
        else:
            new_params = self._plan_agg_fn()(
                params, agg_in, jnp.asarray(plan, dtype=jnp.float32),
                jnp.asarray(weights, dtype=jnp.float32)
            )
        losses = [float(x) for x in np.asarray(losses_dev)]
        new_locals = masking.unstack_tree(stacked, num) if use_prev else None
        return new_params, losses, new_locals


@dataclasses.dataclass
class ShardMapEngine(_BatchedEngineBase):
    """Multi-device engine: client axis sharded over a 1-D mesh.

    Each bucket's stacked clients are padded to a multiple of the mesh size
    and distributed over the ``"clients"`` axis; every device runs the shared
    vmapped local-round core for its shard, then the round's transmitted
    subtree — the trainable group's weight-scaled update, BN running moments
    dropped — is ``psum``-reduced across the mesh.  Frozen groups are
    replicated with the broadcast global model and never cross devices, so a
    partial round's inter-device traffic shrinks exactly like the paper's
    client<->server communication (Eq. 5).

    ``devices=0`` meshes every visible device.  MOON is the exception to the
    only-the-update-travels rule: its per-client local models leave the mesh
    sharded, but ``run_round`` then reorders and unstacks them into the
    host-side per-client store ``run_federated`` keeps, which does gather
    them each round (the cost of MOON's contrastive term, not of this
    engine).
    """

    name: str = "shard_map"
    devices: int = 0

    def __post_init__(self):
        super().__post_init__()
        from repro.launch.mesh import make_client_mesh

        self.mesh = make_client_mesh(self.devices)
        self._abs_meshes: dict[int, Any] = {}

    @property
    def num_devices(self) -> int:
        return self.mesh.shape[CLIENT_AXIS]

    # -- compiled-program builders ----------------------------------------

    def _local_fn(self, group: int, stacked_prev: bool) -> Callable:
        """Jitted shard_map'd (local round + on-mesh weighted reduction) for
        ``group``.  Each device vmaps its client shard; the weight-scaled
        trainable-subtree sum is psum'd so the result is replicated."""
        key = (group, stacked_prev)
        if key in self._local_fns:
            return self._local_fns[key]

        one_client = self._one_client_fn(group)
        partition = self.partition
        prev_axis = 0 if stacked_prev else None

        fused = self.fused_adam
        cfg = self.compression

        if cfg is not None:
            # Compressed transmission boundary: each device quantizes its
            # clients' updates (with per-client error-feedback residuals
            # riding the client axis) BEFORE the weight-scale psum, so only
            # compressed-value subtrees ever cross the mesh.  The epilogue is
            # always the per-leaf tree form — the fused packed epilogue stays
            # reserved for the uncompressed path (training steps may still
            # run the fused kernel; only the reduction differs).
            sel = None if group < 0 else (group,)

            def device_round(global_params, inputs, labels, step_valid, prev,
                             w_norm, res):
                self.trace_count += 1
                locals_stacked, losses = jax.vmap(
                    one_client, in_axes=(None, 0, 0, 0, prev_axis)
                )(global_params, inputs, labels, step_valid, prev)
                tx_stacked, new_res = jax.vmap(
                    lambda l, r: compress.transmit_tree(
                        global_params, l, r, cfg, partition=partition,
                        groups=sel)
                )(locals_stacked, res)
                sub = (
                    tx_stacked if group < 0
                    else masking.select(tx_stacked, partition, group)
                )
                sub = aggregation.drop_local_stats(sub)
                update = jax.tree.map(
                    lambda x: jnp.tensordot(w_norm, x.astype(jnp.float32),
                                            axes=1), sub
                )
                update = jax.lax.psum(update, CLIENT_AXIS)
                if stacked_prev:
                    return update, losses, locals_stacked, new_res
                return update, losses, new_res

            c = P(CLIENT_AXIS)
            in_specs = (P(), c, c, c, c if stacked_prev else P(), c, c)
            out_specs = ((P(), c, c, c) if stacked_prev else (P(), c, c))
            self._local_fns[key] = jax.jit(
                _shard_map(
                    device_round, mesh=self.mesh, in_specs=in_specs,
                    out_specs=out_specs, **_SHARD_MAP_KW,
                ),
                donate_argnums=self._donate_prev(stacked_prev),
            )
            return self._local_fns[key]

        def device_round(global_params, inputs, labels, step_valid, prev, w_norm):
            self.trace_count += 1
            locals_stacked, losses = jax.vmap(
                one_client, in_axes=(None, 0, 0, 0, prev_axis)
            )(global_params, inputs, labels, step_valid, prev)
            if fused:
                # Fused weight-scale epilogue: pack the stacked locals back
                # into kernel layout and reduce only the *transmitted* rows
                # (trainable groups minus BN stats) — one gather + tensordot
                # instead of a per-leaf tree, and only scaled transmitted
                # blocks ever leave the device.
                packed, _ = madam_ops.pack_stacked(
                    locals_stacked, FUSED_BLOCK_ROWS)
                sel = tuple(range(partition.num_groups)) if group < 0 else group
                tx = _transmitted_rows(global_params, partition, sel)
                update = jnp.tensordot(w_norm, packed[:, tx], axes=1)
            else:
                sub = (
                    locals_stacked if group < 0
                    else masking.select(locals_stacked, partition, group)
                )
                sub = aggregation.drop_local_stats(sub)
                update = jax.tree.map(
                    lambda x: jnp.tensordot(w_norm, x.astype(jnp.float32), axes=1), sub
                )
            update = jax.lax.psum(update, CLIENT_AXIS)
            if stacked_prev:
                return update, losses, locals_stacked
            return update, losses

        c = P(CLIENT_AXIS)
        in_specs = (P(), c, c, c, c if stacked_prev else P(), c)
        out_specs = (P(), c, c) if stacked_prev else (P(), c)
        self._local_fns[key] = jax.jit(
            _shard_map(
                device_round, mesh=self.mesh, in_specs=in_specs,
                out_specs=out_specs, **_SHARD_MAP_KW,
            ),
            donate_argnums=self._donate_prev(stacked_prev),
        )
        return self._local_fns[key]

    def _plan_local_fn(self, stacked_prev: bool) -> Callable:
        """Jitted shard_map'd plan round: each device vmaps the masked plan
        step over its client shard, then per-leaf plan-weighted sums are
        ``psum``-reduced across the mesh.  ``eff_w`` arrives host-normalised
        per group over the *whole cohort* (each group's own participant
        denominator, zero rows for padding clients), so summing the psum'd
        buckets yields each group's participant-weighted average directly —
        per-group weight sums on-mesh, exactly like the homogeneous path's
        single-group reduction."""
        key = ("plan", stacked_prev)
        if key in self._local_fns:
            return self._local_fns[key]

        one_client = self._one_client_plan_fn()
        partition = self.partition
        prev_axis = 0 if stacked_prev else None

        fused = self.fused_adam
        cfg = self.compression

        if cfg is not None:
            # Compressed plan boundary: per-client traced bitmask decides
            # which leaves consume error feedback and travel; the per-leaf
            # plan-weighted psum epilogue follows (tree form — see _local_fn).
            def device_round(global_params, inputs, labels, step_valid, prev,
                             gmask, eff_w, res):
                self.trace_count += 1
                locals_stacked, losses = jax.vmap(
                    one_client, in_axes=(None, 0, 0, 0, prev_axis, 0)
                )(global_params, inputs, labels, step_valid, prev, gmask)
                tx_stacked, new_res = jax.vmap(
                    lambda l, r, m: compress.transmit_tree_plan(
                        global_params, l, r, m, cfg, partition=partition)
                )(locals_stacked, res, gmask)
                sub = aggregation.drop_local_stats(tx_stacked)

                def _wsum(path, x):
                    g = partition.group_of(
                        "/".join(masking._entry_str(e) for e in path))
                    return jnp.tensordot(eff_w[:, g], x.astype(jnp.float32),
                                         axes=1)

                update = jax.tree_util.tree_map_with_path(_wsum, sub)
                update = jax.lax.psum(update, CLIENT_AXIS)
                if stacked_prev:
                    return update, losses, locals_stacked, new_res
                return update, losses, new_res

            c = P(CLIENT_AXIS)
            in_specs = (P(), c, c, c, c if stacked_prev else P(), c, c, c)
            out_specs = ((P(), c, c, c) if stacked_prev else (P(), c, c))
            self._local_fns[key] = jax.jit(
                _shard_map(
                    device_round, mesh=self.mesh, in_specs=in_specs,
                    out_specs=out_specs, **_SHARD_MAP_KW,
                ),
                donate_argnums=self._donate_prev(stacked_prev),
            )
            return self._local_fns[key]

        def device_round(global_params, inputs, labels, step_valid, prev,
                         gmask, eff_w):
            self.trace_count += 1
            locals_stacked, losses = jax.vmap(
                one_client, in_axes=(None, 0, 0, 0, prev_axis, 0)
            )(global_params, inputs, labels, step_valid, prev, gmask)
            if fused:
                # Fused plan epilogue: every non-stat row travels (any client
                # may have trained it), weighted per row by its group's
                # per-client effective weight — one einsum over the packed
                # buffer instead of a per-leaf tree walk.
                packed, _ = madam_ops.pack_stacked(
                    locals_stacked, FUSED_BLOCK_ROWS)
                rows, gids_rows = _plan_rows(global_params, partition)
                wrow = eff_w[:, gids_rows]                     # (C, T)
                update = jnp.einsum("ct,ctl->tl", wrow, packed[:, rows])
            else:
                sub = aggregation.drop_local_stats(locals_stacked)

                def _wsum(path, x):
                    g = partition.group_of(
                        "/".join(masking._entry_str(e) for e in path))
                    return jnp.tensordot(eff_w[:, g], x.astype(jnp.float32), axes=1)

                update = jax.tree_util.tree_map_with_path(_wsum, sub)
            update = jax.lax.psum(update, CLIENT_AXIS)
            if stacked_prev:
                return update, losses, locals_stacked
            return update, losses

        c = P(CLIENT_AXIS)
        in_specs = (P(), c, c, c, c if stacked_prev else P(), c, c)
        out_specs = (P(), c, c) if stacked_prev else (P(), c)
        self._local_fns[key] = jax.jit(
            _shard_map(
                device_round, mesh=self.mesh, in_specs=in_specs,
                out_specs=out_specs, **_SHARD_MAP_KW,
            ),
            donate_argnums=self._donate_prev(stacked_prev),
        )
        return self._local_fns[key]

    def _cohort_pad_for(self, submesh) -> int:
        return submesh.width if submesh is not None else self.num_devices

    def _abstract_mesh(self, width: int):
        """Cached AbstractMesh of ``width`` (None when this jax can't)."""
        if width not in self._abs_meshes:
            from repro.core.compat import abstract_client_mesh

            self._abs_meshes[width] = abstract_client_mesh(width, CLIENT_AXIS)
        return self._abs_meshes[width]

    def _cohort_fn(self, group: int, stacked_prev: bool, submesh=None) -> Callable:
        """Plain (no-psum) shard_map'd local round for async cohorts: each
        device vmaps its client shard and the stacked locals leave the mesh
        sharded — aggregation happens later, in the server policy, possibly
        against a newer global model, so it cannot be fused on-mesh here.

        Without a submesh the program binds the engine's full client mesh
        (the synchronous / PR 3 placement).  With one, the trace is built
        over an *AbstractMesh* of the submesh's width and cached per width —
        the concrete devices arrive through the inputs' ``NamedSharding``
        (``_place_cohort_args``), so every equal-width submesh replays the
        same trace.  When this jax can't trace abstractly, fall back to one
        concrete-mesh trace per device set (the persistent XLA cache still
        dedups the identical HLO)."""
        if submesh is None:
            key, mesh = (group, stacked_prev), self.mesh
        else:
            am = self._abstract_mesh(submesh.width)
            if am is not None:
                key, mesh = (group, stacked_prev, submesh.width), am
            else:  # pragma: no cover - depends on installed jax
                key = (group, stacked_prev,
                       tuple(getattr(d, "id", i)
                             for i, d in enumerate(submesh.devices)))
                mesh = submesh.mesh
        if key in self._cohort_fns:
            return self._cohort_fns[key]

        one_client = self._one_client_fn(group)
        prev_axis = 0 if stacked_prev else None

        def device_cohort(global_params, inputs, labels, step_valid, prev):
            self.trace_count += 1
            return jax.vmap(one_client, in_axes=(None, 0, 0, 0, prev_axis))(
                global_params, inputs, labels, step_valid, prev
            )

        c = P(CLIENT_AXIS)
        in_specs = (P(), c, c, c, c if stacked_prev else P())
        self._cohort_fns[key] = jax.jit(
            _shard_map(
                device_cohort, mesh=mesh, in_specs=in_specs,
                out_specs=(c, c), **_SHARD_MAP_KW,
            ),
            donate_argnums=self._donate_prev(stacked_prev),
        )
        return self._cohort_fns[key]

    def _plan_cohort_fn(self, stacked_prev: bool, submesh=None) -> Callable:
        """Plan-round cohort program: ``_cohort_fn``'s no-psum contract with
        the per-client group bitmask riding the client axis as a sixth
        sharded input.  Same trace-sharing story: AbstractMesh per width
        when available, concrete mesh otherwise."""
        if submesh is None:
            key, mesh = ("plan", stacked_prev), self.mesh
        else:
            am = self._abstract_mesh(submesh.width)
            if am is not None:
                key, mesh = ("plan", stacked_prev, submesh.width), am
            else:  # pragma: no cover - depends on installed jax
                key = ("plan", stacked_prev,
                       tuple(getattr(d, "id", i)
                             for i, d in enumerate(submesh.devices)))
                mesh = submesh.mesh
        if key in self._cohort_fns:
            return self._cohort_fns[key]

        one_client = self._one_client_plan_fn()
        prev_axis = 0 if stacked_prev else None

        def device_cohort(global_params, inputs, labels, step_valid, prev,
                          gmask):
            self.trace_count += 1
            return jax.vmap(one_client, in_axes=(None, 0, 0, 0, prev_axis, 0))(
                global_params, inputs, labels, step_valid, prev, gmask
            )

        c = P(CLIENT_AXIS)
        in_specs = (P(), c, c, c, c if stacked_prev else P(), c)
        self._cohort_fns[key] = jax.jit(
            _shard_map(
                device_cohort, mesh=mesh, in_specs=in_specs,
                out_specs=(c, c), **_SHARD_MAP_KW,
            ),
            donate_argnums=self._donate_prev(stacked_prev),
        )
        return self._cohort_fns[key]

    def _place_cohort_args(self, args: tuple, submesh, *,
                           stacked_prev: bool) -> tuple:
        if submesh is None or self._abstract_mesh(submesh.width) is None:
            # concrete-mesh traces shard host arrays themselves
            return args
        from jax.sharding import NamedSharding

        rep = NamedSharding(submesh.mesh, P())
        shd = NamedSharding(submesh.mesh, P(CLIENT_AXIS))
        params, inputs, labels, step_valid, prev = args[:5]
        placed = (jax.device_put(params, rep),
                  jax.device_put(inputs, shd),
                  jax.device_put(labels, shd),
                  jax.device_put(step_valid, shd),
                  jax.device_put(prev, shd if stacked_prev else rep))
        if len(args) == 6:      # plan cohorts: the bitmask rides the client axis
            placed += (jax.device_put(args[5], shd),)
        return placed

    def cohort_pool(self, max_inflight: int):
        """Cut this engine's client mesh into equal-width disjoint submeshes,
        one in-flight cohort per submesh."""
        if max_inflight <= 1:
            return None
        from repro.launch.mesh import SubmeshPool

        num = min(max_inflight, self.num_devices)
        return SubmeshPool(num, devices=self.num_devices)

    def _splice_fn(self, group: int, n_buckets: int) -> Callable:
        """Sum the buckets' psum'd updates and splice into the global model
        (cast back to each leaf's dtype; BN stats already dropped on-mesh)."""
        key = (group, n_buckets)
        if key in self._agg_fns:
            return self._agg_fns[key]
        partition = self.partition

        # Compressed rounds always reduce in the per-leaf tree form (the
        # packed epilogue is the uncompressed fused path's fast lane).
        if self.fused_adam and self.compression is None:
            def splice(global_params, updates):
                # Scatter the summed transmitted rows into the packed global
                # and unpack — ``unpack`` restores each leaf's recorded
                # dtype, so untransmitted f32 leaves round-trip bit-exact.
                self.trace_count += 1
                summed = jax.tree.map(lambda *xs: sum(xs), *updates)
                pg, meta = madam_ops.pack(global_params, FUSED_BLOCK_ROWS)
                sel = tuple(range(partition.num_groups)) if group < 0 else group
                tx = _transmitted_rows(global_params, partition, sel)
                pg = pg.at[tx].set(summed)
                return madam_ops.unpack(pg, meta)
        else:
            def splice(global_params, updates):
                self.trace_count += 1
                summed = jax.tree.map(lambda *xs: sum(xs), *updates)
                ref = (
                    global_params if group < 0
                    else masking.select(global_params, partition, group)
                )
                ref = aggregation.drop_local_stats(ref)
                averaged = jax.tree.map(lambda s, r: s.astype(r.dtype), summed, ref)
                return masking.tree_update(global_params, averaged)

        self._agg_fns[key] = jax.jit(splice, donate_argnums=self._donate_params())
        return self._agg_fns[key]

    def _plan_splice_fn(self, n_buckets: int) -> Callable:
        """Sum the buckets' psum'd plan updates and splice: a leaf whose
        group somebody trained takes the summed participant-weighted average
        (cast back to its dtype); a zero-trainer group's leaves keep the
        frozen global *bit-identical* (``trained`` is the per-group
        had-participants bitmap, computed host-side from the plan)."""
        key = ("plan", n_buckets)
        if key in self._agg_fns:
            return self._agg_fns[key]
        partition = self.partition

        if self.fused_adam and self.compression is None:
            def splice(global_params, updates, trained):
                # Row-granular zero-trainer freeze: a row whose group nobody
                # trained keeps the packed global's value bit-exact, exactly
                # like the unfused leaf-granular ``jnp.where(trained[g], ...)``.
                self.trace_count += 1
                summed = jax.tree.map(lambda *xs: sum(xs), *updates)
                pg, meta = madam_ops.pack(global_params, FUSED_BLOCK_ROWS)
                rows, gids_rows = _plan_rows(global_params, partition)
                keep = trained[jnp.asarray(gids_rows)][:, None]
                pg = pg.at[rows].set(jnp.where(keep, summed, pg[rows]))
                return madam_ops.unpack(pg, meta)
        else:
            def splice(global_params, updates, trained):
                self.trace_count += 1
                summed = jax.tree.map(lambda *xs: sum(xs), *updates)
                ref = aggregation.drop_local_stats(global_params)

                def _choose(path, s, r):
                    g = partition.group_of(
                        "/".join(masking._entry_str(e) for e in path))
                    return jnp.where(trained[g], s.astype(r.dtype), r)

                averaged = jax.tree_util.tree_map_with_path(_choose, summed, ref)
                return masking.tree_update(global_params, averaged)

        self._agg_fns[key] = jax.jit(splice, donate_argnums=self._donate_params())
        return self._agg_fns[key]

    # -- round execution ---------------------------------------------------

    def run_round(
        self,
        params: PyTree,
        spec: RoundSpec,
        datasets: Sequence[ClientDataset],
        *,
        seeds: Sequence[int],
        weights: Sequence[float],
        epochs: int,
        batch_size: int,
        prev_params: Sequence[PyTree | None] | None = None,
        tracker=None,
        plan=None,
        client_ids: Sequence[int] | None = None,
    ) -> tuple[PyTree, list[float], list[PyTree] | None]:
        self._guard_round(weights, tracker)
        plan = resolve_plan(plan, spec, self.partition.num_groups)
        ids = self._require_client_ids(client_ids, len(datasets))
        group = FULL_NETWORK if spec.is_full else spec.group
        use_prev = self.algo.name == "moon"
        num = len(datasets)
        w = np.asarray(weights, dtype=np.float32)
        w_norm = w / w.sum()
        if plan is not None:
            # Per-group participant denominators over the whole cohort:
            # zero-trainer groups keep eff_w all-zero and are spliced from
            # the frozen global instead.
            denom = aggregation.plan_group_denominators(plan, w)     # (M,)
            eff = w[:, None] * plan.astype(np.float32)               # (num, M)
            eff_norm = eff / np.where(denom > 0, denom, 1.0)[None, :]
            trained = jnp.asarray(denom > 0)

        updates: list[PyTree] = []
        loss_parts: list[tuple[tuple[int, ...], jax.Array]] = []
        local_parts: list[tuple[tuple[int, ...], PyTree]] = []
        for bucket, prev_arg in self._buckets(
            params, datasets, batch_size=batch_size, epochs=epochs, seeds=seeds,
            prev_params=prev_params, use_prev=use_prev,
            pad_clients_to=self.num_devices,
        ):
            res_args: tuple = ()
            if self.compression is not None:
                res_args = (self._stacked_residuals(
                    ids, bucket.members, bucket.num_clients, params),)
            if plan is None:
                wb = np.zeros(bucket.num_clients, dtype=np.float32)
                wb[: bucket.num_real] = w_norm[list(bucket.members)]
                fn = self._local_fn(group, stacked_prev=use_prev)
                out = fn(params, bucket.inputs, bucket.labels,
                         bucket.step_valid, prev_arg, wb, *res_args)
            else:
                wb = np.zeros((bucket.num_clients, plan.shape[1]),
                              dtype=np.float32)
                wb[: bucket.num_real] = eff_norm[list(bucket.members)]
                fn = self._plan_local_fn(stacked_prev=use_prev)
                out = fn(params, bucket.inputs, bucket.labels,
                         bucket.step_valid, prev_arg,
                         self._bucket_gmask(plan, bucket), wb, *res_args)
            update, bucket_losses = out[0], out[1]
            updates.append(update)
            n = bucket.num_real
            loss_parts.append((bucket.members, bucket_losses[:n]))
            if use_prev:
                local_parts.append((
                    bucket.members,
                    jax.tree.map(lambda x: x[:n], out[2]),
                ))
            if self.compression is not None:
                self._store_residuals(ids, bucket.members, out[-1])

        if plan is None:
            new_params = self._splice_fn(group, len(updates))(params, updates)
        else:
            new_params = self._plan_splice_fn(len(updates))(
                params, updates, trained)
        losses_dev = self._gather_order(loss_parts, num)
        losses = [float(x) for x in np.asarray(losses_dev)]
        if use_prev:
            stacked = self._gather_order(local_parts, num)
            new_locals = masking.unstack_tree(stacked, num)
        else:
            new_locals = None
        return new_params, losses, new_locals


def make_engine(
    name: str,
    *,
    trainer: LocalTrainer,
    partition: Partition,
    algo: AlgoConfig,
    sim_devices: int = 0,
    donate: bool = True,
    fused_adam: bool = False,
    compression: compress.CompressionConfig | None = None,
    state_store: Any = None,
):
    """Build a client-simulation engine by name.

    ``sim_devices`` only matters for ``"shard_map"``: the number of devices
    to mesh over the ``"clients"`` axis (0 = all visible devices)::

        engine = make_engine("vmap", trainer=trainer, partition=partition,
                             algo=AlgoConfig())
        engine.run_round(...)   # same contract for every engine

    ``donate`` (batched engines only) donates the global params into the
    aggregation/splice jit (in-place update) and the stacked MOON prev-model
    tree into the local-round jit.  With donation on, ``run_round``
    *consumes* its params argument — callers must thread the returned params
    into the next round (``run_federated`` does; pass ``donate=False`` to
    keep re-feeding the same tree, e.g. for fixed-workload benchmarking).

    ``fused_adam`` routes every local step through the Pallas masked-Adam
    kernel (interpret mode off-TPU — docs/KERNELS.md): packed (rows, 128)
    optimizer state, block-masked fused update, and on the shard_map engine
    a packed weight-scale epilogue feeding the on-mesh psum.

    ``compression`` (a ``core.compress.CompressionConfig``, or ``None`` for
    the byte-identical legacy paths) compresses every client's transmitted
    update at the engine's transmission boundary with per-client
    error-feedback residuals; ``run_round`` then requires ``client_ids=``
    (docs/COMPRESSION.md).
    """
    if name == "sequential":
        return SequentialEngine(trainer=trainer, partition=partition, algo=algo,
                                fused_adam=fused_adam, compression=compression,
                                state_store=state_store)
    if name == "vmap":
        return VmapEngine(trainer=trainer, partition=partition, algo=algo,
                          donate=donate, fused_adam=fused_adam,
                          compression=compression, state_store=state_store)
    if name == "shard_map":
        return ShardMapEngine(trainer=trainer, partition=partition, algo=algo,
                              donate=donate, devices=sim_devices,
                              fused_adam=fused_adam, compression=compression,
                              state_store=state_store)
    raise ValueError(f"unknown engine {name!r}; expected one of {ENGINES}")
