"""Task adapters: bind a model family (ResNet vision / small-NLP text) to the
uniform interface the FL engine consumes.

    adapter.init(key)                       -> params
    adapter.loss(params, inputs, labels)    -> scalar task loss
    adapter.features(params, inputs)        -> (B, d) penultimate features (MOON)
    adapter.evaluate(params, inputs, labels)-> accuracy
    adapter.stats(params, inputs)           -> pruned BN-stat updates (or None)
    adapter.partition(params)               -> core.Partition (Appendix-A groups)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config
from repro.core.partition import Partition, build_partition
from repro.models import nlp_small, resnet

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TaskAdapter:
    name: str
    init: Callable[[Any], PyTree]
    loss: Callable[[PyTree, jax.Array, jax.Array], jax.Array]
    features: Callable[[PyTree, jax.Array], jax.Array]
    evaluate: Callable[[PyTree, jax.Array, jax.Array], jax.Array]
    stats: Callable[[PyTree, jax.Array], PyTree | None]
    partition: Callable[[PyTree], Partition]
    flops_per_sample: float = 0.0


# ---------------------------------------------------------------------------
# Vision (ResNet)
# ---------------------------------------------------------------------------

def _resnet_features(params, images):
    x = resnet.conv_apply(params["stem"]["conv"], images)
    x, _ = resnet.bn_apply(params["stem"]["bn"], x, train=False)
    x = jax.nn.relu(x)
    for name in sorted(params["blocks"]):
        blk = params["blocks"][name]
        stride = 2 if "sc_conv" in blk else 1
        h = resnet.conv_apply(blk["conv1"], x, stride)
        h, _ = resnet.bn_apply(blk["bn1"], h, train=False)
        h = jax.nn.relu(h)
        h = resnet.conv_apply(blk["conv2"], h)
        h, _ = resnet.bn_apply(blk["bn2"], h, train=False)
        if "sc_conv" in blk:
            sc = resnet.conv_apply(blk["sc_conv"], x, stride)
            sc, _ = resnet.bn_apply(blk["sc_bn"], sc, train=False)
        else:
            sc = x
        x = jax.nn.relu(h + sc)
    return jnp.mean(x, axis=(1, 2))


def _conv_flops(spec, image_size=32) -> float:
    """Rough per-sample forward matmul FLOPs for the cost model."""
    total, hw, cin = 0.0, image_size * image_size, 3
    for stage, (n_blocks, cout) in enumerate(zip(spec["stages"], spec["channels"])):
        for b in range(n_blocks):
            if stage > 0 and b == 0:
                hw /= 4
            total += 2 * 9 * cin * cout * hw + 2 * 9 * cout * cout * hw
            cin = cout
    return total


_RESNET_SPECS = {
    "resnet4": resnet.RESNET4,   # test-scale: fast compile, same BN/shortcut structure
    "resnet8": resnet.RESNET8,
    "resnet18": resnet.RESNET18,
}


def resnet_task(depth: str = "resnet8", num_classes: int = 20) -> TaskAdapter:
    spec = _RESNET_SPECS[depth]

    def init(key):
        return resnet.resnet_init(key, spec, num_classes)

    def loss(params, images, labels):
        logits, _ = resnet.resnet_apply(params, images, train=True)
        return resnet.cls_loss(logits, labels)

    def stats(params, images):
        _, upd = resnet.resnet_apply(params, images, train=True)
        return upd

    def evaluate(params, images, labels):
        # Batch-statistics mode: BN running stats are client-local and never
        # aggregated (paper §4), so the global model is scored with batch
        # stats on the balanced eval set (deterministic given the set).
        logits, _ = resnet.resnet_apply(params, images, train=True)
        return resnet.accuracy(logits, labels)

    def make_partition(params):
        return build_partition(params, resnet.resnet_group_key, resnet.resnet_order_key)

    return TaskAdapter(
        name=depth,
        init=init,
        loss=loss,
        features=_resnet_features,
        evaluate=evaluate,
        stats=stats,
        partition=make_partition,
        flops_per_sample=_conv_flops(spec),
    )


# ---------------------------------------------------------------------------
# Text (small transformer classifier)
# ---------------------------------------------------------------------------

def nlp_task(num_classes: int = 4, cfg: ModelConfig | None = None, smoke: bool = False) -> TaskAdapter:
    cfg = cfg or get_config("nlp-transformer", smoke=smoke)

    def init(key):
        return nlp_small.nlp_init(key, cfg, num_classes)

    def loss(params, tokens, labels):
        logits = nlp_small.nlp_apply(params, cfg, tokens)
        return resnet.cls_loss(logits, labels)

    def features(params, tokens):
        # penultimate = pooled pre-head representation
        import jax.numpy as jnp

        b, s = tokens.shape
        from repro.models.layers import embed, norm_apply

        x = embed(params["embed"], tokens)
        x = x + params["embed"]["pos"][None, :s, :].astype(x.dtype)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        from repro.models import attention as attn
        from repro.models.layers import mlp_apply

        for i in range(cfg.num_layers):
            p = params["blocks"][str(i)]
            h = norm_apply(cfg.norm_kind, p["attn_norm"], x)
            y, _ = attn.gqa_full(p["attn"], cfg, h, positions, causal=False)
            x = x + y
            h = norm_apply(cfg.norm_kind, p["mlp_norm"], x)
            x = x + mlp_apply(p["mlp"], cfg.mlp_kind, h)
        return jnp.mean(x, axis=1)

    def evaluate(params, tokens, labels):
        logits = nlp_small.nlp_apply(params, cfg, tokens)
        return resnet.accuracy(logits, labels)

    def make_partition(params):
        return build_partition(params, nlp_small.nlp_group_key)

    from repro.models.layers import mlp_flops

    flops = cfg.num_layers * (
        2 * 4 * cfg.d_model * cfg.d_model + mlp_flops(cfg.mlp_kind, cfg.d_model, cfg.d_ff)
    ) * cfg.max_position_embeddings

    return TaskAdapter(
        name="nlp-transformer",
        init=init,
        loss=loss,
        features=features,
        evaluate=evaluate,
        stats=lambda params, tokens: None,
        partition=make_partition,
        flops_per_sample=float(flops),
    )
