"""DLG privacy attack + PSNR metrics (paper §4.4, Table 9, Appendix E).

Deep Leakage from Gradients (Zhu et al. 2019): recover a client's input by
optimising a dummy input whose gradients match the transmitted ones.  Under
FedPart only the trainable group's gradients are visible to the attacker —
fewer "equations" for the same unknowns — and reconstruction quality (PSNR)
drops accordingly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import masking
from repro.core.partition import Partition
from repro.optim.adam import AdamConfig, adam_init, adam_update

PyTree = Any


@dataclasses.dataclass(frozen=True)
class DLGConfig:
    iterations: int = 300
    lr: float = 0.1
    seed: int = 0


def _grad_of_sample(
    loss_fn: Callable[[PyTree, jax.Array], jax.Array],
    params: PyTree,
    x: jax.Array,
) -> PyTree:
    return jax.grad(lambda p: loss_fn(p, x))(params)


def _grad_match_loss(g_a: PyTree, g_b: PyTree) -> jax.Array:
    sq = jax.tree.map(
        lambda a, b: jnp.sum((a.astype(jnp.float32) - b.astype(jnp.float32)) ** 2),
        g_a,
        g_b,
    )
    return jax.tree.reduce(lambda x, y: x + y, sq, jnp.float32(0.0))


def dlg_attack(
    loss_fn: Callable[[PyTree, jax.Array], jax.Array],
    params: PyTree,
    target_x: jax.Array,
    cfg: DLGConfig,
    *,
    partition: Partition | None = None,
    group: int | None = None,
    observe_transform: Optional[Callable[[PyTree], PyTree]] = None,
) -> tuple[jax.Array, jax.Array]:
    """Run DLG.  ``loss_fn(params, x)`` is the client training loss for input
    ``x`` (labels closed over — the paper's setting with known labels).

    If ``partition``/``group`` are given, the attacker only observes the
    gradients of that layer group (FedPart's transmitted subset).

    ``observe_transform`` models a lossy channel between client and attacker:
    it is applied to the *target* observation only (e.g. the int8 / 1-bit
    quantize-dequantize of ``core.compress`` — what an eavesdropper on the
    compressed wire actually sees), while the attacker still matches with its
    own exact candidate gradients, per the strongest-attacker convention.

    Returns (reconstructed_x, final gradient-match loss).
    """
    observe_params = params
    if group is not None:
        assert partition is not None

        def observed_grads(x):
            g = _grad_of_sample(loss_fn, params, x)
            return masking.select(g, partition, group)

    else:

        def observed_grads(x):
            return _grad_of_sample(loss_fn, params, x)

    target_g = observed_grads(target_x)
    if observe_transform is not None:
        target_g = observe_transform(target_g)
    target_g = jax.lax.stop_gradient(target_g)

    def attack_loss(x_hat):
        return _grad_match_loss(observed_grads(x_hat), target_g)

    key = jax.random.key(cfg.seed)
    x_hat = jax.random.normal(key, target_x.shape, target_x.dtype) * 0.5
    adam_cfg = AdamConfig(lr=cfg.lr)
    opt = adam_init(x_hat)

    @jax.jit
    def step(x_hat, opt):
        loss, g = jax.value_and_grad(attack_loss)(x_hat)
        x_new, opt = adam_update(g, opt, x_hat, adam_cfg)
        return x_new, opt, loss

    loss = jnp.float32(0.0)
    for _ in range(cfg.iterations):
        x_hat, opt, loss = step(x_hat, opt)
    return x_hat, loss


# ---------------------------------------------------------------------------
# Metrics (paper Eq. 8-9)
# ---------------------------------------------------------------------------

def mse(x: jax.Array, x_hat: jax.Array) -> jax.Array:
    return jnp.mean((x.astype(jnp.float32) - x_hat.astype(jnp.float32)) ** 2)


def psnr(x: jax.Array, x_hat: jax.Array, data_range: float = 1.0) -> jax.Array:
    """PSNR = −10·log10(MSE) with inputs normalised to ``data_range``."""
    m = mse(x / data_range, x_hat / data_range)
    return -10.0 * jnp.log10(jnp.maximum(m, 1e-12))
