"""Adaptive server control loop for the async runtime (docs/CONTROL.md).

Every knob the async runtime exposes — ``buffer_k``, ``staleness_exponent``,
``max_inflight_cohorts``, the layer-group schedule — is static config by
default, while ``core.telemetry.Timeline`` already records the quantities
the partial-participation literature says a server should react to:
staleness moments, effective participation, occupancy/overlap, per-group
loss progress.  This module closes that loop.

The seam is deliberately small:

* a :class:`ServerController` observes a merge-aligned
  ``core.telemetry.TimelineWindow`` **between merges** and returns a
  :class:`PolicyAdjustment` — the actuators are the in-flight cohort
  target, the FedBuff merge goal K, a layer-group override for the *next*
  server version (``core.schedule.ScheduleIndex.override_group``), the
  dispatch cohort size, and the plan-prefix boost
  (``PlanAssigner.assign(boost=...)``);
* ``runtime/engine.py`` applies the adjustment right after the version bump
  and before the post-merge dispatch, and books a ``"control"`` timeline
  event so every decision is auditable;
* decisions are **virtual-event-only**: a controller sees windowed virtual
  timestamps, staleness counts, and merge losses — never wall-clock, device
  counts, or submesh state — so adaptive runs reproduce event-for-event on
  any machine, exactly like the static runtime.

``FLRunConfig(controller="static")`` — the default — builds *no* controller
(``make_controller`` returns ``None``) and the engine's hot path contains no
control branches at all: static is structurally absent, the same way
``compression="none"`` is, and bit-identical to the pre-controller runtime.

Three concrete controllers compose into the ``"adaptive"`` bundle:

* :class:`AdaptiveInflightController` — hill-climbs
  ``max_inflight_cohorts`` on the windowed occupancy of the configured
  slots: grow while the slots stay busy (overlap keeps paying), shrink when
  they sit idle (the fleet can't feed them).
* :class:`StalenessBufferController` — tracks the windowed discounted
  mixing coefficient ``E[(1+s)^-a]`` and moves the FedBuff goal K to keep
  it above a floor: a larger K means fewer version bumps per flight, hence
  less staleness; with slack it shrinks K back for faster virtual progress.
* :class:`ProgressGroupController` — repeats the just-trained layer group
  while its windowed merge-loss delta keeps improving (bounded consecutive
  repeats), instead of marching the fixed FedPart cycle; FNU rounds always
  follow the schedule.  Composes with per-client plans: the override
  changes the ``RoundSpec`` that ``PlanAssigner.assign`` sees, nothing else.

Two more join the bundle when their knobs are set (the participation axis,
ROADMAP item 4 — docs/CONTROL.md):

* :class:`ParticipationController`
  (``controller_participation_target > 0``) — holds a windowed
  effective-participation target by moving the dispatch cohort size within
  ``controller_cohort_bounds``; under biased cohort selection it tracks the
  inverse-inclusion-probability estimate, i.e. *debiased* coverage.
* :class:`PlanAssignmentController` (``controller_plan_boost_max > 0``,
  non-homogeneous plans) — grows every capacity tier's plan prefix by a
  bounded boost while deep layer groups show stalled windowed
  ``group_progress``, and decays it once they recover.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Protocol, Sequence

from repro.core.schedule import PlanAssigner
from repro.core.telemetry import TimelineWindow

if TYPE_CHECKING:  # engine.py owns the FLRunConfig import cycle
    from repro.fl.server import FLRunConfig

CONTROLLERS = ("static", "adaptive")


@dataclasses.dataclass
class PolicyAdjustment:
    """What a controller wants changed, all fields optional (None = keep).

    ``group_override`` targets the *next* server version (the one the
    triggering merge just advanced to); the engine clamps/validates and
    applies it through ``ScheduleIndex.override_group``.  ``cohort_size``
    re-targets the dispatch cohort (clamped to
    ``controller_cohort_bounds``); ``plan_boost`` extends every capacity
    tier's plan prefix by that many extra groups (clamped to
    ``[0, controller_plan_boost_max]``, ``PlanAssigner.assign``)."""

    max_inflight: int | None = None
    buffer_k: int | None = None
    group_override: int | None = None
    cohort_size: int | None = None
    plan_boost: int | None = None
    note: str = ""

    def __bool__(self) -> bool:
        return (self.max_inflight is not None or self.buffer_k is not None
                or self.group_override is not None
                or self.cohort_size is not None
                or self.plan_boost is not None)

    def merged(self, other: "PolicyAdjustment") -> "PolicyAdjustment":
        """Right-biased field-wise merge (later controllers win)."""
        return PolicyAdjustment(
            max_inflight=(other.max_inflight if other.max_inflight is not None
                          else self.max_inflight),
            buffer_k=(other.buffer_k if other.buffer_k is not None
                      else self.buffer_k),
            group_override=(other.group_override
                            if other.group_override is not None
                            else self.group_override),
            cohort_size=(other.cohort_size if other.cohort_size is not None
                         else self.cohort_size),
            plan_boost=(other.plan_boost if other.plan_boost is not None
                        else self.plan_boost),
            note="; ".join(n for n in (self.note, other.note) if n),
        )


class ServerController(Protocol):
    """The control seam: observe a merge-aligned window, adjust knobs."""

    def observe(self, window: TimelineWindow) -> PolicyAdjustment:
        """Called between merges (after the version bump, before the
        post-merge dispatch) with ``Timeline.window(controller_window)``.
        Must be a pure function of the window plus the controller's own
        state — virtual-event-only, never host state."""
        ...


@dataclasses.dataclass
class AdaptiveInflightController:
    """Hill-climb the in-flight cohort target on windowed slot occupancy.

    ``utilisation = span_seconds / (current * duration)`` measures how busy
    the ``current`` in-flight slots were over the window (1.0 = every slot
    flying the whole time).  Busy slots (>= ``grow_at``) mean overlap is
    paying and another slot likely would too; idle slots (< ``shrink_at``)
    mean the fleet can't feed the ones we have.  One step per observation,
    clamped to ``bounds``."""

    bounds: tuple[int, int]
    current: int
    grow_at: float = 0.6
    shrink_at: float = 0.2

    def __post_init__(self):
        lo, hi = self.bounds
        if not (1 <= lo <= hi):
            raise ValueError(f"inflight bounds must satisfy 1 <= lo <= hi, "
                             f"got {self.bounds}")
        self.current = min(max(self.current, lo), hi)

    def observe(self, window: TimelineWindow) -> PolicyAdjustment:
        lo, hi = self.bounds
        if window.duration <= 0.0:
            return PolicyAdjustment()
        util = window.span_seconds() / (self.current * window.duration)
        if util >= self.grow_at and self.current < hi:
            self.current += 1
            return PolicyAdjustment(
                max_inflight=self.current,
                note=f"inflight->{self.current} (util={util:.2f})")
        if util < self.shrink_at and self.current > lo:
            self.current -= 1
            return PolicyAdjustment(
                max_inflight=self.current,
                note=f"inflight->{self.current} (util={util:.2f})")
        return PolicyAdjustment()


@dataclasses.dataclass
class StalenessBufferController:
    """Keep the windowed discounted mixing coefficient above a floor by
    moving the FedBuff merge goal K.

    The merge mixes the buffered average into the model with coefficient
    ``m = E_w[(1+s)^-a]`` (docs/ASYNC.md); when the window's unweighted
    estimate ``TimelineWindow.discounted_mix(a)`` falls below ``mix_floor``
    the model has stopped moving, so K grows — a bigger buffer commits
    fewer versions per flight, which *lowers* future staleness.  With
    ``slack`` of headroom K shrinks back for faster virtual progress.
    A no-op when ``exponent == 0`` (the discount never bites)."""

    exponent: float
    bounds: tuple[int, int]
    current: int
    mix_floor: float = 0.5
    slack: float = 0.15

    def __post_init__(self):
        lo, hi = self.bounds
        if not (1 <= lo <= hi):
            raise ValueError(f"buffer bounds must satisfy 1 <= lo <= hi, "
                             f"got {self.bounds}")
        self.current = min(max(self.current, lo), hi)

    def observe(self, window: TimelineWindow) -> PolicyAdjustment:
        if self.exponent == 0.0 or not window.of_kind("complete"):
            return PolicyAdjustment()
        lo, hi = self.bounds
        mix = window.discounted_mix(self.exponent)
        if mix < self.mix_floor and self.current < hi:
            self.current += 1
            return PolicyAdjustment(
                buffer_k=self.current,
                note=f"buffer_k->{self.current} (mix={mix:.2f})")
        if mix >= self.mix_floor + self.slack and self.current > lo:
            self.current -= 1
            return PolicyAdjustment(
                buffer_k=self.current,
                note=f"buffer_k->{self.current} (mix={mix:.2f})")
        return PolicyAdjustment()


@dataclasses.dataclass
class ProgressGroupController:
    """Repeat a partial layer group while its merges keep paying off.

    After a merge of group ``g`` (>= 0), the next version repeats ``g``
    when the windowed evidence shows improvement — the group's own
    ``TimelineWindow.group_progress`` delta when the window holds >= 2 of
    its merges, else the last consecutive merge-loss delta — bounded by
    ``max_repeats`` consecutive overrides so the schedule always resumes.
    Full-network merges reset the streak and always follow the schedule."""

    max_repeats: int
    min_delta: float = 0.0
    _streak_group: int = dataclasses.field(default=-1, repr=False)
    _streak: int = dataclasses.field(default=0, repr=False)

    def observe(self, window: TimelineWindow) -> PolicyAdjustment:
        merges = window.of_kind("merge")
        if self.max_repeats <= 0 or len(merges) < 2:
            return PolicyAdjustment()
        last = merges[-1]
        group = int(last.get("group", -1))
        if group < 0:
            self._streak_group, self._streak = -1, 0
            return PolicyAdjustment()
        same = [e for e in merges if int(e.get("group", -1)) == group]
        delta = (window.group_progress()[group] if len(same) >= 2
                 else float(merges[-2]["loss"]) - float(last["loss"]))
        if group != self._streak_group:
            self._streak_group, self._streak = group, 0
        if delta > self.min_delta and self._streak < self.max_repeats:
            self._streak += 1
            return PolicyAdjustment(
                group_override=group,
                note=f"repeat group {group} (delta={delta:.4f})")
        self._streak = 0
        return PolicyAdjustment()


@dataclasses.dataclass
class ParticipationController:
    """Hold a windowed ``effective_participation`` target by moving the
    dispatch cohort size within bounds — the adaptive *participation rate*
    knob (ROADMAP item 4).

    ``TimelineWindow.effective_participation`` is the fraction of the fleet
    that delivered inside the window (Sen et al.'s effective-participation
    rate); under biased cohort selection (``debiased=True``) the
    inverse-inclusion-probability estimate is used instead, so the target
    tracks the *debiased* coverage of the objective rather than raw
    arrivals.  Below ``target`` (with ``slack`` deadband) the cohort grows
    by a quarter step; above it shrinks — larger cohorts raise coverage at
    the price of per-merge staleness, which the buffer/inflight controllers
    then rebalance.  One step per observation, clamped to ``bounds``;
    silent while nothing has been delivered."""

    target: float
    bounds: tuple[int, int]
    current: int
    num_clients: int
    debiased: bool = False
    slack: float = 0.1

    def __post_init__(self):
        lo, hi = self.bounds
        if not (1 <= lo <= hi):
            raise ValueError(f"cohort bounds must satisfy 1 <= lo <= hi, "
                             f"got {self.bounds}")
        if not 0.0 < self.target <= 1.0:
            raise ValueError(f"participation target must be in (0, 1], "
                             f"got {self.target}")
        if self.num_clients < 1:
            raise ValueError(f"num_clients must be >= 1, "
                             f"got {self.num_clients}")
        self.current = min(max(self.current, lo), hi)

    def observe(self, window: TimelineWindow) -> PolicyAdjustment:
        if not window.of_kind("complete"):
            return PolicyAdjustment()
        lo, hi = self.bounds
        ep = window.effective_participation(
            self.num_clients, inverse_probability=self.debiased)
        step = max(1, self.current // 4)
        if ep < self.target * (1.0 - self.slack) and self.current < hi:
            self.current = min(self.current + step, hi)
            return PolicyAdjustment(
                cohort_size=self.current,
                note=f"cohort->{self.current} (ep={ep:.2f})")
        if ep > self.target * (1.0 + self.slack) and self.current > lo:
            self.current = max(self.current - step, lo)
            return PolicyAdjustment(
                cohort_size=self.current,
                note=f"cohort->{self.current} (ep={ep:.2f})")
        return PolicyAdjustment()


@dataclasses.dataclass
class PlanAssignmentController:
    """Shift capacity-tier plan assignment toward stalled layer groups —
    the adaptive *plan assignment* knob (ROADMAP item 4).

    Under a nested/random plan, a tier of capacity ``c`` trains only its
    ``ceil(c*M)``-group prefix, so deep groups see updates from capable
    tiers only.  When the window shows a deep group stalled — merged >= 2
    times with ``group_progress <= min_delta`` while sitting beyond the
    weakest tier's base prefix (``>= min_prefix``, i.e. coverage-limited) —
    the boost grows by one: every tier's prefix extends by one extra group
    (``PlanAssigner.assign(boost=...)``), recruiting more trainers for the
    deep end.  The boost decays back toward 0 once no group is stalled, so
    the fleet returns to its capacity-honest assignment.  Bounded by
    ``max_boost``; observes per-tier delivery shares
    (``TimelineWindow.tier_participation``) purely for its audit note."""

    num_tiers: int
    min_prefix: int
    max_boost: int
    min_delta: float = 0.0
    current: int = 0

    def __post_init__(self):
        if self.num_tiers < 1:
            raise ValueError(f"num_tiers must be >= 1, got {self.num_tiers}")
        if self.max_boost < 0:
            raise ValueError(f"max_boost must be >= 0, got {self.max_boost}")
        self.current = min(max(self.current, 0), self.max_boost)

    def observe(self, window: TimelineWindow) -> PolicyAdjustment:
        if self.max_boost <= 0:
            return PolicyAdjustment()
        counts: dict[int, int] = {}
        for e in window.of_kind("merge"):
            g = int(e.get("group", -1))
            counts[g] = counts.get(g, 0) + 1
        progress = window.group_progress()
        stalled = [g for g, delta in progress.items()
                   if g >= 0 and counts.get(g, 0) >= 2
                   and delta <= self.min_delta]
        deep = [g for g in stalled if g >= self.min_prefix]
        if deep and self.current < self.max_boost:
            self.current += 1
            shares = window.tier_participation(self.num_tiers)
            return PolicyAdjustment(
                plan_boost=self.current,
                note=f"plan_boost->{self.current} (stalled "
                     f"{sorted(deep)}, tiers "
                     f"{[round(s, 2) for s in shares]})")
        if not stalled and self.current > 0:
            self.current -= 1
            return PolicyAdjustment(
                plan_boost=self.current,
                note=f"plan_boost->{self.current} (recovered)")
        return PolicyAdjustment()


@dataclasses.dataclass
class CompositeController:
    """Run sub-controllers in order; their (disjoint) adjustments merge."""

    parts: Sequence[ServerController]

    def observe(self, window: TimelineWindow) -> PolicyAdjustment:
        adj = PolicyAdjustment()
        for part in self.parts:
            adj = adj.merged(part.observe(window))
        return adj


def make_controller(run_cfg: "FLRunConfig", *, num_clients: int = 0,
                    num_groups: int = 0,
                    cohort_size: int = 0) -> ServerController | None:
    """Build the configured controller, or ``None`` for ``"static"``.

    ``None`` is the structural-absence contract: the engine installs no
    observation hook at all, so the default config cannot perturb the
    static trajectories (pinned in tests/test_async_runtime.py).

    The adaptive bundle always carries the three PR-9 controllers; the two
    participation knobs join only when their configs turn them on:
    :class:`ParticipationController` with
    ``controller_participation_target > 0`` (needs ``num_clients``, which
    the engine passes), :class:`PlanAssignmentController` with
    ``controller_plan_boost_max > 0`` under a non-homogeneous plan (needs
    ``num_groups``).  ``cohort_size`` seeds the participation controller's
    starting point (the engine passes its resolved dispatch target)."""
    if run_cfg.controller == "static":
        return None
    if run_cfg.controller != "adaptive":
        raise ValueError(f"unknown controller {run_cfg.controller!r}; "
                         f"expected one of {CONTROLLERS}")
    if run_cfg.controller_window < 1:
        raise ValueError("controller_window must be >= 1, got "
                         f"{run_cfg.controller_window}")
    inflight_lo, inflight_hi = run_cfg.controller_inflight_bounds
    start = min(max(run_cfg.max_inflight_cohorts, inflight_lo), inflight_hi)
    buf_lo, buf_hi = run_cfg.controller_buffer_bounds
    parts: list[ServerController] = [
        AdaptiveInflightController(
            bounds=(inflight_lo, inflight_hi), current=start),
        StalenessBufferController(
            exponent=run_cfg.staleness_exponent,
            bounds=(buf_lo, buf_hi),
            current=run_cfg.buffer_k if run_cfg.buffer_k > 0 else buf_lo,
            mix_floor=run_cfg.controller_mix_floor),
        ProgressGroupController(max_repeats=run_cfg.controller_max_repeats),
    ]
    if run_cfg.controller_participation_target > 0.0:
        if num_clients < 1:
            raise ValueError(
                "controller_participation_target > 0 needs num_clients — "
                "the engine passes the fleet size")
        c_lo, c_hi = run_cfg.controller_cohort_bounds
        parts.append(ParticipationController(
            target=run_cfg.controller_participation_target,
            bounds=(c_lo, c_hi),
            current=cohort_size if cohort_size > 0 else c_lo,
            num_clients=num_clients,
            debiased=run_cfg.participation_sampling == "biased"))
    if (run_cfg.controller_plan_boost_max > 0
            and run_cfg.plan != "homogeneous" and num_groups >= 1):
        assigner = PlanAssigner(
            num_groups=num_groups, kind=run_cfg.plan,
            capacity_tiers=tuple(run_cfg.capacity_tiers), seed=run_cfg.seed)
        min_prefix = min(assigner.prefix_len(ci)
                         for ci in range(len(assigner.capacity_tiers)))
        parts.append(PlanAssignmentController(
            num_tiers=len(assigner.capacity_tiers), min_prefix=min_prefix,
            max_boost=run_cfg.controller_plan_boost_max))
    return CompositeController(parts=tuple(parts))
