"""Server aggregation policies for the async runtime.

A policy decides **when** buffered client updates are merged into the global
model and **how much** each one counts.  Two are provided:

* ``SyncFedAvgPolicy`` ("sync") — the oracle: a barrier per cohort.  Merge
  only once nothing is left in flight, i.e. classic synchronous FedAvg
  expressed as an event-driven policy.  With a perfect fleet this reproduces
  ``run_federated``'s synchronous loop exactly (the degenerate-config
  equivalence pinned in tests/test_async_runtime.py).
* ``FedBuffPolicy`` ("fedbuff") — buffered asynchronous aggregation (Nguyen
  et al., FedBuff): merge as soon as ``buffer_goal`` (K) updates have
  arrived, without waiting for stragglers.  Updates dispatched against an
  older server version are discounted by the polynomial staleness weight
  ``(1 + staleness)^(-staleness_exponent)`` (Xie et al., FedAsync's poly
  strategy); exponent 0 recovers plain sample-size weighting.

The FedPart interplay is the part the literature doesn't cover: each update
carries only its dispatch-time *transmitted subtree* (the scheduled layer
group, BN running moments already dropped), and the schedule advances on
server versions, so a buffer can hold updates for **different** layer groups.
``merge`` therefore averages per group and splices each averaged subtree into
the *current* global model — a stale update for group ``g`` merges against
today's frozen context, never against the model it was trained from.  The
averaging path reuses ``core.aggregation`` (``tree_mean_stacked`` + splice),
i.e. exactly the synchronous engines' aggregation arithmetic.

Updates may arrive compressed (``ClientUpdate.encoding``, ``core.compress``):
the runtime decompresses at resolution, so every policy here is agnostic —
staleness scales and merges apply to decompressed values, and the encoded
wire size only matters to the cost books (``comm_bytes``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, masking
from repro.core.partition import Partition

PyTree = Any

POLICIES = ("sync", "fedbuff")


@dataclasses.dataclass
class ClientUpdate:
    """One client's delivered contribution, as the server buffer sees it."""

    client_id: int
    version: int            # server version at dispatch (staleness anchor)
    group: int              # layer group trained (FULL_NETWORK on FNU rounds)
    subtree: PyTree         # transmitted subtree, BN running moments dropped
    weight: float           # sample-size weight (len of the client dataset)
    loss: float
    dispatched_t: float     # virtual dispatch time
    completed_t: float = float("nan")
    comp_flops: float = 0.0  # local-training FLOPs this dispatch burned
    comm_bytes: int = 0      # upstream bytes of the transmitted subtree
    # Per-client layer plans: the exact group set this client trained (None
    # = the homogeneous path, where ``group`` alone describes the subtree).
    # The subtree then holds the *union* of the trained groups and the merge
    # splices per (client, group).
    groups: tuple[int, ...] | None = None
    # Transmission compression (core.compress): the wire format this update
    # travelled in ("int8" | "onebit" | "topk"; None = exact).  ``subtree``
    # always holds the *decompressed* server view — the merge and staleness
    # discounting below are value-level and never see codes — while
    # ``comm_bytes`` books the *encoded* size (docs/COMPRESSION.md).
    encoding: str | None = None
    # Availability-biased cohort selection (docs/ASYNC.md): the client's
    # stationary inclusion probability at dispatch.  The merge divides the
    # sample-size weight by it (Horvitz–Thompson,
    # ``core.aggregation.debias_weights``) so skewed arrivals leave the
    # global objective unbiased; 1.0 — the blind sampler's value — is the
    # exact identity, keeping uniform runs bit-for-bit.
    inclusion_prob: float = 1.0

    def staleness(self, current_version: int) -> int:
        return max(current_version - self.version, 0)


@dataclasses.dataclass
class AggregationPolicy:
    """Base: polynomial staleness weighting + per-group splice merging."""

    partition: Partition
    staleness_exponent: float = 0.0
    # K; 0 = whatever the last cohort's size was.  Deliberately a plain
    # mutable field: it is the staleness-aware controller's actuator
    # (runtime.control, docs/CONTROL.md), re-targeted between merges —
    # should_merge always reads the *current* goal.
    buffer_goal: int = 0

    name = "base"

    def staleness_scale(self, staleness: int) -> float:
        """``(1 + s)^(-a)`` — 1.0 for fresh updates, monotone decreasing."""
        if staleness < 0:
            raise ValueError(f"staleness must be >= 0, got {staleness}")
        if self.staleness_exponent == 0.0:
            return 1.0
        return float((1.0 + staleness) ** (-self.staleness_exponent))

    def goal(self, cohort_size: int) -> int:
        return self.buffer_goal if self.buffer_goal > 0 else cohort_size

    def should_merge(self, buffered: int, pending: int, cohort_size: int) -> bool:
        """Called after every delivery/drop.  ``pending`` counts updates still
        in flight that *will* be delivered (drops excluded)."""
        raise NotImplementedError

    def merge(
        self,
        global_params: PyTree,
        updates: Sequence[ClientUpdate],
        version: int,
    ) -> tuple[PyTree, dict]:
        """Merge buffered updates into the current global model.

        Updates are grouped by trained layer group (buffer order preserved).
        Per group, staleness enters twice, following FedAsync's polynomial
        strategy generalised to buffers:

        * **within** the buffer, each update's sample-size weight — first
          divided by its ``inclusion_prob`` (Horvitz–Thompson debiasing of
          availability-biased cohorts, ``core.aggregation.debias_weights``;
          exactly the identity at the default 1.0) — is scaled by
          ``(1+s)^-a`` before averaging (staler contributions count less
          against fresher ones);
        * **against** the current model, the averaged subtree is mixed in
          with coefficient ``m = sum(w*scale)/sum(w)`` — the sample-weighted
          mean staleness scale — so a buffer of stale updates moves the
          global model less: ``(1-m)*current + m*averaged``.

        With exponent 0 every scale is exactly 1.0, ``m == 1.0``, and the
        merge reduces to the synchronous splice (the degenerate-config
        equivalence).  The splice always lands on the *current* frozen
        context — a stale group-``g`` update never resurrects the model it
        was trained from.  When a FULL_NETWORK update shares the buffer with
        partial-group updates, the full tree merges **first** and the
        targeted subtrees splice on top, so a partial update is never wiped
        by a later full splice and the result is independent of arrival
        order; each group's mixing context is the progressively-merged
        model, not a pre-merge snapshot.

        Updates carrying a per-client layer plan (``u.groups`` set,
        docs/HETEROGENEITY.md) are unbundled into one contribution per
        **(client, group)**: each trained group's slice of the update's
        subtree joins that group's average with the *update's own* staleness
        scale, so a buffer can mix plan and homogeneous updates for the same
        group and every group's denominator sums exactly the weights of the
        clients that trained it.  Returns ``(new_params, info)`` with the
        merge telemetry (mean loss, staleness stats, per-group counts)."""
        if not updates:
            raise ValueError("merge called with an empty buffer")
        # Contributions per group: FULL_NETWORK (whole-tree) updates first,
        # then partial groups ascending — order-independent, and targeted
        # subtrees win where they overlap the full splice.  (Partial groups
        # are disjoint by construction.)
        by_group: dict[int, list[tuple[ClientUpdate, PyTree]]] = {}
        for u in updates:
            if u.groups is None:
                by_group.setdefault(u.group, []).append((u, u.subtree))
            else:
                for g in u.groups:
                    by_group.setdefault(int(g), []).append(
                        (u, masking.select(u.subtree, self.partition, int(g))))

        params = global_params
        for group in sorted(by_group, key=lambda g: (g >= 0, g)):
            contribs = by_group[group]
            w = np.array([u.weight for u, _ in contribs], dtype=np.float32)
            # Inverse-inclusion-probability debiasing (docs/ASYNC.md): a
            # no-op returning `w` itself when every prob is 1.0 (blind
            # sampling / uniform availability — the bit-exact contract).
            w = aggregation.debias_weights(
                w, np.array([u.inclusion_prob for u, _ in contribs],
                            dtype=np.float64))
            scale = np.array(
                [self.staleness_scale(u.staleness(version))
                 for u, _ in contribs],
                dtype=np.float32,
            )
            if float((w * scale).sum()) <= 0.0:
                raise ValueError(
                    f"group {group} merge weights must sum to a positive value"
                )
            stacked = masking.stack_trees([sub for _, sub in contribs])
            averaged = aggregation.tree_mean_stacked(stacked, w * scale)
            m = float((w * scale).sum() / w.sum())
            if m < 1.0:
                current = aggregation.drop_local_stats(
                    params if group < 0
                    else masking.select(params, self.partition, group))
                averaged = jax.tree.map(
                    lambda c, a: ((1.0 - m) * c.astype(jnp.float32)
                                  + m * a.astype(jnp.float32)).astype(a.dtype),
                    current, averaged,
                )
            params = masking.tree_update(params, averaged)

        stalenesses = [u.staleness(version) for u in updates]
        info = {
            "loss": float(np.mean([u.loss for u in updates])),
            "merged": len(updates),
            "staleness_mean": float(np.mean(stalenesses)),
            "staleness_max": int(max(stalenesses)),
            "groups": {int(g): len(ups) for g, ups in by_group.items()},
        }
        return params, info


@dataclasses.dataclass
class SyncFedAvgPolicy(AggregationPolicy):
    """Barrier per cohort: merge only once nothing deliverable is in flight."""

    name = "sync"

    def should_merge(self, buffered: int, pending: int, cohort_size: int) -> bool:
        return buffered > 0 and pending == 0


@dataclasses.dataclass
class FedBuffPolicy(AggregationPolicy):
    """Buffered async aggregation: merge at K updates, stragglers be damned.

    The ``pending == 0`` clause is the starvation guard: when drops/stragglers
    leave the buffer short of K with nothing in flight, merge what arrived
    rather than deadlock."""

    name = "fedbuff"

    def should_merge(self, buffered: int, pending: int, cohort_size: int) -> bool:
        if buffered <= 0:
            return False
        return buffered >= self.goal(cohort_size) or pending == 0


def make_policy(
    name: str,
    partition: Partition,
    *,
    staleness_exponent: float = 0.0,
    buffer_goal: int = 0,
) -> AggregationPolicy:
    """Build an aggregation policy by name (``"sync"`` | ``"fedbuff"``)."""
    if name == "sync":
        return SyncFedAvgPolicy(partition=partition,
                                staleness_exponent=staleness_exponent,
                                buffer_goal=buffer_goal)
    if name == "fedbuff":
        return FedBuffPolicy(partition=partition,
                             staleness_exponent=staleness_exponent,
                             buffer_goal=buffer_goal)
    raise ValueError(f"unknown policy {name!r}; expected one of {POLICIES}")
