from repro.fl.runtime.clients import AvailabilityConfig, ClientAvailability  # noqa: F401
from repro.fl.runtime.control import (CONTROLLERS,  # noqa: F401
                                      AdaptiveInflightController,
                                      CompositeController,
                                      ParticipationController,
                                      PlanAssignmentController,
                                      PolicyAdjustment,
                                      ProgressGroupController,
                                      ServerController,
                                      StalenessBufferController,
                                      make_controller)
from repro.fl.runtime.engine import run_federated_async  # noqa: F401
from repro.fl.runtime.policy import (POLICIES, AggregationPolicy,  # noqa: F401
                                     ClientUpdate, FedBuffPolicy,
                                     SyncFedAvgPolicy, make_policy)
