"""Event-driven asynchronous federated runtime (virtual clock, host-parallel
dispatch).

``run_federated_async`` replaces the synchronous "everyone trains, then we
average" barrier with an explicit discrete-event simulation:

1. **Dispatch.**  While the server sits at version ``v``, it samples a cohort
   of idle, available clients (``sample_fraction`` of the fleet) and trains
   them *as one stacked batch* through the session's client engine
   (``fl.batched.make_engine`` — the vmap / shard_map engines are the
   execution backend, not a parallel implementation).  Every client in the
   cohort trains the layer group scheduled for version ``v``
   (``core.schedule.ScheduleIndex``) against the version-``v`` model.  Up to
   Under a per-client layer plan (``FLRunConfig.plan``, ``PlanAssigner``)
   each cohort member instead trains its *own* group subset for version
   ``v``, and its virtual duration/books use exactly its trained groups'
   bytes and FLOPs (docs/HETEROGENEITY.md).  Up to
   ``FLRunConfig.max_inflight_cohorts`` cohorts may be in flight at once:
   with the default ``1`` dispatch is merge-driven (the original async
   runtime); with more, freed capacity is topped up immediately, so several
   cohorts train concurrently — in virtual time *and* on the host, each on
   its own disjoint device submesh (``launch.mesh.SubmeshPool``,
   ``engine.cohort_pool``).  jax's async dispatch makes the launch
   non-blocking; results are only materialised when a cohort's first
   completion event pops.  When no submesh is free the launch queues, and
   the dispatch is still booked at its virtual time.
2. **Flight.**  Each client's completion is booked on a virtual timeline:
   local compute scaled by its persistent speed multiplier, up/down transfer
   of the transmitted subtree, latency jitter, dropout — all from the seeded
   availability model (``runtime.clients``) and the virtual-time cost model
   (``core.costs.VirtualTimeModel``).  Cohort spans are booked per submesh
   in a ``core.costs.SubmeshOccupancy`` ledger, so the timeline shows how
   much of the run genuinely overlapped.
3. **Merge.**  Delivered updates accumulate in the server buffer; the
   aggregation policy (``runtime.policy``) decides when to merge (barrier,
   or FedBuff's goal-K) and discounts stale updates polynomially.  A merge
   bumps the server version — which advances the FedPart schedule — and
   tops the in-flight cohorts back up, so slow clients from old versions
   keep training while the server moves on: that overlap is the async win.

Time-to-accuracy comes out as first-class output: every dispatch, delivery,
drop, merge, and eval is logged against the virtual clock in a
``core.telemetry.Timeline`` attached to the returned ``FLResult``, with
dispatch events carrying their submesh binding and span.

**Degenerate-config equivalence** (pinned in tests/test_async_runtime.py):
with full participation, a perfect fleet (default ``AvailabilityConfig``),
``buffer_k = 0`` (goal = cohort size), ``staleness_exponent = 0`` and
``max_inflight_cohorts = 1``, every cohort is a barrier round — the
client-selection RNG stream, per-client seeds, local training programs, and
aggregation arithmetic all coincide with the synchronous path, so params /
losses / cost books match ``run_federated`` to <=1e-5 under every engine.
The dispatch decisions depend only on virtual events, never on host speed or
device count, so a given config is reproducible on any machine; submeshes
only decide *where* a cohort's compiled program runs.

**Adaptive server control** (``FLRunConfig.controller``, ``runtime.control``,
docs/CONTROL.md): with ``controller="adaptive"`` a ``ServerController``
observes a merge-aligned ``Timeline.window`` between merges and may adjust
the in-flight cohort target, the FedBuff goal K, or pin the next version's
layer group (``ScheduleIndex.override_group``); every decision is recorded
as a ``"control"`` timeline event.  The default ``"static"`` builds no
controller at all — the hot path has no observation hook and reproduces the
pre-controller runtime bit-for-bit.

**Transmission compression** (``FLRunConfig.compression``, ``core.compress``,
docs/COMPRESSION.md): the local training programs are untouched
(``run_local_async`` always returns exact locals); quantisation happens
host-side at update *resolution*, against the dispatch-version model, with a
runtime-owned per-client error-feedback residual.  Buffered ``ClientUpdate``
subtrees therefore hold the *decompressed* server view — staleness
discounting and the policy merge operate on values — while each update's
``comm_bytes`` (and hence ``VirtualTimeModel.comm_seconds``) books the
*encoded* wire size from the ``core.compress`` byte ledger.

**Population scale** (``fl.population``, docs/POPULATION.md): ``clients_data``
may be a ``ClientPopulation`` instead of a materialised sequence, and every
per-dispatch cost here is O(cohort), never O(population): cohorts are drawn
by Floyd's algorithm over ``range(n) - busy`` (``IncrementalSampler``),
availability filtering runs over *sampled candidates only*
(``ClientAvailability.arrival_ok``), speed multipliers hash lazily from
``(seed, client_id)``, datasets materialise only for picked members, and the
MOON prev-models / EF residuals live in a bounded ``ClientStateStore``
(``FLRunConfig.state_store_entries`` / ``state_store_spill``).  With an empty
busy set the incremental sampler consumes the exact
``sample_without_replacement`` stream of the synchronous server, so the
degenerate-config equivalence holds unchanged.
"""

from __future__ import annotations

import heapq
import itertools
from collections import deque
from typing import TYPE_CHECKING, Any, Sequence

import jax
import numpy as np

from repro.core import aggregation, compress, masking
from repro.core.costs import comm_cost, comp_cost, plan_step_flops
from repro.core.partition import (group_param_bytes, group_param_counts,
                                  total_param_bytes)
from repro.core.schedule import PlanAssigner, RoundSpec, ScheduleIndex
from repro.core.telemetry import Timeline
from repro.fl.batched import make_engine, resolve_plan
from repro.fl.client import LocalTrainer
from repro.fl.population import (ClientPopulation, IncrementalSampler,
                                 as_population, client_round_seed,
                                 resolve_cohort_size,
                                 weighted_sample_without_replacement)
from repro.fl.runtime.clients import ClientAvailability
from repro.fl.runtime.control import make_controller
from repro.fl.runtime.policy import ClientUpdate, make_policy
from repro.fl.tasks import TaskAdapter
from repro.optim.adam import AdamConfig

if TYPE_CHECKING:  # imported lazily at runtime to avoid the fl.server cycle
    from repro.fl.server import FLResult, FLRunConfig

PyTree = Any


def _steps_per_round(n: int, batch_size: int, epochs: int) -> int:
    """Local step count of ``data.pipeline.batch_plan`` without building it."""
    bs = min(batch_size, n)
    per_epoch = (n - bs) // bs + 1 if n >= bs else 1
    return epochs * per_epoch


class _Cohort:
    """One dispatched cohort: virtual bookkeeping happens at dispatch; the
    host launch may be deferred (submesh exhaustion) and the results are
    materialised lazily, at the cohort's first popped member event."""

    __slots__ = ("picked", "datasets", "seeds", "prevs", "spec", "plan",
                 "params", "dispatched_t", "end_t", "updates", "submesh",
                 "stacked", "losses_dev", "launched", "resolved", "tl_event")

    def __init__(self, *, picked, datasets, seeds, prevs, spec, plan, params,
                 dispatched_t, end_t, updates, tl_event):
        self.picked = picked
        self.datasets = datasets
        self.seeds = seeds
        self.prevs = prevs
        self.spec = spec
        self.plan = plan              # per-client group bitmask (None = homogeneous)
        self.params = params          # version-``v`` tree captured at dispatch
        self.dispatched_t = dispatched_t
        self.end_t = end_t            # last member completion (virtual)
        self.updates = updates
        self.tl_event = tl_event
        self.submesh = None
        self.stacked = None
        self.losses_dev = None
        self.launched = False
        self.resolved = False


def run_federated_async(
    adapter: TaskAdapter,
    clients_data: Sequence | ClientPopulation,
    eval_set: tuple[np.ndarray, np.ndarray],
    rounds: Sequence[RoundSpec],
    run_cfg: "FLRunConfig",
    *,
    init_key=None,
    verbose: bool = False,
) -> "FLResult":
    from repro.fl.server import FLResult  # deferred: fl.server imports us

    if run_cfg.track_stepsizes:
        raise ValueError("track_stepsizes requires runtime='sync' with "
                         "engine='sequential'")
    if run_cfg.max_inflight_cohorts < 1:
        raise ValueError("max_inflight_cohorts must be >= 1, got "
                         f"{run_cfg.max_inflight_cohorts}")
    if not rounds:  # mirror the sync loop's graceful no-op
        key = init_key if init_key is not None else jax.random.key(run_cfg.seed)
        params = adapter.init(key)
        partition = adapter.partition(params)
        return FLResult(history=[], params=params, partition=partition,
                        tracker=None, comm_total_bytes=0, comp_total_flops=0.0,
                        comm_fnu_bytes=0, comp_fnu_flops=0.0,
                        timeline=Timeline())
    key = init_key if init_key is not None else jax.random.key(run_cfg.seed)
    params = adapter.init(key)
    partition = adapter.partition(params)
    trainer = LocalTrainer(
        adapter=adapter,
        partition=partition,
        algo=run_cfg.algo,
        adam=AdamConfig(lr=run_cfg.lr, eps=run_cfg.adam_eps),
    )
    engine = make_engine(
        run_cfg.engine, trainer=trainer, partition=partition,
        algo=run_cfg.algo, sim_devices=run_cfg.sim_devices,
        donate=run_cfg.donate_buffers, fused_adam=run_cfg.fused_adam,
    )
    policy = make_policy(
        run_cfg.async_policy, partition,
        staleness_exponent=run_cfg.staleness_exponent,
        buffer_goal=run_cfg.buffer_k,
    )
    sched = ScheduleIndex.from_rounds(rounds)
    assigner = PlanAssigner(
        num_groups=partition.num_groups, kind=run_cfg.plan,
        capacity_tiers=tuple(run_cfg.capacity_tiers), seed=run_cfg.seed)
    population = as_population(clients_data)
    n_clients = population.num_clients
    avail = ClientAvailability(run_cfg.availability, n_clients)
    vtm = run_cfg.vtime
    timeline = Timeline()
    # Same selection stream as the synchronous server: one Floyd k-subset
    # sample per dispatch whenever the whole fleet is idle (busy empty).
    rng = np.random.default_rng(run_cfg.seed)
    eval_x, eval_y = eval_set
    eval_fn = jax.jit(adapter.evaluate)
    is_moon = run_cfg.algo.name == "moon"
    ccfg = compress.make_config(
        run_cfg.compression, topk_fraction=run_cfg.topk_fraction,
        error_feedback=run_cfg.error_feedback,
        block_rows=run_cfg.compression_block_rows)
    # Per-client cross-dispatch state — MOON prev-models ("moon") and EF
    # residuals ("ef") — lives in one bounded LRU store so host memory
    # tracks the active cohorts, not the population.
    state_store = run_cfg.make_state_store()

    # Cost tables: upstream bytes + per-step flops per scheduled group.  With
    # compression on, the upstream table prices the *encoded* wire format
    # (payload + scales + indices; BN stats stay dense-f32).
    if ccfg is None:
        group_bytes = group_param_bytes(params, partition)
        full_bytes = int(total_param_bytes(params))
    else:
        group_bytes = compress.group_encoded_bytes(params, partition, ccfg)
        full_bytes = int(group_bytes.sum())
    group_counts = group_param_counts(params, partition).astype(np.float64)
    _flops_cache: dict[int, float] = {}

    def _step_flops(spec: RoundSpec) -> float:
        if spec.group not in _flops_cache:
            _flops_cache[spec.group] = float(
                comp_cost(partition, [spec], group_fwd_flops=group_counts)
                .per_round_flops[0]
            )
        return _flops_cache[spec.group]

    _plan_flops_cache: dict[tuple[int, ...], float] = {}

    def _plan_flops(groups: tuple[int, ...]) -> float:
        if groups not in _plan_flops_cache:
            _plan_flops_cache[groups] = plan_step_flops(
                partition, groups, group_fwd_flops=group_counts)
        return _plan_flops_cache[groups]

    # -- host-parallel dispatch state ---------------------------------------
    max_inflight = run_cfg.max_inflight_cohorts
    # Controller-adjustable dispatch knobs (docs/CONTROL.md): the cohort
    # target the participation controller moves within
    # ``controller_cohort_bounds``, and the plan-prefix boost the plan
    # controller hands to ``PlanAssigner.assign``.  Static runs never touch
    # either, so the resolved values are the legacy constants bit-for-bit.
    cohort_target = resolve_cohort_size(n_clients, run_cfg.sample_fraction,
                                        run_cfg.cohort_size)
    plan_boost = 0
    num_tiers = len(assigner.capacity_tiers)
    # Server control loop (docs/CONTROL.md): None under the default
    # controller="static" — structurally absent, so the static hot path has
    # no observation hook at all.  Adaptive runs may grow the in-flight
    # target later, so the submesh pool is carved for the controller's upper
    # bound up front (dispatches beyond the current target never happen; the
    # pool only bounds where launched cohorts can land).
    controller = make_controller(run_cfg, num_clients=n_clients,
                                 num_groups=partition.num_groups,
                                 cohort_size=cohort_target)
    pool_cap = (max(max_inflight, run_cfg.controller_inflight_bounds[1])
                if controller is not None else max_inflight)
    pool = engine.cohort_pool(pool_cap)
    occupancy = vtm.occupancy()
    launch_queue: deque[_Cohort] = deque()
    # Results land on per-submesh devices; pull them back to the default
    # device at resolve whenever cohorts can live on >1 submesh, so the
    # policy's merge never mixes committed devices.
    xfer_back = pool is not None and pool.num_submeshes > 1
    home = jax.devices()[0] if xfer_back else None

    # -- event-loop state ---------------------------------------------------
    events: list[tuple] = []         # min-heap of (t, seq, kind, upd, cohort)
    seq = itertools.count()          # FIFO tiebreak for simultaneous events
    busy: set[int] = set()
    retry_pending = False            # a "retry" wait event is already booked
    retry_streak = 0                 # consecutive dispatches with no arrival
    buffer: list[ClientUpdate] = []
    history: list[dict] = []
    version = 0                      # server aggregations committed so far
    vclock = 0.0
    pending = 0                      # in-flight updates that WILL deliver
    inflight = 0                     # dispatched-but-unresolved cohorts
    last_cohort = 0
    total = len(rounds)

    def launch(cohort: _Cohort, submesh) -> None:
        """Hand the cohort's stacked local-training program to jax (async
        dispatch: returns before the results exist) and book its occupancy."""
        cohort.submesh = submesh
        cohort.stacked, cohort.losses_dev = engine.run_local_async(
            cohort.params, cohort.spec, cohort.datasets, seeds=cohort.seeds,
            epochs=run_cfg.local_epochs, batch_size=run_cfg.batch_size,
            prev_params=cohort.prevs, submesh=submesh, plan=cohort.plan,
        )
        cohort.launched = True
        idx = submesh.index if submesh is not None else -1
        cohort.tl_event["submesh"] = idx
        occupancy.book(idx, cohort.dispatched_t, cohort.end_t)

    def resolve(cohort: _Cohort) -> None:
        """Materialise the cohort's results into its member updates (blocks
        on the in-flight arrays), free its submesh, and start the next
        queued launch."""
        nonlocal inflight
        if cohort.resolved:
            return
        if not cohort.launched:  # queued past exhaustion: run unbound now
            launch(cohort, None)
        cohort.resolved = True
        inflight -= 1
        stacked = cohort.stacked
        losses = [float(x) for x in np.asarray(cohort.losses_dev)]
        if is_moon:
            moon_stacked = (jax.device_put(stacked, home) if xfer_back
                            else stacked)
            for i, ci in enumerate(cohort.picked):
                state_store.put("moon", int(ci),
                                jax.tree.map(lambda x: x[i], moon_stacked))
        spec = cohort.spec
        if cohort.plan is None:
            sub = stacked if spec.is_full else masking.select(
                stacked, partition, spec.group)
        else:
            # Heterogeneous cohort: pull the cohort's *union* of trained
            # groups off the mesh, then slice each member down to exactly
            # the groups its plan row trained.
            union = tuple(int(g)
                          for g in np.flatnonzero(cohort.plan.any(axis=0)))
            sub = (stacked if len(union) == partition.num_groups
                   else masking.select(stacked, partition, union))
        sub = aggregation.drop_local_stats(sub)
        if xfer_back:
            # Pull only the *transmitted* subtree back to the home device
            # (the paper's Eq. 5 saving applied to the simulator's own
            # traffic) so the merge never mixes committed devices.
            sub = jax.device_put(sub, home)
        subs = masking.unstack_tree(sub, len(cohort.picked))
        # Host-side transmission compression: quantise each member's subtree
        # against the dispatch-version model (stats already dropped), carrying
        # the per-client EF residual across dispatches.  The buffered subtree
        # is the *decompressed* server view; ``comm_bytes`` already booked the
        # encoded size at dispatch.
        g_views: dict = {}

        def _g_view(sel):
            if sel not in g_views:
                t = (cohort.params if sel is None
                     else masking.select(cohort.params, partition, sel))
                g_views[sel] = aggregation.drop_local_stats(t)
            return g_views[sel]

        for i, upd in enumerate(cohort.updates):
            upd_sub = (subs[i] if upd.groups is None else
                       masking.select(subs[i], partition, upd.groups))
            if ccfg is not None:
                sel = (upd.groups if upd.groups is not None
                       else (None if spec.is_full else spec.group))
                res_full = state_store.get("ef", upd.client_id)
                if res_full is None:
                    res_full = compress.init_residual(cohort.params)
                res_sub = aggregation.drop_local_stats(
                    res_full if sel is None
                    else masking.select(res_full, partition, sel))
                upd_sub, new_res = compress.transmit_tree(
                    _g_view(sel), upd_sub, res_sub, ccfg, partition=partition)
                state_store.put("ef", upd.client_id,
                                masking.tree_update(res_full, new_res))
            upd.subtree = upd_sub
            upd.loss = losses[i]
        # Drop the big references now, not at last-straggler pop: the params
        # snapshot, the in-flight outputs, and (MOON) the superseded
        # prev-model trees whose store slots were just overwritten.
        cohort.stacked = cohort.losses_dev = cohort.params = None
        cohort.prevs = None
        if cohort.submesh is not None:
            pool.release(cohort.submesh)
            while launch_queue and pool.free_count > 0:
                nxt = launch_queue.popleft()
                if not nxt.launched:
                    launch(nxt, pool.acquire())

    def book_retry(t: float, rejected: list[int]) -> None:
        """Every sampled candidate is unavailable at ``t``: book one
        deterministic virtual-clock wait/retry event instead of training
        anyone.  The wait is the earliest trace on-window among the rejected
        candidates when the trace rejected them, else the configured
        ``retry_wait`` backoff (an on-window candidate that merely failed
        its i.i.d. coin can pass on the very next attempt).  At most one
        retry event is in flight at a time."""
        nonlocal retry_pending, retry_streak
        if retry_pending:
            return
        retry_streak += 1
        if retry_streak > 1000:
            raise RuntimeError(
                "async runtime: 1000 consecutive dispatch attempts found no "
                "available client — the availability trace/knobs leave the "
                "fleet effectively unreachable")
        waits: list[float] = []
        if avail.cfg.trace:
            coin_failed = False
            for ci in rejected:
                w = avail.next_on_time(ci, t) - t
                if w > 0.0:
                    waits.append(w)
                else:
                    coin_failed = True
            if coin_failed or not waits:
                waits.append(avail.cfg.retry_wait)
        else:
            waits.append(avail.cfg.retry_wait)
        wait = min(waits)
        retry_pending = True
        timeline.record(t, "wait", until=t + wait, rejected=len(rejected))
        heapq.heappush(events, (t + wait, next(seq), "retry", None, None))

    def dispatch(t: float, fragment_ok: bool) -> int:
        """Sample a cohort at the current version, book each member's
        completion on the virtual timeline, and launch its stacked training
        program on a free submesh (queueing the launch when none is).

        ``fragment_ok`` mirrors the merge-driven regime's behaviour: the
        dispatch a merge (or stall) triggers takes whatever idle clients
        exist, while capacity top-ups demand a full cohort's worth — filling
        spare capacity with fragment cohorts would inflate total client work
        (and retrace per cohort width) instead of overlapping it."""
        nonlocal pending, last_cohort, inflight, retry_streak
        spec = sched.for_version(version)
        pool_size = n_clients - len(busy)
        if pool_size <= 0:
            return 0
        n_pick = cohort_target
        if pool_size < n_pick and not fragment_ok:
            return 0
        # O(cohort) selection at population scale: Floyd-sample candidates
        # from range(n) minus the busy set — the fleet is never enumerated.
        # Blind mode filters each candidate through its *own* arrival draw
        # and tops up until the cohort fills or the idle pool runs dry;
        # biased mode weights candidates by their *current* availability and
        # draws the cohort in one weighted pass (docs/ASYNC.md).
        k_target = min(n_pick, pool_size)
        sampler = IncrementalSampler(rng, n_clients, busy)
        picked: list[int] = []
        rejected: list[int] = []
        if run_cfg.participation_sampling == "biased":
            # Availability-biased selection: oversample a candidate pool,
            # weight by current availability (trace window x stationary
            # arrival rate), and take an Efraimidis–Spirakis weighted
            # k-subset — off-window candidates are never picked, and each
            # pick records its inclusion probability so the merge can
            # inverse-probability debias.
            pool_ids: list[int] = []
            pool_w: list[float] = []
            navail = 0
            while navail < k_target and sampler.remaining > 0:
                need = k_target - navail
                ask = (need if not avail.cfg.trace else
                       max(need, min(4 * k_target, sampler.remaining)))
                for ci in sampler.draw(ask):
                    w = avail.availability_weight(ci, t)
                    pool_ids.append(ci)
                    pool_w.append(w)
                    if w > 0.0:
                        navail += 1
            if navail == 0:
                book_retry(t, pool_ids)
                return 0
            picked = weighted_sample_without_replacement(
                rng, pool_ids, pool_w, min(k_target, navail))
        else:
            while len(picked) < k_target and sampler.remaining > 0:
                for ci in sampler.draw(k_target - len(picked)):
                    (picked if avail.arrival_ok(ci, t) else rejected).append(ci)
            if not picked:
                # Every candidate failed its arrival draw: wait, never train
                # provably-unavailable clients.
                book_retry(t, rejected)
                return 0
        retry_streak = 0
        k = len(picked)

        datasets = [population.dataset(ci) for ci in picked]
        seeds = [client_round_seed(run_cfg.seed, spec.index, int(ci))
                 for ci in picked]
        prevs = ([state_store.get("moon", int(ci)) for ci in picked]
                 if is_moon else None)
        # Per-client layer plan for this dispatch.  The raw plan (None only
        # under the homogeneous *kind*) decides the updates' trained group
        # sets, so the policy merge unbundles per (client, group) for every
        # plan-kind dispatch — even a cohort whose rows happen to equal the
        # round mask, which `resolve_plan` collapses to the legacy compiled
        # programs for *execution* only.  Otherwise a collapsed cohort's
        # whole-tree update sharing a buffer with plan updates would dodge
        # the per-group denominators (docs/HETEROGENEITY.md).
        plan_raw = assigner.assign(spec, picked, boost=plan_boost)
        plan = resolve_plan(plan_raw, spec, partition.num_groups)
        up_bytes = full_bytes if spec.is_full else int(group_bytes[spec.group])
        step_flops = _step_flops(spec)

        # Per-member draw order (jitter, then drop) matches the pre-host-
        # parallel runtime exactly, so seeded availability streams replay.
        biased = run_cfg.participation_sampling == "biased"
        members, end_t = [], t
        for i, ci in enumerate(picked):
            if plan_raw is None:
                groups_i, ub, sf = None, up_bytes, step_flops
            else:
                # Capacity-aware books: a client moves and computes exactly
                # its own trained groups' bytes/FLOPs.  (For a collapsed
                # cohort these equal the legacy per-round numbers exactly.)
                groups_i = tuple(int(g) for g in np.flatnonzero(plan_raw[i]))
                ub = (full_bytes if len(groups_i) == partition.num_groups
                      else int(group_bytes[list(groups_i)].sum()))
                sf = _plan_flops(groups_i)
            flops = sf * _steps_per_round(
                len(datasets[i]), run_cfg.batch_size, run_cfg.local_epochs)
            dur = vtm.round_seconds(
                flops, ub, speed=avail.speed(ci), jitter=avail.jitter())
            upd = ClientUpdate(
                client_id=int(ci), version=version, group=spec.group,
                subtree=None, weight=float(len(datasets[i])),
                loss=float("nan"), dispatched_t=t, completed_t=t + dur,
                comp_flops=flops, comm_bytes=ub, groups=groups_i,
                encoding=None if ccfg is None else ccfg.kind,
                inclusion_prob=avail.inclusion_prob(ci) if biased else 1.0,
            )
            members.append((upd, "drop" if avail.drops() else "complete"))
            end_t = max(end_t, t + dur)
        timeline.record(t, "dispatch", version=version, group=spec.group,
                        clients=[int(c) for c in picked], t_end=end_t)
        cohort = _Cohort(picked=picked, datasets=datasets, seeds=seeds,
                         prevs=prevs, spec=spec, plan=plan, params=params,
                         dispatched_t=t, end_t=end_t,
                         updates=[u for u, _ in members],
                         tl_event=timeline.events[-1])
        inflight += 1
        for upd, kind in members:
            if kind == "complete":
                pending += 1
            heapq.heappush(events,
                           (upd.completed_t, next(seq), kind, upd, cohort))
            busy.add(upd.client_id)
        submesh = pool.acquire() if pool is not None else None
        if pool is None or submesh is not None:
            launch(cohort, submesh)
        else:
            launch_queue.append(cohort)
        last_cohort = k
        return k

    def top_up(t: float, fragment_ok: bool = False) -> None:
        """Dispatch until the in-flight target is met (or nothing is
        dispatchable).  With ``max_inflight == 1`` this is exactly one
        attempt — the merge-driven dispatch of the original async runtime.
        Only the first attempt may take a fragment cohort (``fragment_ok``:
        merge- and stall-triggered dispatches), so spare capacity is filled
        with full cohorts or not at all."""
        first = fragment_ok
        while inflight < max_inflight:
            if dispatch(t, first) == 0:
                break
            first = False

    def flush() -> None:
        """Commit one server aggregation: merge the buffer, eval on the sync
        cadence, advance the schedule, let the controller adjust its knobs,
        top the in-flight cohorts back up."""
        nonlocal params, version, max_inflight, cohort_target, plan_boost
        spec = sched.for_version(version)
        params, info = policy.merge(params, buffer, version)
        buffer.clear()
        entry = {"round": spec.index, "phase": spec.phase, "group": spec.group,
                 "loss": info["loss"], "t": vclock, "merged": info["merged"],
                 "staleness_mean": info["staleness_mean"],
                 "staleness_max": info["staleness_max"]}
        timeline.record(vclock, "merge", version=version, group=spec.group, **{
            k: info[k] for k in
            ("loss", "merged", "staleness_mean", "staleness_max")})
        if spec.index % run_cfg.eval_every == 0 or spec.index == total - 1:
            acc = float(eval_fn(params, eval_x[: run_cfg.eval_batch],
                                eval_y[: run_cfg.eval_batch]))
            entry["acc"] = acc
            timeline.record(vclock, "eval", version=version, acc=acc)
        history.append(entry)
        if verbose:
            print(f"merge v{version:3d} [{spec.phase}:{spec.group:3d}] "
                  f"t={vclock:8.2f}s loss={entry['loss']:.4f} "
                  f"acc={entry.get('acc', float('nan')):.4f} "
                  f"stale(mean={entry['staleness_mean']:.2f},"
                  f"max={entry['staleness_max']})")
        version += 1
        if controller is not None and version < total:
            # Observe between merges, apply before the post-merge dispatch so
            # the new targets govern it.  Everything the controller saw is
            # virtual-event-only, so adaptive runs replay on any host.
            adj = controller.observe(timeline.window(run_cfg.controller_window))
            if adj:
                if adj.max_inflight is not None:
                    max_inflight = min(max(adj.max_inflight, 1), pool_cap)
                if adj.buffer_k is not None:
                    policy.buffer_goal = max(adj.buffer_k, 1)
                if (adj.group_override is not None
                        and 0 <= adj.group_override < partition.num_groups):
                    sched.override_group(version, adj.group_override)
                if adj.cohort_size is not None:
                    c_lo, c_hi = run_cfg.controller_cohort_bounds
                    cohort_target = min(max(int(adj.cohort_size), c_lo),
                                        c_hi, n_clients)
                if adj.plan_boost is not None:
                    plan_boost = min(max(int(adj.plan_boost), 0),
                                     run_cfg.controller_plan_boost_max)
                timeline.record(vclock, "control", version=version,
                                max_inflight=max_inflight,
                                buffer_k=policy.buffer_goal,
                                group_override=adj.group_override,
                                cohort_size=cohort_target,
                                plan_boost=plan_boost,
                                note=adj.note)
        if version < total:
            if max_inflight == 1:
                # Merge-driven regime: every merge dispatches, full stop —
                # even when an earlier cohort hasn't delivered its first
                # event yet (a straggler-triggered merge right after another
                # cohort's dispatch).  Gating that on the in-flight count
                # would silently diverge from the original async runtime.
                dispatch(vclock, True)
            else:
                top_up(vclock, fragment_ok=True)

    # -- main loop ----------------------------------------------------------
    top_up(0.0, fragment_ok=True)
    while version < total:
        if not events:
            # No one in flight: either merge the stragglers' leftovers or
            # re-dispatch (e.g. a fully-dropped cohort).
            if buffer and policy.should_merge(len(buffer), 0, last_cohort):
                flush()
                continue
            if dispatch(vclock, True) == 0 and not events:
                # (a failed dispatch may have booked a "retry" wait event —
                # that IS progress: the virtual clock advances to the next
                # arrival window instead of training unavailable clients)
                raise RuntimeError(
                    "async runtime stalled: no events in flight, nothing "
                    "dispatchable, and the buffer cannot merge")
            continue
        t, _, kind, upd, cohort = heapq.heappop(events)
        vclock = t
        if kind == "retry":
            # The booked wait elapsed: the server tries to fill its
            # capacity again, now that an arrival window may have opened.
            retry_pending = False
            if version < total:
                top_up(vclock, fragment_ok=True)
            continue
        busy.discard(upd.client_id)
        resolve(cohort)
        if kind == "complete":
            pending -= 1
            buffer.append(upd)
            timeline.record(t, "complete", client=upd.client_id,
                            staleness=upd.staleness(version),
                            comm_bytes=upd.comm_bytes,
                            comp_flops=upd.comp_flops,
                            inclusion_prob=upd.inclusion_prob,
                            tier=upd.client_id % num_tiers)
        else:
            timeline.record(t, "drop", client=upd.client_id,
                            comp_flops=upd.comp_flops)
        if buffer and policy.should_merge(len(buffer), pending, last_cohort):
            flush()
        elif max_inflight > 1 and version < total:
            top_up(vclock)

    if occupancy.spans:
        timeline.record(vclock, "occupancy", **occupancy.summary())

    # Cost books over the committed server rounds, as actually trained: with
    # no controller the effective specs ARE `rounds` (identical to the sync
    # ledger by construction); group overrides swap in the groups the
    # controller pinned.  The timeline holds the per-update async accounting
    # on top.
    effective = [sched.for_version(v) for v in range(total)]
    comm = comm_cost(params, partition, effective, compression=ccfg)
    comp = comp_cost(partition, effective, group_fwd_flops=group_counts)
    return FLResult(
        history=history,
        params=params,
        partition=partition,
        tracker=None,
        comm_total_bytes=comm.total_bytes,
        comp_total_flops=float(comp.total_flops),
        comm_fnu_bytes=comm.fnu_total_bytes,
        comp_fnu_flops=float(comp.fnu_total_flops),
        timeline=timeline,
    )
