"""Client availability / latency model for the async runtime.

Real federated deployments never see the simulator's implicit "every client
is always reachable, equally fast, and perfectly reliable" regime: devices
come and go, compute speeds span orders of magnitude, and a fraction of
dispatched work simply vanishes (Sen et al. 2025; Liu et al. 2023).
``ClientAvailability`` is the seeded, deterministic stand-in for all of that:

* **compute-speed multipliers** — one persistent log-uniform draw per client
  in ``[1/(1+spread), 1+spread]``; a client's local round takes
  ``flops / (flops_per_second * speed)`` virtual seconds.  The draw is
  *counter-based*: derived lazily per client id from
  ``SeedSequence((seed, stream, client_id))``, so no O(N) speed table is
  ever built — a population of 10^6+ virtual clients costs nothing until a
  client is actually sampled (docs/POPULATION.md);
* **latency jitter** — a fresh multiplicative draw per dispatch in
  ``[1, 1+jitter]``, modelling network variance on top of the deterministic
  cost model (``core.costs.VirtualTimeModel``);
* **dropout** — per-dispatch probability that the client trains but its
  update never reaches the server (compute burned, no bytes delivered);
* **unavailability** — per-dispatch probability a client cannot be sampled
  at all (the arrival process: offline, charging, metered network);
* **availability traces** — deterministic per-client periodic on/off
  windows (``trace="diurnal"``: duty cycle + phase hashed counter-based
  from ``SeedSequence((seed, stream, id))``, O(1) at population scale like
  ``speed()``; ``trace="file"``: a real on-disk trace tiled over the
  fleet).  A client is sampleable at virtual time ``t`` only inside its
  on-window; ``arrival_ok(client_id, t)`` is therefore time- and id-aware,
  and ``next_on_time`` tells the runtime exactly how long to wait when
  every sampled candidate is off (docs/ASYNC.md).

Everything stochastic draws from one ``numpy`` generator seeded by
``AvailabilityConfig.seed``, consumed in dispatch order, so a run is
reproducible event-for-event.  The trace is *pure* — on/off is a function
of ``(seed, client_id, t)`` and consumes no stream randomness — so layering
a trace over the i.i.d. knobs never desyncs the per-dispatch stream, and
the degenerate trace (``duty_cycle=(1.0, 1.0)``: every client always on)
is bit-identical to no trace at all.  Crucially, a **degenerate config
(all knobs 0, no trace) consumes no randomness at all** — the async
runtime's client-selection stream then advances exactly like the
synchronous server's, which is what makes the sync-equivalence guarantee
testable (docs/ASYNC.md).

This model is also the *only* source of fleet feedback the adaptive server
control loop ever sees (``runtime.control``, docs/CONTROL.md): stragglers,
drops, and staleness show up as virtual timeline events, the controller
windows those events, and its knob adjustments change only *future*
dispatches — the availability stream itself is never re-seeded or consumed
out of dispatch order, so static and adaptive runs draw identical
randomness for identical dispatch sequences.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

TRACES = ("", "diurnal", "file")


@dataclasses.dataclass(frozen=True)
class AvailabilityConfig:
    """Knobs of the client availability / latency model (all default to the
    degenerate "perfect fleet": homogeneous, instant, reliable, always on)."""

    speed_spread: float = 0.0       # persistent per-client speed heterogeneity
    latency_jitter: float = 0.0     # per-dispatch multiplicative latency noise
    dropout_prob: float = 0.0       # per-dispatch update-loss probability
    unavailable_prob: float = 0.0   # per-dispatch sampling-exclusion probability
    seed: int = 0
    # -- trace-driven availability (deterministic on/off windows) -----------
    trace: str = ""                 # "" (always on) | "diurnal" | "file"
    trace_period: float = 16.0      # virtual seconds per on/off cycle
    duty_cycle: tuple[float, float] = (1.0, 1.0)  # per-client on-fraction range
    trace_path: str = ""            # on-disk trace (required for trace="file")
    retry_wait: float = 0.5         # virtual-clock backoff when every sampled
    #                                 candidate fails its i.i.d. arrival draw

    def __post_init__(self):
        for name in ("speed_spread", "latency_jitter"):
            if getattr(self, name) < 0.0:
                raise ValueError(f"{name} must be >= 0, got {getattr(self, name)}")
        for name in ("dropout_prob", "unavailable_prob"):
            v = getattr(self, name)
            if not 0.0 <= v < 1.0:
                raise ValueError(f"{name} must be in [0, 1), got {v}")
        if self.trace not in TRACES:
            raise ValueError(f"unknown trace {self.trace!r}; "
                             f"expected one of {TRACES}")
        lo, hi = self.duty_cycle
        if not 0.0 < lo <= hi <= 1.0:
            raise ValueError("duty_cycle must satisfy 0 < lo <= hi <= 1, "
                             f"got {self.duty_cycle}")
        if self.trace and self.trace_period <= 0.0:
            raise ValueError(f"trace_period must be > 0, got {self.trace_period}")
        if self.trace == "file" and not self.trace_path:
            raise ValueError("trace='file' requires a trace_path")
        if self.retry_wait <= 0.0:
            raise ValueError(f"retry_wait must be > 0, got {self.retry_wait}")

    @property
    def is_degenerate(self) -> bool:
        """True when the model is the perfect fleet (sync-equivalent)."""
        return (self.speed_spread == 0.0 and self.latency_jitter == 0.0
                and self.dropout_prob == 0.0 and self.unavailable_prob == 0.0
                and self.trace == "")


# SeedSequence stream tags for the per-client persistent draws: keyed by
# (seed, tag, client_id) so speeds / trace parameters are pure functions of
# the id — identical whether the fleet has 8 clients or 10^8, and regardless
# of sampling order.
_SPEED_STREAM = 0x5BEED
_TRACE_STREAM = 0x7AACE


class ClientAvailability:
    """Seeded realisation of ``AvailabilityConfig`` for ``num_clients``.

    O(1) to construct at any population size: per-client speeds and trace
    parameters are derived lazily (counter-based hashing per id, memoised
    for sampled clients), and the per-dispatch event stream is a single
    generator consumed in dispatch order as before.  (``trace="file"``
    additionally reads its trace file once, on first use — O(trace), never
    O(population).)"""

    def __init__(self, cfg: AvailabilityConfig, num_clients: int):
        if num_clients < 1:
            raise ValueError(f"num_clients must be >= 1, got {num_clients}")
        self.cfg = cfg
        self.num_clients = num_clients
        self._speed_cache: dict[int, float] = {}
        # id -> (duty, phase, period); populated lazily per sampled client.
        self._trace_cache: dict[int, tuple[float, float, float]] = {}
        self._file_trace: tuple[float, np.ndarray, np.ndarray] | None = None
        # Per-dispatch draws come from a *separate* stream so the number of
        # clients never shifts the event randomness.
        self._rng = np.random.default_rng((cfg.seed, 0x5EED))

    def speed(self, client_id: int) -> float:
        """Persistent log-uniform multiplier in [1/(1+spread), 1+spread],
        a pure function of (seed, client_id) — no O(N) table, no draw at
        all on a homogeneous fleet (the degenerate no-RNG contract)."""
        if self.cfg.speed_spread <= 0.0:
            return 1.0
        s = self._speed_cache.get(client_id)
        if s is None:
            rng = np.random.default_rng(np.random.SeedSequence(
                (self.cfg.seed, _SPEED_STREAM, int(client_id))))
            lim = np.log1p(self.cfg.speed_spread)
            s = float(np.exp(rng.uniform(-lim, lim)))
            self._speed_cache[client_id] = s
        return s

    @property
    def speeds(self) -> np.ndarray:
        """The full speed table (diagnostics / small fleets only — this is
        the one deliberately O(N) accessor)."""
        return np.array([self.speed(i) for i in range(self.num_clients)])

    def jitter(self) -> float:
        """Multiplicative latency factor for one dispatch (1.0 when off)."""
        if self.cfg.latency_jitter <= 0.0:
            return 1.0
        return float(self._rng.uniform(1.0, 1.0 + self.cfg.latency_jitter))

    def drops(self) -> bool:
        """Whether this dispatch's update is lost in transit."""
        if self.cfg.dropout_prob <= 0.0:
            return False
        return bool(self._rng.random() < self.cfg.dropout_prob)

    # -- trace-driven on/off windows ----------------------------------------

    def _load_file_trace(self) -> tuple[float, np.ndarray, np.ndarray]:
        """Read an on-disk availability trace (once, lazily).

        Two formats: a ``.npz`` with ``duty`` / ``phase`` arrays (and an
        optional scalar ``period``), or a JSON object with the same keys.
        Client ``i`` uses entry ``i % len(duty)`` — a short real-device
        trace tiles over an arbitrarily large virtual fleet."""
        if self._file_trace is None:
            path = self.cfg.trace_path
            if path.endswith(".npz"):
                with np.load(path) as data:
                    duty = np.asarray(data["duty"], dtype=np.float64)
                    phase = np.asarray(data["phase"], dtype=np.float64)
                    period = (float(data["period"]) if "period" in data
                              else self.cfg.trace_period)
            else:
                with open(path) as f:
                    obj = json.load(f)
                duty = np.asarray(obj["duty"], dtype=np.float64)
                phase = np.asarray(obj["phase"], dtype=np.float64)
                period = float(obj.get("period", self.cfg.trace_period))
            if duty.ndim != 1 or duty.size < 1 or phase.shape != duty.shape:
                raise ValueError(
                    f"trace file {path!r} needs 1-D duty/phase arrays of "
                    f"equal nonzero length, got {duty.shape} / {phase.shape}")
            if not ((duty > 0.0) & (duty <= 1.0)).all():
                raise ValueError(
                    f"trace file {path!r} duty entries must lie in (0, 1]")
            if period <= 0.0:
                raise ValueError(
                    f"trace file {path!r} period must be > 0, got {period}")
            self._file_trace = (period, duty, np.mod(phase, 1.0))
        return self._file_trace

    def _trace_params(self, client_id: int) -> tuple[float, float, float]:
        """``(duty, phase, period)`` for one client — a pure function of
        (seed, client_id) for the diurnal trace (counter-based, like
        ``speed``), or the tiled file entry.  Memoised per sampled id."""
        p = self._trace_cache.get(client_id)
        if p is None:
            if self.cfg.trace == "file":
                period, duty, phase = self._load_file_trace()
                i = int(client_id) % duty.size
                p = (float(duty[i]), float(phase[i]), period)
            else:
                rng = np.random.default_rng(np.random.SeedSequence(
                    (self.cfg.seed, _TRACE_STREAM, int(client_id))))
                lo, hi = self.cfg.duty_cycle
                p = (float(rng.uniform(lo, hi)), float(rng.random()),
                     self.cfg.trace_period)
            self._trace_cache[client_id] = p
        return p

    def trace_on(self, client_id: int, t: float) -> bool:
        """Whether the client's trace window is *on* at virtual time ``t``
        (always True without a trace).  Pure — consumes no randomness."""
        if not self.cfg.trace:
            return True
        duty, phase, period = self._trace_params(client_id)
        if duty >= 1.0:
            return True
        return float(np.mod(t / period + phase, 1.0)) < duty

    def next_on_time(self, client_id: int, t: float) -> float:
        """Earliest virtual time >= ``t`` the client's window is on —
        ``t`` itself when already on, else the start of the next cycle.
        Deterministic: this is what the runtime books its wait/retry
        event at when every sampled candidate is off."""
        if self.trace_on(client_id, t):
            return t
        _, phase, period = self._trace_params(client_id)
        pos = float(np.mod(t / period + phase, 1.0))
        return t + (1.0 - pos) * period

    def availability_weight(self, client_id: int, t: float) -> float:
        """The client's *current* availability — the biased cohort
        sampler's selection weight: its trace window (0/1) times the
        stationary i.i.d. arrival rate.  Pure — consumes no randomness."""
        on = 1.0 if self.trace_on(client_id, t) else 0.0
        return on * (1.0 - self.cfg.unavailable_prob)

    def inclusion_prob(self, client_id: int) -> float:
        """Stationary per-client inclusion rate relative to an always-on
        client — its trace duty cycle (1.0 without a trace).  Recorded on
        each ``ClientUpdate`` under biased sampling so the merge can
        inverse-probability debias (docs/ASYNC.md); the i.i.d.
        ``unavailable_prob`` factor is shared by every client and cancels
        in the normalised average, so it is deliberately not included."""
        if not self.cfg.trace:
            return 1.0
        duty, _, _ = self._trace_params(client_id)
        return min(duty, 1.0)

    def arrival_ok(self, client_id: int | None = None, t: float = 0.0) -> bool:
        """One candidate's arrival draw at virtual time ``t``
        (population-scale sampling: the availability filter runs over
        *sampled* candidates only, never the whole fleet).  The trace
        check is pure and runs first — an off-window client is rejected
        without touching the stream — then the i.i.d. knob draws exactly
        as before, so no-trace configs replay bit-for-bit and the knob-off
        path consumes no randomness (the degenerate-config contract)."""
        if self.cfg.trace:
            if client_id is None:
                raise ValueError(
                    "trace-driven availability needs a client_id")
            if not self.trace_on(client_id, t):
                return False
        if self.cfg.unavailable_prob <= 0.0:
            return True
        return bool(self._rng.random() >= self.cfg.unavailable_prob)
