"""Client availability / latency model for the async runtime.

Real federated deployments never see the simulator's implicit "every client
is always reachable, equally fast, and perfectly reliable" regime: devices
come and go, compute speeds span orders of magnitude, and a fraction of
dispatched work simply vanishes (Sen et al. 2025; Liu et al. 2023).
``ClientAvailability`` is the seeded, deterministic stand-in for all of that:

* **compute-speed multipliers** — one persistent log-uniform draw per client
  in ``[1/(1+spread), 1+spread]``; a client's local round takes
  ``flops / (flops_per_second * speed)`` virtual seconds.  The draw is
  *counter-based*: derived lazily per client id from
  ``SeedSequence((seed, stream, client_id))``, so no O(N) speed table is
  ever built — a population of 10^6+ virtual clients costs nothing until a
  client is actually sampled (docs/POPULATION.md);
* **latency jitter** — a fresh multiplicative draw per dispatch in
  ``[1, 1+jitter]``, modelling network variance on top of the deterministic
  cost model (``core.costs.VirtualTimeModel``);
* **dropout** — per-dispatch probability that the client trains but its
  update never reaches the server (compute burned, no bytes delivered);
* **unavailability** — per-dispatch probability a client cannot be sampled
  at all (the arrival process: offline, charging, metered network).

Everything draws from one ``numpy`` generator seeded by
``AvailabilityConfig.seed``, consumed in dispatch order, so a run is
reproducible event-for-event.  Crucially, a **degenerate config (all knobs
0) consumes no randomness at all** — the async runtime's client-selection
stream then advances exactly like the synchronous server's, which is what
makes the sync-equivalence guarantee testable (docs/ASYNC.md).

This model is also the *only* source of fleet feedback the adaptive server
control loop ever sees (``runtime.control``, docs/CONTROL.md): stragglers,
drops, and staleness show up as virtual timeline events, the controller
windows those events, and its knob adjustments change only *future*
dispatches — the availability stream itself is never re-seeded or consumed
out of dispatch order, so static and adaptive runs draw identical
randomness for identical dispatch sequences.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class AvailabilityConfig:
    """Knobs of the client availability / latency model (all default to the
    degenerate "perfect fleet": homogeneous, instant, reliable, always on)."""

    speed_spread: float = 0.0       # persistent per-client speed heterogeneity
    latency_jitter: float = 0.0     # per-dispatch multiplicative latency noise
    dropout_prob: float = 0.0       # per-dispatch update-loss probability
    unavailable_prob: float = 0.0   # per-dispatch sampling-exclusion probability
    seed: int = 0

    def __post_init__(self):
        for name in ("speed_spread", "latency_jitter"):
            if getattr(self, name) < 0.0:
                raise ValueError(f"{name} must be >= 0, got {getattr(self, name)}")
        for name in ("dropout_prob", "unavailable_prob"):
            v = getattr(self, name)
            if not 0.0 <= v < 1.0:
                raise ValueError(f"{name} must be in [0, 1), got {v}")

    @property
    def is_degenerate(self) -> bool:
        """True when the model is the perfect fleet (sync-equivalent)."""
        return (self.speed_spread == 0.0 and self.latency_jitter == 0.0
                and self.dropout_prob == 0.0 and self.unavailable_prob == 0.0)


# SeedSequence stream tag for the per-client persistent speed draw: keyed by
# (seed, tag, client_id) so speeds are a pure function of the id — identical
# whether the fleet has 8 clients or 10^8, and regardless of sampling order.
_SPEED_STREAM = 0x5BEED


class ClientAvailability:
    """Seeded realisation of ``AvailabilityConfig`` for ``num_clients``.

    O(1) to construct at any population size: per-client speeds are derived
    lazily (counter-based hashing per id, memoised for sampled clients), and
    the per-dispatch event stream is a single generator consumed in dispatch
    order as before."""

    def __init__(self, cfg: AvailabilityConfig, num_clients: int):
        if num_clients < 1:
            raise ValueError(f"num_clients must be >= 1, got {num_clients}")
        self.cfg = cfg
        self.num_clients = num_clients
        self._speed_cache: dict[int, float] = {}
        # Per-dispatch draws come from a *separate* stream so the number of
        # clients never shifts the event randomness.
        self._rng = np.random.default_rng((cfg.seed, 0x5EED))

    def speed(self, client_id: int) -> float:
        """Persistent log-uniform multiplier in [1/(1+spread), 1+spread],
        a pure function of (seed, client_id) — no O(N) table, no draw at
        all on a homogeneous fleet (the degenerate no-RNG contract)."""
        if self.cfg.speed_spread <= 0.0:
            return 1.0
        s = self._speed_cache.get(client_id)
        if s is None:
            rng = np.random.default_rng(np.random.SeedSequence(
                (self.cfg.seed, _SPEED_STREAM, int(client_id))))
            lim = np.log1p(self.cfg.speed_spread)
            s = float(np.exp(rng.uniform(-lim, lim)))
            self._speed_cache[client_id] = s
        return s

    @property
    def speeds(self) -> np.ndarray:
        """The full speed table (diagnostics / small fleets only — this is
        the one deliberately O(N) accessor)."""
        return np.array([self.speed(i) for i in range(self.num_clients)])

    def jitter(self) -> float:
        """Multiplicative latency factor for one dispatch (1.0 when off)."""
        if self.cfg.latency_jitter <= 0.0:
            return 1.0
        return float(self._rng.uniform(1.0, 1.0 + self.cfg.latency_jitter))

    def drops(self) -> bool:
        """Whether this dispatch's update is lost in transit."""
        if self.cfg.dropout_prob <= 0.0:
            return False
        return bool(self._rng.random() < self.cfg.dropout_prob)

    def available(self, candidates: Sequence[int]) -> list[int]:
        """Filter a candidate (idle) client list through the arrival process.

        With ``unavailable_prob == 0`` this is the identity and consumes no
        randomness (the degenerate-config contract)."""
        cand = list(candidates)
        if self.cfg.unavailable_prob <= 0.0 or not cand:
            return cand
        keep = self._rng.random(len(cand)) >= self.cfg.unavailable_prob
        return [c for c, k in zip(cand, keep) if k]

    def arrival_ok(self) -> bool:
        """One candidate's arrival draw (population-scale sampling: the
        availability filter runs over *sampled* candidates only, never the
        whole fleet).  Consumes no randomness when the knob is off — the
        degenerate-config contract."""
        if self.cfg.unavailable_prob <= 0.0:
            return True
        return bool(self._rng.random() >= self.cfg.unavailable_prob)
