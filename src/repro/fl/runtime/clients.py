"""Client availability / latency model for the async runtime.

Real federated deployments never see the simulator's implicit "every client
is always reachable, equally fast, and perfectly reliable" regime: devices
come and go, compute speeds span orders of magnitude, and a fraction of
dispatched work simply vanishes (Sen et al. 2025; Liu et al. 2023).
``ClientAvailability`` is the seeded, deterministic stand-in for all of that:

* **compute-speed multipliers** — one persistent log-uniform draw per client
  in ``[1/(1+spread), 1+spread]``; a client's local round takes
  ``flops / (flops_per_second * speed)`` virtual seconds;
* **latency jitter** — a fresh multiplicative draw per dispatch in
  ``[1, 1+jitter]``, modelling network variance on top of the deterministic
  cost model (``core.costs.VirtualTimeModel``);
* **dropout** — per-dispatch probability that the client trains but its
  update never reaches the server (compute burned, no bytes delivered);
* **unavailability** — per-dispatch probability a client cannot be sampled
  at all (the arrival process: offline, charging, metered network).

Everything draws from one ``numpy`` generator seeded by
``AvailabilityConfig.seed``, consumed in dispatch order, so a run is
reproducible event-for-event.  Crucially, a **degenerate config (all knobs
0) consumes no randomness at all** — the async runtime's client-selection
stream then advances exactly like the synchronous server's, which is what
makes the sync-equivalence guarantee testable (docs/ASYNC.md).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class AvailabilityConfig:
    """Knobs of the client availability / latency model (all default to the
    degenerate "perfect fleet": homogeneous, instant, reliable, always on)."""

    speed_spread: float = 0.0       # persistent per-client speed heterogeneity
    latency_jitter: float = 0.0     # per-dispatch multiplicative latency noise
    dropout_prob: float = 0.0       # per-dispatch update-loss probability
    unavailable_prob: float = 0.0   # per-dispatch sampling-exclusion probability
    seed: int = 0

    def __post_init__(self):
        for name in ("speed_spread", "latency_jitter"):
            if getattr(self, name) < 0.0:
                raise ValueError(f"{name} must be >= 0, got {getattr(self, name)}")
        for name in ("dropout_prob", "unavailable_prob"):
            v = getattr(self, name)
            if not 0.0 <= v < 1.0:
                raise ValueError(f"{name} must be in [0, 1), got {v}")

    @property
    def is_degenerate(self) -> bool:
        """True when the model is the perfect fleet (sync-equivalent)."""
        return (self.speed_spread == 0.0 and self.latency_jitter == 0.0
                and self.dropout_prob == 0.0 and self.unavailable_prob == 0.0)


class ClientAvailability:
    """Seeded realisation of ``AvailabilityConfig`` for ``num_clients``."""

    def __init__(self, cfg: AvailabilityConfig, num_clients: int):
        if num_clients < 1:
            raise ValueError(f"num_clients must be >= 1, got {num_clients}")
        self.cfg = cfg
        self.num_clients = num_clients
        rng = np.random.default_rng(cfg.seed)
        if cfg.speed_spread > 0.0:
            lo, hi = -np.log1p(cfg.speed_spread), np.log1p(cfg.speed_spread)
            self.speeds = np.exp(rng.uniform(lo, hi, num_clients))
        else:
            self.speeds = np.ones(num_clients, dtype=np.float64)
        # Per-dispatch draws come from a *separate* stream so adding clients
        # (more speed draws) doesn't shift the event randomness.
        self._rng = np.random.default_rng((cfg.seed, 0x5EED))

    def speed(self, client_id: int) -> float:
        return float(self.speeds[client_id])

    def jitter(self) -> float:
        """Multiplicative latency factor for one dispatch (1.0 when off)."""
        if self.cfg.latency_jitter <= 0.0:
            return 1.0
        return float(self._rng.uniform(1.0, 1.0 + self.cfg.latency_jitter))

    def drops(self) -> bool:
        """Whether this dispatch's update is lost in transit."""
        if self.cfg.dropout_prob <= 0.0:
            return False
        return bool(self._rng.random() < self.cfg.dropout_prob)

    def available(self, candidates: Sequence[int]) -> list[int]:
        """Filter a candidate (idle) client list through the arrival process.

        With ``unavailable_prob == 0`` this is the identity and consumes no
        randomness (the degenerate-config contract)."""
        cand = list(candidates)
        if self.cfg.unavailable_prob <= 0.0 or not cand:
            return cand
        keep = self._rng.random(len(cand)) >= self.cfg.unavailable_prob
        return [c for c, k in zip(cand, keep) if k]
