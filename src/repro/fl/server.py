"""Server orchestration: the FedPart / FNU round loop (paper §3).

Per round: select trainable group from the schedule, broadcast, clients train
locally, server averages exactly the transmitted parameters (full network on
FNU rounds, the trainable group's subtree on partial rounds; BN running
statistics never travel), evaluates the global model on the balanced set,
and books communication/compute costs.

Client execution is delegated to a pluggable engine (``repro.fl.batched``):

* ``engine="sequential"`` — the reference oracle: a Python loop over the
  selected clients, one jitted dispatch per (client, step);
* ``engine="vmap"``       — the batched engine: clients stacked along a
  leading axis, the whole local round one vmapped compiled program and the
  aggregation one on-device reduction;
* ``engine="shard_map"``  — the multi-device engine: the stacked client axis
  sharded over a 1-D "clients" mesh (``sim_devices`` of them; 0 = all), local
  rounds vmapped per device and aggregation an on-mesh psum of the
  transmitted subtree only.

All three are equivalent to <=1e-5 (``tests/test_engine_equivalence.py``);
docs/ENGINES.md is the quick reference for picking one.

Orthogonally to the engine, ``FLRunConfig(runtime=...)`` picks the *runtime*
— how rounds relate to time:

* ``runtime="sync"``  — this module's loop: one barrier per schedule entry;
* ``runtime="async"`` — the event-driven simulator (``repro.fl.runtime``):
  client availability/latency/dropout on a virtual clock, buffered
  staleness-weighted aggregation (FedBuff), partial participation, and
  time-to-accuracy as first-class output.  In the degenerate config (perfect
  fleet, full buffer, exponent 0) it reproduces this loop to <=1e-5
  (docs/ASYNC.md).

Orthogonally to both, ``FLRunConfig(plan=..., capacity_tiers=...)`` picks the
*per-client layer plan* (``core.schedule.PlanAssigner``): with
``plan="homogeneous"`` (default) every client trains the round's scheduled
group exactly as before; ``"nested"`` / ``"random"`` give capacity-tiered
clients different group subsets in the same round, and aggregation averages
each group over only the clients that trained it (docs/HETEROGENEITY.md).

``FLRunConfig(compression=...)`` additionally compresses the transmitted
subtree at the client→server boundary (int8 / 1-bit / top-k with per-client
error feedback, ``core.compress``); ``"none"`` (default) is structurally
absent — today's paths bit-for-bit (docs/COMPRESSION.md).

``clients_data`` may also be a ``fl.population.ClientPopulation`` — a
*streaming* client store that produces shards on demand from
(seed, client_id), so cohorts can be sampled from populations of millions of
virtual clients with host cost O(cohort): selection is Floyd's O(cohort)
algorithm, per-(round, client) seeds are collision-free ``SeedSequence``
hashes, and cross-round per-client state (MOON prev-models, EF residuals)
lives in a bounded LRU ``ClientStateStore`` with optional disk spill
(``state_store_entries`` / ``state_store_spill``, docs/POPULATION.md).  A
legacy materialised ``Sequence`` is wrapped transparently and behaves
exactly as before.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import numpy as np

from repro.core import compress
from repro.core.costs import VirtualTimeModel, comm_cost, comp_cost
from repro.core.partition import Partition, group_param_counts
from repro.core.schedule import PlanAssigner, RoundSpec
from repro.core.telemetry import StepSizeTracker, Timeline
from repro.fl.algorithms import AlgoConfig
from repro.fl.batched import make_engine
from repro.fl.client import LocalTrainer
from repro.fl.population import (ClientPopulation, ClientStateStore,
                                 as_population, client_round_seed,
                                 resolve_cohort_size,
                                 sample_without_replacement)
from repro.fl.runtime.clients import AvailabilityConfig
from repro.fl.tasks import TaskAdapter
from repro.optim.adam import AdamConfig

PyTree = Any

RUNTIMES = ("sync", "async")


@dataclasses.dataclass(frozen=True)
class FLRunConfig:
    local_epochs: int = 8
    batch_size: int = 32
    lr: float = 1e-3
    adam_eps: float = 1e-8
    algo: AlgoConfig = AlgoConfig()
    sample_fraction: float = 1.0    # participation fraction per dispatch/round
    cohort_size: int = 0            # explicit clients per dispatch (0 = use fraction)
    # Async cohort selection (docs/ASYNC.md): "blind" rejection-samples each
    # candidate through its own arrival draw (the legacy path, bit-exact);
    # "biased" weights candidates by current availability and records each
    # pick's inclusion probability for inverse-probability debiased merges.
    participation_sampling: str = "blind"   # "blind" | "biased" (async only)
    seed: int = 0
    eval_every: int = 1
    eval_batch: int = 256
    track_stepsizes: bool = False
    engine: str = "sequential"      # "sequential" | "vmap" | "shard_map"
    sim_devices: int = 0            # shard_map mesh size (0 = all devices)
    donate_buffers: bool = True     # donate params into the agg jit + MOON prev stack (batched engines)
    fused_adam: bool = False        # Pallas masked-Adam local steps (docs/KERNELS.md)
    # -- transmitted-subtree compression (core.compress, docs/COMPRESSION.md)
    compression: str = "none"       # "none" | "int8" | "onebit" | "topk"
    topk_fraction: float = 0.01     # retained fraction per leaf (topk only)
    error_feedback: bool = True     # per-client EF residuals (compressed kinds)
    compression_block_rows: int = 0  # scale granularity: 0 = per leaf, B = B*128-elem blocks
    # -- per-client layer plans (heterogeneous fleets, docs/HETEROGENEITY.md)
    plan: str = "homogeneous"       # "homogeneous" | "nested" | "random"
    capacity_tiers: tuple[float, ...] = ()  # tier capacities in (0,1]; () = one full-capacity tier
    # -- bounded per-client state (population scale, docs/POPULATION.md) ----
    state_store_entries: int = 0    # LRU cap on MOON prevs + EF residuals (0 = unbounded)
    state_store_spill: str = ""     # spill dir for evicted entries ("" = drop on evict)
    # -- runtime (sync barrier loop vs event-driven async simulator) --------
    runtime: str = "sync"           # "sync" | "async" (repro.fl.runtime)
    async_policy: str = "fedbuff"   # "fedbuff" | "sync" (barrier oracle)
    buffer_k: int = 0               # FedBuff goal K (0 = cohort size)
    staleness_exponent: float = 0.0  # poly staleness discount (1+s)^-a
    availability: AvailabilityConfig = AvailabilityConfig()
    vtime: VirtualTimeModel = VirtualTimeModel()
    # Host-parallel dispatch: cohorts concurrently in flight.  1 = the
    # merge-driven dispatch of the original async runtime (dispatch only at
    # merges/stalls); >1 keeps that many cohorts training at once, each on
    # its own disjoint device submesh when the engine has one to give
    # (docs/ASYNC.md "Host-parallel dispatch").
    max_inflight_cohorts: int = 1
    # -- adaptive server control loop (fl/runtime/control.py, docs/CONTROL.md)
    controller: str = "static"      # "static" (no controller object) | "adaptive"
    controller_window: int = 4      # merges per observation window
    controller_inflight_bounds: tuple[int, int] = (1, 4)  # adaptive inflight lo/hi
    controller_buffer_bounds: tuple[int, int] = (1, 8)    # adaptive buffer_k lo/hi
    controller_mix_floor: float = 0.5  # min windowed discounted mixing coeff
    controller_max_repeats: int = 2    # consecutive layer-group repeats cap
    # The two participation knobs (docs/CONTROL.md): a windowed
    # effective-participation target the ParticipationController holds by
    # moving the cohort size inside controller_cohort_bounds (0.0 = off),
    # and the PlanAssignmentController's cap on extra layer groups added to
    # every capacity tier's plan prefix (0 = off).
    controller_participation_target: float = 0.0
    controller_cohort_bounds: tuple[int, int] = (1, 64)
    controller_plan_boost_max: int = 0

    def __post_init__(self):
        """Loud validation of the participation axis — a fraction of 0 used
        to silently train 1 client per round via ``resolve_cohort_size``'s
        ``max(1, ...)`` clamp."""
        if not 0.0 < self.sample_fraction <= 1.0:
            raise ValueError(
                f"sample_fraction must be in (0, 1], got {self.sample_fraction}")
        if self.cohort_size < 0:
            raise ValueError(
                f"cohort_size must be >= 0, got {self.cohort_size}")
        if self.participation_sampling not in ("blind", "biased"):
            raise ValueError(
                f"unknown participation_sampling "
                f"{self.participation_sampling!r}; expected 'blind' or "
                f"'biased'")
        if not 0.0 <= self.controller_participation_target <= 1.0:
            raise ValueError(
                f"controller_participation_target must be in [0, 1], got "
                f"{self.controller_participation_target}")
        lo, hi = self.controller_cohort_bounds
        if not 1 <= lo <= hi:
            raise ValueError(
                f"controller_cohort_bounds must satisfy 1 <= lo <= hi, got "
                f"{self.controller_cohort_bounds}")
        if self.controller_plan_boost_max < 0:
            raise ValueError(
                f"controller_plan_boost_max must be >= 0, got "
                f"{self.controller_plan_boost_max}")

    def make_state_store(self) -> ClientStateStore:
        """The per-run store for cross-round per-client state (MOON
        prev-models, EF residuals).  The defaults mean unbounded — the
        legacy dict semantics, bit-for-bit."""
        return ClientStateStore(max_entries=self.state_store_entries,
                                spill_dir=self.state_store_spill or None)


@dataclasses.dataclass
class FLResult:
    history: list[dict]
    params: PyTree
    partition: Partition
    tracker: StepSizeTracker | None
    comm_total_bytes: int
    comp_total_flops: float
    comm_fnu_bytes: int
    comp_fnu_flops: float
    timeline: Timeline | None = None   # async runtime: virtual-clock event log

    @property
    def best_acc(self) -> float:
        accs = [h["acc"] for h in self.history if "acc" in h]
        return max(accs) if accs else float("nan")

    @property
    def final_acc(self) -> float:
        accs = [h["acc"] for h in self.history if "acc" in h]
        return accs[-1] if accs else float("nan")


def run_federated(
    adapter: TaskAdapter,
    clients_data: Sequence | ClientPopulation,
    eval_set: tuple[np.ndarray, np.ndarray],
    rounds: Sequence[RoundSpec],
    run_cfg: FLRunConfig,
    *,
    init_key=None,
    verbose: bool = False,
) -> FLResult:
    if run_cfg.runtime == "async":
        from repro.fl.runtime.engine import run_federated_async
        return run_federated_async(adapter, clients_data, eval_set, rounds,
                                   run_cfg, init_key=init_key, verbose=verbose)
    if run_cfg.runtime != "sync":
        raise ValueError(
            f"unknown runtime {run_cfg.runtime!r}; expected one of {RUNTIMES}")
    if run_cfg.participation_sampling != "blind":
        raise ValueError(
            "participation_sampling='biased' needs the arrival process — "
            "use runtime='async'")
    if run_cfg.track_stepsizes and run_cfg.engine != "sequential":
        raise ValueError("track_stepsizes requires engine='sequential'")
    key = init_key if init_key is not None else jax.random.key(run_cfg.seed)
    params = adapter.init(key)
    partition = adapter.partition(params)
    trainer = LocalTrainer(
        adapter=adapter,
        partition=partition,
        algo=run_cfg.algo,
        adam=AdamConfig(lr=run_cfg.lr, eps=run_cfg.adam_eps),
    )
    ccfg = compress.make_config(
        run_cfg.compression, topk_fraction=run_cfg.topk_fraction,
        error_feedback=run_cfg.error_feedback,
        block_rows=run_cfg.compression_block_rows)
    state_store = run_cfg.make_state_store()
    engine = make_engine(
        run_cfg.engine, trainer=trainer, partition=partition,
        algo=run_cfg.algo, sim_devices=run_cfg.sim_devices,
        donate=run_cfg.donate_buffers, fused_adam=run_cfg.fused_adam,
        compression=ccfg, state_store=state_store,
    )
    assigner = PlanAssigner(
        num_groups=partition.num_groups, kind=run_cfg.plan,
        capacity_tiers=tuple(run_cfg.capacity_tiers), seed=run_cfg.seed)
    rng = np.random.default_rng(run_cfg.seed)
    eval_x, eval_y = eval_set
    eval_fn = jax.jit(adapter.evaluate)

    tracker = StepSizeTracker() if run_cfg.track_stepsizes else None
    history: list[dict] = []
    is_moon = run_cfg.algo.name == "moon"

    # The population seam: a legacy Sequence becomes a (materialised)
    # population; everything below touches only the sampled cohort, so a
    # streaming population of millions costs O(cohort) per round.
    population = as_population(clients_data)
    n_clients = population.num_clients
    for spec in rounds:
        n_pick = resolve_cohort_size(n_clients, run_cfg.sample_fraction,
                                     run_cfg.cohort_size)
        picked = sample_without_replacement(rng, n_clients, n_pick)
        if tracker is not None:
            tracker.mark_round_boundary()

        datasets = [population.dataset(ci) for ci in picked]
        seeds = [client_round_seed(run_cfg.seed, spec.index, ci)
                 for ci in picked]
        weights = [len(d) for d in datasets]
        prevs = ([state_store.get("moon", int(ci)) for ci in picked]
                 if is_moon else None)

        params, losses, new_locals = engine.run_round(
            params,
            spec,
            datasets,
            seeds=seeds,
            weights=weights,
            epochs=run_cfg.local_epochs,
            batch_size=run_cfg.batch_size,
            prev_params=prevs,
            tracker=tracker,
            plan=assigner.assign(spec, [int(ci) for ci in picked]),
            client_ids=[int(ci) for ci in picked],
        )
        if new_locals is not None:
            for ci, local in zip(picked, new_locals):
                state_store.put("moon", int(ci), local)

        entry = {"round": spec.index, "phase": spec.phase, "group": spec.group,
                 "loss": float(np.mean(losses))}
        if spec.index % run_cfg.eval_every == 0 or spec.index == len(rounds) - 1:
            acc = float(eval_fn(params, eval_x[: run_cfg.eval_batch], eval_y[: run_cfg.eval_batch]))
            entry["acc"] = acc
        history.append(entry)
        if verbose:
            print(f"round {spec.index:3d} [{spec.phase}:{spec.group:3d}] "
                  f"loss={entry['loss']:.4f} acc={entry.get('acc', float('nan')):.4f}")

    # Cost bookkeeping (per client, per the paper's Comm./Comp. metrics).
    group_weights = group_param_counts(params, partition).astype(np.float64)
    comm = comm_cost(params, partition, rounds, compression=ccfg)
    comp = comp_cost(partition, rounds, group_fwd_flops=group_weights)
    return FLResult(
        history=history,
        params=params,
        partition=partition,
        tracker=tracker,
        comm_total_bytes=comm.total_bytes,
        comp_total_flops=float(comp.total_flops),
        comm_fnu_bytes=comm.fnu_total_bytes,
        comp_fnu_flops=float(comp.fnu_total_flops),
    )
