"""Population-scale streaming client store (docs/POPULATION.md).

Cohorts sampled from millions of virtual clients: client state — dataset
shards, MOON prev-models, error-feedback residuals, capacity tiers, speed
multipliers — is produced on demand from (seed, client_id), so host memory
and per-round overhead scale with the *cohort*, never the population.
"""

from repro.fl.population.base import (  # noqa: F401
    ClientPopulation,
    MaterializedPopulation,
    as_population,
)
from repro.fl.population.sampling import (  # noqa: F401
    IncrementalSampler,
    client_round_seed,
    resolve_cohort_size,
    sample_excluding,
    sample_without_replacement,
    weighted_sample_without_replacement,
)
from repro.fl.population.store import ClientStateStore  # noqa: F401
from repro.fl.population.synthetic import SyntheticPopulation  # noqa: F401
