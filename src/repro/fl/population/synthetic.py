"""Streaming synthetic populations: shards synthesized from (seed, id).

The million-client regime cannot partition one global array — the array
itself would be the O(N) cost.  ``SyntheticPopulation`` instead *derives*
each client's shard directly from the population seed and the client id:

* a per-client ``np.random.SeedSequence((seed, stream, client_id))`` gives
  counter-based, order-independent randomness — client 999_999's shard is
  identical whether it is the first or the millionth ever sampled, and two
  processes agree without coordination;
* the shard itself reuses ``data.synthetic``'s generators
  (``make_vision_dataset`` / ``make_text_dataset``), so a streamed client
  sees exactly the class prototypes / Markov structure a materialised split
  of the same spec would (the task is a property of the spec's
  ``proto_seed``, not of the population);
* label skew follows the Dirichlet(α) recipe of ``data.partitioner``: each
  client draws a persistent class-probability vector from Dirichlet(α·1)
  and samples its labels from it — per-id, no global label array.  ``α = 0``
  keeps the uniform (IID-in-distribution) stream;
* shard sizes are deterministic per id (fixed, or log-range drawn), and
  ``num_samples`` answers without building arrays — aggregation weights and
  the async runtime's virtual-time books never force materialisation.

A bounded ``ClientStateStore`` (kind ``"data"``) caches recently-built
shards so a cohort that is re-sampled soon does not pay regeneration, with
optional disk spill; host memory stays O(cache), never O(N).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.pipeline import ClientDataset
from repro.data.synthetic import (TextDatasetSpec, VisionDatasetSpec,
                                  make_text_dataset, make_vision_dataset)
from repro.fl.population.base import ClientPopulation
from repro.fl.population.store import ClientStateStore

# Stream tags: keep the independent per-client draws (shard size / label
# skew vs. sample noise) on distinct SeedSequence keys.
_PLAN_STREAM = 0x0DA7A
_SAMPLE_STREAM = 0x5A3D5


@dataclasses.dataclass
class SyntheticPopulation(ClientPopulation):
    """Virtual fleet of ``population`` clients with on-demand shards.

    ``samples_per_client`` is either a fixed ``int`` or an inclusive
    ``(lo, hi)`` range drawn per client; ``alpha > 0`` switches the per-client
    label distribution to Dirichlet(α) skew (``data.partitioner`` semantics,
    derived per id); ``cache_entries`` bounds the in-memory shard cache
    (0 = cache nothing beyond the entry being built).
    """

    spec: VisionDatasetSpec | TextDatasetSpec
    population: int
    samples_per_client: int | tuple[int, int] = 64
    alpha: float = 0.0
    seed: int = 0
    cache_entries: int = 64
    cache_dir: str | None = None

    def __post_init__(self):
        if self.population < 1:
            raise ValueError(
                f"population must be >= 1, got {self.population}")
        if self.alpha < 0.0:
            raise ValueError(f"alpha must be >= 0, got {self.alpha}")
        spc = self.samples_per_client
        if isinstance(spc, int):
            lo = hi = int(spc)
        else:
            lo, hi = (int(spc[0]), int(spc[1]))
        if not 1 <= lo <= hi:
            raise ValueError(
                f"samples_per_client must be >= 1 (lo <= hi), got {spc}")
        self._size_range = (lo, hi)
        if isinstance(self.spec, VisionDatasetSpec):
            self._make = make_vision_dataset
        elif isinstance(self.spec, TextDatasetSpec):
            self._make = make_text_dataset
        else:
            raise TypeError(f"unsupported dataset spec {type(self.spec)}")
        self._cache = ClientStateStore(max_entries=max(0, self.cache_entries),
                                       spill_dir=self.cache_dir)

    # -- ClientPopulation contract ------------------------------------------

    @property
    def num_clients(self) -> int:
        return self.population

    def num_samples(self, client_id: int) -> int:
        n, _ = self._client_plan(self._check_id(client_id))
        return n

    def dataset(self, client_id: int) -> ClientDataset:
        cid = self._check_id(client_id)
        cached = self._cache.get("data", cid)
        if cached is not None:
            return ClientDataset(inputs=cached["inputs"],
                                 labels=cached["labels"])
        n, class_probs = self._client_plan(cid)
        sample_seed = int(np.random.SeedSequence(
            (self.seed, _SAMPLE_STREAM, cid)).generate_state(1)[0])
        inputs, labels = self._make(self.spec, n, seed=sample_seed,
                                    class_probs=class_probs)
        if self.cache_entries:
            self._cache.put("data", cid, {"inputs": inputs, "labels": labels})
        return ClientDataset(inputs=inputs, labels=labels)

    # -- per-id derivations --------------------------------------------------

    def _client_plan(self, cid: int) -> tuple[int, np.ndarray | None]:
        """(shard size, class-probability vector or None) — cheap: draws a
        handful of scalars, never the shard arrays."""
        rng = np.random.default_rng(
            np.random.SeedSequence((self.seed, _PLAN_STREAM, cid)))
        lo, hi = self._size_range
        n = lo if lo == hi else int(rng.integers(lo, hi + 1))
        probs = None
        if self.alpha > 0.0:
            probs = rng.dirichlet(
                np.full(self.spec.num_classes, self.alpha))
        return n, probs

    def cache_stats(self) -> dict:
        return self._cache.stats()
