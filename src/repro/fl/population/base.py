"""``ClientPopulation`` — client state produced on demand from (seed, id).

The historical simulator API materialises every client host-side
(``run_federated(clients_data: Sequence, ...)``), which caps the fleet at a
few hundred clients.  Production federations sample cohorts from populations
of 10^6–10^8 mostly-offline devices; only the sampled cohort should ever
cost memory or compute (docs/POPULATION.md).

A ``ClientPopulation`` is the lazy contract behind that:

* ``num_clients``            — the population size N (a number, not a list);
* ``dataset(client_id)``     — that client's shard, built (or fetched from a
  bounded cache) on demand;
* ``num_samples(client_id)`` — the shard size *without* building the arrays
  (aggregation weights and virtual-time cost books need only this);
* ``capacity_tier(client_id)`` — the client's capacity tier for per-client
  layer plans (stable in ``client_id``, never an O(N) table).

``MaterializedPopulation`` wraps today's ``Sequence[ClientDataset]`` so the
legacy call signature keeps working verbatim: ``as_population`` is the single
adapter seam both runtimes go through.
"""

from __future__ import annotations

import abc
from typing import Sequence

from repro.data.pipeline import ClientDataset


class ClientPopulation(abc.ABC):
    """Lazy client-state factory: everything is a function of (seed, id)."""

    @property
    @abc.abstractmethod
    def num_clients(self) -> int:
        """Population size N.  Only ever used as a sampling bound."""

    @abc.abstractmethod
    def dataset(self, client_id: int) -> ClientDataset:
        """The client's dataset shard, produced on demand.  Must be
        deterministic in (population seed, client_id): two calls — or two
        processes — see identical arrays."""

    def num_samples(self, client_id: int) -> int:
        """Shard size without materialising it.  Subclasses with a cheap
        closed form should override; the default builds the shard."""
        return len(self.dataset(client_id))

    def capacity_tier(self, client_id: int, num_tiers: int) -> int:
        """Stable capacity-tier assignment (round-robin by id — matches
        ``core.schedule.PlanAssigner.tier_of``, so plan semantics are
        identical whether the fleet is materialised or streamed)."""
        return int(client_id) % max(1, num_tiers)

    def _check_id(self, client_id: int) -> int:
        cid = int(client_id)
        if not 0 <= cid < self.num_clients:
            raise IndexError(
                f"client_id {cid} out of range for population of "
                f"{self.num_clients}")
        return cid

    def materialize(self) -> list[ClientDataset]:
        """Eagerly build every shard (tests / tiny populations only)."""
        n = self.num_clients
        if n > 100_000:
            raise ValueError(
                f"refusing to materialize {n} clients host-side; sample a "
                "cohort instead (that is the point of this class)")
        return [self.dataset(i) for i in range(n)]


class MaterializedPopulation(ClientPopulation):
    """The legacy path as a (trivial) population: a host-side ``Sequence`` of
    ``ClientDataset``.  O(1) per lookup, nothing lazy — exists so both
    runtimes speak only ``ClientPopulation``."""

    def __init__(self, clients: Sequence[ClientDataset]):
        self._clients = list(clients)
        if not self._clients:
            raise ValueError("population must contain at least one client")

    @property
    def num_clients(self) -> int:
        return len(self._clients)

    def dataset(self, client_id: int) -> ClientDataset:
        return self._clients[self._check_id(client_id)]

    def num_samples(self, client_id: int) -> int:
        return len(self._clients[self._check_id(client_id)])


def as_population(clients) -> ClientPopulation:
    """The adapter seam: pass ``ClientPopulation`` through, wrap a legacy
    ``Sequence[ClientDataset]`` in ``MaterializedPopulation``."""
    if isinstance(clients, ClientPopulation):
        return clients
    return MaterializedPopulation(clients)
