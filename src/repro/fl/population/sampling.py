"""O(cohort) sampling + seed derivation for population-scale federations.

Two host-side costs silently scale with the *population* in a naive
simulator even though only the *cohort* ever trains:

* **cohort selection** — ``rng.choice(N, k, replace=False)`` materialises a
  permutation-sized workspace.  ``sample_without_replacement`` is Floyd's
  algorithm (Bentley & Floyd, CACM 1987): exactly ``k`` draws, ``O(k)``
  memory, uniform over k-subsets of ``range(n)`` — the population size never
  appears as an allocation.  ``sample_excluding`` extends it to "the first
  ``n`` naturals minus a (small, cohort-scale) excluded set" by sampling
  *ranks* in the reduced pool and mapping rank -> id with a binary search
  over the sorted exclusions — ``O(k log k + k log |excluded|)``.
* **per-(round, client) seed derivation** — the historical linear formula
  ``seed*100_003 + round*1_009 + client_id`` collides as soon as client ids
  span more than 1_009 (round r, client c and round r+1, client c-1_009
  train on identical batch orders).  ``client_round_seed`` feeds the triple
  through ``np.random.SeedSequence``, whose hashing mixes all inputs into
  the full 32-bit output space — collisions across any realistic grid are
  ruled out by the regression test in tests/test_population.py.

Both are shared by the synchronous server loop and the async runtime so the
degenerate-config equivalence contract keeps holding: the two paths consume
the *same* selection stream whenever the fleet is perfect.
"""

from __future__ import annotations

from bisect import bisect_right, insort
from typing import Sequence

import numpy as np


def client_round_seed(seed: int, round_index: int, client_id: int) -> int:
    """Collision-resistant per-(run, round, client) seed.

    ``SeedSequence`` hashing mixes the triple into a uniform 32-bit word, so
    distinct (round, client) pairs get independent batch-order streams no
    matter how large client ids grow (the linear formula this replaces
    collided at ``client_id`` spans > 1_009).
    """
    ss = np.random.SeedSequence((int(seed), int(round_index), int(client_id)))
    return int(ss.generate_state(1, np.uint32)[0])


def resolve_cohort_size(n_clients: int, sample_fraction: float,
                        cohort_size: int = 0) -> int:
    """Clients per dispatch: an explicit ``cohort_size`` wins (clamped to
    the population — the natural knob at population scale, where a fraction
    of 10^6 is meaningless), else the legacy ``sample_fraction`` rounding."""
    if cohort_size:
        if cohort_size < 0:
            raise ValueError(f"cohort_size must be >= 0, got {cohort_size}")
        return max(1, min(int(cohort_size), n_clients))
    return max(1, int(round(sample_fraction * n_clients)))


def sample_without_replacement(rng: np.random.Generator, n: int, k: int
                               ) -> list[int]:
    """Floyd's algorithm: a uniform k-subset of ``range(n)`` in O(k).

    Consumes exactly ``k`` draws from ``rng`` and allocates O(k) — never
    O(n) — so cohorts can be sampled from populations of millions without
    touching the non-participants.

    >>> r = np.random.default_rng(0)
    >>> s = sample_without_replacement(r, 10**9, 4)
    >>> len(s) == len(set(s)) == 4 and all(0 <= x < 10**9 for x in s)
    True
    """
    if not 0 <= k <= n:
        raise ValueError(f"need 0 <= k <= n, got k={k}, n={n}")
    chosen: set[int] = set()
    out: list[int] = []
    for j in range(n - k, n):
        t = int(rng.integers(0, j + 1))
        pick = t if t not in chosen else j
        chosen.add(pick)
        out.append(pick)
    return out


def _nth_absent(rank: int, excluded: Sequence[int]) -> int:
    """The ``rank``-th natural number (0-based) not in sorted ``excluded``.

    Binary search on ``id - |{e in excluded : e <= id}| == rank``: both sides
    are monotone in ``id``, so O(log |excluded|).

    >>> _nth_absent(0, [0, 1, 4]), _nth_absent(2, [0, 1, 4])
    (2, 5)
    """
    lo, hi = rank, rank + len(excluded)
    while lo < hi:
        mid = (lo + hi) // 2
        absent_through_mid = mid + 1 - bisect_right(excluded, mid)
        if absent_through_mid >= rank + 1:
            hi = mid
        else:
            lo = mid + 1
    return lo


def sample_excluding(rng: np.random.Generator, n: int, k: int,
                     excluded: Sequence[int]) -> list[int]:
    """Uniform k-subset of ``range(n)`` minus sorted ``excluded``, in
    O(k log k + k log |excluded|) — the async runtime's busy-set-aware
    cohort sampler.  With ``excluded`` empty this *is*
    ``sample_without_replacement`` (same rng stream, same result), which is
    what keeps the degenerate async config on the synchronous server's
    selection stream.
    """
    if not excluded:
        return sample_without_replacement(rng, n, k)
    m = n - len(excluded)
    if not 0 <= k <= m:
        raise ValueError(f"need 0 <= k <= {m} available ids, got k={k}")
    ranks = sample_without_replacement(rng, m, k)
    return [_nth_absent(r, excluded) for r in ranks]


def weighted_sample_without_replacement(
        rng: np.random.Generator, ids: Sequence[int],
        weights: Sequence[float], k: int) -> list[int]:
    """Weighted k-subset of ``ids`` without replacement, O(|ids|).

    Efraimidis–Spirakis exponential keys: draw one uniform vector, key each
    candidate by ``u ** (1/w)``, keep the ``k`` largest — equivalent to
    sequential weighted sampling without replacement.  Zero-weight
    candidates are never selected; with all weights equal this is a uniform
    k-subset (a *different* uniform draw than Floyd's — the biased cohort
    sampler's stream, docs/ASYNC.md).  Consumes exactly one ``rng.random``
    vector of ``len(ids)``, so runs replay deterministically per stream.

    >>> r = np.random.default_rng(0)
    >>> picks = weighted_sample_without_replacement(
    ...     r, [3, 7, 9], [1.0, 0.0, 1.0], 2)
    >>> sorted(picks)
    [3, 9]
    """
    ids = [int(i) for i in ids]
    w = np.asarray(list(weights), dtype=np.float64)
    if w.shape != (len(ids),):
        raise ValueError(f"need one weight per id, got {w.shape} weights "
                         f"for {len(ids)} ids")
    if (w < 0.0).any():
        raise ValueError("weights must be >= 0")
    eligible = int((w > 0.0).sum())
    if not 0 <= k <= eligible:
        raise ValueError(f"need 0 <= k <= {eligible} positive-weight ids, "
                         f"got k={k}")
    if k == 0:
        return []
    u = rng.random(len(ids))
    keys = np.full(len(ids), -np.inf)
    pos = w > 0.0
    # log-space keys (log(u)/w) are monotone in u**(1/w) and never underflow
    with np.errstate(divide="ignore"):
        keys[pos] = np.log(u[pos]) / w[pos]
    order = np.argsort(-keys, kind="stable")
    return [ids[i] for i in order[:k]]


class IncrementalSampler:
    """Stateful without-replacement sampler over ``range(n)`` minus a busy
    set: repeated ``draw(k)`` calls never repeat an id (previously drawn ids
    join the exclusion), so availability-rejected candidates can be topped
    up without O(n) work or replacement bias."""

    def __init__(self, rng: np.random.Generator, n: int,
                 busy: Sequence[int] = ()):
        self._rng = rng
        self._n = n
        self._excluded = sorted(int(b) for b in busy)

    @property
    def remaining(self) -> int:
        return self._n - len(self._excluded)

    def draw(self, k: int) -> list[int]:
        k = min(k, self.remaining)
        if k <= 0:
            return []
        out = sample_excluding(self._rng, self._n, k, self._excluded)
        for ci in out:
            insort(self._excluded, ci)
        return out
