"""Bounded per-client state store: LRU in memory, optional spill to disk.

Three kinds of per-client state grow without bound in a naive simulator —
MOON's previous local model (a full parameter tree per *ever-sampled*
client), compression error-feedback residuals (``core.compress``), and lazy
dataset shards.  At population scale (10^6+ clients, Sen et al. 2025) even
a few KB per touched client eventually dominates host memory.

``ClientStateStore`` bounds that: a ``max_entries`` LRU over ``(kind,
client_id)`` keys.  On eviction the entry is either

* **spilled** — pickled to ``spill_dir`` (tree structure + leaves as numpy
  arrays) and transparently reloaded on the next ``get``, value-exact
  (pinned by round-trip tests: a MOON prev or an EF residual that crossed
  the disk boundary produces bit-identical training); or
* **dropped** (no ``spill_dir``) — the next ``get`` returns ``None``, which
  consumers already treat as "first contact" (MOON falls back to the global
  model, error feedback restarts from a zero residual).  That is a
  *semantic approximation* the caller opts into by bounding the store.

``max_entries=0`` (the default in ``FLRunConfig``) means unbounded —
bit-identical to the dict-based stores this class replaced.
"""

from __future__ import annotations

import os
import pickle
from collections import OrderedDict
from typing import Any, Hashable

import jax
import numpy as np

PyTree = Any


def _to_host(tree: PyTree) -> PyTree:
    """Leaves as host numpy arrays (device buffers pin device memory and do
    not pickle portably)."""
    return jax.tree.map(np.asarray, tree)


class ClientStateStore:
    """LRU map ``(kind, client_id) -> pytree`` with optional disk spill.

    ``kind`` namespaces the independent state families sharing one budget
    (``"moon"`` prev-models, ``"ef"`` error-feedback residuals, ``"data"``
    dataset shards); ``max_entries`` caps the total *in-memory* entry count
    across kinds.  All values are converted to host numpy on ``put`` so the
    store never pins device buffers.
    """

    def __init__(self, max_entries: int = 0, spill_dir: str | None = None):
        if max_entries < 0:
            raise ValueError(f"max_entries must be >= 0, got {max_entries}")
        self.max_entries = int(max_entries)
        self.spill_dir = spill_dir
        self._mem: OrderedDict[tuple[str, Hashable], PyTree] = OrderedDict()
        self._spilled: set[tuple[str, Hashable]] = set()
        self.evictions = 0      # entries pushed out of memory (spilled or dropped)
        self.spills = 0         # evictions persisted to disk
        self.loads = 0          # disk reloads
        if spill_dir:
            os.makedirs(spill_dir, exist_ok=True)

    # -- bookkeeping --------------------------------------------------------

    def __len__(self) -> int:
        return len(self._mem)

    def __contains__(self, key: tuple[str, Hashable]) -> bool:
        return key in self._mem or key in self._spilled

    def keys(self):
        return list(self._mem.keys()) + sorted(self._spilled - set(self._mem))

    def _path(self, key: tuple[str, Hashable]) -> str:
        kind, cid = key
        return os.path.join(self.spill_dir, f"{kind}-{cid}.pkl")

    # -- core API -----------------------------------------------------------

    def get(self, kind: str, client_id: Hashable) -> PyTree | None:
        """The stored tree, or ``None`` for never-seen / dropped entries.
        Reloads transparently from disk if the entry was spilled."""
        key = (kind, client_id)
        if key in self._mem:
            self._mem.move_to_end(key)
            return self._mem[key]
        if key in self._spilled:
            with open(self._path(key), "rb") as f:
                treedef, leaves = pickle.load(f)
            self.loads += 1
            tree = jax.tree.unflatten(treedef, leaves)
            self._insert(key, tree)
            return tree
        return None

    def put(self, kind: str, client_id: Hashable, tree: PyTree) -> None:
        self._insert((kind, client_id), _to_host(tree))

    def pop(self, kind: str, client_id: Hashable) -> None:
        """Forget an entry entirely (memory and disk)."""
        key = (kind, client_id)
        self._mem.pop(key, None)
        if key in self._spilled:
            self._spilled.discard(key)
            try:
                os.remove(self._path(key))
            except OSError:
                pass

    def _insert(self, key: tuple[str, Hashable], tree: PyTree) -> None:
        self._mem[key] = tree
        self._mem.move_to_end(key)
        if self.max_entries:
            while len(self._mem) > self.max_entries:
                old_key, old_tree = self._mem.popitem(last=False)
                self.evictions += 1
                if self.spill_dir is not None:
                    leaves, treedef = jax.tree.flatten(old_tree)
                    with open(self._path(old_key), "wb") as f:
                        pickle.dump((treedef, leaves), f,
                                    protocol=pickle.HIGHEST_PROTOCOL)
                    self._spilled.add(old_key)
                    self.spills += 1
                else:
                    self._spilled.discard(old_key)

    # -- diagnostics --------------------------------------------------------

    def stats(self) -> dict:
        return {"in_memory": len(self._mem), "on_disk": len(self._spilled),
                "evictions": self.evictions, "spills": self.spills,
                "loads": self.loads, "max_entries": self.max_entries}
