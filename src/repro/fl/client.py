"""Client-side local training.

``LocalTrainer`` builds jitted per-batch step functions — one FNU variant and
one per layer group (the group index is static, so XLA prunes the dead
backward graph per group exactly as in the production launcher).  BN
statistics ride along as a ``has_aux`` output and are spliced back without a
second forward pass.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import aggregation, masking
from repro.core.partition import Partition
from repro.fl.algorithms import AlgoConfig, augment_loss
from repro.fl.tasks import TaskAdapter
from repro.kernels.masked_adam import ops as madam_ops
from repro.kernels.masked_adam.kernel import masked_adam_kernel
from repro.optim.adam import AdamConfig, AdamState, adam_init, adam_update
from repro.optim.partial import fused_adam_init, guard_fused_config

PyTree = Any


@dataclasses.dataclass
class LocalTrainer:
    adapter: TaskAdapter
    partition: Partition
    algo: AlgoConfig
    adam: AdamConfig

    def __post_init__(self):
        self.trace_count = 0  # jit (re)traces across all cached step fns
        self._full_step = jax.jit(self._counted(self.make_full_step()))
        self._partial_steps: dict[int, Callable] = {}
        self._plan_steps: dict[tuple[int, ...], Callable] = {}
        self._fused_steps: dict[Any, Callable] = {}

    def _counted(self, fn: Callable) -> Callable:
        """Wrap a step fn so each XLA trace bumps ``trace_count`` (the wrapper
        body only runs while tracing; compiled replays skip it)."""

        def traced(*args):
            self.trace_count += 1
            return fn(*args)

        return traced

    # -- loss assembly -----------------------------------------------------

    def _total_loss(self, params, inputs, labels, global_params, prev_params):
        task = self.adapter.loss(params, inputs, labels)
        kw: dict = {}
        if self.algo.name == "fedprox":
            kw = {"params": params, "global_params": global_params}
        elif self.algo.name == "moon":
            kw = {
                "z": self.adapter.features(params, inputs),
                "z_glob": jax.lax.stop_gradient(
                    self.adapter.features(global_params, inputs)
                ),
                "z_prev": jax.lax.stop_gradient(
                    self.adapter.features(prev_params, inputs)
                ),
            }
        return augment_loss(self.algo, task, **kw)

    # -- step builders -------------------------------------------------------

    def make_full_step(self):
        """Raw (unjitted) FNU step — reused by the batched vmap engine."""

        def step(params, opt_state, inputs, labels, global_params, prev_params):
            def loss_fn(p):
                loss = self._total_loss(p, inputs, labels, global_params, prev_params)
                stats = self.adapter.stats(p, inputs)
                return loss, stats

            (loss, stats), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            new_params, new_state = adam_update(grads, opt_state, params, self.adam)
            if stats is not None:
                new_params = masking.tree_update(new_params, stats)
            return new_params, new_state, loss

        return step

    def make_partial_step(self, group):
        """Raw (unjitted) partial step for ``group`` — an int, or a sequence
        of group ids for per-client layer plans (docs/HETEROGENEITY.md) —
        reused by the batched vmap engine (the group set is static, so XLA
        prunes the dead backward graph per distinct set in both engines)."""

        def step(params, opt_state, inputs, labels, global_params, prev_params):
            trainable = masking.select(params, self.partition, group)
            frozen = masking.complement(params, self.partition, group)

            def loss_fn(sub):
                p = masking.merge(sub, frozen)
                loss = self._total_loss(p, inputs, labels, global_params, prev_params)
                stats = self.adapter.stats(p, inputs)
                return loss, stats

            (loss, stats), grads = jax.value_and_grad(loss_fn, has_aux=True)(trainable)
            new_sub, new_state = adam_update(grads, opt_state, trainable, self.adam)
            new_params = masking.merge(new_sub, frozen)
            if stats is not None:
                new_params = masking.tree_update(new_params, stats)
            return new_params, new_state, loss

        return step

    # -- fused (Pallas masked-Adam) step builders ---------------------------

    def _fused_update(self, params, grads, opt_state, block_mask, block_rows):
        """Shared tail of every fused step: pack params/grads into the kernel
        layout, run the fused masked Adam (m/v stay packed across steps —
        ``optim.partial.fused_adam_init``), unpack the new params."""
        step_i = opt_state.step + 1
        pp, meta = madam_ops.pack(params, block_rows)
        pg, _ = madam_ops.pack(grads, block_rows)
        scalars = madam_ops.adam_scalars(
            step_i, self.adam.lr, self.adam.b1, self.adam.b2, self.adam.eps)
        np_, nm, nv = masked_adam_kernel(
            pp, pg, opt_state.m, opt_state.v, jnp.asarray(block_mask),
            scalars, b1=self.adam.b1, b2=self.adam.b2, block_rows=block_rows,
            interpret=madam_ops.default_interpret(),
        )
        return madam_ops.unpack(np_, meta), AdamState(step_i, nm, nv)

    def make_fused_step(self, group=None, block_rows: int = 8):
        """Raw (unjitted) fused step: FNU-shaped full-tree gradient, one
        fused kernel pass with a *static* per-block mask — ``group=None``
        trains every layer group (FNU), an int / sequence trains that
        homogeneous group set, and frozen blocks copy through bit-exact
        (Eq. 1's masked form; equivalence with the pruned partial step is
        pinned in tests).  BN running moments are excluded from the kernel
        mask and spliced fresh from the forward pass, exactly like the
        unfused steps.  ``opt_state`` is the packed ``fused_adam_init``
        state."""
        guard_fused_config(self.adam)
        partition = self.partition

        def step(params, opt_state, inputs, labels, global_params, prev_params):
            def loss_fn(p):
                loss = self._total_loss(p, inputs, labels, global_params, prev_params)
                stats = self.adapter.stats(p, inputs)
                return loss, stats

            (loss, stats), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            sel = tuple(range(partition.num_groups)) if group is None else group
            bm = madam_ops.block_mask_for_group(
                params, partition, sel, block_rows,
                exclude=aggregation.is_local_stat)
            new_params, new_state = self._fused_update(
                params, grads, opt_state, bm, block_rows)
            if stats is not None:
                new_params = masking.tree_update(new_params, stats)
            return new_params, new_state, loss

        return step

    def make_fused_plan_step(self, block_rows: int = 8):
        """Fused step for per-client layer plans: same kernel pass, but the
        block mask is *traced* from the client's ``(M,)`` group bitmask
        (seventh argument) via static per-block group ids — one compiled
        program serves every plan row, mirroring ``_one_client_plan_fn``'s
        contract without the per-leaf re-pinning (the kernel mask already
        freezes untrained blocks)."""
        guard_fused_config(self.adam)
        partition = self.partition

        def step(params, opt_state, inputs, labels, global_params,
                 prev_params, gmask):
            def loss_fn(p):
                loss = self._total_loss(p, inputs, labels, global_params, prev_params)
                stats = self.adapter.stats(p, inputs)
                return loss, stats

            (loss, stats), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            gids = madam_ops.block_group_ids(
                params, partition, block_rows,
                exclude=aggregation.is_local_stat)
            bm = madam_ops.plan_block_mask(gids, gmask)
            new_params, new_state = self._fused_update(
                params, grads, opt_state, bm, block_rows)
            if stats is not None:
                new_params = masking.tree_update(new_params, stats)
            return new_params, new_state, loss

        return step

    def fused_step(self, group=None) -> Callable:
        """Jitted cache over ``make_fused_step`` keys (None / int / tuple)."""
        key = group if (group is None or isinstance(group, int)) \
            else tuple(sorted(int(g) for g in group))
        if key not in self._fused_steps:
            self._fused_steps[key] = jax.jit(
                self._counted(self.make_fused_step(key)))
        return self._fused_steps[key]

    def partial_step(self, group: int) -> Callable:
        if group not in self._partial_steps:
            self._partial_steps[group] = jax.jit(
                self._counted(self.make_partial_step(group))
            )
        return self._partial_steps[group]

    def plan_step(self, groups: tuple[int, ...]) -> Callable:
        """Jitted partial step for a *set* of layer groups (one cached trace
        per distinct set — capacity tiers, so a handful per run)."""
        key = tuple(sorted(int(g) for g in groups))
        if key not in self._plan_steps:
            self._plan_steps[key] = jax.jit(
                self._counted(self.make_partial_step(key))
            )
        return self._plan_steps[key]

    # -- local round ---------------------------------------------------------

    def run_local_round(
        self,
        global_params: PyTree,
        group: int,                    # FULL_NETWORK (-1) for FNU rounds
        data,                          # ClientDataset
        *,
        epochs: int,
        batch_size: int,
        seed: int,
        prev_params: PyTree | None = None,
        step_tracker=None,
        groups: Sequence[int] | None = None,
        fused: bool = False,
    ) -> tuple[PyTree, float]:
        """Train locally; returns (updated full params, mean loss).

        ``groups`` (per-client layer plans) overrides ``group`` with a *set*
        of trainable layer groups; a set covering every group is the FNU
        step.  ``fused`` routes every step through the Pallas masked-Adam
        kernel (docs/KERNELS.md) with packed optimizer state."""
        params = global_params
        prev = prev_params if prev_params is not None else global_params
        if groups is not None:
            groups = tuple(sorted(int(g) for g in groups))
            full = len(groups) == self.partition.num_groups
        else:
            full = group < 0
        if fused:
            opt_state = fused_adam_init(params)
            step = self.fused_step(
                None if full else (groups if groups is not None else group))
        elif full:
            opt_state = adam_init(params)
            step = self._full_step
        elif groups is not None:
            opt_state = adam_init(masking.select(params, self.partition, groups))
            step = self.plan_step(groups)
        else:
            opt_state = adam_init(masking.select(params, self.partition, group))
            step = self.partial_step(group)
        losses = []
        for inputs, labels in data.batches(batch_size, epochs, seed):
            before = params
            params, opt_state, loss = step(
                params, opt_state, inputs, labels, global_params, prev
            )
            losses.append(float(loss))
            if step_tracker is not None:
                step_tracker.record(before, params)
        return params, float(jnp.mean(jnp.array(losses))) if losses else 0.0
