"""FL algorithm plugins: FedAvg, FedProx, MOON — each composable with both
FNU and FedPart update modes (the paper's Table 1 matrix).

An algorithm contributes a loss *augmentation* on top of the task loss:

    FedAvg : nothing
    FedProx: + (mu/2)·‖w − w_global‖²   over trainable params
    MOON   : + mu·contrastive(z_local, z_global, z_prev)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AlgoConfig:
    name: str = "fedavg"            # fedavg | fedprox | moon
    prox_mu: float = 0.01
    moon_mu: float = 1.0
    moon_tau: float = 0.5


def prox_term(params: PyTree, global_params: PyTree) -> jax.Array:
    sq = jax.tree.map(
        lambda a, b: jnp.sum((a.astype(jnp.float32) - b.astype(jnp.float32)) ** 2),
        params,
        global_params,
    )
    return jax.tree.reduce(lambda x, y: x + y, sq, jnp.float32(0.0))


def moon_contrastive(
    z: jax.Array, z_glob: jax.Array, z_prev: jax.Array, tau: float
) -> jax.Array:
    """Model-contrastive loss (Li et al. 2021): pull the local representation
    towards the global model's, push it from the previous local model's."""

    def cos(a, b):
        a = a / (jnp.linalg.norm(a, axis=-1, keepdims=True) + 1e-8)
        b = b / (jnp.linalg.norm(b, axis=-1, keepdims=True) + 1e-8)
        return jnp.sum(a * b, axis=-1)

    pos = cos(z, z_glob) / tau
    neg = cos(z, z_prev) / tau
    return jnp.mean(-pos + jax.scipy.special.logsumexp(jnp.stack([pos, neg]), axis=0))


def augment_loss(
    algo: AlgoConfig,
    task_loss: jax.Array,
    *,
    params: PyTree | None = None,
    global_params: PyTree | None = None,
    z: jax.Array | None = None,
    z_glob: jax.Array | None = None,
    z_prev: jax.Array | None = None,
) -> jax.Array:
    if algo.name == "fedavg":
        return task_loss
    if algo.name == "fedprox":
        return task_loss + 0.5 * algo.prox_mu * prox_term(params, global_params)
    if algo.name == "moon":
        return task_loss + algo.moon_mu * moon_contrastive(z, z_glob, z_prev, algo.moon_tau)
    raise ValueError(f"unknown algorithm {algo.name!r}")
