"""Pallas TPU kernels (validated with interpret=True on CPU):

- ``flash_attention``: online-softmax attention, causal/sliding-window, GQA.
- ``masked_adam``: fused Eq.-1 masked Adam (block-skip on frozen groups).
- ``ssd_chunk``: chunked decay linear-attention scan (Mamba2 SSD / mLSTM core).

Each kernel package ships ``kernel.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd wrapper) and ``ref.py`` (pure-jnp oracle).
"""
