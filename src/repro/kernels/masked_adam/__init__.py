from repro.kernels.masked_adam import ops  # noqa: F401
from repro.kernels.masked_adam.kernel import (LANES,  # noqa: F401
                                              masked_adam_kernel,
                                              masked_adam_stacked)
from repro.kernels.masked_adam.ops import (PackMeta,  # noqa: F401
                                           block_group_ids,
                                           block_mask_for_group,
                                           block_masks_for_plan,
                                           default_interpret,
                                           fused_masked_adam, pack,
                                           pack_stacked, plan_block_mask,
                                           unpack, unpack_stacked)
