from repro.kernels.masked_adam import ops  # noqa: F401
