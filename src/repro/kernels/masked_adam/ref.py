"""Pure-jnp oracle for the fused masked Adam kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def masked_adam_ref(
    p: jax.Array,            # (rows, 128)
    g: jax.Array,
    m: jax.Array,            # f32
    v: jax.Array,            # f32
    block_mask: jax.Array,   # (num_blocks,) int32
    scalars: jax.Array,      # [lr, bc1, bc2, eps]
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    block_rows: int = 8,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    lr, bc1, bc2, eps = scalars[0], scalars[1], scalars[2], scalars[3]
    g32 = g.astype(jnp.float32)
    m_new = b1 * m + (1 - b1) * g32
    v_new = b2 * v + (1 - b2) * g32 * g32
    p_new = p.astype(jnp.float32) - lr * (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)

    rows = p.shape[0]
    mask_rows = jnp.repeat(block_mask != 0, block_rows)[:, None]  # (rows, 1)
    p_out = jnp.where(mask_rows, p_new.astype(p.dtype), p)
    m_out = jnp.where(mask_rows, m_new, m)
    v_out = jnp.where(mask_rows, v_new, v)
    return p_out, m_out, v_out
