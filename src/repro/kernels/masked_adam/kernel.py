"""Pallas TPU fused (masked) Adam — the paper's Eq. 1 inner loop as a single
memory-bound pass.

    w ← w − γ·S ⊙ AdamDir(∇L)

Unfused, the update reads/writes p, m, v and reads g through ~9 HBM-roundtrip
intermediates; fused it is one read of each input and one write of each
output — the optimizer update runs at the HBM roofline.  The binary mask S is
*block-granular* (FedPart masks whole layers, so every block of a tensor
shares its group's bit): frozen blocks skip ALL arithmetic and just copy
through — on TPU the copy is also elided by aliasing the input and output
buffers, so frozen bytes are never touched.

Layout: parameters are packed to (rows, 128) lanes; the grid walks row-blocks
of (block_rows, 128); the per-block mask and the Adam bias corrections arrive
as scalar-prefetch-style side inputs.

NOTE (DESIGN.md §6): in the production FedPart path the *partitioned* update
never materialises frozen tensors at all; this kernel serves the Eq. 1 masked
semantics (reference form) and any mixed-group tensor boundary.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _adam_kernel(
    mask_ref,                     # (1,) int32 — this block's S bit
    sc_ref,                       # (4,) f32 — [lr, bc1, bc2, eps]
    p_ref, g_ref, m_ref, v_ref,   # (BR, 128) blocks
    p_out, m_out, v_out,
    *,
    b1: float,
    b2: float,
):
    @pl.when(mask_ref[0] != 0)
    def _update():
        lr, bc1, bc2, eps = sc_ref[0], sc_ref[1], sc_ref[2], sc_ref[3]
        g = g_ref[...].astype(jnp.float32)
        m_new = b1 * m_ref[...] + (1.0 - b1) * g
        v_new = b2 * v_ref[...] + (1.0 - b2) * g * g
        mh = m_new / bc1
        vh = v_new / bc2
        p_new = p_ref[...].astype(jnp.float32) - lr * mh / (jnp.sqrt(vh) + eps)
        p_out[...] = p_new.astype(p_out.dtype)
        m_out[...] = m_new
        v_out[...] = v_new

    @pl.when(mask_ref[0] == 0)
    def _copy():
        # With input/output aliasing this is elided on TPU; kept for the
        # interpret-mode semantics.
        p_out[...] = p_ref[...]
        m_out[...] = m_ref[...]
        v_out[...] = v_ref[...]


def masked_adam_kernel(
    p: jax.Array,          # (rows, 128)
    g: jax.Array,
    m: jax.Array,          # f32
    v: jax.Array,          # f32
    block_mask: jax.Array, # (num_blocks,) int32
    scalars: jax.Array,    # (4,) f32: [lr, bias_corr1, bias_corr2, eps]
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    block_rows: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    rows, lanes = p.shape
    assert lanes == LANES and rows % block_rows == 0, (p.shape, block_rows)
    nb = rows // block_rows
    assert block_mask.shape == (nb,), (block_mask.shape, nb)

    kernel = functools.partial(_adam_kernel, b1=b1, b2=b2)

    def blk(i):
        return (i, 0)

    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,), memory_space=pltpu.SMEM),
            pl.BlockSpec((4,), lambda i: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((block_rows, LANES), blk),
            pl.BlockSpec((block_rows, LANES), blk),
            pl.BlockSpec((block_rows, LANES), blk),
            pl.BlockSpec((block_rows, LANES), blk),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANES), blk),
            pl.BlockSpec((block_rows, LANES), blk),
            pl.BlockSpec((block_rows, LANES), blk),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(p.shape, p.dtype),
            jax.ShapeDtypeStruct(m.shape, jnp.float32),
            jax.ShapeDtypeStruct(v.shape, jnp.float32),
        ],
        input_output_aliases={2: 0, 4: 1, 5: 2},
        interpret=interpret,
    )(block_mask, scalars, p, g, m, v)


def masked_adam_stacked(
    p: jax.Array,           # (clients, rows, 128)
    g: jax.Array,
    m: jax.Array,           # f32
    v: jax.Array,           # f32
    block_masks: jax.Array, # (clients, num_blocks) int32
    scalars: jax.Array,     # (4,) f32, shared across clients
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    block_rows: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Client-stacked variant: fold the client axis into the row-block grid
    so one ``pallas_call`` sweeps every client's blocks.  Valid because each
    client's ``rows`` is a block multiple (``ops.pack_stacked`` guarantees
    it), so client boundaries coincide with block boundaries and the per-
    client masks concatenate to one grid-aligned mask."""
    clients, rows, lanes = p.shape
    assert rows % block_rows == 0, (p.shape, block_rows)
    assert block_masks.shape == (clients, rows // block_rows), (
        block_masks.shape, p.shape, block_rows)

    def fold(x):
        return x.reshape(clients * rows, lanes)

    out = masked_adam_kernel(
        fold(p), fold(g), fold(m), fold(v), block_masks.reshape(-1), scalars,
        b1=b1, b2=b2, block_rows=block_rows, interpret=interpret,
    )
    return tuple(x.reshape(clients, rows, lanes) for x in out)
