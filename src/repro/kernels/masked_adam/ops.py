"""Pytree-level wrapper: pack a parameter pytree into the kernel's (rows, 128)
layout with block-aligned leaf boundaries, derive the per-block mask from a
layer-group partition, run the fused kernel, unpack.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import Partition, path_str, tree_paths
from repro.kernels.masked_adam.kernel import LANES, masked_adam_kernel

PyTree = Any


@dataclasses.dataclass(frozen=True)
class PackMeta:
    shapes: tuple[tuple[int, ...], ...]
    sizes: tuple[int, ...]
    padded: tuple[int, ...]      # padded element count per leaf
    treedef: Any
    dtype: Any


def _block_elems(block_rows: int) -> int:
    return block_rows * LANES


def pack(tree: PyTree, block_rows: int = 8) -> tuple[jax.Array, PackMeta]:
    """Flatten + pad each leaf to a block multiple, concat, reshape (R,128)."""
    leaves, treedef = jax.tree.flatten(tree)
    be = _block_elems(block_rows)
    flat_parts, shapes, sizes, padded = [], [], [], []
    for leaf in leaves:
        arr = leaf.reshape(-1).astype(jnp.float32)
        n = arr.shape[0]
        pad = (-n) % be
        if pad:
            arr = jnp.concatenate([arr, jnp.zeros((pad,), arr.dtype)])
        flat_parts.append(arr)
        shapes.append(tuple(leaf.shape))
        sizes.append(n)
        padded.append(n + pad)
    flat = jnp.concatenate(flat_parts) if flat_parts else jnp.zeros((0,), jnp.float32)
    meta = PackMeta(tuple(shapes), tuple(sizes), tuple(padded), treedef,
                    leaves[0].dtype if leaves else jnp.float32)
    return flat.reshape(-1, LANES), meta


def unpack(packed: jax.Array, meta: PackMeta, dtype=None) -> PyTree:
    flat = packed.reshape(-1)
    out, off = [], 0
    for shape, n, pn in zip(meta.shapes, meta.sizes, meta.padded):
        leaf = flat[off : off + n].reshape(shape)
        out.append(leaf.astype(dtype) if dtype is not None else leaf)
        off += pn
    return jax.tree.unflatten(meta.treedef, out)


def block_mask_for_group(
    tree: PyTree, partition: Partition, groups, block_rows: int = 8
) -> np.ndarray:
    """Per-block int32 mask aligned with ``pack``'s layout."""
    sel = {groups} if isinstance(groups, int) else set(int(g) for g in groups)
    be = _block_elems(block_rows)
    bits = []
    for path, leaf in tree_paths(tree):
        n = int(np.prod(leaf.shape)) if leaf.ndim else 1
        nblocks = -(-n // be)
        bit = 1 if partition.group_of(path_str(path)) in sel else 0
        bits.extend([bit] * nblocks)
    return np.asarray(bits, dtype=np.int32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret", "b1", "b2"))
def _run(packed_p, packed_g, packed_m, packed_v, block_mask, scalars,
         block_rows, interpret, b1, b2):
    return masked_adam_kernel(
        packed_p, packed_g, packed_m, packed_v, block_mask, scalars,
        b1=b1, b2=b2, block_rows=block_rows, interpret=interpret,
    )


def fused_masked_adam(
    params: PyTree,
    grads: PyTree,
    m: PyTree,
    v: PyTree,
    step: jax.Array,              # int32 scalar (1-based after increment)
    block_mask: np.ndarray,
    *,
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    block_rows: int = 8,
    interpret: bool = True,
) -> tuple[PyTree, PyTree, PyTree]:
    """Fused Eq.-1 Adam over a whole pytree.  Returns (params, m, v)."""
    pp, meta = pack(params, block_rows)
    pg, _ = pack(grads, block_rows)
    pm, _ = pack(m, block_rows)
    pv, _ = pack(v, block_rows)
    t = step.astype(jnp.float32)
    scalars = jnp.stack(
        [jnp.float32(lr), 1.0 - b1**t, 1.0 - b2**t, jnp.float32(eps)]
    )
    np_, nm, nv = _run(pp, pg, pm, pv, jnp.asarray(block_mask), scalars,
                       block_rows, interpret, b1, b2)
    return (
        unpack(np_, meta, dtype=meta.dtype),
        unpack(nm, meta),
        unpack(nv, meta),
    )
