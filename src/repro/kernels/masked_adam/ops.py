"""Pytree-level wrapper: pack a parameter pytree into the kernel's (rows, 128)
layout with block-aligned leaf boundaries, derive the per-block mask from a
layer-group partition, run the fused kernel, unpack.

Layout contract (docs/KERNELS.md): leaves are laid out in ``jax.tree.flatten``
order, each flattened and zero-padded up to a multiple of
``block_rows * 128`` elements, so every leaf starts on a block boundary and a
per-*block* mask can express any per-*leaf* (i.e. per layer-group) selection.
``pack`` asserts that ``tree_flatten_with_path`` walks leaves in the same
order — the mask builders below iterate paths, and a silent ordering mismatch
would misalign masks with the packed buffer.

The compute buffer is float32 (the kernel's accumulation dtype); ``PackMeta``
records every leaf's original dtype and ``unpack`` restores it, so
``unpack(pack(tree))`` round-trips mixed-dtype trees exactly
(f32 -> f32 and bf16 -> f32 -> bf16 are value-exact).

``pack_stacked``/``unpack_stacked`` are the client-stacked variants the
batched engines use: trees whose every leaf carries a leading ``clients``
axis pack to ``(clients, R, 128)`` with the same per-client layout.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import Partition, path_str, tree_paths
from repro.kernels.masked_adam.kernel import LANES, masked_adam_kernel

PyTree = Any


@dataclasses.dataclass(frozen=True)
class PackMeta:
    shapes: tuple[tuple[int, ...], ...]
    sizes: tuple[int, ...]
    padded: tuple[int, ...]      # padded element count per leaf
    treedef: Any
    dtypes: tuple[Any, ...]      # per-leaf original dtype, restored by unpack

    @property
    def rows(self) -> int:
        return sum(self.padded) // LANES


def _block_elems(block_rows: int) -> int:
    return block_rows * LANES


def _assert_layout_order(tree: PyTree, leaves: list) -> None:
    """``pack`` lays leaves out in ``jax.tree.flatten`` order while the mask
    builders iterate ``tree_flatten_with_path``; jax guarantees these agree,
    but a silent divergence (e.g. an exotic custom pytree node) would
    misalign every mask bit — fail loudly instead."""
    path_leaves = [leaf for _, leaf in tree_paths(tree)]
    if len(path_leaves) != len(leaves) or any(
        a is not b for a, b in zip(leaves, path_leaves)
    ):
        raise AssertionError(
            "tree_flatten_with_path visits leaves in a different order than "
            "jax.tree.flatten for this pytree; block masks would be "
            "misaligned with the packed buffer"
        )


def _pad_counts(leaves, block_rows: int):
    be = _block_elems(block_rows)
    sizes, padded = [], []
    for leaf in leaves:
        n = int(np.prod(leaf.shape)) if leaf.ndim else 1
        sizes.append(n)
        padded.append(n + (-n) % be)
    return sizes, padded


def packed_rows(tree: PyTree, block_rows: int = 8) -> int:
    """Row count of ``pack(tree, block_rows)`` without materialising it."""
    leaves = jax.tree.leaves(tree)
    _, padded = _pad_counts(leaves, block_rows)
    return sum(padded) // LANES


def pack(tree: PyTree, block_rows: int = 8) -> tuple[jax.Array, PackMeta]:
    """Flatten + pad each leaf to a block multiple, concat, reshape (R,128).

    The buffer is float32 (kernel compute dtype); per-leaf dtypes are
    recorded in the returned ``PackMeta`` and restored by ``unpack``."""
    leaves, treedef = jax.tree.flatten(tree)
    _assert_layout_order(tree, leaves)
    be = _block_elems(block_rows)
    flat_parts, shapes, sizes, padded, dtypes = [], [], [], [], []
    for leaf in leaves:
        arr = leaf.reshape(-1).astype(jnp.float32)
        n = arr.shape[0]
        pad = (-n) % be
        if pad:
            arr = jnp.concatenate([arr, jnp.zeros((pad,), arr.dtype)])
        flat_parts.append(arr)
        shapes.append(tuple(leaf.shape))
        sizes.append(n)
        padded.append(n + pad)
        dtypes.append(jnp.asarray(leaf).dtype)
    flat = jnp.concatenate(flat_parts) if flat_parts else jnp.zeros((0,), jnp.float32)
    meta = PackMeta(tuple(shapes), tuple(sizes), tuple(padded), treedef,
                    tuple(dtypes))
    return flat.reshape(-1, LANES), meta


def unpack(packed: jax.Array, meta: PackMeta, dtype=None) -> PyTree:
    """Invert ``pack``: slice, reshape, and cast each leaf back to its
    recorded dtype.  ``dtype=`` (a single dtype forced onto every leaf) is
    deprecated — it was only ever a workaround for the meta not recording
    per-leaf dtypes."""
    if dtype is not None:
        warnings.warn(
            "unpack(dtype=...) is deprecated: unpack now restores each "
            "leaf's recorded dtype by default",
            DeprecationWarning, stacklevel=2,
        )
    flat = packed.reshape(-1)
    out, off = [], 0
    for shape, n, pn, dt in zip(meta.shapes, meta.sizes, meta.padded,
                                meta.dtypes):
        leaf = flat[off : off + n].reshape(shape)
        out.append(leaf.astype(dtype if dtype is not None else dt))
        off += pn
    return jax.tree.unflatten(meta.treedef, out)


def pack_stacked(tree: PyTree, block_rows: int = 8) -> tuple[jax.Array, PackMeta]:
    """``pack`` for client-stacked trees (every leaf has a leading ``clients``
    axis): returns ``(clients, R, 128)`` where each client's rows follow the
    single-tree layout exactly (``meta.shapes`` are the *per-client* shapes)."""
    leaves, treedef = jax.tree.flatten(tree)
    _assert_layout_order(tree, leaves)
    if not leaves:
        raise ValueError("pack_stacked needs at least one leaf to size the "
                         "client axis")
    clients = leaves[0].shape[0]
    be = _block_elems(block_rows)
    flat_parts, shapes, sizes, padded, dtypes = [], [], [], [], []
    for leaf in leaves:
        if leaf.shape[0] != clients:
            raise ValueError(
                f"stacked leaves disagree on the client axis: "
                f"{leaf.shape[0]} vs {clients}")
        arr = leaf.reshape(clients, -1).astype(jnp.float32)
        n = arr.shape[1]
        pad = (-n) % be
        if pad:
            arr = jnp.concatenate(
                [arr, jnp.zeros((clients, pad), arr.dtype)], axis=1)
        flat_parts.append(arr)
        shapes.append(tuple(leaf.shape[1:]))
        sizes.append(n)
        padded.append(n + pad)
        dtypes.append(jnp.asarray(leaf).dtype)
    flat = jnp.concatenate(flat_parts, axis=1)
    meta = PackMeta(tuple(shapes), tuple(sizes), tuple(padded), treedef,
                    tuple(dtypes))
    return flat.reshape(clients, -1, LANES), meta


def unpack_stacked(packed: jax.Array, meta: PackMeta) -> PyTree:
    """Invert ``pack_stacked`` (leading client axis restored on every leaf)."""
    clients = packed.shape[0]
    flat = packed.reshape(clients, -1)
    out, off = [], 0
    for shape, n, pn, dt in zip(meta.shapes, meta.sizes, meta.padded,
                                meta.dtypes):
        leaf = flat[:, off : off + n].reshape((clients,) + shape)
        out.append(leaf.astype(dt))
        off += pn
    return jax.tree.unflatten(meta.treedef, out)


# ---------------------------------------------------------------------------
# Block-mask builders (host-side, static layout)
# ---------------------------------------------------------------------------

def block_group_ids(
    tree: PyTree,
    partition: Partition,
    block_rows: int = 8,
    exclude: Callable[[str], bool] | None = None,
) -> np.ndarray:
    """Per-block layer-group id aligned with ``pack``'s layout — the bridge
    between the partition's per-*leaf* grouping and the kernel's per-*block*
    mask.  Blocks of leaves matched by ``exclude`` (e.g.
    ``aggregation.is_local_stat`` for BN running moments) get id ``-1``:
    never kernel-trained, handled by the caller's stats splice."""
    leaves = jax.tree.leaves(tree)
    _assert_layout_order(tree, leaves)
    be = _block_elems(block_rows)
    ids = []
    for path, leaf in tree_paths(tree):
        n = int(np.prod(leaf.shape)) if leaf.ndim else 1
        nblocks = -(-n // be)
        p = path_str(path)
        gid = -1 if (exclude is not None and exclude(p)) \
            else partition.group_of(p)
        ids.extend([gid] * nblocks)
    return np.asarray(ids, dtype=np.int32)


def block_mask_for_group(
    tree: PyTree, partition: Partition, groups, block_rows: int = 8,
    exclude: Callable[[str], bool] | None = None,
) -> np.ndarray:
    """Per-block int32 mask aligned with ``pack``'s layout: 1 where the
    block's leaf belongs to ``groups`` (an int or a set of group ids), 0
    elsewhere.  ``exclude`` forces matched leaves' blocks to 0."""
    sel = {groups} if isinstance(groups, (int, np.integer)) \
        else set(int(g) for g in groups)
    gids = block_group_ids(tree, partition, block_rows, exclude)
    return np.where(np.isin(gids, sorted(sel)) & (gids >= 0), 1, 0).astype(
        np.int32)


def block_masks_for_plan(
    tree: PyTree, partition: Partition, plan, block_rows: int = 8,
    exclude: Callable[[str], bool] | None = None,
) -> np.ndarray:
    """Per-client per-block masks for a ``(clients, M)`` layer-plan bitmask
    (docs/HETEROGENEITY.md): row ``c`` is ``block_mask_for_group`` of client
    ``c``'s trained group set.  Shape ``(clients, nblocks)`` int32."""
    p = np.asarray(plan, dtype=bool)
    if p.ndim != 2 or p.shape[1] != partition.num_groups:
        raise ValueError(
            f"plan shape {p.shape} does not match "
            f"{partition.num_groups} layer groups")
    gids = block_group_ids(tree, partition, block_rows, exclude)
    out = np.zeros((p.shape[0], gids.shape[0]), dtype=np.int32)
    valid = gids >= 0
    out[:, valid] = p[:, gids[valid]]
    return out


def plan_block_mask(gids: np.ndarray, gmask: jax.Array) -> jax.Array:
    """Traced per-client block mask from static per-block group ids and one
    client's traced ``(M,)`` group bitmask — the in-jit counterpart of
    ``block_masks_for_plan`` (one compiled program serves every plan row)."""
    safe = jnp.asarray(np.maximum(gids, 0))
    bits = jnp.take(gmask, safe) > 0
    return jnp.where(jnp.asarray(gids >= 0), bits, False).astype(jnp.int32)


def default_interpret() -> bool:
    """Run the kernel in Pallas interpret mode off-TPU (CPU/GPU testing);
    compiled Mosaic on TPU."""
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret", "b1", "b2"))
def _run(packed_p, packed_g, packed_m, packed_v, block_mask, scalars,
         block_rows, interpret, b1, b2):
    return masked_adam_kernel(
        packed_p, packed_g, packed_m, packed_v, block_mask, scalars,
        b1=b1, b2=b2, block_rows=block_rows, interpret=interpret,
    )


def adam_scalars(step: jax.Array, lr: float, b1: float, b2: float,
                 eps: float) -> jax.Array:
    """The kernel's (4,) SMEM side input: [lr, bias_corr1, bias_corr2, eps]
    — bias corrections computed exactly as ``optim.adam.adam_update`` does
    (``step`` is the 1-based post-increment count)."""
    t = step.astype(jnp.float32)
    return jnp.stack(
        [jnp.float32(lr), 1.0 - b1**t, 1.0 - b2**t, jnp.float32(eps)])


def fused_masked_adam(
    params: PyTree,
    grads: PyTree,
    m: PyTree,
    v: PyTree,
    step: jax.Array,              # int32 scalar (1-based after increment)
    block_mask: np.ndarray,
    *,
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    block_rows: int = 8,
    interpret: bool = True,
) -> tuple[PyTree, PyTree, PyTree]:
    """Fused Eq.-1 Adam over a whole pytree.  Returns (params, m, v)."""
    pp, meta = pack(params, block_rows)
    pg, _ = pack(grads, block_rows)
    pm, meta_m = pack(m, block_rows)
    pv, meta_v = pack(v, block_rows)
    scalars = adam_scalars(step, lr, b1, b2, eps)
    np_, nm, nv = _run(pp, pg, pm, pv, jnp.asarray(block_mask), scalars,
                       block_rows, interpret, b1, b2)
    return (
        unpack(np_, meta),
        unpack(nm, meta_m),   # m/v metas record float32 — the state dtype —
        unpack(nv, meta_v),   # independent of the params' leaf dtypes
    )
