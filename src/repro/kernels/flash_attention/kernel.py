"""Pallas TPU flash attention (forward) with causal / sliding-window masking
and GQA head sharing.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the kv-block axis is
minor, so the online-softmax accumulators (m, l, acc) live in VMEM scratch
and carry across kv iterations; outputs are written on the last kv block.

BlockSpecs tile Q/O as (1, 1, BQ, D) and K/V as (1, 1, BK, D) in VMEM; the
KV head index is ``h // (q_heads // kv_heads)`` via the index map (GQA).
MXU alignment: BQ = BK = 128, D padded to a multiple of 128 by the wrapper.

Causal blocks fully above the diagonal are skipped with ``pl.when`` (no MXU
work issued); the diagonal block applies an iota mask.  ``window > 0`` adds
the sliding-window lower bound — blocks entirely below the window are
skipped symmetrically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref,            # inputs
    o_ref,                          # output
    acc_ref, m_ref, l_ref,          # VMEM scratch carried over kv blocks
    *,
    bq: int,
    bk: int,
    kv_seq: int,
    causal: bool,
    window: int,
    scale: float,
):
    qb = pl.program_id(2)
    kb = pl.program_id(3)
    nkb = pl.num_programs(3)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qb * bq
    k_start = kb * bk

    # Block-level skip decisions (static per (qb, kb) pair given causal/window).
    run = True
    if causal:
        run = jnp.logical_and(True, k_start <= q_start + bq - 1)
    if window > 0:
        run = jnp.logical_and(run, k_start + bk - 1 >= q_start - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                       # (bq, bk)

        q_idx = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_idx = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_idx < kv_seq
        if causal:
            mask = jnp.logical_and(mask, k_idx <= q_idx)
        if window > 0:
            mask = jnp.logical_and(mask, k_idx > q_idx - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                             # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(kb == nkb - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,            # (B, H, Sq, D)
    k: jax.Array,            # (B, Hkv, Skv, D)
    v: jax.Array,            # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    window: int = 0,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
    kv_len: int | None = None,       # true (unpadded) KV length for masking
    head_dim: int | None = None,     # true head dim for the softmax scale
) -> jax.Array:
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    rep = h // hkv
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    grid = (b, h, sq // bq, skv // bk)
    scale = 1.0 / ((head_dim or d) ** 0.5)

    kernel = functools.partial(
        _attn_kernel,
        bq=bq,
        bk=bk,
        kv_seq=kv_len if kv_len is not None else skv,
        causal=causal,
        window=window,
        scale=scale,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi // rep, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),   # m (running max)
            pltpu.VMEM((bq, 1), jnp.float32),   # l (running denom)
        ],
        interpret=interpret,
    )(q, k, v)
