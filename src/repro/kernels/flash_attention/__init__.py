from repro.kernels.flash_attention import ops  # noqa: F401
