"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,            # (B, H, Sq, D)
    k: jax.Array,            # (B, Hkv, Skv, D)
    v: jax.Array,            # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    q_idx = jnp.arange(sq)[:, None]
    k_idx = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = mask & (k_idx <= q_idx)
    if window > 0:
        mask = mask & (k_idx > q_idx - window)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
