"""Jit'd public wrapper for the flash-attention kernel.

Handles layout (the model uses (B, S, H, D); the kernel wants (B, H, S, D)),
head-dim padding to the 128-lane MXU width, ragged tails via sequence
padding, and the CPU fallback (interpret mode).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref


def _pad_to(x: jax.Array, axis: int, mult: int) -> tuple[jax.Array, int]:
    size = x.shape[axis]
    target = -(-size // mult) * mult
    if target == size:
        return x, size
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(x, pad), size


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "interpret", "bq", "bk")
)
def flash_attention_bhsd(
    q: jax.Array,            # (B, H, Sq, D)
    k: jax.Array,            # (B, Hkv, Skv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    interpret: bool = True,
    bq: int = 128,
    bk: int = 128,
) -> jax.Array:
    sq0, skv0, d0 = q.shape[2], k.shape[2], q.shape[3]
    # MXU alignment: pad head dim to 128 lanes, seq to block multiples.
    q, _ = _pad_to(q, 3, 128)
    k, _ = _pad_to(k, 3, 128)
    v, _ = _pad_to(v, 3, 128)
    bq_eff = min(bq, q.shape[2])
    bk_eff = min(bk, k.shape[2])
    q, _ = _pad_to(q, 2, bq_eff)
    k, _ = _pad_to(k, 2, bk_eff)
    v, _ = _pad_to(v, 2, bk_eff)
    # Padded KV columns are masked inside the kernel via the true kv length;
    # the softmax scale uses the true head dim (zero-padded lanes contribute
    # nothing to q·k but must not change the scale).
    out = flash_attention_kernel(
        q, k, v, causal=causal, window=window, bq=bq_eff, bk=bk_eff,
        interpret=interpret, kv_len=skv0, head_dim=d0,
    )
    return out[:, :, :sq0, :d0]


def flash_attention(
    q: jax.Array,            # (B, S, H, D) — model layout
    k: jax.Array,            # (B, S, Hkv, D)
    v: jax.Array,
    *,
    mask=None,               # accepted for API parity; causal masks only
    causal: bool = True,
    window: int = 0,
    interpret: bool = True,
) -> jax.Array:
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_bhsd(
        qt, kt, vt, causal=causal, window=window, interpret=interpret
    )
    return jnp.swapaxes(out, 1, 2)


def attention_reference(q, k, v, *, causal=True, window=0):
    """(B,S,H,D)-layout oracle, for tests."""
    out = attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=causal, window=window,
    )
    return jnp.swapaxes(out, 1, 2)
