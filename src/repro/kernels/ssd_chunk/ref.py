"""Pure-jnp oracle for the SSD chunk kernel: the direct sequential
recurrence S_t = a_t S_{t-1} + k_t v_tᵀ, y_t = q_t·S_t."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(
    q: jax.Array,        # (B, H, S, N)
    k: jax.Array,
    v: jax.Array,        # (B, H, S, P)
    log_a: jax.Array,    # (B, H, S)
) -> tuple[jax.Array, jax.Array]:
    b, h, s, n = q.shape
    p = v.shape[-1]
    a = jnp.exp(log_a.astype(jnp.float32))

    def step(state, inp):
        qt, kt, vt, at = inp                      # (B,H,N),(B,H,N),(B,H,P),(B,H)
        state = at[..., None, None] * state + kt[..., :, None] * vt[..., None, :]
        yt = jnp.einsum("bhn,bhnp->bhp", qt, state)
        return state, yt

    xs = (
        jnp.moveaxis(q.astype(jnp.float32), 2, 0),
        jnp.moveaxis(k.astype(jnp.float32), 2, 0),
        jnp.moveaxis(v.astype(jnp.float32), 2, 0),
        jnp.moveaxis(a, 2, 0),
    )
    state0 = jnp.zeros((b, h, n, p), jnp.float32)
    final, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 2).astype(v.dtype), final
