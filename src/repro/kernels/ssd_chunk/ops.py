"""Jit'd wrapper for the SSD chunk kernel: model layout (B,S,H,·) <-> kernel
layout (B,H,S,·), lane padding for N/P, chunk selection."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_chunk.kernel import ssd_chunk_kernel
from repro.kernels.ssd_chunk.ref import ssd_ref


def _pad_last(x, mult):
    n = x.shape[-1]
    t = -(-n // mult) * mult
    if t == n:
        return x
    pad = [(0, 0)] * x.ndim
    pad[-1] = (0, t - n)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    q: jax.Array,        # (B, S, H, N) — model layout
    k: jax.Array,
    v: jax.Array,        # (B, S, H, P)
    log_a: jax.Array,    # (B, S, H)
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y: (B,S,H,P), final_state: (B,H,N,P))."""
    n0, p0 = q.shape[-1], v.shape[-1]
    qt = _pad_last(jnp.swapaxes(q, 1, 2), 128)
    kt = _pad_last(jnp.swapaxes(k, 1, 2), 128)
    vt = _pad_last(jnp.swapaxes(v, 1, 2), 128)
    la = jnp.swapaxes(log_a, 1, 2)                 # (B,H,S)
    y, state = ssd_chunk_kernel(qt, kt, vt, la, chunk=chunk, interpret=interpret)
    return jnp.swapaxes(y, 1, 2)[..., :p0], state[:, :, :n0, :p0]


def ssd_reference(q, k, v, log_a):
    """(B,S,H,·)-layout oracle."""
    y, state = ssd_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        jnp.swapaxes(log_a, 1, 2),
    )
    return jnp.swapaxes(y, 1, 2), state
