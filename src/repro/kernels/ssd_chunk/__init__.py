from repro.kernels.ssd_chunk import ops  # noqa: F401
