"""Pallas TPU kernel for the chunked decay-weighted linear-attention scan —
the compute core of Mamba2 (SSD) and mLSTM (repro.models.ssm).

Computes, per (batch, head), with per-step decays a_t = exp(log_a_t) <= 1:

    S_t = a_t · S_{t-1} + k_t ⊗ v_t            y_t = q_t · S_t

Grid: (batch, heads, num_chunks) — the chunk axis is minor, so the running
state S (N×P, f32) lives in VMEM scratch and carries across chunk steps.
Per chunk of length Q the kernel does three MXU matmuls:

    intra  = ((q·kᵀ) ⊙ D_causal-decay) @ v          (Q,Q)·(Q,P)
    y     += (q ⊙ exp(cum)) @ S_prev                 (Q,N)·(N,P)
    S_new  = a_tot·S_prev + (k ⊙ exp(tot−cum))ᵀ @ v  (N,Q)·(Q,P)

BlockSpecs tile q/k as (1,1,Q,N), v/y as (1,1,Q,P), log_a as (1,1,Q) — all
VMEM; N, P, Q should be multiples of the 128-lane MXU width for peak
utilisation (the wrapper pads).  The decay matrices are built in-register
from the cumulative log-decay (exp of differences; ≤ 1, numerically safe).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    q_ref, k_ref, v_ref, la_ref,      # inputs (blocked per chunk)
    y_ref, s_out_ref,                  # outputs
    state_ref,                         # scratch: (N, P) f32 carried over chunks
    *,
    chunk: int,
):
    c = pl.program_id(2)
    ncs = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (Q, N)
    k = k_ref[0, 0].astype(jnp.float32)            # (Q, N)
    v = v_ref[0, 0].astype(jnp.float32)            # (Q, P)
    la = la_ref[0, 0].astype(jnp.float32)          # (Q,)

    cum = jnp.cumsum(la)                           # inclusive
    total = cum[-1]

    # Intra-chunk: scores[i,j] = (q_i·k_j)·exp(cum_i − cum_j) for i >= j.
    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q,Q)
    diff = cum[:, None] - cum[None, :]
    causal = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    w = jnp.where(causal, qk * jnp.exp(diff), 0.0)
    y = jax.lax.dot_general(w, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (Q,P)

    # Inter-chunk: y += (q ⊙ exp(cum)) @ S_prev
    q_dec = q * jnp.exp(cum)[:, None]
    y = y + jax.lax.dot_general(q_dec, state_ref[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # State update: S = exp(total)·S + (k ⊙ exp(total−cum))ᵀ @ v
    k_dec = k * jnp.exp(total - cum)[:, None]
    s_chunk = jax.lax.dot_general(k_dec, v, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (N,P)
    state_ref[...] = jnp.exp(total) * state_ref[...] + s_chunk

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(c == ncs - 1)
    def _final():
        s_out_ref[0, 0] = state_ref[...].astype(s_out_ref.dtype)


def ssd_chunk_kernel(
    q: jax.Array,        # (B, H, S, N)
    k: jax.Array,        # (B, H, S, N)
    v: jax.Array,        # (B, H, S, P)
    log_a: jax.Array,    # (B, H, S)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y: (B,H,S,P), final_state: (B,H,N,P))."""
    b, h, s, n = q.shape
    p = v.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    grid = (b, h, nc)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    blk_n = pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci: (bi, hi, ci, 0))
    blk_p = pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0))
    blk_a = pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci))
    y, s_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[blk_n, blk_n, blk_p, blk_a],
        out_specs=[
            blk_p,
            pl.BlockSpec((1, 1, n, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), v.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(q, k, v, log_a)
    return y, s_out
