"""Checkpointing: pytree <-> .npz with path-keyed flat layout, plus FL
round-state (round index, schedule position, RNG seed) as JSON sidecar."""

from __future__ import annotations

import json
import os
from typing import Any

import numpy as np

from repro.core.partition import tree_paths

PyTree = Any

_SEP = "##"


def save_pytree(path: str, tree: PyTree) -> None:
    flat = {_SEP.join(p): np.asarray(leaf) for p, leaf in tree_paths(tree)}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **flat)


def load_pytree(path: str) -> PyTree:
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    tree: PyTree = {}
    for key in data.files:
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = data[key]
    return tree


def save_round_state(path: str, state: dict) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(state, f, indent=2)


def load_round_state(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def save_checkpoint(directory: str, params: PyTree, round_state: dict) -> None:
    save_pytree(os.path.join(directory, "params.npz"), params)
    save_round_state(os.path.join(directory, "state.json"), round_state)


def load_checkpoint(directory: str) -> tuple[PyTree, dict]:
    return (
        load_pytree(os.path.join(directory, "params.npz")),
        load_round_state(os.path.join(directory, "state.json")),
    )
