from repro.checkpoint.io import (  # noqa: F401
    load_checkpoint,
    load_pytree,
    save_checkpoint,
    save_pytree,
)
