"""Decoder-only transformer assembly (dense / GQA / MLA / MoE) plus the
xLSTM and Zamba2-hybrid assemblies.

Layers are *stacked*: per-block parameter pytrees carry a leading layer axis
and the forward pass is a ``jax.lax.scan`` over it — keeping the lowered HLO
(and CPU compile time for the 80 dry-run combinations) small.  Heterogeneous
stacks (deepseek's leading dense blocks, zamba2's shared-attention chunks,
xLSTM's mLSTM/sLSTM alternation) are expressed as a few homogeneous stacks.

Set ``scan_layers=False`` in ``init``/``forward`` calls via config name suffix
is NOT supported — the FL-simulation models (paper's ResNet / small NLP
transformer) use the *unstacked* builders in ``repro.models.nlp_small`` and
``repro.models.resnet`` instead, which FedPart partitions per-layer.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    bf16_grad_barrier,
    embed,
    embedding_init,
    linear,
    linear_init,
    mlp_apply,
    mlp_init,
    norm_apply,
    norm_init,
    unembed,
)

PyTree = Any


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _act_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.activation_dtype)


# ---------------------------------------------------------------------------
# Decoder block
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, *, use_moe: bool) -> PyTree:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    p: PyTree = {
        "attn_norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
        "mlp_norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
    }
    if cfg.use_mla:
        p["attn"] = attn.mla_init(k1, cfg, dt)
    else:
        p["attn"] = attn.gqa_init(k1, cfg, dt)
    if use_moe:
        p["moe"] = moe_lib.moe_init(k2, cfg, dt)
    else:
        p["mlp"] = mlp_init(k2, cfg.mlp_kind, cfg.d_model, cfg.d_ff, dt)
    return p


def block_forward(
    p: PyTree,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    use_moe: bool,
    window: int,
    impl: str,
) -> tuple[jax.Array, PyTree, jax.Array]:
    h = norm_apply(cfg.norm_kind, p["attn_norm"], x)
    if cfg.use_mla:
        y, (c0, c1) = attn.mla_full(p["attn"], cfg, h, positions, window=window, impl=impl)
        kv = {"c_kv": c0, "k_rope": c1}
    else:
        y, (ck, cv) = attn.gqa_full(p["attn"], cfg, h, positions, window=window, impl=impl)
        kv = {"k": ck, "v": cv}
    x = x + y
    h = norm_apply(cfg.norm_kind, p["mlp_norm"], x)
    if use_moe:
        y, aux = moe_lib.moe_apply(p["moe"], cfg, h)
    else:
        y, aux = mlp_apply(p["mlp"], cfg.mlp_kind, h), jnp.float32(0.0)
    return bf16_grad_barrier(x + y), kv, aux


def block_decode(
    p: PyTree,
    cfg: ModelConfig,
    x: jax.Array,
    cache: PyTree,
    pos: jax.Array,
    *,
    use_moe: bool,
    window: int,
) -> tuple[jax.Array, PyTree]:
    h = norm_apply(cfg.norm_kind, p["attn_norm"], x)
    if cfg.use_mla:
        y, (c0, c1) = attn.mla_decode(
            p["attn"], cfg, h, cache["c_kv"], cache["k_rope"], pos, window=window
        )
        new_cache = {"c_kv": c0, "k_rope": c1}
    else:
        y, (ck, cv) = attn.gqa_decode(
            p["attn"], cfg, h, cache["k"], cache["v"], pos, window=window
        )
        new_cache = {"k": ck, "v": cv}
    x = x + y
    h = norm_apply(cfg.norm_kind, p["mlp_norm"], x)
    if use_moe:
        y, _ = moe_lib.moe_apply(p["moe"], cfg, h)
    else:
        y = mlp_apply(p["mlp"], cfg.mlp_kind, h)
    return x + y, new_cache


def _scan(body, carry, xs, *, remat: bool = False, unroll: int = 1):
    if remat:
        body = jax.checkpoint(body)
    return jax.lax.scan(body, carry, xs, unroll=max(1, unroll))


def _stack_init(key, n: int, one_init):
    keys = jax.random.split(key, n)
    return jax.vmap(one_init)(keys)


# ---------------------------------------------------------------------------
# Decoder-only model
# ---------------------------------------------------------------------------

def decoder_init(key, cfg: ModelConfig) -> PyTree:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 5)
    n_moe = cfg.num_layers - cfg.first_dense_layers if cfg.is_moe else 0
    n_dense = cfg.num_layers - n_moe
    params: PyTree = {
        "embed": embedding_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
    }
    if n_dense > 0:
        params["blocks"] = _stack_init(
            keys[1], n_dense, lambda k: block_init(k, cfg, use_moe=False)
        )
    if n_moe > 0:
        params["moe_blocks"] = _stack_init(
            keys[2], n_moe, lambda k: block_init(k, cfg, use_moe=True)
        )
    if not cfg.tie_embeddings:
        params["head"] = linear_init(keys[3], cfg.d_model, cfg.vocab_size, dt)
    if cfg.mtp_depth > 0:  # deepseek-v3 multi-token prediction head
        params["mtp"] = {
            "proj": linear_init(keys[4], 2 * cfg.d_model, cfg.d_model, dt),
            "norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
            "block": block_init(jax.random.fold_in(keys[4], 1), cfg, use_moe=False),
        }
    return params


def _embed_inputs(params, cfg, tokens, media_embeds):
    x = embed(params["embed"], tokens, _act_dtype(cfg))
    if media_embeds is not None:
        x = jnp.concatenate([media_embeds.astype(x.dtype), x], axis=1)
    return x


def _logits(params, cfg, x):
    if cfg.tie_embeddings:
        return unembed(params["embed"], x)
    return linear(params["head"], x.astype(jnp.float32))


MTP_WEIGHT = 0.3   # deepseek-v3 MTP loss weight (lambda in the paper)


def decoder_forward(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    labels: jax.Array | None = None,
    media_embeds: jax.Array | None = None,
    window: int = 0,
    impl: str = "xla",
    collect_cache: bool = False,
    remat: bool = False,
    unroll: int = 1,
) -> tuple[jax.Array, PyTree | None, jax.Array]:
    """Full-sequence forward (training / prefill).

    Returns (logits, caches | None, aux_loss).  ``window`` > 0 applies
    sliding-window attention (the long-context variant).
    """
    x = _embed_inputs(params, cfg, tokens, media_embeds)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    aux_total = jnp.float32(0.0)
    caches = {}

    def run_stack(x, aux, stack, use_moe):
        def body(carry, p):
            xc, auxc = carry
            y, kv, aux_l = block_forward(
                p, cfg, xc, positions, use_moe=use_moe, window=window, impl=impl
            )
            return (y, auxc + aux_l), kv if collect_cache else None

        (x, aux), kvs = _scan(body, (x, aux), stack, remat=remat, unroll=unroll)
        return x, aux, kvs

    if "blocks" in params:
        x, aux_total, kvs = run_stack(x, aux_total, params["blocks"], use_moe=False)
        if collect_cache:
            caches["blocks"] = kvs
    if "moe_blocks" in params:
        x, aux_total, kvs = run_stack(x, aux_total, params["moe_blocks"], use_moe=True)
        if collect_cache:
            caches["moe_blocks"] = kvs

    x = norm_apply(cfg.norm_kind, params["final_norm"], x)
    logits = _logits(params, cfg, x)
    if cfg.mtp_depth > 0 and "mtp" in params and labels is not None:
        # deepseek-v3 multi-token prediction (training aux objective):
        # combine position t's hidden state with the embedding of token t+1,
        # run one extra dense block, predict the t+1 position's label.
        st = tokens.shape[1]
        x_tok = x[:, -st:]                       # token positions (skip media)
        mtp = params["mtp"]
        nxt = embed(params["embed"], tokens[:, 1:], x_tok.dtype)
        h = jnp.concatenate([x_tok[:, :-1], nxt], axis=-1)
        h = norm_apply(cfg.norm_kind, mtp["norm"], linear(mtp["proj"], h))
        h, _, _ = block_forward(
            mtp["block"], cfg, h, positions[:, : st - 1],
            use_moe=False, window=window, impl=impl,
        )
        mtp_logits = _logits(params, cfg, h)
        aux_total = aux_total + MTP_WEIGHT * lm_loss(mtp_logits, labels[:, 1:])
    return logits, (caches if collect_cache else None), aux_total


def decoder_decode_step(
    params: PyTree,
    cfg: ModelConfig,
    token: jax.Array,          # (B, 1) int32
    cache: PyTree,
    pos: jax.Array,            # scalar int32
    *,
    window: int = 0,
    unroll: int = 1,
) -> tuple[jax.Array, PyTree]:
    """One-token serve step against the KV cache."""
    x = embed(params["embed"], token, _act_dtype(cfg))
    new_cache: PyTree = {}

    def run_stack(x, stack, stack_cache, use_moe):
        def body(carry, inp):
            p, c = inp
            y, nc = block_decode(p, cfg, carry, c, pos, use_moe=use_moe, window=window)
            return y, nc

        x, ncs = _scan(body, x, (stack, stack_cache), unroll=unroll)
        return x, ncs

    if "blocks" in params:
        x, nc = run_stack(x, params["blocks"], cache["blocks"], use_moe=False)
        new_cache["blocks"] = nc
    if "moe_blocks" in params:
        x, nc = run_stack(x, params["moe_blocks"], cache["moe_blocks"], use_moe=True)
        new_cache["moe_blocks"] = nc

    x = norm_apply(cfg.norm_kind, params["final_norm"], x)
    return _logits(params, cfg, x), new_cache


def decoder_cache_init(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> PyTree:
    n_moe = cfg.num_layers - cfg.first_dense_layers if cfg.is_moe else 0
    n_dense = cfg.num_layers - n_moe
    hd = cfg.resolved_head_dim

    def layer_cache(n_layers):
        if cfg.use_mla:
            return {
                "c_kv": jnp.zeros((n_layers, batch, cache_len, cfg.kv_lora_rank), dtype),
                "k_rope": jnp.zeros(
                    (n_layers, batch, cache_len, cfg.qk_rope_head_dim), dtype
                ),
            }
        return {
            "k": jnp.zeros((n_layers, batch, cache_len, cfg.num_kv_heads, hd), dtype),
            "v": jnp.zeros((n_layers, batch, cache_len, cfg.num_kv_heads, hd), dtype),
        }

    cache: PyTree = {}
    if n_dense > 0:
        cache["blocks"] = layer_cache(n_dense)
    if n_moe > 0:
        cache["moe_blocks"] = layer_cache(n_moe)
    return cache


# ---------------------------------------------------------------------------
# xLSTM model (alternating mLSTM / sLSTM pairs)
# ---------------------------------------------------------------------------

def xlstm_init(key, cfg: ModelConfig) -> PyTree:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 4)
    assert cfg.num_layers % 2 == 0, "xlstm assembly uses mLSTM/sLSTM pairs"
    n_pairs = cfg.num_layers // 2

    def pair_init(k):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        return {
            "m_norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
            "mlstm": ssm_lib.mlstm_init(k1, cfg, dt),
            "s_norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
            "slstm": ssm_lib.slstm_init(k2, cfg, dt),
        }

    return {
        "embed": embedding_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
        "pairs": _stack_init(keys[1], n_pairs, pair_init),
        "final_norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
        "head": linear_init(keys[2], cfg.d_model, cfg.vocab_size, dt),
    }


def _xlstm_pair_forward(p, cfg, x):
    h, m_cache = ssm_lib.mlstm_forward(
        p["mlstm"], cfg, norm_apply(cfg.norm_kind, p["m_norm"], x)
    )
    x = x + h
    h, s_cache = ssm_lib.slstm_forward(
        p["slstm"], cfg, norm_apply(cfg.norm_kind, p["s_norm"], x)
    )
    return x + h, {"mlstm": m_cache, "slstm": s_cache}


def xlstm_forward(
    params: PyTree, cfg: ModelConfig, tokens: jax.Array, *,
    collect_cache: bool = False, remat: bool = False, unroll: int = 1,
) -> tuple[jax.Array, PyTree | None, jax.Array]:
    x = embed(params["embed"], tokens, _act_dtype(cfg))

    def body(carry, p):
        y, cache = _xlstm_pair_forward(p, cfg, carry)
        return y, cache if collect_cache else None

    x, caches = _scan(body, x, params["pairs"], remat=remat, unroll=unroll)
    x = norm_apply(cfg.norm_kind, params["final_norm"], x)
    return _logits(params, cfg, x), caches, jnp.float32(0.0)


def xlstm_decode_step(
    params: PyTree, cfg: ModelConfig, token: jax.Array, cache: PyTree, pos: jax.Array,
    *, unroll: int = 1,
) -> tuple[jax.Array, PyTree]:
    x = embed(params["embed"], token, _act_dtype(cfg))

    def body(carry, inp):
        p, c = inp
        h, mc = ssm_lib.mlstm_decode(
            p["mlstm"], cfg, norm_apply(cfg.norm_kind, p["m_norm"], carry), c["mlstm"]
        )
        x1 = carry + h
        h, sc = ssm_lib.slstm_decode(
            p["slstm"], cfg, norm_apply(cfg.norm_kind, p["s_norm"], x1), c["slstm"]
        )
        return x1 + h, {"mlstm": mc, "slstm": sc}

    x, new_cache = _scan(body, x, (params["pairs"], cache), unroll=unroll)
    x = norm_apply(cfg.norm_kind, params["final_norm"], x)
    return _logits(params, cfg, x), new_cache


def xlstm_cache_init(cfg: ModelConfig, batch: int, dtype) -> PyTree:
    n_pairs = cfg.num_layers // 2

    def one(_):
        return {
            "mlstm": ssm_lib.mlstm_cache_init(cfg, batch, dtype),
            "slstm": ssm_lib.slstm_cache_init(cfg, batch, dtype),
        }

    return jax.vmap(one)(jnp.arange(n_pairs))


# ---------------------------------------------------------------------------
# Zamba2 hybrid (mamba2 chunks + one shared attention block)
# ---------------------------------------------------------------------------

def hybrid_layout(cfg: ModelConfig) -> tuple[int, int]:
    """(num_chunks, tail) — ``num_chunks`` groups of ``attn_every`` mamba
    blocks, each preceded by the shared attention block; ``tail`` leftover
    mamba blocks."""
    per = max(cfg.attn_every, 1)
    return cfg.num_layers // per, cfg.num_layers % per


def hybrid_init(key, cfg: ModelConfig) -> PyTree:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 6)
    n_chunks, tail = hybrid_layout(cfg)
    per = max(cfg.attn_every, 1)

    def chunk_init(k):
        return _stack_init(k, per, lambda kk: {
            "norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
            "mamba": ssm_lib.mamba2_init(kk, cfg, dt),
        })

    params: PyTree = {
        "embed": embedding_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
        "chunks": _stack_init(keys[1], n_chunks, chunk_init),
        "shared_attn": {
            # zamba2: shared block consumes concat(hidden, original embedding)
            "in_proj": linear_init(keys[2], 2 * cfg.d_model, cfg.d_model, dt),
            "block": block_init(keys[3], cfg, use_moe=False),
        },
        "final_norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
        "head": linear_init(keys[4], cfg.d_model, cfg.vocab_size, dt),
    }
    if tail > 0:
        params["tail"] = _stack_init(keys[5], tail, lambda kk: {
            "norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
            "mamba": ssm_lib.mamba2_init(kk, cfg, dt),
        })
    return params


def hybrid_forward(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    window: int = 0,
    impl: str = "xla",
    collect_cache: bool = False,
    remat: bool = False,
    unroll: int = 1,
) -> tuple[jax.Array, PyTree | None, jax.Array]:
    x = embed(params["embed"], tokens, _act_dtype(cfg))
    x0 = x
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def mamba_body(carry, p):
        y, c = ssm_lib.mamba2_forward(
            p["mamba"], cfg, norm_apply(cfg.norm_kind, p["norm"], carry)
        )
        return carry + y, c if collect_cache else None

    def chunk_body(carry, chunk_params):
        xc = carry
        h = linear(params["shared_attn"]["in_proj"], jnp.concatenate([xc, x0], axis=-1))
        y, kv, _ = block_forward(
            params["shared_attn"]["block"], cfg, h, positions,
            use_moe=False, window=window, impl=impl,
        )
        xc = xc + y
        xc, mcaches = _scan(mamba_body, xc, chunk_params, unroll=unroll)
        return xc, {"attn_kv": kv, "mamba": mcaches} if collect_cache else None

    x, chunk_caches = _scan(chunk_body, x, params["chunks"], remat=remat, unroll=unroll)
    tail_caches = None
    if "tail" in params:
        x, tail_caches = _scan(mamba_body, x, params["tail"], remat=remat, unroll=unroll)
    x = norm_apply(cfg.norm_kind, params["final_norm"], x)
    logits = _logits(params, cfg, x)
    caches = {"chunks": chunk_caches, "tail": tail_caches} if collect_cache else None
    return logits, caches, jnp.float32(0.0)


def hybrid_decode_step(
    params: PyTree,
    cfg: ModelConfig,
    token: jax.Array,
    cache: PyTree,
    pos: jax.Array,
    *,
    window: int = 0,
    unroll: int = 1,
) -> tuple[jax.Array, PyTree]:
    x = embed(params["embed"], token, _act_dtype(cfg))
    x0 = x

    def mamba_body(carry, inp):
        p, c = inp
        y, nc = ssm_lib.mamba2_decode(
            p["mamba"], cfg, norm_apply(cfg.norm_kind, p["norm"], carry), c
        )
        return carry + y, nc

    def chunk_body(carry, inp):
        xc = carry
        p_chunk, c_chunk = inp
        h = linear(params["shared_attn"]["in_proj"], jnp.concatenate([xc, x0], axis=-1))
        y, attn_nc = block_decode(
            params["shared_attn"]["block"], cfg, h, c_chunk["attn_kv"], pos,
            use_moe=False, window=window,
        )
        xc = xc + y
        xc, m_nc = _scan(mamba_body, xc, (p_chunk, c_chunk["mamba"]), unroll=unroll)
        return xc, {"attn_kv": attn_nc, "mamba": m_nc}

    x, chunk_nc = _scan(chunk_body, x, (params["chunks"], cache["chunks"]), unroll=unroll)
    new_cache: PyTree = {"chunks": chunk_nc}
    if "tail" in params:
        x, tail_nc = _scan(mamba_body, x, (params["tail"], cache["tail"]), unroll=unroll)
        new_cache["tail"] = tail_nc
    else:
        new_cache["tail"] = cache.get("tail")
    x = norm_apply(cfg.norm_kind, params["final_norm"], x)
    return _logits(params, cfg, x), new_cache


def hybrid_cache_init(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> PyTree:
    n_chunks, tail = hybrid_layout(cfg)
    per = max(cfg.attn_every, 1)
    hd = cfg.resolved_head_dim

    def mamba_caches(n):
        return jax.vmap(lambda _: ssm_lib.mamba2_cache_init(cfg, batch, dtype))(
            jnp.arange(n)
        )

    def one_chunk(_):
        return {
            "attn_kv": {
                "k": jnp.zeros((batch, cache_len, cfg.num_kv_heads, hd), dtype),
                "v": jnp.zeros((batch, cache_len, cfg.num_kv_heads, hd), dtype),
            },
            "mamba": mamba_caches(per),
        }

    cache: PyTree = {"chunks": jax.vmap(one_chunk)(jnp.arange(n_chunks))}
    cache["tail"] = mamba_caches(tail) if tail > 0 else None
    return cache


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(
    logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None
) -> jax.Array:
    """Next-token cross entropy.  logits: (B,S,V); labels: (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
