"""Unified model API: one dispatch layer over every architecture kind.

The launcher, smoke tests, and benchmarks speak only this interface:

    init(key, cfg)                          -> params
    forward(params, cfg, batch, ...)        -> (logits, cache|None, aux)
    loss(params, cfg, batch)                -> scalar
    decode_step(params, cfg, batch, cache, pos) -> (logits, cache)
    cache_init(cfg, batch, cache_len, dtype)    -> cache
    input_specs(cfg, shape)                 -> {name: ShapeDtypeStruct}

Input shapes (the four assigned):

    train_4k     seq 4,096   batch 256   train_step
    prefill_32k  seq 32,768  batch 32    prefill (forward + cache)
    decode_32k   seq 32,768  batch 128   serve_step (1 token + cache)
    long_500k    seq 524,288 batch 1     serve_step, sub-quadratic policy
"""

from __future__ import annotations

import dataclasses
from typing import Any, Literal

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, frontends, transformer

PyTree = Any

LONG_WINDOW = 16_384   # sliding-window size for dense/MoE archs at 500k


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def decode_window(cfg: ModelConfig, seq_len: int) -> int:
    """Sliding-window policy for long-context decode (DESIGN.md §4).

    - SSM (xlstm): no attention cache at all -> 0 (unused).
    - MLA (deepseek-v3): the compressed latent cache is what makes 500k
      feasible -> full cache (0 = no window).
    - dense / other MoE / hybrid shared-attn: window of LONG_WINDOW.
    """
    if seq_len <= 200_000:
        return 0
    if cfg.kind == "xlstm" or cfg.use_mla:
        return 0
    return cfg.sliding_window or LONG_WINDOW


def cache_length(cfg: ModelConfig, seq_len: int) -> int:
    w = decode_window(cfg, seq_len)
    return w if w > 0 else seq_len


def supports_shape(cfg: ModelConfig, shape: InputShape) -> bool:
    if shape.name == "long_500k" and not cfg.supports_long_decode:
        return False  # whisper (DESIGN.md §4)
    return True


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

def init(key, cfg: ModelConfig) -> PyTree:
    if cfg.kind == "decoder":
        return transformer.decoder_init(key, cfg)
    if cfg.kind == "encdec":
        return encdec.encdec_init(key, cfg)
    if cfg.kind == "xlstm":
        return transformer.xlstm_init(key, cfg)
    if cfg.kind == "hybrid":
        return transformer.hybrid_init(key, cfg)
    raise ValueError(f"unknown model kind {cfg.kind!r}")


def forward(
    params: PyTree,
    cfg: ModelConfig,
    batch: dict,
    *,
    window: int = 0,
    impl: str = "xla",
    collect_cache: bool = False,
    remat: bool = False,
    unroll: int = 1,
) -> tuple[jax.Array, PyTree | None, jax.Array]:
    kw = dict(collect_cache=collect_cache, remat=remat, unroll=unroll)
    if cfg.kind == "decoder":
        return transformer.decoder_forward(
            params, cfg, batch["tokens"], media_embeds=batch.get("media"),
            labels=batch.get("labels"), window=window, impl=impl, **kw,
        )
    if cfg.kind == "encdec":
        return encdec.encdec_forward(
            params, cfg, batch["tokens"], batch["frames"], window=window, **kw,
        )
    if cfg.kind == "xlstm":
        return transformer.xlstm_forward(params, cfg, batch["tokens"], **kw)
    if cfg.kind == "hybrid":
        return transformer.hybrid_forward(
            params, cfg, batch["tokens"], window=window, impl=impl, **kw,
        )
    raise ValueError(cfg.kind)


def loss(params: PyTree, cfg: ModelConfig, batch: dict, *, impl: str = "xla",
         remat: bool = False, unroll: int = 1) -> jax.Array:
    logits, _, aux = forward(params, cfg, batch, impl=impl, remat=remat, unroll=unroll)
    labels = batch["labels"]
    if cfg.num_media_tokens > 0:
        # media positions carry no labels; score token positions only
        logits = logits[:, cfg.num_media_tokens :, :]
    return transformer.lm_loss(logits, labels) + aux


def decode_step(
    params: PyTree,
    cfg: ModelConfig,
    token: jax.Array,
    cache: PyTree,
    pos: jax.Array,
    *,
    window: int = 0,
    unroll: int = 1,
) -> tuple[jax.Array, PyTree]:
    if cfg.kind == "decoder":
        return transformer.decoder_decode_step(
            params, cfg, token, cache, pos, window=window, unroll=unroll)
    if cfg.kind == "encdec":
        return encdec.encdec_decode_step(
            params, cfg, token, cache, pos, window=window, unroll=unroll)
    if cfg.kind == "xlstm":
        return transformer.xlstm_decode_step(params, cfg, token, cache, pos, unroll=unroll)
    if cfg.kind == "hybrid":
        return transformer.hybrid_decode_step(
            params, cfg, token, cache, pos, window=window, unroll=unroll)
    raise ValueError(cfg.kind)


def cache_init(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> PyTree:
    if cfg.kind == "decoder":
        return transformer.decoder_cache_init(cfg, batch, cache_len, dtype)
    if cfg.kind == "encdec":
        return encdec.encdec_cache_init(cfg, batch, cache_len, dtype)
    if cfg.kind == "xlstm":
        return transformer.xlstm_cache_init(cfg, batch, dtype)
    if cfg.kind == "hybrid":
        return transformer.hybrid_cache_init(cfg, batch, cache_len, dtype)
    raise ValueError(cfg.kind)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs; no allocation) + synthetic batches
# ---------------------------------------------------------------------------

def _token_len(cfg: ModelConfig, seq_len: int) -> int:
    """VLM: media embeddings occupy the first ``num_media_tokens`` positions."""
    return seq_len - cfg.num_media_tokens


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict[str, Any]:
    i32 = jnp.dtype("int32")
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        st = _token_len(cfg, s)
        specs: dict[str, Any] = {"tokens": jax.ShapeDtypeStruct((b, st), i32)}
        if cfg.kind == "encdec":
            specs["frames"] = frontends.frame_embeds_spec(cfg, b)
        if cfg.num_media_tokens > 0:
            specs["media"] = frontends.media_embeds_spec(cfg, b)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, st), i32)
        return specs
    # decode: one token + cache + position
    cl = cache_length(cfg, s)
    cache = jax.eval_shape(
        lambda: cache_init(cfg, b, cl, jnp.dtype(cfg.param_dtype))
    )
    return {
        "token": jax.ShapeDtypeStruct((b, 1), i32),
        "cache": cache,
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def synth_batch(key, cfg: ModelConfig, shape: InputShape) -> dict:
    """Concrete random batch matching ``input_specs`` (CPU smoke tests)."""
    keys = jax.random.split(key, 4)
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        st = _token_len(cfg, s)
        batch: dict = {
            "tokens": jax.random.randint(keys[0], (b, st), 0, cfg.vocab_size, jnp.int32)
        }
        if cfg.kind == "encdec":
            batch["frames"] = frontends.synth_frame_embeds(keys[1], cfg, b)
        if cfg.num_media_tokens > 0:
            batch["media"] = frontends.synth_media_embeds(keys[1], cfg, b)
        if shape.kind == "train":
            batch["labels"] = jax.random.randint(
                keys[2], (b, st), 0, cfg.vocab_size, jnp.int32
            )
        return batch
    cl = cache_length(cfg, s)
    return {
        "token": jax.random.randint(keys[0], (b, 1), 0, cfg.vocab_size, jnp.int32),
        "cache": cache_init(cfg, b, cl, jnp.dtype(cfg.param_dtype)),
        "pos": jnp.int32(s - 1),
    }
