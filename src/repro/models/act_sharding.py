"""Optional activation-sharding constraints (§Perf iteration 3).

GSPMD propagates parameter shardings through the model well — except where a
logical axis does not divide the mesh axis.  llava-34b's 56 attention heads
over a 16-way model axis is the canonical failure: the (B, H, Sq, Skv)
score/prob tensors get fully replicated (533 GB/dev at train_4k, measured).

Under ``activation_sharding(mesh)``, attention constrains the score layout to
shard the *query-sequence* axis over "model" (always divisible for the
assigned shapes) and batch over the DP axes — softmax stays local, the
replicated tensors disappear, and the downstream resharding collectives with
them.  A no-op outside the context, so baselines stay honest.
"""

from __future__ import annotations

from contextlib import contextmanager
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX: list[Any] = [None]


@contextmanager
def activation_sharding(mesh):
    _CTX[0] = mesh
    try:
        yield
    finally:
        _CTX[0] = None


def enabled() -> bool:
    return _CTX[0] is not None


def _dp(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def constrain_scores(x: jax.Array) -> jax.Array:
    """x: (B, H, Sq, Skv) attention scores/probs."""
    mesh = _CTX[0]
    if mesh is None or x.ndim != 4:
        return x
    dp = _dp(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    model = mesh.shape.get("model", 1)
    b, h, sq, skv = x.shape
    spec = [None, None, None, None]
    if dp and b % dp_size == 0:
        spec[0] = dp
    if h % model == 0 and h >= model:
        spec[1] = "model"            # heads divide: the natural layout
    elif sq % model == 0 and sq >= model:
        spec[2] = "model"            # heads don't: shard query positions
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def constrain_resid(x: jax.Array) -> jax.Array:
    """x: (B, S, d) residual-stream activations: batch over DP axes."""
    mesh = _CTX[0]
    if mesh is None or x.ndim != 3:
        return x
    dp = _dp(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    if not dp or x.shape[0] % dp_size != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp, None, None))
    )
