"""Whisper-style encoder-decoder backbone.

The modality frontend (mel-spectrogram + conv feature extractor) is a STUB:
``input_specs`` supplies precomputed frame embeddings of shape
(batch, encoder_seq, d_model) — the sanctioned carve-out (DESIGN.md §2).
The transformer backbone — bidirectional encoder, causal decoder with
cross-attention — is fully implemented, with learned absolute positions and
pre-LayerNorm blocks matching Whisper.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (
    embed,
    embedding_init,
    linear,
    mlp_apply,
    mlp_init,
    norm_apply,
    norm_init,
    unembed,
)

PyTree = Any


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def _act(cfg):
    return jnp.dtype(cfg.activation_dtype)


def _enc_block_init(key, cfg: ModelConfig) -> PyTree:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
        "attn": attn.gqa_init(k1, cfg, dt),
        "mlp_norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
        "mlp": mlp_init(k2, cfg.mlp_kind, cfg.d_model, cfg.d_ff, dt),
    }


def _dec_block_init(key, cfg: ModelConfig) -> PyTree:
    dt = _dtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
        "self_attn": attn.gqa_init(k1, cfg, dt),
        "cross_norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
        "cross_attn": attn.gqa_init(k2, cfg, dt),
        "mlp_norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
        "mlp": mlp_init(k3, cfg.mlp_kind, cfg.d_model, cfg.d_ff, dt),
    }


def encdec_init(key, cfg: ModelConfig) -> PyTree:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 6)
    n_enc = cfg.encoder_layers or cfg.num_layers
    enc_keys = jax.random.split(keys[0], n_enc)
    dec_keys = jax.random.split(keys[1], cfg.num_layers)
    return {
        "enc_pos": (jax.random.normal(keys[2], (cfg.encoder_seq, cfg.d_model)) * 0.01).astype(dt),
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg))(enc_keys),
        "enc_norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
        "embed": embedding_init(keys[3], cfg.vocab_size, cfg.d_model, dt),
        "dec_pos": (
            jax.random.normal(keys[4], (max(cfg.max_position_embeddings, 8), cfg.d_model))
            * 0.01
        ).astype(dt),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg))(dec_keys),
        "final_norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
    }


def _cross_attend(p, cfg, x, enc_k, enc_v):
    """Cross-attention: queries from decoder stream, K/V precomputed from the
    encoder output (cached at prefill)."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = linear(p["wq"], x).reshape(b, s, cfg.num_heads, hd)
    y = attn._sdpa(q, enc_k, enc_v, None)
    return linear(p["wo"], y.reshape(b, s, cfg.num_heads * hd))


def _enc_kv(p, cfg, enc_out):
    b, t, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = linear(p["wk"], enc_out).reshape(b, t, cfg.num_kv_heads, hd)
    v = linear(p["wv"], enc_out).reshape(b, t, cfg.num_kv_heads, hd)
    return k, v


def _scan(body, carry, xs, *, remat: bool = False, unroll: int = 1):
    if remat:
        body = jax.checkpoint(body)
    return jax.lax.scan(body, carry, xs, unroll=max(1, unroll))


def encode(params: PyTree, cfg: ModelConfig, frames: jax.Array,
           *, remat: bool = False, unroll: int = 1) -> jax.Array:
    """frames: (B, encoder_seq, d_model) stub embeddings -> encoder output."""
    x = frames.astype(_act(cfg)) + params["enc_pos"].astype(_act(cfg))[None]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(carry, p):
        h = norm_apply(cfg.norm_kind, p["attn_norm"], carry)
        y, _ = attn.gqa_full(p["attn"], cfg, h, positions, causal=False)
        x1 = carry + y
        h = norm_apply(cfg.norm_kind, p["mlp_norm"], x1)
        return x1 + mlp_apply(p["mlp"], cfg.mlp_kind, h), None

    x, _ = _scan(body, x, params["enc_blocks"], remat=remat, unroll=unroll)
    return norm_apply(cfg.norm_kind, params["enc_norm"], x)


def encdec_forward(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jax.Array,
    frames: jax.Array,
    *,
    window: int = 0,
    collect_cache: bool = False,
    remat: bool = False,
    unroll: int = 1,
) -> tuple[jax.Array, PyTree | None, jax.Array]:
    """Teacher-forced decoder over stub audio-frame embeddings."""
    enc_out = encode(params, cfg, frames, remat=remat, unroll=unroll)
    x = embed(params["embed"], tokens, _act(cfg))
    b, s, _ = x.shape
    # Learned absolute decoder positions (whisper-style), modulo the table
    # size so backbone-scale shapes beyond 448 positions still lower.
    table = params["dec_pos"].astype(x.dtype)
    pos_ids = jnp.arange(s, dtype=jnp.int32) % table.shape[0]
    x = x + jnp.take(table, pos_ids, axis=0)[None]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(carry, p):
        h = norm_apply(cfg.norm_kind, p["self_norm"], carry)
        y, (sk, sv) = attn.gqa_full(p["self_attn"], cfg, h, positions, window=window)
        x1 = carry + y
        h = norm_apply(cfg.norm_kind, p["cross_norm"], x1)
        ck, cv = _enc_kv(p["cross_attn"], cfg, enc_out)
        x1 = x1 + _cross_attend(p["cross_attn"], cfg, h, ck, cv)
        h = norm_apply(cfg.norm_kind, p["mlp_norm"], x1)
        out = x1 + mlp_apply(p["mlp"], cfg.mlp_kind, h)
        cache = {"self_k": sk, "self_v": sv, "cross_k": ck, "cross_v": cv}
        return out, cache if collect_cache else None

    x, caches = _scan(body, x, params["dec_blocks"], remat=remat, unroll=unroll)
    x = norm_apply(cfg.norm_kind, params["final_norm"], x)
    logits = unembed(params["embed"], x)  # whisper ties decoder embeddings
    return logits, caches, jnp.float32(0.0)


def encdec_decode_step(
    params: PyTree,
    cfg: ModelConfig,
    token: jax.Array,
    cache: PyTree,
    pos: jax.Array,
    *,
    window: int = 0,
    unroll: int = 1,
) -> tuple[jax.Array, PyTree]:
    """One decoder token against cached self-KV and encoder cross-KV."""
    x = embed(params["embed"], token, _act(cfg))
    table = params["dec_pos"].astype(x.dtype)
    x = x + jnp.take(table, (pos % table.shape[0])[None], axis=0)[None]

    def body(carry, inp):
        p, c = inp
        h = norm_apply(cfg.norm_kind, p["self_norm"], carry)
        y, (sk, sv) = attn.gqa_decode(
            p["self_attn"], cfg, h, c["self_k"], c["self_v"], pos, window=window
        )
        x1 = carry + y
        h = norm_apply(cfg.norm_kind, p["cross_norm"], x1)
        x1 = x1 + _cross_attend(p["cross_attn"], cfg, h, c["cross_k"], c["cross_v"])
        h = norm_apply(cfg.norm_kind, p["mlp_norm"], x1)
        out = x1 + mlp_apply(p["mlp"], cfg.mlp_kind, h)
        return out, {"self_k": sk, "self_v": sv, "cross_k": c["cross_k"], "cross_v": c["cross_v"]}

    x, new_cache = _scan(body, x, (params["dec_blocks"], cache), unroll=unroll)
    x = norm_apply(cfg.norm_kind, params["final_norm"], x)
    return unembed(params["embed"], x), new_cache


def encdec_cache_init(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> PyTree:
    hd = cfg.resolved_head_dim
    n = cfg.num_layers
    return {
        "self_k": jnp.zeros((n, batch, cache_len, cfg.num_kv_heads, hd), dtype),
        "self_v": jnp.zeros((n, batch, cache_len, cfg.num_kv_heads, hd), dtype),
        "cross_k": jnp.zeros((n, batch, cfg.encoder_seq, cfg.num_kv_heads, hd), dtype),
        "cross_v": jnp.zeros((n, batch, cfg.encoder_seq, cfg.num_kv_heads, hd), dtype),
    }
