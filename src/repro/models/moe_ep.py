"""Expert-parallel MoE via explicit ``shard_map`` (the §Perf optimization).

Why: under GSPMD auto-sharding, the sort-based dispatch scatter
(token-sharded source -> expert-sharded buffer) triggers "involuntary full
rematerialization": the (E·C, d) buffer is replicated to every device and
combined with all-reduces — 150 GB per MoE layer for deepseek-v3's train_4k,
8.8 TB of collective traffic per step per device (measured; EXPERIMENTS.md
§Perf).

Here the communication pattern is explicit instead:

- tokens stay sharded over the data axes and **replicated over "model"** —
  every model rank runs the (cheap) router + sort dispatch identically;
- each model rank computes ONLY its E/model_size experts (expert weights are
  sharded on the expert axis; under FSDP the d_model axis is all-gathered
  over "data", standard ZeRO);
- each rank combines its experts' outputs into a partial per-token sum, adds
  its tensor-parallel slice of the shared expert, and one ``psum("model")``
  completes the layer.

The only per-layer collectives are that psum (+ FSDP weight all-gathers):
~1 GB/layer for deepseek train_4k instead of ~150 GB.  Numerics match
``moe.moe_apply`` exactly (tests/test_moe_ep.py).
"""

from __future__ import annotations

from contextlib import contextmanager
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

# The jax shard_map symbol/kwarg churn is resolved once in core/compat.py.
from repro.core.compat import SHARD_MAP_NO_CHECK_KW as _SHARD_MAP_KW
from repro.core.compat import shard_map as _shard_map

from repro.configs.base import ModelConfig

PyTree = Any

_EP_MESH: list[Any] = [None]          # [mesh] or [None]
_EP_FSDP: list[bool] = [False]


@contextmanager
def expert_parallel(mesh, fsdp: bool = False):
    """Enable the shard_map EP path for ``moe_apply`` during tracing."""
    _EP_MESH[0] = mesh
    _EP_FSDP[0] = fsdp
    try:
        yield
    finally:
        _EP_MESH[0] = None
        _EP_FSDP[0] = False


def ep_enabled() -> bool:
    return _EP_MESH[0] is not None


def _dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _moe_param_specs(cfg: ModelConfig, fsdp: bool) -> PyTree:
    d_ax = "data" if fsdp else None
    specs: PyTree = {
        "router": {"w": P(None, None)},
        "experts": {
            "w_gate": P("model", d_ax, None),
            "w_up": P("model", d_ax, None),
            "w_down": P("model", None, d_ax),
        },
    }
    if cfg.num_shared_experts > 0:
        specs["shared"] = {
            "w_gate": {"w": P(None, "model")},
            "w_up": {"w": P(None, "model")},
            "w_down": {"w": P("model", None)},
        }
    return specs


def moe_apply_ep(params: PyTree, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Drop-in replacement for ``moe.moe_apply`` under a mesh context."""
    mesh = _EP_MESH[0]
    fsdp = _EP_FSDP[0]
    dp = _dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    model_size = mesh.shape["model"]
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    assert e % model_size == 0, (e, model_size)
    e_loc = e // model_size

    batch_shardable = dp and x.shape[0] % dp_size == 0
    x_spec = P(dp, None, None) if batch_shardable else P(None, None, None)
    p_specs = _moe_param_specs(cfg, fsdp)

    def body(p, x_loc):
        b_loc, s, d = x_loc.shape
        t = b_loc * s
        cap = max(int(t * k * cfg.capacity_factor) // e, 1)
        xf = x_loc.reshape(t, d)

        # --- routing (identical on every model rank; tokens replicated) ----
        logits = (xf @ p["router"]["w"].astype(xf.dtype)).astype(jnp.float32)
        if cfg.router_score == "sigmoid":
            scores = jax.nn.sigmoid(logits)
        else:
            scores = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(scores, k)
        gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

        probs_mean = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=0)
        counts = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0)
        frac = counts / (t * k)
        aux = e * jnp.sum(frac * probs_mean) * cfg.aux_loss_weight
        if dp:
            aux = jax.lax.pmean(aux, dp)

        # --- local sort-based dispatch (no cross-device movement) ----------
        flat_expert = expert_idx.reshape(-1)
        order = jnp.argsort(flat_expert, stable=True)
        sorted_expert = flat_expert[order]
        cnt = jnp.bincount(flat_expert, length=e)
        start = jnp.cumsum(cnt) - cnt
        rank_sorted = jnp.arange(t * k) - start[sorted_expert]
        slot_sorted = jnp.where(
            rank_sorted < cap, sorted_expert * cap + rank_sorted, e * cap
        )
        slots = jnp.zeros((t * k,), jnp.int32).at[order].set(slot_sorted.astype(jnp.int32))
        token_idx = jnp.repeat(jnp.arange(t), k)
        buf = jnp.zeros((e * cap + 1, d), dtype=x_loc.dtype)
        buf = buf.at[slots].set(xf[token_idx])

        # --- my experts only ------------------------------------------------
        ridx = jax.lax.axis_index("model")
        my0 = ridx * e_loc * cap
        buf_my = jax.lax.dynamic_slice_in_dim(buf, my0, e_loc * cap, axis=0)
        expert_in = buf_my.reshape(e_loc, cap, d)

        ew = p["experts"]
        w_gate, w_up, w_down = ew["w_gate"], ew["w_up"], ew["w_down"]
        if fsdp:
            w_gate = jax.lax.all_gather(w_gate, "data", axis=1, tiled=True)
            w_up = jax.lax.all_gather(w_up, "data", axis=1, tiled=True)
            w_down = jax.lax.all_gather(w_down, "data", axis=2, tiled=True)
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, w_gate.astype(x_loc.dtype)))
        u = jnp.einsum("ecd,edf->ecf", expert_in, w_up.astype(x_loc.dtype))
        out_my = jnp.einsum("ecf,efd->ecd", g * u, w_down.astype(x_loc.dtype))
        out_flat = out_my.reshape(e_loc * cap, d)

        # --- local combine of my experts' slots ----------------------------
        in_mine = (slots >= my0) & (slots < my0 + e_loc * cap)
        local_idx = jnp.clip(slots - my0, 0, e_loc * cap - 1)
        vals = out_flat[local_idx] * in_mine[:, None].astype(x_loc.dtype)
        weighted = vals * gate_vals.reshape(-1)[:, None].astype(x_loc.dtype)
        y_partial = jnp.zeros((t, d), x_loc.dtype).at[token_idx].add(weighted)

        # --- shared expert: tensor-parallel slice + same psum ---------------
        if cfg.num_shared_experts > 0:
            sh = p["shared"]
            gs = jax.nn.silu(xf @ sh["w_gate"]["w"].astype(xf.dtype))
            us = xf @ sh["w_up"]["w"].astype(xf.dtype)
            y_partial = y_partial + (gs * us) @ sh["w_down"]["w"].astype(xf.dtype)

        y = jax.lax.psum(y_partial, "model")
        return y.reshape(b_loc, s, d), aux

    sm = _shard_map(
        body,
        mesh=mesh,
        in_specs=(p_specs, x_spec),
        out_specs=(x_spec, P()),
        **_SHARD_MAP_KW,
    )
    return sm(params, x)
