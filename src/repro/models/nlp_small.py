"""The paper's language-modality model (Appendix A, Fig. 5): a small
transformer *classifier* — AGNews / SogouNews are 4/5-way classification
tasks.  Embedding + learned positions, N pre-LN encoder blocks, mean-pool,
linear classifier head.

Parameters are unstacked (``blocks/{i}/...``) so FedPart partitions per
block: #1 = embedding(+positions), #2..#N+1 = blocks, #last = classifier.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (
    embed,
    embedding_init,
    linear,
    linear_init,
    mlp_apply,
    mlp_init,
    norm_apply,
    norm_init,
)

PyTree = Any


def nlp_init(key, cfg: ModelConfig, num_classes: int) -> PyTree:
    dt = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, cfg.num_layers + 3)
    params: PyTree = {
        "embed": {
            **embedding_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
            "pos": (
                jax.random.normal(keys[1], (cfg.max_position_embeddings, cfg.d_model)) * 0.01
            ).astype(dt),
        },
        "blocks": {},
        "head": {
            "norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
            "fc": linear_init(keys[2], cfg.d_model, num_classes, dt, bias=True),
        },
    }
    for i in range(cfg.num_layers):
        k1, k2 = jax.random.split(keys[3 + i])
        params["blocks"][str(i)] = {
            "attn_norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
            "attn": attn.gqa_init(k1, cfg, dt),
            "mlp_norm": norm_init(cfg.norm_kind, cfg.d_model, dt),
            "mlp": mlp_init(k2, cfg.mlp_kind, cfg.d_model, cfg.d_ff, dt),
        }
    return params


def nlp_apply(params: PyTree, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    """tokens: (B, S) -> class logits (B, num_classes)."""
    b, s = tokens.shape
    x = embed(params["embed"], tokens)
    x = x + params["embed"]["pos"][None, :s, :].astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    for i in range(cfg.num_layers):
        p = params["blocks"][str(i)]
        h = norm_apply(cfg.norm_kind, p["attn_norm"], x)
        y, _ = attn.gqa_full(p["attn"], cfg, h, positions, causal=False)
        x = x + y
        h = norm_apply(cfg.norm_kind, p["mlp_norm"], x)
        x = x + mlp_apply(p["mlp"], cfg.mlp_kind, h)
    x = norm_apply(cfg.norm_kind, params["head"]["norm"], jnp.mean(x, axis=1))
    return linear(params["head"]["fc"], x)


def nlp_group_key(path: tuple[str, ...]) -> tuple:
    if path[0] == "embed":
        return ("embed",)
    if path[0] == "head":
        return ("head",)
    return ("block", "blocks", int(path[1]))
