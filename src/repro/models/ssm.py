"""State-space and recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM / sLSTM).

A single chunked decay-weighted linear-attention engine
(``chunked_decay_attention``) implements the shared recurrence

    S_t = a_t * S_{t-1} + k_t ⊗ v_t          y_t = q_t · S_t

in chunk-parallel form: heavy matmuls live *outside* the chunk scan (so the
compiled HLO's FLOPs are visible to ``cost_analysis`` instead of hidden in a
loop body), and only the O(H·N·P) state crosses chunk boundaries.  Mamba2
(a = exp(-Δ·exp(A_log)), k = B, q = C, v = Δ·x) and mLSTM (a = σ(f), k, q
from projections, v scaled by the input gate) both lower onto it.

sLSTM keeps its true sequential recurrence (h_{t-1} feeds the gates) and runs
as a ``lax.scan`` over time with the standard m-stabiliser.

TPU note (DESIGN.md §6): xLSTM's exponential input gate is stabilised here by
clipping the exponent rather than the per-step max-stabiliser state of the
original CUDA implementation — the stabiliser's per-position rescaling has no
chunk-parallel form, and the clipped gate keeps the chunked forward exactly
consistent with the recurrent decode (asserted in tests).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import linear, linear_init, rmsnorm, rmsnorm_init

PyTree = Any


# ---------------------------------------------------------------------------
# Generic chunked decay attention
# ---------------------------------------------------------------------------

def chunked_decay_attention(
    q: jax.Array,        # (B, S, H, N)
    k: jax.Array,        # (B, S, H, N)
    v: jax.Array,        # (B, S, H, P)
    log_a: jax.Array,    # (B, S, H) — per-step decay logs, <= 0
    chunk: int,
    init_state: jax.Array | None = None,   # (B, H, N, P)
) -> tuple[jax.Array, jax.Array]:
    """Returns (y: (B,S,H,P), final_state: (B,H,N,P))."""
    b, s, h, n = q.shape
    p = v.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    nc = s // chunk

    def to_chunks(x):
        return x.reshape(b, nc, chunk, *x.shape[2:])

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    la = to_chunks(log_a).astype(jnp.float32)                 # (b,nc,Q,h)
    cum = jnp.cumsum(la, axis=2)                              # inclusive
    total = cum[:, :, -1:, :]                                 # (b,nc,1,h)

    # Intra-chunk: scores[i,j] = (q_i·k_j)·exp(cum_i − cum_j), causal i>=j.
    qk = jnp.einsum("bcqhn,bcthn->bcqth", qc, kc)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])   # (b,c,q,t,h)
    causal = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    w = qk.astype(jnp.float32) * decay * causal[None, None, :, :, None]
    y_intra = jnp.einsum("bcqth,bcthp->bcqhp", w.astype(v.dtype), vc)

    # Per-chunk state contribution: S_c = Σ_t exp(total − cum_t)·k_t ⊗ v_t
    kw = kc.astype(jnp.float32) * jnp.exp(total - cum)[..., None]
    s_c = jnp.einsum("bcthn,bcthp->bchnp", kw.astype(v.dtype), vc)

    # Inter-chunk recurrence over nc (only the state crosses the scan).
    a_tot = jnp.exp(total[:, :, 0, :])                        # (b,nc,h)
    s0 = (
        init_state
        if init_state is not None
        else jnp.zeros((b, h, n, p), v.dtype)
    )

    def step(carry, inp):
        a_c, s_chunk = inp                                    # (b,h), (b,h,n,p)
        prev = carry
        new = a_c[..., None, None].astype(carry.dtype) * carry + s_chunk
        return new, prev

    final, s_prev = jax.lax.scan(
        step,
        s0,
        (jnp.moveaxis(a_tot, 1, 0), jnp.moveaxis(s_c, 1, 0)),
    )
    s_prev = jnp.moveaxis(s_prev, 0, 1)                       # (b,nc,h,n,p)

    y_inter = jnp.einsum(
        "bcqhn,bchnp,bcqh->bcqhp",
        qc,
        s_prev,
        jnp.exp(cum).astype(v.dtype),
    )
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, final


def decay_attention_step(
    q: jax.Array,        # (B, H, N)
    k: jax.Array,        # (B, H, N)
    v: jax.Array,        # (B, H, P)
    a: jax.Array,        # (B, H) decay in (0,1]
    state: jax.Array,    # (B, H, N, P)
) -> tuple[jax.Array, jax.Array]:
    """Single-token recurrence (decode): O(H·N·P) per step."""
    new_state = a[..., None, None] * state + k[..., :, None] * v[..., None, :]
    y = jnp.einsum("bhn,bhnp->bhp", q, new_state)
    return y, new_state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def _mamba_dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    hdim = cfg.ssm_head_dim or 64
    heads = cfg.ssm_num_heads or d_inner // hdim
    n = cfg.ssm_state_dim or 64
    return d_inner, heads, hdim, n


CONV_W = 4  # causal depthwise conv width


def mamba2_init(key, cfg: ModelConfig, dtype) -> PyTree:
    d_inner, heads, hdim, n = _mamba_dims(cfg)
    conv_dim = d_inner + 2 * n
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_inner + 2 * n + heads   # z, x, B, C, dt
    return {
        "in_proj": linear_init(ks[0], cfg.d_model, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (CONV_W, conv_dim)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.zeros((heads,), jnp.float32),            # A = -exp(a_log) = -1
        "dt_bias": jnp.full((heads,), -2.0, jnp.float32),     # softplus ≈ 0.12
        "d_skip": jnp.ones((heads,), dtype),
        "out_norm": rmsnorm_init(d_inner, dtype),
        "out_proj": linear_init(ks[2], d_inner, cfg.d_model, dtype),
    }


def _causal_conv(seq: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv.  seq: (B,S,C); w: (W,C)."""
    width = w.shape[0]
    pad = jnp.pad(seq, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(seq)
    for i in range(width):
        out = out + pad[:, i : i + seq.shape[1], :] * w[i]
    return out + b


def _mamba_heads(cfg, xbc, d_inner, heads, hdim, n):
    xs = xbc[..., :d_inner]
    bmat = xbc[..., d_inner : d_inner + n]
    cmat = xbc[..., d_inner + n :]
    return xs, bmat, cmat


def mamba2_forward(
    params: PyTree, cfg: ModelConfig, x: jax.Array
) -> tuple[jax.Array, PyTree]:
    """Full-sequence Mamba2.  Returns (y, cache) where cache holds the final
    SSM state and conv tail (for chunked prefill continuation)."""
    b, s, _ = x.shape
    d_inner, heads, hdim, n = _mamba_dims(cfg)
    zxbcdt = linear(params["in_proj"], x)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : d_inner + d_inner + 2 * n]
    dt_raw = zxbcdt[..., -heads:]

    xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype)))
    xs, bmat, cmat = _mamba_heads(cfg, xbc, d_inner, heads, hdim, n)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])   # (b,s,h)
    a = -jnp.exp(params["a_log"])                                          # (h,)
    log_decay = dt * a                                                     # <= 0
    xh = xs.reshape(b, s, heads, hdim)
    v = xh * dt[..., None].astype(x.dtype)
    k = jnp.broadcast_to(bmat[:, :, None, :], (b, s, heads, n))
    q = jnp.broadcast_to(cmat[:, :, None, :], (b, s, heads, n))

    y, state = chunked_decay_attention(q, k, v, log_decay, cfg.ssm_chunk)
    y = y + xh * params["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_inner)
    y = rmsnorm(params["out_norm"], y * jax.nn.silu(z))
    y = linear(params["out_proj"], y)
    cache = {
        "state": state,                                   # (b,h,n,p)
        "conv": jnp.pad(
            zxbcdt[..., d_inner : d_inner + d_inner + 2 * n],
            ((0, 0), (CONV_W - 1, 0), (0, 0)),
        )[:, -(CONV_W - 1) :, :],                         # last W-1 pre-conv inputs
    }
    return y, cache


def mamba2_decode(
    params: PyTree, cfg: ModelConfig, x: jax.Array, cache: PyTree
) -> tuple[jax.Array, PyTree]:
    """One-token step.  x: (B,1,d); cache: {state (b,h,n,p), conv (b,W-1,c)}."""
    b = x.shape[0]
    d_inner, heads, hdim, n = _mamba_dims(cfg)
    zxbcdt = linear(params["in_proj"], x)
    z = zxbcdt[..., :d_inner]
    xbc_new = zxbcdt[:, 0, d_inner : d_inner + d_inner + 2 * n]            # (b,c)
    dt_raw = zxbcdt[..., -heads:]

    conv_in = jnp.concatenate([cache["conv"], xbc_new[:, None, :]], axis=1)  # (b,W,c)
    w = params["conv_w"].astype(x.dtype)
    xbc = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", conv_in, w) + params["conv_b"].astype(x.dtype)
    )
    xs, bmat, cmat = _mamba_heads(cfg, xbc, d_inner, heads, hdim, n)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])  # (b,h)
    a = jnp.exp(dt * -jnp.exp(params["a_log"]))
    xh = xs.reshape(b, heads, hdim)
    v = xh * dt[..., None].astype(x.dtype)
    k = jnp.broadcast_to(bmat[:, None, :], (b, heads, n))
    q = jnp.broadcast_to(cmat[:, None, :], (b, heads, n))
    y, state = decay_attention_step(q, k, v, a.astype(x.dtype), cache["state"])
    y = y + xh * params["d_skip"].astype(x.dtype)[None, :, None]
    y = y.reshape(b, 1, d_inner)
    y = rmsnorm(params["out_norm"], y * jax.nn.silu(z))
    y = linear(params["out_proj"], y)
    return y, {"state": state, "conv": conv_in[:, 1:, :]}


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM)
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig, dtype) -> PyTree:
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    hdim = cfg.ssm_head_dim or 64
    heads = d_inner // hdim
    ks = jax.random.split(key, 7)
    return {
        "up_proj": linear_init(ks[0], d, 2 * d_inner, dtype),   # x-branch, z-gate
        "wq": linear_init(ks[1], d_inner, d_inner, dtype),
        "wk": linear_init(ks[2], d_inner, d_inner, dtype),
        "wv": linear_init(ks[3], d_inner, d_inner, dtype),
        "w_i": linear_init(ks[4], d_inner, heads, dtype, bias=True),
        "w_f": linear_init(ks[5], d_inner, heads, dtype, bias=True),
        "out_norm": rmsnorm_init(d_inner, dtype),
        "down_proj": linear_init(ks[6], d_inner, d, dtype),
    }


def _mlstm_qkv(params, cfg, xb):
    d_inner = xb.shape[-1]
    hdim = cfg.ssm_head_dim or 64
    heads = d_inner // hdim
    shp = (*xb.shape[:-1], heads, hdim)
    q = linear(params["wq"], xb).reshape(shp)
    k = linear(params["wk"], xb).reshape(shp) / jnp.sqrt(jnp.float32(hdim)).astype(xb.dtype)
    v = linear(params["wv"], xb).reshape(shp)
    i_raw = linear(params["w_i"], xb).astype(jnp.float32)
    f_raw = linear(params["w_f"], xb).astype(jnp.float32)
    return q, k, v, i_raw, f_raw, heads, hdim


def mlstm_forward(
    params: PyTree, cfg: ModelConfig, x: jax.Array
) -> tuple[jax.Array, PyTree]:
    b, s, d = x.shape
    up = linear(params["up_proj"], x)
    d_inner = up.shape[-1] // 2
    xb, z = up[..., :d_inner], up[..., d_inner:]
    q, k, v, i_raw, f_raw, heads, hdim = _mlstm_qkv(params, cfg, xb)
    # Exponential input gate, clipped for stability.  The original CUDA
    # implementation keeps a per-step max-stabiliser state m_t; that form is
    # causal but not expressible in chunk-parallel linear attention without
    # per-position rescaling, so we clip the exponent instead — exactly
    # consistent between the chunked forward and the recurrent decode
    # (DESIGN.md §6; asserted by tests/test_decode_consistency.py).
    i_gate = jnp.exp(jnp.minimum(i_raw, 8.0))                  # (b,s,h)
    log_f = jax.nn.log_sigmoid(f_raw)                          # <= 0
    v_scaled = v * i_gate[..., None].astype(v.dtype)
    y, state = chunked_decay_attention(q, k, v_scaled, log_f, cfg.ssm_chunk)
    # Normaliser: same recurrence with v ≡ 1  ->  n_t·q_t
    ones = jnp.ones((b, s, heads, 1), v.dtype) * i_gate[..., None].astype(v.dtype)
    norm, n_state = chunked_decay_attention(q, k, ones, log_f, cfg.ssm_chunk)
    denom = jnp.maximum(jnp.abs(norm[..., 0]), 1.0)[..., None]
    h = (y / denom).reshape(b, s, d_inner)
    h = rmsnorm(params["out_norm"], h) * jax.nn.silu(z)
    out = linear(params["down_proj"], h)
    cache = {"state": state, "n_state": n_state}
    return out, cache


def mlstm_decode(
    params: PyTree, cfg: ModelConfig, x: jax.Array, cache: PyTree
) -> tuple[jax.Array, PyTree]:
    b = x.shape[0]
    up = linear(params["up_proj"], x)
    d_inner = up.shape[-1] // 2
    xb, z = up[:, 0, :d_inner], up[:, 0, d_inner:]
    q, k, v, i_raw, f_raw, heads, hdim = _mlstm_qkv(params, cfg, xb)
    i_gate = jnp.exp(jnp.minimum(i_raw, 8.0))
    f_gate = jax.nn.sigmoid(f_raw)
    v_scaled = v * i_gate[..., None].astype(v.dtype)
    y, state = decay_attention_step(
        q, k, v_scaled, f_gate.astype(v.dtype), cache["state"]
    )
    ones = jnp.ones((b, heads, 1), v.dtype) * i_gate[..., None].astype(v.dtype)
    norm, n_state = decay_attention_step(
        q, k, ones, f_gate.astype(v.dtype), cache["n_state"]
    )
    denom = jnp.maximum(jnp.abs(norm[..., 0]), 1.0)[..., None]
    h = (y / denom).reshape(b, 1, d_inner)
    h = rmsnorm(params["out_norm"], h) * jax.nn.silu(z)[:, None, :]
    out = linear(params["down_proj"], h)
    return out, {"state": state, "n_state": n_state}


def mlstm_cache_init(cfg: ModelConfig, batch: int, dtype) -> PyTree:
    d_inner = cfg.ssm_expand * cfg.d_model
    hdim = cfg.ssm_head_dim or 64
    heads = d_inner // hdim
    return {
        "state": jnp.zeros((batch, heads, hdim, hdim), dtype),
        "n_state": jnp.zeros((batch, heads, hdim, 1), dtype),
    }


def mamba2_cache_init(cfg: ModelConfig, batch: int, dtype) -> PyTree:
    d_inner, heads, hdim, n = _mamba_dims(cfg)
    return {
        "state": jnp.zeros((batch, heads, n, hdim), dtype),
        "conv": jnp.zeros((batch, CONV_W - 1, d_inner + 2 * n), dtype),
    }


# ---------------------------------------------------------------------------
# sLSTM block (true recurrence, lax.scan over time)
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig, dtype) -> PyTree:
    d = cfg.d_model
    hdim = cfg.ssm_head_dim or 64
    heads = d // hdim
    ks = jax.random.split(key, 3)
    # 4 gates (z, i, f, o), input + block-diagonal recurrent weights per head
    return {
        "w_x": linear_init(ks[0], d, 4 * d, dtype, bias=True),
        "r_h": (jax.random.normal(ks[1], (heads, hdim, 4 * hdim)) * (1.0 / jnp.sqrt(jnp.float32(hdim)))).astype(dtype),
        "out_norm": rmsnorm_init(d, dtype),
        "out_proj": linear_init(ks[2], d, d, dtype),
    }


def slstm_forward(
    params: PyTree, cfg: ModelConfig, x: jax.Array, init: PyTree | None = None
) -> tuple[jax.Array, PyTree]:
    """Sequential sLSTM with exp-gating m-stabiliser.  x: (B,S,d)."""
    b, s, d = x.shape
    hdim = cfg.ssm_head_dim or 64
    heads = d // hdim
    gx = linear(params["w_x"], x)                              # (b,s,4d)
    state = init if init is not None else slstm_cache_init_shapes(b, heads, hdim, x.dtype)

    r_h = params["r_h"].astype(x.dtype)

    def step(carry, g_t):
        c, n, h, m = carry                                     # (b,heads,hdim)...
        rec = jnp.einsum("bhp,hpq->bhq", h, r_h)               # (b,heads,4*hdim)
        g = g_t.reshape(b, heads, 4, hdim) + rec.reshape(b, heads, 4, hdim)
        z_t = jnp.tanh(g[:, :, 0])
        i_t = g[:, :, 1].astype(jnp.float32)
        f_t = g[:, :, 2].astype(jnp.float32)
        o_t = jax.nn.sigmoid(g[:, :, 3])
        log_f = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(log_f + m, i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(log_f + m - m_new)
        c_new = f_p.astype(x.dtype) * c + i_p.astype(x.dtype) * z_t
        n_new = f_p.astype(x.dtype) * n + i_p.astype(x.dtype)
        h_new = o_t * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    (c, n, h, m), hs = jax.lax.scan(step, state, jnp.moveaxis(gx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d)
    y = linear(params["out_proj"], rmsnorm(params["out_norm"], y))
    return y, (c, n, h, m)


def slstm_decode(
    params: PyTree, cfg: ModelConfig, x: jax.Array, cache: PyTree
) -> tuple[jax.Array, PyTree]:
    y, new = slstm_forward(params, cfg, x, init=cache)
    return y, new


def slstm_cache_init_shapes(b, heads, hdim, dtype):
    z = jnp.zeros((b, heads, hdim), dtype)
    m = jnp.full((b, heads, hdim), -30.0, jnp.float32)
    return (z, z, z, m)


def slstm_cache_init(cfg: ModelConfig, batch: int, dtype) -> PyTree:
    d = cfg.d_model
    hdim = cfg.ssm_head_dim or 64
    heads = d // hdim
    return slstm_cache_init_shapes(batch, heads, hdim, dtype)
