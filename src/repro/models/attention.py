"""Attention variants: GQA/MQA (full-causal and sliding-window) and
DeepSeek-style MLA (multi-head latent attention) with a compressed KV cache.

Two entry points per variant:

* ``*_full``   — whole-sequence forward (training / prefill).  Returns the
  output and the KV-cache tensors for the sequence (prefill writes them).
* ``*_decode`` — one-token step against an existing cache (serve_step).
  Sliding-window caches are ring buffers: RoPE is applied at *write* time
  with absolute positions so slot order is irrelevant to the attention math.

The default math path is pure jnp (XLA fusions); the Pallas flash-attention
kernel in ``repro.kernels.flash_attention`` is selected via ``impl='pallas'``
where supported (TPU; interpret mode in tests).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import act_sharding
from repro.models.layers import apply_rope, linear, linear_init, rmsnorm, rmsnorm_init, rope_angles

PyTree = Any

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig, dtype) -> PyTree:
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": linear_init(k1, cfg.d_model, cfg.num_heads * hd, dtype),
        "wk": linear_init(k2, cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wv": linear_init(k3, cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wo": linear_init(k4, cfg.num_heads * hd, cfg.d_model, dtype),
    }


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    return x.reshape(*x.shape[:-1], n, hd)


def _sdpa(q, k, v, mask, impl: str = "xla"):
    """q: (B,S,H,D); k/v: (B,T,Hkv,D); mask: (B,S,T) or (S,T) bool."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    if impl == "pallas" and s > 1:
        from repro.kernels.flash_attention import ops as fa_ops

        return fa_ops.flash_attention(q, k, v, mask=mask)
    kr = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vr = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    logits = jnp.einsum("bshd,bthd->bhst", q, kr).astype(jnp.float32) * scale
    logits = act_sharding.constrain_scores(logits)
    if mask is not None:
        m = mask if mask.ndim == 3 else mask[None]
        logits = jnp.where(m[:, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    probs = act_sharding.constrain_scores(probs)
    return jnp.einsum("bhst,bthd->bshd", probs, vr)


def causal_mask(s: int, window: int = 0) -> jax.Array:
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    m = j <= i
    if window > 0:
        m = m & (j > i - window)
    return m


def gqa_full(
    params: PyTree,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    window: int = 0,
    causal: bool = True,
    impl: str = "xla",
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Full-sequence attention.  Returns (y, (k_cache, v_cache))."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = _split_heads(linear(params["wq"], x), cfg.num_heads, hd)
    k = _split_heads(linear(params["wk"], x), cfg.num_kv_heads, hd)
    v = _split_heads(linear(params["wv"], x), cfg.num_kv_heads, hd)
    if cfg.use_rope:
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    mask = causal_mask(s, window) if causal else None
    y = _sdpa(q, k, v, mask, impl=impl)
    y = linear(params["wo"], y.reshape(b, s, cfg.num_heads * hd))
    return y, (k, v)


def gqa_decode(
    params: PyTree,
    cfg: ModelConfig,
    x: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    window: int = 0,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """One-token decode.  x: (B,1,d); caches: (B,W,Hkv,D); pos: scalar int32.

    ``window == 0`` means the cache length equals the full context and slot
    index == absolute position.  ``window > 0`` means a ring buffer of W slots.
    """
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    cache_len = k_cache.shape[1]
    q = _split_heads(linear(params["wq"], x), cfg.num_heads, hd)
    k = _split_heads(linear(params["wk"], x), cfg.num_kv_heads, hd)
    v = _split_heads(linear(params["wv"], x), cfg.num_kv_heads, hd)
    if cfg.use_rope:
        posv = jnp.full((b, 1), pos, dtype=jnp.int32)
        cos, sin = rope_angles(posv, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    slot = jnp.where(window > 0, pos % cache_len, pos)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, slot, 0, 0))
    # Valid slots: all once pos+1 >= cache_len, else indices <= pos.
    idx = jnp.arange(cache_len)
    valid = jnp.where(pos + 1 >= cache_len, jnp.ones((cache_len,), bool), idx <= pos)
    mask = jnp.broadcast_to(valid[None, None, :], (b, 1, cache_len))
    y = _sdpa(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype), mask)
    y = linear(params["wo"], y.reshape(b, 1, cfg.num_heads * hd))
    return y, (k_cache, v_cache)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig, dtype) -> PyTree:
    keys = jax.random.split(key, 6)
    h = cfg.num_heads
    qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    p: PyTree = {}
    if cfg.q_lora_rank > 0:
        p["wq_a"] = linear_init(keys[0], cfg.d_model, cfg.q_lora_rank, dtype)
        p["q_norm"] = rmsnorm_init(cfg.q_lora_rank, dtype)
        p["wq_b"] = linear_init(keys[1], cfg.q_lora_rank, h * qk_dim, dtype)
    else:
        p["wq"] = linear_init(keys[0], cfg.d_model, h * qk_dim, dtype)
    p["wkv_a"] = linear_init(keys[2], cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_head_dim, dtype)
    p["kv_norm"] = rmsnorm_init(cfg.kv_lora_rank, dtype)
    p["wkv_b"] = linear_init(
        keys[3], cfg.kv_lora_rank, h * (cfg.qk_nope_head_dim + cfg.v_head_dim), dtype
    )
    p["wo"] = linear_init(keys[4], h * cfg.v_head_dim, cfg.d_model, dtype)
    return p


def _mla_q(params: PyTree, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = cfg.num_heads
    qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    if cfg.q_lora_rank > 0:
        q = linear(params["wq_b"], rmsnorm(params["q_norm"], linear(params["wq_a"], x)))
    else:
        q = linear(params["wq"], x)
    return q.reshape(*x.shape[:-1], h, qk_dim)


def _mla_scores_and_out(params, cfg, q, c_kv, k_rope, mask):
    """q: (B,S,H,qk); c_kv: (B,T,rank); k_rope: (B,T,rope) — shared across heads."""
    h = cfg.num_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    kv = linear(params["wkv_b"], rmsnorm(params["kv_norm"], c_kv))
    kv = kv.reshape(*c_kv.shape[:-1], h, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    scale = 1.0 / jnp.sqrt(jnp.float32(nope + rope_d))
    logits = (
        jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
        + jnp.einsum("bshd,btd->bhst", q_rope, k_rope)
    ).astype(jnp.float32) * scale
    logits = act_sharding.constrain_scores(logits)
    if mask is not None:
        m = mask if mask.ndim == 3 else mask[None]
        logits = jnp.where(m[:, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    probs = act_sharding.constrain_scores(probs)
    y = jnp.einsum("bhst,bthd->bshd", probs, v)
    return linear(params["wo"], y.reshape(*q.shape[:2], h * vd))


def mla_full(
    params: PyTree,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    window: int = 0,
    impl: str = "xla",
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Returns (y, (c_kv_cache, k_rope_cache)) — the compressed MLA cache."""
    b, s, _ = x.shape
    q = _mla_q(params, cfg, x)
    ckr = linear(params["wkv_a"], x)
    c_kv, k_rope_raw = ckr[..., : cfg.kv_lora_rank], ckr[..., cfg.kv_lora_rank :]
    cos, sin = rope_angles(positions, cfg.qk_rope_head_dim, cfg.rope_theta)
    nope = cfg.qk_nope_head_dim
    q_rope = apply_rope(q[..., nope:], cos, sin)
    q = jnp.concatenate([q[..., :nope], q_rope], axis=-1)
    k_rope = apply_rope(k_rope_raw[..., None, :], cos, sin)[..., 0, :]
    mask = causal_mask(s, window)
    y = _mla_scores_and_out(params, cfg, q, c_kv, k_rope, mask)
    return y, (c_kv, k_rope)


def mla_decode(
    params: PyTree,
    cfg: ModelConfig,
    x: jax.Array,
    c_cache: jax.Array,
    r_cache: jax.Array,
    pos: jax.Array,
    *,
    window: int = 0,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """One-token MLA decode against the latent cache.

    c_cache: (B,W,kv_rank); r_cache: (B,W,rope_dim).  The latent cache costs
    (kv_rank + rope_dim) ≈ 576 bytes·dtype per token per layer — this is what
    makes the 500k-context decode shape feasible for deepseek-v3 (DESIGN §4).
    """
    b = x.shape[0]
    cache_len = c_cache.shape[1]
    q = _mla_q(params, cfg, x)
    ckr = linear(params["wkv_a"], x)
    c_kv, k_rope_raw = ckr[..., : cfg.kv_lora_rank], ckr[..., cfg.kv_lora_rank :]
    posv = jnp.full((b, 1), pos, dtype=jnp.int32)
    cos, sin = rope_angles(posv, cfg.qk_rope_head_dim, cfg.rope_theta)
    nope = cfg.qk_nope_head_dim
    q = jnp.concatenate([q[..., :nope], apply_rope(q[..., nope:], cos, sin)], axis=-1)
    k_rope = apply_rope(k_rope_raw[..., None, :], cos, sin)[..., 0, :]
    slot = jnp.where(window > 0, pos % cache_len, pos)
    c_cache = jax.lax.dynamic_update_slice(c_cache, c_kv.astype(c_cache.dtype), (0, slot, 0))
    r_cache = jax.lax.dynamic_update_slice(r_cache, k_rope.astype(r_cache.dtype), (0, slot, 0))
    idx = jnp.arange(cache_len)
    valid = jnp.where(pos + 1 >= cache_len, jnp.ones((cache_len,), bool), idx <= pos)
    mask = jnp.broadcast_to(valid[None, None, :], (b, 1, cache_len))
    y = _mla_scores_and_out(
        params, cfg, q, c_cache.astype(x.dtype), r_cache.astype(x.dtype), mask
    )
    return y, (c_cache, r_cache)
