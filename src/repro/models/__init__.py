"""Model zoo: building blocks + assembled architectures.

Public entry point is ``repro.models.api`` (init / forward / loss /
decode_step / cache_init / input_specs) which dispatches on
``ModelConfig.kind``.
"""

from repro.models import api  # noqa: F401
