"""Stub modality frontends (the sanctioned carve-out, DESIGN.md §2).

``[audio]`` and ``[vlm]`` architectures specify the transformer backbone
only; the mel-spectrogram/conv feature extractor (whisper) and ViT/SigLIP
vision tower + projector (llava) are represented by *precomputed embedding
inputs* of the correct shape.  This module centralises those shapes:
ShapeDtypeStructs for the dry-run, and synthetic embedding generators for
CPU smoke tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def frame_embeds_spec(cfg: ModelConfig, batch: int) -> jax.ShapeDtypeStruct:
    """Whisper stub: conv-frontend output frames (B, encoder_seq, d_model)."""
    return jax.ShapeDtypeStruct(
        (batch, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.activation_dtype)
    )


def media_embeds_spec(cfg: ModelConfig, batch: int) -> jax.ShapeDtypeStruct:
    """VLM stub: projected vision-tower patch embeddings (B, n_media, d)."""
    return jax.ShapeDtypeStruct(
        (batch, cfg.num_media_tokens, cfg.d_model), jnp.dtype(cfg.activation_dtype)
    )


def synth_frame_embeds(key, cfg: ModelConfig, batch: int) -> jax.Array:
    return jax.random.normal(
        key, (batch, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.activation_dtype)
    )


def synth_media_embeds(key, cfg: ModelConfig, batch: int) -> jax.Array:
    return jax.random.normal(
        key, (batch, cfg.num_media_tokens, cfg.d_model), jnp.dtype(cfg.activation_dtype)
    )
