"""ResNet-8 / ResNet-18 (paper Appendix A) for the faithful FL reproduction.

Parameters are *unstacked* (one subtree per conv layer) so FedPart's
Appendix-A partitioning applies literally: groups #1..#9 are conv+BN pairs,
#10 is the FC classifier (ResNet-8); ResNet-18 follows the same scheme with
17 conv groups + FC.

BatchNorm running statistics (``mean_ema`` / ``var_ema``) are client-local:
``core.aggregation`` filters them from server averaging, matching the paper's
"refrain from uploading local statistical information".
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

BN_MOMENTUM = 0.9


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------

def conv_init(key, kh: int, kw: int, cin: int, cout: int, dtype=jnp.float32) -> PyTree:
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout)) * np.sqrt(2.0 / fan_in)
    return {"w": w.astype(dtype)}


def conv_apply(p: PyTree, x: jax.Array, stride: int = 1) -> jax.Array:
    return jax.lax.conv_general_dilated(
        x,
        p["w"].astype(x.dtype),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def bn_init(c: int, dtype=jnp.float32) -> PyTree:
    return {
        "scale": jnp.ones((c,), dtype),
        "bias": jnp.zeros((c,), dtype),
        "mean_ema": jnp.zeros((c,), jnp.float32),
        "var_ema": jnp.ones((c,), jnp.float32),
    }


def bn_apply(
    p: PyTree, x: jax.Array, train: bool, eps: float = 1e-5
) -> tuple[jax.Array, PyTree | None]:
    """Returns (y, stats_update) — stats_update is a pruned dict in train mode."""
    if train:
        mean = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        upd = {
            "mean_ema": BN_MOMENTUM * p["mean_ema"] + (1 - BN_MOMENTUM) * mean,
            "var_ema": BN_MOMENTUM * p["var_ema"] + (1 - BN_MOMENTUM) * var,
        }
    else:
        mean, var = p["mean_ema"], p["var_ema"]
        upd = None
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    return y * p["scale"] + p["bias"], upd


# ---------------------------------------------------------------------------
# ResNet
# ---------------------------------------------------------------------------

RESNET4 = {"stages": (1, 1), "channels": (8, 16), "name": "resnet4"}   # test-scale
RESNET8 = {"stages": (1, 1, 2), "channels": (16, 32, 64), "name": "resnet8"}
RESNET18 = {"stages": (2, 2, 2, 2), "channels": (64, 128, 256, 512), "name": "resnet18"}


def resnet_init(key, spec: dict, num_classes: int, in_channels: int = 3) -> PyTree:
    keys = iter(jax.random.split(key, 64))
    chans = spec["channels"]
    params: PyTree = {
        "stem": {"conv": conv_init(next(keys), 3, 3, in_channels, chans[0]), "bn": bn_init(chans[0])},
        "blocks": {},
        "head": {
            "w": (jax.random.normal(next(keys), (chans[-1], num_classes)) * 0.01).astype(jnp.float32),
            "b": jnp.zeros((num_classes,), jnp.float32),
        },
    }
    cin = chans[0]
    bidx = 0
    for stage, (n_blocks, cout) in enumerate(zip(spec["stages"], chans)):
        for b in range(n_blocks):
            stride = 2 if (stage > 0 and b == 0) else 1
            blk: PyTree = {
                "conv1": conv_init(next(keys), 3, 3, cin, cout),
                "bn1": bn_init(cout),
                "conv2": conv_init(next(keys), 3, 3, cout, cout),
                "bn2": bn_init(cout),
            }
            if stride != 1 or cin != cout:
                # In these CIFAR ResNets a shortcut conv exists iff stride==2,
                # so the stride is re-derivable from the structure at apply
                # time (keeps the pytree arrays-only).
                blk["sc_conv"] = conv_init(next(keys), 1, 1, cin, cout)
                blk["sc_bn"] = bn_init(cout)
            params["blocks"][f"{bidx:02d}"] = blk
            cin = cout
            bidx += 1
    return params


def resnet_apply(
    params: PyTree, images: jax.Array, train: bool = True
) -> tuple[jax.Array, PyTree]:
    """images: (B,H,W,C) -> (logits, bn_stats_updates pruned tree)."""
    stats: PyTree = {"stem": {}, "blocks": {}}
    x = conv_apply(params["stem"]["conv"], images)
    x, upd = bn_apply(params["stem"]["bn"], x, train)
    if upd:
        stats["stem"]["bn"] = upd
    x = jax.nn.relu(x)

    for name in sorted(params["blocks"]):
        blk = params["blocks"][name]
        stride = 2 if "sc_conv" in blk else 1
        h = conv_apply(blk["conv1"], x, stride)
        h, u1 = bn_apply(blk["bn1"], h, train)
        h = jax.nn.relu(h)
        h = conv_apply(blk["conv2"], h)
        h, u2 = bn_apply(blk["bn2"], h, train)
        if "sc_conv" in blk:
            sc = conv_apply(blk["sc_conv"], x, stride)
            sc, u3 = bn_apply(blk["sc_bn"], sc, train)
        else:
            sc, u3 = x, None
        x = jax.nn.relu(h + sc)
        if train:
            bstats = {"bn1": u1, "bn2": u2}
            if u3 is not None:
                bstats["sc_bn"] = u3
            stats["blocks"][name] = bstats

    x = jnp.mean(x, axis=(1, 2))
    logits = x @ params["head"]["w"] + params["head"]["b"]
    return logits, stats


# ---------------------------------------------------------------------------
# FedPart grouping (Appendix A): one group per conv(+BN), FC last.
# ---------------------------------------------------------------------------

def resnet_group_key(path: tuple[str, ...]) -> tuple:
    if path[0] == "stem":
        return ("conv", -1, 0)
    if path[0] == "head":
        return ("head",)
    if path[0] == "blocks":
        blk = int(path[1])
        part = path[2]
        if part in ("conv1", "bn1", "sc_conv", "sc_bn"):
            return ("conv", blk, 1)
        return ("conv", blk, 2)
    return ("misc", path[0])


def resnet_order_key(key: tuple) -> tuple:
    if key[0] == "conv":
        return (0, key[1], key[2])
    if key[0] == "misc":
        return (1, 0, 0)
    return (2, 0, 0)  # head last


def cls_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logz = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
