"""Mixture-of-Experts layer: top-k router + capacity-bounded expert FFNs.

Dispatch is sort-based (MegaBlocks-style) rather than one-hot-einsum
(GShard-style): token->expert assignments are ranked inside each expert via an
argsort + offset subtraction, then scattered into a dense (E, C, d) buffer.
This avoids ever materialising the (T, E, C) dispatch tensor — at
train_4k scale (T=1M tokens, E=256) that tensor would be terabytes — while
remaining fully static-shaped and pjit-shardable: the buffer's E axis is
sharded over the ``model`` mesh axis (expert parallelism), so the scatter
lowers to the MoE all-to-all.

Supports softmax and sigmoid (deepseek-v3) router scores, shared experts,
top-k weight renormalisation, and the standard load-balance auxiliary loss.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import linear_init, mlp_apply, mlp_init

PyTree = Any


def moe_init(key, cfg: ModelConfig, dtype) -> PyTree:
    k_r, k_e, k_s = jax.random.split(key, 3)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(k_e, 3)
    scale_in = 1.0 / jnp.sqrt(jnp.float32(d))
    scale_out = 1.0 / jnp.sqrt(jnp.float32(f))
    p: PyTree = {
        "router": linear_init(k_r, d, e, dtype),
        "experts": {
            "w_gate": (jax.random.normal(ks[0], (e, d, f)) * scale_in).astype(dtype),
            "w_up": (jax.random.normal(ks[1], (e, d, f)) * scale_in).astype(dtype),
            "w_down": (jax.random.normal(ks[2], (e, f, d)) * scale_out).astype(dtype),
        },
    }
    if cfg.num_shared_experts > 0:
        p["shared"] = mlp_init(
            k_s, cfg.mlp_kind, d, cfg.moe_d_ff * cfg.num_shared_experts, dtype
        )
    return p


def _capacity(num_tokens: int, cfg: ModelConfig) -> int:
    c = int(num_tokens * cfg.num_experts_per_tok * cfg.capacity_factor) // cfg.num_experts
    return max(c, 1)


def moe_apply(params: PyTree, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).

    Under ``moe_ep.expert_parallel(mesh)`` this dispatches to the explicit
    shard_map expert-parallel path (see moe_ep.py for why)."""
    from repro.models import moe_ep

    if moe_ep.ep_enabled():
        return moe_ep.moe_apply_ep(params, cfg, x)
    b, s, d = x.shape
    t = b * s
    k = cfg.num_experts_per_tok
    e = cfg.num_experts
    cap = _capacity(t, cfg)
    xf = x.reshape(t, d)

    # --- Router -----------------------------------------------------------
    logits = (xf @ params["router"]["w"].astype(xf.dtype)).astype(jnp.float32)
    if cfg.router_score == "sigmoid":            # deepseek-v3
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(scores, k)          # (T, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- Load-balance auxiliary loss (Switch/GShard form) -------------------
    probs_mean = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=0)            # (E,)
    counts = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0)
    frac = counts / (t * k)
    aux_loss = e * jnp.sum(frac * probs_mean) * cfg.aux_loss_weight

    # --- Sort-based dispatch ------------------------------------------------
    flat_expert = expert_idx.reshape(-1)                      # (T*k,)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    # rank within expert = index-in-sorted − start offset of that expert
    start = jnp.cumsum(jnp.bincount(flat_expert, length=e)) - jnp.bincount(
        flat_expert, length=e
    )
    rank_sorted = jnp.arange(t * k) - start[sorted_expert]
    slot_sorted = jnp.where(
        rank_sorted < cap, sorted_expert * cap + rank_sorted, e * cap
    )  # overflow tokens -> dropped sentinel slot
    slots = jnp.zeros((t * k,), jnp.int32).at[order].set(slot_sorted.astype(jnp.int32))

    token_idx = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e * cap + 1, d), dtype=x.dtype)
    buf = buf.at[slots].set(xf[token_idx], mode="drop")
    expert_in = buf[: e * cap].reshape(e, cap, d)

    # --- Expert FFNs (batched over the expert axis; shardable on E) --------
    ew = params["experts"]
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, ew["w_gate"].astype(x.dtype)))
    u = jnp.einsum("ecd,edf->ecf", expert_in, ew["w_up"].astype(x.dtype))
    expert_out = jnp.einsum("ecf,efd->ecd", g * u, ew["w_down"].astype(x.dtype))

    # --- Combine ------------------------------------------------------------
    out_buf = jnp.concatenate(
        [expert_out.reshape(e * cap, d), jnp.zeros((1, d), x.dtype)], axis=0
    )
    gathered = out_buf[slots]                                  # (T*k, d)
    weighted = gathered * gate_vals.reshape(-1)[:, None].astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[token_idx].add(weighted)

    if cfg.num_shared_experts > 0:
        y = y + mlp_apply(params["shared"], cfg.mlp_kind, xf)
    return y.reshape(b, s, d), aux_loss


def moe_flops_per_token(cfg: ModelConfig) -> int:
    """Active matmul FLOPs per token (routed top-k x capacity + shared)."""
    routed = 2 * 3 * cfg.d_model * cfg.moe_d_ff * cfg.num_experts_per_tok
    shared = 2 * 3 * cfg.d_model * cfg.moe_d_ff * cfg.num_shared_experts
    router = 2 * cfg.d_model * cfg.num_experts
    return int(routed * cfg.capacity_factor + shared + router)
