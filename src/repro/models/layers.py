"""Foundational layers: norms, linear/embedding, MLPs, RoPE.

Everything is functional: parameters are nested dicts of arrays, layer
functions are pure.  Initialisers take an explicit PRNG key and a dtype.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# ---------------------------------------------------------------------------
# Gradient-dtype barrier (§Perf iteration 6)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def bf16_grad_barrier(x):
    """Identity whose cotangent is cast to the primal dtype.

    The f32 upcasts inside norm/softmax leak f32 *activation gradients* into
    the residual stream; under SPMD those f32 tensors are what the partitioner
    all-gathers/all-reduces (measured: f32[16,4096,7168] collectives dominate
    deepseek-EP train).  Casting cotangents back to bf16 at block boundaries
    halves those collective bytes — the standard mixed-precision contract
    (bf16 activation grads, f32 only inside reductions)."""
    return x


def _bgb_fwd(x):
    # residuals must be jax types: carry the dtype via a zero-size array
    return x, jnp.zeros((0,), x.dtype)


def _bgb_bwd(res, ct):
    return (ct.astype(res.dtype),)


bf16_grad_barrier.defvjp(_bgb_fwd, _bgb_bwd)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> PyTree:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params: PyTree, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype) -> PyTree:
    return {"scale": jnp.ones((d,), dtype=dtype), "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm(params: PyTree, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dt)


def norm_init(kind: str, d: int, dtype) -> PyTree:
    return layernorm_init(d, dtype) if kind == "layernorm" else rmsnorm_init(d, dtype)


def norm_apply(kind: str, params: PyTree, x: jax.Array) -> jax.Array:
    return layernorm(params, x) if kind == "layernorm" else rmsnorm(params, x)


# ---------------------------------------------------------------------------
# Linear / embedding
# ---------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, dtype, bias: bool = False) -> PyTree:
    scale = 1.0 / np.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def linear(params: PyTree, x: jax.Array) -> jax.Array:
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


def embedding_init(key, vocab: int, d: int, dtype) -> PyTree:
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(params: PyTree, ids: jax.Array, dtype=None) -> jax.Array:
    table = params["table"]
    out = jnp.take(table, ids, axis=0)
    return out.astype(dtype) if dtype is not None else out


def unembed(params: PyTree, x: jax.Array) -> jax.Array:
    """Project back to vocab; computed in f32 for a stable softmax."""
    return x.astype(jnp.float32) @ params["table"].astype(jnp.float32).T


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, kind: str, d_model: int, d_ff: int, dtype) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": linear_init(k1, d_model, d_ff, dtype),
            "w_up": linear_init(k2, d_model, d_ff, dtype),
            "w_down": linear_init(k3, d_ff, d_model, dtype),
        }
    if kind == "gelu":
        return {
            "w_in": linear_init(k1, d_model, d_ff, dtype, bias=True),
            "w_out": linear_init(k2, d_ff, d_model, dtype, bias=True),
        }
    raise ValueError(f"unknown mlp kind {kind!r}")


def mlp_apply(params: PyTree, kind: str, x: jax.Array) -> jax.Array:
    if kind == "swiglu":
        g = jax.nn.silu(linear(params["w_gate"], x))
        return linear(params["w_down"], g * linear(params["w_up"], x))
    if kind == "geglu":
        g = jax.nn.gelu(linear(params["w_gate"], x), approximate=True)
        return linear(params["w_down"], g * linear(params["w_up"], x))
    if kind == "gelu":
        h = jax.nn.gelu(linear(params["w_in"], x), approximate=True)
        return linear(params["w_out"], h)
    raise ValueError(f"unknown mlp kind {kind!r}")


def mlp_flops(kind: str, d_model: int, d_ff: int) -> int:
    """Matmul FLOPs per token (multiply-adds x2)."""
    mats = 3 if kind in ("swiglu", "geglu") else 2
    return 2 * mats * d_model * d_ff


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for ``positions`` — shapes (..., head_dim // 2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate pairs (x_even, x_odd).  x: (..., seq, heads, head_dim);
    cos/sin: (..., seq, half) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # broadcast over head axis
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)
