"""Partial (FedPart) optimizer: gradients and optimizer state exist only for
the round's trainable group.

Two mathematically equivalent realisations (asserted equal in
``tests/test_partial_equivalence.py``):

* ``masked_step``      — paper Eq. 1 literally: full gradient, multiplied by
  the binary mask S.  Reference semantics; wasteful.
* ``partitioned_step`` — gradients w.r.t. the pruned trainable subtree only,
  frozen remainder closed over as constants.  XLA prunes the dead backward
  graph; Adam m/v are allocated for the subtree only.  This is what the
  framework runs.
"""

from __future__ import annotations

from typing import Any, Callable

import jax

from repro.core import masking
from repro.core.partition import Partition
from repro.optim.adam import AdamConfig, AdamState, adam_init, adam_update

PyTree = Any


def masked_step(
    loss_fn: Callable[[PyTree], jax.Array],
    params: PyTree,
    opt_state: AdamState,
    mask: PyTree,
    cfg: AdamConfig,
) -> tuple[PyTree, AdamState, jax.Array]:
    """Eq. 1: w ← w − γ·S⊙update(∇L).  Full-tree gradient, masked update."""
    loss, grads = jax.value_and_grad(loss_fn)(params)
    grads = masking.apply_mask(grads, mask)
    new_params, new_state = adam_update(grads, opt_state, params, cfg)
    # Mask the parameter delta too: Adam's bias correction would otherwise
    # move frozen params through stale m/v.
    new_params = jax.tree.map(
        lambda n, o, m: jax.numpy.where(m, n, o), new_params, params, mask
    )
    return new_params, new_state, loss


def partitioned_step(
    loss_fn: Callable[[PyTree], jax.Array],
    params: PyTree,
    partition: Partition,
    group: int,
    opt_state: AdamState | None,
    cfg: AdamConfig,
) -> tuple[PyTree, AdamState, jax.Array]:
    """Gradient w.r.t. the trainable subtree only; merge back after update.

    ``opt_state`` is over the *subtree* (None -> freshly initialised), so m/v
    memory is 1/M of the full model.
    """
    trainable = masking.select(params, partition, group)
    frozen = masking.complement(params, partition, group)

    def sub_loss(sub):
        return loss_fn(masking.merge(sub, frozen))

    loss, grads = jax.value_and_grad(sub_loss)(trainable)
    if opt_state is None:
        opt_state = adam_init(trainable)
    new_sub, new_state = adam_update(grads, opt_state, trainable, cfg)
    return masking.merge(new_sub, frozen), new_state, loss


def full_step(
    loss_fn: Callable[[PyTree], jax.Array],
    params: PyTree,
    opt_state: AdamState,
    cfg: AdamConfig,
) -> tuple[PyTree, AdamState, jax.Array]:
    """FNU step (FedAvg baseline)."""
    loss, grads = jax.value_and_grad(loss_fn)(params)
    new_params, new_state = adam_update(grads, opt_state, params, cfg)
    return new_params, new_state, loss
