"""Partial (FedPart) optimizer: gradients and optimizer state exist only for
the round's trainable group.

Two mathematically equivalent realisations (asserted equal in
``tests/test_partial_equivalence.py``):

* ``masked_step``      — paper Eq. 1 literally: full gradient, multiplied by
  the binary mask S.  Reference semantics; wasteful.
* ``partitioned_step`` — gradients w.r.t. the pruned trainable subtree only,
  frozen remainder closed over as constants.  XLA prunes the dead backward
  graph; Adam m/v are allocated for the subtree only.  This is what the
  framework runs.

A third realisation, ``fused_masked_step``, is Eq. 1 through the Pallas
masked-Adam kernel (``kernels/masked_adam``): params/grads are packed into
the kernel's (rows, 128) block layout, the whole optimizer update runs as one
fused pass with a per-block mask, and m/v live *packed* across steps
(``fused_adam_init``).  The three-way equivalence is pinned in
``tests/test_kernels_adam.py``; the engines' ``fused_adam=True`` path builds
on the same step shape (docs/KERNELS.md).
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import masking
from repro.core.partition import Partition
from repro.kernels.masked_adam import ops as madam_ops
from repro.kernels.masked_adam.kernel import LANES, masked_adam_kernel
from repro.optim.adam import AdamConfig, AdamState, adam_init, adam_update

PyTree = Any


def fused_adam_init(params: PyTree, block_rows: int = 8) -> AdamState:
    """Adam state over the *packed* (rows, 128) layout: m/v are single f32
    buffers aligned with ``ops.pack(params)``, not per-leaf trees.  This is
    what keeps the fused scan pack-free for the optimizer state — only
    params/grads are packed each step."""
    rows = madam_ops.packed_rows(params, block_rows)
    z = jnp.zeros((rows, LANES), jnp.float32)
    return AdamState(step=jnp.zeros((), jnp.int32), m=z, v=jnp.zeros_like(z))


def guard_fused_config(cfg: AdamConfig) -> None:
    """The kernel implements plain Adam — weight decay would silently not be
    applied, so refuse it loudly."""
    if cfg.weight_decay:
        raise ValueError(
            "fused_adam does not support weight_decay "
            f"(got {cfg.weight_decay}); use the unfused engines")


def fused_masked_step(
    loss_fn: Callable[[PyTree], jax.Array],
    params: PyTree,
    opt_state: AdamState,          # packed state from ``fused_adam_init``
    partition: Partition,
    groups,                        # int or set of trainable group ids
    cfg: AdamConfig,
    *,
    block_rows: int = 8,
    interpret: bool | None = None,
) -> tuple[PyTree, AdamState, jax.Array]:
    """Eq. 1 through the fused kernel: full-tree gradient, block-masked
    packed Adam update, frozen blocks copy through bit-exact."""
    guard_fused_config(cfg)
    loss, grads = jax.value_and_grad(loss_fn)(params)
    step = opt_state.step + 1
    bm = madam_ops.block_mask_for_group(params, partition, groups, block_rows)
    pp, meta = madam_ops.pack(params, block_rows)
    pg, _ = madam_ops.pack(grads, block_rows)
    scalars = madam_ops.adam_scalars(step, cfg.lr, cfg.b1, cfg.b2, cfg.eps)
    if interpret is None:
        interpret = madam_ops.default_interpret()
    np_, nm, nv = masked_adam_kernel(
        pp, pg, opt_state.m, opt_state.v, jnp.asarray(bm), scalars,
        b1=cfg.b1, b2=cfg.b2, block_rows=block_rows, interpret=interpret,
    )
    return madam_ops.unpack(np_, meta), AdamState(step, nm, nv), loss


def masked_step(
    loss_fn: Callable[[PyTree], jax.Array],
    params: PyTree,
    opt_state: AdamState,
    mask: PyTree,
    cfg: AdamConfig,
) -> tuple[PyTree, AdamState, jax.Array]:
    """Eq. 1: w ← w − γ·S⊙update(∇L).  Full-tree gradient, masked update."""
    loss, grads = jax.value_and_grad(loss_fn)(params)
    grads = masking.apply_mask(grads, mask)
    new_params, new_state = adam_update(grads, opt_state, params, cfg)
    # Mask the parameter delta too: Adam's bias correction would otherwise
    # move frozen params through stale m/v.
    new_params = jax.tree.map(
        lambda n, o, m: jax.numpy.where(m, n, o), new_params, params, mask
    )
    return new_params, new_state, loss


def partitioned_step(
    loss_fn: Callable[[PyTree], jax.Array],
    params: PyTree,
    partition: Partition,
    group: int,
    opt_state: AdamState | None,
    cfg: AdamConfig,
) -> tuple[PyTree, AdamState, jax.Array]:
    """Gradient w.r.t. the trainable subtree only; merge back after update.

    ``opt_state`` is over the *subtree* (None -> freshly initialised), so m/v
    memory is 1/M of the full model.
    """
    trainable = masking.select(params, partition, group)
    frozen = masking.complement(params, partition, group)

    def sub_loss(sub):
        return loss_fn(masking.merge(sub, frozen))

    loss, grads = jax.value_and_grad(sub_loss)(trainable)
    if opt_state is None:
        opt_state = adam_init(trainable)
    new_sub, new_state = adam_update(grads, opt_state, trainable, cfg)
    return masking.merge(new_sub, frozen), new_state, loss


def full_step(
    loss_fn: Callable[[PyTree], jax.Array],
    params: PyTree,
    opt_state: AdamState,
    cfg: AdamConfig,
) -> tuple[PyTree, AdamState, jax.Array]:
    """FNU step (FedAvg baseline)."""
    loss, grads = jax.value_and_grad(loss_fn)(params)
    new_params, new_state = adam_update(grads, opt_state, params, cfg)
    return new_params, new_state, loss
