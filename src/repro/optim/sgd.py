"""SGD (+momentum) — used for DLG privacy experiments and ablations."""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    lr: float = 0.01
    momentum: float = 0.0


class SGDState(NamedTuple):
    velocity: PyTree


def sgd_init(params: PyTree) -> SGDState:
    return SGDState(
        velocity=jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    )


def sgd_update(
    grads: PyTree, state: SGDState, params: PyTree, cfg: SGDConfig
) -> tuple[PyTree, SGDState]:
    def upd(g, v, p):
        v_new = cfg.momentum * v + g.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * v_new).astype(p.dtype), v_new

    out = jax.tree.map(upd, grads, state.velocity, params)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, SGDState(velocity=new_v)
