"""Adam / AdamW from scratch (no optax in this container).

``init``/``update`` operate on arbitrary pytrees.  The *partial* variants in
``repro.optim.partial`` wrap these over pruned trainable subtrees, so
optimizer state is materialised only for the round's trainable group —
FedPart's memory lever (DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0


class AdamState(NamedTuple):
    step: jax.Array
    m: PyTree
    v: PyTree


def adam_init(params: PyTree) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), m=zeros,
                     v=jax.tree.map(jnp.copy, zeros))


def adam_update(
    grads: PyTree, state: AdamState, params: PyTree, cfg: AdamConfig
) -> tuple[PyTree, AdamState]:
    """Returns (new_params, new_state)."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * (g32 * g32)
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamState(step=step, m=new_m, v=new_v)
