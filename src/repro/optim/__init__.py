from repro.optim.adam import AdamConfig, AdamState, adam_init, adam_update  # noqa: F401
from repro.optim.sgd import SGDConfig, SGDState, sgd_init, sgd_update  # noqa: F401
from repro.optim.partial import full_step, masked_step, partitioned_step  # noqa: F401
