"""Mask / split / merge utilities over partitioned parameter pytrees.

Two equivalent realisations of the paper's Eq. 1 masked update are provided:

* ``mask_tree``        — the paper's literal binary mask ``S`` (bool pytree).
* ``select``/``merge`` — the partitioned form: the trainable group is carved
  out as a *pruned subtree*, gradients are taken w.r.t. that subtree only, and
  the result is merged back.  This is the form the framework actually runs —
  XLA prunes the dead backward graph and shrinks the gradient collectives,
  turning the paper's incidental comm/comp savings into compiled ones.

``tests/test_partial_equivalence.py`` asserts the two forms produce identical
updates.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core.partition import Partition, Path, path_str

PyTree = Any

GroupSel = Sequence[int] | int


def _as_group_set(groups: GroupSel) -> frozenset[int]:
    if isinstance(groups, int):
        return frozenset((groups,))
    return frozenset(int(g) for g in groups)


# ---------------------------------------------------------------------------
# Boolean masks (paper Eq. 1 form)
# ---------------------------------------------------------------------------

def mask_tree(params: PyTree, partition: Partition, groups: GroupSel) -> PyTree:
    """Binary mask pytree: True where the leaf belongs to ``groups``."""
    sel = _as_group_set(groups)

    def _mask(path, leaf):
        p = path_str(tuple(_entry_str(e) for e in path))
        keep = partition.group_of(p) in sel
        return jnp.full(jnp.shape(leaf), keep, dtype=bool)

    return jax.tree_util.tree_map_with_path(_mask, params)


def apply_mask(update: PyTree, mask: PyTree) -> PyTree:
    """``S ⊙ update`` — elementwise masked update (paper Eq. 1)."""
    return jax.tree.map(lambda u, m: jnp.where(m, u, jnp.zeros_like(u)), update, mask)


def _entry_str(entry: Any) -> str:
    if isinstance(entry, jax.tree_util.DictKey):
        return str(entry.key)
    if isinstance(entry, jax.tree_util.SequenceKey):
        return str(entry.idx)
    if isinstance(entry, jax.tree_util.GetAttrKey):
        return str(entry.name)
    return str(entry)


# ---------------------------------------------------------------------------
# Pruned-subtree form (what the framework runs)
# ---------------------------------------------------------------------------

def select(params: PyTree, partition: Partition, groups: GroupSel) -> PyTree:
    """Return a pruned pytree holding only leaves assigned to ``groups``."""
    sel = _as_group_set(groups)
    return _filter(params, (), lambda p: partition.group_of(p) in sel)


def complement(params: PyTree, partition: Partition, groups: GroupSel) -> PyTree:
    """Return a pruned pytree holding every leaf *not* in ``groups``."""
    sel = _as_group_set(groups)
    return _filter(params, (), lambda p: partition.group_of(p) not in sel)


def _filter(node: PyTree, prefix: Path, keep) -> PyTree:
    if isinstance(node, dict):
        out = {}
        for k, v in node.items():
            sub = _filter(v, prefix + (str(k),), keep)
            if sub is not None and (not isinstance(sub, dict) or sub):
                out[k] = sub
        return out
    if isinstance(node, (list, tuple)):
        # Parameter containers are dicts in this codebase; sequences are kept
        # atomic only if every element stays.
        items = [_filter(v, prefix + (str(i),), keep) for i, v in enumerate(node)]
        kept = [it for it in items if it is not None]
        if not kept:
            return None
        if len(kept) != len(items):
            raise ValueError(
                f"Partial selection inside a sequence at {path_str(prefix)}; "
                "use dict containers for partitionable parameters."
            )
        return type(node)(items) if not isinstance(node, tuple) else tuple(items)
    return node if keep(path_str(prefix)) else None


def merge(*trees: PyTree) -> PyTree:
    """Deep-merge pruned dict pytrees back into one tree (disjoint leaves)."""
    out: PyTree = {}
    for tree in trees:
        out = _merge2(out, tree)
    return out


def _merge2(a: PyTree, b: PyTree) -> PyTree:
    if b is None:
        return a
    if isinstance(a, dict) and isinstance(b, dict):
        out = dict(a)
        for k, v in b.items():
            out[k] = _merge2(out[k], v) if k in out else v
        return out
    if isinstance(a, dict) and not a:
        return b
    if a is None or (isinstance(a, dict) and not a):
        return b
    raise ValueError("merge: overlapping leaves between pruned trees")


# ---------------------------------------------------------------------------
# Client-axis (stacked) helpers — used by the batched vmap engine
# ---------------------------------------------------------------------------

def stack_trees(trees: Sequence[PyTree]) -> PyTree:
    """Stack same-structure pytrees along a new leading *client* axis."""
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *trees)


def unstack_tree(stacked: PyTree, num_clients: int) -> list[PyTree]:
    """Inverse of ``stack_trees``: one pytree per client-axis index (lazy
    device slices; nothing is copied until a leaf is consumed)."""
    return [jax.tree.map(lambda x: x[i], stacked) for i in range(num_clients)]


def apply_mask_stacked(update: PyTree, mask: PyTree) -> PyTree:
    """``S ⊙ update`` where ``update`` carries a leading client axis and
    ``mask`` is an unbatched bool pytree (``mask_tree`` output): the group
    mask broadcasts across clients — the paper's Eq. 1 form under a client
    axis.  The engine itself runs the pruned-subtree form (``select``/
    ``merge``); this is the literal-mask counterpart, kept equivalent by
    tests/test_partition.py."""
    return jax.tree.map(
        lambda u, m: jnp.where(m[None, ...], u, jnp.zeros_like(u)), update, mask
    )


def tree_update(base: PyTree, patch: PyTree) -> PyTree:
    """Return ``base`` with the leaves present in (pruned) ``patch`` replaced."""
    if not isinstance(base, dict):
        return patch
    out = dict(base)
    for k, v in (patch or {}).items():
        out[k] = tree_update(base[k], v) if k in out else v
    return out
