"""jax API compat shims shared across the repo.

jax >= 0.5 promotes ``shard_map`` to ``jax.shard_map``; the replication-check
kwarg was also renamed (``check_rep`` -> ``check_vma``) on its own schedule.
Resolve both the symbol and the kwarg by inspection, not version guesswork,
in exactly one place — ``models/moe_ep.py`` and ``fl/batched.py`` both build
on this.
"""

from __future__ import annotations

import inspect as _inspect

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map

# Splat into every shard_map call to disable the replication check under
# whichever name this jax spells it.
SHARD_MAP_NO_CHECK_KW = {
    ("check_vma" if "check_vma" in _inspect.signature(shard_map).parameters
     else "check_rep"): False
}


def abstract_client_mesh(width: int, axis: str = "clients"):
    """``jax.sharding.AbstractMesh`` with one ``width``-sized axis, or ``None``
    when this jax cannot build one.

    An abstract mesh lets one traced ``shard_map`` program serve every
    concrete mesh of the same shape — the submesh bindings in ``fl/batched.py``
    use it to share a single trace across equal-width submeshes (the concrete
    devices come in through the inputs' ``NamedSharding``).  The constructor
    signature has moved across jax releases, so resolve it by trying, not by
    version guesswork; callers fall back to per-submesh concrete-mesh traces
    on ``None``.
    """
    am = getattr(jax.sharding, "AbstractMesh", None)
    if am is None:  # pragma: no cover - depends on installed jax
        return None
    for args in (((axis, int(width)),),), ((int(width),), (axis,)):
        try:
            return am(*args)
        except TypeError:  # pragma: no cover - depends on installed jax
            continue
    return None  # pragma: no cover - depends on installed jax
