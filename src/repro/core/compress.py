"""Lossy compression of transmitted subtrees (docs/COMPRESSION.md).

FedPart already shrinks communication structurally — partial rounds move only
the scheduled group's subtree (Eq. 5).  This module shrinks the *remaining*
off-mesh bytes another 4–32x by compressing the per-client update at the
transmission boundary:

* ``int8``   — symmetric per-block quantization: ``q = round(127 x / s)``
  with ``s = max|x|`` per block, dequantized as ``q s / 127``.  Worst-case
  elementwise error ``s / 254``.  ~4x smaller than f32 (+ one f32 scale per
  block).
* ``onebit`` — sign-SGD-style 1-bit encoding with a per-block magnitude
  ``s = mean|x|``; dequantized as ``sign(x) * s``.  ~32x smaller.
* ``topk``   — per-leaf magnitude top-k sparsification: the ``k =
  ceil(topk_fraction * n)`` largest-|x| elements travel as (value, index)
  pairs; everything else is dropped.

Each scheme compresses the client's *update* ``u = local - global`` (scale
invariance makes this interchangeable with compressing the weight-scaled
subtree: the server reconstructs ``global + c_i`` per client and the usual
weighted aggregation applies).  With ``error_feedback=True`` every client
carries a persistent residual ``r``: the transmitted value is ``c = Q(u + r)``
and the new residual ``r' = (u + r) - c``, so quantization error telescopes
across rounds instead of accumulating (1-bit Adam / EF-SGD contract; the
per-round identity ``sum(c) + r == sum(u)`` is pinned by
tests/test_compress.py).

Blocking: ``block_rows = 0`` (default) uses one scale per leaf;
``block_rows = B`` uses blocks of ``B * 128`` elements with per-leaf padding —
the same lane width and leaf alignment as the packed masked-Adam layout
(``kernels/masked_adam/ops.pack``; blocks never span leaves), so ``B = 8``
matches the kernel's 8x128 block grid exactly.

Two realisations are provided and pinned equal:

* ``qdq_leaf`` / ``transmit_tree*`` — the jit-friendly quantize→dequantize
  path the engines run on device (values only, nothing materialises the wire
  format);
* ``encode_leaf`` / ``decode_leaf`` — the host-side wire format (int8 codes /
  packed sign bits / (value, index) pairs + per-block f32 scales), whose
  actual array bytes match the analytic ledger (``leaf_encoded_bytes``) that
  ``core.costs.comm_cost`` and the async runtime book.

Client-local statistics (BN running moments) never travel and are never
compressed; they keep the legacy 4-bytes/param ledger basis.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, masking
from repro.core.partition import Partition

PyTree = Any

KINDS = ("none", "int8", "onebit", "topk")

#: lane width of one packed block row — matches kernels/masked_adam/ops.LANES
#: so ``block_rows=8`` reproduces the kernel's 8x128 block granularity.
LANES = 128

F32_BYTES = 4
INT8_BYTES = 1
SCALE_BYTES = 4      # one f32 scale per block
INDEX_BYTES = 4      # one int32 index per retained top-k element


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Static description of one transmission-compression scheme.

    Hashable and frozen so engines can key jit caches on it.  ``kind`` is
    never ``"none"`` here — the *absence* of compression is represented by
    passing ``None`` around (``make_config``), which keeps the legacy paths
    structurally untouched.
    """

    kind: str
    topk_fraction: float = 0.01
    error_feedback: bool = True
    block_rows: int = 0          # 0 = one block (scale) per leaf

    def __post_init__(self):
        if self.kind not in KINDS[1:]:
            raise ValueError(
                f"compression kind must be one of {KINDS[1:]}, got {self.kind!r}"
                " (represent 'none' as None — make_config does this)")
        if self.kind == "topk" and not 0.0 < self.topk_fraction <= 1.0:
            raise ValueError(
                f"topk_fraction must be in (0, 1], got {self.topk_fraction}")
        if self.block_rows < 0:
            raise ValueError(f"block_rows must be >= 0, got {self.block_rows}")

    @property
    def block_elems(self) -> int:
        """Elements per scale block (0 = whole leaf)."""
        return self.block_rows * LANES


def make_config(kind: str = "none", *, topk_fraction: float = 0.01,
                error_feedback: bool = True,
                block_rows: int = 0) -> CompressionConfig | None:
    """``FLRunConfig`` string -> config object, or ``None`` for ``"none"``.

    Returning ``None`` (not a no-op config) is what makes ``"none"``
    structurally absent: every consumer guards on ``compression is None`` and
    runs the byte-identical legacy path."""
    if kind == "none":
        return None
    if kind not in KINDS:
        raise ValueError(f"compression must be one of {KINDS}, got {kind!r}")
    return CompressionConfig(kind=kind, topk_fraction=topk_fraction,
                             error_feedback=error_feedback,
                             block_rows=block_rows)


# ---------------------------------------------------------------------------
# Block geometry (shared by the qdq path, the wire format and the ledger)
# ---------------------------------------------------------------------------

def _num_blocks(n: int, cfg: CompressionConfig) -> int:
    if n == 0:
        return 0
    be = cfg.block_elems or n
    return -(-n // be)


def _topk_k(n: int, cfg: CompressionConfig) -> int:
    if n == 0:
        return 0
    return min(n, max(1, math.ceil(cfg.topk_fraction * n)))


def _blocked(flat: jax.Array, cfg: CompressionConfig):
    """Zero-pad ``flat`` to a whole number of blocks -> ((nb, be), valid)."""
    n = flat.shape[0]
    be = cfg.block_elems or n
    nb = -(-n // be)
    blocks = jnp.pad(flat, (0, nb * be - n)).reshape(nb, be)
    valid = (jnp.arange(nb * be) < n).reshape(nb, be)
    return blocks, valid


def _int8_scales(blocks: jax.Array) -> jax.Array:
    s = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    return jnp.where(s > 0, s, 1.0)


def _onebit_scales(blocks: jax.Array, valid: jax.Array) -> jax.Array:
    cnt = jnp.maximum(jnp.sum(valid, axis=1, keepdims=True), 1)
    return jnp.sum(jnp.abs(blocks), axis=1, keepdims=True) / cnt


# ---------------------------------------------------------------------------
# Quantize -> dequantize (the on-device value path the engines run)
# ---------------------------------------------------------------------------

def qdq_leaf(x: jax.Array, cfg: CompressionConfig) -> jax.Array:
    """Quantize-dequantize one f32 leaf: the values the server would see
    after decoding the wire format (``decode_leaf(encode_leaf(x))`` —
    bit-identical, pinned by tests/test_compress.py).  Jit/vmap-friendly:
    all shapes are static functions of ``x.shape`` and ``cfg``."""
    n = int(np.prod(x.shape)) if x.shape else 1
    if n == 0:
        return x
    flat = x.astype(jnp.float32).reshape(-1)
    if cfg.kind == "topk":
        k = _topk_k(n, cfg)
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        deq = jnp.zeros_like(flat).at[idx].set(flat[idx])
        return deq.reshape(x.shape)
    blocks, valid = _blocked(flat, cfg)
    if cfg.kind == "int8":
        scale = _int8_scales(blocks)
        q = jnp.clip(jnp.round(blocks * (127.0 / scale)), -127.0, 127.0)
        deq = q * (scale / 127.0)
    elif cfg.kind == "onebit":
        scale = _onebit_scales(blocks, valid)
        deq = jnp.where(blocks >= 0, scale, -scale)
    else:  # pragma: no cover - guarded by CompressionConfig
        raise ValueError(f"unknown compression kind {cfg.kind!r}")
    return deq.reshape(-1)[:n].reshape(x.shape)


def transmit_leaf(g_leaf: jax.Array, local_leaf: jax.Array,
                  res_leaf: jax.Array,
                  cfg: CompressionConfig) -> tuple[jax.Array, jax.Array]:
    """One leaf's error-feedback transmission step.

    ``u = local - global`` is the true update; the client transmits
    ``c = Q(u + r)`` and keeps ``r' = (u + r) - c``.  Returns the *server
    view* ``global + c`` (cast back to the leaf dtype) and the new residual
    (f32).  With ``error_feedback=False`` the residual stays untouched (all
    zeros) and ``c = Q(u)``."""
    g32 = g_leaf.astype(jnp.float32)
    u = local_leaf.astype(jnp.float32) - g32
    t = u + res_leaf if cfg.error_feedback else u
    c = qdq_leaf(t, cfg)
    new_res = (t - c) if cfg.error_feedback else res_leaf
    tx = (g32 + c).astype(local_leaf.dtype)
    return tx, new_res


def init_residual(params: PyTree) -> PyTree:
    """Fresh all-zero f32 error-feedback residual for one client."""
    return jax.tree.map(lambda x: jnp.zeros(jnp.shape(x), jnp.float32), params)


def _split_pairs(pairs: PyTree) -> tuple[PyTree, PyTree]:
    is_pair = lambda x: isinstance(x, tuple)
    tx = jax.tree.map(lambda pr: pr[0], pairs, is_leaf=is_pair)
    res = jax.tree.map(lambda pr: pr[1], pairs, is_leaf=is_pair)
    return tx, res


def transmit_tree(global_params: PyTree, local: PyTree, residual: PyTree,
                  cfg: CompressionConfig, *, partition: Partition,
                  groups: Sequence[int] | None = None
                  ) -> tuple[PyTree, PyTree]:
    """Apply the transmission step to every *transmitted* leaf of a full
    client tree: leaves outside ``groups`` (``None`` = all groups) and
    client-local statistics pass through unchanged (``local`` value, residual
    untouched) — they do not travel, so they must not consume error feedback.

    Returns ``(tx, new_residual)`` where ``tx`` is the full tree the
    server-side aggregation consumes in place of ``local`` (the
    decompress-and-splice view: ``global + Q(update)`` on transmitted leaves,
    ``local`` elsewhere)."""
    sel = None if groups is None else frozenset(int(g) for g in groups)

    def _leaf(path, g_leaf, l_leaf, r_leaf):
        p = "/".join(masking._entry_str(e) for e in path)
        if aggregation.is_local_stat(p) or (
                sel is not None and partition.group_of(p) not in sel):
            return (l_leaf, r_leaf)
        return transmit_leaf(g_leaf, l_leaf, r_leaf, cfg)

    pairs = jax.tree_util.tree_map_with_path(
        _leaf, global_params, local, residual)
    return _split_pairs(pairs)


def transmit_tree_plan(global_params: PyTree, local: PyTree, residual: PyTree,
                       gmask: jax.Array, cfg: CompressionConfig, *,
                       partition: Partition) -> tuple[PyTree, PyTree]:
    """Plan-program variant of ``transmit_tree``: the trained-group set is a
    *traced* ``(M,)`` bitmask (one per client riding the stacked axis), so the
    per-leaf decision is a ``jnp.where`` instead of structural pruning.
    Untrained leaves keep ``local`` (== global under the masked step) and
    their residual untouched; statistics are excluded statically."""
    bits = jnp.asarray(gmask, jnp.float32) != 0

    def _leaf(path, g_leaf, l_leaf, r_leaf):
        p = "/".join(masking._entry_str(e) for e in path)
        if aggregation.is_local_stat(p):
            return (l_leaf, r_leaf)
        bit = bits[partition.group_of(p)]
        tx, nr = transmit_leaf(g_leaf, l_leaf, r_leaf, cfg)
        return (jnp.where(bit, tx, l_leaf),
                jnp.where(bit, nr, r_leaf))

    pairs = jax.tree_util.tree_map_with_path(
        _leaf, global_params, local, residual)
    return _split_pairs(pairs)


# ---------------------------------------------------------------------------
# Wire format (host-side; what the byte ledger prices)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EncodedLeaf:
    """One leaf's encoded payload.  ``nbytes`` is the actual array storage —
    tests pin it equal to the analytic ``leaf_encoded_bytes`` model."""

    kind: str
    shape: tuple[int, ...]
    dtype: Any
    payload: np.ndarray                 # int8 codes / packed sign bits / f32 values
    scales: np.ndarray | None = None    # (nblocks,) f32, quantized kinds only
    indices: np.ndarray | None = None   # (k,) int32, topk only

    @property
    def nbytes(self) -> int:
        total = self.payload.nbytes
        if self.scales is not None:
            total += self.scales.nbytes
        if self.indices is not None:
            total += self.indices.nbytes
        return total


def encode_leaf(x, cfg: CompressionConfig) -> EncodedLeaf:
    """Encode one leaf into its compact wire format (host-side numpy)."""
    arr = np.asarray(x)
    n = arr.size
    flat = jnp.asarray(arr, jnp.float32).reshape(-1)
    if cfg.kind == "topk":
        k = _topk_k(n, cfg)
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        idx = np.asarray(idx, np.int32)
        vals = np.asarray(flat, np.float32)[idx]
        return EncodedLeaf(kind=cfg.kind, shape=arr.shape, dtype=arr.dtype,
                           payload=vals, indices=idx)
    blocks, valid = _blocked(flat, cfg)
    if cfg.kind == "int8":
        scale = _int8_scales(blocks)
        q = jnp.clip(jnp.round(blocks * (127.0 / scale)), -127.0, 127.0)
        codes = np.asarray(q, np.int8).reshape(-1)[:n]
        return EncodedLeaf(kind=cfg.kind, shape=arr.shape, dtype=arr.dtype,
                           payload=codes,
                           scales=np.asarray(scale, np.float32).reshape(-1))
    if cfg.kind == "onebit":
        scale = _onebit_scales(blocks, valid)
        signs = np.asarray(flat >= 0, bool)
        return EncodedLeaf(kind=cfg.kind, shape=arr.shape, dtype=arr.dtype,
                           payload=np.packbits(signs),
                           scales=np.asarray(scale, np.float32).reshape(-1))
    raise ValueError(f"unknown compression kind {cfg.kind!r}")


def decode_leaf(enc: EncodedLeaf, cfg: CompressionConfig) -> jax.Array:
    """Decode back to the leaf's shape/dtype.  Bit-identical to
    ``qdq_leaf`` on the same input (same arithmetic, same order)."""
    n = int(np.prod(enc.shape)) if enc.shape else 1
    if enc.kind == "topk":
        flat = jnp.zeros((n,), jnp.float32)
        deq = flat.at[jnp.asarray(enc.indices)].set(jnp.asarray(enc.payload))
        return deq.reshape(enc.shape).astype(enc.dtype)
    nb = enc.scales.shape[0]
    be = (cfg.block_elems or n)
    scale = jnp.asarray(enc.scales, jnp.float32).reshape(nb, 1)
    if enc.kind == "int8":
        q = jnp.pad(jnp.asarray(enc.payload, jnp.float32), (0, nb * be - n))
        deq = q.reshape(nb, be) * (scale / 127.0)
    elif enc.kind == "onebit":
        bits = np.unpackbits(enc.payload)[:n].astype(bool)
        bits = jnp.pad(jnp.asarray(bits), (0, nb * be - n))
        deq = jnp.where(bits.reshape(nb, be), scale, -scale)
    else:
        raise ValueError(f"unknown compression kind {enc.kind!r}")
    return deq.reshape(-1)[:n].reshape(enc.shape).astype(enc.dtype)


# ---------------------------------------------------------------------------
# Analytic byte ledger (consumed by core.costs and the async runtime)
# ---------------------------------------------------------------------------

def leaf_encoded_bytes(n: int, cfg: CompressionConfig | None) -> int:
    """Wire bytes for one transmitted leaf of ``n`` elements: payload plus
    per-block scales plus top-k indices.  ``cfg=None`` is the legacy dense
    f32 ledger (4 bytes/param)."""
    if n == 0:
        return 0
    if cfg is None:
        return F32_BYTES * n
    nb = _num_blocks(n, cfg)
    if cfg.kind == "int8":
        return INT8_BYTES * n + SCALE_BYTES * nb
    if cfg.kind == "onebit":
        return -(-n // 8) + SCALE_BYTES * nb
    if cfg.kind == "topk":
        return _topk_k(n, cfg) * (F32_BYTES + INDEX_BYTES)
    raise ValueError(f"unknown compression kind {cfg.kind!r}")


def group_encoded_bytes(params: PyTree, partition: Partition,
                        cfg: CompressionConfig | None) -> np.ndarray:
    """Per-group transmitted bytes under ``cfg`` — the compressed counterpart
    of ``partition.group_param_bytes``.  Client-local statistics keep the
    dense-f32 basis (they are not compressed; keeping them priced preserves
    the legacy ledger exactly at ``cfg=None``)."""
    out = np.zeros(partition.num_groups, dtype=np.int64)

    def _add(path, leaf):
        p = "/".join(masking._entry_str(e) for e in path)
        n = int(np.prod(jnp.shape(leaf))) if jnp.shape(leaf) else 1
        eff = None if aggregation.is_local_stat(p) else cfg
        out[partition.group_of(p)] += leaf_encoded_bytes(n, eff)

    jax.tree_util.tree_map_with_path(_add, params)
    return out


def tree_encoded_bytes(tree: PyTree, cfg: CompressionConfig | None) -> int:
    """Total wire bytes of one (possibly pruned) transmitted subtree."""
    total = 0

    def _add(path, leaf):
        nonlocal total
        p = "/".join(masking._entry_str(e) for e in path)
        n = int(np.prod(jnp.shape(leaf))) if jnp.shape(leaf) else 1
        eff = None if aggregation.is_local_stat(p) else cfg
        total += leaf_encoded_bytes(n, eff)

    jax.tree_util.tree_map_with_path(_add, tree)
    return total
