"""Trainable-layer selection schedules (paper §3.2, Fig. 3).

The FedPart training run is a sequence of communication rounds; each round is
either a full-network-update (FNU) round or a partial round training exactly
one layer group.  The canonical schedule is::

    [warm-up FNU x W] then C cycles of:
        for group in order(1..M): [partial(group) x R/L]
        [bridge FNU x B]            # paper inserts 5 between cycles

Bridges only separate cycles, so a run has ``W + C*M*(R/L) + (C-1)*B``
rounds (``total_rounds``).  Orders: ``sequential`` (shallow->deep, the
default), ``reverse``, ``random`` (reshuffled every cycle) — Table 7's three
variants.

Example — the paper's default shape at toy scale::

    >>> sched = FedPartSchedule(num_groups=3, warmup_rounds=1,
    ...                         rounds_per_layer=2, cycles=2, bridge_rounds=1)
    >>> [(r.phase, r.group) for r in sched.rounds()[:4]]
    [('warmup', -1), ('partial', 0), ('partial', 0), ('partial', 1)]
    >>> sched.total_rounds == 1 + 2 * 3 * 2 + 1 * 1
    True

Every consumer — ``fl.server.run_federated``, the mesh trainer in
``launch.fedtrain``, the cost ledger in ``core.costs`` — iterates the same
``RoundSpec`` list, so schedule semantics live in exactly one place (see
docs/ARCHITECTURE.md).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Literal, Sequence

import numpy as np

Phase = Literal["warmup", "partial", "bridge"]
Order = Literal["sequential", "reverse", "random"]

FULL_NETWORK = -1  # sentinel group id meaning "all groups trainable"


@dataclasses.dataclass(frozen=True)
class RoundSpec:
    """What round ``index`` trains: one group, or the full network."""

    index: int
    phase: Phase
    cycle: int            # -1 during warm-up
    group: int            # FULL_NETWORK for FNU rounds

    @property
    def is_full(self) -> bool:
        return self.group == FULL_NETWORK


@dataclasses.dataclass(frozen=True)
class FedPartSchedule:
    """Round-by-round plan for a FedPart run.

    Degenerate corners are well-defined: ``cycles=0`` yields only the warm-up
    (no partial rounds, no bridges), ``warmup_rounds=0`` starts partial
    immediately, and ``order="random"`` is deterministic under a fixed
    ``seed`` (one fresh permutation per cycle from a single generator).

    >>> FedPartSchedule(num_groups=4, warmup_rounds=2, cycles=0).total_rounds
    2
    >>> s = FedPartSchedule(num_groups=3, warmup_rounds=0, rounds_per_layer=1)
    >>> [r.group for r in s.rounds()]
    [0, 1, 2]
    """

    num_groups: int
    warmup_rounds: int = 5
    rounds_per_layer: int = 2          # "R/L" in the paper (2 R/L default)
    cycles: int = 1                    # "C" in the paper's tables
    bridge_rounds: int = 5             # FNU rounds inserted between cycles
    order: Order = "sequential"
    seed: int = 0

    def rounds(self) -> list[RoundSpec]:
        """Materialise the full ``RoundSpec`` list, indices 0..total-1."""
        rng = np.random.default_rng(self.seed)
        specs: list[RoundSpec] = []
        idx = 0
        for _ in range(self.warmup_rounds):
            specs.append(RoundSpec(idx, "warmup", -1, FULL_NETWORK))
            idx += 1
        for c in range(self.cycles):
            groups = self._cycle_order(c, rng)
            for g in groups:
                for _ in range(self.rounds_per_layer):
                    specs.append(RoundSpec(idx, "partial", c, int(g)))
                    idx += 1
            if c != self.cycles - 1:
                for _ in range(self.bridge_rounds):
                    specs.append(RoundSpec(idx, "bridge", c, FULL_NETWORK))
                    idx += 1
        return specs

    def _cycle_order(self, cycle: int, rng: np.random.Generator) -> Sequence[int]:
        base = np.arange(self.num_groups)
        if self.order == "sequential":
            return base
        if self.order == "reverse":
            return base[::-1]
        if self.order == "random":
            return rng.permutation(base)
        raise ValueError(f"unknown order {self.order!r}")

    def __iter__(self) -> Iterator[RoundSpec]:
        return iter(self.rounds())

    @property
    def total_rounds(self) -> int:
        """``W + C*M*(R/L) + (C-1)*B`` — the paper's round budget with
        bridges only *between* cycles (none after the last)."""
        per_cycle = self.num_groups * self.rounds_per_layer
        bridges = self.bridge_rounds * max(self.cycles - 1, 0)
        return self.warmup_rounds + self.cycles * per_cycle + bridges


@dataclasses.dataclass(frozen=True)
class ScheduleIndex:
    """``RoundSpec``-by-*server-version* lookup for asynchronous runtimes.

    Synchronous training identifies "round" with "position in the schedule";
    an asynchronous server does not — client completions arrive continuously
    and the schedule must advance on **server aggregations** (version bumps),
    never on client completions.  ``ScheduleIndex`` makes that rule
    well-defined: version ``v`` (the number of aggregations the server has
    committed) maps to ``specs[v]``, and dispatches issued while the server
    sits at version ``v`` train the group of ``specs[v]`` regardless of how
    many stale cohorts are still in flight.  Versions past the end clamp to
    the final spec so late dispatches (drained after the last planned
    aggregation) stay well-defined.

    >>> idx = ScheduleIndex.from_rounds(
    ...     FedPartSchedule(num_groups=2, warmup_rounds=1,
    ...                     rounds_per_layer=1).rounds())
    >>> (idx.for_version(0).phase, idx.for_version(1).group)
    ('warmup', 0)
    >>> idx.for_version(99).group == idx.for_version(len(idx) - 1).group
    True
    >>> idx.staleness(completed_at_version=3, dispatched_at_version=1)
    2
    """

    specs: tuple[RoundSpec, ...]

    @classmethod
    def from_rounds(cls, rounds: Sequence[RoundSpec]) -> "ScheduleIndex":
        specs = tuple(rounds)
        if not specs:
            raise ValueError("ScheduleIndex needs at least one RoundSpec")
        return cls(specs=specs)

    def for_version(self, version: int) -> RoundSpec:
        """The spec governing dispatches while the server is at ``version``."""
        if version < 0:
            raise ValueError(f"server version must be >= 0, got {version}")
        return self.specs[min(version, len(self.specs) - 1)]

    @staticmethod
    def staleness(completed_at_version: int, dispatched_at_version: int) -> int:
        """Server versions the model advanced while the update was in flight."""
        return max(completed_at_version - dispatched_at_version, 0)

    def __len__(self) -> int:
        return len(self.specs)

    def __iter__(self) -> Iterator[RoundSpec]:
        return iter(self.specs)


@dataclasses.dataclass(frozen=True)
class FNUSchedule:
    """Baseline: every round trains the full network (FedAvg et al.)."""

    total: int

    def rounds(self) -> list[RoundSpec]:
        return [RoundSpec(i, "warmup", -1, FULL_NETWORK) for i in range(self.total)]

    def __iter__(self) -> Iterator[RoundSpec]:
        return iter(self.rounds())

    @property
    def total_rounds(self) -> int:
        return self.total


def matched_fnu(schedule: FedPartSchedule) -> FNUSchedule:
    """FNU baseline with the same number of communication rounds."""
    return FNUSchedule(total=schedule.total_rounds)
