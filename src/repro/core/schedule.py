"""Trainable-layer selection schedules (paper §3.2, Fig. 3).

The FedPart training run is a sequence of communication rounds; each round is
either a full-network-update (FNU) round or a partial round training exactly
one layer group.  The canonical schedule is::

    [warm-up FNU x W] then C cycles of:
        for group in order(1..M): [partial(group) x R/L]
        [bridge FNU x B]            # paper inserts 5 between cycles

Bridges only separate cycles, so a run has ``W + C*M*(R/L) + (C-1)*B``
rounds (``total_rounds``).  Orders: ``sequential`` (shallow->deep, the
default), ``reverse``, ``random`` (reshuffled every cycle) — Table 7's three
variants.

Example — the paper's default shape at toy scale::

    >>> sched = FedPartSchedule(num_groups=3, warmup_rounds=1,
    ...                         rounds_per_layer=2, cycles=2, bridge_rounds=1)
    >>> [(r.phase, r.group) for r in sched.rounds()[:4]]
    [('warmup', -1), ('partial', 0), ('partial', 0), ('partial', 1)]
    >>> sched.total_rounds == 1 + 2 * 3 * 2 + 1 * 1
    True

Every consumer — ``fl.server.run_federated``, the mesh trainer in
``launch.fedtrain``, the cost ledger in ``core.costs`` — iterates the same
``RoundSpec`` list, so schedule semantics live in exactly one place (see
docs/ARCHITECTURE.md).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Literal, Sequence

import numpy as np

Phase = Literal["warmup", "partial", "bridge"]
Order = Literal["sequential", "reverse", "random"]

FULL_NETWORK = -1  # sentinel group id meaning "all groups trainable"


@dataclasses.dataclass(frozen=True)
class RoundSpec:
    """What round ``index`` trains: one group, or the full network."""

    index: int
    phase: Phase
    cycle: int            # -1 during warm-up
    group: int            # FULL_NETWORK for FNU rounds

    @property
    def is_full(self) -> bool:
        return self.group == FULL_NETWORK


@dataclasses.dataclass(frozen=True)
class FedPartSchedule:
    """Round-by-round plan for a FedPart run.

    Degenerate corners are well-defined: ``cycles=0`` yields only the warm-up
    (no partial rounds, no bridges), ``warmup_rounds=0`` starts partial
    immediately, and ``order="random"`` is deterministic under a fixed
    ``seed`` (one fresh permutation per cycle from a single generator).

    >>> FedPartSchedule(num_groups=4, warmup_rounds=2, cycles=0).total_rounds
    2
    >>> s = FedPartSchedule(num_groups=3, warmup_rounds=0, rounds_per_layer=1)
    >>> [r.group for r in s.rounds()]
    [0, 1, 2]
    """

    num_groups: int
    warmup_rounds: int = 5
    rounds_per_layer: int = 2          # "R/L" in the paper (2 R/L default)
    cycles: int = 1                    # "C" in the paper's tables
    bridge_rounds: int = 5             # FNU rounds inserted between cycles
    order: Order = "sequential"
    seed: int = 0

    def rounds(self) -> list[RoundSpec]:
        """Materialise the full ``RoundSpec`` list, indices 0..total-1."""
        rng = np.random.default_rng(self.seed)
        specs: list[RoundSpec] = []
        idx = 0
        for _ in range(self.warmup_rounds):
            specs.append(RoundSpec(idx, "warmup", -1, FULL_NETWORK))
            idx += 1
        for c in range(self.cycles):
            groups = self._cycle_order(c, rng)
            for g in groups:
                for _ in range(self.rounds_per_layer):
                    specs.append(RoundSpec(idx, "partial", c, int(g)))
                    idx += 1
            if c != self.cycles - 1:
                for _ in range(self.bridge_rounds):
                    specs.append(RoundSpec(idx, "bridge", c, FULL_NETWORK))
                    idx += 1
        return specs

    def _cycle_order(self, cycle: int, rng: np.random.Generator) -> Sequence[int]:
        base = np.arange(self.num_groups)
        if self.order == "sequential":
            return base
        if self.order == "reverse":
            return base[::-1]
        if self.order == "random":
            return rng.permutation(base)
        raise ValueError(f"unknown order {self.order!r}")

    def __iter__(self) -> Iterator[RoundSpec]:
        return iter(self.rounds())

    @property
    def total_rounds(self) -> int:
        """``W + C*M*(R/L) + (C-1)*B`` — the paper's round budget with
        bridges only *between* cycles (none after the last)."""
        per_cycle = self.num_groups * self.rounds_per_layer
        bridges = self.bridge_rounds * max(self.cycles - 1, 0)
        return self.warmup_rounds + self.cycles * per_cycle + bridges


@dataclasses.dataclass(frozen=True)
class ScheduleIndex:
    """``RoundSpec``-by-*server-version* lookup for asynchronous runtimes.

    Synchronous training identifies "round" with "position in the schedule";
    an asynchronous server does not — client completions arrive continuously
    and the schedule must advance on **server aggregations** (version bumps),
    never on client completions.  ``ScheduleIndex`` makes that rule
    well-defined: version ``v`` (the number of aggregations the server has
    committed) maps to ``specs[v]``, and dispatches issued while the server
    sits at version ``v`` train the group of ``specs[v]`` regardless of how
    many stale cohorts are still in flight.  Versions past the end clamp to
    the final spec so late dispatches (drained after the last planned
    aggregation) stay well-defined.

    A server controller (docs/CONTROL.md) may *override* the group a future
    version trains (``override_group``): the override keeps the base spec's
    ``index`` — so eval cadence and history numbering are untouched — and
    only redirects which subtree the version's dispatches train.  With no
    overrides registered, lookups are exactly the static schedule.

    >>> idx = ScheduleIndex.from_rounds(
    ...     FedPartSchedule(num_groups=2, warmup_rounds=1,
    ...                     rounds_per_layer=1).rounds())
    >>> (idx.for_version(0).phase, idx.for_version(1).group)
    ('warmup', 0)
    >>> idx.for_version(99).group == idx.for_version(len(idx) - 1).group
    True
    >>> idx.staleness(completed_at_version=3, dispatched_at_version=1)
    2
    >>> spec = idx.override_group(2, 0)    # repeat group 0 at version 2
    >>> (idx.for_version(2).group, idx.for_version(2).index)
    (0, 2)
    >>> spec.phase
    'partial'
    """

    specs: tuple[RoundSpec, ...]
    # Controller-installed per-version redirects (version -> spec).  Excluded
    # from eq/hash: two indices over the same schedule stay interchangeable
    # keys regardless of what a controller did to one of them.
    overrides: dict[int, RoundSpec] = dataclasses.field(
        default_factory=dict, compare=False, repr=False)

    @classmethod
    def from_rounds(cls, rounds: Sequence[RoundSpec]) -> "ScheduleIndex":
        specs = tuple(rounds)
        if not specs:
            raise ValueError("ScheduleIndex needs at least one RoundSpec")
        return cls(specs=specs)

    def for_version(self, version: int) -> RoundSpec:
        """The spec governing dispatches while the server is at ``version``."""
        if version < 0:
            raise ValueError(f"server version must be >= 0, got {version}")
        if version in self.overrides:
            return self.overrides[version]
        return self.specs[min(version, len(self.specs) - 1)]

    def override_group(self, version: int, group: int) -> RoundSpec:
        """Pin the layer group trained at ``version`` (controller actuator).

        The override inherits the base spec's ``index`` and ``cycle`` —
        history numbering, eval cadence, and the run's round budget are
        unchanged — and takes ``phase="partial"`` for a real group (or the
        base phase when re-pinning a full-network round).  Returns the
        installed spec."""
        base = self.specs[min(version, len(self.specs) - 1)]
        spec = RoundSpec(index=base.index,
                         phase="partial" if group >= 0 else base.phase,
                         cycle=base.cycle, group=int(group))
        self.overrides[version] = spec
        return spec

    @staticmethod
    def staleness(completed_at_version: int, dispatched_at_version: int) -> int:
        """Server versions the model advanced while the update was in flight."""
        return max(completed_at_version - dispatched_at_version, 0)

    def __len__(self) -> int:
        return len(self.specs)

    def __iter__(self) -> Iterator[RoundSpec]:
        return iter(self.specs)


PLAN_KINDS = ("homogeneous", "nested", "random")


def round_base_mask(spec: RoundSpec, num_groups: int) -> np.ndarray:
    """The homogeneous round mask for ``spec``: all groups on FNU rounds,
    one-hot ``spec.group`` otherwise.  The single source of truth both for
    ``PlanAssigner.base_mask`` and for the engines' homogeneous-plan
    collapse check (``fl.batched.resolve_plan``)."""
    mask = np.zeros(num_groups, dtype=bool)
    if spec.is_full:
        mask[:] = True
    else:
        mask[spec.group] = True
    return mask


@dataclasses.dataclass(frozen=True)
class PlanAssigner:
    """Capacity tiers -> per-client *layer plans* (heterogeneity axis).

    The base ``FedPartSchedule`` names one group per round for the whole
    cohort.  Real fleets are capacity-heterogeneous (FedPLT, arXiv:2605.02337):
    a phone-class client cannot train the deepest blocks a workstation can.
    ``PlanAssigner`` lifts the round's single ``RoundSpec`` entry into a
    **per-client group bitmask** — ``assign`` returns a ``(clients, M)`` bool
    array saying which layer groups each client trains this round.  Clients
    are mapped onto ``capacity_tiers`` (fractions of the model a tier can
    hold, shallow-first) round-robin by client id, so tier membership is
    stable across rounds and engines.

    Three plan kinds:

    * ``"homogeneous"`` — every client trains exactly the scheduled group
      (all groups on FNU rounds): today's behaviour, tiers ignored.
      ``assign`` returns ``None`` so every consumer can keep its legacy
      (bit-identical) path.
    * ``"nested"`` — FedPLT-style *prefixes*: a tier with capacity ``c``
      owns the shallowest ``ceil(c * M)`` groups.  FNU rounds train the
      whole prefix; a partial round scheduled for group ``g`` trains
      ``min(g, prefix - 1)`` — capable clients follow the schedule, weak
      clients keep refining the deepest group they can hold, and deep groups
      are averaged over only the clients that actually trained them.
    * ``"random"`` — seeded per-(round, client) subsets: each client draws
      ``ceil(c * M)`` distinct groups from its own deterministic stream
      (``seed``, round index, client id), modelling fleets where per-round
      trainability is arbitrary (memory pressure, partial checkpoints).

    Every client always trains at least one group, so dispatches are never
    vacuous; a *group* nobody picked is still well-defined at aggregation
    time (the global stays frozen verbatim — see ``core.aggregation``).

    >>> pa = PlanAssigner(num_groups=4, kind="nested",
    ...                   capacity_tiers=(0.5, 1.0))
    >>> pa.prefix_len(0), pa.prefix_len(1)    # tier 0 -> 2 groups, tier 1 -> 4
    (2, 4)
    >>> plan = pa.assign(RoundSpec(0, "partial", 0, 3), [0, 1])
    >>> plan.astype(int).tolist()             # client 0 clamps 3 -> 1
    [[0, 1, 0, 0], [0, 0, 0, 1]]
    >>> pa.assign(RoundSpec(0, "warmup", -1, FULL_NETWORK),
    ...           [0, 1]).astype(int).tolist()
    [[1, 1, 0, 0], [1, 1, 1, 1]]
    >>> PlanAssigner(num_groups=4).assign(
    ...     RoundSpec(0, "partial", 0, 2), [0, 1]) is None   # homogeneous
    True
    """

    num_groups: int
    kind: str = "homogeneous"
    capacity_tiers: tuple[float, ...] = (1.0,)
    seed: int = 0

    def __post_init__(self):
        if self.kind not in PLAN_KINDS:
            raise ValueError(
                f"unknown plan kind {self.kind!r}; expected one of {PLAN_KINDS}")
        if self.num_groups < 1:
            raise ValueError(f"num_groups must be >= 1, got {self.num_groups}")
        tiers = tuple(float(c) for c in self.capacity_tiers) or (1.0,)
        if any(not (0.0 < c <= 1.0) for c in tiers):
            raise ValueError(
                f"capacity tiers must lie in (0, 1], got {tiers}")
        object.__setattr__(self, "capacity_tiers", tiers)

    # -- tier bookkeeping ---------------------------------------------------

    def tier_of(self, client_id: int) -> int:
        """Stable round-robin tier assignment by client id."""
        return int(client_id) % len(self.capacity_tiers)

    def capacity_of(self, client_id: int) -> float:
        return self.capacity_tiers[self.tier_of(client_id)]

    def prefix_len(self, client_id: int, boost: int = 0) -> int:
        """Groups a client can hold: ``ceil(capacity * M)``, at least 1.

        ``boost`` extends the prefix by that many extra groups (clamped to
        ``M``) — the PlanAssignmentController's actuator (docs/CONTROL.md):
        a positive boost recruits every tier for deeper groups than its
        capacity alone would assign.  0 (the default, and every static run)
        is the capacity-honest assignment, bit-for-bit."""
        c = self.capacity_of(client_id)
        base = max(1, min(self.num_groups, int(np.ceil(c * self.num_groups))))
        if boost:
            base = max(1, min(self.num_groups, base + int(boost)))
        return base

    # -- plan construction --------------------------------------------------

    def base_mask(self, spec: RoundSpec) -> np.ndarray:
        """The homogeneous round mask: all groups on FNU, one-hot otherwise."""
        return round_base_mask(spec, self.num_groups)

    def assign(self, spec: RoundSpec, client_ids: Sequence[int],
               boost: int = 0) -> np.ndarray | None:
        """Per-client plan for ``spec``: ``(len(client_ids), num_groups)``
        bool bitmask, or ``None`` for the homogeneous kind (consumers keep
        their legacy single-group path, bit-for-bit).  ``boost`` extends
        every client's prefix/subset size by that many groups (see
        ``prefix_len``; 0 = capacity-honest, the static default)."""
        if self.kind == "homogeneous":
            return None
        plan = np.zeros((len(client_ids), self.num_groups), dtype=bool)
        if self.kind == "nested":
            for i, ci in enumerate(client_ids):
                pre = self.prefix_len(ci, boost)
                if spec.is_full:
                    plan[i, :pre] = True
                else:
                    plan[i, min(spec.group, pre - 1)] = True
            return plan
        # "random": one deterministic stream per (seed, round, client) so a
        # client's draw is independent of cohort composition and engine.
        for i, ci in enumerate(client_ids):
            k = self.prefix_len(ci, boost)
            rng = np.random.default_rng(
                (self.seed, int(spec.index), int(ci)))
            plan[i, rng.choice(self.num_groups, size=k, replace=False)] = True
        return plan


@dataclasses.dataclass(frozen=True)
class FNUSchedule:
    """Baseline: every round trains the full network (FedAvg et al.)."""

    total: int

    def rounds(self) -> list[RoundSpec]:
        return [RoundSpec(i, "warmup", -1, FULL_NETWORK) for i in range(self.total)]

    def __iter__(self) -> Iterator[RoundSpec]:
        return iter(self.rounds())

    @property
    def total_rounds(self) -> int:
        return self.total


def matched_fnu(schedule: FedPartSchedule) -> FNUSchedule:
    """FNU baseline with the same number of communication rounds."""
    return FNUSchedule(total=schedule.total_rounds)
