"""Communication / computation cost model (paper §3.4, Eq. 5–6).

Communication is exact: bytes of the parameters transmitted per round
(upstream, per client — matching the paper's "Comm." metric).

Computation uses the standard fwd/bwd decomposition.  Two bookkeepings are
provided:

* ``paper_compute_ratio``     — the paper's Eq. 6 accounting: a partial round
  training group *i* is charged full forward plus ``i/M`` of a full backward.
  With bwd ≈ 2×fwd this telescopes to the paper's ≈2/3.
* ``truncated_compute_ratio`` — the sharper model: backprop to group *i* needs
  the activation-gradient chain from the output down to *i* (suffix) plus the
  weight gradient of *i* only; frozen layers never materialise weight grads.
  This gives ≈1/2 for uniform layers.  (DESIGN.md §6 documents why the paper's
  own wording — "no grads for layers preceding the trainable ones" — matches
  neither derivation; we implement both and flag the gap.)
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.partition import Partition, group_param_bytes
from repro.core.schedule import RoundSpec


# ---------------------------------------------------------------------------
# Communication
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CommReport:
    per_round_bytes: np.ndarray     # upstream bytes per client per round
    total_bytes: int
    fnu_total_bytes: int

    @property
    def ratio_to_fnu(self) -> float:
        return self.total_bytes / max(self.fnu_total_bytes, 1)


def comm_cost(
    params,
    partition: Partition,
    rounds: Sequence[RoundSpec],
    compression=None,
) -> CommReport:
    """Upstream bytes per client per round.

    With ``compression`` (a ``core.compress.CompressionConfig``) the per-group
    bytes are the *encoded* wire sizes (payload + per-block scales + top-k
    indices — ``compress.group_encoded_bytes``); ``fnu_total_bytes`` stays the
    dense-f32 FNU baseline so ``ratio_to_fnu`` reports the combined
    partial-round x compression saving."""
    group_bytes = group_param_bytes(params, partition)
    fnu_full = int(group_bytes.sum())
    if compression is not None:
        from repro.core import compress

        group_bytes = compress.group_encoded_bytes(params, partition,
                                                   compression)
    full = int(group_bytes.sum())
    per_round = np.array(
        [full if r.is_full else int(group_bytes[r.group]) for r in rounds],
        dtype=np.int64,
    )
    return CommReport(
        per_round_bytes=per_round,
        total_bytes=int(per_round.sum()),
        fnu_total_bytes=fnu_full * len(rounds),
    )


# ---------------------------------------------------------------------------
# Computation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CompReport:
    per_round_flops: np.ndarray     # per client per local step, forward+backward
    total_flops: int
    fnu_total_flops: int

    @property
    def ratio_to_fnu(self) -> float:
        return self.total_flops / max(self.fnu_total_flops, 1)


def _norm_group_fwd(partition: Partition, group_fwd_flops: Sequence[float] | None):
    if group_fwd_flops is None:
        return np.ones(partition.num_groups, dtype=np.float64)
    arr = np.asarray(group_fwd_flops, dtype=np.float64)
    assert arr.shape == (partition.num_groups,)
    return arr


def comp_cost(
    partition: Partition,
    rounds: Sequence[RoundSpec],
    group_fwd_flops: Sequence[float] | None = None,
    bwd_fwd_ratio: float = 2.0,
    bookkeeping: str = "truncated",
) -> CompReport:
    """FLOPs per round under the chosen bookkeeping ("paper" or "truncated")."""
    fwd = _norm_group_fwd(partition, group_fwd_flops)
    m = partition.num_groups
    full_fwd = float(fwd.sum())
    full_bwd = bwd_fwd_ratio * full_fwd
    full_round = full_fwd + full_bwd

    def partial_round(g: int) -> float:
        if bookkeeping == "paper":
            # Eq. 6: forward everywhere + (position/M) of a full backward.
            frac = (g + 1) / m
            return full_fwd + frac * full_bwd
        if bookkeeping == "truncated":
            # Activation-grad chain over the suffix (groups >= g), each costing
            # ~= its forward, plus the weight grad of group g (~= its forward).
            act_chain = float(fwd[g:].sum())
            weight_grad = float(fwd[g])
            return full_fwd + act_chain + weight_grad
        raise ValueError(f"unknown bookkeeping {bookkeeping!r}")

    per_round = np.array(
        [full_round if r.is_full else partial_round(r.group) for r in rounds],
        dtype=np.float64,
    )
    return CompReport(
        per_round_flops=per_round,
        total_flops=int(per_round.sum()),
        fnu_total_flops=int(full_round * len(rounds)),
    )


def plan_step_flops(
    partition: Partition,
    groups: Sequence[int],
    group_fwd_flops: Sequence[float] | None = None,
    bwd_fwd_ratio: float = 2.0,
) -> float:
    """Per-step FLOPs for a client training an arbitrary *set* of layer
    groups (per-client layer plans, docs/HETEROGENEITY.md), truncated
    bookkeeping: full forward, activation-grad chain from the output down to
    the shallowest trained group, weight grads for exactly the trained
    groups.  A set covering every group is the FNU round cost; a singleton
    ``{g}`` equals ``comp_cost``'s truncated partial round for ``g``."""
    fwd = _norm_group_fwd(partition, group_fwd_flops)
    sel = sorted({int(g) for g in groups})
    if not sel:
        raise ValueError("a plan step needs at least one trained group")
    full_fwd = float(fwd.sum())
    if len(sel) == partition.num_groups:
        return full_fwd + bwd_fwd_ratio * full_fwd
    act_chain = float(fwd[sel[0]:].sum())
    weight_grads = float(fwd[sel].sum())
    return full_fwd + act_chain + weight_grads


# ---------------------------------------------------------------------------
# Optimizer-step kernel book (fused masked Adam, docs/KERNELS.md)
# ---------------------------------------------------------------------------
#
# The local Adam step is memory-bound: ~12 flops/param against 4 B/param per
# array pass.  The book below models the HBM traffic of the two realisations
# the engines can take — it feeds ``benchmarks/kernels_bench.py``'s derived
# columns and the roofline notes in docs/KERNELS.md, and is deliberately
# *separate* from the paper-metric books above (``comm_cost``/``comp_cost``
# stay byte-for-byte what tests/test_engine_equivalence.py pins).

F32_BYTES = 4
#: fused Pallas kernel: p,g,m,v read + p,m,v written, one pass each.
FUSED_ADAM_PASSES = 7
#: unfused element-wise XLA lowering of the same update: m, v, m-hat, v-hat
#: and p each materialise as a separate read-modify-write (3+3+2+2+4 passes).
UNFUSED_ADAM_PASSES = 14
#: per trained param: 2 EMA updates (4), bias corrections (2), sqrt+eps+div
#: (3), lr scale + subtract (2), mask select (1).
ADAM_FLOPS_PER_PARAM = 12


def adam_step_bytes(n_params: int, *, fused: bool,
                    trained_fraction: float = 1.0) -> int:
    """HBM bytes of one masked-Adam step over ``n_params`` f32 params.

    The fused kernel streams every block once (4 read passes) but skips the
    write-back of frozen blocks (``@pl.when`` on the block mask), so writes
    scale with the trained fraction; the unfused lowering reads and writes
    everything regardless of the mask."""
    if not 0.0 <= trained_fraction <= 1.0:
        raise ValueError(f"trained_fraction must be in [0,1], got {trained_fraction}")
    passes = (4.0 + 3.0 * trained_fraction) if fused \
        else float(UNFUSED_ADAM_PASSES)
    return int(F32_BYTES * passes * n_params)


def adam_step_flops(n_params: int, trained_fraction: float = 1.0) -> int:
    """Arithmetic cost of the same step — trained blocks only; frozen blocks
    are pure copies in both realisations."""
    return int(ADAM_FLOPS_PER_PARAM * n_params * trained_fraction)


def fused_adam_traffic_ratio(trained_fraction: float = 1.0) -> float:
    """Unfused/fused byte ratio: the roofline *upper bound* on the speedup
    the fused kernel can deliver on a memory-bound part (2.0x at full
    training, 3.5x when every block is frozen)."""
    return UNFUSED_ADAM_PASSES / (4.0 + 3.0 * trained_fraction)


# ---------------------------------------------------------------------------
# Virtual time (async runtime)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class VirtualTimeModel:
    """Maps the cost ledger onto a *virtual* wall-clock for the async runtime.

    The event-driven simulator (``repro.fl.runtime``) needs a duration for
    every dispatched client round: local compute scaled by the client's speed
    multiplier, plus up/down transfer of the round's transmitted subtree, plus
    a fixed network latency — all in simulated seconds.  The absolute scales
    are arbitrary (only ratios matter for time-to-accuracy comparisons); the
    defaults are calibrated to the repo's *test-scale* workloads — where
    "flops" are the param-count proxy ``comp_cost`` books — so a full-network
    round lands at O(0.1-1) virtual seconds instead of microseconds.

    ``round_seconds`` is deliberately deterministic — stochastic jitter and
    speed heterogeneity live in the availability model
    (``repro.fl.runtime.clients``), which passes them in as multipliers.
    """

    flops_per_second: float = 1e6
    bytes_per_second: float = 1e6
    base_latency_s: float = 0.0

    def comp_seconds(self, flops: float, speed: float = 1.0) -> float:
        if speed <= 0.0:
            raise ValueError(f"client speed multiplier must be > 0, got {speed}")
        return float(flops) / (self.flops_per_second * speed)

    def comm_seconds(self, nbytes: float) -> float:
        return float(nbytes) / self.bytes_per_second

    def round_seconds(
        self,
        flops: float,
        nbytes: float,
        *,
        speed: float = 1.0,
        jitter: float = 1.0,
    ) -> float:
        """One client's dispatch->completion duration: download + local
        training + upload (the transmitted subtree travels both ways)."""
        if jitter <= 0.0:
            raise ValueError(f"latency jitter multiplier must be > 0, got {jitter}")
        base = (
            self.comp_seconds(flops, speed)
            + 2.0 * self.comm_seconds(nbytes)
            + self.base_latency_s
        )
        return base * jitter

    def occupancy(self) -> "SubmeshOccupancy":
        """Fresh submesh-occupancy book for one run: the async runtime books
        every cohort's virtual span (dispatch → last completion) against the
        submesh that hosted it, so the timeline can report how much of the
        run actually overlapped (``SubmeshOccupancy``)."""
        return SubmeshOccupancy()


def overlap_of_spans(spans: Sequence[tuple[float, float]]) -> float:
    """Total time during which >= 2 of the ``(start, end)`` spans are active
    simultaneously (closes sort before opens at ties, so back-to-back spans
    don't count).  Shared by ``SubmeshOccupancy`` and
    ``telemetry.Timeline.overlap_seconds``."""
    edges: list[tuple[float, int]] = []
    for s, e in spans:
        edges += [(s, 1), (e, -1)]
    edges.sort()
    total, depth, last = 0.0, 0, 0.0
    for t, d in edges:
        if depth >= 2:
            total += t - last
        depth += d
        last = t
    return total


def max_concurrency_of_spans(spans: Sequence[tuple[float, float]]) -> int:
    """Peak number of simultaneously active ``(start, end)`` spans."""
    edges: list[tuple[float, int]] = []
    for s, e in spans:
        edges += [(s, 1), (e, -1)]
    edges.sort()                      # ties: close (-1) before open (+1)
    depth = peak = 0
    for _, d in edges:
        depth += d
        peak = max(peak, depth)
    return peak


@dataclasses.dataclass
class SubmeshOccupancy:
    """Virtual-time occupancy ledger for host-parallel async dispatch.

    The event-driven runtime (``repro.fl.runtime``) trains up to
    ``max_inflight_cohorts`` cohorts concurrently on disjoint submeshes; this
    book records, per submesh, the virtual-time span each hosted cohort
    occupied (dispatch → last member completion).  From it fall out the
    quantities ``async_bench.py`` sweeps: per-submesh busy time, how much of
    the run ≥2 cohorts genuinely overlapped, and peak concurrency — the
    evidence that inflight > 1 changed the *timeline* (the aggregation math
    is unchanged; docs/ASYNC.md).  ``submesh = -1`` marks cohorts that ran
    unbound (no pool / queued past exhaustion).
    """

    spans: list[tuple[int, float, float]] = dataclasses.field(
        default_factory=list)

    def book(self, submesh: int, start: float, end: float) -> None:
        if end < start:
            raise ValueError(f"occupancy span ends before it starts: "
                             f"[{start}, {end}]")
        self.spans.append((int(submesh), float(start), float(end)))

    def _merged(self, spans) -> list[tuple[float, float]]:
        out: list[list[float]] = []
        for s, e in sorted((s, e) for _, s, e in spans):
            if out and s <= out[-1][1]:
                out[-1][1] = max(out[-1][1], e)
            else:
                out.append([s, e])
        return [(s, e) for s, e in out]

    def busy_seconds(self, submesh: int | None = None) -> float:
        """Union length of the (optionally per-submesh) occupied spans."""
        spans = (self.spans if submesh is None
                 else [sp for sp in self.spans if sp[0] == submesh])
        return sum(e - s for s, e in self._merged(spans))

    def overlap_seconds(self) -> float:
        """Virtual time during which at least two cohorts were in flight."""
        return overlap_of_spans([(s, e) for _, s, e in self.spans])

    def max_concurrency(self) -> int:
        return max_concurrency_of_spans([(s, e) for _, s, e in self.spans])

    def summary(self) -> dict:
        """The occupancy roll-up the runtime logs into the Timeline."""
        meshes = sorted({s for s, _, _ in self.spans})
        return {
            "cohorts": len(self.spans),
            "submeshes": len(meshes),
            "busy_seconds": {int(m): self.busy_seconds(m) for m in meshes},
            "overlap_seconds": self.overlap_seconds(),
            "max_concurrency": self.max_concurrency(),
        }


def paper_asymptotic_comp_ratio(bwd_fwd_ratio: float = 2.0) -> float:
    """Eq. 6's closed form: (M·D_f + (M+1)/2·D_b) / (M·(D_f+D_b)) -> 2/3."""
    return (1.0 + bwd_fwd_ratio / 2.0) / (1.0 + bwd_fwd_ratio)


def comm_asymptotic_ratio(num_groups: int) -> float:
    """Eq. 5: partial rounds move 1/M of the FNU bytes (uniform groups)."""
    return 1.0 / num_groups
