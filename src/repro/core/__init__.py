"""Core FedPart library: the paper's contribution as composable JAX pieces.

- ``partition``   — ordered layer-group partitioning of parameter pytrees
- ``masking``     — mask / pruned-subtree forms of the Eq. 1 update
- ``schedule``    — trainable-layer selection schedules (§3.2)
- ``aggregation`` — full / partial server averaging
- ``costs``       — Eq. 5/6 communication & computation cost model
- ``telemetry``   — step-size tracking (Fig. 1), Monte-Carlo k (App. G)
"""

from repro.core.partition import (  # noqa: F401
    Partition,
    build_partition,
    default_group_key,
    group_param_bytes,
    group_param_counts,
    total_param_bytes,
    total_param_count,
)
from repro.core.masking import (  # noqa: F401
    apply_mask,
    complement,
    mask_tree,
    merge,
    select,
    tree_update,
)
from repro.core.schedule import (  # noqa: F401
    FULL_NETWORK,
    FedPartSchedule,
    FNUSchedule,
    RoundSpec,
    matched_fnu,
)
from repro.core.aggregation import (  # noqa: F401
    aggregate_full,
    aggregate_partial,
    tree_mean,
)
from repro.core import costs, telemetry  # noqa: F401
