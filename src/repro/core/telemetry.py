"""Training telemetry: update-step-size tracking (Fig. 1) and the
Monte-Carlo estimate of the mask-uniformity constant k (Appendix G).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masking
from repro.core.partition import Partition

PyTree = Any


# ---------------------------------------------------------------------------
# Update step sizes (Fig. 1)
# ---------------------------------------------------------------------------

def update_step_size(prev: PyTree, new: PyTree) -> float:
    """Global L2 norm of the parameter update ‖w_{t+1} − w_t‖."""
    sq = jax.tree.reduce(
        lambda acc, x: acc + x,
        jax.tree.map(
            lambda a, b: jnp.sum((b.astype(jnp.float32) - a.astype(jnp.float32)) ** 2),
            prev,
            new,
        ),
        jnp.float32(0.0),
    )
    return float(jnp.sqrt(sq))


@dataclasses.dataclass
class StepSizeTracker:
    """Records ‖Δw‖ per local iteration plus round-boundary markers.

    Reproduces Fig. 1: under FNU the step size spikes right after each server
    averaging (layer mismatch); under FedPart the spikes shrink.
    """

    sizes: list[float] = dataclasses.field(default_factory=list)
    boundaries: list[int] = dataclasses.field(default_factory=list)

    def record(self, prev: PyTree, new: PyTree) -> None:
        self.sizes.append(update_step_size(prev, new))

    def mark_round_boundary(self) -> None:
        self.boundaries.append(len(self.sizes))

    def post_aggregation_spike(self, window: int = 3) -> float:
        """Mean ratio of step size just after vs. just before each boundary.

        > 1 means averaging disturbed the model (layer mismatch); FedPart
        should yield a ratio much closer to 1 than FNU.
        """
        ratios = []
        for b in self.boundaries:
            if b - window < 0 or b + window > len(self.sizes):
                continue
            before = np.mean(self.sizes[b - window : b])
            after = np.mean(self.sizes[b : b + window])
            if before > 0:
                ratios.append(after / before)
        return float(np.mean(ratios)) if ratios else float("nan")


# ---------------------------------------------------------------------------
# Virtual-time timeline (async runtime): time-to-accuracy as first-class output
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Timeline:
    """Event log of a federated run against the *virtual* clock.

    The async runtime (``repro.fl.runtime``) books every dispatch, client
    completion/drop, server merge, and evaluation here with its simulated
    timestamp, so time-to-accuracy curves — the quantity the async literature
    optimises — come out of a run as first-class data instead of being
    re-derived from round counts.

    Events are dicts with at least ``{"t", "kind"}`` where ``t`` is in
    **virtual seconds** (``core.costs.VirtualTimeModel`` units — only ratios
    between configs are meaningful).  Per kind:

    * ``"dispatch"`` — ``{"version", "group", "clients", "t_end"}``: a cohort
      sampled while the server sat at ``version``; ``t_end`` is its last
      member's completion time (the cohort's *span* is ``[t, t_end]``).
    * ``"complete"`` / ``"drop"`` — ``{"client", "comp_flops", ...}``; a
      completion adds ``staleness`` (server versions committed since its
      dispatch) and the delivered ``comm_bytes``; drops burn compute but
      deliver nothing upstream.
    * ``"merge"`` — ``{"version", "group", "loss", "merged",
      "staleness_mean", "staleness_max"}``: server aggregation number
      ``version`` (0-based merge index) committed the buffer; ``group`` is
      the layer group its schedule entry trained (-1 = full network).
    * ``"eval"`` — ``{"version", "acc"}`` on the eval cadence.
    * ``"control"`` — a server controller's knob adjustment
      (docs/CONTROL.md), recorded at the merge that triggered it.
    * ``"wait"`` — ``{"until", "rejected"}``: every sampled candidate was
      unavailable, so the server booked a deterministic retry at ``until``
      instead of training anyone (docs/ASYNC.md).

    >>> tl = Timeline()
    >>> tl.record(0.5, "eval", version=0, acc=0.25)
    >>> tl.record(1.5, "eval", version=1, acc=0.75)
    >>> tl.total_seconds
    1.5
    >>> tl.time_to_accuracy(0.5)
    1.5
    >>> [e["acc"] for e in tl.of_kind("eval")]
    [0.25, 0.75]
    """

    events: list[dict] = dataclasses.field(default_factory=list)

    def record(self, t: float, kind: str, **fields) -> None:
        """Append one event at virtual time ``t`` (events are kept in the
        order they were recorded, which is causal order for the runtime)."""
        self.events.append({"t": float(t), "kind": kind, **fields})

    def of_kind(self, kind: str) -> list[dict]:
        """All events of ``kind``, in recorded (causal) order."""
        return [e for e in self.events if e["kind"] == kind]

    def window(self, last_merges: int = 1) -> "TimelineWindow":
        """The merge-aligned observation window over the last ``last_merges``
        server aggregations — the :class:`TimelineWindow` a
        ``ServerController`` observes between merges (docs/CONTROL.md).

        The window ends at the most recent merge event and reaches back
        ``last_merges`` merges: its events are everything recorded *after*
        the boundary merge (exclusive) through the end of the log, so the
        trailing eval of the final merge is included.  ``t_start`` is the
        boundary merge's timestamp (0.0 when the window spans the whole
        run); ``t_end`` is the final merge's.  With no merges recorded yet
        the window is empty (``t_start == t_end == 0.0``).

        >>> tl = Timeline()
        >>> tl.record(1.0, "merge", version=0, group=0, loss=2.0)
        >>> tl.record(3.0, "merge", version=1, group=1, loss=1.0)
        >>> w = tl.window(1)
        >>> (w.t_start, w.t_end, len(w.events))
        (1.0, 3.0, 1)
        >>> tl.window(5).t_start      # clamps to the start of the run
        0.0
        >>> Timeline().window().duration
        0.0
        """
        if last_merges < 1:
            raise ValueError(f"last_merges must be >= 1, got {last_merges}")
        pos = [i for i, e in enumerate(self.events) if e["kind"] == "merge"]
        if not pos:
            return TimelineWindow(t_start=0.0, t_end=0.0, events=[])
        t_end = self.events[pos[-1]]["t"]
        if len(pos) > last_merges:
            boundary = pos[-1 - last_merges]
            return TimelineWindow(t_start=self.events[boundary]["t"],
                                  t_end=t_end,
                                  events=self.events[boundary + 1:])
        return TimelineWindow(t_start=0.0, t_end=t_end,
                              events=list(self.events))

    @property
    def total_seconds(self) -> float:
        return max((e["t"] for e in self.events), default=0.0)

    @property
    def delivered_comm_bytes(self) -> int:
        """Upstream bytes of updates that actually reached the server."""
        return int(sum(e.get("comm_bytes", 0) for e in self.of_kind("complete")))

    @property
    def spent_comp_flops(self) -> float:
        """Local-training FLOPs spent, including dropped clients' wasted work."""
        return float(sum(e.get("comp_flops", 0.0)
                         for e in self.of_kind("complete") + self.of_kind("drop")))

    def cohort_spans(self) -> list[tuple[int, float, float]]:
        """``(submesh, dispatch_t, last_completion_t)`` per dispatched cohort
        (host-parallel runtime: dispatch events carry their submesh binding
        and booked span; ``-1`` = unbound)."""
        return [(int(e.get("submesh", -1)), e["t"], e["t_end"])
                for e in self.of_kind("dispatch") if "t_end" in e]

    def overlap_seconds(self) -> float:
        """Virtual time with >=2 cohorts concurrently in flight — the
        quantity ``max_inflight_cohorts > 1`` exists to create."""
        from repro.core.costs import overlap_of_spans

        return overlap_of_spans([(s, e) for _, s, e in self.cohort_spans()])

    def accuracy_curve(self) -> list[tuple[float, float]]:
        """``(virtual_seconds, accuracy)`` per evaluation, time-ordered."""
        return [(e["t"], e["acc"]) for e in sorted(self.of_kind("eval"),
                                                   key=lambda e: e["t"])]

    def time_to_accuracy(self, threshold: float) -> float:
        """First virtual time the eval accuracy reaches ``threshold``
        (``inf`` if it never does) — the sweep metric in async_bench."""
        for t, acc in self.accuracy_curve():
            if acc >= threshold:
                return t
        return float("inf")


@dataclasses.dataclass
class TimelineWindow:
    """A merge-aligned slice of a :class:`Timeline` with the windowed
    reducers a server controller observes (docs/CONTROL.md).

    Built by :meth:`Timeline.window`.  ``t_start`` / ``t_end`` are virtual
    seconds (the boundary merge's and final merge's timestamps); ``events``
    are the raw event dicts recorded after the boundary merge.  All reducers
    are pure functions of ``events`` — virtual-event-only, so anything
    decided from them is host- and device-count independent.

    >>> tl = Timeline()
    >>> tl.record(0.0, "dispatch", version=0, group=0, clients=[0, 1],
    ...           t_end=2.0)
    >>> tl.record(1.0, "complete", client=0, staleness=0, comm_bytes=8,
    ...           comp_flops=4.0)
    >>> tl.record(2.0, "complete", client=1, staleness=2, comm_bytes=8,
    ...           comp_flops=4.0)
    >>> tl.record(2.0, "merge", version=0, group=0, loss=2.0)
    >>> w = tl.window()
    >>> w.duration
    2.0
    >>> w.staleness_moments()
    (1.0, 2.0)
    >>> w.effective_participation(4)
    0.5
    >>> w.span_seconds()
    2.0
    """

    t_start: float
    t_end: float
    events: list[dict]

    def of_kind(self, kind: str) -> list[dict]:
        return [e for e in self.events if e["kind"] == kind]

    @property
    def duration(self) -> float:
        """Window length in virtual seconds (0.0 for an empty window)."""
        return max(self.t_end - self.t_start, 0.0)

    @property
    def merges(self) -> int:
        """Server aggregations inside the window (<= the requested span)."""
        return len(self.of_kind("merge"))

    def staleness_moments(self) -> tuple[float, float]:
        """First and second moments ``(E[s], E[s^2])`` of the staleness of
        the window's delivered updates — the quantities the
        partial-participation convergence bounds track.  ``(0.0, 0.0)``
        when nothing was delivered.

        >>> TimelineWindow(0.0, 0.0, []).staleness_moments()
        (0.0, 0.0)
        """
        s = [float(e.get("staleness", 0)) for e in self.of_kind("complete")]
        if not s:
            return (0.0, 0.0)
        return (float(np.mean(s)), float(np.mean(np.square(s))))

    def discounted_mix(self, exponent: float) -> float:
        """Mean polynomial staleness discount ``E[(1+s)^-a]`` over the
        window's deliveries — an unweighted estimate of the merge's mixing
        coefficient ``m`` (docs/ASYNC.md).  1.0 when nothing was delivered
        (no evidence the discount is biting) or when ``exponent == 0``.

        >>> w = TimelineWindow(0.0, 1.0, [
        ...     {"t": 0.5, "kind": "complete", "client": 0, "staleness": 0},
        ...     {"t": 1.0, "kind": "complete", "client": 1, "staleness": 3},
        ... ])
        >>> w.discounted_mix(1.0)
        0.625
        >>> w.discounted_mix(0.0)
        1.0
        """
        if exponent == 0.0:
            return 1.0
        s = [float(e.get("staleness", 0)) for e in self.of_kind("complete")]
        if not s:
            return 1.0
        return float(np.mean([(1.0 + x) ** (-exponent) for x in s]))

    def effective_participation(self, num_clients: int, *,
                                inverse_probability: bool = False) -> float:
        """Fraction of the fleet that *delivered* an update inside the
        window — distinct completing clients over ``num_clients`` (the
        effective-participation rate of Sen et al.).  Drops don't count.

        With ``inverse_probability=True`` each distinct client counts
        ``1 / inclusion_prob`` (its complete events' recorded inclusion
        probability, 1.0 when absent) — the Horvitz–Thompson estimate of
        the fleet coverage an availability-*biased* cohort sampler is
        achieving (docs/ASYNC.md): a delivered low-duty client stands in
        for the rarely-on slice of the fleet it was sampled from.  Clipped
        to 1.0; identical to the plain rate when every prob is 1.0.

        >>> w = TimelineWindow(0.0, 1.0, [
        ...     {"t": 0.5, "kind": "complete", "client": 0,
        ...      "inclusion_prob": 0.25},
        ...     {"t": 1.0, "kind": "complete", "client": 1,
        ...      "inclusion_prob": 1.0},
        ... ])
        >>> w.effective_participation(8)
        0.25
        >>> w.effective_participation(8, inverse_probability=True)
        0.625
        """
        if num_clients < 1:
            raise ValueError(f"num_clients must be >= 1, got {num_clients}")
        seen: dict[int, float] = {}
        for e in self.of_kind("complete"):
            seen[e["client"]] = float(e.get("inclusion_prob", 1.0))
        if not inverse_probability:
            return len(seen) / num_clients
        est = sum(1.0 / max(p, 1.0 / num_clients) for p in seen.values())
        return min(est / num_clients, 1.0)

    def inclusion_moments(self) -> tuple[float, float]:
        """``(mean, min)`` of the inclusion probabilities recorded on the
        window's deliveries (``(1.0, 1.0)`` when nothing was delivered or
        nothing recorded one) — how skewed the arrivals the merge had to
        debias actually were.

        >>> TimelineWindow(0.0, 0.0, []).inclusion_moments()
        (1.0, 1.0)
        """
        probs = [float(e.get("inclusion_prob", 1.0))
                 for e in self.of_kind("complete")]
        if not probs:
            return (1.0, 1.0)
        return (float(np.mean(probs)), float(min(probs)))

    def tier_participation(self, num_tiers: int) -> list[float]:
        """Per capacity tier, the share of the window's deliveries that
        came from that tier (``tier`` on complete events; falls back to
        ``client % num_tiers``, the ``PlanAssigner.tier_of`` convention).
        All zeros when nothing was delivered — the plan-assignment
        controller's per-tier coverage signal.

        >>> w = TimelineWindow(0.0, 1.0, [
        ...     {"t": 0.5, "kind": "complete", "client": 0, "tier": 0},
        ...     {"t": 0.7, "kind": "complete", "client": 1, "tier": 1},
        ...     {"t": 1.0, "kind": "complete", "client": 2, "tier": 0},
        ... ])
        >>> w.tier_participation(2)
        [0.6666666666666666, 0.3333333333333333]
        """
        if num_tiers < 1:
            raise ValueError(f"num_tiers must be >= 1, got {num_tiers}")
        counts = [0] * num_tiers
        total = 0
        for e in self.of_kind("complete"):
            tier = int(e.get("tier", int(e.get("client", 0)) % num_tiers))
            counts[tier % num_tiers] += 1
            total += 1
        if total == 0:
            return [0.0] * num_tiers
        return [c / total for c in counts]

    def _spans(self) -> list[tuple[float, float]]:
        """Cohort spans dispatched inside the window, clipped to it."""
        spans = []
        for e in self.of_kind("dispatch"):
            if "t_end" not in e:
                continue
            s, t = max(e["t"], self.t_start), min(e["t_end"], self.t_end)
            if t > s:
                spans.append((s, t))
        return spans

    def span_seconds(self) -> float:
        """Total in-window flight seconds of the window's cohorts (each
        dispatch's ``[t, t_end]`` span clipped to the window, summed).
        Divided by ``max_inflight * duration`` this is the occupancy of the
        configured in-flight slots — the adaptive-inflight controller's
        utilisation signal."""
        return float(sum(t - s for s, t in self._spans()))

    def overlap_seconds(self) -> float:
        """Virtual seconds with >= 2 of the window's cohorts concurrently in
        flight (clipped to the window) — the windowed form of
        :meth:`Timeline.overlap_seconds`."""
        from repro.core.costs import overlap_of_spans

        return overlap_of_spans(self._spans())

    def group_progress(self) -> dict[int, float]:
        """Per layer group, the windowed merge-loss improvement: first minus
        last merge loss for that group (positive = the group's merges are
        still paying off; 0.0 for a group merged once).  Keys are the merge
        events' ``group`` fields (-1 = full network).

        >>> w = TimelineWindow(0.0, 3.0, [
        ...     {"t": 1.0, "kind": "merge", "version": 0, "group": 2,
        ...      "loss": 2.0},
        ...     {"t": 2.0, "kind": "merge", "version": 1, "group": 2,
        ...      "loss": 1.5},
        ...     {"t": 3.0, "kind": "merge", "version": 2, "group": -1,
        ...      "loss": 1.4},
        ... ])
        >>> w.group_progress()
        {2: 0.5, -1: 0.0}
        """
        losses: dict[int, list[float]] = {}
        for e in self.of_kind("merge"):
            losses.setdefault(int(e.get("group", -1)), []).append(
                float(e["loss"]))
        return {g: ls[0] - ls[-1] for g, ls in losses.items()}


# ---------------------------------------------------------------------------
# Monte-Carlo estimate of k (Assumption 3 / Appendix G)
# ---------------------------------------------------------------------------

def estimate_k(
    per_sample_grads: Sequence[PyTree],
    partition: Partition,
    params_template: PyTree,
    *,
    masks: str = "random",
    num_masks: int = 32,
    seed: int = 0,
) -> float:
    """k = max_S E‖S⊙(g−ḡ)‖ / min_S E‖S⊙(g−ḡ)‖ (Assumption 3, Appendix G).

    ``masks="random"``: the paper's Monte-Carlo setting — random masks of
    density 1/M over the flat parameter vector (paper reports k ≈ 1.1–1.2).
    ``masks="groups"``: the *structured* layer-group masks FedPart actually
    uses.  These concentrate variance very differently across layers, so k is
    much larger — a genuine gap between Assumption 3's Monte-Carlo
    justification and the deployed masks, recorded in EXPERIMENTS.md.
    """
    mean_grad = jax.tree.map(
        lambda *leaves: sum(x.astype(jnp.float32) for x in leaves) / len(leaves),
        *per_sample_grads,
    )
    centred = [
        jax.tree.map(lambda a, b: a.astype(jnp.float32) - b, g, mean_grad)
        for g in per_sample_grads
    ]
    m = partition.num_groups

    if masks == "groups":
        norms = np.zeros(m, dtype=np.float64)
        for c in centred:
            for gi in range(m):
                sub = masking.select(c, partition, gi)
                sq = jax.tree.reduce(
                    lambda acc, x: acc + x,
                    jax.tree.map(lambda x: jnp.sum(x**2), sub),
                    jnp.float32(0.0),
                )
                norms[gi] += float(jnp.sqrt(sq))
        norms /= len(centred)
        norms = norms[norms > 0]
        return float(norms.max() / norms.min()) if norms.size else float("nan")

    # random masks of density 1/M over the flattened gradient
    flats = [
        np.concatenate([np.ravel(np.asarray(x)) for x in jax.tree.leaves(c)])
        for c in centred
    ]
    dim = flats[0].shape[0]
    rng = np.random.default_rng(seed)
    norms = []
    for _ in range(num_masks):
        mask = rng.random(dim) < (1.0 / m)
        norms.append(np.mean([np.linalg.norm(f[mask]) for f in flats]))
    norms = np.asarray(norms)
    norms = norms[norms > 0]
    return float(norms.max() / norms.min()) if norms.size else float("nan")
