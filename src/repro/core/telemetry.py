"""Training telemetry: update-step-size tracking (Fig. 1) and the
Monte-Carlo estimate of the mask-uniformity constant k (Appendix G).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masking
from repro.core.partition import Partition

PyTree = Any


# ---------------------------------------------------------------------------
# Update step sizes (Fig. 1)
# ---------------------------------------------------------------------------

def update_step_size(prev: PyTree, new: PyTree) -> float:
    """Global L2 norm of the parameter update ‖w_{t+1} − w_t‖."""
    sq = jax.tree.reduce(
        lambda acc, x: acc + x,
        jax.tree.map(
            lambda a, b: jnp.sum((b.astype(jnp.float32) - a.astype(jnp.float32)) ** 2),
            prev,
            new,
        ),
        jnp.float32(0.0),
    )
    return float(jnp.sqrt(sq))


@dataclasses.dataclass
class StepSizeTracker:
    """Records ‖Δw‖ per local iteration plus round-boundary markers.

    Reproduces Fig. 1: under FNU the step size spikes right after each server
    averaging (layer mismatch); under FedPart the spikes shrink.
    """

    sizes: list[float] = dataclasses.field(default_factory=list)
    boundaries: list[int] = dataclasses.field(default_factory=list)

    def record(self, prev: PyTree, new: PyTree) -> None:
        self.sizes.append(update_step_size(prev, new))

    def mark_round_boundary(self) -> None:
        self.boundaries.append(len(self.sizes))

    def post_aggregation_spike(self, window: int = 3) -> float:
        """Mean ratio of step size just after vs. just before each boundary.

        > 1 means averaging disturbed the model (layer mismatch); FedPart
        should yield a ratio much closer to 1 than FNU.
        """
        ratios = []
        for b in self.boundaries:
            if b - window < 0 or b + window > len(self.sizes):
                continue
            before = np.mean(self.sizes[b - window : b])
            after = np.mean(self.sizes[b : b + window])
            if before > 0:
                ratios.append(after / before)
        return float(np.mean(ratios)) if ratios else float("nan")


# ---------------------------------------------------------------------------
# Virtual-time timeline (async runtime): time-to-accuracy as first-class output
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Timeline:
    """Event log of a federated run against the *virtual* clock.

    The async runtime (``repro.fl.runtime``) books every dispatch, client
    completion/drop, server merge, and evaluation here with its simulated
    timestamp, so time-to-accuracy curves — the quantity the async literature
    optimises — come out of a run as first-class data instead of being
    re-derived from round counts.

    Events are dicts with at least ``{"t", "kind"}``; merges add
    ``{"version", "loss", "staleness_mean", "staleness_max"}``, evals add
    ``{"version", "acc"}``, completions add the per-update comm bytes and
    comp flops actually spent (dropped clients burn compute but deliver no
    bytes upstream).
    """

    events: list[dict] = dataclasses.field(default_factory=list)

    def record(self, t: float, kind: str, **fields) -> None:
        self.events.append({"t": float(t), "kind": kind, **fields})

    def of_kind(self, kind: str) -> list[dict]:
        return [e for e in self.events if e["kind"] == kind]

    @property
    def total_seconds(self) -> float:
        return max((e["t"] for e in self.events), default=0.0)

    @property
    def delivered_comm_bytes(self) -> int:
        """Upstream bytes of updates that actually reached the server."""
        return int(sum(e.get("comm_bytes", 0) for e in self.of_kind("complete")))

    @property
    def spent_comp_flops(self) -> float:
        """Local-training FLOPs spent, including dropped clients' wasted work."""
        return float(sum(e.get("comp_flops", 0.0)
                         for e in self.of_kind("complete") + self.of_kind("drop")))

    def cohort_spans(self) -> list[tuple[int, float, float]]:
        """``(submesh, dispatch_t, last_completion_t)`` per dispatched cohort
        (host-parallel runtime: dispatch events carry their submesh binding
        and booked span; ``-1`` = unbound)."""
        return [(int(e.get("submesh", -1)), e["t"], e["t_end"])
                for e in self.of_kind("dispatch") if "t_end" in e]

    def overlap_seconds(self) -> float:
        """Virtual time with >=2 cohorts concurrently in flight — the
        quantity ``max_inflight_cohorts > 1`` exists to create."""
        from repro.core.costs import overlap_of_spans

        return overlap_of_spans([(s, e) for _, s, e in self.cohort_spans()])

    def accuracy_curve(self) -> list[tuple[float, float]]:
        """``(virtual_seconds, accuracy)`` per evaluation, time-ordered."""
        return [(e["t"], e["acc"]) for e in sorted(self.of_kind("eval"),
                                                   key=lambda e: e["t"])]

    def time_to_accuracy(self, threshold: float) -> float:
        """First virtual time the eval accuracy reaches ``threshold``
        (``inf`` if it never does) — the sweep metric in async_bench."""
        for t, acc in self.accuracy_curve():
            if acc >= threshold:
                return t
        return float("inf")


# ---------------------------------------------------------------------------
# Monte-Carlo estimate of k (Assumption 3 / Appendix G)
# ---------------------------------------------------------------------------

def estimate_k(
    per_sample_grads: Sequence[PyTree],
    partition: Partition,
    params_template: PyTree,
    *,
    masks: str = "random",
    num_masks: int = 32,
    seed: int = 0,
) -> float:
    """k = max_S E‖S⊙(g−ḡ)‖ / min_S E‖S⊙(g−ḡ)‖ (Assumption 3, Appendix G).

    ``masks="random"``: the paper's Monte-Carlo setting — random masks of
    density 1/M over the flat parameter vector (paper reports k ≈ 1.1–1.2).
    ``masks="groups"``: the *structured* layer-group masks FedPart actually
    uses.  These concentrate variance very differently across layers, so k is
    much larger — a genuine gap between Assumption 3's Monte-Carlo
    justification and the deployed masks, recorded in EXPERIMENTS.md.
    """
    mean_grad = jax.tree.map(
        lambda *leaves: sum(x.astype(jnp.float32) for x in leaves) / len(leaves),
        *per_sample_grads,
    )
    centred = [
        jax.tree.map(lambda a, b: a.astype(jnp.float32) - b, g, mean_grad)
        for g in per_sample_grads
    ]
    m = partition.num_groups

    if masks == "groups":
        norms = np.zeros(m, dtype=np.float64)
        for c in centred:
            for gi in range(m):
                sub = masking.select(c, partition, gi)
                sq = jax.tree.reduce(
                    lambda acc, x: acc + x,
                    jax.tree.map(lambda x: jnp.sum(x**2), sub),
                    jnp.float32(0.0),
                )
                norms[gi] += float(jnp.sqrt(sq))
        norms /= len(centred)
        norms = norms[norms > 0]
        return float(norms.max() / norms.min()) if norms.size else float("nan")

    # random masks of density 1/M over the flattened gradient
    flats = [
        np.concatenate([np.ravel(np.asarray(x)) for x in jax.tree.leaves(c)])
        for c in centred
    ]
    dim = flats[0].shape[0]
    rng = np.random.default_rng(seed)
    norms = []
    for _ in range(num_masks):
        mask = rng.random(dim) < (1.0 / m)
        norms.append(np.mean([np.linalg.norm(f[mask]) for f in flats]))
    norms = np.asarray(norms)
    norms = norms[norms > 0]
    return float(norms.max() / norms.min()) if norms.size else float("nan")
