"""Layer-group partitioning of parameter pytrees.

The paper (Appendix A) numbers the trainable parameters of a model into M
ordered *layer groups* (#1 .. #M), shallow to deep; each conv/block weight
travels together with its accompanying norm parameters.  FedPart trains and
transmits exactly one group per communication round.

This module maps an arbitrary parameter pytree (nested dicts of arrays) onto
such an ordered partition.  Groups are identified by *group keys* derived from
parameter paths; an ordering function sorts the keys shallow -> deep.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Mapping

import jax
import numpy as np

Path = tuple[str, ...]
PyTree = Any


# ---------------------------------------------------------------------------
# Path helpers
# ---------------------------------------------------------------------------

def _key_entry_to_str(entry: Any) -> str:
    """Normalise a jax KeyEntry to a plain string."""
    if isinstance(entry, jax.tree_util.DictKey):
        return str(entry.key)
    if isinstance(entry, jax.tree_util.SequenceKey):
        return str(entry.idx)
    if isinstance(entry, jax.tree_util.GetAttrKey):
        return str(entry.name)
    return str(entry)


def tree_paths(tree: PyTree) -> list[tuple[Path, Any]]:
    """Flatten ``tree`` into ``[(path, leaf), ...]`` with string path parts."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(tuple(_key_entry_to_str(k) for k in path), leaf) for path, leaf in flat]


def path_str(path: Path) -> str:
    return "/".join(path)


# ---------------------------------------------------------------------------
# Group keys
# ---------------------------------------------------------------------------

_SHALLOW_FIRST = ("embed", "embedding", "tok_embed", "patch_embed", "stem", "conv_in")
_DEEP_LAST = ("head", "lm_head", "classifier", "final_norm", "norm_f", "fc_out")

_BLOCK_RE = re.compile(r"^(blocks?|layers?|stages?|enc_blocks?|dec_blocks?)$")


def default_group_key(path: Path) -> tuple:
    """Default grouping: one group per block index, plus embed / head groups.

    Paths like ``("blocks", "3", "attn", "wq")`` map to ``("block", "blocks", 3)``
    so every parameter of block 3 (including its norms) shares a group —
    mirroring the paper's Appendix-A partitioning where conv weights and their
    BN params form one numbered layer.
    """
    head = path[0]
    if head in _SHALLOW_FIRST:
        return ("embed",)
    if head in _DEEP_LAST:
        return ("head",)
    if _BLOCK_RE.match(head) and len(path) > 1 and path[1].isdigit():
        return ("block", head, int(path[1]))
    # Anything else (stand-alone norms, scalars) is its own shallow group keyed
    # by its first path component.
    return ("misc", head)


def default_order_key(group_key: tuple) -> tuple:
    kind = group_key[0]
    if kind == "embed":
        return (0,)
    if kind == "misc":
        return (1, group_key[1])
    if kind == "block":
        # enc blocks before dec blocks, then by index
        return (2, group_key[1], group_key[2])
    if kind == "head":
        return (3,)
    return (9, str(group_key))


# ---------------------------------------------------------------------------
# Partition
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Partition:
    """An ordered partition of parameter paths into layer groups."""

    group_keys: tuple[tuple, ...]                 # ordered, shallow -> deep
    assignment: Mapping[str, int]                 # path_str -> group index

    @property
    def num_groups(self) -> int:
        return len(self.group_keys)

    def group_of(self, path: Path | str) -> int:
        key = path if isinstance(path, str) else path_str(path)
        return self.assignment[key]

    def paths_in(self, group: int) -> list[str]:
        return [p for p, g in self.assignment.items() if g == group]

    def describe(self) -> str:
        lines = []
        for i, key in enumerate(self.group_keys):
            n = sum(1 for g in self.assignment.values() if g == i)
            lines.append(f"#{i + 1}: {key} ({n} tensors)")
        return "\n".join(lines)


def build_partition(
    params: PyTree,
    group_key_fn: Callable[[Path], tuple] = default_group_key,
    order_key_fn: Callable[[tuple], tuple] = default_order_key,
) -> Partition:
    """Build an ordered layer-group partition for ``params``."""
    keys_by_path: dict[str, tuple] = {}
    for path, _ in tree_paths(params):
        keys_by_path[path_str(path)] = group_key_fn(path)
    ordered = sorted(set(keys_by_path.values()), key=order_key_fn)
    index = {k: i for i, k in enumerate(ordered)}
    assignment = {p: index[k] for p, k in keys_by_path.items()}
    return Partition(group_keys=tuple(ordered), assignment=assignment)


# ---------------------------------------------------------------------------
# Sizes / byte accounting (used by core.costs)
# ---------------------------------------------------------------------------

def leaf_count(leaf: Any) -> int:
    return int(np.prod(np.shape(leaf))) if np.ndim(leaf) else 1


def leaf_bytes(leaf: Any) -> int:
    dtype = getattr(leaf, "dtype", np.dtype("float32"))
    return leaf_count(leaf) * np.dtype(dtype).itemsize


def group_param_counts(params: PyTree, partition: Partition) -> np.ndarray:
    counts = np.zeros(partition.num_groups, dtype=np.int64)
    for path, leaf in tree_paths(params):
        counts[partition.group_of(path)] += leaf_count(leaf)
    return counts


def group_param_bytes(params: PyTree, partition: Partition) -> np.ndarray:
    out = np.zeros(partition.num_groups, dtype=np.int64)
    for path, leaf in tree_paths(params):
        out[partition.group_of(path)] += leaf_bytes(leaf)
    return out


def total_param_count(params: PyTree) -> int:
    return int(sum(leaf_count(leaf) for _, leaf in tree_paths(params)))


def total_param_bytes(params: PyTree) -> int:
    return int(sum(leaf_bytes(leaf) for _, leaf in tree_paths(params)))
