"""Server-side aggregation (full and partial).

FNU rounds average every parameter; partial rounds average only the trainable
group's (pruned) subtrees and splice them into the global model.  Per the
paper (§4, following FedBN), client-local statistics (BatchNorm running
moments) are *never* aggregated — on full AND partial rounds alike — they are
filtered by path suffix.

Two layouts are supported:

* list-of-pytrees (``aggregate_full`` / ``aggregate_partial``) — the
  sequential oracle's host-side path;
* a single *stacked* pytree with a leading client axis
  (``*_stacked`` variants) — the batched vmap engine's on-device path, one
  weighted reduction per leaf instead of a Python accumulation loop.

Heterogeneous cohorts (per-client layer plans, docs/HETEROGENEITY.md) use the
``aggregate_plan*`` pair: each layer group is averaged over *only the clients
whose plan bit for it is set*, with its own weight denominator
(``plan_group_denominators``); a group nobody trained keeps the frozen global
verbatim.  A homogeneous plan reproduces the single-group paths bit-for-bit
(tests/test_plans.py).

Transmission compression (``core.compress``, docs/COMPRESSION.md) composes
*upstream* of everything here: clients quantise their transmitted leaves and
the server view is reconstructed as ``global + decode(codes)`` **before**
averaging, so every path in this module — including the plan-aware splices
and their zero-trainer ``jnp.where`` freeze — consumes decompressed values
unchanged.  In particular a group nobody trained still keeps the frozen
global bit-for-bit even while other groups' error-feedback residuals are
active: untransmitted leaves never enter an average or consume residual.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masking
from repro.core.partition import Partition

PyTree = Any

# Path components that denote client-local statistics (never aggregated).
LOCAL_STAT_KEYS = ("mean_ema", "var_ema", "num_batches")


def is_local_stat(path: str) -> bool:
    return any(path.endswith(k) or f"/{k}" in path for k in LOCAL_STAT_KEYS)


def _normalized_weights(num: int, weights: Sequence[float] | None) -> list[float]:
    if weights is None:
        return [1.0 / num] * num
    if len(weights) != num:
        raise ValueError(f"{len(weights)} weights for {num} client trees")
    total = float(sum(weights))
    if total <= 0.0:
        raise ValueError(f"client weights must sum to a positive value, got {total}")
    return [float(x) / total for x in weights]


def debias_weights(weights: np.ndarray,
                   inclusion_probs: np.ndarray) -> np.ndarray:
    """Horvitz–Thompson debiasing: divide each client's aggregation weight
    by its inclusion probability, so availability-biased cohort selection
    (docs/ASYNC.md) leaves the *expected* global objective unbiased — a
    rarely-on client counts more when it does land.

    With every probability exactly 1.0 (uniform availability, or the blind
    sampler's default) the input array is returned unchanged — today's
    uniform weights bit-for-bit, the degenerate contract the async
    equivalence tests pin.

    >>> debias_weights(np.array([2.0, 4.0]), np.array([1.0, 1.0]))
    array([2., 4.])
    >>> debias_weights(np.array([2.0, 4.0]), np.array([0.5, 1.0]))
    array([4., 4.])
    """
    probs = np.asarray(inclusion_probs, dtype=np.float64)
    if probs.shape != np.shape(weights):
        raise ValueError(f"{probs.shape} inclusion probs for "
                         f"{np.shape(weights)} weights")
    if ((probs <= 0.0) | (probs > 1.0)).any():
        raise ValueError("inclusion probabilities must lie in (0, 1]")
    if (probs == 1.0).all():
        return weights
    return (np.asarray(weights, dtype=np.float64) / probs).astype(
        np.asarray(weights).dtype)


def tree_mean(trees: Sequence[PyTree], weights: Sequence[float] | None = None) -> PyTree:
    """Weighted elementwise mean of same-structure pytrees."""
    w = _normalized_weights(len(trees), weights)

    def _avg(*leaves):
        acc = jnp.zeros_like(leaves[0], dtype=jnp.float32)
        for wi, leaf in zip(w, leaves):
            acc = acc + wi * leaf.astype(jnp.float32)
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(_avg, *trees)


def tree_mean_stacked(
    stacked: PyTree, weights: jax.Array | Sequence[float] | None = None
) -> PyTree:
    """Weighted mean over the leading *client* axis of a stacked pytree.

    One ``tensordot`` per leaf — runs entirely on device, so the batched
    engine's aggregation compiles into a single dispatch.
    """
    num = jax.tree.leaves(stacked)[0].shape[0]
    if weights is None:
        w = jnp.full((num,), 1.0 / num, dtype=jnp.float32)
    else:
        w = jnp.asarray(weights, dtype=jnp.float32)
        if w.shape != (num,):
            raise ValueError(f"weights shape {w.shape} != ({num},)")
        if not isinstance(w, jax.core.Tracer) and float(jnp.sum(w)) <= 0.0:
            # Traced weights can't be value-checked here; the vmap engine
            # guards them host-side before dispatch (batched.run_round).
            raise ValueError(
                f"client weights must sum to a positive value, got {float(jnp.sum(w))}"
            )
        w = w / jnp.sum(w)

    def _avg(leaf):
        out = jnp.tensordot(w, leaf.astype(jnp.float32), axes=1)
        return out.astype(leaf.dtype)

    return jax.tree.map(_avg, stacked)


def _splice_skipping_local_stats(global_params: PyTree, averaged: PyTree) -> PyTree:
    """Take ``averaged`` leaves except at client-local-stat paths (keep global)."""

    def _choose(path, g_leaf, a_leaf):
        p = "/".join(masking._entry_str(e) for e in path)
        return g_leaf if is_local_stat(p) else a_leaf

    return jax.tree_util.tree_map_with_path(_choose, global_params, averaged)


def drop_local_stats(tree: PyTree, _prefix: str = "") -> PyTree:
    """Prune client-local-stat leaves from a (possibly pruned) dict pytree."""
    if not isinstance(tree, dict):
        return tree
    out = {}
    for k, v in tree.items():
        path = f"{_prefix}/{k}" if _prefix else str(k)
        if is_local_stat(path):
            continue
        sub = drop_local_stats(v, path)
        if isinstance(sub, dict) and not sub:
            continue
        out[k] = sub
    return out


def aggregate_full(
    global_params: PyTree,
    client_params: Sequence[PyTree],
    weights: Sequence[float] | None = None,
) -> PyTree:
    """FNU aggregation: average everything except client-local statistics."""
    averaged = tree_mean(client_params, weights)
    return _splice_skipping_local_stats(global_params, averaged)


def aggregate_partial(
    global_params: PyTree,
    client_subtrees: Sequence[PyTree],
    weights: Sequence[float] | None = None,
) -> PyTree:
    """Partial aggregation: average the pruned trainable subtrees and splice.

    ``client_subtrees`` are pruned pytrees (``masking.select`` output) holding
    only the round's trainable group.  Only those bytes ever travel — this is
    the paper's Eq. 5 comm saving.  BN running moments inside the group stay
    client-local and are excluded from the splice.
    """
    averaged = drop_local_stats(tree_mean(client_subtrees, weights))
    return masking.tree_update(global_params, averaged)


def aggregate_full_stacked(
    global_params: PyTree,
    stacked_params: PyTree,
    weights: jax.Array | Sequence[float] | None = None,
) -> PyTree:
    """``aggregate_full`` over a stacked (client-axis) tree, on device."""
    averaged = tree_mean_stacked(stacked_params, weights)
    return _splice_skipping_local_stats(global_params, averaged)


def aggregate_partial_stacked(
    global_params: PyTree,
    stacked_params: PyTree,
    partition: Partition,
    group: int,
    weights: jax.Array | Sequence[float] | None = None,
) -> PyTree:
    """``aggregate_partial`` over stacked *full* client params, on device.

    Selects the trainable group under the client axis (path-based, so the
    leading axis is transparent), averages with one reduction per leaf, and
    splices — BN running moments excluded exactly as in the host path.
    """
    sub = masking.select(stacked_params, partition, group)
    averaged = drop_local_stats(tree_mean_stacked(sub, weights))
    return masking.tree_update(global_params, averaged)


# ---------------------------------------------------------------------------
# Per-client layer plans (heterogeneous cohorts — docs/HETEROGENEITY.md)
# ---------------------------------------------------------------------------

def plan_group_denominators(
    plan: Any, weights: Sequence[float] | Any
) -> np.ndarray:
    """Per-group aggregation denominators under a per-client layer plan.

    ``plan`` is the ``(clients, M)`` bool bitmask (``PlanAssigner.assign``),
    ``weights`` the raw per-client sample weights.  Group ``g``'s denominator
    is the sum of the weights of exactly the clients that trained ``g`` —
    the quantity every plan-aware aggregation path divides by.  A group
    nobody trained has denominator 0 (and keeps the frozen global verbatim).
    """
    p = np.asarray(plan, dtype=np.float32)
    w = np.asarray(weights, dtype=np.float32)
    if p.ndim != 2 or w.shape != (p.shape[0],):
        raise ValueError(f"plan {p.shape} / weights {w.shape} mismatch")
    return w @ p


def aggregate_plan(
    global_params: PyTree,
    client_subtrees: Sequence[PyTree],
    partition: Partition,
    plan: Any,
    weights: Sequence[float],
) -> PyTree:
    """Per-group participant-weighted aggregation (host list-of-pytrees path).

    ``client_subtrees[i]`` must contain (at least) client ``i``'s trained
    groups per ``plan``.  Each layer group is averaged over **only the
    clients whose plan row sets its bit**, with its own weight denominator;
    a group nobody trained keeps the frozen global verbatim.  BN running
    moments never travel, exactly as in the homogeneous paths.
    """
    p = np.asarray(plan, dtype=bool)
    if len(client_subtrees) != p.shape[0]:
        raise ValueError(
            f"{len(client_subtrees)} client trees for plan of {p.shape[0]}")
    new_params = global_params
    for g in range(p.shape[1]):
        members = np.flatnonzero(p[:, g])
        if members.size == 0:
            continue                      # zero-trainer group: frozen global
        subs = [masking.select(client_subtrees[i], partition, g)
                for i in members]
        averaged = drop_local_stats(
            tree_mean(subs, [float(weights[i]) for i in members]))
        new_params = masking.tree_update(new_params, averaged)
    return new_params


def aggregate_plan_stacked(
    global_params: PyTree,
    stacked_params: PyTree,
    partition: Partition,
    plan: Any,
    weights: jax.Array | Sequence[float],
) -> PyTree:
    """``aggregate_plan`` over stacked full client params, on device.

    One weighted reduction per leaf: leaf in group ``g`` is averaged with
    the plan-masked weights ``w * plan[:, g]`` normalised by that group's
    own denominator.  ``jnp.where`` on the (host-static-shaped, traced-value)
    denominator keeps a zero-trainer group's leaves *bit-identical* to the
    frozen global.  With a homogeneous plan (every row == the round mask)
    the arithmetic collapses to ``aggregate_{full,partial}_stacked``'s
    normalise-then-tensordot, which is what makes the legacy paths a special
    case rather than a parallel implementation (tests/test_plans.py pins
    both properties).
    """
    num = jax.tree.leaves(stacked_params)[0].shape[0]
    plan_f = jnp.asarray(plan, dtype=jnp.float32)
    w = jnp.asarray(weights, dtype=jnp.float32)
    if plan_f.shape != (num, partition.num_groups) or w.shape != (num,):
        raise ValueError(
            f"plan {plan_f.shape} / weights {w.shape} do not match "
            f"{num} stacked clients x {partition.num_groups} groups")
    eff = w[:, None] * plan_f                       # (clients, M)
    denom = jnp.sum(eff, axis=0)                    # (M,) per-group weight sums

    def _leaf(path, g_leaf, s_leaf):
        p = "/".join(masking._entry_str(e) for e in path)
        if is_local_stat(p):
            return g_leaf
        g = partition.group_of(p)
        trained = denom[g] > 0
        wg = eff[:, g] / jnp.where(trained, denom[g], 1.0)
        avg = jnp.tensordot(wg, s_leaf.astype(jnp.float32), axes=1)
        return jnp.where(trained, avg.astype(g_leaf.dtype), g_leaf)

    return jax.tree_util.tree_map_with_path(_leaf, global_params, stacked_params)


def broadcast(global_params: PyTree, num_clients: int) -> list[PyTree]:
    """Server -> clients: each client receives a copy of the global model."""
    return [jax.tree.map(lambda x: x, global_params) for _ in range(num_clients)]
