"""Server-side aggregation (full and partial).

FNU rounds average every parameter; partial rounds average only the trainable
group's (pruned) subtrees and splice them into the global model.  Per the
paper (§4, following FedBN), client-local statistics (BatchNorm running
moments) are *never* aggregated — they are filtered by path suffix.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import masking
from repro.core.partition import Partition

PyTree = Any

# Path components that denote client-local statistics (never aggregated).
LOCAL_STAT_KEYS = ("mean_ema", "var_ema", "num_batches")


def is_local_stat(path: str) -> bool:
    return any(path.endswith(k) or f"/{k}" in path for k in LOCAL_STAT_KEYS)


def tree_mean(trees: Sequence[PyTree], weights: Sequence[float] | None = None) -> PyTree:
    """Weighted elementwise mean of same-structure pytrees."""
    if weights is None:
        w = [1.0 / len(trees)] * len(trees)
    else:
        total = float(sum(weights))
        w = [float(x) / total for x in weights]

    def _avg(*leaves):
        acc = jnp.zeros_like(leaves[0], dtype=jnp.float32)
        for wi, leaf in zip(w, leaves):
            acc = acc + wi * leaf.astype(jnp.float32)
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(_avg, *trees)


def aggregate_full(
    global_params: PyTree,
    client_params: Sequence[PyTree],
    weights: Sequence[float] | None = None,
) -> PyTree:
    """FNU aggregation: average everything except client-local statistics."""
    averaged = tree_mean(client_params, weights)

    # Splice averaged leaves into global, skipping local-stat paths.
    def _choose(path, g_leaf, a_leaf):
        p = "/".join(masking._entry_str(e) for e in path)
        return g_leaf if is_local_stat(p) else a_leaf

    return jax.tree_util.tree_map_with_path(_choose, global_params, averaged)


def aggregate_partial(
    global_params: PyTree,
    client_subtrees: Sequence[PyTree],
    weights: Sequence[float] | None = None,
) -> PyTree:
    """Partial aggregation: average the pruned trainable subtrees and splice.

    ``client_subtrees`` are pruned pytrees (``masking.select`` output) holding
    only the round's trainable group.  Only those bytes ever travel — this is
    the paper's Eq. 5 comm saving.
    """
    averaged = tree_mean(client_subtrees, weights)
    return masking.tree_update(global_params, averaged)


def broadcast(global_params: PyTree, num_clients: int) -> list[PyTree]:
    """Server -> clients: each client receives a copy of the global model."""
    return [jax.tree.map(lambda x: x, global_params) for _ in range(num_clients)]
