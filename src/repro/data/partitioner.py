"""Client data partitioners: IID and Dirichlet(α) label-skew (paper §4 /
Table 4: α=1, Appendix F.3: α=0.1)."""

from __future__ import annotations

import numpy as np


def iid_partition(
    num_samples: int, num_clients: int, seed: int = 0
) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(num_samples)
    return [np.sort(chunk) for chunk in np.array_split(perm, num_clients)]


def dirichlet_partition(
    labels: np.ndarray, num_clients: int, alpha: float, seed: int = 0,
    min_per_client: int = 2,
) -> list[np.ndarray]:
    """Label-skew partition: for each class, distribute its samples across
    clients with proportions ~ Dirichlet(alpha)."""
    rng = np.random.default_rng(seed)
    num_classes = int(labels.max()) + 1
    client_idx: list[list[int]] = [[] for _ in range(num_clients)]
    for c in range(num_classes):
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(num_clients, alpha))
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for client, chunk in enumerate(np.split(idx, cuts)):
            client_idx[client].extend(chunk.tolist())
    # Guarantee a minimum per client by stealing from the largest.
    sizes = [len(ci) for ci in client_idx]
    for i in range(num_clients):
        while len(client_idx[i]) < min_per_client:
            donor = int(np.argmax([len(ci) for ci in client_idx]))
            client_idx[i].append(client_idx[donor].pop())
    return [np.sort(np.array(ci, dtype=np.int64)) for ci in client_idx]


def partition_stats(parts: list[np.ndarray], labels: np.ndarray) -> np.ndarray:
    """(clients, classes) count matrix — for heterogeneity diagnostics."""
    num_classes = int(labels.max()) + 1
    out = np.zeros((len(parts), num_classes), np.int64)
    for i, idx in enumerate(parts):
        for c, n in zip(*np.unique(labels[idx], return_counts=True)):
            out[i, int(c)] = n
    return out
