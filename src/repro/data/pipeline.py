"""Batching pipeline for the FL simulation: per-client epoch iterators with
deterministic shuffling, plus a balanced held-out eval set (the paper tests
the global model on a balanced set)."""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class ClientDataset:
    inputs: np.ndarray      # images (N,H,W,C) or tokens (N,S)
    labels: np.ndarray      # (N,)

    def __len__(self) -> int:
        return len(self.labels)

    def batches(
        self, batch_size: int, epochs: int, seed: int
    ) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        """``epochs`` passes of shuffled, truncated-to-full batches (at least
        one batch per epoch even if the client has < batch_size samples)."""
        rng = np.random.default_rng(seed)
        n = len(self)
        for _ in range(epochs):
            order = rng.permutation(n)
            bs = min(batch_size, n)
            for start in range(0, max(n - bs + 1, 1), bs):
                idx = order[start : start + bs]
                yield self.inputs[idx], self.labels[idx]


def build_clients(
    inputs: np.ndarray, labels: np.ndarray, parts: list[np.ndarray]
) -> list[ClientDataset]:
    return [ClientDataset(inputs[p], labels[p]) for p in parts]


def balanced_eval_set(
    inputs: np.ndarray, labels: np.ndarray, per_class: int, seed: int = 1
) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    picks = []
    for c in np.unique(labels):
        idx = np.where(labels == c)[0]
        picks.append(rng.choice(idx, size=min(per_class, len(idx)), replace=False))
    sel = np.concatenate(picks)
    rng.shuffle(sel)
    return inputs[sel], labels[sel]
